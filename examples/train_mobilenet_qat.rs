//! End-to-end driver (the EXPERIMENTS.md §End-to-end run): the full paper
//! workflow on MobileNetV2 at 3-bit weights —
//!
//!   FP pretrain → MSE range init → QAT baseline (LSQ)
//!                                → QAT + iterative weight freezing
//!   each followed by pre/post BN-re-estimation evaluation,
//!   with the loss curve logged to results/e2e_loss_curve.csv.
//!
//!     cargo run --release --example train_mobilenet_qat
//!
//! Runs on the native backend out of the box; prefers the PJRT artifacts
//! when `make artifacts` has produced them.

use anyhow::Result;
use oscillations_qat::coordinator::experiment::{Lab, QatSpec};
use oscillations_qat::coordinator::Schedule;
use oscillations_qat::runtime::auto_backend;
use std::path::Path;

fn main() -> Result<()> {
    let be = auto_backend(Path::new("artifacts"))?;
    let mut lab = Lab::new(be.as_ref());
    lab.fp_steps = std::env::var("E2E_FP_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(600);
    lab.qat_steps = std::env::var("E2E_QAT_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(400);
    lab.seeds = vec![0];

    println!("== end-to-end: MobileNetV2, 3-bit weights ==");
    let t0 = std::time::Instant::now();

    let baseline = lab.run_qat(&QatSpec::weight_only("mbv2", 3, 0))?;
    baseline.run.history.save_csv(Path::new("results/e2e_loss_curve.csv"))?;
    println!(
        "LSQ baseline : pre-BN {:.2}%  post-BN {:.2}%  osc {:.2}%  ({:.1} steps/s)",
        baseline.pre_bn_acc, baseline.post_bn_acc, baseline.osc_pct,
        baseline.run.steps_per_sec
    );

    let freeze = lab.run_qat(&QatSpec {
        f_th: Schedule::Cosine { from: 0.04, to: 0.01 },
        ..QatSpec::weight_only("mbv2", 3, 0)
    })?;
    freeze.run.history.save_csv(Path::new("results/e2e_loss_curve_freeze.csv"))?;
    println!(
        "LSQ + Freeze : pre-BN {:.2}%  post-BN {:.2}%  osc {:.2}%  frozen {:.2}%",
        freeze.pre_bn_acc, freeze.post_bn_acc, freeze.osc_pct, freeze.frozen_pct
    );

    println!("\nloss curves -> results/e2e_loss_curve*.csv");
    println!("total wall-clock {:.1?}", t0.elapsed());

    // the paper's two claims, checked end to end:
    assert!(
        baseline.post_bn_acc >= baseline.pre_bn_acc - 1.0,
        "BN re-estimation should not hurt"
    );
    assert!(
        freeze.osc_pct <= baseline.osc_pct,
        "freezing must reduce oscillations ({:.2}% vs {:.2}%)",
        freeze.osc_pct,
        baseline.osc_pct
    );
    println!("end-to-end invariants OK");
    Ok(())
}
