//! The paper's §2.2 toy problem, end to end: watch a single latent weight
//! oscillate around the decision boundary under the STE, see that the
//! multiplicative estimators (EWGS/DSQ/PSG) cannot stop it, and that the
//! additive dampening term can (appendix A.1).
//!
//!     cargo run --release --example toy_oscillations

use oscillations_qat::toy::{run, stats, ToyCfg, ToyEstimator};

fn sparkline(traj: &[(f32, f32)], s: f32) -> String {
    // map integer states to characters for a quick terminal trace
    traj.iter()
        .step_by(traj.len() / 120 + 1)
        .map(|&(_, q)| match (q / s).round() as i64 {
            3 => '▆',
            2 => '▂',
            _ => '.',
        })
        .collect()
}

fn main() {
    let ests: Vec<(&str, ToyEstimator)> = vec![
        ("STE", ToyEstimator::Ste),
        ("EWGS δ=0.2", ToyEstimator::Ewgs { delta: 0.2 }),
        ("DSQ k=5", ToyEstimator::Dsq { k: 5.0 }),
        ("PSG ε=0.01", ToyEstimator::Psg { eps: 0.01 }),
        ("Dampen λ=0.6", ToyEstimator::Dampen { lambda: 0.6 }),
    ];
    println!("w* = 0.252, grid step s = 0.1 → optimum between states 2 and 3\n");
    for (name, est) in ests {
        let cfg = ToyCfg { est, steps: 1200, ..Default::default() };
        let traj = run(&cfg);
        let st = stats(&traj, 300, cfg.s);
        println!("{name:<14} freq {:>6.4}  amp {:>7.5}  up-frac {:>5.3}", st.freq,
                 st.amplitude, st.frac_up);
        println!("  {}", sparkline(&traj, cfg.s));
    }
    println!("\nFrequency ∝ distance (appendix A.2):");
    for d in [0.04f32, 0.02, 0.01, 0.005] {
        let cfg = ToyCfg { w_star: 0.25 + d, steps: 6000, ..Default::default() };
        let st = stats(&run(&cfg), 1000, cfg.s);
        println!("  d/s = {:<5.3} -> freq {:.4}", d / cfg.s, st.freq);
    }
    println!("\nLearning rate moves amplitude, not frequency (appendix A.3):");
    for lr in [0.02f32, 0.01, 0.005] {
        let cfg = ToyCfg { lr, steps: 8000, ..Default::default() };
        let st = stats(&run(&cfg), 2000, cfg.s);
        println!("  lr = {lr:<6} -> freq {:.4}  amplitude {:.5}", st.freq, st.amplitude);
    }
}
