//! Quickstart: load an AOT artifact, run a handful of QAT steps, inspect
//! the oscillation telemetry.
//!
//!     make artifacts            # once (python, build time)
//!     cargo run --release --example quickstart
//!
//! This is the smallest end-to-end path through the stack: Rust loads the
//! HLO text the JAX/Pallas layers produced, compiles it on the PJRT CPU
//! client, and drives a few training steps with all state owned host-side.

use anyhow::Result;
use oscillations_qat::coordinator::{RunCfg, Trainer};
use oscillations_qat::osc;
use oscillations_qat::runtime::Runtime;
use std::path::Path;

fn main() -> Result<()> {
    let rt = Runtime::new(Path::new("artifacts"))?;
    println!("models in index: {:?}", rt.index.models.keys().collect::<Vec<_>>());

    let model = "mbv2";
    let info = rt.index.model(model)?;
    println!(
        "{model}: {} params, {} low-bit weight tensors, depthwise layers {:?}",
        info.param_count,
        info.lowbit.len(),
        info.depthwise()
    );

    // initial state straight from the QTNS the AOT step dumped
    let state = rt.initial_state(model)?;
    println!("state tensors: {} ({} elements)", state.len(), state.num_elements());

    // 20 QAT steps at 3-bit weights, oscillation tracking on
    let trainer = Trainer::new(&rt);
    let mut cfg = RunCfg::qat(model, 20, 3, 0);
    cfg.quant_w = true;
    cfg.log_every = 5;
    let out = trainer.train(state, &cfg)?;

    for row in &out.history.rows {
        println!(
            "step {:>3}  loss {:.4}  acc {:.3}  osc {:.4}  frozen {:.4}",
            row[0], row[1], row[4], row[5], row[6]
        );
    }
    let summary = osc::summarize(&out.state, &info.lowbit);
    println!(
        "after 20 steps: {:.2}% of {} low-bit weights oscillating ({:.1} steps/s)",
        summary.osc_pct(),
        summary.total_weights,
        out.steps_per_sec
    );
    Ok(())
}
