//! Quickstart: pick a backend, run a handful of QAT steps, inspect the
//! oscillation telemetry.
//!
//!     cargo run --release --example quickstart
//!
//! With no `artifacts/` directory this runs on the pure-Rust native
//! backend out of the box. After `make artifacts` (python, build time) the
//! same example drives the compiled PJRT artifacts instead: Rust loads the
//! HLO text the JAX/Pallas layers produced, compiles it on the PJRT CPU
//! client, and owns all state host-side either way.

use anyhow::Result;
use oscillations_qat::coordinator::{RunCfg, Trainer};
use oscillations_qat::osc;
use oscillations_qat::runtime::auto_backend;
use std::path::Path;

fn main() -> Result<()> {
    let be = auto_backend(Path::new("artifacts"))?;
    let be = be.as_ref();
    println!("backend: {}", be.kind());
    println!("models in index: {:?}", be.index().models.keys().collect::<Vec<_>>());

    let model = "mbv2";
    let info = be.index().model(model)?;
    println!(
        "{model}: {} params, {} low-bit weight tensors, depthwise layers {:?}",
        info.param_count,
        info.lowbit.len(),
        info.depthwise()
    );

    // fresh initial state (QTNS dump on PJRT, procedural on native)
    let state = be.initial_state(model)?;
    println!("state tensors: {} ({} elements)", state.len(), state.num_elements());

    // 20 QAT steps at 3-bit weights, oscillation tracking on
    let trainer = Trainer::new(be);
    let mut cfg = RunCfg::qat(model, 20, 3, 0);
    cfg.quant_w = true;
    cfg.log_every = 5;
    let out = trainer.train(state, &cfg)?;

    for row in &out.history.rows {
        println!(
            "step {:>3}  loss {:.4}  acc {:.3}  osc {:.4}  frozen {:.4}",
            row[0], row[1], row[4], row[5], row[6]
        );
    }
    let summary = osc::summarize(&out.state, &info.lowbit);
    println!(
        "after 20 steps: {:.2}% of {} low-bit weights oscillating ({:.1} steps/s)",
        summary.osc_pct(),
        summary.total_weights,
        out.steps_per_sec
    );
    for t in &summary.per_tensor {
        println!(
            "  {:<10} {:>5} weights  osc {:>6.2}%  frozen {:>6.2}%",
            t.name,
            t.total,
            t.osc_pct(),
            t.frozen_pct()
        );
    }
    Ok(())
}
