//! End-to-end deployment: QAT-train a zoo model, export it as a
//! BN-folded bit-packed integer artifact, and serve batched requests
//! from the packed engine.
//!
//!     cargo run --release --example deploy_pipeline
//!
//! Prints the export size report, the top-1 agreement between the
//! integer engine and the simulated fake-quant eval path, and the
//! serving throughput/latency summary.

use anyhow::Result;
use oscillations_qat::coordinator::evaluator::EvalQuant;
use oscillations_qat::coordinator::{bn_restim, qat, RunCfg, Schedule, Trainer};
use oscillations_qat::data::{DataCfg, Dataset};
use oscillations_qat::deploy::export::{export_model, ExportCfg};
use oscillations_qat::deploy::serve::{bench_serve, ServeCfg};
use oscillations_qat::deploy::Engine;
use oscillations_qat::runtime::native::model::zoo_model;
use oscillations_qat::runtime::{Backend, NativeBackend};
use oscillations_qat::state::NamedTensors;
use std::sync::Arc;

fn main() -> Result<()> {
    let be = NativeBackend::new();
    let model = "efflite";
    let bits = 4;
    let data = DataCfg { val_size: 64, ..Default::default() };

    // --- QAT train (short run) + BN re-estimation ----------------------
    println!("training {model} at w{bits}a{bits} (short run)...");
    let trainer = Trainer::new(&be);
    let mut fp = RunCfg::fp(model, 60, 0.02, 0);
    fp.data = data.clone();
    let run = trainer.train(be.initial_state(model)?, &fp)?;
    let mut state = run.state;
    qat::prepare_qat(&be, &mut state, model, bits, bits, &data, 0)?;
    let mut cfg = RunCfg::qat(model, 80, bits, 0);
    cfg.quant_a = true;
    cfg.data = data.clone();
    cfg.f_th = Schedule::Cosine { from: 0.04, to: 0.01 };
    cfg.m_osc = 0.1;
    let run = trainer.train(state, &cfg)?;
    let mut state = run.state;
    let q = EvalQuant::full(bits);
    bn_restim::reestimate(&be, &mut state, model, q, &data, 0, 8)?;

    // --- export: BN fold + grid snap + bit-pack ------------------------
    let nm = zoo_model(model).expect("zoo model");
    let ecfg = ExportCfg { bits_w: bits, bits_a: bits, quant_a: true };
    let (dm, report) = export_model(&nm, &state, &ecfg)?;
    println!(
        "exported {} layers, {} weights ({} frozen verified on-grid): \
         packed {} B vs f32 {} B = ratio {:.3}",
        report.layers,
        report.total_weights,
        report.frozen_verified,
        report.packed_bytes,
        report.f32_bytes,
        report.ratio()
    );

    // --- agreement with the simulated eval path ------------------------
    let info = be.index().model(model)?.clone();
    let hyper = q.hyper();
    let ds = Dataset::new(data.clone());
    // decode-once: the packed payloads are unpacked one time here, and
    // every serving worker below shares the same cached planes
    let engine = Arc::new(Engine::new(dm));
    println!(
        "prepared planes: {} B cached on top of {} B packed",
        engine.prepared().plane_bytes(),
        engine.model().packed_weight_bytes()
    );
    let d_in = engine.model().d_in();
    let (mut agree, mut total) = (0usize, 0usize);
    let mut inputs: Vec<Vec<f32>> = vec![];
    for bch in ds.val_batches() {
        let b = bch.x.shape[0];
        let mut io = NamedTensors::new();
        io.insert("batch/x", bch.x.clone());
        io.insert("batch/y", bch.y.clone());
        let out = be.execute(&info.artifacts["eval"], &[&state, &io, &hyper])?;
        let ref_pred = out.expect("pred")?;
        let got = engine.predict_batch(&bch.x.data, b)?;
        for i in 0..b {
            total += 1;
            if got[i] == ref_pred.data[i] as usize {
                agree += 1;
            }
            inputs.push(bch.x.data[i * d_in..(i + 1) * d_in].to_vec());
        }
    }
    println!(
        "integer engine vs fake-quant eval: {}/{} top-1 agreement ({:.1}%)",
        agree,
        total,
        100.0 * agree as f64 / total.max(1) as f64
    );

    // --- batched serving -----------------------------------------------
    let scfg = ServeCfg { workers: 4, max_batch: 16, queue_cap: 256 };
    let sreport = bench_serve(engine, &scfg, &inputs)?;
    println!("{}", sreport.summary());
    Ok(())
}
