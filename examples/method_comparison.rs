//! Method comparison on one model/bit-width (a single Table-6-style row
//! block): LSQ baseline vs EWGS vs dampening vs freezing, weight+act
//! quantization.
//!
//!     cargo run --release --example method_comparison -- [bits] [steps]

use anyhow::Result;
use oscillations_qat::analysis::report::TableRenderer;
use oscillations_qat::coordinator::experiment::{Lab, QatSpec};
use oscillations_qat::coordinator::Schedule;
use oscillations_qat::runtime::auto_backend;
use std::path::Path;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let bits: u32 = argv.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let steps: u64 = argv.get(2).and_then(|s| s.parse().ok()).unwrap_or(300);

    let be = auto_backend(Path::new("artifacts"))?;
    let mut lab = Lab::new(be.as_ref());
    lab.qat_steps = steps;
    lab.seeds = vec![0];

    let mut table = TableRenderer::new(
        &format!("MobileNetV2 W{bits}/A{bits} method comparison ({steps} steps)"),
        &["Method", "post-BN acc (%)", "Osc (%)", "Frozen (%)"],
    );
    let methods: Vec<(&str, QatSpec)> = vec![
        ("LSQ", QatSpec::full("mbv2", bits, 0)),
        ("EWGS", QatSpec { estimator: "ewgs".into(), ..QatSpec::full("mbv2", bits, 0) }),
        (
            "LSQ + Dampen",
            QatSpec {
                lam: Schedule::Cosine { from: 0.0, to: 1e-2 },
                ..QatSpec::full("mbv2", bits, 0)
            },
        ),
        (
            "LSQ + Freeze",
            QatSpec {
                f_th: Schedule::Cosine { from: 0.04, to: 0.01 },
                ..QatSpec::full("mbv2", bits, 0)
            },
        ),
    ];
    for (name, spec) in methods {
        let out = lab.run_qat(&spec)?;
        table.row(vec![
            name.into(),
            format!("{:.2}", out.post_bn_acc),
            format!("{:.2}", out.osc_pct),
            format!("{:.2}", out.frozen_pct),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}
