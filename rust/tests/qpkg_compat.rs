//! QPKG backward compatibility: a **committed version-1 fixture**
//! (written by the PR-2 era scalar-scale serializer; layout pinned in
//! `deploy/format.rs`) must keep loading after the format moved to
//! version 2, upgrading its per-layer `f32 w_scale` to a one-element
//! scale vector — and re-saving it must produce a valid v2 file with
//! identical content.

use oscillations_qat::deploy::format::{DeployModel, DeployOp};
use std::path::PathBuf;

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/tiny_v1.qpkg")
}

#[test]
fn committed_v1_fixture_loads_and_upgrades() {
    let m = DeployModel::read_qpkg(&fixture_path()).expect("v1 fixture must load");

    // header fields survive
    assert_eq!(m.name, "tiny");
    assert_eq!(m.input_hw, 2);
    assert_eq!(m.num_classes, 3);
    assert!(m.quant_a);
    assert_eq!(m.bits_w, 3);
    assert_eq!(m.bits_a, 3);
    assert_eq!(m.layers.len(), 2);

    // layer 0: dense stem with a folded-BN requant, scalar scale upgraded
    let stem = &m.layers[0];
    assert_eq!(stem.name, "stem");
    assert_eq!(stem.op, DeployOp::Full);
    assert_eq!((stem.d_in, stem.d_out), (12, 3));
    assert!(stem.relu && !stem.aq);
    assert_eq!(stem.w_bits, 3);
    assert_eq!(stem.w_scales, vec![0.1], "v1 scalar must upgrade to a 1-vector");
    assert!(!stem.per_channel());
    assert_eq!(stem.a_scale, 1.0);
    let rq = stem.requant.as_ref().expect("stem requant");
    assert_eq!(rq.mult, vec![1.0, 0.5, 2.0]);
    assert_eq!(rq.add, vec![0.0, -0.1, 0.2]);
    assert!(stem.bias.is_none());
    // packed 3-bit codes decode to the values the v1 writer packed
    let codes = stem.weights.unpack();
    assert_eq!(codes.len(), 36);
    for (i, &c) in codes.iter().enumerate() {
        assert_eq!(c, (i % 8) as u32, "code {i}");
    }

    // layer 1: depthwise head with bias, quantized activations
    let head = &m.layers[1];
    assert_eq!(head.name, "head");
    assert_eq!(head.op, DeployOp::Dw);
    assert!(head.aq && !head.relu);
    assert_eq!(head.w_bits, 4);
    assert_eq!(head.act_bits, 3);
    assert_eq!(head.w_scales, vec![0.2]);
    assert_eq!(head.a_scale, 0.05);
    assert_eq!(head.bias.as_deref(), Some(&[0.1, 0.2, 0.3][..]));
    assert!(head.requant.is_none());
    assert_eq!(head.weights.unpack(), vec![1, 2, 3, 4, 5, 6, 7, 8, 9]);

    // re-serializing writes version 2 and round-trips the same model
    let v2_bytes = m.to_bytes();
    assert_eq!(&v2_bytes[..4], b"QPKG");
    assert_eq!(u32::from_le_bytes(v2_bytes[4..8].try_into().unwrap()), 2);
    let m2 = DeployModel::from_bytes(&v2_bytes).expect("upgraded model must round-trip");
    assert_eq!(m, m2);

    // and the raw fixture really is version 1 on disk
    let raw = std::fs::read(fixture_path()).unwrap();
    assert_eq!(u32::from_le_bytes(raw[4..8].try_into().unwrap()), 1);
    assert_ne!(raw, v2_bytes, "v2 layout must differ from the v1 bytes");
}
