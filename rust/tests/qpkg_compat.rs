//! QPKG backward compatibility: **committed fixtures for every historic
//! version** must keep loading after the format moved to version 3.
//!
//! * `tiny_v1.qpkg` — PR-2 era scalar-scale serializer (single `f32
//!   w_scale` + single `f32 a_scale` per layer);
//! * `tiny_v2.qpkg` — PR-3 era serializer (counted per-channel
//!   `w_scales` array + single `f32 a_scale` per layer).
//!
//! The v1 -> v3 and v2 -> v3 upgrade matrix checks header fields, the
//! upgraded scale-array lengths (weight *and* activation), the packed
//! codes, and that the dequantized weight planes are **bit-identical**
//! after the upgrade; re-saving any upgraded model must produce a valid
//! version-3 file with identical content.

use oscillations_qat::deploy::format::{DeployModel, DeployOp};
use std::path::PathBuf;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(format!("tests/fixtures/{name}"))
}

/// Header + structure assertions shared by every upgraded fixture: both
/// files describe the same tiny two-layer model, differing only in their
/// scale payloads.
fn assert_common_structure(m: &DeployModel) {
    assert_eq!(m.name, "tiny");
    assert_eq!(m.input_hw, 2);
    assert_eq!(m.num_classes, 3);
    assert!(m.quant_a);
    assert_eq!(m.bits_w, 3);
    assert_eq!(m.bits_a, 3);
    assert_eq!(m.layers.len(), 2);

    let stem = &m.layers[0];
    assert_eq!(stem.name, "stem");
    assert_eq!(stem.op, DeployOp::Full);
    assert_eq!((stem.d_in, stem.d_out), (12, 3));
    assert!(stem.relu && !stem.aq);
    assert_eq!(stem.w_bits, 3);
    assert_eq!(stem.act_bits, 8);
    let rq = stem.requant.as_ref().expect("stem requant");
    assert_eq!(rq.mult, vec![1.0, 0.5, 2.0]);
    assert_eq!(rq.add, vec![0.0, -0.1, 0.2]);
    assert!(stem.bias.is_none());
    let codes = stem.weights.unpack();
    assert_eq!(codes.len(), 36);
    for (i, &c) in codes.iter().enumerate() {
        assert_eq!(c, (i % 8) as u32, "stem code {i}");
    }

    let head = &m.layers[1];
    assert_eq!(head.name, "head");
    assert_eq!(head.op, DeployOp::Dw);
    assert!(head.aq && !head.relu);
    assert_eq!(head.w_bits, 4);
    assert_eq!(head.act_bits, 3);
    assert_eq!(head.bias.as_deref(), Some(&[0.1, 0.2, 0.3][..]));
    assert!(head.requant.is_none());
    assert_eq!(head.weights.unpack(), vec![1, 2, 3, 4, 5, 6, 7, 8, 9]);
}

/// Re-serializing an upgraded model must emit version 3 bytes that load
/// back to the identical struct.
fn assert_resaves_as_v3(m: &DeployModel, raw: &[u8]) {
    let v3_bytes = m.to_bytes();
    assert_eq!(&v3_bytes[..4], b"QPKG");
    assert_eq!(u32::from_le_bytes(v3_bytes[4..8].try_into().unwrap()), 3);
    let m2 = DeployModel::from_bytes(&v3_bytes).expect("upgraded model must round-trip");
    assert_eq!(m, &m2);
    assert_ne!(raw, &v3_bytes[..], "v3 layout must differ from the fixture bytes");
}

/// The dequantized weight planes of an upgraded model, layer by layer —
/// the bit pattern the engine actually serves from.
fn dequant_planes(m: &DeployModel) -> Vec<Vec<f32>> {
    m.layers
        .iter()
        .map(|l| {
            let mut out = Vec::new();
            l.weights
                .dequant_pc_into(l.grid_n_int(), &l.w_scales, l.scale_group(), &mut out);
            out
        })
        .collect()
}

#[test]
fn committed_v1_fixture_loads_and_upgrades() {
    let path = fixture_path("tiny_v1.qpkg");
    let m = DeployModel::read_qpkg(&path).expect("v1 fixture must load");
    assert_common_structure(&m);

    // v1 scalars upgrade to one-element scale vectors, weight and act
    assert_eq!(m.layers[0].w_scales, vec![0.1], "v1 w_scale must upgrade to a 1-vector");
    assert!(!m.layers[0].per_channel());
    assert_eq!(m.layers[0].a_scales, vec![1.0]);
    assert!(!m.layers[0].per_channel_act());
    assert_eq!(m.layers[1].w_scales, vec![0.2]);
    assert_eq!(m.layers[1].a_scales, vec![0.05], "v1 a_scale must upgrade to a 1-vector");

    // and the raw fixture really is version 1 on disk
    let raw = std::fs::read(&path).unwrap();
    assert_eq!(u32::from_le_bytes(raw[4..8].try_into().unwrap()), 1);
    assert_resaves_as_v3(&m, &raw);
}

#[test]
fn committed_v2_fixture_loads_and_upgrades() {
    let path = fixture_path("tiny_v2.qpkg");
    let m = DeployModel::read_qpkg(&path).expect("v2 fixture must load");
    assert_common_structure(&m);

    // v2 carries per-channel weight scales already; its single f32
    // a_scale upgrades to a one-element vector
    assert_eq!(m.layers[0].w_scales, vec![0.1, 0.07, 0.2]);
    assert!(m.layers[0].per_channel());
    assert_eq!(m.layers[0].a_scales, vec![1.0]);
    assert_eq!(m.layers[1].w_scales, vec![0.2, 0.15, 0.3]);
    assert!(m.layers[1].per_channel());
    assert_eq!(m.layers[1].a_scales, vec![0.05]);
    assert!(!m.layers[1].per_channel_act());
    assert_eq!(m.layers[1].w_scale_of(1), 0.15);

    let raw = std::fs::read(&path).unwrap();
    assert_eq!(u32::from_le_bytes(raw[4..8].try_into().unwrap()), 2);
    assert_resaves_as_v3(&m, &raw);
}

#[test]
fn upgrade_matrix_preserves_dequant_planes_bit_for_bit() {
    // the engine's operand is the dequantized weight plane: after any
    // upgrade (v1 -> v3, v2 -> v3, and the re-saved v3 of each) the
    // planes must be bit-identical to the in-memory model's
    for name in ["tiny_v1.qpkg", "tiny_v2.qpkg"] {
        let m = DeployModel::read_qpkg(&fixture_path(name)).unwrap();
        let planes = dequant_planes(&m);
        assert_eq!(planes[0].len(), 36, "{name}");
        assert_eq!(planes[1].len(), 9, "{name}");
        // resave as v3 and reload: planes unchanged to the bit
        let m3 = DeployModel::from_bytes(&m.to_bytes()).unwrap();
        assert_eq!(planes, dequant_planes(&m3), "{name} planes drifted across the upgrade");
        // spot-check the mapping: code c dequantizes to s_c * (c + gn)
        let stem = &m.layers[0];
        let gn = stem.grid_n_int();
        for (i, &v) in planes[0].iter().enumerate() {
            let s = stem.w_scales[i % stem.w_scales.len()];
            let want = s * (stem.weights.get(i) as i32 + gn) as f32;
            assert_eq!(v, want, "{name} stem plane [{i}]");
        }
    }
    // the two fixtures describe the same codes; only the v2 per-channel
    // scales change the plane values
    let m1 = DeployModel::read_qpkg(&fixture_path("tiny_v1.qpkg")).unwrap();
    let m2 = DeployModel::read_qpkg(&fixture_path("tiny_v2.qpkg")).unwrap();
    assert_eq!(m1.layers[0].weights, m2.layers[0].weights);
    assert_ne!(dequant_planes(&m1)[0], dequant_planes(&m2)[0]);
}
