//! Integration tests across the full stack: backend + coordinator.
//!
//! These run against the **native** backend, so they need no artifacts, no
//! Python and no XLA — `cargo test` on a fresh checkout exercises the full
//! paper pipeline (FP pretrain -> range init -> QAT with dampening and
//! freezing variants -> BN re-estimation -> eval) unconditionally.
//!
//! Structure: one #[test] entry point runs every sub-check sequentially
//! against a single backend (mirrors the PJRT suite layout, where the
//! !Send client forces this shape; here it simply keeps output ordered),
//! and a failing sub-check reports its name before the suite fails.

use oscillations_qat::coordinator::evaluator::{EvalQuant, Evaluator};
use oscillations_qat::coordinator::{bn_restim, qat, RunCfg, Schedule, Trainer};
use oscillations_qat::data::DataCfg;
use oscillations_qat::osc;
use oscillations_qat::runtime::{Backend, NativeBackend, Runtime};
use oscillations_qat::state::NamedTensors;
use oscillations_qat::tensor::Tensor;
use std::path::{Path, PathBuf};

fn small_data() -> DataCfg {
    DataCfg { val_size: 64, ..Default::default() }
}

/// Scratch dir for checkpoint caching — cleared on entry so a stale
/// checkpoint from a crashed earlier run (recycled pid) is never loaded.
fn scratch_dir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qat_integration_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn integration_suite() {
    // The native pass always runs: zero artifacts, zero skips.
    let native = NativeBackend::new();
    run_suite(&native, "native");

    // Bonus PJRT pass when `make artifacts` output is available (the
    // checks are backend-generic), so artifact-backed setups keep their
    // coverage of the Runtime path.
    let dir = std::env::var("QAT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if dir.join("index.json").exists() {
        match Runtime::new(&dir) {
            Ok(rt) => run_suite(&rt, "pjrt"),
            Err(e) => eprintln!("!! artifacts at {} unusable ({e}); PJRT pass skipped", dir.display()),
        }
    }
}

fn run_suite(be: &dyn Backend, tag: &str) {
    let checks: Vec<(&str, fn(&dyn Backend))> = vec![
        ("index_lists_all_models_and_kernels", index_lists_all_models_and_kernels),
        ("initial_state_matches_signature", initial_state_matches_signature),
        ("kernel_artifact_matches_its_ref_twin", kernel_artifact_matches_its_ref_twin),
        ("fp_train_step_reduces_loss", fp_train_step_reduces_loss),
        (
            "qat_freezing_pins_weights_and_reduces_oscillation",
            qat_freezing_pins_weights_and_reduces_oscillation,
        ),
        ("eval_and_bn_reestimation_roundtrip", eval_and_bn_reestimation_roundtrip),
        ("range_estimation_sets_positive_scales", range_estimation_sets_positive_scales),
        ("determinism_same_seed_same_result", determinism_same_seed_same_result),
        ("estimator_artifacts_execute", estimator_artifacts_execute),
        ("dampening_reports_regularizer_loss", dampening_reports_regularizer_loss),
        ("full_paper_pipeline_end_to_end", full_paper_pipeline_end_to_end),
    ];
    let mut failed = vec![];
    for (name, f) in checks {
        eprintln!("--- integration[{tag}]: {name}");
        let t0 = std::time::Instant::now();
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(be)));
        eprintln!(
            "--- integration[{tag}]: {name} {} in {:.1?}",
            if ok.is_ok() { "ok" } else { "FAILED" },
            t0.elapsed()
        );
        if ok.is_err() {
            failed.push(name);
        }
    }
    assert!(failed.is_empty(), "[{tag}] failed sub-checks: {failed:?}");
}

fn index_lists_all_models_and_kernels(be: &dyn Backend) {
    for m in ["mbv2", "resnet18", "mbv3", "efflite"] {
        let info = be.index().model(m).expect(m);
        assert!(info.param_count > 10_000, "{m} too small");
        assert!(!info.lowbit.is_empty());
        assert!(!info.depthwise().is_empty() || m == "resnet18");
        assert!(info.artifacts.contains_key("train_lsq"));
        assert!(info.artifacts.contains_key("eval"));
        assert!(info.artifacts.contains_key("bnstats"));
    }
    assert!(be.index().kernels.len() >= 6);
}

fn initial_state_matches_signature(be: &dyn Backend) {
    let state = be.initial_state("mbv2").unwrap();
    let artifact = be.index().model("mbv2").unwrap().artifacts["train_lsq"].clone();
    let sig = be.signature(&artifact).unwrap();
    // every state/* signature input must resolve from the initial state
    for spec in &sig.inputs {
        if let Some(key) = spec.name.strip_prefix("state/") {
            let t = state
                .get(key)
                .unwrap_or_else(|| panic!("missing state tensor {key}"));
            assert_eq!(t.len(), spec.num_elements(), "shape mismatch for {key}");
        }
    }
}

fn kernel_artifact_matches_its_ref_twin(be: &dyn Backend) {
    // the fused fake-quant and its reference twin must agree numerically
    let a_name = be.index().kernels["kernel_fakequant"].clone();
    let b_name = be.index().kernels["kernel_fakequant_ref"].clone();
    let sig = be.signature(&a_name).unwrap();
    let mut io = NamedTensors::new();
    for spec in &sig.inputs {
        let n = spec.num_elements().max(1);
        let data: Vec<f32> = (0..n).map(|i| ((i % 31) as f32 - 15.0) * 0.013).collect();
        io.insert(spec.name.clone(), Tensor::new(spec.shape.clone(), data));
    }
    io.insert("s", Tensor::scalar(0.07));
    io.insert("n", Tensor::scalar(-4.0));
    io.insert("p", Tensor::scalar(3.0));
    let oa = be.execute(&a_name, &[&io]).unwrap();
    let ob = be.execute(&b_name, &[&io]).unwrap();
    let ta = oa.map.values().next().unwrap();
    let tb = ob.map.values().next().unwrap();
    assert_eq!(ta.len(), tb.len());
    for (x, y) in ta.data.iter().zip(&tb.data) {
        assert!((x - y).abs() < 1e-5, "{x} vs {y}");
    }
}

fn fp_train_step_reduces_loss(be: &dyn Backend) {
    let state = be.initial_state("mbv2").unwrap();
    let trainer = Trainer::new(be);
    let mut cfg = RunCfg::fp("mbv2", 40, 0.02, 0);
    cfg.data = small_data();
    cfg.log_every = 1;
    let out = trainer.train(state, &cfg).unwrap();
    let losses = out.history.col("loss").unwrap();
    let first = losses[..5].iter().sum::<f64>() / 5.0;
    let last = losses[losses.len() - 5..].iter().sum::<f64>() / 5.0;
    assert!(
        last < first,
        "FP training should reduce loss: first~{first:.3} last~{last:.3}"
    );
}

fn qat_freezing_pins_weights_and_reduces_oscillation(be: &dyn Backend) {
    let info = be.index().model("mbv2").unwrap().clone();
    let mut state = be.initial_state("mbv2").unwrap();
    qat::prepare_qat(be, &mut state, "mbv2", 3, 8, &small_data(), 0).unwrap();
    let trainer = Trainer::new(be);

    // aggressive freezing threshold: most oscillating weights should
    // freeze quickly (fast EMA so the short test can trip the threshold)
    let mut cfg = RunCfg::qat("mbv2", 100, 3, 0);
    cfg.data = small_data();
    cfg.lr = Schedule::Const(0.03);
    cfg.f_th = Schedule::Const(0.01);
    cfg.m_osc = 0.1;
    let out = trainer.train(state, &cfg).unwrap();
    let summary = osc::summarize(&out.state, &info.lowbit);
    assert!(
        summary.frozen > 0,
        "aggressive threshold should freeze something: {summary:?}"
    );
    // frozen weights must sit exactly on the grid: w = s * fint
    for name in &info.lowbit {
        let w = out.state.get(&format!("params/{name}")).unwrap();
        let b = out.state.get(&format!("osc/{name}#b")).unwrap();
        let fint = out.state.get(&format!("osc/{name}#fint")).unwrap();
        let s = out
            .state
            .get(&format!("params/{}", osc::weight_scale_of(name)))
            .unwrap()
            .item();
        for i in 0..w.len() {
            if b.data[i] > 0.5 {
                assert!(
                    (w.data[i] - s * fint.data[i]).abs() < 1e-5,
                    "{name}[{i}] frozen but off-grid"
                );
            }
        }
    }

    // frozen weights never change *in the integer domain* under further
    // training (the latent value may still follow a learned scale s)
    let frozen_before: Vec<(String, Vec<f32>, Vec<f32>)> = info
        .lowbit
        .iter()
        .map(|n| {
            (
                n.clone(),
                out.state.get(&format!("osc/{n}#b")).unwrap().data.clone(),
                out.state.get(&format!("osc/{n}#fint")).unwrap().data.clone(),
            )
        })
        .collect();
    let mut cfg2 = cfg.clone();
    cfg2.steps = 20;
    let out2 = trainer.train(out.state, &cfg2).unwrap();
    for (name, b, fint_before) in frozen_before {
        let b_after = out2.state.get(&format!("osc/{name}#b")).unwrap();
        let fint_after = out2.state.get(&format!("osc/{name}#fint")).unwrap();
        let w_after = out2.state.get(&format!("params/{name}")).unwrap();
        let s_after = out2
            .state
            .get(&format!("params/{}", osc::weight_scale_of(&name)))
            .unwrap()
            .item();
        for i in 0..b.len() {
            if b[i] > 0.5 {
                assert!(b_after.data[i] > 0.5, "{name}[{i}] un-froze");
                assert_eq!(
                    fint_after.data[i], fint_before[i],
                    "{name}[{i}] frozen integer changed"
                );
                assert!(
                    (w_after.data[i] - s_after * fint_after.data[i]).abs() < 1e-5,
                    "{name}[{i}] frozen but off-grid after more training"
                );
            }
        }
    }
}

fn eval_and_bn_reestimation_roundtrip(be: &dyn Backend) {
    let mut state = be.initial_state("mbv2").unwrap();
    qat::prepare_qat(be, &mut state, "mbv2", 3, 8, &small_data(), 1).unwrap();
    let trainer = Trainer::new(be);
    let mut cfg = RunCfg::qat("mbv2", 30, 3, 1);
    cfg.data = small_data();
    let out = trainer.train(state, &cfg).unwrap();
    let mut state = out.state;

    let ev = Evaluator::new(be, "mbv2").unwrap();
    let q = EvalQuant::weights(3);
    let pre = ev.eval_val(&state, &small_data(), q).unwrap();
    assert!(pre.samples >= 64);
    assert!(pre.acc >= 0.0 && pre.acc <= 100.0);

    let updated = bn_restim::reestimate(be, &mut state, "mbv2", q, &small_data(), 1, 8).unwrap();
    assert!(updated > 5, "should update many BN layers, got {updated}");
    let post = ev.eval_val(&state, &small_data(), q).unwrap();
    // re-estimated stats must keep the network functional
    assert!(post.loss.is_finite());
}

fn range_estimation_sets_positive_scales(be: &dyn Backend) {
    let mut state = be.initial_state("resnet18").unwrap();
    qat::prepare_qat(be, &mut state, "resnet18", 4, 4, &small_data(), 0).unwrap();
    let info = be.index().model("resnet18").unwrap();
    for name in &info.lowbit {
        let s = state
            .get(&format!("params/{}", osc::weight_scale_of(name)))
            .unwrap()
            .item();
        assert!(s > 0.0 && s < 1.0, "{name} scale {s}");
    }
    // act scales were calibrated (params/ only — opt/ momenta are zero)
    let n_as = state
        .map
        .keys()
        .filter(|k| k.starts_with("params/") && k.ends_with(".as"))
        .count();
    assert!(n_as >= 4, "expected calibrated act scales, got {n_as}");
    for (k, v) in &state.map {
        if k.starts_with("params/") && k.ends_with(".as") {
            assert!(v.item() > 0.0, "{k} must be positive");
        }
    }
}

fn determinism_same_seed_same_result(be: &dyn Backend) {
    let trainer = Trainer::new(be);
    let mut results = vec![];
    for _ in 0..2 {
        let state = be.initial_state("mbv2").unwrap();
        let mut cfg = RunCfg::fp("mbv2", 10, 0.02, 7);
        cfg.data = small_data();
        let out = trainer.train(state, &cfg).unwrap();
        results.push(out.history.last("loss").unwrap());
    }
    assert_eq!(results[0], results[1], "same seed must reproduce bit-exact");
}

fn estimator_artifacts_execute(be: &dyn Backend) {
    let trainer = Trainer::new(be);
    for est in ["ewgs", "dsq", "psg", "pact"] {
        let state = be.initial_state("mbv2").unwrap();
        let mut cfg = RunCfg::qat("mbv2", 2, 4, 0);
        cfg.estimator = est.into();
        cfg.quant_a = true;
        cfg.data = small_data();
        let out = trainer.train(state, &cfg).unwrap();
        let loss = out.history.last("loss").unwrap();
        assert!(loss.is_finite(), "{est} produced {loss}");
    }
}

fn dampening_reports_regularizer_loss(be: &dyn Backend) {
    let mut state = be.initial_state("mbv3").unwrap();
    qat::prepare_qat(be, &mut state, "mbv3", 3, 8, &small_data(), 0).unwrap();
    let trainer = Trainer::new(be);
    let mut cfg = RunCfg::qat("mbv3", 10, 3, 0);
    cfg.data = small_data();
    cfg.lam = Schedule::Const(1e-2);
    cfg.log_every = 1;
    let out = trainer.train(state, &cfg).unwrap();
    let damp = out.history.col("damp").unwrap();
    assert!(damp.iter().any(|&d| d > 0.0), "dampening loss should be active: {damp:?}");
    assert!(out.history.last("loss").unwrap().is_finite());
}

fn full_paper_pipeline_end_to_end(be: &dyn Backend) {
    // FP pretrain (cached) -> range init -> QAT (freezing schedule) ->
    // BN re-estimation -> eval: the complete §5.1 workflow on one model.
    let ckpts = scratch_dir();
    let data = small_data();
    let fp = qat::fp_pretrained(be, &ckpts, "efflite", 0, 80, &data).unwrap();
    // cache round-trip: second call must load the identical checkpoint
    let fp2 = qat::fp_pretrained(be, &ckpts, "efflite", 0, 80, &data).unwrap();
    assert_eq!(fp.map, fp2.map, "checkpoint cache must round-trip");

    let mut state = fp;
    qat::prepare_qat(be, &mut state, "efflite", 3, 8, &data, 0).unwrap();
    let trainer = Trainer::new(be);
    let mut cfg = RunCfg::qat("efflite", 60, 3, 0);
    cfg.data = data.clone();
    cfg.f_th = Schedule::Cosine { from: 0.04, to: 0.01 };
    cfg.m_osc = 0.1;
    let run = trainer.train(state, &cfg).unwrap();
    let mut state = run.state;

    let ev = Evaluator::new(be, "efflite").unwrap();
    let q = EvalQuant::weights(3);
    let pre = ev.eval_val(&state, &data, q).unwrap();
    bn_restim::reestimate(be, &mut state, "efflite", q, &data, 0, 8).unwrap();
    let post = ev.eval_val(&state, &data, q).unwrap();
    assert!(pre.loss.is_finite() && post.loss.is_finite());
    assert!((0.0..=100.0).contains(&post.acc));

    let info = be.index().model("efflite").unwrap();
    let summary = osc::summarize(&state, &info.lowbit);
    assert!(summary.total_weights > 0);
    eprintln!(
        "[e2e] efflite w3: pre {:.2}% post {:.2}% osc {:.2}% frozen {:.2}%",
        pre.acc,
        post.acc,
        summary.osc_pct(),
        summary.frozen_pct()
    );
    std::fs::remove_dir_all(&ckpts).ok();
}
