//! Integration tests across the full stack: runtime + artifacts +
//! coordinator. These need `make artifacts` to have run; they skip (with a
//! loud message) when the artifacts are missing so `cargo test` stays
//! usable on a fresh checkout.
//!
//! The heavyweight XLA compiles are shared through a lazily-initialized
//! runtime; tests are threaded through one executable so each artifact
//! compiles at most once per test binary.

//! NOTE on structure: the PJRT client is deliberately !Send (Rc-based C
//! API handles), so the expensive Runtime cannot live in a shared static
//! across libtest's worker threads. Instead one #[test] entry point runs
//! every sub-check sequentially against a single Runtime — each artifact
//! compiles exactly once per test binary, and a failing sub-check reports
//! its name before the suite fails.

use oscillations_qat::coordinator::evaluator::{EvalQuant, Evaluator};
use oscillations_qat::coordinator::{bn_restim, qat, RunCfg, Schedule, Trainer};
use oscillations_qat::data::DataCfg;
use oscillations_qat::osc;
use oscillations_qat::runtime::Runtime;
use oscillations_qat::state::NamedTensors;
use oscillations_qat::tensor::Tensor;
use std::path::{Path, PathBuf};

fn artifact_dir() -> PathBuf {
    std::env::var("QAT_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    })
}

fn small_data() -> DataCfg {
    DataCfg { val_size: 64, ..Default::default() }
}

#[test]
fn integration_suite() {
    let dir = artifact_dir();
    if !dir.join("index.json").exists() {
        eprintln!(
            "!! artifacts missing at {} — run `make artifacts`; skipping integration suite",
            dir.display()
        );
        return;
    }
    let rt = Runtime::new(&dir).expect("runtime");
    let checks: Vec<(&str, fn(&Runtime))> = vec![
        ("index_lists_all_models_and_kernels", index_lists_all_models_and_kernels),
        ("initial_state_matches_manifest", initial_state_matches_manifest),
        ("kernel_artifact_matches_its_ref_twin", kernel_artifact_matches_its_ref_twin),
        ("fp_train_step_reduces_loss", fp_train_step_reduces_loss),
        (
            "qat_freezing_pins_weights_and_reduces_oscillation",
            qat_freezing_pins_weights_and_reduces_oscillation,
        ),
        ("eval_and_bn_reestimation_roundtrip", eval_and_bn_reestimation_roundtrip),
        ("range_estimation_sets_positive_scales", range_estimation_sets_positive_scales),
        ("determinism_same_seed_same_result", determinism_same_seed_same_result),
        ("estimator_artifacts_execute", estimator_artifacts_execute),
    ];
    let mut failed = vec![];
    for (name, f) in checks {
        eprintln!("--- integration: {name}");
        let t0 = std::time::Instant::now();
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&rt)));
        eprintln!("--- integration: {name} {} in {:.1?}",
                  if ok.is_ok() { "ok" } else { "FAILED" }, t0.elapsed());
        if ok.is_err() {
            failed.push(name);
        }
    }
    assert!(failed.is_empty(), "failed sub-checks: {failed:?}");
}

fn index_lists_all_models_and_kernels(rt: &Runtime) {
    for m in ["mbv2", "resnet18", "mbv3", "efflite"] {
        let info = rt.index.model(m).expect(m);
        assert!(info.param_count > 10_000, "{m} too small");
        assert!(!info.lowbit.is_empty());
        assert!(!info.depthwise().is_empty() || m == "resnet18");
        assert!(info.artifacts.contains_key("train_lsq"));
        assert!(info.artifacts.contains_key("eval"));
        assert!(info.artifacts.contains_key("bnstats"));
    }
    assert!(rt.index.kernels.len() >= 6);
}

fn initial_state_matches_manifest(rt: &Runtime) {
    let state = rt.initial_state("mbv2").unwrap();
    let artifact_name = rt.index.model("mbv2").unwrap().artifacts["train_lsq"].clone();
    let artifact = rt.artifact(&artifact_name).unwrap();
    // every state/* manifest input must resolve from the QTNS state
    for spec in &artifact.manifest.inputs {
        if let Some(key) = spec.name.strip_prefix("state/") {
            let t = state
                .get(key)
                .unwrap_or_else(|| panic!("missing state tensor {key}"));
            assert_eq!(t.len(), spec.num_elements(), "shape mismatch for {key}");
        }
    }
}

fn kernel_artifact_matches_its_ref_twin(rt: &Runtime) {
    // the fused Pallas fake-quant and the pure-jnp reference must agree
    // numerically when executed through PJRT from rust
    let a = rt.artifact(&rt.index.kernels["kernel_fakequant"]).unwrap();
    let b = rt.artifact(&rt.index.kernels["kernel_fakequant_ref"]).unwrap();
    let mut io = NamedTensors::new();
    for spec in &a.manifest.inputs {
        let n = spec.num_elements().max(1);
        let data: Vec<f32> = (0..n).map(|i| ((i % 31) as f32 - 15.0) * 0.013).collect();
        io.insert(spec.name.clone(), Tensor::new(spec.shape.clone(), data));
    }
    let oa = a.execute(&[&io]).unwrap();
    let ob = b.execute(&[&io]).unwrap();
    let ta = oa.map.values().next().unwrap();
    let tb = ob.map.values().next().unwrap();
    assert_eq!(ta.len(), tb.len());
    for (x, y) in ta.data.iter().zip(&tb.data) {
        assert!((x - y).abs() < 1e-5, "{x} vs {y}");
    }
}

fn fp_train_step_reduces_loss(rt: &Runtime) {
    let state = rt.initial_state("mbv2").unwrap();
    let trainer = Trainer::new(&rt);
    let mut cfg = RunCfg::fp("mbv2", 40, 0.02, 0);
    cfg.data = small_data();
    cfg.log_every = 1;
    let out = trainer.train(state, &cfg).unwrap();
    let losses = out.history.col("loss").unwrap();
    let first = losses[..5].iter().sum::<f64>() / 5.0;
    let last = losses[losses.len() - 5..].iter().sum::<f64>() / 5.0;
    assert!(
        last < first,
        "FP training should reduce loss: first~{first:.3} last~{last:.3}"
    );
}

fn qat_freezing_pins_weights_and_reduces_oscillation(rt: &Runtime) {
    let info = rt.index.model("mbv2").unwrap().clone();
    let mut state = rt.initial_state("mbv2").unwrap();
    qat::prepare_qat(&rt, &mut state, "mbv2", 3, 8, &small_data(), 0).unwrap();
    let trainer = Trainer::new(&rt);

    // aggressive freezing threshold: most weights should freeze quickly
    let mut cfg = RunCfg::qat("mbv2", 60, 3, 0);
    cfg.data = small_data();
    cfg.f_th = Schedule::Const(0.01);
    cfg.m_osc = 0.1; // fast EMA so the short test can trip the threshold
    let out = trainer.train(state, &cfg).unwrap();
    let summary = osc::summarize(&out.state, &info.lowbit);
    assert!(
        summary.frozen > 0,
        "aggressive threshold should freeze something: {summary:?}"
    );
    // frozen weights must sit exactly on the grid: w = s * fint
    for name in &info.lowbit {
        let w = out.state.get(&format!("params/{name}")).unwrap();
        let b = out.state.get(&format!("osc/{name}#b")).unwrap();
        let fint = out.state.get(&format!("osc/{name}#fint")).unwrap();
        let s = out
            .state
            .get(&format!("params/{}", osc::weight_scale_of(name)))
            .unwrap()
            .item();
        for i in 0..w.len() {
            if b.data[i] > 0.5 {
                assert!(
                    (w.data[i] - s * fint.data[i]).abs() < 1e-5,
                    "{name}[{i}] frozen but off-grid"
                );
            }
        }
    }
}

fn eval_and_bn_reestimation_roundtrip(rt: &Runtime) {
    let mut state = rt.initial_state("mbv2").unwrap();
    qat::prepare_qat(&rt, &mut state, "mbv2", 3, 8, &small_data(), 1).unwrap();
    let trainer = Trainer::new(&rt);
    let mut cfg = RunCfg::qat("mbv2", 30, 3, 1);
    cfg.data = small_data();
    let out = trainer.train(state, &cfg).unwrap();
    let mut state = out.state;

    let ev = Evaluator::new(&rt, "mbv2").unwrap();
    let q = EvalQuant::weights(3);
    let pre = ev.eval_val(&state, &small_data(), q).unwrap();
    assert!(pre.samples >= 64);
    assert!(pre.acc >= 0.0 && pre.acc <= 100.0);

    let updated = bn_restim::reestimate(&rt, &mut state, "mbv2", q, &small_data(), 1, 8)
        .unwrap();
    assert!(updated > 5, "should update many BN layers, got {updated}");
    let post = ev.eval_val(&state, &small_data(), q).unwrap();
    // re-estimated stats must keep the network functional
    assert!(post.loss.is_finite());
}

fn range_estimation_sets_positive_scales(rt: &Runtime) {
    let mut state = rt.initial_state("resnet18").unwrap();
    qat::prepare_qat(&rt, &mut state, "resnet18", 4, 4, &small_data(), 0).unwrap();
    let info = rt.index.model("resnet18").unwrap();
    for name in &info.lowbit {
        let s = state
            .get(&format!("params/{}", osc::weight_scale_of(name)))
            .unwrap()
            .item();
        assert!(s > 0.0 && s < 1.0, "{name} scale {s}");
    }
    // act scales were calibrated (params/ only — opt/ momenta are zero)
    let n_as = state
        .map
        .keys()
        .filter(|k| k.starts_with("params/") && k.ends_with(".as"))
        .count();
    assert!(n_as > 5);
    for (k, v) in &state.map {
        if k.starts_with("params/") && k.ends_with(".as") {
            assert!(v.item() > 0.0, "{k} must be positive");
        }
    }
}

fn determinism_same_seed_same_result(rt: &Runtime) {
    let trainer = Trainer::new(&rt);
    let mut results = vec![];
    for _ in 0..2 {
        let state = rt.initial_state("mbv2").unwrap();
        let mut cfg = RunCfg::fp("mbv2", 10, 0.02, 7);
        cfg.data = small_data();
        let out = trainer.train(state, &cfg).unwrap();
        results.push(out.history.last("loss").unwrap());
    }
    assert_eq!(results[0], results[1], "same seed must reproduce bit-exact");
}

fn estimator_artifacts_execute(rt: &Runtime) {
    let trainer = Trainer::new(&rt);
    for est in ["ewgs", "dsq", "psg", "pact"] {
        let state = rt.initial_state("mbv2").unwrap();
        let mut cfg = RunCfg::qat("mbv2", 2, 4, 0);
        cfg.estimator = est.into();
        cfg.quant_a = true;
        cfg.data = small_data();
        let out = trainer.train(state, &cfg).unwrap();
        let loss = out.history.last("loss").unwrap();
        assert!(loss.is_finite(), "{est} produced {loss}");
    }
}
