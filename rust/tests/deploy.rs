//! Deployment round-trip: QAT-train a zoo model natively, export it with
//! BN folding into a bit-packed integer artifact, and check that (a) the
//! QPKG file round-trips, (b) the packed file honours the `bits/32` size
//! budget, and (c) the packed integer engine — standalone and behind the
//! batched serving front-end — reproduces the fake-quant eval path's
//! top-1 predictions on the validation split exactly.

use oscillations_qat::coordinator::evaluator::EvalQuant;
use oscillations_qat::coordinator::{bn_restim, qat, RunCfg, Schedule, Trainer};
use oscillations_qat::data::{DataCfg, Dataset};
use oscillations_qat::deploy::export::{export_model, ExportCfg};
use oscillations_qat::deploy::format::DeployModel;
use oscillations_qat::deploy::serve::{bench_serve, ServeCfg};
use oscillations_qat::deploy::{Engine, EngineOpts};
use oscillations_qat::runtime::native::model::zoo_model;
use oscillations_qat::runtime::{Backend, NativeBackend};
use oscillations_qat::state::NamedTensors;
use std::sync::Arc;

const MODEL: &str = "efflite";
/// The spatial-depthwise acceptance model: true 2-D `[C, 3, 3]` convs
/// over channel-last blocks, including a stride-2 downsampling stage.
const MODEL_2D: &str = "efflite_2d";
const BITS: u32 = 4;
const D_IN: usize = 16 * 16 * 3;

fn small_data() -> DataCfg {
    DataCfg { val_size: 64, ..Default::default() }
}

/// Train a W4/A4 QAT model with the freezing schedule and re-estimated
/// BN statistics — the state every check below exports. With
/// `per_channel` the quantizers run the v3 default regime: one learned
/// LSQ weight scale per output channel *and* one learned activation
/// scale per input channel (the paper's depth-wise operating point);
/// without it, the `--per-tensor` legacy single-scale quantizers.
fn trained_state(be: &NativeBackend, model: &str, per_channel: bool) -> NamedTensors {
    let data = small_data();
    let trainer = Trainer::new(be);
    let mut fp = RunCfg::fp(model, 60, 0.02, 0);
    fp.data = data.clone();
    let run = trainer.train(be.initial_state(model).unwrap(), &fp).unwrap();
    let mut state = run.state;

    qat::prepare_qat(be, &mut state, model, BITS, BITS, &data, 0).unwrap();
    if per_channel {
        let n = qat::to_per_channel_scales(be, &mut state, model, BITS, BITS, &data, 0).unwrap();
        assert!(n >= 5, "expected every weight tensor converted, got {n}");
    }
    let mut cfg = RunCfg::qat(model, 80, BITS, 0);
    cfg.quant_a = true;
    cfg.data = data.clone();
    cfg.f_th = Schedule::Cosine { from: 0.04, to: 0.01 };
    cfg.m_osc = 0.1;
    let run = trainer.train(state, &cfg).unwrap();
    let mut state = run.state;

    let q = EvalQuant::full(BITS);
    bn_restim::reestimate(be, &mut state, model, q, &data, 0, 8).unwrap();
    state
}

/// Per-sample top-1 predictions of the simulated fake-quant eval path,
/// plus the flattened per-sample inputs.
fn reference_preds(
    be: &NativeBackend,
    model: &str,
    state: &NamedTensors,
) -> (Vec<usize>, Vec<Vec<f32>>) {
    let info = be.index().model(model).unwrap().clone();
    let eval_name = info.artifacts["eval"].clone();
    let hyper = EvalQuant::full(BITS).hyper();
    let ds = Dataset::new(small_data());
    let mut preds = vec![];
    let mut inputs = vec![];
    for bch in ds.val_batches() {
        let b = bch.x.shape[0];
        let mut io = NamedTensors::new();
        io.insert("batch/x", bch.x.clone());
        io.insert("batch/y", bch.y.clone());
        let out = be.execute(&eval_name, &[state, &io, &hyper]).unwrap();
        let p = out.expect("pred").unwrap();
        assert_eq!(p.len(), b);
        for i in 0..b {
            preds.push(p.data[i] as usize);
            inputs.push(bch.x.data[i * D_IN..(i + 1) * D_IN].to_vec());
        }
    }
    (preds, inputs)
}

fn agreement(got: &[usize], want: &[usize]) -> f64 {
    assert_eq!(got.len(), want.len());
    let hits = got.iter().zip(want).filter(|(a, b)| a == b).count();
    hits as f64 / want.len().max(1) as f64
}

/// Engine thread count of the suite, `QAT_ENGINE_THREADS` (default 1):
/// the CI test matrix runs this suite once at the default and once at 2
/// so the scoped-thread path is exercised on every PR.
fn engine_threads() -> usize {
    std::env::var("QAT_ENGINE_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
        .max(1)
}

fn engine_opts() -> EngineOpts {
    EngineOpts { threads: engine_threads(), ..Default::default() }
}

/// Chunked batch prediction over the whole input set (the serving-shaped
/// access pattern every engine-mode check below shares).
fn predict_all(eng: &Engine, inputs: &[Vec<f32>]) -> Vec<usize> {
    let mut preds = Vec::with_capacity(inputs.len());
    for chunk in inputs.chunks(16) {
        let mut x = Vec::with_capacity(chunk.len() * D_IN);
        for s in chunk {
            x.extend_from_slice(s);
        }
        preds.extend(eng.predict_batch(&x, chunk.len()).unwrap());
    }
    preds
}

#[test]
fn deploy_roundtrip_suite() {
    let be = NativeBackend::new();
    let state = trained_state(&be, MODEL, false);
    let (ref_preds, inputs) = reference_preds(&be, MODEL, &state);
    assert_eq!(ref_preds.len(), 64);

    // ---- export with BN folding + grid snapping -----------------------
    let nm = zoo_model(MODEL).unwrap();
    let cfg = ExportCfg { bits_w: BITS, bits_a: BITS, quant_a: true };
    let (dm, report) = export_model(&nm, &state, &cfg).unwrap();
    assert_eq!(report.layers, nm.layers.len());
    assert!(report.total_weights > 10_000, "{report:?}");
    assert!(
        report.frozen_verified > 0,
        "the freezing schedule should have frozen (and verified) weights: {report:?}"
    );
    // non-frozen weights land within half a grid step of their snapped int
    assert!(
        report.max_offgrid <= 0.5 + 1e-6,
        "snap distance out of range: {report:?}"
    );
    // BN layers all folded away; no layer carries BN state
    for l in &dm.layers {
        assert!(l.requant.is_some() || l.name == "head", "{} lost its BN fold", l.name);
    }

    // ---- size budget: packed file <= (bits/32 + eps) * f32 weights ----
    // every layer is at most 8-bit, so the whole-file budget is 8/32 of
    // the f32 weight payload plus the small per-layer aux/header epsilon
    let dir = std::env::temp_dir().join(format!("qat_deploy_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.qpkg");
    dm.write_qpkg(&path).unwrap();
    let file_bytes = std::fs::metadata(&path).unwrap().len() as f64;
    let f32_bytes = dm.f32_weight_bytes() as f64;
    let eps_bytes = (dm.aux_bytes() + 64 * dm.layers.len() + 256) as f64;
    assert!(
        file_bytes <= f32_bytes * (8.0 / 32.0) + eps_bytes,
        "qpkg {} B exceeds the bits/32 budget over {} f32 B (+{} eps)",
        file_bytes,
        f32_bytes,
        eps_bytes
    );
    // the 4-bit interior really packs 2 codes per byte
    for l in dm.layers.iter().filter(|l| l.w_bits == 4) {
        assert_eq!(l.weights.num_bytes(), (l.weights.len + 1) / 2, "{}", l.name);
    }

    // ---- QPKG round-trip ---------------------------------------------
    let dm2 = DeployModel::read_qpkg(&path).unwrap();
    assert_eq!(dm, dm2);

    // ---- packed engine vs the fake-quant eval path --------------------
    // The linear kernels are bit-exact against the interpreter; the
    // folded BN affine differs from the BN op sequence only in f32
    // association (ulp-level, see the verified BN-fold deviation bound of
    // ~2e-7 relative). 100% agreement is therefore asserted empirically
    // for this pinned (model, seed, bits) configuration — if this ever
    // trips after changing those knobs, inspect the offending sample's
    // top-2 logit margin before suspecting the engine.
    // f32-exact mode: replays the simulated kernels' arithmetic
    let exact = Engine::with_mode(dm.clone(), false);
    let mut exact_preds = vec![];
    for x in &inputs {
        exact_preds.push(exact.predict_batch(x, 1).unwrap()[0]);
    }
    assert_eq!(
        agreement(&exact_preds, &ref_preds),
        1.0,
        "f32-exact engine disagrees with the fake-quant eval path"
    );

    // i32-accumulation mode (the deployment path), batched, at the
    // matrix-selected thread count
    let int = Engine::with_opts(dm2.clone(), true, engine_opts());
    let int_preds = predict_all(&int, &inputs);
    assert_eq!(
        agreement(&int_preds, &ref_preds),
        1.0,
        "integer engine disagrees with the fake-quant eval path"
    );

    // decode-once planes, streaming decode, and the scoped-thread batch
    // split must all reproduce the same predictions
    for (label, opts) in [
        ("streaming", EngineOpts { prepared: false, ..Default::default() }),
        ("threads=2", EngineOpts { threads: 2, ..Default::default() }),
    ] {
        let eng = Engine::with_opts(dm2.clone(), true, opts);
        let preds = predict_all(&eng, &inputs);
        assert_eq!(preds, int_preds, "{label} engine drifted from the prepared engine");
    }

    // ---- batched serving front-end ------------------------------------
    let scfg = ServeCfg { workers: 4, max_batch: 8, queue_cap: 64 };
    let report = bench_serve(Arc::new(int), &scfg, &inputs).unwrap();
    assert_eq!(report.requests, inputs.len());
    assert_eq!(
        agreement(&report.preds, &ref_preds),
        1.0,
        "served predictions disagree with the fake-quant eval path"
    );
    assert!(report.throughput_rps > 0.0);
    assert!(report.mean_batch >= 1.0);
    eprintln!(
        "[deploy] {MODEL} w{BITS}a{BITS}: 100% top-1 agreement over {} samples; {}",
        ref_preds.len(),
        report.summary()
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The per-channel acceptance criterion: a w4a4 QAT run of a depth-wise
/// zoo model in the **per-channel default regime** — per-channel weight
/// scales *and* per-channel activation scales — exports through QPKG,
/// the file round-trips, and both engine paths (f32-bit-exact and
/// i32-accumulation, standalone and behind the batched server) reproduce
/// the fake-quant eval path's top-1 predictions exactly.
#[test]
fn per_channel_deploy_roundtrip_suite() {
    let be = NativeBackend::new();
    let state = trained_state(&be, MODEL, true);

    // the trained state really carries per-channel scale vectors, for
    // weights ([d_out]) and for activation sites ([d_in])
    let nm = zoo_model(MODEL).unwrap();
    for l in &nm.layers {
        let s = state.get(&format!("params/{}.s", l.name)).unwrap();
        assert_eq!(s.len(), l.d_out, "{} should train per-channel scales", l.name);
        if l.aq {
            let sa = state.get(&format!("params/{}.as", l.name)).unwrap();
            assert_eq!(sa.len(), l.d_in, "{} should train per-channel act scales", l.name);
        }
    }

    let (ref_preds, inputs) = reference_preds(&be, MODEL, &state);
    assert_eq!(ref_preds.len(), 64);

    let cfg = ExportCfg { bits_w: BITS, bits_a: BITS, quant_a: true };
    let (dm, report) = export_model(&nm, &state, &cfg).unwrap();
    assert!(report.frozen_verified > 0, "freezing should engage per-channel: {report:?}");
    assert!(report.max_offgrid <= 0.5 + 1e-6, "{report:?}");
    for (dl, nl) in dm.layers.iter().zip(&nm.layers) {
        assert!(dl.per_channel(), "{} exported without per-channel scales", dl.name);
        assert_eq!(dl.w_scales.len(), dl.d_out, "{}", dl.name);
        if nl.aq {
            assert!(dl.per_channel_act(), "{} lost its per-channel act scales", dl.name);
            assert_eq!(dl.a_scales.len(), dl.d_in, "{}", dl.name);
        }
    }

    // ---- QPKG file round-trip -----------------------------------------
    let dir = std::env::temp_dir().join(format!("qat_deploy_pc_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model_pc.qpkg");
    dm.write_qpkg(&path).unwrap();
    let raw = std::fs::read(&path).unwrap();
    assert_eq!(
        u32::from_le_bytes(raw[4..8].try_into().unwrap()),
        4,
        "exports are version 4 on disk"
    );
    let dm2 = DeployModel::read_qpkg(&path).unwrap();
    assert_eq!(dm, dm2);

    // the per-channel scale arrays cost d_out f32s per layer but the
    // packed payload still honours the bits/32 budget
    let file_bytes = std::fs::metadata(&path).unwrap().len() as f64;
    let f32_bytes = dm.f32_weight_bytes() as f64;
    let eps_bytes = (dm.aux_bytes() + 64 * dm.layers.len() + 256) as f64;
    assert!(file_bytes <= f32_bytes * (8.0 / 32.0) + eps_bytes);

    // ---- both engine paths: 100% top-1 agreement ----------------------
    let exact = Engine::with_mode(dm.clone(), false);
    let mut exact_preds = vec![];
    for x in &inputs {
        exact_preds.push(exact.predict_batch(x, 1).unwrap()[0]);
    }
    assert_eq!(
        agreement(&exact_preds, &ref_preds),
        1.0,
        "per-channel f32-exact engine disagrees with the fake-quant eval path"
    );

    let int = Engine::with_opts(dm2.clone(), true, engine_opts());
    let int_preds = predict_all(&int, &inputs);
    assert_eq!(
        agreement(&int_preds, &ref_preds),
        1.0,
        "per-channel integer engine disagrees with the fake-quant eval path"
    );

    // the threaded and streaming engines reproduce the same predictions
    // on the per-channel export too
    for (label, opts) in [
        ("streaming", EngineOpts { prepared: false, ..Default::default() }),
        ("threads=2", EngineOpts { threads: 2, ..Default::default() }),
    ] {
        let eng = Engine::with_opts(dm2.clone(), true, opts);
        let preds = predict_all(&eng, &inputs);
        assert_eq!(preds, int_preds, "per-channel {label} engine drifted");
    }

    // ---- batched serving ----------------------------------------------
    let scfg = ServeCfg { workers: 4, max_batch: 8, queue_cap: 64 };
    let sreport = bench_serve(Arc::new(int), &scfg, &inputs).unwrap();
    assert_eq!(
        agreement(&sreport.preds, &ref_preds),
        1.0,
        "served per-channel predictions disagree with the fake-quant eval path"
    );
    eprintln!(
        "[deploy] {MODEL} w{BITS}a{BITS} per-channel (weights+activations): \
         100% top-1 agreement over {} samples; {}",
        ref_preds.len(),
        sreport.summary()
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The spatial-depthwise acceptance criterion (QPKG v4): a w4a4
/// per-channel QAT run of a **2-D zoo model** — true `[C, 3, 3]` spatial
/// depthwise convs over channel-last blocks, including a stride-2
/// downsampling stage — exports with BN folding into a version-4 QPKG,
/// the file round-trips with its spatial metadata intact, and every
/// engine mode (f32-bit-exact, i32-accumulation prepared / streaming /
/// threaded, and the batched server) reproduces the fake-quant eval
/// path's top-1 predictions exactly. The dw layers carry per-channel
/// activation scales, so the i32 route runs the spatial exact-integer
/// fast path rather than falling back to f32.
#[test]
fn spatial_deploy_roundtrip_suite() {
    let be = NativeBackend::new();
    let state = trained_state(&be, MODEL_2D, true);

    // per-channel scale vectors sized by the layer's channel layout:
    // [w_channels] for weights (C for spatial dw), [act_channels] for
    // quantized-activation inputs
    let nm = zoo_model(MODEL_2D).unwrap();
    for l in &nm.layers {
        let s = state.get(&format!("params/{}.s", l.name)).unwrap();
        assert_eq!(s.len(), l.w_channels(), "{} weight scale count", l.name);
        if l.aq {
            let sa = state.get(&format!("params/{}.as", l.name)).unwrap();
            assert_eq!(sa.len(), l.act_channels(), "{} act scale count", l.name);
        }
    }

    let (ref_preds, inputs) = reference_preds(&be, MODEL_2D, &state);
    assert_eq!(ref_preds.len(), 64);

    let cfg = ExportCfg { bits_w: BITS, bits_a: BITS, quant_a: true };
    let (dm, report) = export_model(&nm, &state, &cfg).unwrap();
    assert!(report.frozen_verified > 0, "freezing should engage on spatial dw: {report:?}");
    assert!(report.max_offgrid <= 0.5 + 1e-6, "{report:?}");

    // the export preserved the spatial geometry and per-channel scales
    let dws: Vec<_> = dm
        .layers
        .iter()
        .filter(|l| l.op == oscillations_qat::deploy::format::DeployOp::DwSpatial)
        .collect();
    assert_eq!(dws.len(), 2, "efflite_2d has two spatial dw stages");
    for dl in &dws {
        let sp = dl.spatial.expect("spatial metadata must survive export");
        assert_eq!(sp.kernel, 3);
        assert_eq!(dl.d_in, sp.hw_in * sp.hw_in * sp.channels);
        assert_eq!(dl.d_out, sp.hw_out() * sp.hw_out() * sp.channels);
        assert_eq!(dl.scale_group(), 9);
        assert_eq!(dl.w_scales.len(), sp.channels, "{} weight scales", dl.name);
        assert_eq!(dl.a_scales.len(), sp.channels, "{} act scales", dl.name);
        assert!(dl.per_channel_act(), "{} must take the exact-i32 spatial path", dl.name);
        assert!(dl.requant.is_some(), "{} lost its BN fold", dl.name);
    }
    assert_eq!(dws[1].spatial.unwrap().stride, 2, "b2.dw downsamples");

    // ---- QPKG v4 file round-trip --------------------------------------
    let dir = std::env::temp_dir().join(format!("qat_deploy_2d_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model_2d.qpkg");
    dm.write_qpkg(&path).unwrap();
    let raw = std::fs::read(&path).unwrap();
    assert_eq!(
        u32::from_le_bytes(raw[4..8].try_into().unwrap()),
        4,
        "spatial exports are version 4 on disk"
    );
    let dm2 = DeployModel::read_qpkg(&path).unwrap();
    assert_eq!(dm, dm2);

    let file_bytes = std::fs::metadata(&path).unwrap().len() as f64;
    let f32_bytes = dm.f32_weight_bytes() as f64;
    let eps_bytes = (dm.aux_bytes() + 64 * dm.layers.len() + 256) as f64;
    assert!(file_bytes <= f32_bytes * (8.0 / 32.0) + eps_bytes);

    // ---- every engine mode: 100% top-1 agreement ----------------------
    let exact = Engine::with_mode(dm.clone(), false);
    let mut exact_preds = vec![];
    for x in &inputs {
        exact_preds.push(exact.predict_batch(x, 1).unwrap()[0]);
    }
    assert_eq!(
        agreement(&exact_preds, &ref_preds),
        1.0,
        "spatial f32-exact engine disagrees with the fake-quant eval path"
    );

    let int = Engine::with_opts(dm2.clone(), true, engine_opts());
    let int_preds = predict_all(&int, &inputs);
    assert_eq!(
        agreement(&int_preds, &ref_preds),
        1.0,
        "spatial integer engine disagrees with the fake-quant eval path"
    );

    for (label, opts) in [
        ("streaming", EngineOpts { prepared: false, ..Default::default() }),
        ("threads=2", EngineOpts { threads: 2, ..Default::default() }),
    ] {
        let eng = Engine::with_opts(dm2.clone(), true, opts);
        let preds = predict_all(&eng, &inputs);
        assert_eq!(preds, int_preds, "spatial {label} engine drifted");
    }

    // ---- batched serving ----------------------------------------------
    let scfg = ServeCfg { workers: 4, max_batch: 8, queue_cap: 64 };
    let sreport = bench_serve(Arc::new(int), &scfg, &inputs).unwrap();
    assert_eq!(
        agreement(&sreport.preds, &ref_preds),
        1.0,
        "served spatial predictions disagree with the fake-quant eval path"
    );
    eprintln!(
        "[deploy] {MODEL_2D} w{BITS}a{BITS} spatial per-channel (qpkg v4): \
         100% top-1 agreement over {} samples; {}",
        ref_preds.len(),
        sreport.summary()
    );
    std::fs::remove_dir_all(&dir).ok();
}
