//! End-to-end multi-model fleet tests over a real TCP socket: several
//! resident models answering concurrently with bit-exact per-model
//! predictions, LRU plane demotion under a too-small memory budget
//! (observed through `GET /v1/models`), zero-downtime hot-swap under
//! live traffic with no stale cache hits, and the deprecated
//! `/v1/predict` alias answering `Deprecation: true`.

use oscillations_qat::deploy::format::{DeployLayer, DeployModel, DeployOp, Requant};
use oscillations_qat::deploy::packed::Packed;
use oscillations_qat::deploy::serve::http::{format_request, read_response};
use oscillations_qat::deploy::serve::registry::plane_cost;
use oscillations_qat::deploy::serve::{
    BatchForward, EngineCfg, HttpCfg, HttpServer, ModelRegistry, RegistryCfg, ServeCfg,
};
use oscillations_qat::deploy::Engine;
use oscillations_qat::json;
use std::io::Write;
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

/// 12-feature single-layer model on a 3-bit grid where feature block
/// `c` drives class `(c + rot) % 3` — three rotations give three
/// distinguishable models that share one plane cost.
fn rot_model(name: &str, rot: usize) -> DeployModel {
    let mut codes = vec![4u32; 12 * 3]; // grid int 0
    for c in 0..3usize {
        for f in 0..4usize {
            codes[(c * 4 + f) * 3 + (c + rot) % 3] = 6; // grid int +2 -> weight 1.0
        }
    }
    DeployModel {
        name: name.into(),
        input_hw: 2,
        num_classes: 3,
        quant_a: false,
        bits_w: 3,
        bits_a: 8,
        layers: vec![DeployLayer {
            name: "head".into(),
            op: DeployOp::Full,
            d_in: 12,
            d_out: 3,
            relu: false,
            aq: false,
            act_bits: 8,
            a_scales: vec![1.0],
            w_bits: 3,
            w_scales: vec![0.5],
            weights: Packed::pack(&codes, 3).unwrap(),
            bias: None,
            requant: Some(Requant { mult: vec![1.0; 3], add: vec![0.0; 3] }),
        }],
    }
}

fn one_hot_block(c: usize) -> Vec<f32> {
    let mut x = vec![0.0f32; 12];
    for f in 0..4 {
        x[c * 4 + f] = 1.0;
    }
    x
}

/// `{"input":[...]}` — the resource routes carry the model in the path.
fn input_body(input: &[f32]) -> Vec<u8> {
    let mut s = String::from("{\"input\":[");
    for (i, v) in input.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("{v}"));
    }
    s.push_str("]}");
    s.into_bytes()
}

fn registry(mem_budget: Option<usize>) -> ModelRegistry {
    ModelRegistry::new(RegistryCfg {
        serve: ServeCfg::default(),
        engine: EngineCfg::default(),
        mem_budget,
        ..RegistryCfg::default()
    })
}

fn parse_body(resp: &oscillations_qat::deploy::serve::http::ClientResponse) -> json::Json {
    json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap()
}

/// The fleet listing as `(id, mode)` pairs, fetched over the wire.
fn fleet_modes(stream: &mut TcpStream) -> Vec<(String, String)> {
    stream.write_all(b"GET /v1/models HTTP/1.1\r\n\r\n").unwrap();
    let resp = read_response(stream).unwrap();
    assert_eq!(resp.status, 200);
    let j = parse_body(&resp);
    j.get("models")
        .as_arr()
        .unwrap()
        .iter()
        .map(|m| {
            (
                m.get("id").as_str().unwrap().to_string(),
                m.get("mode").as_str().unwrap().to_string(),
            )
        })
        .collect()
}

#[test]
fn three_resident_models_answer_concurrently_and_bit_exactly() {
    let mut models = registry(None);
    for rot in 0..3usize {
        models.insert_model(&format!("m{rot}"), rot_model(&format!("rot{rot}"), rot)).unwrap();
    }
    let srv = HttpServer::start_registry(models, &HttpCfg::default()).unwrap();
    let addr = srv.addr();
    // the ground truth each fleet answer must match to the bit
    let refs: Vec<Engine> = (0..3).map(|rot| Engine::new(rot_model("ref", rot))).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..3usize)
            .map(|rot| {
                let expect = &refs[rot];
                s.spawn(move || {
                    let mut stream = TcpStream::connect(addr).unwrap();
                    for round in 0..8 {
                        let c = round % 3;
                        let req = format_request(
                            &format!("/v1/models/m{rot}/predict"),
                            &input_body(&one_hot_block(c)),
                            &[],
                        );
                        stream.write_all(&req).unwrap();
                        let resp = read_response(&mut stream).unwrap();
                        assert_eq!(resp.status, 200, "m{rot} round {round}");
                        let j = parse_body(&resp);
                        assert_eq!(j.get("pred").as_usize(), Some((c + rot) % 3), "m{rot}");
                        let got: Vec<f32> = j
                            .get("logits")
                            .as_arr()
                            .unwrap()
                            .iter()
                            .map(|v| v.as_f64().unwrap() as f32)
                            .collect();
                        let want = expect.forward_batch(&one_hot_block(c), 1).unwrap();
                        assert_eq!(got, want, "m{rot} logits must match a direct forward");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    srv.stop();
}

#[test]
fn too_small_budget_demotes_lru_and_traffic_promotes_it_back() {
    let dm = rot_model("rot0", 0);
    let cost = plane_cost(&dm);
    assert!(cost > 0);
    // room for exactly two resident plane sets
    let mut models = registry(Some(2 * cost));
    for rot in 0..3usize {
        models.insert_model(&format!("m{rot}"), rot_model(&format!("rot{rot}"), rot)).unwrap();
    }
    let srv = HttpServer::start_registry(models, &HttpCfg::default()).unwrap();
    let mut stream = TcpStream::connect(srv.addr()).unwrap();
    // installing m2 had to steal m0's planes (m0 was least recently used)
    let modes = fleet_modes(&mut stream);
    assert_eq!(
        modes,
        vec![
            ("m0".to_string(), "streaming".to_string()),
            ("m1".to_string(), "prepared".to_string()),
            ("m2".to_string(), "prepared".to_string()),
        ],
        "{modes:?}"
    );
    // streaming entries still answer correctly
    for (send, expect_pred) in [(0usize, 0usize), (1, 1)] {
        let req = format_request("/v1/models/m0/predict", &input_body(&one_hot_block(send)), &[]);
        stream.write_all(&req).unwrap();
        let resp = read_response(&mut stream).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(parse_body(&resp).get("pred").as_usize(), Some(expect_pred));
    }
    // two hits made m0 the hottest entry: it won its planes back from
    // the now-coldest m1
    let modes = fleet_modes(&mut stream);
    assert_eq!(
        modes,
        vec![
            ("m0".to_string(), "prepared".to_string()),
            ("m1".to_string(), "streaming".to_string()),
            ("m2".to_string(), "prepared".to_string()),
        ],
        "{modes:?}"
    );
    srv.stop();
}

#[test]
fn hot_swap_under_live_traffic_drops_nothing_and_serves_no_stale_answers() {
    let dir = std::env::temp_dir().join("qat_http_fleet_swap");
    std::fs::create_dir_all(&dir).unwrap();
    let p1: PathBuf = dir.join("swap_v1.qpkg");
    let p2: PathBuf = dir.join("swap_v2.qpkg");
    rot_model("swap_v1", 0).write_qpkg(&p1).unwrap();
    rot_model("swap_v2", 1).write_qpkg(&p2).unwrap();

    let mut models = registry(None);
    models.load_qpkg("m", &p1).unwrap();
    let srv = HttpServer::start_registry(models, &HttpCfg::default()).unwrap();
    let addr = srv.addr();

    // prime the response cache on version 1. The probe input is scaled
    // so its bytes never collide with the workers' traffic below — a
    // worker answer must not refill the cache slot this test watches.
    let probe_input: Vec<f32> = one_hot_block(0).iter().map(|v| v * 2.0).collect();
    let mut stream = TcpStream::connect(addr).unwrap();
    let probe = format_request("/v1/models/m/predict", &input_body(&probe_input), &[]);
    stream.write_all(&probe).unwrap();
    let first = read_response(&mut stream).unwrap();
    assert_eq!(first.status, 200);
    assert_eq!(first.header("x-cache"), Some("miss"));
    assert_eq!(parse_body(&first).get("pred").as_usize(), Some(0));
    stream.write_all(&probe).unwrap();
    let hit = read_response(&mut stream).unwrap();
    assert_eq!(hit.header("x-cache"), Some("hit"));

    // live traffic while the admin connection swaps v1 <-> v2: every
    // request must answer 200 with one of the two valid predictions
    let done = AtomicBool::new(false);
    std::thread::scope(|s| {
        let workers: Vec<_> = (0..2usize)
            .map(|w| {
                let done = &done;
                s.spawn(move || {
                    let mut stream = TcpStream::connect(addr).unwrap();
                    let mut n = 0u32;
                    while !done.load(Ordering::Relaxed) || n < 20 {
                        let c = (n as usize) % 3;
                        let req = format_request(
                            "/v1/models/m/predict",
                            &input_body(&one_hot_block(c)),
                            &[],
                        );
                        stream.write_all(&req).unwrap();
                        let resp = read_response(&mut stream).unwrap();
                        assert_eq!(resp.status, 200, "worker {w} req {n} dropped mid-swap");
                        let pred = parse_body(&resp).get("pred").as_usize().unwrap();
                        assert!(
                            pred == c || pred == (c + 1) % 3,
                            "worker {w} req {n}: pred {pred} matches neither version"
                        );
                        n += 1;
                    }
                })
            })
            .collect();
        let mut admin = TcpStream::connect(addr).unwrap();
        // v2, v1, v2: three cutovers under traffic, landing on rot-1
        for round in 0..3 {
            let path = if round % 2 == 0 { &p2 } else { &p1 };
            let body = format!("{{\"qpkg\":\"{}\"}}", path.display());
            admin
                .write_all(&format_request("/v1/models/m/load", body.as_bytes(), &[]))
                .unwrap();
            let resp = read_response(&mut admin).unwrap();
            assert_eq!(resp.status, 200, "swap {round}");
            assert_eq!(
                parse_body(&resp).get("version").as_usize(),
                Some(round + 2),
                "swap {round}"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        done.store(true, Ordering::Relaxed);
        for h in workers {
            h.join().unwrap();
        }
    });

    // the fleet landed on version 4 = rot-1 weights: the primed query
    // must be recomputed (new content id keys the cache), not replayed
    stream.write_all(&probe).unwrap();
    let fresh = read_response(&mut stream).unwrap();
    assert_eq!(fresh.status, 200);
    assert_eq!(fresh.header("x-cache"), Some("miss"), "stale cache hit after swap");
    assert_eq!(parse_body(&fresh).get("pred").as_usize(), Some(1));
    srv.stop();
}

#[test]
fn legacy_predict_alias_routes_by_body_model_and_answers_deprecation() {
    let mut models = registry(None);
    models.insert_model("m0", rot_model("rot0", 0)).unwrap();
    models.insert_model("m1", rot_model("rot1", 1)).unwrap();
    let srv = HttpServer::start_registry(models, &HttpCfg::default()).unwrap();
    let mut stream = TcpStream::connect(srv.addr()).unwrap();
    // resource route: no deprecation marker
    let req = format_request("/v1/models/m1/predict", &input_body(&one_hot_block(0)), &[]);
    stream.write_all(&req).unwrap();
    let resp = read_response(&mut stream).unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("deprecation"), None);
    assert_eq!(parse_body(&resp).get("pred").as_usize(), Some(1));
    // legacy alias: the body's model field routes, Deprecation: true
    let mut body = String::from("{\"model\":\"m1\",\"input\":[");
    for (i, v) in one_hot_block(2).iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&format!("{v}"));
    }
    body.push_str("]}");
    stream.write_all(&format_request("/v1/predict", body.as_bytes(), &[])).unwrap();
    let resp = read_response(&mut stream).unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("deprecation"), Some("true"));
    assert_eq!(parse_body(&resp).get("pred").as_usize(), Some(0)); // (2 + 1) % 3
    // legacy alias with no body model falls back to the default entry (m0)
    stream
        .write_all(&format_request("/v1/predict", &input_body(&one_hot_block(2)), &[]))
        .unwrap();
    let resp = read_response(&mut stream).unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(parse_body(&resp).get("pred").as_usize(), Some(2));
    srv.stop();
}
