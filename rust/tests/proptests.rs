//! Property-based tests over coordinator invariants.
//!
//! The offline crate cache has no `proptest`, so this file carries a small
//! hand-rolled property harness (`for_random_cases`) driven by the same
//! PCG32 substrate the data pipeline uses: each property runs against a
//! few hundred randomized cases with shrink-free but seeded-reproducible
//! failures (the failing seed is printed).

use oscillations_qat::analysis::histogram::Histogram;
use oscillations_qat::analysis::kl::gaussian_kl;
use oscillations_qat::deploy::serve::percentile as exact_percentile;
use oscillations_qat::coordinator::Schedule;
use oscillations_qat::deploy::engine::{
    dw_f32, dw_i32, dw_spatial_f32, dw_spatial_i32, matmul_f32, matmul_i32, packed_dw,
    packed_dw_spatial, packed_dw_spatial_i32, packed_matmul, packed_matmul_i32, EngineOpts,
};
use oscillations_qat::deploy::packed::Packed;
use oscillations_qat::json;
use oscillations_qat::obs::metrics::bucket_edges;
use oscillations_qat::obs::Histogram as ObsHistogram;
use oscillations_qat::quant::{self, range_est};
use oscillations_qat::rng::Pcg32;
use oscillations_qat::runtime::native::kernels::{self, OscState};
use oscillations_qat::state::NamedTensors;
use oscillations_qat::tensor::{round_ties_even, Tensor};
use oscillations_qat::toy::{run, stats, ToyCfg, ToyEstimator};

/// Mini property harness: `f(case_rng)` must hold for `n` seeded cases.
fn for_random_cases(n: u64, name: &str, mut f: impl FnMut(&mut Pcg32)) {
    for seed in 0..n {
        let mut rng = Pcg32::new(seed, 0x9999);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if result.is_err() {
            panic!("property {name} failed at case seed {seed}");
        }
    }
}

#[test]
fn fake_quant_always_on_grid_and_idempotent() {
    for_random_cases(300, "fq_grid", |rng| {
        let bits = 2 + rng.below(7) as u32;
        let (n, p) = quant::weight_grid(bits);
        let s = rng.uniform(1e-3, 0.5);
        let w: Vec<f32> = (0..rng.below(200) + 1).map(|_| rng.normal() * 2.0).collect();
        let q = quant::fake_quant(&w, s, n, p);
        for &v in &q {
            let int = v / s;
            assert!((int - round_ties_even(int)).abs() < 1e-4);
            assert!(int >= n - 1e-4 && int <= p + 1e-4);
        }
        let q2 = quant::fake_quant(&q, s, n, p);
        for (a, b) in q.iter().zip(&q2) {
            assert!((a - b).abs() < 1e-6, "not idempotent: {a} vs {b}");
        }
    });
}

#[test]
fn mse_scale_never_worse_than_absmax_scale() {
    for_random_cases(120, "mse_scale", |rng| {
        let bits = 2 + rng.below(4) as u32;
        let (n, p) = quant::weight_grid(bits);
        let scale = rng.uniform(0.01, 2.0);
        let w: Vec<f32> = (0..64 + rng.below(512)).map(|_| rng.normal() * scale).collect();
        let s = range_est::mse_weight_scale(&w, n, p);
        let absmax = w.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        if absmax > 0.0 {
            let naive = absmax / p.max(-n);
            assert!(
                quant::quant_mse(&w, s, n, p) <= quant::quant_mse(&w, naive, n, p) + 1e-12
            );
        }
    });
}

#[test]
fn schedules_stay_within_endpoint_bounds() {
    for_random_cases(300, "schedule_bounds", |rng| {
        let a = rng.uniform(-2.0, 2.0);
        let b = rng.uniform(-2.0, 2.0);
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        for sched in [Schedule::Cosine { from: a, to: b }, Schedule::Linear { from: a, to: b }] {
            for i in 0..=20 {
                let v = sched.at(i as f32 / 20.0);
                assert!(v >= lo - 1e-5 && v <= hi + 1e-5, "{sched:?} at {i}: {v}");
            }
            // monotone between endpoints
            let mut last = sched.at(0.0);
            for i in 1..=20 {
                let v = sched.at(i as f32 / 20.0);
                if b >= a {
                    assert!(v >= last - 1e-5);
                } else {
                    assert!(v <= last + 1e-5);
                }
                last = v;
            }
        }
    });
}

#[test]
fn json_roundtrip_arbitrary_trees() {
    for_random_cases(200, "json_roundtrip", |rng| {
        fn gen(rng: &mut Pcg32, depth: usize) -> json::Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => json::Json::Null,
                1 => json::Json::Bool(rng.next_f32() < 0.5),
                2 => json::Json::Num((rng.normal() * 100.0).round() as f64 / 4.0),
                3 => {
                    let n = rng.below(8);
                    json::Json::Str(
                        (0..n).map(|_| char::from(32 + rng.below(90) as u8)).collect(),
                    )
                }
                4 => json::Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
                _ => json::Json::Obj(
                    (0..rng.below(5))
                        .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let v = gen(rng, 3);
        let text = json::to_string(&v);
        let v2 = json::parse(&text).expect("parse own output");
        assert_eq!(v, v2, "roundtrip failed for {text}");
    });
}

#[test]
fn qtns_roundtrip_arbitrary_states() {
    for_random_cases(60, "qtns_roundtrip", |rng| {
        let mut s = NamedTensors::new();
        let n_tensors = 1 + rng.below(12);
        for i in 0..n_tensors {
            let ndim = rng.below(4);
            let shape: Vec<usize> = (0..ndim).map(|_| 1 + rng.below(6)).collect();
            let n: usize = shape.iter().product();
            let data: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            s.insert(format!("group{}/t{}", i % 3, i), Tensor::new(shape, data));
        }
        let dir = std::env::temp_dir().join("qat_prop_qtns");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("case_{}.qtns", rng.next_u32()));
        s.write_qtns(&p).unwrap();
        let s2 = NamedTensors::read_qtns(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(s.map, s2.map);
    });
}

#[test]
fn gaussian_kl_nonnegative() {
    for_random_cases(500, "kl_nonneg", |rng| {
        let m1 = rng.normal() * 3.0;
        let m2 = rng.normal() * 3.0;
        let v1 = rng.uniform(1e-4, 9.0);
        let v2 = rng.uniform(1e-4, 9.0);
        let kl = gaussian_kl(m1, v1, m2, v2);
        assert!(kl >= -1e-9, "KL must be >= 0: {kl}");
    });
}

#[test]
fn histogram_conserves_mass() {
    for_random_cases(200, "hist_mass", |rng| {
        let mut h = Histogram::new(-1.0, 1.0, 1 + rng.below(40));
        let n = rng.below(500);
        for _ in 0..n {
            h.add(rng.normal());
        }
        let binned: u64 = h.counts.iter().sum();
        assert_eq!(binned + h.clipped, h.total);
        assert_eq!(h.total, n as u64);
    });
}

#[test]
fn obs_histogram_percentiles_within_one_bucket_of_exact() {
    // the live log-bucketed latency histogram (obs::metrics) must agree
    // with the exact sort-based serve::percentile to within one √2
    // bucket at every sample size from 1 to ~10k; the bucket upper edge
    // it reports may over-state the true value but never under-state it
    for_random_cases(30, "obs_hist_pcts", |rng| {
        let n = 1 + rng.below(10_000);
        let h = ObsHistogram::new();
        let mut xs: Vec<f64> = (0..n)
            .map(|_| (rng.uniform(0.0, 1.0) as f64).powi(3) * 2.0 + 1e-6)
            .collect();
        for &x in &xs {
            h.record(x);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // same edge-table indexing the histogram itself uses
        let bucket = |v: f64| bucket_edges().partition_point(|&e| v > e);
        for q in [0.5, 0.95, 0.99] {
            let exact = exact_percentile(&xs, q);
            let approx = h.percentile(q);
            assert!(approx.is_finite(), "n={n} q={q}: non-finite {approx}");
            let (be, ba) = (bucket(exact), bucket(approx));
            assert!(
                be.abs_diff(ba) <= 1,
                "n={n} q={q}: exact {exact} (bucket {be}) vs hist {approx} (bucket {ba})"
            );
            assert!(approx >= exact * (1.0 - 1e-12), "n={n} q={q}: {approx} < {exact}");
        }
    });
    // empty histograms mirror serve::percentile's NaN no-sample marker
    assert!(ObsHistogram::new().percentile(0.5).is_nan());
}

// ---------------------------------------------------------------------
// Native-backend kernel invariants

#[test]
fn native_fake_quant_on_grid_and_idempotent() {
    for_random_cases(300, "native_fq_grid", |rng| {
        let bits = 2 + rng.below(7) as u32;
        let (n, p) = quant::weight_grid(bits);
        let s = rng.uniform(1e-3, 0.5);
        let w: Vec<f32> = (0..rng.below(200) + 1).map(|_| rng.normal() * 2.0).collect();
        let q = kernels::fake_quant(&w, s, n, p);
        for &v in &q {
            let int = v / s;
            assert!((int - round_ties_even(int)).abs() < 1e-4, "off-grid: {v}");
            assert!(int >= n - 1e-4 && int <= p + 1e-4, "outside grid: {v}");
        }
        let q2 = kernels::fake_quant(&q, s, n, p);
        for (a, b) in q.iter().zip(&q2) {
            assert!((a - b).abs() < 1e-6, "not idempotent: {a} vs {b}");
        }
        // and the native kernel is the same function as the host mirror
        assert_eq!(q, quant::fake_quant(&w, s, n, p));
    });
}

fn random_osc_state(rng: &mut Pcg32, len: usize, n: f32, p: f32) -> OscState {
    let span = (p - n) as usize + 1;
    let int = |rng: &mut Pcg32| (n + rng.below(span) as f32).clamp(n, p);
    OscState {
        f: (0..len).map(|_| rng.uniform(0.0, 1.0)).collect(),
        b: (0..len).map(|_| if rng.next_f32() < 0.3 { 1.0 } else { 0.0 }).collect(),
        fint: (0..len).map(|_| int(rng)).collect(),
        psign: (0..len).map(|_| rng.below(3) as f32 - 1.0).collect(),
        wintp: (0..len).map(|_| int(rng)).collect(),
        iema: (0..len).map(|_| int(rng) + rng.uniform(-0.4, 0.4)).collect(),
    }
}

#[test]
fn native_osc_ema_stays_in_unit_interval() {
    // f is an EMA of a {0,1} indicator: it must stay inside [0, 1] for any
    // momentum m in [0, 1] and any trajectory of weight proposals
    for_random_cases(120, "osc_ema_bounded", |rng| {
        let bits = 2 + rng.below(3) as u32;
        let (n, p) = quant::weight_grid(bits);
        let s = rng.uniform(0.01, 0.4);
        let len = 1 + rng.below(40);
        let mut st = random_osc_state(rng, len, n, p);
        let m = rng.uniform(0.0, 1.0);
        let f_th = rng.uniform(0.005, 1.2);
        for _ in 0..30 {
            let mut w: Vec<f32> = (0..len).map(|_| rng.normal() * s * 4.0).collect();
            let osc = kernels::osc_update(&mut w, s, n, p, &mut st, m, f_th);
            for i in 0..len {
                assert!((0.0..=1.0).contains(&st.f[i]), "f out of [0,1]: {}", st.f[i]);
                assert!(osc[i] == 0.0 || osc[i] == 1.0);
                assert!(st.b[i] == 0.0 || st.b[i] == 1.0);
                assert!(st.psign[i] == -1.0 || st.psign[i] == 0.0 || st.psign[i] == 1.0);
            }
        }
    });
}

#[test]
fn native_frozen_weights_never_change() {
    // once b = 1, the integer assignment is immutable and the latent
    // weight always equals s * fint, whatever SGD proposes
    for_random_cases(80, "frozen_immutable", |rng| {
        let (n, p) = quant::weight_grid(2 + rng.below(3) as u32);
        let s = rng.uniform(0.01, 0.4);
        let len = 1 + rng.below(30);
        let mut st = random_osc_state(rng, len, n, p);
        // low threshold: freezing happens eagerly during the run
        let m = rng.uniform(0.05, 0.5);
        let f_th = 0.01;
        let mut frozen_int: Vec<Option<f32>> = vec![None; len];
        for _ in 0..40 {
            let mut w: Vec<f32> = (0..len).map(|_| rng.normal() * s * 4.0).collect();
            kernels::osc_update(&mut w, s, n, p, &mut st, m, f_th);
            for i in 0..len {
                if let Some(fint) = frozen_int[i] {
                    assert_eq!(st.b[i], 1.0, "weight un-froze");
                    assert_eq!(st.fint[i], fint, "frozen integer drifted");
                    assert!((w[i] - s * fint).abs() < 1e-6, "latent left the pin");
                }
                if st.b[i] > 0.5 && frozen_int[i].is_none() {
                    frozen_int[i] = Some(st.fint[i]);
                    assert!(st.fint[i] >= n && st.fint[i] <= p, "pin off-grid");
                }
            }
        }
    });
}

#[test]
fn native_quant_matmul_matches_naive() {
    for_random_cases(80, "qmm_naive", |rng| {
        let (gn, gp) = quant::weight_grid(2 + rng.below(4) as u32);
        let s = rng.uniform(0.01, 0.5);
        let (m, k, n) = (1 + rng.below(6), 1 + rng.below(10), 1 + rng.below(6));
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
        let got = kernels::quant_matmul(&x, &w, m, k, n, s, gn, gp);
        let wq = quant::fake_quant(&w, s, gn, gp);
        for i in 0..m {
            for j in 0..n {
                let mut want = 0.0f32;
                for kk in 0..k {
                    want += x[i * k + kk] * wq[kk * n + j];
                }
                assert!(
                    (got[i * n + j] - want).abs() < 1e-4,
                    "qmm[{i},{j}]: {} vs {want}",
                    got[i * n + j]
                );
            }
        }
    });
}

// ---------------------------------------------------------------------
// Deploy-engine bit-exactness (packed integer inference vs the native
// fake-quant kernels)

/// Snap `w` to the grid and bit-pack it — through the exporter's own
/// mapping, so these properties test the real encoding.
fn pack_like_export(w: &[f32], s: f32, bits: u32) -> (Packed, i32) {
    oscillations_qat::deploy::export::snap_and_pack(w, s, bits).unwrap()
}

#[test]
fn packed_roundtrip_arbitrary_codes() {
    for_random_cases(200, "packed_roundtrip", |rng| {
        let bits = 1 + rng.below(8) as u32;
        let n = 1 + rng.below(300);
        let codes: Vec<u32> = (0..n).map(|_| rng.below(1usize << bits) as u32).collect();
        let p = Packed::pack(&codes, bits).unwrap();
        assert_eq!(p.unpack(), codes);
        assert_eq!(p.bytes.len(), (n * bits as usize + 7) / 8);
    });
}

#[test]
fn bulk_lut_decoder_bitexact_vs_get_loop() {
    // the byte-level bulk decoder (LUT bytes for 1/2/4/8-bit, u64-window
    // chunks for 3/5/6/7-bit) must reproduce per-element `get(i)` for
    // every width and for odd lengths that straddle bytes and chunks
    for_random_cases(300, "bulk_decode", |rng| {
        let bits = 1 + rng.below(8) as u32;
        // lengths deliberately off every chunk multiple (8-code chunks,
        // 2/4/8-code bytes): n mod lcm is uniform over the cases
        let n = 1 + rng.below(97);
        let codes: Vec<u32> = (0..n).map(|_| rng.below(1usize << bits) as u32).collect();
        let p = Packed::pack(&codes, bits).unwrap();
        let by_get: Vec<u32> = (0..p.len).map(|i| p.get(i)).collect();
        let mut bulk = Vec::new();
        p.unpack_into(&mut bulk);
        assert_eq!(bulk, by_get, "bits {bits} n {n}");
        // the signed-int bulk decode is the same stream plus the offset
        let grid_n = -(1i32 << (bits - 1));
        let mut ints = Vec::new();
        p.ints_into(grid_n, &mut ints);
        let want: Vec<i32> = by_get.iter().map(|&c| c as i32 + grid_n).collect();
        assert_eq!(ints, want, "bits {bits} n {n}");
    });
}

#[test]
fn blocked_kernels_bitexact_vs_scalar_reference() {
    // the cache-blocked, register-tiled plane kernels must equal the
    // plain scalar loops to the bit: the f32 pair because the per-output
    // accumulation order (kk ascending, a == 0.0 skip) is preserved, the
    // i32 pair because integer arithmetic is exact
    for_random_cases(150, "blocked_kernels", |rng| {
        let (m, k, n) = (1 + rng.below(5), 1 + rng.below(150), 1 + rng.below(9));
        let mut x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        for v in x.iter_mut() {
            if rng.next_f32() < 0.3 {
                *v = 0.0;
            }
        }
        let wq: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
        let mut got = vec![0.0f32; m * n];
        matmul_f32(&x, &wq, m, k, n, &mut got);
        let mut want = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let a = x[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                for j in 0..n {
                    want[i * n + j] += a * wq[kk * n + j];
                }
            }
        }
        assert_eq!(got, want, "matmul_f32 {m}x{k}x{n}");

        let qa: Vec<i32> = (0..m * k).map(|_| rng.below(16) as i32 - 2).collect();
        let wi: Vec<i32> = (0..k * n).map(|_| rng.below(255) as i32 - 127).collect();
        let mut got = vec![0i32; m * n];
        matmul_i32(&qa, &wi, m, k, n, &mut got);
        let mut want = vec![0i32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    want[i * n + j] += qa[i * k + kk] * wi[kk * n + j];
                }
            }
        }
        assert_eq!(got, want, "matmul_i32 {m}x{k}x{n}");

        // unrolled circular dw (wrap channels peeled) vs the modulo loop
        let c = 1 + rng.below(20);
        let b = 1 + rng.below(4);
        let xd: Vec<f32> = (0..b * c).map(|_| rng.normal()).collect();
        let wd: Vec<f32> = (0..c * 3).map(|_| rng.normal() * 0.4).collect();
        let mut got = vec![0.0f32; b * c];
        dw_f32(&xd, &wd, b, c, &mut got);
        for bi in 0..b {
            for ci in 0..c {
                let mut acc = 0.0f32;
                for t in 0..3usize {
                    let j = (ci + t + c - 1) % c;
                    acc += wd[ci * 3 + t] * xd[bi * c + j];
                }
                assert_eq!(got[bi * c + ci], acc, "dw_f32 c {c} [{bi},{ci}]");
            }
        }
        let qd: Vec<i32> = (0..b * c).map(|_| rng.below(16) as i32).collect();
        let wdi: Vec<i32> = (0..c * 3).map(|_| rng.below(15) as i32 - 7).collect();
        let mut got = vec![0i32; b * c];
        dw_i32(&qd, &wdi, b, c, &mut got);
        for bi in 0..b {
            for ci in 0..c {
                let mut acc = 0i32;
                for t in 0..3usize {
                    let j = (ci + t + c - 1) % c;
                    acc += wdi[ci * 3 + t] * qd[bi * c + j];
                }
                assert_eq!(got[bi * c + ci], acc, "dw_i32 c {c} [{bi},{ci}]");
            }
        }
    });
}

#[test]
fn packed_dequant_matches_fake_quant_exactly() {
    // the engine's on-the-fly dequant must reproduce the fake-quant
    // weights bit for bit on every grid the runtime uses
    for_random_cases(200, "packed_dequant", |rng| {
        let bits = [2u32, 3, 4, 8][rng.below(4)];
        let (gn, gp) = quant::weight_grid(bits);
        let s = rng.uniform(1e-3, 0.5);
        let w: Vec<f32> = (0..1 + rng.below(200)).map(|_| rng.normal() * 2.0).collect();
        let (packed, grid_n) = pack_like_export(&w, s, bits);
        let mut deq = Vec::new();
        packed.dequant_into(grid_n, s, &mut deq);
        assert_eq!(deq, kernels::fake_quant(&w, s, gn, gp), "bits {bits}");
    });
}

#[test]
fn packed_matmul_bitexact_vs_native_kernel() {
    // same loop order, same `a == 0.0` skip: the packed engine must match
    // kernels::quant_matmul to the bit for 2/3/4/8-bit grids
    for_random_cases(120, "packed_matmul_exact", |rng| {
        let bits = [2u32, 3, 4, 8][rng.below(4)];
        let (gn, gp) = quant::weight_grid(bits);
        let s = rng.uniform(0.01, 0.5);
        let (m, k, n) = (1 + rng.below(5), 1 + rng.below(12), 1 + rng.below(7));
        let mut x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        // force exact zeros so the skip fast path is exercised every case
        for v in x.iter_mut() {
            if rng.next_f32() < 0.3 {
                *v = 0.0;
            }
        }
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
        let (packed, grid_n) = pack_like_export(&w, s, bits);
        let got = packed_matmul(&x, &packed, m, k, n, &[s], grid_n);
        let want = kernels::quant_matmul(&x, &w, m, k, n, s, gn, gp);
        assert_eq!(got, want, "bits {bits} m {m} k {k} n {n}");
    });
}

#[test]
fn packed_dw_bitexact_vs_interp_order() {
    // the depthwise 3-tap kernel accumulates in the interpreter's exact
    // order; replay that order here over fake-quant weights
    for_random_cases(120, "packed_dw_exact", |rng| {
        let bits = [2u32, 3, 4, 8][rng.below(4)];
        let (gn, gp) = quant::weight_grid(bits);
        let s = rng.uniform(0.01, 0.5);
        let (b, c) = (1 + rng.below(4), 3 + rng.below(12));
        let x: Vec<f32> = (0..b * c).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..c * 3).map(|_| rng.normal() * 0.4).collect();
        let (packed, grid_n) = pack_like_export(&w, s, bits);
        let got = packed_dw(&x, &packed, b, c, &[s], grid_n);
        let wq = kernels::fake_quant(&w, s, gn, gp);
        for bi in 0..b {
            for ci in 0..c {
                let mut acc = 0.0f32;
                for t in 0..3usize {
                    let j = (ci + t + c - 1) % c;
                    acc += wq[ci * 3 + t] * x[bi * c + j];
                }
                assert_eq!(got[bi * c + ci], acc, "bits {bits} [{bi},{ci}]");
            }
        }
    });
}

#[test]
fn i32_accumulation_exact_on_power_of_two_scales() {
    // with power-of-two scales and small integers every f32 op is exact,
    // so the i32 path must agree with the f32 path to the bit — this
    // pins the integer accumulation (and its qa == 0 skip) itself
    for_random_cases(120, "i32_accum_exact", |rng| {
        let bits = [2u32, 3, 4, 8][rng.below(4)];
        let s_a = [0.5f32, 0.25, 0.125][rng.below(3)];
        let s_w = [0.5f32, 0.25, 0.0625][rng.below(3)];
        let (gn, gp) = quant::weight_grid(bits);
        let (m, k, n) = (1 + rng.below(4), 1 + rng.below(10), 1 + rng.below(6));
        let qa: Vec<i32> = (0..m * k).map(|_| rng.below(8) as i32).collect();
        let w: Vec<f32> = (0..k * n)
            .map(|_| (gn + rng.below((gp - gn) as usize + 1) as f32) * s_w)
            .collect();
        let (packed, grid_n) = pack_like_export(&w, s_w, bits);
        let acc = packed_matmul_i32(&qa, &packed, m, k, n, grid_n);
        let zscale = s_a as f64 * s_w as f64;
        let got: Vec<f32> = acc.iter().map(|&v| (zscale * v as f64) as f32).collect();
        let a_q: Vec<f32> = qa.iter().map(|&c| s_a * c as f32).collect();
        let want = packed_matmul(&a_q, &packed, m, k, n, &[s_w], grid_n);
        assert_eq!(got, want, "bits {bits} s_a {s_a} s_w {s_w}");
    });
}

// ---------------------------------------------------------------------
// Per-channel round-trip bit-exactness: random per-channel scales at
// 2/3/4/8 bits -> export encoding -> QPKG v2 bytes -> engine math equals
// the per-channel fake-quant eval math, to the bit.

/// Random positive per-channel scale vector.
fn random_scales(rng: &mut Pcg32, n_ch: usize) -> Vec<f32> {
    (0..n_ch).map(|_| rng.uniform(5e-3, 0.5)).collect()
}

#[test]
fn per_channel_dequant_matches_fake_quant_pc_exactly() {
    use oscillations_qat::deploy::export::snap_and_pack_pc;
    use oscillations_qat::runtime::native::kernels::fake_quant_pc;
    for_random_cases(200, "pc_dequant", |rng| {
        let bits = [2u32, 3, 4, 8][rng.below(4)];
        let (gn, gp) = quant::weight_grid(bits);
        // both layouts: dense columns (group 1) and dw rows (group 3)
        for group in [1usize, 3] {
            let n_ch = 1 + rng.below(8);
            let rows = 1 + rng.below(20);
            let len = if group == 1 { rows * n_ch } else { n_ch * 3 };
            let scales = random_scales(rng, n_ch);
            let w: Vec<f32> = (0..len).map(|_| rng.normal() * 2.0).collect();
            let (packed, grid_n) = snap_and_pack_pc(&w, &scales, group, bits).unwrap();
            let mut deq = Vec::new();
            packed.dequant_pc_into(grid_n, &scales, group, &mut deq);
            assert_eq!(
                deq,
                fake_quant_pc(&w, &scales, group, gn, gp),
                "bits {bits} group {group}"
            );
        }
    });
}

#[test]
fn per_channel_qpkg_v2_roundtrip_is_engine_bitexact() {
    use oscillations_qat::deploy::export::snap_and_pack_pc;
    use oscillations_qat::deploy::format::{DeployLayer, DeployModel, DeployOp};
    use oscillations_qat::runtime::native::kernels::fake_quant_pc;
    for_random_cases(60, "pc_qpkg_roundtrip", |rng| {
        let bits = [2u32, 3, 4, 8][rng.below(4)];
        let (gn, gp) = quant::weight_grid(bits);
        // one full layer (hw chosen so d_in = hw*hw*3) + one dw layer
        let hw = 1 + rng.below(3);
        let d_in = hw * hw * 3;
        let c = 2 + rng.below(6);
        let full_scales = random_scales(rng, c);
        let dw_scales = random_scales(rng, c);
        let w_full: Vec<f32> = (0..d_in * c).map(|_| rng.normal() * 0.5).collect();
        let w_dw: Vec<f32> = (0..c * 3).map(|_| rng.normal() * 0.5).collect();
        let (p_full, grid_n) = snap_and_pack_pc(&w_full, &full_scales, 1, bits).unwrap();
        let (p_dw, _) = snap_and_pack_pc(&w_dw, &dw_scales, 3, bits).unwrap();
        let layer = |name: &str, op, d_in, weights, scales: &Vec<f32>| DeployLayer {
            name: name.into(),
            op,
            d_in,
            d_out: c,
            relu: false,
            aq: false,
            act_bits: 8,
            a_scales: vec![1.0],
            w_bits: bits,
            w_scales: scales.clone(),
            weights,
            bias: None,
            requant: None,
            spatial: None,
        };
        let dm = DeployModel {
            name: "pcprop".into(),
            input_hw: hw,
            num_classes: c,
            quant_a: false,
            bits_w: bits,
            bits_a: 8,
            layers: vec![
                layer("full", DeployOp::Full, d_in, p_full, &full_scales),
                layer("dw", DeployOp::Dw, c, p_dw, &dw_scales),
            ],
        };
        // QPKG v2 byte round-trip preserves everything
        let dm2 = DeployModel::from_bytes(&dm.to_bytes()).expect("v2 roundtrip");
        assert_eq!(dm, dm2);
        // engine forward == per-channel fake-quant reference math, bit
        // for bit (same loop order as the native interpreter)
        let b = 1 + rng.below(3);
        let mut x: Vec<f32> = (0..b * d_in).map(|_| rng.normal()).collect();
        for v in x.iter_mut() {
            if rng.next_f32() < 0.25 {
                *v = 0.0;
            }
        }
        let got = oscillations_qat::deploy::Engine::new(dm2).forward_batch(&x, b).unwrap();
        let wq_full = fake_quant_pc(&w_full, &full_scales, 1, gn, gp);
        let wq_dw = fake_quant_pc(&w_dw, &dw_scales, 3, gn, gp);
        let mut mid = vec![0.0f32; b * c];
        for bi in 0..b {
            for kk in 0..d_in {
                let a = x[bi * d_in + kk];
                if a == 0.0 {
                    continue;
                }
                for j in 0..c {
                    mid[bi * c + j] += a * wq_full[kk * c + j];
                }
            }
        }
        let mut want = vec![0.0f32; b * c];
        for bi in 0..b {
            for ci in 0..c {
                let mut acc = 0.0f32;
                for t in 0..3usize {
                    let j = (ci + t + c - 1) % c;
                    acc += wq_dw[ci * 3 + t] * mid[bi * c + j];
                }
                want[bi * c + ci] = acc;
            }
        }
        assert_eq!(got, want, "bits {bits} c {c} hw {hw}");
    });
}

#[test]
fn prepared_threaded_engine_bitexact_vs_streaming() {
    // decode-once planes, per-call streaming decode, and the scoped
    // batch-row thread split are three routes through identical
    // arithmetic: the logits must agree to the bit in both accumulation
    // modes, on random per-channel models with quantized activations
    use oscillations_qat::deploy::export::snap_and_pack_pc;
    use oscillations_qat::deploy::format::{DeployLayer, DeployModel, DeployOp, Requant};
    for_random_cases(40, "engine_modes", |rng| {
        let bits = [2u32, 3, 4, 8][rng.below(4)];
        let hw = 1 + rng.below(3);
        let d_in = hw * hw * 3;
        let c = 2 + rng.below(6);
        let full_scales = random_scales(rng, c);
        let dw_scales = random_scales(rng, c);
        let w_full: Vec<f32> = (0..d_in * c).map(|_| rng.normal() * 0.5).collect();
        let w_dw: Vec<f32> = (0..c * 3).map(|_| rng.normal() * 0.5).collect();
        let (p_full, _) = snap_and_pack_pc(&w_full, &full_scales, 1, bits).unwrap();
        let (p_dw, _) = snap_and_pack_pc(&w_dw, &dw_scales, 3, bits).unwrap();
        let dm = DeployModel {
            name: "modes".into(),
            input_hw: hw,
            num_classes: c,
            quant_a: true,
            bits_w: bits,
            bits_a: bits,
            layers: vec![
                DeployLayer {
                    name: "full".into(),
                    op: DeployOp::Full,
                    d_in,
                    d_out: c,
                    relu: true,
                    aq: false,
                    act_bits: 8,
                    a_scales: vec![1.0],
                    w_bits: bits,
                    w_scales: full_scales.clone(),
                    weights: p_full,
                    bias: Some((0..c).map(|_| rng.normal() * 0.1).collect()),
                    requant: Some(Requant {
                        mult: (0..c).map(|_| rng.uniform(0.5, 2.0)).collect(),
                        add: (0..c).map(|_| rng.normal() * 0.1).collect(),
                    }),
                    spatial: None,
                },
                DeployLayer {
                    name: "dw".into(),
                    op: DeployOp::Dw,
                    d_in: c,
                    d_out: c,
                    relu: false,
                    aq: true,
                    act_bits: bits,
                    a_scales: vec![rng.uniform(0.01, 0.3)],
                    w_bits: bits,
                    w_scales: dw_scales.clone(),
                    weights: p_dw,
                    bias: None,
                    requant: None,
                    spatial: None,
                },
            ],
        };
        let b = 1 + rng.below(6);
        let x: Vec<f32> = (0..b * d_in).map(|_| rng.normal()).collect();
        for int_accum in [false, true] {
            let streaming = oscillations_qat::deploy::Engine::with_opts(
                dm.clone(),
                int_accum,
                EngineOpts { prepared: false, ..Default::default() },
            )
            .forward_batch(&x, b)
            .unwrap();
            let prepared = oscillations_qat::deploy::Engine::with_opts(
                dm.clone(),
                int_accum,
                EngineOpts::default(),
            )
            .forward_batch(&x, b)
            .unwrap();
            assert_eq!(streaming, prepared, "bits {bits} int_accum {int_accum}");
            let threads = 2 + rng.below(3);
            let mt = oscillations_qat::deploy::Engine::with_opts(
                dm.clone(),
                int_accum,
                EngineOpts { threads, ..Default::default() },
            )
            .forward_batch(&x, b)
            .unwrap();
            assert_eq!(prepared, mt, "bits {bits} int_accum {int_accum} threads {threads}");
        }
    });
}

#[test]
fn per_channel_activation_engine_bitexact_vs_interp_math() {
    // QPKG v3: per-input-channel activation scales on every quantized-
    // activation site. The engine (prepared, streaming, threaded, both
    // accumulation modes) must reproduce the interpreter's fake-quant
    // arithmetic to the bit: per-channel act fake-quant, then the scalar
    // loop order over per-channel fake-quant weights.
    use oscillations_qat::deploy::export::snap_and_pack_pc;
    use oscillations_qat::deploy::format::{DeployLayer, DeployModel, DeployOp, Requant};
    use oscillations_qat::runtime::native::kernels::fake_quant_pc;
    for_random_cases(40, "pcact_engine", |rng| {
        let bits = [2u32, 3, 4, 8][rng.below(4)];
        let (gn, gp) = quant::weight_grid(bits);
        let act_p = quant::act_grid(bits);
        let hw = 1 + rng.below(3);
        let d_in = hw * hw * 3;
        let c = 2 + rng.below(6);
        let full_scales = random_scales(rng, c);
        let dw_scales = random_scales(rng, c);
        // per-channel activation scales on BOTH quantized sites
        let a1: Vec<f32> = (0..d_in).map(|_| rng.uniform(0.01, 0.4)).collect();
        let a2: Vec<f32> = (0..c).map(|_| rng.uniform(0.01, 0.4)).collect();
        let w_full: Vec<f32> = (0..d_in * c).map(|_| rng.normal() * 0.5).collect();
        let w_dw: Vec<f32> = (0..c * 3).map(|_| rng.normal() * 0.5).collect();
        let (p_full, _) = snap_and_pack_pc(&w_full, &full_scales, 1, bits).unwrap();
        let (p_dw, _) = snap_and_pack_pc(&w_dw, &dw_scales, 3, bits).unwrap();
        let requant = Requant {
            mult: (0..c).map(|_| rng.uniform(0.5, 2.0)).collect(),
            add: (0..c).map(|_| rng.normal() * 0.1).collect(),
        };
        let dm = DeployModel {
            name: "pcact".into(),
            input_hw: hw,
            num_classes: c,
            quant_a: true,
            bits_w: bits,
            bits_a: bits,
            layers: vec![
                DeployLayer {
                    name: "full".into(),
                    op: DeployOp::Full,
                    d_in,
                    d_out: c,
                    relu: true,
                    aq: true,
                    act_bits: bits,
                    a_scales: a1.clone(),
                    w_bits: bits,
                    w_scales: full_scales.clone(),
                    weights: p_full,
                    bias: None,
                    requant: Some(requant.clone()),
                    spatial: None,
                },
                DeployLayer {
                    name: "dw".into(),
                    op: DeployOp::Dw,
                    d_in: c,
                    d_out: c,
                    relu: false,
                    aq: true,
                    act_bits: bits,
                    a_scales: a2.clone(),
                    w_bits: bits,
                    w_scales: dw_scales.clone(),
                    weights: p_dw,
                    bias: None,
                    requant: None,
                    spatial: None,
                },
            ],
        };
        // the v3 byte round-trip preserves the activation scale arrays
        let dm2 = oscillations_qat::deploy::format::DeployModel::from_bytes(&dm.to_bytes())
            .expect("v3 roundtrip");
        assert_eq!(dm, dm2);

        let b = 1 + rng.below(4);
        let x: Vec<f32> = (0..b * d_in).map(|_| rng.normal()).collect();

        // ---- interpreter-math reference ----
        let wq_full = fake_quant_pc(&w_full, &full_scales, 1, gn, gp);
        let wq_dw = fake_quant_pc(&w_dw, &dw_scales, 3, gn, gp);
        let aq1 = fake_quant_pc(&x, &a1, 1, 0.0, act_p);
        let mut mid = vec![0.0f32; b * c];
        for bi in 0..b {
            for kk in 0..d_in {
                let a = aq1[bi * d_in + kk];
                if a == 0.0 {
                    continue;
                }
                for j in 0..c {
                    mid[bi * c + j] += a * wq_full[kk * c + j];
                }
            }
        }
        for bi in 0..b {
            for j in 0..c {
                let idx = bi * c + j;
                mid[idx] = requant.mult[j] * mid[idx] + requant.add[j];
                if mid[idx] < 0.0 {
                    mid[idx] = 0.0;
                }
            }
        }
        let aq2 = fake_quant_pc(&mid, &a2, 1, 0.0, act_p);
        let mut want = vec![0.0f32; b * c];
        for bi in 0..b {
            for ci in 0..c {
                let mut acc = 0.0f32;
                for t in 0..3usize {
                    let j = (ci + t + c - 1) % c;
                    acc += wq_dw[ci * 3 + t] * aq2[bi * c + j];
                }
                want[bi * c + ci] = acc;
            }
        }

        // ---- every engine mode reproduces it to the bit ----
        for int_accum in [false, true] {
            for opts in [
                EngineOpts::default(),
                EngineOpts { prepared: false, ..Default::default() },
                EngineOpts { threads: 2 + rng.below(3), ..Default::default() },
            ] {
                let got = oscillations_qat::deploy::Engine::with_opts(dm.clone(), int_accum, opts)
                    .forward_batch(&x, b)
                    .unwrap();
                assert_eq!(got, want, "bits {bits} int_accum {int_accum} opts {opts:?}");
            }
        }
    });
}

/// Scalar oracle for the spatial depthwise kernels: per output element,
/// in-bounds taps in ascending `(ky, kx)` order — the interpreter's term
/// sequence. Shared by the f32 and (via exact small-integer values) the
/// i32 property below.
#[allow(clippy::too_many_arguments)]
fn dw_spatial_scalar_oracle(
    x: &[f32],
    wq: &[f32],
    b: usize,
    hw_in: usize,
    c_dim: usize,
    stride: usize,
    pad: usize,
) -> Vec<f32> {
    let hw_out = (hw_in + 2 * pad - 3) / stride + 1;
    let mut out = vec![0.0f32; b * hw_out * hw_out * c_dim];
    for bi in 0..b {
        for yo in 0..hw_out {
            for xo in 0..hw_out {
                for c in 0..c_dim {
                    let mut acc = 0.0f32;
                    for ky in 0..3usize {
                        let y = yo * stride + ky;
                        if y < pad || y - pad >= hw_in {
                            continue;
                        }
                        for kx in 0..3usize {
                            let xx = xo * stride + kx;
                            if xx < pad || xx - pad >= hw_in {
                                continue;
                            }
                            let j = ((y - pad) * hw_in + (xx - pad)) * c_dim + c;
                            acc += wq[c * 9 + ky * 3 + kx] * x[bi * hw_in * hw_in * c_dim + j];
                        }
                    }
                    out[(bi * hw_out * hw_out + yo * hw_out + xo) * c_dim + c] = acc;
                }
            }
        }
    }
    out
}

/// Random spatial-depthwise geometry drawn so `hw_out >= 1` always holds
/// (`hw_in + 2*pad >= 3`): returns `(hw_in, c, stride, pad)`.
fn random_spatial_geometry(rng: &mut Pcg32) -> (usize, usize, usize, usize) {
    let pad = rng.below(2);
    let hw_in = if pad == 0 { 3 + rng.below(3) } else { 1 + rng.below(5) };
    let c = 1 + rng.below(6);
    let stride = 1 + rng.below(2);
    (hw_in, c, stride, pad)
}

#[test]
fn spatial_dw_kernels_bitexact_vs_scalar_oracle() {
    // QPKG v4 kernels: the blocked f32 kernel, its streaming-decode
    // wrapper, and the i32 twin must reproduce the scalar tap walk to
    // the bit over random geometry (stride 1/2, pad 0/1) and random
    // per-channel scales (group = 9)
    use oscillations_qat::deploy::export::snap_and_pack_pc;
    use oscillations_qat::runtime::native::kernels::fake_quant_pc;
    for_random_cases(80, "dw_spatial_kernel", |rng| {
        let bits = [2u32, 3, 4, 8][rng.below(4)];
        let (gn, gp) = quant::weight_grid(bits);
        let (hw_in, c, stride, pad) = random_spatial_geometry(rng);
        let hw_out = (hw_in + 2 * pad - 3) / stride + 1;
        let b = 1 + rng.below(3);
        let scales = random_scales(rng, c);
        let x: Vec<f32> = (0..b * hw_in * hw_in * c).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..c * 9).map(|_| rng.normal() * 0.5).collect();
        let (packed, grid_n) = snap_and_pack_pc(&w, &scales, 9, bits).unwrap();
        let wq = fake_quant_pc(&w, &scales, 9, gn, gp);
        let want = dw_spatial_scalar_oracle(&x, &wq, b, hw_in, c, stride, pad);
        // prepared-plane kernel over the dequantized weights
        let mut got = vec![0.0f32; b * hw_out * hw_out * c];
        dw_spatial_f32(&x, &wq, b, hw_in, c, stride, pad, &mut got);
        assert_eq!(got, want, "f32 {hw_in}x{hw_in}x{c} s{stride} p{pad} bits {bits}");
        // streaming decode takes the same route through the bitstream
        let streamed = packed_dw_spatial(&x, &packed, b, hw_in, c, stride, pad, &scales, grid_n);
        assert_eq!(streamed, want, "streaming {hw_in}x{hw_in}x{c} s{stride} p{pad}");
        // i32 twin: small codes keep every product exact in f32, so the
        // f32 oracle doubles as the integer reference
        let qa: Vec<i32> = (0..b * hw_in * hw_in * c).map(|_| rng.below(16) as i32).collect();
        let mut wi = Vec::new();
        packed.ints_into(grid_n, &mut wi);
        let mut goti = vec![0i32; b * hw_out * hw_out * c];
        dw_spatial_i32(&qa, &wi, b, hw_in, c, stride, pad, &mut goti);
        let streamed_i = packed_dw_spatial_i32(&qa, &packed, b, hw_in, c, stride, pad, grid_n);
        assert_eq!(goti, streamed_i, "i32 prepared vs streaming");
        let xf: Vec<f32> = qa.iter().map(|&v| v as f32).collect();
        let wf: Vec<f32> = wi.iter().map(|&v| v as f32).collect();
        let wanti = dw_spatial_scalar_oracle(&xf, &wf, b, hw_in, c, stride, pad);
        let gotif: Vec<f32> = goti.iter().map(|&v| v as f32).collect();
        assert_eq!(gotif, wanti, "i32 {hw_in}x{hw_in}x{c} s{stride} p{pad}");
    });
}

#[test]
fn spatial_engine_modes_bitexact_and_v4_roundtrip() {
    // QPKG v4 end to end: a spatial depthwise layer with per-channel
    // weight AND activation scales feeding a dense head. The f32-exact
    // engine must reproduce the interpreter-math scalar reference to the
    // bit in every mode; the int-accum engine (which now takes the i32
    // fast path despite per_channel_act) must be mode-stable; and the v4
    // byte round-trip must preserve the model exactly.
    use oscillations_qat::deploy::export::snap_and_pack_pc;
    use oscillations_qat::deploy::format::{
        DeployLayer, DeployModel, DeployOp, DwSpatialMeta, Requant,
    };
    use oscillations_qat::runtime::native::kernels::fake_quant_pc;
    for_random_cases(40, "dw2d_engine", |rng| {
        let bits = [2u32, 3, 4, 8][rng.below(4)];
        let (gn, gp) = quant::weight_grid(bits);
        let act_p = quant::act_grid(bits);
        // channel count pinned to 3 so the first layer can sit at the
        // model input (d_in = input_hw^2 * 3); geometry otherwise random
        let c = 3usize;
        let pad = rng.below(2);
        let hw = if pad == 0 { 3 + rng.below(2) } else { 2 + rng.below(3) };
        let stride = 1 + rng.below(2);
        let hw_out = (hw + 2 * pad - 3) / stride + 1;
        let (d_in, d_sp) = (hw * hw * c, hw_out * hw_out * c);
        let nc = 2 + rng.below(4);
        let w_scales = random_scales(rng, c);
        let a_scales: Vec<f32> = (0..c).map(|_| rng.uniform(0.01, 0.4)).collect();
        let head_scales = random_scales(rng, nc);
        let w_sp: Vec<f32> = (0..c * 9).map(|_| rng.normal() * 0.5).collect();
        let w_head: Vec<f32> = (0..d_sp * nc).map(|_| rng.normal() * 0.5).collect();
        let (p_sp, _) = snap_and_pack_pc(&w_sp, &w_scales, 9, bits).unwrap();
        let (p_head, _) = snap_and_pack_pc(&w_head, &head_scales, 1, bits).unwrap();
        let requant = Requant {
            mult: (0..d_sp).map(|_| rng.uniform(0.5, 2.0)).collect(),
            add: (0..d_sp).map(|_| rng.normal() * 0.1).collect(),
        };
        let dm = DeployModel {
            name: "dw2d".into(),
            input_hw: hw,
            num_classes: nc,
            quant_a: true,
            bits_w: bits,
            bits_a: bits,
            layers: vec![
                DeployLayer {
                    name: "dw2d".into(),
                    op: DeployOp::DwSpatial,
                    d_in,
                    d_out: d_sp,
                    relu: true,
                    aq: true,
                    act_bits: bits,
                    a_scales: a_scales.clone(),
                    w_bits: bits,
                    w_scales: w_scales.clone(),
                    weights: p_sp,
                    bias: None,
                    requant: Some(requant.clone()),
                    spatial: Some(DwSpatialMeta {
                        kernel: 3,
                        stride,
                        pad,
                        hw_in: hw,
                        channels: c,
                    }),
                },
                DeployLayer {
                    name: "head".into(),
                    op: DeployOp::Full,
                    d_in: d_sp,
                    d_out: nc,
                    relu: false,
                    aq: false,
                    act_bits: 8,
                    a_scales: vec![1.0],
                    w_bits: bits,
                    w_scales: head_scales.clone(),
                    weights: p_head,
                    bias: None,
                    requant: None,
                    spatial: None,
                },
            ],
        };
        // v4 byte round-trip preserves the spatial metadata exactly
        let dm2 = DeployModel::from_bytes(&dm.to_bytes()).expect("v4 roundtrip");
        assert_eq!(dm, dm2);

        let b = 1 + rng.below(3);
        let x: Vec<f32> = (0..b * d_in).map(|_| rng.normal()).collect();

        // ---- interpreter-math reference (f32-exact route) ----
        let wq_sp = fake_quant_pc(&w_sp, &w_scales, 9, gn, gp);
        let wq_head = fake_quant_pc(&w_head, &head_scales, 1, gn, gp);
        let aq = fake_quant_pc(&x, &a_scales, 1, 0.0, act_p);
        let mut mid = dw_spatial_scalar_oracle(&aq, &wq_sp, b, hw, c, stride, pad);
        for bi in 0..b {
            for o in 0..d_sp {
                let idx = bi * d_sp + o;
                mid[idx] = requant.mult[o] * mid[idx] + requant.add[o];
                if mid[idx] < 0.0 {
                    mid[idx] = 0.0;
                }
            }
        }
        let mut want = vec![0.0f32; b * nc];
        for bi in 0..b {
            for kk in 0..d_sp {
                let a = mid[bi * d_sp + kk];
                if a == 0.0 {
                    continue;
                }
                for j in 0..nc {
                    want[bi * nc + j] += a * wq_head[kk * nc + j];
                }
            }
        }

        let modes = [
            EngineOpts::default(),
            EngineOpts { prepared: false, ..Default::default() },
            EngineOpts { threads: 2 + rng.below(3), ..Default::default() },
        ];
        // f32-exact engine == interpreter math, every mode
        for opts in modes {
            let got = oscillations_qat::deploy::Engine::with_opts(dm.clone(), false, opts)
                .forward_batch(&x, b)
                .unwrap();
            assert_eq!(got, want, "bits {bits} f32-exact opts {opts:?}");
        }
        // int-accum engine: the exact-integer fast path engages on the
        // per-channel-act spatial layer; all modes must agree bit-for-bit
        let int_ref = oscillations_qat::deploy::Engine::with_mode(dm.clone(), true)
            .forward_batch(&x, b)
            .unwrap();
        for opts in modes {
            let got = oscillations_qat::deploy::Engine::with_opts(dm.clone(), true, opts)
                .forward_batch(&x, b)
                .unwrap();
            assert_eq!(got, int_ref, "bits {bits} int-accum opts {opts:?}");
        }
        // and top-1 agreement between the two accumulation routes
        for bi in 0..b {
            let f = &want[bi * nc..(bi + 1) * nc];
            let i = &int_ref[bi * nc..(bi + 1) * nc];
            assert_eq!(
                oscillations_qat::deploy::engine::argmax(f),
                oscillations_qat::deploy::engine::argmax(i),
                "top-1 drift, sample {bi}"
            );
        }
    });
}

#[test]
fn spatial_i32_fast_path_exact_on_pow2_grids() {
    // On power-of-two scale grids every f32 op in the reference route is
    // exact, so the composed-requant i32 fast path must agree with the
    // f32-exact engine to the bit — including per-channel activation
    // scales, the configuration QPKG v4 newly admits to the integer path.
    use oscillations_qat::deploy::export::snap_and_pack_pc;
    use oscillations_qat::deploy::format::{
        DeployLayer, DeployModel, DeployOp, DwSpatialMeta, Requant,
    };
    for_random_cases(60, "dw2d_i32_exact", |rng| {
        let pow2 = [0.5f32, 0.25, 0.125, 0.0625];
        let c = 3usize;
        let pad = rng.below(2);
        let hw = if pad == 0 { 3 + rng.below(2) } else { 2 + rng.below(3) };
        let stride = 1 + rng.below(2);
        let hw_out = (hw + 2 * pad - 3) / stride + 1;
        let (d_in, d_sp) = (hw * hw * c, hw_out * hw_out * c);
        let w_scales: Vec<f32> = (0..c).map(|_| pow2[rng.below(4)]).collect();
        let a_scales: Vec<f32> = (0..c).map(|_| pow2[rng.below(4)]).collect();
        // weights already on each channel's grid: snap is the identity
        let w: Vec<f32> = (0..c * 9)
            .map(|i| (rng.below(15) as f32 - 7.0) * w_scales[i / 9])
            .collect();
        let (packed, _) = snap_and_pack_pc(&w, &w_scales, 9, 4).unwrap();
        let dm = DeployModel {
            name: "dw2d-i32".into(),
            input_hw: hw,
            num_classes: d_sp,
            quant_a: true,
            bits_w: 4,
            bits_a: 4,
            layers: vec![DeployLayer {
                name: "dw2d".into(),
                op: DeployOp::DwSpatial,
                d_in,
                d_out: d_sp,
                relu: rng.below(2) == 1,
                aq: true,
                act_bits: 4,
                a_scales: a_scales.clone(),
                w_bits: 4,
                w_scales: w_scales.clone(),
                weights: packed,
                bias: None,
                requant: Some(Requant {
                    // pow2 mults keep the composed product exact too
                    mult: (0..d_sp).map(|_| pow2[rng.below(4)] * 4.0).collect(),
                    add: (0..d_sp).map(|_| rng.normal() * 0.1).collect(),
                }),
                spatial: Some(DwSpatialMeta {
                    kernel: 3,
                    stride,
                    pad,
                    hw_in: hw,
                    channels: c,
                }),
            }],
        };
        let b = 1 + rng.below(4);
        // inputs already on each channel's activation grid
        let x: Vec<f32> = (0..b * d_in)
            .map(|i| rng.below(16) as f32 * a_scales[i % c])
            .collect();
        let exact = oscillations_qat::deploy::Engine::with_mode(dm.clone(), false)
            .forward_batch(&x, b)
            .unwrap();
        for opts in [
            EngineOpts::default(),
            EngineOpts { prepared: false, ..Default::default() },
            EngineOpts { threads: 2, ..Default::default() },
        ] {
            let got = oscillations_qat::deploy::Engine::with_opts(dm.clone(), true, opts)
                .forward_batch(&x, b)
                .unwrap();
            assert_eq!(got, exact, "i32 fast path must be exact, opts {opts:?}");
        }
    });
}

#[test]
fn adaround_pc_assignment_lands_on_channel_grid() {
    // per-channel Table-3 machinery: candidates collected from a state
    // with [d_out] scale vectors carry their own channel's step size, and
    // a sampled assignment lands every latent exactly on that channel's
    // grid.
    use oscillations_qat::quant::{adaround, sampler};
    for_random_cases(60, "adaround_pc", |rng| {
        // skip C = 3: a square [3, 3] tensor with 3 scales is the
        // documented `osc::scale_for` ambiguity (resolves to columns) and
        // no zoo layer has it — dw widths are 32..64
        let c = match 2 + rng.below(8) {
            3 => 4,
            other => other,
        };
        let scales: Vec<f32> = (0..c).map(|_| rng.uniform(0.01, 0.5)).collect();
        let (n, p) = quant::weight_grid(3);
        let w: Vec<f32> = (0..c * 3).map(|_| rng.normal() * 0.5).collect();
        let mut s = NamedTensors::new();
        s.insert("params/d.w", Tensor::new(vec![c, 3], w));
        s.insert("params/d.s", Tensor::new(vec![c], scales.clone()));
        s.insert(
            "osc/d.w#f",
            Tensor::new(vec![c, 3], (0..c * 3).map(|_| rng.uniform(0.0, 0.1)).collect()),
        );
        s.insert(
            "osc/d.w#iema",
            Tensor::new(vec![c, 3], (0..c * 3).map(|_| rng.uniform(-3.5, 2.5)).collect()),
        );
        let lb = vec!["d.w".to_string()];
        let mut cands = adaround::collect_candidates(
            &s,
            &lb,
            |name| format!("{}.s", &name[..name.len() - 2]),
            0.05,
            n,
            p,
        );
        // each candidate resolved its own channel's scale ([C, 3] rows)
        for cand in &cands {
            assert_eq!(
                cand.scale,
                scales[cand.index / 3],
                "candidate {} wrong channel scale",
                cand.index
            );
        }
        // a stochastic sample lands every candidate latent on its grid
        let mut srng = Pcg32::new(rng.next_u32() as u64, 0xad);
        sampler::sample_assignment(&mut s, &mut cands, &mut srng);
        let w2 = s.get("params/d.w").unwrap();
        for cand in &cands {
            let int = if cand.up { cand.down + 1.0 } else { cand.down };
            assert!(int >= n && int <= p, "assignment escaped the grid");
            assert_eq!(w2.data[cand.index], cand.scale * int, "index {}", cand.index);
            let r = w2.data[cand.index] / cand.scale;
            assert!((r - round_ties_even(r)).abs() < 1e-4, "latent off-grid: {r}");
        }
    });
}

// ---------------------------------------------------------------------
// Shard wire protocol: the supervisor <-> shard-worker framing must
// round-trip arbitrary payloads and reject truncated / oversized /
// garbage input with a typed error — never a panic, never a hang.

#[test]
fn shard_frames_roundtrip_and_prefixes_never_panic() {
    use oscillations_qat::deploy::serve::shard::proto::{
        decode_frame, encode_frame, FrameType, HEADER_LEN,
    };
    let types = [
        FrameType::Hello,
        FrameType::Request,
        FrameType::Response,
        FrameType::Error,
        FrameType::Heartbeat,
        FrameType::Shutdown,
    ];
    for_random_cases(200, "shard_frame_roundtrip", |rng| {
        let ty = types[rng.below(types.len())];
        let payload: Vec<u8> = (0..rng.below(600)).map(|_| rng.below(256) as u8).collect();
        let frame = encode_frame(ty, &payload);
        assert_eq!(frame.len(), HEADER_LEN + payload.len());
        let (got_ty, got_payload, used) =
            decode_frame(&frame).expect("valid frame").expect("complete frame");
        assert_eq!(got_ty, ty);
        assert_eq!(got_payload, &payload[..]);
        assert_eq!(used, frame.len());
        // every strict prefix is "need more bytes", never an error: a
        // slow or killed peer must not be misread as a protocol breach
        let cut = rng.below(frame.len());
        assert_eq!(decode_frame(&frame[..cut]).expect("prefix"), None, "cut at {cut}");
        // trailing bytes of a following frame are left untouched
        let mut two = frame.clone();
        two.extend_from_slice(&encode_frame(FrameType::Heartbeat, &[]));
        let (_, _, used2) = decode_frame(&two).unwrap().unwrap();
        assert_eq!(used2, frame.len());
    });
}

#[test]
fn shard_frame_decoder_rejects_garbage_without_panicking() {
    use oscillations_qat::deploy::serve::shard::proto::{
        decode_frame, FrameType, ProtoError, MAGIC, MAX_FRAME, VERSION,
    };
    for_random_cases(300, "shard_frame_garbage", |rng| {
        // pure noise: must return Ok(None) or a typed error, never panic
        let noise: Vec<u8> = (0..rng.below(64)).map(|_| rng.below(256) as u8).collect();
        let _ = decode_frame(&noise);
        if let Some(&b0) = noise.first() {
            if b0 != MAGIC[0] {
                assert_eq!(decode_frame(&noise), Err(ProtoError::BadMagic));
            }
        }
        // a declared length beyond MAX_FRAME is rejected from the header
        // alone — the decoder must not wait for (or allocate) the body
        let over = (MAX_FRAME + 1 + rng.below(1 << 20)) as u32;
        let mut hdr = vec![MAGIC[0], MAGIC[1], VERSION, FrameType::Heartbeat as u8];
        hdr.extend_from_slice(&over.to_le_bytes());
        assert_eq!(decode_frame(&hdr), Err(ProtoError::Oversized(over as usize)));
        // unknown version / frame-type bytes are typed errors
        let bad_ver = [MAGIC[0], MAGIC[1], VERSION + 1 + rng.below(200) as u8];
        assert!(matches!(decode_frame(&bad_ver), Err(ProtoError::BadVersion(_))));
        let bad_ty = [MAGIC[0], MAGIC[1], VERSION, 7 + rng.below(200) as u8];
        assert!(matches!(decode_frame(&bad_ty), Err(ProtoError::BadType(_))));
    });
}

#[test]
fn shard_payload_codecs_roundtrip_and_reject_mutations() {
    use oscillations_qat::deploy::serve::shard::proto::{Hello, WireRequest, WireResponse};
    for_random_cases(150, "shard_codec_roundtrip", |rng| {
        let req = WireRequest {
            id: rng.next_u32() as u64 | ((rng.next_u32() as u64) << 32),
            deadline_ms: rng.below(60_000) as u32,
            idempotent: rng.next_f32() < 0.5,
            input: (0..rng.below(80)).map(|_| rng.normal()).collect(),
        };
        let bytes = req.encode();
        assert_eq!(WireRequest::decode(&bytes).expect("request roundtrip"), req);
        let resp = WireResponse {
            id: req.id,
            pred: rng.below(10) as u32,
            batch: 1 + rng.below(16) as u32,
            latency_us: rng.next_u32() as u64,
            logits: (0..rng.below(16)).map(|_| rng.normal()).collect(),
        };
        let rb = resp.encode();
        assert_eq!(WireResponse::decode(&rb).expect("response roundtrip"), resp);
        let hello = Hello {
            model: (0..rng.below(12)).map(|_| char::from(97 + rng.below(26) as u8)).collect(),
            d_in: rng.below(4096) as u32,
            num_classes: 1 + rng.below(64) as u32,
            plane_bytes: rng.next_u32() as u64,
            pid: rng.next_u32(),
        };
        let hb = hello.encode();
        assert_eq!(Hello::decode(&hb).expect("hello roundtrip"), hello);
        // strict codecs: any truncation and any trailing byte is an
        // error, so a half-written payload can never decode as a shorter
        // valid message
        for (name, bytes) in [("request", &bytes), ("response", &rb), ("hello", &hb)] {
            if !bytes.is_empty() {
                let cut = rng.below(bytes.len());
                let truncated = &bytes[..cut];
                let ok = match name {
                    "request" => WireRequest::decode(truncated).is_ok(),
                    "response" => WireResponse::decode(truncated).is_ok(),
                    _ => Hello::decode(truncated).is_ok(),
                };
                assert!(!ok, "{name} accepted a truncated payload (cut {cut})");
            }
            let mut padded = bytes.to_vec();
            padded.push(rng.below(256) as u8);
            let ok = match name {
                "request" => WireRequest::decode(&padded).is_ok(),
                "response" => WireResponse::decode(&padded).is_ok(),
                _ => Hello::decode(&padded).is_ok(),
            };
            assert!(!ok, "{name} accepted trailing bytes");
        }
    });
}

#[test]
fn toy_oscillation_is_bounded_near_optimum() {
    // invariant: under every estimator the latent weight stays within one
    // grid step of the optimum once converged
    for_random_cases(40, "toy_bounded", |rng| {
        let est = match rng.below(5) {
            0 => ToyEstimator::Ste,
            1 => ToyEstimator::Ewgs { delta: rng.uniform(0.05, 0.5) },
            2 => ToyEstimator::Psg { eps: rng.uniform(0.001, 0.05) },
            3 => ToyEstimator::Dsq { k: rng.uniform(2.0, 8.0) },
            _ => ToyEstimator::Dampen { lambda: rng.uniform(0.1, 1.0) },
        };
        let w_star = rng.uniform(-0.3, 0.3);
        let cfg = ToyCfg { est, w_star, steps: 3000, lr: 0.01, ..Default::default() };
        let traj = run(&cfg);
        for &(w, _) in &traj[1500..] {
            assert!(
                (w - w_star).abs() <= cfg.s * 1.5,
                "{est:?} diverged: w={w} w*={w_star}"
            );
        }
    });
}

#[test]
fn toy_frequency_monotone_in_distance() {
    // appendix A.2 as a property over random base grids: the further the
    // optimum sits from its nearest grid point, the higher the measured
    // oscillation frequency.
    for_random_cases(25, "freq_monotone", |rng| {
        let level = rng.below(3) as f32 * 0.1;
        let mut freqs = vec![];
        for dist_frac in [0.1, 0.5, 0.9] {
            let d = 0.05 * dist_frac; // distance from the grid point `level + 0.1`
            let cfg = ToyCfg {
                w_star: level + 0.1 - d,
                steps: 5000,
                ..Default::default()
            };
            freqs.push(stats(&run(&cfg), 1500, cfg.s).freq);
        }
        assert!(
            freqs[2] >= freqs[0] - 0.02 && freqs[1] >= freqs[0] - 0.02,
            "freq should grow with distance: {freqs:?}"
        );
    });
}
