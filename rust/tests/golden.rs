//! Golden-parity tests: the native kernels vs fixtures generated from the
//! pure-jnp oracles in `python/compile/kernels/ref.py` (see
//! `gen_fixtures.py`). If these pass, the native backend computes exactly
//! what the reference (and therefore the Pallas kernels, which are tested
//! against the same oracles in python/tests) specifies.

use oscillations_qat::json::{self, Json};
use oscillations_qat::runtime::native::kernels::{self, OscState};
use std::path::{Path, PathBuf};

const TOL: f32 = 1e-5;

fn fixture(name: &str) -> Json {
    let path: PathBuf = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(format!("{name}.json"));
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e} — run gen_fixtures.py", path.display()));
    json::parse(&text).expect("fixture JSON")
}

fn vecf(case: &Json, key: &str) -> Vec<f32> {
    case.get(key)
        .as_arr()
        .unwrap_or_else(|| panic!("fixture field {key} missing"))
        .iter()
        .map(|v| v.as_f64().expect("number") as f32)
        .collect()
}

fn scalarf(case: &Json, key: &str) -> f32 {
    case.get(key).as_f64().unwrap_or_else(|| panic!("fixture scalar {key} missing")) as f32
}

fn assert_close(name: &str, case_idx: usize, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{name}[{case_idx}] length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= TOL,
            "{name}[{case_idx}][{i}]: native {g} vs ref {w}"
        );
    }
}

#[test]
fn fake_quant_matches_ref_fixtures() {
    let fx = fixture("fake_quant");
    let cases = fx.get("cases").as_arr().unwrap();
    assert!(!cases.is_empty());
    for (ci, case) in cases.iter().enumerate() {
        let w = vecf(case, "w");
        let got = kernels::fake_quant(
            &w,
            scalarf(case, "s"),
            scalarf(case, "n"),
            scalarf(case, "p"),
        );
        assert_close("fake_quant", ci, &got, &vecf(case, "out"));
    }
}

#[test]
fn fake_quant_pc_matches_ref_fixtures() {
    let fx = fixture("fake_quant_pc");
    let cases = fx.get("cases").as_arr().unwrap();
    assert!(!cases.is_empty());
    for (ci, case) in cases.iter().enumerate() {
        let w = vecf(case, "w");
        let scales = vecf(case, "scales");
        let group = scalarf(case, "group") as usize;
        let (n, p) = (scalarf(case, "n"), scalarf(case, "p"));
        let got = kernels::fake_quant_pc(&w, &scales, group, n, p);
        assert_close("fake_quant_pc", ci, &got, &vecf(case, "out"));
        let ints = kernels::int_weights_pc(&w, &scales, group, n, p);
        assert_close("int_weights_pc", ci, &ints, &vecf(case, "ints"));
    }
}

#[test]
fn act_requant_pc_matches_ref_fixtures() {
    // the per-channel activation requant path the interpreter and the
    // deploy engine share: codes = clip(round(a / s_c), 0, p) with
    // channel c = i % n_scales, then a_q = s_c * code
    let fx = fixture("act_requant_pc");
    let cases = fx.get("cases").as_arr().unwrap();
    assert!(!cases.is_empty());
    for (ci, case) in cases.iter().enumerate() {
        let a = vecf(case, "a");
        let scales = vecf(case, "scales");
        let p = scalarf(case, "p");
        let codes = kernels::int_weights_pc(&a, &scales, 1, 0.0, p);
        assert_close("act_requant_pc.codes", ci, &codes, &vecf(case, "codes"));
        let ns = scales.len();
        let a_q: Vec<f32> =
            codes.iter().enumerate().map(|(i, &c)| scales[i % ns] * c).collect();
        assert_close("act_requant_pc.out", ci, &a_q, &vecf(case, "out"));
        // the fused form is the same function
        let fq = kernels::fake_quant_pc(&a, &scales, 1, 0.0, p);
        assert_close("act_requant_pc.fused", ci, &fq, &vecf(case, "out"));
    }
}

#[test]
fn osc_update_matches_ref_fixtures() {
    let fx = fixture("osc_update");
    let cases = fx.get("cases").as_arr().unwrap();
    assert!(!cases.is_empty());
    for (ci, case) in cases.iter().enumerate() {
        let mut w = vecf(case, "w");
        let mut st = OscState {
            f: vecf(case, "f"),
            b: vecf(case, "b"),
            fint: vecf(case, "fint"),
            psign: vecf(case, "psign"),
            wintp: vecf(case, "wintp"),
            iema: vecf(case, "iema"),
        };
        let osc = kernels::osc_update(
            &mut w,
            scalarf(case, "s"),
            scalarf(case, "n"),
            scalarf(case, "p"),
            &mut st,
            scalarf(case, "m"),
            scalarf(case, "f_th"),
        );
        assert_close("osc.w_out", ci, &w, &vecf(case, "w_out"));
        assert_close("osc.f_out", ci, &st.f, &vecf(case, "f_out"));
        assert_close("osc.b_out", ci, &st.b, &vecf(case, "b_out"));
        assert_close("osc.fint_out", ci, &st.fint, &vecf(case, "fint_out"));
        assert_close("osc.psign_out", ci, &st.psign, &vecf(case, "psign_out"));
        assert_close("osc.wint_out", ci, &st.wintp, &vecf(case, "wint_out"));
        assert_close("osc.iema_out", ci, &st.iema, &vecf(case, "iema_out"));
        assert_close("osc.osc", ci, &osc, &vecf(case, "osc"));
    }
}

#[test]
fn dw_spatial_matches_ref_fixtures() {
    // fwd + bwd of the true 2-D spatial depthwise conv vs the jax
    // lax.conv oracle and its autodiff vjp (ref.dw_spatial_vjp_ref)
    let fx = fixture("dw_spatial");
    let cases = fx.get("cases").as_arr().unwrap();
    assert!(!cases.is_empty());
    for (ci, case) in cases.iter().enumerate() {
        let x = vecf(case, "x");
        let w = vecf(case, "w");
        let g = vecf(case, "g");
        let b = scalarf(case, "b") as usize;
        let hw_in = scalarf(case, "hw_in") as usize;
        let channels = scalarf(case, "channels") as usize;
        let stride = scalarf(case, "stride") as usize;
        let pad = scalarf(case, "pad") as usize;
        let hw_out = scalarf(case, "hw_out") as usize;
        assert_eq!(kernels::dw_spatial_out(hw_in, stride, pad), hw_out);

        let mut z = vec![0.0f32; b * hw_out * hw_out * channels];
        kernels::dw_spatial_fwd(&x, &w, b, hw_in, channels, stride, pad, &mut z);
        assert_close("dw_spatial.out", ci, &z, &vecf(case, "out"));

        let mut dw = vec![0.0f32; channels * 9];
        let mut dx = vec![0.0f32; x.len()];
        kernels::dw_spatial_bwd(&x, &w, &g, b, hw_in, channels, stride, pad, &mut dw, &mut dx);
        assert_close("dw_spatial.dw", ci, &dw, &vecf(case, "dw"));
        assert_close("dw_spatial.dx", ci, &dx, &vecf(case, "dx"));
    }
}

#[test]
fn quant_matmul_matches_ref_fixtures() {
    let fx = fixture("quant_matmul");
    let cases = fx.get("cases").as_arr().unwrap();
    assert!(!cases.is_empty());
    for (ci, case) in cases.iter().enumerate() {
        let x = vecf(case, "x");
        let w = vecf(case, "w");
        let xs = vecf(case, "x_shape");
        let ws = vecf(case, "w_shape");
        let (m, k, n) = (xs[0] as usize, xs[1] as usize, ws[1] as usize);
        let got = kernels::quant_matmul(
            &x,
            &w,
            m,
            k,
            n,
            scalarf(case, "s"),
            scalarf(case, "n"),
            scalarf(case, "p"),
        );
        assert_close("quant_matmul", ci, &got, &vecf(case, "out"));
    }
}
