//! End-to-end HTTP serving tests over a real TCP socket: keep-alive
//! request/response cycles with correct predictions, deadline 503s,
//! load shedding at ~2x queue capacity with fast bounded errors, and
//! the repeated-query response cache.

use anyhow::Result;
use oscillations_qat::deploy::format::{DeployLayer, DeployModel, DeployOp, Requant};
use oscillations_qat::deploy::packed::Packed;
use oscillations_qat::deploy::serve::http::{format_request, read_response};
use oscillations_qat::deploy::serve::{BatchForward, HttpCfg, HttpServer, ServeCfg};
use oscillations_qat::deploy::Engine;
use oscillations_qat::json;
use std::io::Write;
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

/// 12-feature single-layer model on a 3-bit grid: class `c` sums
/// feature block `c` (same construction as the serve unit tests, built
/// through the public format API here).
fn tiny_model() -> DeployModel {
    let mut codes = vec![4u32; 12 * 3]; // grid int 0
    for c in 0..3usize {
        for f in 0..4usize {
            codes[(c * 4 + f) * 3 + c] = 6; // grid int +2 -> weight 1.0
        }
    }
    DeployModel {
        name: "tiny".into(),
        input_hw: 2,
        num_classes: 3,
        quant_a: false,
        bits_w: 3,
        bits_a: 8,
        layers: vec![DeployLayer {
            name: "head".into(),
            op: DeployOp::Full,
            d_in: 12,
            d_out: 3,
            relu: false,
            aq: false,
            act_bits: 8,
            a_scales: vec![1.0],
            w_bits: 3,
            w_scales: vec![0.5],
            weights: Packed::pack(&codes, 3).unwrap(),
            bias: None,
            requant: Some(Requant { mult: vec![1.0; 3], add: vec![0.0; 3] }),
        }],
    }
}

fn one_hot_block(c: usize) -> Vec<f32> {
    let mut x = vec![0.0f32; 12];
    for f in 0..4 {
        x[c * 4 + f] = 1.0;
    }
    x
}

fn body_for(input: &[f32]) -> Vec<u8> {
    let mut s = String::from("{\"model\":\"tiny\",\"input\":[");
    for (i, v) in input.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("{v}"));
    }
    s.push_str("]}");
    s.into_bytes()
}

fn start_tiny(serve: &ServeCfg, http: &HttpCfg) -> HttpServer {
    let fwd: Arc<dyn BatchForward> = Arc::new(Engine::new(tiny_model()));
    HttpServer::start(fwd, serve, http).expect("http server start")
}

#[test]
fn keepalive_connection_serves_correct_predictions() {
    let srv = start_tiny(&ServeCfg::default(), &HttpCfg::default());
    let mut stream = TcpStream::connect(srv.addr()).unwrap();
    // several requests over ONE connection, each answered in order
    for round in 0..2 {
        for c in 0..3 {
            let req = format_request("/v1/predict", &body_for(&one_hot_block(c)), &[]);
            stream.write_all(&req).unwrap();
            let resp = read_response(&mut stream).unwrap();
            assert_eq!(resp.status, 200, "round {round} class {c}");
            assert_eq!(resp.header("connection"), Some("keep-alive"));
            let j = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
            assert_eq!(j.get("pred").as_usize(), Some(c), "round {round} class {c}");
            assert_eq!(j.get("logits").as_arr().unwrap().len(), 3);
        }
    }
    assert!(srv.stats().ok.load(std::sync::atomic::Ordering::Relaxed) >= 6);
    srv.stop();
}

#[test]
fn expired_deadline_returns_503_not_a_hang() {
    let srv = start_tiny(&ServeCfg::default(), &HttpCfg::default());
    let mut stream = TcpStream::connect(srv.addr()).unwrap();
    // an explicit zero budget is already expired: deterministic 503
    let req = format_request(
        "/v1/predict",
        &body_for(&one_hot_block(0)),
        &[("X-Deadline-Ms", "0")],
    );
    let t0 = Instant::now();
    stream.write_all(&req).unwrap();
    let resp = read_response(&mut stream).unwrap();
    assert_eq!(resp.status, 503);
    assert_eq!(resp.header("x-shed"), Some("deadline"));
    assert!(t0.elapsed() < Duration::from_secs(5), "shed must be fast");
    // the keep-alive connection survives and still serves
    stream
        .write_all(&format_request("/v1/predict", &body_for(&one_hot_block(2)), &[]))
        .unwrap();
    let resp = read_response(&mut stream).unwrap();
    assert_eq!(resp.status, 200);
    srv.stop();
}

/// A forward that takes a long, fixed time per batch — stands in for a
/// heavy model so overload and deadline behaviour is observable.
struct SlowForward {
    delay: Duration,
}

impl BatchForward for SlowForward {
    fn d_in(&self) -> usize {
        12
    }
    fn num_classes(&self) -> usize {
        3
    }
    fn model_name(&self) -> &str {
        "tiny"
    }
    fn forward_batch(&self, _x: &[f32], b: usize) -> Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        Ok((0..b * 3).map(|i| (i % 3) as f32).collect())
    }
}

#[test]
fn deadlined_request_behind_a_stalled_pool_gets_a_fast_503() {
    let fwd: Arc<dyn BatchForward> = Arc::new(SlowForward { delay: Duration::from_millis(400) });
    let serve = ServeCfg { workers: 1, max_batch: 1, queue_cap: 8 };
    let http = HttpCfg { cache_cap: 0, ..HttpCfg::default() };
    let srv = HttpServer::start(fwd, &serve, &http).unwrap();
    // request A occupies the single worker for 400ms
    let mut a = TcpStream::connect(srv.addr()).unwrap();
    a.write_all(&format_request("/v1/predict", &body_for(&one_hot_block(0)), &[]))
        .unwrap();
    std::thread::sleep(Duration::from_millis(100)); // A is in the worker now
    // request B has a 50ms budget: it expires while queued and must be
    // answered 503 long before the worker frees up
    let mut b = TcpStream::connect(srv.addr()).unwrap();
    let t0 = Instant::now();
    b.write_all(&format_request(
        "/v1/predict",
        &body_for(&one_hot_block(1)),
        &[("X-Deadline-Ms", "50")],
    ))
    .unwrap();
    let resp = read_response(&mut b).unwrap();
    let waited = t0.elapsed();
    assert_eq!(resp.status, 503, "queued past its deadline");
    assert_eq!(resp.header("x-shed"), Some("deadline"));
    assert!(
        waited < Duration::from_millis(280),
        "deadline 503 took {waited:?}, must not wait out the 400ms worker"
    );
    // A still completes normally
    let resp = read_response(&mut a).unwrap();
    assert_eq!(resp.status, 200);
    srv.stop();
}

#[test]
fn overload_at_twice_queue_capacity_sheds_fast() {
    let fwd: Arc<dyn BatchForward> = Arc::new(SlowForward { delay: Duration::from_millis(60) });
    // single slow worker, tiny queue: total in-flight capacity is
    // queue(2) + batcher(1) + dispatch(2) + worker(1) = 6
    let serve = ServeCfg { workers: 1, max_batch: 1, queue_cap: 2 };
    let http = HttpCfg { cache_cap: 0, ..HttpCfg::default() };
    let srv = HttpServer::start(fwd, &serve, &http).unwrap();
    let addr = srv.addr();
    let clients = 12; // ~2x capacity
    let barrier = Barrier::new(clients);
    let results: Vec<(u16, Option<String>, Duration)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let barrier = &barrier;
                s.spawn(move || {
                    let mut stream = TcpStream::connect(addr).unwrap();
                    let _ = stream.set_nodelay(true);
                    let req =
                        format_request("/v1/predict", &body_for(&one_hot_block(c % 3)), &[]);
                    barrier.wait(); // all clients fire at once
                    let t0 = Instant::now();
                    stream.write_all(&req).unwrap();
                    let resp = read_response(&mut stream).unwrap();
                    (resp.status, resp.header("x-shed").map(String::from), t0.elapsed())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    srv.stop();
    let ok = results.iter().filter(|(s, ..)| *s == 200).count();
    let shed: Vec<_> = results.iter().filter(|(s, ..)| *s == 503).collect();
    assert_eq!(ok + shed.len(), clients, "only 200s and 503s: {results:?}");
    assert!(ok >= 1, "the pool must still serve under overload: {results:?}");
    assert!(
        !shed.is_empty(),
        "2x queue capacity must shed at least one request: {results:?}"
    );
    for (_, hdr, _) in &shed {
        assert_eq!(hdr.as_deref(), Some("queue"), "{results:?}");
    }
    // shed answers are fast errors — far under the ~360ms it would take
    // the single 60ms worker to drain the whole fleet
    for (status, _, lat) in &results {
        if *status == 503 {
            assert!(*lat < Duration::from_millis(200), "slow shed: {lat:?}");
        }
    }
}

#[test]
fn repeated_query_is_served_from_the_cache() {
    let srv = start_tiny(&ServeCfg::default(), &HttpCfg::default());
    let mut stream = TcpStream::connect(srv.addr()).unwrap();
    let req = format_request("/v1/predict", &body_for(&one_hot_block(1)), &[]);
    stream.write_all(&req).unwrap();
    let first = read_response(&mut stream).unwrap();
    assert_eq!(first.status, 200);
    assert_eq!(first.header("x-cache"), Some("miss"));
    // byte-identical query: answered from the cache, same prediction
    stream.write_all(&req).unwrap();
    let second = read_response(&mut stream).unwrap();
    assert_eq!(second.status, 200);
    assert_eq!(second.header("x-cache"), Some("hit"));
    let j1 = json::parse(std::str::from_utf8(&first.body).unwrap()).unwrap();
    let j2 = json::parse(std::str::from_utf8(&second.body).unwrap()).unwrap();
    assert_eq!(j1.get("pred").as_usize(), j2.get("pred").as_usize());
    assert_eq!(j2.get("cached"), &json::Json::Bool(true));
    assert_eq!(srv.stats().cache_hits.load(std::sync::atomic::Ordering::Relaxed), 1);
    srv.stop();
}

#[test]
fn health_stats_and_malformed_requests() {
    let srv = start_tiny(&ServeCfg::default(), &HttpCfg::default());
    let mut stream = TcpStream::connect(srv.addr()).unwrap();
    stream.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
    let h = read_response(&mut stream).unwrap();
    assert_eq!(h.status, 200);
    let j = json::parse(std::str::from_utf8(&h.body).unwrap()).unwrap();
    assert_eq!(j.get("model").as_str(), Some("tiny"));
    // malformed JSON body -> 400, connection still usable afterwards
    stream
        .write_all(&format_request("/v1/predict", b"{\"input\": [1, 2", &[]))
        .unwrap();
    assert_eq!(read_response(&mut stream).unwrap().status, 400);
    // wrong input width -> 400
    stream
        .write_all(&format_request("/v1/predict", &body_for(&[1.0, 2.0]), &[]))
        .unwrap();
    assert_eq!(read_response(&mut stream).unwrap().status, 400);
    // stats endpoint reflects the traffic
    stream.write_all(b"GET /stats HTTP/1.1\r\n\r\n").unwrap();
    let st = read_response(&mut stream).unwrap();
    assert_eq!(st.status, 200);
    let j = json::parse(std::str::from_utf8(&st.body).unwrap()).unwrap();
    assert!(j.get("bad").as_usize().unwrap_or(0) >= 2, "{j:?}");
    srv.stop();
}

/// The stable machine-readable code from a unified error body
/// `{"error":{"code","message","model"}}`.
fn error_code(resp: &oscillations_qat::deploy::serve::http::ClientResponse) -> String {
    let j = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    j.get("error").get("code").as_str().unwrap_or("").to_string()
}

#[test]
fn error_responses_carry_stable_codes_end_to_end() {
    let srv = start_tiny(&ServeCfg::default(), &HttpCfg::default());
    let mut stream = TcpStream::connect(srv.addr()).unwrap();
    // wrong input width -> bad_input_width, and the body names the model
    stream
        .write_all(&format_request("/v1/predict", &body_for(&[1.0, 2.0]), &[]))
        .unwrap();
    let resp = read_response(&mut stream).unwrap();
    assert_eq!(resp.status, 400);
    assert_eq!(error_code(&resp), "bad_input_width");
    let j = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    assert_eq!(j.get("error").get("model").as_str(), Some("tiny"));
    // unknown model id -> model_not_found on both routing surfaces
    stream
        .write_all(&format_request(
            "/v1/predict",
            b"{\"model\":\"nope\",\"input\":[1]}",
            &[],
        ))
        .unwrap();
    let resp = read_response(&mut stream).unwrap();
    assert_eq!(resp.status, 404);
    assert_eq!(error_code(&resp), "model_not_found");
    // the resource route carries the model in the path alone (a body
    // model field contradicting the path would be a 400 instead)
    stream
        .write_all(&format_request(
            "/v1/models/nope/predict",
            b"{\"input\":[1,2,3]}",
            &[],
        ))
        .unwrap();
    let resp = read_response(&mut stream).unwrap();
    assert_eq!(resp.status, 404);
    assert_eq!(error_code(&resp), "model_not_found");
    // an already-expired deadline -> deadline_exceeded with the shed header
    stream
        .write_all(&format_request(
            "/v1/predict",
            &body_for(&one_hot_block(0)),
            &[("X-Deadline-Ms", "0")],
        ))
        .unwrap();
    let resp = read_response(&mut stream).unwrap();
    assert_eq!(resp.status, 503);
    assert_eq!(resp.header("x-shed"), Some("deadline"));
    assert_eq!(error_code(&resp), "deadline_exceeded");
    // unknown path -> route_not_found
    stream
        .write_all(&format_request("/v1/nope", &body_for(&one_hot_block(0)), &[]))
        .unwrap();
    let resp = read_response(&mut stream).unwrap();
    assert_eq!(resp.status, 404);
    assert_eq!(error_code(&resp), "route_not_found");
    srv.stop();
}

#[test]
fn legacy_predict_alias_answers_deprecation_and_resource_route_does_not() {
    let srv = start_tiny(&ServeCfg::default(), &HttpCfg::default());
    let mut stream = TcpStream::connect(srv.addr()).unwrap();
    stream
        .write_all(&format_request("/v1/predict", &body_for(&one_hot_block(1)), &[]))
        .unwrap();
    let resp = read_response(&mut stream).unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("deprecation"), Some("true"));
    stream
        .write_all(&format_request(
            "/v1/models/tiny/predict",
            &body_for(&one_hot_block(1)),
            &[],
        ))
        .unwrap();
    let resp = read_response(&mut stream).unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("deprecation"), None);
    srv.stop();
}

#[test]
fn metrics_endpoint_exposes_prometheus_text() {
    let srv = start_tiny(&ServeCfg::default(), &HttpCfg::default());
    let mut stream = TcpStream::connect(srv.addr()).unwrap();
    // one real predict so latency + every stage histogram has a sample
    stream
        .write_all(&format_request("/v1/predict", &body_for(&one_hot_block(0)), &[]))
        .unwrap();
    assert_eq!(read_response(&mut stream).unwrap().status, 200);
    stream.write_all(b"GET /metrics HTTP/1.1\r\n\r\n").unwrap();
    let resp = read_response(&mut stream).unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("content-type"), Some("text/plain; version=0.0.4"));
    let text = std::str::from_utf8(&resp.body).unwrap();
    for needle in [
        "# TYPE qat_http_requests_total counter",
        "qat_http_cache_misses_total 1",
        "qat_pool_requests_total 1",
        "qat_pool_batches_total 1",
        "# TYPE qat_http_open_connections gauge",
        "qat_http_open_connections 1",
        "# TYPE qat_request_latency_seconds histogram",
        "qat_request_latency_seconds_count 1",
        "qat_request_latency_seconds_bucket{le=\"+Inf\"} 1",
        "qat_stage_queue_seconds_count 1",
        "qat_stage_compute_seconds_count 1",
        "qat_stage_parse_seconds_count",
        "qat_stage_write_seconds_count",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    // bucket rows are cumulative and the +Inf row closes at the count
    let mut last = 0u64;
    let mut rows = 0;
    let bucket_rows =
        text.lines().filter(|l| l.starts_with("qat_request_latency_seconds_bucket"));
    for line in bucket_rows {
        let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
        assert!(v >= last, "non-cumulative bucket row: {line}");
        last = v;
        rows += 1;
    }
    assert!(rows > 10, "expected the full edge table, got {rows} rows");
    assert_eq!(last, 1, "+Inf row must equal the sample count");
    srv.stop();
}
