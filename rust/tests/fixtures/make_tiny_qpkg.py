#!/usr/bin/env python3
"""Regenerate the committed QPKG compatibility fixtures.

Writes the byte-exact historic serializations of the "tiny" two-layer
model the `qpkg_compat.rs` suite pins down:

* ``tiny_v1.qpkg`` — single f32 w_scale + single f32 a_scale per layer
* ``tiny_v2.qpkg`` — counted w_scales array + single f32 a_scale
* ``tiny_v3.qpkg`` — counted w_scales *and* a_scales arrays (the v4
  layout minus the spatial-depthwise op tag / metadata block)

The layouts mirror ``rust/src/deploy/format.rs`` (all little-endian,
LSB-first bit-packed weight codes). The script refuses to overwrite a
committed fixture whose bytes differ from what it would regenerate, so
the v1/v2 fixtures double as a check that this writer replicates the
Rust serializer exactly.
"""

import struct
import sys
from pathlib import Path

HERE = Path(__file__).resolve().parent


def pack_codes(codes, bits):
    """LSB-first bitstream, `ceil(len * bits / 8)` bytes (Packed::pack)."""
    out = bytearray((len(codes) * bits + 7) // 8)
    for i, c in enumerate(codes):
        assert 0 <= c < (1 << bits), (c, bits)
        bit = i * bits
        byte, shift = divmod(bit, 8)
        out[byte] |= (c << shift) & 0xFF
        if shift + bits > 8:
            out[byte + 1] |= c >> (8 - shift)
    return bytes(out)


def u16(v):
    return struct.pack("<H", v)


def u32(v):
    return struct.pack("<I", v)


def f32s(vs):
    return b"".join(struct.pack("<f", v) for v in vs)


def name(s):
    b = s.encode()
    return u16(len(b)) + b


# the "tiny" model: stem [12, 3] dense -> head depthwise 3-tap, 3 wide
STEM = dict(
    name="stem", op=0, relu=1, aq=0, d_in=12, d_out=3, w_bits=3, act_bits=8,
    w_scales=[0.1, 0.07, 0.2], a_scales=[1.0],
    bias=None, requant=([1.0, 0.5, 2.0], [0.0, -0.1, 0.2]),
    codes=[i % 8 for i in range(36)],
)
HEAD = dict(
    name="head", op=1, relu=0, aq=1, d_in=3, d_out=3, w_bits=4, act_bits=3,
    w_scales=[0.2, 0.15, 0.3], a_scales=[0.05, 0.04, 0.06],
    bias=[0.1, 0.2, 0.3], requant=None,
    codes=list(range(1, 10)),
)


def layer_bytes(l, version):
    buf = bytearray()
    buf += name(l["name"])
    buf += bytes([l["op"], l["relu"], l["aq"],
                  l["bias"] is not None, l["requant"] is not None])
    buf += u32(l["d_in"]) + u32(l["d_out"]) + u32(l["w_bits"]) + u32(l["act_bits"])
    if version >= 2:
        buf += u32(len(l["w_scales"])) + f32s(l["w_scales"])
    else:
        buf += f32s(l["w_scales"][:1])
    if version >= 3:
        buf += u32(len(l["a_scales"])) + f32s(l["a_scales"])
    else:
        buf += f32s(l["a_scales"][:1])
    if l["bias"] is not None:
        buf += f32s(l["bias"])
    if l["requant"] is not None:
        mult, add = l["requant"]
        buf += f32s(mult) + f32s(add)
    packed = pack_codes(l["codes"], l["w_bits"])
    buf += u32(len(l["codes"])) + u32(len(packed)) + packed
    return bytes(buf)


def tiny_bytes(version):
    # v1 layers carry only per-tensor scales; drop the per-channel
    # payloads so the upgraded struct matches what v1 could express
    layers = [STEM, HEAD]
    if version == 1:
        layers = [{**l, "w_scales": ([0.1] if l is STEM else [0.2]),
                   "a_scales": l["a_scales"][:1]} for l in layers]
    elif version == 2:
        layers = [{**l, "a_scales": l["a_scales"][:1]} for l in layers]
    buf = bytearray()
    buf += b"QPKG" + u32(version)
    buf += name("tiny")
    buf += u32(2) + u32(3)        # input_hw, num_classes
    buf += bytes([1])             # quant_a
    buf += u32(3) + u32(3)        # bits_w, bits_a
    buf += u32(len(layers))
    for l in layers:
        buf += layer_bytes(l, version)
    return bytes(buf)


def main():
    changed = False
    for version in (1, 2, 3):
        path = HERE / f"tiny_v{version}.qpkg"
        data = tiny_bytes(version)
        if path.exists():
            have = path.read_bytes()
            if have == data:
                print(f"{path.name}: up to date ({len(data)} bytes)")
                continue
            sys.exit(
                f"{path.name}: committed fixture differs from regeneration "
                f"({len(have)} vs {len(data)} bytes) — refusing to overwrite"
            )
        path.write_bytes(data)
        print(f"{path.name}: wrote {len(data)} bytes")
        changed = True
    if not changed:
        print("all fixtures verified byte-identical")


if __name__ == "__main__":
    main()
