//! Chaos tests against the real binary: a 2-model fleet with `--shards
//! 2` per model keeps serving while one model's shard child is
//! `kill -9`'d, the supervisor restarts it within its recovery budget,
//! and SIGTERM drains the whole tree to a clean exit 0. Linux-only:
//! the tests walk `/proc` to find shard children and send raw signals.
#![cfg(target_os = "linux")]

use oscillations_qat::deploy::format::{DeployLayer, DeployModel, DeployOp, Requant};
use oscillations_qat::deploy::packed::Packed;
use oscillations_qat::deploy::serve::http::{format_request, read_response};
use oscillations_qat::json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

extern "C" {
    fn kill(pid: i32, sig: i32) -> i32;
}

const SIGKILL: i32 = 9;
const SIGTERM: i32 = 15;

/// 12-feature single-layer model where feature block `c` drives class
/// `(c + rot) % 3` — same shape the fleet tests use.
fn rot_model(name: &str, rot: usize) -> DeployModel {
    let mut codes = vec![4u32; 12 * 3]; // grid int 0
    for c in 0..3usize {
        for f in 0..4usize {
            codes[(c * 4 + f) * 3 + (c + rot) % 3] = 6; // grid int +2 -> weight 1.0
        }
    }
    DeployModel {
        name: name.into(),
        input_hw: 2,
        num_classes: 3,
        quant_a: false,
        bits_w: 3,
        bits_a: 8,
        layers: vec![DeployLayer {
            name: "head".into(),
            op: DeployOp::Full,
            d_in: 12,
            d_out: 3,
            relu: false,
            aq: false,
            act_bits: 8,
            a_scales: vec![1.0],
            w_bits: 3,
            w_scales: vec![0.5],
            weights: Packed::pack(&codes, 3).unwrap(),
            bias: None,
            requant: Some(Requant { mult: vec![1.0; 3], add: vec![0.0; 3] }),
        }],
    }
}

fn one_hot_block(c: usize) -> Vec<f32> {
    let mut x = vec![0.0f32; 12];
    for f in 0..4 {
        x[c * 4 + f] = 1.0;
    }
    x
}

fn input_body(input: &[f32]) -> Vec<u8> {
    let mut s = String::from("{\"input\":[");
    for (i, v) in input.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("{v}"));
    }
    s.push_str("]}");
    s.into_bytes()
}

/// Kills the serve process tree on drop so a failing assertion never
/// leaks a listener (SIGTERM first for the drain path, SIGKILL after).
struct ServeGuard {
    child: Option<Child>,
}

impl ServeGuard {
    fn pid(&self) -> i32 {
        self.child.as_ref().unwrap().id() as i32
    }

    /// SIGTERM, then wait for a clean exit (the graceful-drain path).
    fn terminate(mut self, timeout: Duration) -> std::process::ExitStatus {
        let mut child = self.child.take().unwrap();
        unsafe { kill(child.id() as i32, SIGTERM) };
        let t0 = Instant::now();
        loop {
            if let Some(status) = child.try_wait().unwrap() {
                return status;
            }
            assert!(t0.elapsed() < timeout, "serve did not exit within {timeout:?} of SIGTERM");
            std::thread::sleep(Duration::from_millis(50));
        }
    }
}

impl Drop for ServeGuard {
    fn drop(&mut self) {
        if let Some(mut child) = self.child.take() {
            if child.try_wait().ok().flatten().is_none() {
                unsafe { kill(child.id() as i32, SIGTERM) };
                for _ in 0..100 {
                    if child.try_wait().ok().flatten().is_some() {
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
                let _ = child.kill();
                let _ = child.wait();
            }
        }
    }
}

/// Spawn `serve --listen 127.0.0.1:0 ...` and parse the bound address
/// out of the startup banner.
fn spawn_serve(extra: &[&str]) -> (ServeGuard, String) {
    // unique per call: the two tests here run concurrently in one process
    static SEQ: std::sync::atomic::AtomicU32 = std::sync::atomic::AtomicU32::new(0);
    let seq = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!("qat_shard_chaos_{}_{seq}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let pa: PathBuf = dir.join("a.qpkg");
    let pb: PathBuf = dir.join("b.qpkg");
    rot_model("rot0", 0).write_qpkg(&pa).unwrap();
    rot_model("rot1", 1).write_qpkg(&pb).unwrap();
    let spec_a = format!("a={}", pa.display());
    let spec_b = format!("b={}", pb.display());
    let mut child = Command::new(env!("CARGO_BIN_EXE_oscillations-qat"))
        .args([
            "serve",
            "--model",
            spec_a.as_str(),
            "--model",
            spec_b.as_str(),
            "--listen",
            "127.0.0.1:0",
            "--workers",
            "1",
        ])
        .args(extra)
        .env_remove("QAT_FAULT_INJECT")
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn serve");
    let stdout = child.stdout.take().unwrap();
    let guard = ServeGuard { child: Some(child) };
    let mut lines = BufReader::new(stdout).lines();
    let addr = loop {
        let line = lines
            .next()
            .expect("serve exited before printing its banner")
            .expect("read banner line");
        if let Some(rest) = line.split("listening on http://").nth(1) {
            break rest.split_whitespace().next().unwrap().to_string();
        }
    };
    (guard, addr)
}

fn get(addr: &str, path: &str) -> oscillations_qat::deploy::serve::http::ClientResponse {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(format!("GET {path} HTTP/1.1\r\n\r\n").as_bytes()).unwrap();
    read_response(&mut s).unwrap()
}

fn metrics_text(addr: &str) -> String {
    let resp = get(addr, "/metrics");
    assert_eq!(resp.status, 200);
    String::from_utf8_lossy(&resp.body).into_owned()
}

/// Wait until `/metrics` reports `qat_shard_up{model="<id>"} <want>`.
fn wait_shards_up(addr: &str, id: &str, want: usize, timeout: Duration) {
    let needle = format!("qat_shard_up{{model=\"{id}\"}} {want}");
    let t0 = Instant::now();
    let mut last = String::new();
    while t0.elapsed() < timeout {
        last = metrics_text(addr);
        if last.contains(&needle) {
            return;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    panic!("shards of {id} never reached {want} up within {timeout:?}; last scrape:\n{last}");
}

/// PIDs of live `shard-worker` children of `parent` serving `model`,
/// found by walking /proc (cmdline + ppid).
fn shard_pids(parent: i32, model: &str) -> Vec<i32> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir("/proc") else {
        return out;
    };
    for e in entries.flatten() {
        let Some(pid) = e.file_name().to_str().and_then(|s| s.parse::<i32>().ok()) else {
            continue;
        };
        let Ok(raw) = std::fs::read(format!("/proc/{pid}/cmdline")) else {
            continue;
        };
        let argv: Vec<&str> =
            raw.split(|&b| b == 0).filter_map(|s| std::str::from_utf8(s).ok()).collect();
        if !argv.iter().any(|a| *a == "shard-worker") {
            continue;
        }
        if argv.windows(2).find(|w| w[0] == "--model-id").map(|w| w[1]) != Some(model) {
            continue;
        }
        // ppid is the second stat field after the parenthesized comm
        let Ok(stat) = std::fs::read_to_string(format!("/proc/{pid}/stat")) else {
            continue;
        };
        let ppid: i32 = stat
            .rsplit(')')
            .next()
            .and_then(|rest| rest.split_whitespace().nth(1))
            .and_then(|s| s.parse().ok())
            .unwrap_or(-1);
        if ppid == parent {
            out.push(pid);
        }
    }
    out
}

fn predict(stream: &mut TcpStream, model: &str, c: usize) -> (u16, String) {
    let req = format_request(
        &format!("/v1/models/{model}/predict"),
        &input_body(&one_hot_block(c)),
        &[],
    );
    stream.write_all(&req).unwrap();
    let resp = read_response(stream).unwrap();
    let code = json::parse(std::str::from_utf8(&resp.body).unwrap_or("{}"))
        .ok()
        .and_then(|j| j.get("error").get("code").as_str().map(String::from))
        .unwrap_or_default();
    (resp.status, code)
}

#[test]
fn kill_9_of_one_shard_is_invisible_to_the_healthy_model_and_recovers() {
    let (guard, addr) = spawn_serve(&["--shards", "2", "--drain-ms", "10000"]);
    // both models fully up (2 shard children each) before the chaos
    wait_shards_up(&addr, "a", 2, Duration::from_secs(60));
    wait_shards_up(&addr, "b", 2, Duration::from_secs(60));
    let victims = shard_pids(guard.pid(), "a");
    assert_eq!(victims.len(), 2, "expected 2 shard children for model a, got {victims:?}");
    unsafe { kill(victims[0], SIGKILL) };

    // ~3s of traffic against both models while the supervisor recovers.
    // The wounded model may shed retryable 503s mid-restart; the healthy
    // model must not miss a single answer, and nothing may 500 or hang.
    let mut conn_a = TcpStream::connect(&addr).unwrap();
    let mut conn_b = TcpStream::connect(&addr).unwrap();
    let t0 = Instant::now();
    let (mut n_a, mut ok_a) = (0u32, 0u32);
    while t0.elapsed() < Duration::from_secs(3) {
        let c = (n_a as usize) % 3;
        let (status, code) = predict(&mut conn_a, "a", c);
        n_a += 1;
        match status {
            200 => ok_a += 1,
            503 => assert!(
                code == "shard_restarting" || code == "queue_full" || code == "deadline_exceeded",
                "model a shed with unexpected code {code:?}"
            ),
            other => panic!("model a answered {other} ({code}) during recovery"),
        }
        let (status, code) = predict(&mut conn_b, "b", c);
        assert_eq!((status, code.as_str()), (200, ""), "healthy model b was disturbed");
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(ok_a > 0, "the surviving sibling shard answered nothing ({n_a} sent)");

    // the supervisor respawned the killed child within the budget
    wait_shards_up(&addr, "a", 2, Duration::from_secs(20));
    let text = metrics_text(&addr);
    assert!(text.contains("qat_shard_restarts_total{model=\"a\"} "), "{text}");
    let healthy = shard_pids(guard.pid(), "a");
    assert_eq!(healthy.len(), 2, "model a must be back to 2 children: {healthy:?}");
    assert!(!healthy.contains(&victims[0]), "killed pid cannot still be serving");

    // ingress stayed up throughout
    assert_eq!(get(&addr, "/healthz").status, 200);

    // SIGTERM drains the whole tree: exit 0 and no orphaned children.
    // Pids are snapshotted first — once the supervisor dies an orphan
    // would reparent to init and escape the ppid filter.
    let pid = guard.pid();
    let mut children = shard_pids(pid, "a");
    children.extend(shard_pids(pid, "b"));
    assert_eq!(children.len(), 4, "expected 4 shard children before drain: {children:?}");
    let status = guard.terminate(Duration::from_secs(30));
    assert_eq!(status.code(), Some(0), "graceful drain must exit 0");
    let still_shard = |pid: i32| {
        std::fs::read(format!("/proc/{pid}/cmdline"))
            .map(|raw| raw.split(|&b| b == 0).any(|a| a == &b"shard-worker"[..]))
            .unwrap_or(false)
    };
    let t0 = Instant::now();
    while children.iter().any(|&c| still_shard(c)) {
        assert!(t0.elapsed() < Duration::from_secs(10), "shard children were orphaned");
        std::thread::sleep(Duration::from_millis(100));
    }
}

#[test]
fn sigterm_with_in_process_pools_drains_and_exits_zero() {
    // --shards 0 (default): the unchanged in-process path must also own
    // the graceful SIGTERM drain
    let (guard, addr) = spawn_serve(&["--drain-ms", "5000"]);
    let mut stream = TcpStream::connect(&addr).unwrap();
    let (status, code) = predict(&mut stream, "a", 1);
    assert_eq!((status, code.as_str()), (200, ""));
    let status = guard.terminate(Duration::from_secs(30));
    assert_eq!(status.code(), Some(0), "graceful drain must exit 0");
    // the listener is gone after the drain
    assert!(TcpStream::connect(&addr).is_err(), "listener must close on SIGTERM");
}
