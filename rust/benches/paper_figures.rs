//! Regenerate every paper FIGURE (1-6).
//!
//! Fig 1/5/6 are the closed-form toy substrate (fast, exact). Fig 2 traces
//! integer weights through a real QAT run; Figs 3/4 histogram the latent
//! weights of baseline / dampened / frozen runs. Reduced scale by default;
//! see paper_tables.rs for the env knobs.

use oscillations_qat::coordinator::experiment::Lab;
use oscillations_qat::runtime::auto_backend;
use std::path::Path;

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let be = auto_backend(Path::new("artifacts"))?;
    let mut lab = Lab::new(be.as_ref());
    lab.qat_steps = env_u64("QAT_BENCH_STEPS", 80);
    lab.fp_steps = env_u64("QAT_BENCH_FP_STEPS", 120);
    lab.bn_batches = 8;
    lab.seeds = vec![0];
    lab.ckpt_dir = Path::new("ckpts/bench").to_path_buf();
    lab.results_dir = Path::new("results/bench").to_path_buf();

    macro_rules! figure {
        ($name:literal, $method:ident) => {{
            let t0 = std::time::Instant::now();
            lab.$method()?;
            eprintln!("[bench] {} regenerated in {:.1?}\n", $name, t0.elapsed());
        }};
    }
    figure!("fig1", fig1);
    figure!("fig5", fig5);
    figure!("fig6", fig6);
    figure!("fig2", fig2);
    figure!("fig34", fig34);
    Ok(())
}
