//! Performance micro-benchmarks (the §Perf instrumentation):
//!
//!   * end-to-end train-step latency / sample throughput per model,
//!   * hot-path kernels (and their reference twins) through the backend,
//!   * eval-step latency,
//!   * data-pipeline generation rate,
//!   * host substrates (fake-quant mirror, JSON manifest parse).
//!
//! Run: `cargo bench --bench perf`. Uses the PJRT artifacts when present,
//! the native backend otherwise.

use oscillations_qat::bench::{bench, bench_for};
use oscillations_qat::coordinator::evaluator::{EvalQuant, Evaluator};
use oscillations_qat::coordinator::{RunCfg, Trainer};
use oscillations_qat::data::{DataCfg, Dataset};
use oscillations_qat::quant;
use oscillations_qat::runtime::auto_backend;
use std::path::Path;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let be = auto_backend(Path::new("artifacts"))?;
    let be = be.as_ref();
    println!("# oscillations-qat perf benchmarks (backend: {})\n", be.kind());

    // -------- host substrates (no backend) --------
    let ds = Dataset::new(DataCfg::default());
    let mut i = 0u64;
    let s = bench("data: synth batch 16x16x16x3", 3, 200, || {
        let b = ds.train_batch(0, i);
        std::hint::black_box(&b.x.data[0]);
        i += 1;
    });
    println!("{}  ({:.0} img/s)", s.report(), s.per_sec(16.0));

    let w: Vec<f32> = (0..262_144).map(|i| ((i % 97) as f32 - 48.0) * 0.01).collect();
    let s = bench("host: fake_quant mirror 256k f32", 3, 50, || {
        std::hint::black_box(quant::fake_quant(&w, 0.05, -4.0, 3.0));
    });
    println!("{}  ({:.2} Gelem/s)", s.report(), s.per_sec(262_144.0) / 1e9);

    // PJRT-only substrate: manifest JSON parse (needs an artifact dir)
    if let Ok(manifest_text) = std::fs::read_to_string("artifacts/mbv2_lsq_train.manifest.json") {
        let s = bench("host: manifest JSON parse (1.2k tensors)", 3, 50, || {
            std::hint::black_box(oscillations_qat::json::parse(&manifest_text).unwrap());
        });
        println!("{}", s.report());
    }

    // -------- hot-path kernels vs refs through the backend --------
    println!();
    for (label, key) in [
        ("kernel: fake_quant", "kernel_fakequant"),
        ("kernel: fake_quant (ref)", "kernel_fakequant_ref"),
        ("kernel: osc_update", "kernel_osc"),
        ("kernel: osc_update (ref)", "kernel_osc_ref"),
        ("kernel: quant_matmul", "kernel_qmm"),
        ("kernel: quant_matmul (ref)", "kernel_qmm_ref"),
    ] {
        let Some(name) = be.index().kernels.get(key).cloned() else { continue };
        let sig = be.signature(&name)?;
        let io = oscillations_qat::bench::kernel_bench_inputs(&sig);
        let s = bench_for(label, 2, Duration::from_secs(2), || {
            let _ = be.execute(&name, &[&io]).expect("exec");
        });
        println!("{}", s.report());
    }

    // -------- end-to-end step latency per model --------
    println!();
    let trainer = Trainer::new(be);
    for model in ["mbv2", "resnet18", "mbv3", "efflite"] {
        let batch = be.index().model(model)?.batch_size as f64;
        let mut cfg = RunCfg::qat(model, 1, 3, 0);
        cfg.quant_a = true;
        let mut cur = Some(be.initial_state(model)?);
        let s = bench_for(
            &format!("step: {model} w3a3 train (batch {batch})"),
            1,
            Duration::from_secs(8),
            || {
                let out = trainer.train(cur.take().unwrap(), &cfg).expect("step");
                cur = Some(out.state);
            },
        );
        println!("{}  ({:.1} samples/s)", s.report(), s.per_sec(batch));
    }

    // -------- eval step --------
    println!();
    let ev = Evaluator::new(be, "mbv2")?;
    let state = be.initial_state("mbv2")?;
    let data = DataCfg { val_size: 16, ..Default::default() };
    let s = bench_for("eval: mbv2 one batch", 1, Duration::from_secs(4), || {
        let _ = ev.eval_val(&state, &data, EvalQuant::weights(3)).expect("eval");
    });
    println!("{}", s.report());

    // -------- deployed inference: packed engine vs simulated eval --------
    println!();
    if let Some(nm) = oscillations_qat::runtime::native::model::zoo_model("mbv2") {
        use oscillations_qat::deploy::export::{export_model, ExportCfg};
        use oscillations_qat::deploy::{Engine, EngineOpts};
        use oscillations_qat::tensor::Tensor;
        // quant_a on so the i32-accumulation path actually runs
        let ecfg = ExportCfg { bits_w: 3, bits_a: 3, quant_a: true };
        let (dm, report) = export_model(&nm, &state, &ecfg)?;
        println!(
            "deploy: mbv2 packed {} B vs f32 {} B (ratio {:.3})",
            report.packed_bytes,
            report.f32_bytes,
            report.ratio()
        );
        let small = Dataset::new(DataCfg { val_size: 16, ..Default::default() });
        let batch = small.val_batches().remove(0);
        let b = batch.x.shape[0];
        // prepared (decode-once planes) vs streaming (re-decode per call)
        // in both accumulation modes, plus scoped-thread batch splitting
        let one = EngineOpts::default();
        let streaming = EngineOpts { prepared: false, ..Default::default() };
        let mt = EngineOpts { threads: 2, ..Default::default() };
        for (label, int_accum, opts) in [
            ("deploy: engine f32-exact streaming, batch 16", false, streaming),
            ("deploy: engine f32-exact prepared, batch 16", false, one),
            ("deploy: engine i32-accum streaming, batch 16", true, streaming),
            ("deploy: engine i32-accum prepared, batch 16", true, one),
            ("deploy: engine i32-accum prepared t2, batch 16", true, mt),
        ] {
            let eng = Engine::with_opts(dm.clone(), int_accum, opts);
            let s = bench_for(label, 1, Duration::from_secs(3), || {
                let _ = eng.forward_batch(&batch.x.data, b).expect("deploy fwd");
            });
            println!("{}  ({:.0} img/s)", s.report(), s.per_sec(b as f64));
        }
        // per-channel export of the same state: the engine pays one scale
        // lookup per plane decode at prepare time; this row tracks the
        // steady-state (decode-once) per-channel cost
        let mut pc_state = state.clone();
        for l in &nm.layers {
            let sc: Vec<f32> = (0..l.d_out).map(|c| 0.02 + 1e-4 * c as f32).collect();
            pc_state.insert(format!("params/{}.s", l.name), Tensor::new(vec![l.d_out], sc));
        }
        let (dm_pc, _) = export_model(&nm, &pc_state, &ecfg)?;
        let eng = Engine::new(dm_pc);
        println!(
            "deploy: mbv2 pc prepared planes {} B cached on top of {} B packed",
            eng.prepared().plane_bytes(),
            eng.model().packed_weight_bytes()
        );
        let label = "deploy: engine i32 per-channel prepared, batch 16";
        let s = bench_for(label, 1, Duration::from_secs(3), || {
            let _ = eng.forward_batch(&batch.x.data, b).expect("deploy fwd pc");
        });
        println!("{}  ({:.0} img/s)", s.report(), s.per_sec(b as f64));

        // QPKG v3 default: per-channel activation scales on every aq
        // site; those layers run the exact-f32 route (no per-output-
        // channel integer requant exists), so this row tracks the
        // per-channel-default serving cost against the rows above
        for l in &nm.layers {
            if l.aq {
                let sa: Vec<f32> = (0..l.d_in).map(|j| 0.02 + 1e-4 * j as f32).collect();
                pc_state.insert(format!("params/{}.as", l.name), Tensor::new(vec![l.d_in], sa));
            }
        }
        let (dm_pcact, _) = export_model(&nm, &pc_state, &ecfg)?;
        let eng = Engine::new(dm_pcact);
        let label = "deploy: engine pc-act (v3) prepared, batch 16";
        let s = bench_for(label, 1, Duration::from_secs(3), || {
            let _ = eng.forward_batch(&batch.x.data, b).expect("deploy fwd pcact");
        });
        println!("{}  ({:.0} img/s)", s.report(), s.per_sec(b as f64));
    }

    if be.compile_seconds() > 0.0 {
        println!("\ntotal XLA compile time: {:.1}s", be.compile_seconds());
    }
    Ok(())
}
