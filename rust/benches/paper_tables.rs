//! Regenerate every paper TABLE (1-8).
//!
//! `cargo bench --bench paper_tables` runs a reduced-scale pass by default
//! (QAT_BENCH_STEPS=80, one seed) so the whole suite demonstrates each
//! table in minutes. The committed EXPERIMENTS.md results were produced
//! with the full settings via the main binary:
//!
//!     cargo run --release -- suite --steps 400 --fp-steps 600 --seeds 0,1
//!
//! Environment knobs: QAT_BENCH_STEPS, QAT_BENCH_FP_STEPS, QAT_BENCH_SEEDS,
//! QAT_BENCH_TABLES (comma list, e.g. "2,4,5").

use oscillations_qat::coordinator::experiment::Lab;
use oscillations_qat::runtime::auto_backend;
use std::path::Path;

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let be = auto_backend(Path::new("artifacts"))?;
    let mut lab = Lab::new(be.as_ref());
    lab.qat_steps = env_u64("QAT_BENCH_STEPS", 80);
    lab.fp_steps = env_u64("QAT_BENCH_FP_STEPS", 120);
    lab.bn_batches = 8;
    lab.seeds = std::env::var("QAT_BENCH_SEEDS")
        .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|_| vec![0]);
    lab.ckpt_dir = Path::new("ckpts/bench").to_path_buf();
    lab.results_dir = Path::new("results/bench").to_path_buf();

    let which: Vec<u32> = std::env::var("QAT_BENCH_TABLES")
        .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|_| (1..=8).collect());

    for t in which {
        let t0 = std::time::Instant::now();
        match t {
            1 => drop(lab.table1()?),
            2 => drop(lab.table2()?),
            3 => drop(lab.table3()?),
            4 => drop(lab.table4()?),
            5 => drop(lab.table5()?),
            6 => drop(lab.table6()?),
            7 => drop(lab.table7()?),
            8 => drop(lab.table8()?),
            _ => continue,
        }
        eprintln!("[bench] table{t} regenerated in {:.1?}\n", t0.elapsed());
    }
    Ok(())
}
