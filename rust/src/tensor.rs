//! Minimal host-side tensor: a shape plus a flat `Vec<f32>`.
//!
//! All interchange with the PJRT runtime is f32 (the artifacts are lowered
//! entirely in f32), so the coordinator does not need a dtype-generic
//! tensor — just enough structure for state management, analysis
//! (histograms, KL, argmax) and the quantization host mirror.

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn filled(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Scalar value of a 0-d (or single-element) tensor.
    pub fn item(&self) -> f32 {
        debug_assert_eq!(self.data.len(), 1, "item() on non-scalar");
        self.data[0]
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    pub fn variance(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        let m = self.mean();
        self.data.iter().map(|x| (x - m) * (x - m)).sum::<f32>()
            / self.data.len() as f32
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    }

    pub fn abs_mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|x| x.abs()).sum::<f32>() / self.data.len() as f32
    }

    /// Index of the maximum element (first on ties).
    pub fn argmax(&self) -> usize {
        argmax(&self.data)
    }
}

/// Index of the maximum element of a slice, first on ties. The single
/// tie-breaking rule shared by eval, the deploy engine and the serving
/// layer — the deploy round-trip's 100%-agreement contract depends on
/// all prediction paths using this one implementation.
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// Round-half-to-even, matching XLA's `round-nearest-even` (and therefore
/// the jnp.round used in every artifact). The host quantization mirror
/// MUST use this — `f32::round()` rounds half away from zero and diverges
/// from the compiled graphs exactly on the oscillation decision boundary.
pub fn round_ties_even(x: f32) -> f32 {
    // stable Rust has f32::round_ties_even since 1.77
    x.round_ties_even()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_stats() {
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 6.0]);
        assert_eq!(t.len(), 4);
        assert!((t.mean() - 3.0).abs() < 1e-6);
        assert!((t.variance() - 3.5).abs() < 1e-6);
        assert_eq!(t.argmax(), 3);
        assert_eq!(t.abs_max(), 6.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![3], vec![1.0]);
    }

    #[test]
    fn ties_to_even() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(-0.5), 0.0);
        assert_eq!(round_ties_even(-1.5), -2.0);
    }

    #[test]
    fn scalar_item() {
        assert_eq!(Tensor::scalar(7.0).item(), 7.0);
    }
}
