//! Metrics sink: JSONL step logs + CSV series under `results/`.

use crate::json::Json;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// Append-only JSONL logger for step metrics.
pub struct JsonlLogger {
    file: Option<std::fs::File>,
}

impl JsonlLogger {
    /// `path = None` -> disabled (useful in tests).
    pub fn new(path: Option<&Path>) -> Self {
        let file = path.and_then(|p| {
            if let Some(dir) = p.parent() {
                let _ = std::fs::create_dir_all(dir);
            }
            std::fs::OpenOptions::new().create(true).append(true).open(p).ok()
        });
        JsonlLogger { file }
    }

    pub fn log(&mut self, fields: &[(&str, f64)]) {
        let Some(f) = self.file.as_mut() else { return };
        let obj: BTreeMap<String, Json> = fields
            .iter()
            .map(|(k, v)| (k.to_string(), Json::Num(*v)))
            .collect();
        let _ = writeln!(f, "{}", crate::json::to_string(&Json::Obj(obj)));
    }
}

/// In-memory step-metric history with CSV export (loss curves etc.).
#[derive(Debug, Clone, Default)]
pub struct History {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
}

impl History {
    pub fn new(columns: &[&str]) -> Self {
        History { columns: columns.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn push(&mut self, row: Vec<f64>) {
        debug_assert_eq!(row.len(), self.columns.len());
        self.rows.push(row);
    }

    pub fn col(&self, name: &str) -> Option<Vec<f64>> {
        let i = self.columns.iter().position(|c| c == name)?;
        Some(self.rows.iter().map(|r| r[i]).collect())
    }

    pub fn last(&self, name: &str) -> Option<f64> {
        let i = self.columns.iter().position(|c| c == name)?;
        self.rows.last().map(|r| r[i])
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.columns.join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(
                &r.iter().map(|v| format!("{v}")).collect::<Vec<_>>().join(","),
            );
            s.push('\n');
        }
        s
    }

    pub fn save_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_roundtrip() {
        let mut h = History::new(&["step", "loss"]);
        h.push(vec![0.0, 2.5]);
        h.push(vec![1.0, 2.0]);
        assert_eq!(h.col("loss").unwrap(), vec![2.5, 2.0]);
        assert_eq!(h.last("loss"), Some(2.0));
        assert!(h.to_csv().starts_with("step,loss\n0,2.5\n"));
    }

    #[test]
    fn jsonl_writes_parseable_lines() {
        let dir = std::env::temp_dir().join("qat_metrics_test");
        let p = dir.join("m.jsonl");
        let _ = std::fs::remove_file(&p);
        let mut l = JsonlLogger::new(Some(&p));
        l.log(&[("step", 1.0), ("loss", 0.5)]);
        drop(l);
        let text = std::fs::read_to_string(&p).unwrap();
        let j = crate::json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(j.get("loss").as_f64(), Some(0.5));
    }

    #[test]
    fn disabled_logger_is_noop() {
        let mut l = JsonlLogger::new(None);
        l.log(&[("x", 1.0)]); // must not panic
    }
}
