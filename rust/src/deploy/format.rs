//! The deployed-model data structure and the versioned QPKG on-disk
//! format.
//!
//! A [`DeployModel`] is the frozen inference artifact an export produces
//! from a trained QAT state: per layer the bit-packed integer weight
//! codes, the LSQ scales, the optional bias, and the BN statistics folded
//! into a per-channel requantization affine (`y = mult[c] * z + add[c]`).
//! No training state (momenta, oscillation EMAs, latent weights) and no
//! running-stat updates survive the export — this struct is everything
//! inference needs and nothing else.
//!
//! QPKG binary layout (all little-endian, version 4):
//!
//! ```text
//! magic  'QPKG'  | u32 version | u16 name_len + name
//! u32 input_hw   | u32 num_classes | u8 quant_a | u32 bits_w | u32 bits_a
//! u32 n_layers, then per layer:
//!   u16 name_len + name
//!   u8 op (0 = full matmul, 1 = depthwise 3-tap, 2 = spatial depthwise)
//!   u8 relu | u8 aq | u8 has_bias | u8 has_requant
//!   u32 d_in | u32 d_out | u32 w_bits | u32 act_bits
//!   u32 kernel | u32 stride | u32 pad | u32 hw_in | u32 channels
//!                                   (spatial metadata, op = 2 only)
//!   u32 n_w_scales | [f32 w_scales; n_w_scales]
//!   u32 n_a_scales | [f32 a_scales; n_a_scales]
//!   [f32 bias; d_out]               (if has_bias)
//!   [f32 mult; d_out] [f32 add; d_out]   (if has_requant)
//!   u32 n_codes | u32 n_bytes | packed weight bitstream
//! ```
//!
//! `n_w_scales` is 1 (per-tensor LSQ) or one per scale channel —
//! `d_out` for dense/1-D depthwise layers, `channels` for spatial
//! depthwise (`[C, 3, 3]` planes, one scale per channel plane);
//! `n_a_scales` is 1 (per-tensor activation LSQ) or one per input
//! channel — `d_in` for 1-D layers, `channels` for spatial depthwise
//! (the `[H, W, C]` channel-last input has `C` channels).
//! **Version negotiation:** the writer always emits version 4 (which
//! added op tag 2 + the spatial metadata block); the reader accepts
//! version 3 files (identical layout minus op tag 2), version 2 files
//! (whose layer record carries a single `f32 a_scale` where v3 puts the
//! counted scale array) and version 1 files (a single `f32 w_scale`
//! *and* a single `f32 a_scale`), upgrading all of them in memory, so
//! every older artifact keeps loading unchanged.

use super::packed::Packed;
use crate::quant::{act_grid, weight_grid};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"QPKG";
/// Version the writer emits.
const VERSION: u32 = 4;
/// Oldest version the reader still accepts (upgraded on load).
const MIN_VERSION: u32 = 1;

/// How a deployed layer mixes its input (mirrors the native zoo ops).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeployOp {
    /// dense matmul, weights `[d_in, d_out]` row-major
    Full,
    /// circular depthwise 3-tap channel conv, weights `[d_out, 3]`
    Dw,
    /// true 2-D spatial depthwise conv over an `[H, W, C]` channel-last
    /// block, weights `[C, k, k]` (QPKG v4)
    DwSpatial,
}

/// Spatial geometry of a [`DeployOp::DwSpatial`] layer (QPKG v4 layer
/// metadata). `kernel` is fixed at 3 today but stored in the file so the
/// format can grow without another version bump.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DwSpatialMeta {
    pub kernel: usize,
    pub stride: usize,
    pub pad: usize,
    /// square input side: the layer reads `hw_in * hw_in * channels`
    pub hw_in: usize,
    pub channels: usize,
}

impl DwSpatialMeta {
    /// Output side length under stride/pad.
    pub fn hw_out(&self) -> usize {
        (self.hw_in + 2 * self.pad - self.kernel) / self.stride + 1
    }
}

/// Per-channel requantization affine (the folded BN): `y = mult*z + add`.
#[derive(Debug, Clone, PartialEq)]
pub struct Requant {
    pub mult: Vec<f32>,
    pub add: Vec<f32>,
}

/// One deployed layer.
#[derive(Debug, Clone, PartialEq)]
pub struct DeployLayer {
    pub name: String,
    pub op: DeployOp,
    pub d_in: usize,
    pub d_out: usize,
    pub relu: bool,
    /// input activations are quantized (unsigned LSQ grid `[0, act_p]`)
    pub aq: bool,
    pub act_bits: u32,
    /// LSQ activation scales: one element (per-tensor) or `d_in`
    /// elements (per-channel, one per input channel)
    pub a_scales: Vec<f32>,
    pub w_bits: u32,
    /// LSQ weight scales: one element (per-tensor) or `d_out` elements
    /// (per-channel, one per output channel / depthwise channel row)
    pub w_scales: Vec<f32>,
    /// packed unsigned weight codes (`grid int - grid_n`)
    pub weights: Packed,
    pub bias: Option<Vec<f32>>,
    pub requant: Option<Requant>,
    /// spatial geometry; `Some` iff `op == DeployOp::DwSpatial`
    pub spatial: Option<DwSpatialMeta>,
}

impl DeployLayer {
    /// Signed weight grid `[n, p]` for this layer's bit-width.
    pub fn w_grid(&self) -> (f32, f32) {
        weight_grid(self.w_bits)
    }

    /// Grid minimum as the integer code offset.
    pub fn grid_n_int(&self) -> i32 {
        -(1i32 << (self.w_bits - 1))
    }

    /// Unsigned activation grid maximum.
    pub fn act_p(&self) -> f32 {
        act_grid(self.act_bits)
    }

    /// Whether the layer carries per-channel weight scales.
    pub fn per_channel(&self) -> bool {
        self.w_scales.len() > 1
    }

    /// Whether the layer carries per-channel activation scales.
    pub fn per_channel_act(&self) -> bool {
        self.a_scales.len() > 1
    }

    /// Channel layout `group` of the packed weight payload (see
    /// `kernels::scale_index`): dense `[d_in, d_out]` codes map to their
    /// output column (`group = 1`), depthwise `[C, 3]` rows to their
    /// channel row (`group = 3`), spatial depthwise `[C, 3, 3]` planes
    /// to their channel plane (`group = 9`).
    pub fn scale_group(&self) -> usize {
        match self.op {
            DeployOp::Full => 1,
            DeployOp::Dw => 3,
            DeployOp::DwSpatial => {
                let sp = self.spatial.expect("DwSpatial layer without metadata");
                sp.kernel * sp.kernel
            }
        }
    }

    /// Number of weight-scale channels in the per-channel layout.
    pub fn w_channels(&self) -> usize {
        match self.op {
            DeployOp::Full | DeployOp::Dw => self.d_out,
            DeployOp::DwSpatial => self.spatial.expect("DwSpatial layer without metadata").channels,
        }
    }

    /// Number of activation-scale channels admitted on this layer's input.
    pub fn act_channels(&self) -> usize {
        match self.op {
            DeployOp::DwSpatial => self.spatial.expect("DwSpatial layer without metadata").channels,
            _ => self.d_in,
        }
    }

    /// Weight scale of output channel `c` (per-tensor scales broadcast).
    pub fn w_scale_of(&self, c: usize) -> f32 {
        self.w_scales[c % self.w_scales.len()]
    }

    /// Activation scale of input channel `j` (per-tensor broadcast).
    pub fn a_scale_of(&self, j: usize) -> f32 {
        self.a_scales[j % self.a_scales.len()]
    }
}

/// A complete deployable model.
#[derive(Debug, Clone, PartialEq)]
pub struct DeployModel {
    pub name: String,
    pub input_hw: usize,
    pub num_classes: usize,
    /// activation quantization was enabled at export
    pub quant_a: bool,
    pub bits_w: u32,
    pub bits_a: u32,
    pub layers: Vec<DeployLayer>,
}

impl DeployModel {
    /// Flattened input width (`hw * hw * 3`).
    pub fn d_in(&self) -> usize {
        self.input_hw * self.input_hw * 3
    }

    /// Total weight count across layers.
    pub fn total_weights(&self) -> usize {
        self.layers.iter().map(|l| l.weights.len).sum()
    }

    /// Bytes the packed weight payloads occupy.
    pub fn packed_weight_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.weights.num_bytes()).sum()
    }

    /// Bytes the same weights occupy as f32 (the training-state baseline).
    pub fn f32_weight_bytes(&self) -> usize {
        self.total_weights() * 4
    }

    /// Bytes of non-weight payload (scales, biases, requant constants).
    pub fn aux_bytes(&self) -> usize {
        let mut n = 0usize;
        for l in &self.layers {
            // two scale counts + both scale arrays
            n += 8 + (l.w_scales.len() + l.a_scales.len()) * 4;
            if let Some(b) = &l.bias {
                n += b.len() * 4;
            }
            if let Some(r) = &l.requant {
                n += (r.mult.len() + r.add.len()) * 4;
            }
            if l.spatial.is_some() {
                // kernel | stride | pad | hw_in | channels
                n += 20;
            }
        }
        n
    }

    // ---------------------------------------------------------------
    // serialization

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(self.packed_weight_bytes() + self.aux_bytes() + 256);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        put_str(&mut buf, &self.name);
        buf.extend_from_slice(&(self.input_hw as u32).to_le_bytes());
        buf.extend_from_slice(&(self.num_classes as u32).to_le_bytes());
        buf.push(self.quant_a as u8);
        buf.extend_from_slice(&self.bits_w.to_le_bytes());
        buf.extend_from_slice(&self.bits_a.to_le_bytes());
        buf.extend_from_slice(&(self.layers.len() as u32).to_le_bytes());
        for l in &self.layers {
            put_str(&mut buf, &l.name);
            buf.push(match l.op {
                DeployOp::Full => 0,
                DeployOp::Dw => 1,
                DeployOp::DwSpatial => 2,
            });
            buf.push(l.relu as u8);
            buf.push(l.aq as u8);
            buf.push(l.bias.is_some() as u8);
            buf.push(l.requant.is_some() as u8);
            buf.extend_from_slice(&(l.d_in as u32).to_le_bytes());
            buf.extend_from_slice(&(l.d_out as u32).to_le_bytes());
            buf.extend_from_slice(&l.w_bits.to_le_bytes());
            buf.extend_from_slice(&l.act_bits.to_le_bytes());
            if l.op == DeployOp::DwSpatial {
                let sp = l.spatial.expect("DwSpatial layer without metadata");
                buf.extend_from_slice(&(sp.kernel as u32).to_le_bytes());
                buf.extend_from_slice(&(sp.stride as u32).to_le_bytes());
                buf.extend_from_slice(&(sp.pad as u32).to_le_bytes());
                buf.extend_from_slice(&(sp.hw_in as u32).to_le_bytes());
                buf.extend_from_slice(&(sp.channels as u32).to_le_bytes());
            }
            buf.extend_from_slice(&(l.w_scales.len() as u32).to_le_bytes());
            put_f32s(&mut buf, &l.w_scales);
            buf.extend_from_slice(&(l.a_scales.len() as u32).to_le_bytes());
            put_f32s(&mut buf, &l.a_scales);
            if let Some(b) = &l.bias {
                put_f32s(&mut buf, b);
            }
            if let Some(r) = &l.requant {
                put_f32s(&mut buf, &r.mult);
                put_f32s(&mut buf, &r.add);
            }
            buf.extend_from_slice(&(l.weights.len as u32).to_le_bytes());
            buf.extend_from_slice(&(l.weights.bytes.len() as u32).to_le_bytes());
            buf.extend_from_slice(&l.weights.bytes);
        }
        buf
    }

    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > buf.len() {
                bail!("qpkg truncated at byte {}", *pos);
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 4)? != MAGIC {
            bail!("bad qpkg magic");
        }
        let version = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?);
        if !(MIN_VERSION..=VERSION).contains(&version) {
            bail!("unsupported qpkg version {version} (supported: {MIN_VERSION}..={VERSION})");
        }
        let name = get_str(buf, &mut pos)?;
        let input_hw = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        let num_classes = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        let quant_a = take(&mut pos, 1)?[0] != 0;
        let bits_w = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?);
        let bits_a = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?);
        let n_layers = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        anyhow::ensure!(n_layers <= 4096, "qpkg claims {n_layers} layers");
        anyhow::ensure!(
            input_hw > 0 && input_hw <= 4096 && num_classes > 0,
            "qpkg header: input_hw {input_hw}, num_classes {num_classes}"
        );
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let lname = get_str(buf, &mut pos)?;
            let op = match take(&mut pos, 1)?[0] {
                0 => DeployOp::Full,
                1 => DeployOp::Dw,
                2 if version >= 4 => DeployOp::DwSpatial,
                2 => bail!("layer {lname}: spatial depthwise (op tag 2) needs qpkg v4, file is v{version}"),
                other => bail!("layer {lname}: unknown op tag {other}"),
            };
            let relu = take(&mut pos, 1)?[0] != 0;
            let aq = take(&mut pos, 1)?[0] != 0;
            let has_bias = take(&mut pos, 1)?[0] != 0;
            let has_requant = take(&mut pos, 1)?[0] != 0;
            let d_in = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            let d_out = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            let w_bits = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?);
            let act_bits = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?);
            anyhow::ensure!((1..=8).contains(&w_bits), "layer {lname}: w_bits {w_bits}");
            // v4 spatial metadata: the geometry must reproduce the layer's
            // flat d_in/d_out exactly, or the engine's tap walk would index
            // out of bounds on a serving worker instead of failing here
            let spatial = if op == DeployOp::DwSpatial {
                let kernel = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
                let stride = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
                let pad = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
                let hw_in = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
                let channels = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
                anyhow::ensure!(kernel == 3, "layer {lname}: spatial kernel {kernel} (only 3 supported)");
                anyhow::ensure!(stride >= 1 && stride <= hw_in.max(1), "layer {lname}: spatial stride {stride}");
                anyhow::ensure!(pad < kernel, "layer {lname}: spatial pad {pad}");
                anyhow::ensure!(
                    hw_in >= 1 && hw_in <= 4096 && channels >= 1,
                    "layer {lname}: spatial geometry {hw_in}x{hw_in}x{channels}"
                );
                anyhow::ensure!(
                    hw_in + 2 * pad >= kernel,
                    "layer {lname}: {hw_in}+2*{pad} input smaller than the {kernel}x{kernel} kernel"
                );
                let sp = DwSpatialMeta { kernel, stride, pad, hw_in, channels };
                let hw_out = sp.hw_out();
                anyhow::ensure!(
                    d_in == hw_in * hw_in * channels,
                    "layer {lname}: d_in {d_in} != {hw_in}x{hw_in}x{channels}"
                );
                anyhow::ensure!(
                    d_out == hw_out * hw_out * channels,
                    "layer {lname}: d_out {d_out} != {hw_out}x{hw_out}x{channels}"
                );
                Some(sp)
            } else {
                None
            };
            // per-channel scale-vector lengths: one per output column /
            // input element for 1-D layers, one per channel for spatial
            let w_ch = spatial.map(|sp| sp.channels).unwrap_or(d_out);
            let a_ch = spatial.map(|sp| sp.channels).unwrap_or(d_in);
            // v1 carries one f32 weight scale, v2+ a counted scale array
            // (1 = per-tensor, w_ch = per-channel)
            let w_scales = if version >= 2 {
                let n_scales = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
                anyhow::ensure!(
                    n_scales == 1 || n_scales == w_ch,
                    "layer {lname}: {n_scales} weight scales for {w_ch} channels"
                );
                get_f32s(buf, &mut pos, n_scales)?
            } else {
                vec![f32::from_le_bytes(take(&mut pos, 4)?.try_into()?)]
            };
            // v1/v2 carry one f32 activation scale, v3+ a counted array
            // (1 = per-tensor, a_ch = per-input-channel)
            let a_scales = if version >= 3 {
                let n_scales = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
                anyhow::ensure!(
                    n_scales == 1 || n_scales == a_ch,
                    "layer {lname}: {n_scales} activation scales for {a_ch} input channels"
                );
                get_f32s(buf, &mut pos, n_scales)?
            } else {
                vec![f32::from_le_bytes(take(&mut pos, 4)?.try_into()?)]
            };
            // the engine divides by these scales; the exporter writes
            // them clamped to >= 1e-8, so demand the symmetric invariant
            // instead of serving NaN/inf logits from a corrupt file
            for (c, &s) in w_scales.iter().enumerate() {
                anyhow::ensure!(
                    s.is_finite() && s > 0.0,
                    "layer {lname}: weight scale [{c}] = {s}"
                );
            }
            for (c, &s) in a_scales.iter().enumerate() {
                anyhow::ensure!(
                    s.is_finite() && s > 0.0,
                    "layer {lname}: activation scale [{c}] = {s}"
                );
            }
            let bias = if has_bias { Some(get_f32s(buf, &mut pos, d_out)?) } else { None };
            let requant = if has_requant {
                Some(Requant {
                    mult: get_f32s(buf, &mut pos, d_out)?,
                    add: get_f32s(buf, &mut pos, d_out)?,
                })
            } else {
                None
            };
            let n_codes = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            let n_bytes = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            anyhow::ensure!(
                n_bytes == (n_codes * w_bits as usize + 7) / 8,
                "layer {lname}: byte count {n_bytes} inconsistent with {n_codes} codes"
            );
            // geometry must be engine-safe: the kernels index the packed
            // payload by (d_in, d_out), so a mismatch here would panic a
            // worker thread instead of failing the load
            let want_codes = match op {
                DeployOp::Full => d_in * d_out,
                DeployOp::Dw => d_out * 3,
                DeployOp::DwSpatial => {
                    let sp = spatial.expect("spatial meta parsed above");
                    sp.channels * sp.kernel * sp.kernel
                }
            };
            anyhow::ensure!(
                n_codes == want_codes,
                "layer {lname}: {n_codes} codes but geometry {d_in}x{d_out} wants {want_codes}"
            );
            if op == DeployOp::Dw {
                anyhow::ensure!(d_in == d_out, "layer {lname}: depthwise d_in {d_in} != d_out {d_out}");
            }
            anyhow::ensure!(
                (1..=8).contains(&act_bits),
                "layer {lname}: act_bits {act_bits}"
            );
            let bytes = take(&mut pos, n_bytes)?.to_vec();
            layers.push(DeployLayer {
                name: lname,
                op,
                d_in,
                d_out,
                relu,
                aq,
                act_bits,
                a_scales,
                w_bits,
                w_scales,
                weights: Packed { bits: w_bits, len: n_codes, bytes },
                bias,
                requant,
                spatial,
            });
        }
        if pos != buf.len() {
            bail!("qpkg trailing bytes ({} of {})", buf.len() - pos, buf.len());
        }
        // cross-layer chaining: the engine feeds each layer's output
        // straight into the next and slices logits by num_classes, so any
        // mismatch must fail the load, not panic a serving worker
        anyhow::ensure!(!layers.is_empty(), "qpkg has no layers");
        let d_in0 = input_hw * input_hw * 3;
        anyhow::ensure!(
            layers[0].d_in == d_in0,
            "first layer wants {} inputs but input_hw {input_hw} gives {d_in0}",
            layers[0].d_in
        );
        for pair in layers.windows(2) {
            anyhow::ensure!(
                pair[0].d_out == pair[1].d_in,
                "layer {} emits {} but layer {} wants {}",
                pair[0].name,
                pair[0].d_out,
                pair[1].name,
                pair[1].d_in
            );
        }
        let last = layers.last().expect("non-empty layers");
        anyhow::ensure!(
            last.d_out == num_classes,
            "last layer {} emits {} but the model claims {num_classes} classes",
            last.name,
            last.d_out
        );
        Ok(DeployModel { name, input_hw, num_classes, quant_a, bits_w, bits_a, layers })
    }

    pub fn write_qpkg(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn read_qpkg(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::from_bytes(&buf)
    }

    /// Load-time prepare hook: decode every layer's packed payload
    /// exactly once into the engine's cached weight planes (see
    /// [`super::engine::PreparedModel`]). Serving stacks call this right
    /// after the QPKG load and share the result behind an `Arc`.
    pub fn prepare(self) -> super::engine::PreparedModel {
        super::engine::PreparedModel::new(self)
    }

    /// [`DeployModel::read_qpkg`] followed by [`DeployModel::prepare`].
    pub fn read_qpkg_prepared(path: &Path) -> Result<super::engine::PreparedModel> {
        Ok(Self::read_qpkg(path)?.prepare())
    }
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    let b = s.as_bytes();
    buf.extend_from_slice(&(b.len() as u16).to_le_bytes());
    buf.extend_from_slice(b);
}

fn get_str(buf: &[u8], pos: &mut usize) -> Result<String> {
    if *pos + 2 > buf.len() {
        bail!("qpkg truncated at byte {}", *pos);
    }
    let n = u16::from_le_bytes(buf[*pos..*pos + 2].try_into()?) as usize;
    *pos += 2;
    if *pos + n > buf.len() {
        bail!("qpkg truncated at byte {}", *pos);
    }
    let s = String::from_utf8(buf[*pos..*pos + n].to_vec())?;
    *pos += n;
    Ok(s)
}

fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for &v in xs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn get_f32s(buf: &[u8], pos: &mut usize, n: usize) -> Result<Vec<f32>> {
    if *pos + n * 4 > buf.len() {
        bail!("qpkg truncated at byte {}", *pos);
    }
    let mut out = Vec::with_capacity(n);
    for c in buf[*pos..*pos + n * 4].chunks_exact(4) {
        out.push(f32::from_le_bytes(c.try_into()?));
    }
    *pos += n * 4;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DeployModel {
        // input_hw 2 -> 12 inputs; stem [12, 3] chains into the dw head
        let codes: Vec<u32> = (0..36).map(|i| i % 8).collect();
        DeployModel {
            name: "tiny".into(),
            input_hw: 2,
            num_classes: 3,
            quant_a: true,
            bits_w: 3,
            bits_a: 3,
            layers: vec![
                DeployLayer {
                    name: "stem".into(),
                    op: DeployOp::Full,
                    d_in: 12,
                    d_out: 3,
                    relu: true,
                    aq: false,
                    act_bits: 8,
                    a_scales: vec![1.0],
                    w_bits: 3,
                    w_scales: vec![0.1],
                    weights: Packed::pack(&codes, 3).unwrap(),
                    bias: None,
                    requant: Some(Requant {
                        mult: vec![1.0, 0.5, 2.0],
                        add: vec![0.0, -0.1, 0.2],
                    }),
                    spatial: None,
                },
                DeployLayer {
                    name: "head".into(),
                    op: DeployOp::Dw,
                    d_in: 3,
                    d_out: 3,
                    relu: false,
                    aq: true,
                    act_bits: 3,
                    a_scales: vec![0.05],
                    w_bits: 4,
                    w_scales: vec![0.2],
                    weights: Packed::pack(&[1, 2, 3, 4, 5, 6, 7, 8, 9], 4).unwrap(),
                    bias: Some(vec![0.1, 0.2, 0.3]),
                    requant: None,
                    spatial: None,
                },
            ],
        }
    }

    /// The sample model with per-channel weight scales on both layers.
    fn sample_per_channel() -> DeployModel {
        let mut m = sample();
        m.layers[0].w_scales = vec![0.1, 0.07, 0.2];
        m.layers[1].w_scales = vec![0.2, 0.15, 0.3];
        m
    }

    /// The per-channel sample with per-channel **activation** scales on
    /// the quantized-activation head (d_in = 3).
    fn sample_per_channel_act() -> DeployModel {
        let mut m = sample_per_channel();
        m.layers[1].a_scales = vec![0.05, 0.04, 0.06];
        m
    }

    /// A v4 model with a true spatial depthwise interior layer:
    /// stem [12, 12] -> dw 2x2x3 (stride 1, pad 1 -> 2x2x3) -> head [12, 3],
    /// per-channel weight + activation scales of length C = 3 on the dw.
    fn sample_spatial() -> DeployModel {
        let sp = DwSpatialMeta { kernel: 3, stride: 1, pad: 1, hw_in: 2, channels: 3 };
        let stem_codes: Vec<u32> = (0..144).map(|i| i % 8).collect();
        let dw_codes: Vec<u32> = (0..27).map(|i| (i * 5) % 16).collect();
        let head_codes: Vec<u32> = (0..36).map(|i| (i + 2) % 8).collect();
        DeployModel {
            name: "tiny2d".into(),
            input_hw: 2,
            num_classes: 3,
            quant_a: true,
            bits_w: 4,
            bits_a: 4,
            layers: vec![
                DeployLayer {
                    name: "stem".into(),
                    op: DeployOp::Full,
                    d_in: 12,
                    d_out: 12,
                    relu: true,
                    aq: false,
                    act_bits: 8,
                    a_scales: vec![1.0],
                    w_bits: 3,
                    w_scales: vec![0.1],
                    weights: Packed::pack(&stem_codes, 3).unwrap(),
                    bias: None,
                    requant: Some(Requant {
                        mult: vec![1.0; 12],
                        add: vec![0.0; 12],
                    }),
                    spatial: None,
                },
                DeployLayer {
                    name: "b1.dw".into(),
                    op: DeployOp::DwSpatial,
                    d_in: 12,
                    d_out: 12,
                    relu: true,
                    aq: true,
                    act_bits: 4,
                    a_scales: vec![0.05, 0.04, 0.06],
                    w_bits: 4,
                    w_scales: vec![0.2, 0.15, 0.3],
                    weights: Packed::pack(&dw_codes, 4).unwrap(),
                    bias: None,
                    requant: Some(Requant {
                        mult: (0..12).map(|i| 0.5 + 0.1 * i as f32).collect(),
                        add: (0..12).map(|i| -0.2 + 0.05 * i as f32).collect(),
                    }),
                    spatial: Some(sp),
                },
                DeployLayer {
                    name: "head".into(),
                    op: DeployOp::Full,
                    d_in: 12,
                    d_out: 3,
                    relu: false,
                    aq: true,
                    act_bits: 4,
                    a_scales: vec![0.03],
                    w_bits: 3,
                    w_scales: vec![0.2, 0.15, 0.3],
                    weights: Packed::pack(&head_codes, 3).unwrap(),
                    bias: Some(vec![0.1, 0.2, 0.3]),
                    requant: None,
                    spatial: None,
                },
            ],
        }
    }

    /// Serialize a non-spatial model in the **version 3** layout — byte
    /// identical to v4 except the version word (v4 only added op tag 2
    /// plus its spatial metadata block, which v3-era layers never carry).
    fn v3_bytes(m: &DeployModel) -> Vec<u8> {
        assert!(
            m.layers.iter().all(|l| l.spatial.is_none()),
            "v3 cannot carry spatial layers"
        );
        let mut buf = m.to_bytes();
        buf[4..8].copy_from_slice(&3u32.to_le_bytes());
        buf
    }

    /// Serialize a model in the **version 1** layout (single f32 w_scale
    /// per layer) — the reader must keep accepting these.
    fn v1_bytes(m: &DeployModel) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&1u32.to_le_bytes());
        put_str(&mut buf, &m.name);
        buf.extend_from_slice(&(m.input_hw as u32).to_le_bytes());
        buf.extend_from_slice(&(m.num_classes as u32).to_le_bytes());
        buf.push(m.quant_a as u8);
        buf.extend_from_slice(&m.bits_w.to_le_bytes());
        buf.extend_from_slice(&m.bits_a.to_le_bytes());
        buf.extend_from_slice(&(m.layers.len() as u32).to_le_bytes());
        for l in &m.layers {
            put_str(&mut buf, &l.name);
            buf.push(match l.op {
                DeployOp::Full => 0,
                DeployOp::Dw => 1,
                DeployOp::DwSpatial => 2,
            });
            buf.push(l.relu as u8);
            buf.push(l.aq as u8);
            buf.push(l.bias.is_some() as u8);
            buf.push(l.requant.is_some() as u8);
            buf.extend_from_slice(&(l.d_in as u32).to_le_bytes());
            buf.extend_from_slice(&(l.d_out as u32).to_le_bytes());
            buf.extend_from_slice(&l.w_bits.to_le_bytes());
            buf.extend_from_slice(&l.act_bits.to_le_bytes());
            buf.extend_from_slice(&l.w_scales[0].to_le_bytes());
            buf.extend_from_slice(&l.a_scales[0].to_le_bytes());
            if let Some(b) = &l.bias {
                put_f32s(&mut buf, b);
            }
            if let Some(r) = &l.requant {
                put_f32s(&mut buf, &r.mult);
                put_f32s(&mut buf, &r.add);
            }
            buf.extend_from_slice(&(l.weights.len as u32).to_le_bytes());
            buf.extend_from_slice(&(l.weights.bytes.len() as u32).to_le_bytes());
            buf.extend_from_slice(&l.weights.bytes);
        }
        buf
    }

    /// Serialize a model in the **version 2** layout (counted w_scales
    /// array, single f32 a_scale per layer) — the PR-3 era writer, whose
    /// files the reader must keep accepting.
    fn v2_bytes(m: &DeployModel) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&2u32.to_le_bytes());
        put_str(&mut buf, &m.name);
        buf.extend_from_slice(&(m.input_hw as u32).to_le_bytes());
        buf.extend_from_slice(&(m.num_classes as u32).to_le_bytes());
        buf.push(m.quant_a as u8);
        buf.extend_from_slice(&m.bits_w.to_le_bytes());
        buf.extend_from_slice(&m.bits_a.to_le_bytes());
        buf.extend_from_slice(&(m.layers.len() as u32).to_le_bytes());
        for l in &m.layers {
            put_str(&mut buf, &l.name);
            buf.push(match l.op {
                DeployOp::Full => 0,
                DeployOp::Dw => 1,
                DeployOp::DwSpatial => 2,
            });
            buf.push(l.relu as u8);
            buf.push(l.aq as u8);
            buf.push(l.bias.is_some() as u8);
            buf.push(l.requant.is_some() as u8);
            buf.extend_from_slice(&(l.d_in as u32).to_le_bytes());
            buf.extend_from_slice(&(l.d_out as u32).to_le_bytes());
            buf.extend_from_slice(&l.w_bits.to_le_bytes());
            buf.extend_from_slice(&l.act_bits.to_le_bytes());
            buf.extend_from_slice(&(l.w_scales.len() as u32).to_le_bytes());
            put_f32s(&mut buf, &l.w_scales);
            buf.extend_from_slice(&l.a_scales[0].to_le_bytes());
            if let Some(b) = &l.bias {
                put_f32s(&mut buf, b);
            }
            if let Some(r) = &l.requant {
                put_f32s(&mut buf, &r.mult);
                put_f32s(&mut buf, &r.add);
            }
            buf.extend_from_slice(&(l.weights.len as u32).to_le_bytes());
            buf.extend_from_slice(&(l.weights.bytes.len() as u32).to_le_bytes());
            buf.extend_from_slice(&l.weights.bytes);
        }
        buf
    }

    #[test]
    fn qpkg_roundtrip() {
        let m = sample();
        let bytes = m.to_bytes();
        let m2 = DeployModel::from_bytes(&bytes).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn qpkg_v3_roundtrips_per_channel_scales() {
        let m = sample_per_channel();
        let m2 = DeployModel::from_bytes(&m.to_bytes()).unwrap();
        assert_eq!(m, m2);
        assert!(m2.layers[0].per_channel());
        assert_eq!(m2.layers[0].w_scale_of(1), 0.07);
        assert_eq!(m2.layers[1].w_scale_of(2), 0.3);
    }

    #[test]
    fn qpkg_v3_roundtrips_per_channel_activation_scales() {
        let m = sample_per_channel_act();
        let m2 = DeployModel::from_bytes(&m.to_bytes()).unwrap();
        assert_eq!(m, m2);
        assert!(!m2.layers[0].per_channel_act());
        assert!(m2.layers[1].per_channel_act());
        assert_eq!(m2.layers[1].a_scale_of(1), 0.04);
        assert_eq!(m2.layers[1].a_scale_of(2), 0.06);
        // per-tensor activation scales broadcast
        assert_eq!(m2.layers[0].a_scale_of(7), 1.0);
    }

    #[test]
    fn v1_layout_upgrades_to_scale_vectors() {
        let m = sample();
        let old = v1_bytes(&m);
        let loaded = DeployModel::from_bytes(&old).unwrap();
        // the in-memory upgrade is exactly the current model with
        // one-element scale vectors — the same struct the writer
        // round-trips
        assert_eq!(loaded, m);
        assert!(!loaded.layers[0].per_channel());
        assert!(!loaded.layers[1].per_channel_act());
        assert_eq!(loaded.layers[0].w_scales, vec![0.1]);
        assert_eq!(loaded.layers[1].a_scales, vec![0.05]);
        // and re-saving silently upgrades the file to the current version
        let resaved = DeployModel::from_bytes(&loaded.to_bytes()).unwrap();
        assert_eq!(resaved, m);
    }

    #[test]
    fn v2_layout_upgrades_activation_scale_to_vector() {
        // v2 carries per-channel w_scales but a single f32 a_scale
        let m = sample_per_channel();
        let old = v2_bytes(&m);
        let loaded = DeployModel::from_bytes(&old).unwrap();
        assert_eq!(loaded, m);
        assert!(loaded.layers[0].per_channel());
        assert_eq!(loaded.layers[1].a_scales, vec![0.05]);
        let resaved = DeployModel::from_bytes(&loaded.to_bytes()).unwrap();
        assert_eq!(resaved, m);
    }

    #[test]
    fn qpkg_v4_roundtrips_spatial_depthwise() {
        let m = sample_spatial();
        let bytes = m.to_bytes();
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), 4);
        let m2 = DeployModel::from_bytes(&bytes).unwrap();
        assert_eq!(m, m2);
        let dw = &m2.layers[1];
        assert_eq!(dw.op, DeployOp::DwSpatial);
        let sp = dw.spatial.unwrap();
        assert_eq!((sp.kernel, sp.stride, sp.pad, sp.hw_in, sp.channels), (3, 1, 1, 2, 3));
        assert_eq!(sp.hw_out(), 2);
        assert_eq!(dw.scale_group(), 9);
        assert_eq!(dw.w_channels(), 3);
        assert_eq!(dw.act_channels(), 3);
        // channel-last: output element o reads channel o % C scales
        assert_eq!(dw.w_scale_of(4), 0.15);
        assert_eq!(dw.a_scale_of(5), 0.06);
    }

    #[test]
    fn v3_layout_upgrades_to_v4() {
        // a v3 file (same layout, older version word) loads to the exact
        // struct the v4 writer round-trips, and re-saving emits v4
        let m = sample_per_channel_act();
        let old = v3_bytes(&m);
        assert_eq!(u32::from_le_bytes(old[4..8].try_into().unwrap()), 3);
        let loaded = DeployModel::from_bytes(&old).unwrap();
        assert_eq!(loaded, m);
        let resaved_bytes = loaded.to_bytes();
        assert_eq!(u32::from_le_bytes(resaved_bytes[4..8].try_into().unwrap()), 4);
        assert_eq!(DeployModel::from_bytes(&resaved_bytes).unwrap(), m);
    }

    #[test]
    fn qpkg_rejects_spatial_in_pre_v4_files() {
        // op tag 2 under a v3 version word must fail cleanly, not parse
        let m = sample_spatial();
        let mut bytes = m.to_bytes();
        bytes[4..8].copy_from_slice(&3u32.to_le_bytes());
        let err = DeployModel::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("needs qpkg v4"), "{err}");
    }

    #[test]
    fn qpkg_rejects_bad_spatial_geometry() {
        // d_in inconsistent with hw_in^2 * channels
        let mut m = sample_spatial();
        m.layers[1].spatial = Some(DwSpatialMeta { kernel: 3, stride: 1, pad: 1, hw_in: 3, channels: 3 });
        assert!(DeployModel::from_bytes(&m.to_bytes()).is_err());
        // non-3 kernel is refused
        let mut m = sample_spatial();
        m.layers[1].spatial = Some(DwSpatialMeta { kernel: 5, stride: 1, pad: 1, hw_in: 2, channels: 3 });
        assert!(DeployModel::from_bytes(&m.to_bytes()).is_err());
        // weight scale count must be 1 or channels (d_out = 12 is wrong)
        let mut m = sample_spatial();
        m.layers[1].w_scales = vec![0.1; 12];
        assert!(DeployModel::from_bytes(&m.to_bytes()).is_err());
        // activation scale count must be 1 or channels
        let mut m = sample_spatial();
        m.layers[1].a_scales = vec![0.05; 12];
        assert!(DeployModel::from_bytes(&m.to_bytes()).is_err());
    }

    #[test]
    fn qpkg_rejects_bad_scale_counts() {
        // weight scale count must be 1 or d_out
        let mut m = sample();
        m.layers[0].w_scales = vec![0.1, 0.2]; // d_out = 3
        assert!(DeployModel::from_bytes(&m.to_bytes()).is_err());
        // activation scale count must be 1 or d_in
        let mut m = sample();
        m.layers[1].a_scales = vec![0.05, 0.04]; // d_in = 3
        assert!(DeployModel::from_bytes(&m.to_bytes()).is_err());
        // non-positive per-channel scale entries are rejected
        let mut m = sample_per_channel();
        m.layers[0].w_scales[1] = 0.0;
        assert!(DeployModel::from_bytes(&m.to_bytes()).is_err());
        let mut m = sample_per_channel_act();
        m.layers[1].a_scales[1] = f32::NAN;
        assert!(DeployModel::from_bytes(&m.to_bytes()).is_err());
        // future versions are refused outright
        let mut bytes = sample().to_bytes();
        bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
        assert!(DeployModel::from_bytes(&bytes).is_err());
    }

    #[test]
    fn qpkg_file_roundtrip() {
        let dir = std::env::temp_dir().join("qat_deploy_fmt");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("tiny.qpkg");
        let m = sample();
        m.write_qpkg(&p).unwrap();
        let m2 = DeployModel::read_qpkg(&p).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn qpkg_rejects_corrupt() {
        assert!(DeployModel::from_bytes(b"NOPE").is_err());
        let mut bytes = sample().to_bytes();
        bytes.truncate(bytes.len() - 3);
        assert!(DeployModel::from_bytes(&bytes).is_err());
        let mut extra = sample().to_bytes();
        extra.push(0);
        assert!(DeployModel::from_bytes(&extra).is_err());
    }

    #[test]
    fn size_accounting() {
        let m = sample();
        assert_eq!(m.total_weights(), 45);
        assert_eq!(m.f32_weight_bytes(), 180);
        // 36 x 3-bit = 14 bytes, 9 x 4-bit = 5 bytes
        assert_eq!(m.packed_weight_bytes(), 19);
        assert!(m.aux_bytes() > 0);
        assert_eq!(m.d_in(), 12);
    }

    #[test]
    fn qpkg_rejects_broken_chaining() {
        // last layer's width must equal num_classes
        let mut m = sample();
        m.num_classes = 7;
        assert!(DeployModel::from_bytes(&m.to_bytes()).is_err());
        // adjacent layers must chain d_out -> d_in
        let mut m = sample();
        m.layers[0].d_out = 5; // codes no longer match 12x5 either
        assert!(DeployModel::from_bytes(&m.to_bytes()).is_err());
    }

    #[test]
    fn prepare_hook_decodes_planes_at_load() {
        let m = sample();
        let pm = m.clone().prepare();
        assert_eq!(pm.model(), &m);
        assert_eq!(pm.layers().len(), 2);
        // stem (aq = false): f32 plane only; head (aq = true): both
        assert_eq!(pm.layers()[0].wq.len(), 36);
        assert!(pm.layers()[0].wi.is_none());
        assert_eq!(pm.layers()[1].wq.len(), 9);
        assert!(pm.layers()[1].wi.is_some());
        assert_eq!(pm.plane_bytes(), 36 * 4 + 9 * 8);
    }

    #[test]
    fn grid_helpers() {
        let l = &sample().layers[0];
        assert_eq!(l.w_grid(), (-4.0, 3.0));
        assert_eq!(l.grid_n_int(), -4);
        assert_eq!(l.act_p(), 255.0);
    }
}
