//! Bit-packed integer code storage for deployed weights.
//!
//! A quantized weight tensor is a vector of grid indices `q in [n, p]`
//! with `p - n + 1 <= 2^bits` states. On disk and in serving memory the
//! indices are stored as unsigned offset codes `c = q - n` packed
//! LSB-first into a contiguous bitstream: 2x int4 per byte, 8x int1 per
//! byte, int8 one per byte, and odd widths (3/5/6/7 bit) straddling byte
//! boundaries. This is what makes the exported artifact `bits/32` the
//! size of the f32 state it came from.
//!
//! Codes are limited to 8 bits (the repo's widest grid), so one code
//! spans at most two bytes and the accessors never need more than a
//! 16-bit window.
//!
//! **Decoding is bulk, not per-element.** [`Packed::get`] extracts one
//! code with bit arithmetic, but every whole-payload decoder
//! ([`Packed::unpack`], [`Packed::ints_into`], [`Packed::dequant_pc_into`])
//! runs through one byte-level core: widths that divide a byte (1/2/4/8
//! bit) emit all of a byte's codes from a 256-entry lookup table in one
//! indexed load, and the odd widths (3/5/6/7 bit) load a whole
//! byte-aligned chunk (e.g. 3 bytes = eight 3-bit codes) into a u64
//! window and shift the codes out — no per-element byte/shift
//! computation, no per-element bounds checks. The bulk core is proven
//! bit-identical to the `get(i)` loop by proptest for every width.

use anyhow::Result;

/// `LUT[b][j]` = the `j`-th `BITS`-wide code of byte `b` (LSB-first).
const fn build_lut<const CODES: usize>(bits: u32) -> [[u8; CODES]; 256] {
    let mask = ((1u32 << bits) - 1) as usize;
    let mut t = [[0u8; CODES]; 256];
    let mut b = 0usize;
    while b < 256 {
        let mut j = 0usize;
        while j < CODES {
            t[b][j] = ((b >> (j * bits as usize)) & mask) as u8;
            j += 1;
        }
        b += 1;
    }
    t
}

static LUT1: [[u8; 8]; 256] = build_lut::<8>(1);
static LUT2: [[u8; 4]; 256] = build_lut::<4>(2);
static LUT4: [[u8; 2]; 256] = build_lut::<2>(4);

/// A bit-packed vector of unsigned codes, each `bits` wide.
#[derive(Debug, Clone, PartialEq)]
pub struct Packed {
    /// bits per code, 1..=8
    pub bits: u32,
    /// number of codes
    pub len: usize,
    /// LSB-first bitstream, `ceil(len * bits / 8)` bytes
    pub bytes: Vec<u8>,
}

impl Packed {
    /// Pack `codes` (each `< 2^bits`) into a bitstream.
    pub fn pack(codes: &[u32], bits: u32) -> Result<Packed> {
        anyhow::ensure!((1..=8).contains(&bits), "packed bits {bits} outside 1..=8");
        let mask = (1u32 << bits) - 1;
        let bits_us = bits as usize;
        let mut bytes = vec![0u8; (codes.len() * bits_us + 7) / 8];
        for (i, &c) in codes.iter().enumerate() {
            anyhow::ensure!(c <= mask, "code {c} does not fit in {bits} bits");
            let bit = i * bits_us;
            let (byte, shift) = (bit / 8, bit % 8);
            bytes[byte] |= (c << shift) as u8;
            if shift + bits_us > 8 {
                bytes[byte + 1] |= (c >> (8 - shift)) as u8;
            }
        }
        Ok(Packed { bits, len: codes.len(), bytes })
    }

    /// Read the `i`-th code.
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        debug_assert!(i < self.len, "packed index {i} out of {}", self.len);
        let bits = self.bits as usize;
        let bit = i * bits;
        let (byte, shift) = (bit / 8, bit % 8);
        let lo = self.bytes[byte] as u32;
        let hi = if shift + bits > 8 { (self.bytes[byte + 1] as u32) << 8 } else { 0 };
        ((lo | hi) >> shift) & ((1u32 << self.bits) - 1)
    }

    /// The bulk byte-level decode core: emit every code in order through
    /// `emit`, whole bytes (or whole byte-aligned chunks for the odd
    /// widths) at a time. Bit-identical to `(0..len).map(|i| get(i))`.
    #[inline]
    fn decode_with(&self, mut emit: impl FnMut(u32)) {
        match self.bits {
            8 => {
                for &b in &self.bytes[..self.len] {
                    emit(b as u32);
                }
            }
            1 | 2 | 4 => {
                let cpb = 8 / self.bits as usize; // codes per byte
                let full = self.len / cpb;
                match self.bits {
                    1 => {
                        for &b in &self.bytes[..full] {
                            for &c in &LUT1[b as usize] {
                                emit(c as u32);
                            }
                        }
                    }
                    2 => {
                        for &b in &self.bytes[..full] {
                            for &c in &LUT2[b as usize] {
                                emit(c as u32);
                            }
                        }
                    }
                    _ => {
                        for &b in &self.bytes[..full] {
                            for &c in &LUT4[b as usize] {
                                emit(c as u32);
                            }
                        }
                    }
                }
                for i in full * cpb..self.len {
                    emit(self.get(i));
                }
            }
            bits => {
                // odd widths: the smallest byte-aligned chunk is
                // lcm(bits, 8) bits — load it into a u64 window once and
                // shift all its codes out
                let bits = bits as usize;
                let (chunk_bytes, chunk_codes) = match bits {
                    3 => (3usize, 8usize),
                    5 => (5, 8),
                    6 => (3, 4),
                    7 => (7, 8),
                    _ => (0, 0), // unreachable for valid payloads
                };
                if chunk_codes == 0 {
                    for i in 0..self.len {
                        emit(self.get(i));
                    }
                    return;
                }
                let mask = (1u64 << bits) - 1;
                let chunks = self.len / chunk_codes;
                for ch in 0..chunks {
                    let mut window = 0u64;
                    for (i, &b) in
                        self.bytes[ch * chunk_bytes..ch * chunk_bytes + chunk_bytes].iter().enumerate()
                    {
                        window |= (b as u64) << (8 * i);
                    }
                    for j in 0..chunk_codes {
                        emit(((window >> (j * bits)) & mask) as u32);
                    }
                }
                for i in chunks * chunk_codes..self.len {
                    emit(self.get(i));
                }
            }
        }
    }

    /// All codes, decoded through the bulk core into `out` (pre-sized,
    /// no reallocation).
    pub fn unpack_into(&self, out: &mut Vec<u32>) {
        out.clear();
        out.reserve_exact(self.len);
        self.decode_with(|c| out.push(c));
    }

    /// All codes, unpacked.
    pub fn unpack(&self) -> Vec<u32> {
        let mut out = Vec::new();
        self.unpack_into(&mut out);
        out
    }

    /// Decode to signed grid integers (`code + grid_n`).
    pub fn ints_into(&self, grid_n: i32, out: &mut Vec<i32>) {
        out.clear();
        out.reserve_exact(self.len);
        self.decode_with(|c| out.push(c as i32 + grid_n));
    }

    /// Decode to the fake-quant weight values `scale * (code + grid_n)`.
    ///
    /// Bit-exact against `kernels::fake_quant` for weights already on the
    /// grid: the grid integer is exactly representable in f32, so the
    /// single multiply here rounds identically to the kernel's
    /// `s * clip(round(w/s), n, p)`.
    pub fn dequant_into(&self, grid_n: i32, scale: f32, out: &mut Vec<f32>) {
        self.dequant_pc_into(grid_n, std::slice::from_ref(&scale), 1, out);
    }

    /// Per-channel decode: code `i` is dequantized with the scale of its
    /// channel, `scales[(i / group) % scales.len()]` (the same layout
    /// rule as `kernels::scale_index`). With a single scale this is
    /// [`Packed::dequant_into`], and it stays bit-exact against
    /// `kernels::fake_quant_pc` for on-grid weights.
    pub fn dequant_pc_into(&self, grid_n: i32, scales: &[f32], group: usize, out: &mut Vec<f32>) {
        out.clear();
        out.reserve_exact(self.len);
        let ns = scales.len().max(1);
        let g = group.max(1);
        // walk the (i / g) % ns scale index incrementally instead of
        // dividing per element
        let mut ci = 0usize;
        let mut left = g;
        self.decode_with(|c| {
            out.push(scales[ci] * ((c as i32 + grid_n) as f32));
            left -= 1;
            if left == 0 {
                left = g;
                ci += 1;
                if ci == ns {
                    ci = 0;
                }
            }
        });
    }

    /// Payload size in bytes.
    pub fn num_bytes(&self) -> usize {
        self.bytes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_widths() {
        for bits in 1..=8u32 {
            let mask = (1u32 << bits) - 1;
            let codes: Vec<u32> = (0..53u32).map(|i| (i * 7 + 3) & mask).collect();
            let p = Packed::pack(&codes, bits).unwrap();
            assert_eq!(p.len, codes.len());
            assert_eq!(p.bytes.len(), (codes.len() * bits as usize + 7) / 8);
            assert_eq!(p.unpack(), codes, "width {bits}");
        }
    }

    #[test]
    fn bulk_decode_matches_get_loop() {
        // odd lengths leave partial chunks/bytes: the bulk core's tail
        // path must agree with per-element extraction at every length
        for bits in 1..=8u32 {
            let mask = (1u32 << bits) - 1;
            for len in [0usize, 1, 2, 3, 7, 8, 9, 15, 16, 17, 23, 40, 41, 53] {
                let codes: Vec<u32> = (0..len as u32).map(|i| (i * 13 + 5) & mask).collect();
                let p = Packed::pack(&codes, bits).unwrap();
                let by_get: Vec<u32> = (0..p.len).map(|i| p.get(i)).collect();
                let mut bulk = Vec::new();
                p.unpack_into(&mut bulk);
                assert_eq!(bulk, by_get, "width {bits} len {len}");
                assert!(bulk.capacity() >= len, "unpack_into must pre-size");
            }
        }
    }

    #[test]
    fn int4_pairs_per_byte() {
        let p = Packed::pack(&[0x3, 0xa, 0xf, 0x1], 4).unwrap();
        assert_eq!(p.bytes, vec![0xa3, 0x1f]);
        assert_eq!(p.get(1), 0xa);
        assert_eq!(p.get(3), 0x1);
    }

    #[test]
    fn three_bit_codes_straddle_bytes() {
        // 8 x 3-bit codes fill exactly 3 bytes
        let codes: Vec<u32> = vec![1, 7, 0, 5, 2, 6, 3, 4];
        let p = Packed::pack(&codes, 3).unwrap();
        assert_eq!(p.bytes.len(), 3);
        assert_eq!(p.unpack(), codes);
    }

    #[test]
    fn rejects_overflow_and_bad_width() {
        assert!(Packed::pack(&[8], 3).is_err());
        assert!(Packed::pack(&[0], 0).is_err());
        assert!(Packed::pack(&[0], 9).is_err());
    }

    #[test]
    fn signed_decode_applies_grid_offset() {
        // 3-bit signed grid [-4, 3]: codes are q + 4
        let q = [-4i32, -1, 0, 3];
        let codes: Vec<u32> = q.iter().map(|&v| (v + 4) as u32).collect();
        let p = Packed::pack(&codes, 3).unwrap();
        let mut ints = Vec::new();
        p.ints_into(-4, &mut ints);
        assert_eq!(ints, q);
        let mut deq = Vec::new();
        p.dequant_into(-4, 0.25, &mut deq);
        assert_eq!(deq, vec![-1.0, -0.25, 0.0, 0.75]);
    }

    #[test]
    fn per_channel_decode_uses_each_channels_scale() {
        // [2, 2] dense columns: channel = i % 2
        let codes = vec![6u32, 6, 2, 2]; // grid ints +2, +2, -2, -2
        let p = Packed::pack(&codes, 3).unwrap();
        let mut deq = Vec::new();
        p.dequant_pc_into(-4, &[0.5, 0.25], 1, &mut deq);
        assert_eq!(deq, vec![1.0, 0.5, -1.0, -0.5]);
        // dw rows [2, 2... use group 2: channel = i / 2
        p.dequant_pc_into(-4, &[0.5, 0.25], 2, &mut deq);
        assert_eq!(deq, vec![1.0, 1.0, -0.5, -0.5]);
        // single scale reproduces the scalar decode
        let mut a = Vec::new();
        let mut b = Vec::new();
        p.dequant_into(-4, 0.3, &mut a);
        p.dequant_pc_into(-4, &[0.3], 1, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn per_channel_decode_walks_scale_index_like_kernels() {
        // long payload across chunk boundaries: the incremental channel
        // walk must equal the (i / g) % ns closed form for every width
        for bits in [2u32, 3, 4, 8] {
            let mask = (1u32 << bits) - 1;
            let codes: Vec<u32> = (0..61u32).map(|i| (i * 11 + 2) & mask).collect();
            let p = Packed::pack(&codes, bits).unwrap();
            for (ns, g) in [(1usize, 1usize), (4, 1), (4, 3), (7, 2)] {
                let scales: Vec<f32> = (0..ns).map(|c| 0.1 + 0.05 * c as f32).collect();
                let mut got = Vec::new();
                p.dequant_pc_into(-4, &scales, g, &mut got);
                let want: Vec<f32> = (0..p.len)
                    .map(|i| scales[(i / g) % ns] * ((p.get(i) as i32 - 4) as f32))
                    .collect();
                assert_eq!(got, want, "bits {bits} ns {ns} g {g}");
            }
        }
    }
}
