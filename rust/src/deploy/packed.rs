//! Bit-packed integer code storage for deployed weights.
//!
//! A quantized weight tensor is a vector of grid indices `q in [n, p]`
//! with `p - n + 1 <= 2^bits` states. On disk and in serving memory the
//! indices are stored as unsigned offset codes `c = q - n` packed
//! LSB-first into a contiguous bitstream: 2x int4 per byte, 8x int1 per
//! byte, int8 one per byte, and odd widths (3/5/6/7 bit) straddling byte
//! boundaries. This is what makes the exported artifact `bits/32` the
//! size of the f32 state it came from.
//!
//! Codes are limited to 8 bits (the repo's widest grid), so one code
//! spans at most two bytes and the accessors never need more than a
//! 16-bit window.

use anyhow::Result;

/// A bit-packed vector of unsigned codes, each `bits` wide.
#[derive(Debug, Clone, PartialEq)]
pub struct Packed {
    /// bits per code, 1..=8
    pub bits: u32,
    /// number of codes
    pub len: usize,
    /// LSB-first bitstream, `ceil(len * bits / 8)` bytes
    pub bytes: Vec<u8>,
}

impl Packed {
    /// Pack `codes` (each `< 2^bits`) into a bitstream.
    pub fn pack(codes: &[u32], bits: u32) -> Result<Packed> {
        anyhow::ensure!((1..=8).contains(&bits), "packed bits {bits} outside 1..=8");
        let mask = (1u32 << bits) - 1;
        let bits_us = bits as usize;
        let mut bytes = vec![0u8; (codes.len() * bits_us + 7) / 8];
        for (i, &c) in codes.iter().enumerate() {
            anyhow::ensure!(c <= mask, "code {c} does not fit in {bits} bits");
            let bit = i * bits_us;
            let (byte, shift) = (bit / 8, bit % 8);
            bytes[byte] |= (c << shift) as u8;
            if shift + bits_us > 8 {
                bytes[byte + 1] |= (c >> (8 - shift)) as u8;
            }
        }
        Ok(Packed { bits, len: codes.len(), bytes })
    }

    /// Read the `i`-th code.
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        debug_assert!(i < self.len, "packed index {i} out of {}", self.len);
        let bits = self.bits as usize;
        let bit = i * bits;
        let (byte, shift) = (bit / 8, bit % 8);
        let lo = self.bytes[byte] as u32;
        let hi = if shift + bits > 8 { (self.bytes[byte + 1] as u32) << 8 } else { 0 };
        ((lo | hi) >> shift) & ((1u32 << self.bits) - 1)
    }

    /// All codes, unpacked.
    pub fn unpack(&self) -> Vec<u32> {
        (0..self.len).map(|i| self.get(i)).collect()
    }

    /// Decode to signed grid integers (`code + grid_n`).
    pub fn ints_into(&self, grid_n: i32, out: &mut Vec<i32>) {
        out.clear();
        out.reserve(self.len);
        for i in 0..self.len {
            out.push(self.get(i) as i32 + grid_n);
        }
    }

    /// Decode to the fake-quant weight values `scale * (code + grid_n)`.
    ///
    /// Bit-exact against `kernels::fake_quant` for weights already on the
    /// grid: the grid integer is exactly representable in f32, so the
    /// single multiply here rounds identically to the kernel's
    /// `s * clip(round(w/s), n, p)`.
    pub fn dequant_into(&self, grid_n: i32, scale: f32, out: &mut Vec<f32>) {
        self.dequant_pc_into(grid_n, std::slice::from_ref(&scale), 1, out);
    }

    /// Per-channel decode: code `i` is dequantized with the scale of its
    /// channel, `scales[(i / group) % scales.len()]` (the same layout
    /// rule as `kernels::scale_index`). With a single scale this is
    /// [`Packed::dequant_into`], and it stays bit-exact against
    /// `kernels::fake_quant_pc` for on-grid weights.
    pub fn dequant_pc_into(&self, grid_n: i32, scales: &[f32], group: usize, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.len);
        let ns = scales.len().max(1);
        let g = group.max(1);
        for i in 0..self.len {
            let s = scales[(i / g) % ns];
            out.push(s * ((self.get(i) as i32 + grid_n) as f32));
        }
    }

    /// Payload size in bytes.
    pub fn num_bytes(&self) -> usize {
        self.bytes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_widths() {
        for bits in 1..=8u32 {
            let mask = (1u32 << bits) - 1;
            let codes: Vec<u32> = (0..53u32).map(|i| (i * 7 + 3) & mask).collect();
            let p = Packed::pack(&codes, bits).unwrap();
            assert_eq!(p.len, codes.len());
            assert_eq!(p.bytes.len(), (codes.len() * bits as usize + 7) / 8);
            assert_eq!(p.unpack(), codes, "width {bits}");
        }
    }

    #[test]
    fn int4_pairs_per_byte() {
        let p = Packed::pack(&[0x3, 0xa, 0xf, 0x1], 4).unwrap();
        assert_eq!(p.bytes, vec![0xa3, 0x1f]);
        assert_eq!(p.get(1), 0xa);
        assert_eq!(p.get(3), 0x1);
    }

    #[test]
    fn three_bit_codes_straddle_bytes() {
        // 8 x 3-bit codes fill exactly 3 bytes
        let codes: Vec<u32> = vec![1, 7, 0, 5, 2, 6, 3, 4];
        let p = Packed::pack(&codes, 3).unwrap();
        assert_eq!(p.bytes.len(), 3);
        assert_eq!(p.unpack(), codes);
    }

    #[test]
    fn rejects_overflow_and_bad_width() {
        assert!(Packed::pack(&[8], 3).is_err());
        assert!(Packed::pack(&[0], 0).is_err());
        assert!(Packed::pack(&[0], 9).is_err());
    }

    #[test]
    fn signed_decode_applies_grid_offset() {
        // 3-bit signed grid [-4, 3]: codes are q + 4
        let q = [-4i32, -1, 0, 3];
        let codes: Vec<u32> = q.iter().map(|&v| (v + 4) as u32).collect();
        let p = Packed::pack(&codes, 3).unwrap();
        let mut ints = Vec::new();
        p.ints_into(-4, &mut ints);
        assert_eq!(ints, q);
        let mut deq = Vec::new();
        p.dequant_into(-4, 0.25, &mut deq);
        assert_eq!(deq, vec![-1.0, -0.25, 0.0, 0.75]);
    }

    #[test]
    fn per_channel_decode_uses_each_channels_scale() {
        // [2, 2] dense columns: channel = i % 2
        let codes = vec![6u32, 6, 2, 2]; // grid ints +2, +2, -2, -2
        let p = Packed::pack(&codes, 3).unwrap();
        let mut deq = Vec::new();
        p.dequant_pc_into(-4, &[0.5, 0.25], 1, &mut deq);
        assert_eq!(deq, vec![1.0, 0.5, -1.0, -0.5]);
        // dw rows [2, 2... use group 2: channel = i / 2
        p.dequant_pc_into(-4, &[0.5, 0.25], 2, &mut deq);
        assert_eq!(deq, vec![1.0, 1.0, -0.5, -0.5]);
        // single scale reproduces the scalar decode
        let mut a = Vec::new();
        let mut b = Vec::new();
        p.dequant_into(-4, 0.3, &mut a);
        p.dequant_pc_into(-4, &[0.3], 1, &mut b);
        assert_eq!(a, b);
    }
}
