//! CI perf-trajectory support: the deploy micro-benchmark suite and the
//! schema-versioned `BENCH_deploy.json` merge + regression gate.
//!
//! The `bench-trajectory` CI job runs the serve smoke benchmark (which
//! writes `BENCH_serve.json`) and then `bench-deploy --smoke`, which:
//!
//! 1. micro-benchmarks the packed kernels in **both decode regimes** —
//!    streaming (`packed_*`: bulk-decode the payload on every call, the
//!    pre-cache behaviour) and prepared (`prepared_*`: decode once, run
//!    the blocked kernels over cached planes) — plus a full
//!    packed-engine forward on a per-channel w4a4 export of a
//!    depth-wise zoo model in three configurations: streaming decode,
//!    prepared (decode-once), and prepared with `--threads` scoped
//!    batch-row workers — plus the same three on a
//!    **per-channel-activation** export (`engine_forward_pcact_*`, the
//!    per-channel-default configuration's exact-f32 route) — plus the
//!    **QPKG v4 spatial-depthwise** rows (`*_dw_spatial_*` kernels and
//!    `engine_forward_dw2d_w4a4{,_i32}`: the efflite_2d export on the
//!    f32-exact route and on the exact-integer path that spatial
//!    depthwise layers keep even with per-channel activation scales) —
//!    plus the
//!    HTTP request codec (`http_json_lazy` vs `http_json_tree`: the
//!    zero-copy field scan against a full `Json`-tree parse of the same
//!    predict body),
//! 2. merges the serve report — which since the HTTP front-end landed
//!    also carries the network rows (`http_keepalive_rps`,
//!    `http_churn_rps`, `http_overload_p99_ms`) — into one
//!    schema-versioned `BENCH_deploy.json` (uploaded as the per-commit
//!    artifact),
//! 3. refuses to emit a report that lost a required kernel row or, once
//!    the serve report is merged, a required serve field
//!    ([`DeployBenchReport::missing_required_rows`] — a gate hole, the
//!    CLI exits non-zero), prints the streaming→prepared and 1→N-thread
//!    speedups ([`DeployBenchReport::speedup_summary`], also appended to
//!    the CI job summary), and
//! 4. compares every throughput metric against the committed
//!    `BENCH_baseline.json` — plus the tail latencies (`serve.p95_ms`,
//!    `serve.http_overload_p99_ms`), gated in the opposite direction —
//!    and **fails the job** when any metric regresses by more than the
//!    allowed fraction (default 25%).
//!
//! The baseline file follows the `--emit-baseline` shape (throughput
//! floors ~half a smoke run, latency ceilings ~double) so runner
//! variance does not flap the gate while order-of-magnitude regressions
//! still trip it. The committed values are conservative estimates of an
//! ubuntu-latest runner's smoke numbers, not a copied measurement —
//! refresh by committing the `BENCH_baseline_suggested.json` artifact
//! of a representative CI run whenever the trajectory legitimately
//! shifts.

use super::engine::{
    dw_f32, dw_i32, dw_spatial_f32, dw_spatial_i32, matmul_f32, matmul_i32, packed_dw,
    packed_dw_i32, packed_dw_spatial, packed_dw_spatial_i32, packed_matmul, packed_matmul_i32,
    Engine, EngineOpts,
};
use super::export::{export_model, snap_and_pack_pc, ExportCfg};
use crate::bench::bench_for;
use crate::json::{self, Json};
use crate::rng::Pcg32;
use crate::runtime::native::model::zoo_model;
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Duration;

/// Version of the `BENCH_deploy.json` schema. Bump when the layout of
/// the report changes; the regression gate refuses to compare reports
/// across schema versions.
pub const SCHEMA_VERSION: u32 = 1;

/// Bench rows that must be present in every report: losing one (renamed
/// bench, dead code path) would silently blind the perf gate to the
/// decode-once engine — or, for the `pcact` rows, to the
/// per-channel-activation forward — so `bench-deploy` fails when any is
/// missing.
pub const REQUIRED_PREPARED_ROWS: &[&str] = &[
    "prepared_matmul_f32_pc",
    "prepared_matmul_i32",
    "prepared_dw_f32_pc",
    "prepared_dw_i32",
    "prepared_dw_spatial_f32_pc",
    "prepared_dw_spatial_i32",
    "engine_forward_pc_w4a4",
    "engine_forward_pc_w4a4_mt",
    "engine_forward_pcact_w4a4",
    "engine_forward_pcact_w4a4_mt",
    "engine_forward_dw2d_w4a4",
    "engine_forward_dw2d_w4a4_i32",
    "http_json_lazy",
];

/// Serve-report fields that must be present once a serve report is
/// merged: the channel-level throughput/tail rows plus the HTTP
/// front-end rows (keep-alive vs churn throughput, overload p99) and
/// the live-histogram p95 cross-check row (`hist_p95_ms`, the
/// `obs::Histogram` twin of the offline sort-based `p95_ms`).
pub const REQUIRED_SERVE_FIELDS: &[&str] = &[
    "throughput_rps",
    "p95_ms",
    "http_keepalive_rps",
    "http_churn_rps",
    "http_overload_p99_ms",
    "hist_p95_ms",
    "fleet_rps_2",
    "fleet_rps_4",
    "fleet_rps_8",
    "swap_p99_spike_ms",
    "shard_rps_2",
    "shard_restart_ms",
];

/// Serve metrics gated as throughput (higher is better, floor below).
/// The `fleet_rps_{n}` rows track aggregate ingress throughput with n
/// resident models, each behind its own worker pool.
pub const SERVE_THROUGHPUT_METRICS: &[&str] = &[
    "throughput_rps",
    "http_keepalive_rps",
    "http_churn_rps",
    "fleet_rps_2",
    "fleet_rps_4",
    "fleet_rps_8",
    "shard_rps_2",
];

/// Serve metrics gated as tail latency (lower is better, ceiling above).
/// `hist_p95_ms` gates the in-process histogram measurement alongside
/// the offline percentile so the two paths can't silently diverge;
/// `swap_p99_spike_ms` bounds the tail while hot-swaps cut over under
/// live traffic; `shard_restart_ms` bounds kill-9-to-serving-again
/// recovery of a shard child.
pub const SERVE_LATENCY_METRICS: &[&str] = &[
    "p95_ms",
    "http_overload_p99_ms",
    "hist_p95_ms",
    "swap_p99_spike_ms",
    "shard_restart_ms",
];

/// (streaming row, prepared row) pairs whose ratio is the decode-once /
/// threading speedup surfaced in the CI job summary.
const SPEEDUP_PAIRS: &[(&str, &str, &str)] = &[
    ("packed_matmul_f32_pc", "prepared_matmul_f32_pc", "matmul f32-pc decode-once"),
    ("packed_matmul_i32", "prepared_matmul_i32", "matmul i32 decode-once"),
    ("packed_dw_f32_pc", "prepared_dw_f32_pc", "dw f32-pc decode-once"),
    ("packed_dw_i32", "prepared_dw_i32", "dw i32 decode-once"),
    ("engine_forward_pc_w4a4_streaming", "engine_forward_pc_w4a4", "engine forward decode-once"),
    ("engine_forward_pc_w4a4", "engine_forward_pc_w4a4_mt", "engine forward 1 -> N threads"),
    ("http_json_tree", "http_json_lazy", "request json lazy-scan vs tree"),
    (
        "engine_forward_pcact_w4a4_streaming",
        "engine_forward_pcact_w4a4",
        "pc-act engine forward decode-once",
    ),
    (
        "engine_forward_pcact_w4a4",
        "engine_forward_pcact_w4a4_mt",
        "pc-act engine forward 1 -> N threads",
    ),
    (
        "packed_dw_spatial_f32_pc",
        "prepared_dw_spatial_f32_pc",
        "dw-spatial f32-pc decode-once",
    ),
    ("packed_dw_spatial_i32", "prepared_dw_spatial_i32", "dw-spatial i32 decode-once"),
    (
        "engine_forward_dw2d_w4a4_streaming",
        "engine_forward_dw2d_w4a4",
        "dw2d engine forward decode-once",
    ),
    (
        "engine_forward_dw2d_w4a4",
        "engine_forward_dw2d_w4a4_i32",
        "dw2d engine forward f32 -> exact-i32",
    ),
];

/// One micro-bench row.
#[derive(Debug, Clone)]
pub struct KernelBenchRow {
    pub name: String,
    /// work items per second (elements for kernels, images for the engine)
    pub per_sec: f64,
    pub mean_ns: f64,
}

/// The merged deploy benchmark report.
#[derive(Debug, Clone)]
pub struct DeployBenchReport {
    pub schema_version: u32,
    pub smoke: bool,
    pub kernels: Vec<KernelBenchRow>,
    /// the serve benchmark object (BENCH_serve.json), when merged
    pub serve: Option<Json>,
}

impl DeployBenchReport {
    pub fn to_json(&self) -> Json {
        let mut kernels = BTreeMap::new();
        for k in &self.kernels {
            let mut row = BTreeMap::new();
            row.insert("per_sec".to_string(), Json::Num(k.per_sec));
            row.insert("mean_ns".to_string(), Json::Num(k.mean_ns));
            kernels.insert(k.name.clone(), Json::Obj(row));
        }
        let mut o = BTreeMap::new();
        o.insert("schema_version".to_string(), Json::Num(self.schema_version as f64));
        o.insert("smoke".to_string(), Json::Bool(self.smoke));
        o.insert("kernels".to_string(), Json::Obj(kernels));
        if let Some(s) = &self.serve {
            o.insert("serve".to_string(), s.clone());
        }
        Json::Obj(o)
    }

    pub fn write_json(&self, path: &Path) -> Result<()> {
        std::fs::write(path, json::to_string(&self.to_json()))
            .with_context(|| format!("write {}", path.display()))?;
        Ok(())
    }

    /// Merge a parsed BENCH_serve.json object into the report.
    pub fn merge_serve(&mut self, serve: Json) {
        self.serve = Some(serve);
    }

    fn row(&self, name: &str) -> Option<&KernelBenchRow> {
        self.kernels.iter().find(|k| k.name == name)
    }

    /// Required rows absent from this report: the prepared-path /
    /// codec kernel rows ([`REQUIRED_PREPARED_ROWS`]) always, and the
    /// serve fields ([`REQUIRED_SERVE_FIELDS`], `serve.`-prefixed) once
    /// a serve report is merged. Non-empty = the perf gate lost sight
    /// of a tracked path and `bench-deploy` must fail.
    pub fn missing_required_rows(&self) -> Vec<String> {
        let mut missing: Vec<String> = REQUIRED_PREPARED_ROWS
            .iter()
            .filter(|name| self.row(name).is_none())
            .map(|s| s.to_string())
            .collect();
        if let Some(serve) = &self.serve {
            for field in REQUIRED_SERVE_FIELDS {
                if serve.get(field).as_f64().is_none() {
                    missing.push(format!("serve.{field}"));
                }
            }
        }
        missing
    }

    /// Human/CI-summary rendering of the streaming→prepared (and
    /// 1→N-thread) throughput deltas, one `old -> new (x speedup)` line
    /// per pair present in the report.
    pub fn speedup_summary(&self) -> String {
        let mut lines = Vec::new();
        for (old, new, label) in SPEEDUP_PAIRS {
            let (Some(o), Some(n)) = (self.row(old), self.row(new)) else { continue };
            if o.per_sec <= 0.0 {
                continue;
            }
            lines.push(format!(
                "{label}: {:.3e}/s -> {:.3e}/s ({:.2}x)",
                o.per_sec,
                n.per_sec,
                n.per_sec / o.per_sec
            ));
        }
        lines.join("\n")
    }
}

/// Micro-benchmark the packed deploy kernels (streaming and prepared
/// decode regimes) and the full engine forward (streaming / prepared /
/// `threads`-way prepared). `smoke` shrinks the per-bench time budget
/// for CI.
pub fn run_deploy_microbench(smoke: bool, threads: usize) -> Result<DeployBenchReport> {
    let budget = if smoke { Duration::from_millis(250) } else { Duration::from_secs(2) };
    let warmup = if smoke { 1 } else { 2 };
    // honored as given (0 -> 1): the _mt row measures exactly the thread
    // count the caller asked for, degenerating to a 1-thread re-run
    let threads = threads.max(1);
    let mut rng = Pcg32::new(42, 0xbe);
    let mut rows: Vec<KernelBenchRow> = Vec::new();
    let mut push = |name: &str, per_iter_items: f64, stats: crate::bench::BenchStats| {
        rows.push(KernelBenchRow {
            name: name.to_string(),
            per_sec: stats.per_sec(per_iter_items),
            mean_ns: stats.mean.as_secs_f64() * 1e9,
        });
    };

    // --- packed matmul, per-channel scales (the stem geometry) ---------
    let (m, k, n) = (16usize, 768, 48);
    let scales: Vec<f32> = (0..n).map(|_| rng.uniform(0.01, 0.3)).collect();
    let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.3).collect();
    let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let (packed, grid_n) = snap_and_pack_pc(&w, &scales, 1, 4)?;
    let items = (m * k * n) as f64;
    let s = bench_for("packed_matmul_f32_pc", warmup, budget, || {
        std::hint::black_box(packed_matmul(&x, &packed, m, k, n, &scales, grid_n));
    });
    push("packed_matmul_f32_pc", items, s);
    // prepared: the decode happens once, outside the timed region
    let mut wq = Vec::new();
    packed.dequant_pc_into(grid_n, &scales, 1, &mut wq);
    let mut out_f = vec![0.0f32; m * n];
    let s = bench_for("prepared_matmul_f32_pc", warmup, budget, || {
        matmul_f32(&x, &wq, m, k, n, &mut out_f);
        std::hint::black_box(&out_f);
    });
    push("prepared_matmul_f32_pc", items, s);
    let qa: Vec<i32> = (0..m * k).map(|_| rng.below(15) as i32).collect();
    let s = bench_for("packed_matmul_i32", warmup, budget, || {
        std::hint::black_box(packed_matmul_i32(&qa, &packed, m, k, n, grid_n));
    });
    push("packed_matmul_i32", items, s);
    let mut wi = Vec::new();
    packed.ints_into(grid_n, &mut wi);
    let mut out_i = vec![0i32; m * n];
    let s = bench_for("prepared_matmul_i32", warmup, budget, || {
        matmul_i32(&qa, &wi, m, k, n, &mut out_i);
        std::hint::black_box(&out_i);
    });
    push("prepared_matmul_i32", items, s);

    // --- packed depthwise, per-channel scales --------------------------
    let (b, c) = (16usize, 256);
    let dw_scales: Vec<f32> = (0..c).map(|_| rng.uniform(0.01, 0.3)).collect();
    let wd: Vec<f32> = (0..c * 3).map(|_| rng.normal() * 0.3).collect();
    let xd: Vec<f32> = (0..b * c).map(|_| rng.normal()).collect();
    let (packed_d, grid_nd) = snap_and_pack_pc(&wd, &dw_scales, 3, 4)?;
    let items = (b * c * 3) as f64;
    let s = bench_for("packed_dw_f32_pc", warmup, budget, || {
        std::hint::black_box(packed_dw(&xd, &packed_d, b, c, &dw_scales, grid_nd));
    });
    push("packed_dw_f32_pc", items, s);
    let mut wqd = Vec::new();
    packed_d.dequant_pc_into(grid_nd, &dw_scales, 3, &mut wqd);
    let mut out_fd = vec![0.0f32; b * c];
    let s = bench_for("prepared_dw_f32_pc", warmup, budget, || {
        dw_f32(&xd, &wqd, b, c, &mut out_fd);
        std::hint::black_box(&out_fd);
    });
    push("prepared_dw_f32_pc", items, s);
    let qad: Vec<i32> = (0..b * c).map(|_| rng.below(15) as i32).collect();
    let s = bench_for("packed_dw_i32", warmup, budget, || {
        std::hint::black_box(packed_dw_i32(&qad, &packed_d, b, c, grid_nd));
    });
    push("packed_dw_i32", items, s);
    let mut wid = Vec::new();
    packed_d.ints_into(grid_nd, &mut wid);
    let mut out_id = vec![0i32; b * c];
    let s = bench_for("prepared_dw_i32", warmup, budget, || {
        dw_i32(&qad, &wid, b, c, &mut out_id);
        std::hint::black_box(&out_id);
    });
    push("prepared_dw_i32", items, s);

    // --- packed spatial depthwise 3x3, per-channel scales (QPKG v4) ----
    // a MobileNet-ish block shape: 8x8 spatial, 32 channels, same-pad
    let (bs, hw_s, c_s, stride_s, pad_s) = (16usize, 8usize, 32usize, 1usize, 1usize);
    let hw_so = (hw_s + 2 * pad_s - 3) / stride_s + 1;
    let sp_scales: Vec<f32> = (0..c_s).map(|_| rng.uniform(0.01, 0.3)).collect();
    let ws: Vec<f32> = (0..c_s * 9).map(|_| rng.normal() * 0.3).collect();
    let xs: Vec<f32> = (0..bs * hw_s * hw_s * c_s).map(|_| rng.normal()).collect();
    let (packed_s, grid_ns) = snap_and_pack_pc(&ws, &sp_scales, 9, 4)?;
    let items = (bs * hw_so * hw_so * c_s * 9) as f64;
    let s = bench_for("packed_dw_spatial_f32_pc", warmup, budget, || {
        std::hint::black_box(packed_dw_spatial(
            &xs, &packed_s, bs, hw_s, c_s, stride_s, pad_s, &sp_scales, grid_ns,
        ));
    });
    push("packed_dw_spatial_f32_pc", items, s);
    let mut wqs = Vec::new();
    packed_s.dequant_pc_into(grid_ns, &sp_scales, 9, &mut wqs);
    let mut out_fs = vec![0.0f32; bs * hw_so * hw_so * c_s];
    let s = bench_for("prepared_dw_spatial_f32_pc", warmup, budget, || {
        dw_spatial_f32(&xs, &wqs, bs, hw_s, c_s, stride_s, pad_s, &mut out_fs);
        std::hint::black_box(&out_fs);
    });
    push("prepared_dw_spatial_f32_pc", items, s);
    let qas: Vec<i32> = (0..bs * hw_s * hw_s * c_s).map(|_| rng.below(15) as i32).collect();
    let s = bench_for("packed_dw_spatial_i32", warmup, budget, || {
        std::hint::black_box(packed_dw_spatial_i32(
            &qas, &packed_s, bs, hw_s, c_s, stride_s, pad_s, grid_ns,
        ));
    });
    push("packed_dw_spatial_i32", items, s);
    let mut wis = Vec::new();
    packed_s.ints_into(grid_ns, &mut wis);
    let mut out_is = vec![0i32; bs * hw_so * hw_so * c_s];
    let s = bench_for("prepared_dw_spatial_i32", warmup, budget, || {
        dw_spatial_i32(&qas, &wis, bs, hw_s, c_s, stride_s, pad_s, &mut out_is);
        std::hint::black_box(&out_is);
    });
    push("prepared_dw_spatial_i32", items, s);

    // --- full engine forward on a per-channel w4a4 depth-wise export ---
    let nm = zoo_model("efflite").context("efflite in the zoo")?;
    let mut state = nm.initial_state();
    for l in &nm.layers {
        let sc: Vec<f32> = (0..l.d_out).map(|_| rng.uniform(0.02, 0.2)).collect();
        state.insert(format!("params/{}.s", l.name), Tensor::new(vec![l.d_out], sc));
    }
    let (dm, _) = export_model(&nm, &state, &ExportCfg { bits_w: 4, bits_a: 4, quant_a: true })?;
    let batch = 16usize;
    let d_in = dm.d_in();
    let xe: Vec<f32> = (0..batch * d_in).map(|_| rng.normal().abs()).collect();
    for (row, opts) in [
        (
            "engine_forward_pc_w4a4_streaming",
            EngineOpts { prepared: false, ..Default::default() },
        ),
        ("engine_forward_pc_w4a4", EngineOpts::default()),
        ("engine_forward_pc_w4a4_mt", EngineOpts { threads, ..Default::default() }),
    ] {
        let eng = Engine::with_opts(dm.clone(), true, opts);
        let s = bench_for(row, warmup, budget, || {
            std::hint::black_box(eng.forward_batch(&xe, batch).expect("engine fwd"));
        });
        push(row, batch as f64, s);
    }

    // --- engine forward with per-channel activation scales ---
    // the same export with [d_in] activation-scale vectors on every
    // quantized-activation site: these dense/1-D layers run the exact
    // f32 route (no per-output-channel integer requant exists for
    // them), so this row tracks the per-channel default's serving cost
    for l in &nm.layers {
        if l.aq {
            let sa: Vec<f32> = (0..l.d_in).map(|_| rng.uniform(0.02, 0.2)).collect();
            state.insert(format!("params/{}.as", l.name), Tensor::new(vec![l.d_in], sa));
        }
    }
    let (dm_pcact, _) =
        export_model(&nm, &state, &ExportCfg { bits_w: 4, bits_a: 4, quant_a: true })?;
    for (row, opts) in [
        (
            "engine_forward_pcact_w4a4_streaming",
            EngineOpts { prepared: false, ..Default::default() },
        ),
        ("engine_forward_pcact_w4a4", EngineOpts::default()),
        ("engine_forward_pcact_w4a4_mt", EngineOpts { threads, ..Default::default() }),
    ] {
        let eng = Engine::with_opts(dm_pcact.clone(), true, opts);
        let s = bench_for(row, warmup, budget, || {
            std::hint::black_box(eng.forward_batch(&xe, batch).expect("engine fwd pcact"));
        });
        push(row, batch as f64, s);
    }

    // --- engine forward on a spatial-depthwise export (QPKG v4) -------
    // efflite_2d with per-channel weight AND activation scales: the
    // `engine_forward_dw2d_w4a4` row runs the f32-exact route, the
    // `_i32` row the composed-requant exact-integer path that spatial
    // depthwise layers keep even under per-channel activation grids
    let nm2d = zoo_model("efflite_2d").context("efflite_2d in the zoo")?;
    let mut state2d = nm2d.initial_state();
    for l in &nm2d.layers {
        let wc = l.w_channels();
        let sc: Vec<f32> = (0..wc).map(|_| rng.uniform(0.02, 0.2)).collect();
        state2d.insert(format!("params/{}.s", l.name), Tensor::new(vec![wc], sc));
        if l.aq {
            let ac = l.act_channels();
            let sa: Vec<f32> = (0..ac).map(|_| rng.uniform(0.02, 0.2)).collect();
            state2d.insert(format!("params/{}.as", l.name), Tensor::new(vec![ac], sa));
        }
    }
    let (dm_2d, _) =
        export_model(&nm2d, &state2d, &ExportCfg { bits_w: 4, bits_a: 4, quant_a: true })?;
    let d_in2d = dm_2d.d_in();
    let xe2d: Vec<f32> = (0..batch * d_in2d).map(|_| rng.normal().abs()).collect();
    for (row, int_accum, opts) in [
        (
            "engine_forward_dw2d_w4a4_streaming",
            false,
            EngineOpts { prepared: false, ..Default::default() },
        ),
        ("engine_forward_dw2d_w4a4", false, EngineOpts::default()),
        ("engine_forward_dw2d_w4a4_i32", true, EngineOpts::default()),
    ] {
        let eng = Engine::with_opts(dm_2d.clone(), int_accum, opts);
        let s = bench_for(row, warmup, budget, || {
            std::hint::black_box(eng.forward_batch(&xe2d, batch).expect("engine fwd dw2d"));
        });
        push(row, batch as f64, s);
    }

    // --- HTTP request codec: lazy field scan vs full tree parse --------
    // a realistic predict body: stem-width input array plus the small
    // fields the server actually reads, and one it skips over
    let d_req = 768usize;
    let req_input: Vec<f32> = (0..d_req).map(|_| rng.normal()).collect();
    let mut body = String::from(
        "{\"model\":\"efflite_w4a4\",\"deadline_ms\":40,\
         \"meta\":{\"client\":\"bench\",\"tags\":[1,2,3]},\"input\":[",
    );
    for (i, v) in req_input.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&format!("{v}"));
    }
    body.push_str("]}");
    let body_bytes = body.as_bytes().to_vec();
    let s = bench_for("http_json_lazy", warmup, budget, || {
        let x = super::serve::http::lazy_f32s(&body_bytes, "input")
            .expect("lazy scan")
            .expect("input present");
        let m = super::serve::http::lazy_str(&body_bytes, "model")
            .expect("lazy scan")
            .expect("model present");
        std::hint::black_box((x, m));
    });
    push("http_json_lazy", 1.0, s);
    let s = bench_for("http_json_tree", warmup, budget, || {
        let j = json::parse(&body).expect("tree parse");
        let x: Vec<f32> = j
            .get("input")
            .as_arr()
            .expect("input array")
            .iter()
            .map(|v| v.as_f64().expect("number") as f32)
            .collect();
        let m = j.get("model").as_str().expect("model").to_string();
        std::hint::black_box((x, m));
    });
    push("http_json_tree", 1.0, s);

    Ok(DeployBenchReport { schema_version: SCHEMA_VERSION, smoke, kernels: rows, serve: None })
}

/// Build a conservative committed-baseline candidate from a measured
/// report: every throughput metric floored at `floor_frac` of the
/// measured value, every tail-latency metric ceilinged at `ceil_mult`
/// of it. `bench-deploy --emit-baseline` writes this next to the run's
/// `BENCH_deploy.json` so refreshing `BENCH_baseline.json` is a
/// copy-after-eyeballing instead of hand-derived arithmetic.
pub fn baseline_from_report(report: &Json, floor_frac: f64, ceil_mult: f64) -> Json {
    let mut o = BTreeMap::new();
    o.insert("schema_version".to_string(), report.get("schema_version").clone());
    o.insert("smoke".to_string(), report.get("smoke").clone());
    let mut kernels = BTreeMap::new();
    if let Some(ks) = report.get("kernels").as_obj() {
        for (name, row) in ks {
            if let Some(per_sec) = row.get("per_sec").as_f64() {
                let mut r = BTreeMap::new();
                r.insert("per_sec".to_string(), Json::Num(per_sec * floor_frac));
                kernels.insert(name.clone(), Json::Obj(r));
            }
        }
    }
    o.insert("kernels".to_string(), Json::Obj(kernels));
    if report.get("serve").as_obj().is_some() {
        let mut s = BTreeMap::new();
        for m in SERVE_THROUGHPUT_METRICS {
            if let Some(v) = report.get("serve").get(m).as_f64() {
                s.insert(m.to_string(), Json::Num(v * floor_frac));
            }
        }
        for m in SERVE_LATENCY_METRICS {
            if let Some(v) = report.get("serve").get(m).as_f64() {
                s.insert(m.to_string(), Json::Num(v * ceil_mult));
            }
        }
        o.insert("serve".to_string(), Json::Obj(s));
    }
    Json::Obj(o)
}

/// Compare a current report against a baseline: every throughput metric
/// present in **both** (each `kernels.<name>.per_sec`, plus the
/// [`SERVE_THROUGHPUT_METRICS`]) must be at least `(1 - max_drop)` of
/// the baseline value, and the tail latencies
/// ([`SERVE_LATENCY_METRICS`], lower is better) must not exceed
/// `(1 + max_drop)` of theirs. Returns the list of violations (empty =
/// pass); bails when the schema versions differ (the numbers would not
/// be comparable).
pub fn check_regression(current: &Json, baseline: &Json, max_drop: f64) -> Result<Vec<String>> {
    let cur_v = current.get("schema_version").as_f64().unwrap_or(-1.0);
    let base_v = baseline.get("schema_version").as_f64().unwrap_or(-1.0);
    anyhow::ensure!(
        cur_v == base_v,
        "schema version mismatch: current {cur_v} vs baseline {base_v} — refresh the baseline"
    );
    let floor = 1.0 - max_drop;
    let mut violations = Vec::new();
    let mut check = |metric: &str, cur: Option<f64>, base: Option<f64>| {
        let Some(base) = base.filter(|&b| b > 0.0) else { return };
        // a baselined metric the current report stopped emitting is a
        // gate hole (renamed/dropped bench row), not a pass
        let Some(cur) = cur else {
            violations.push(format!(
                "{metric}: present in the baseline but missing from the current report — \
                 rename the baseline entry or restore the bench row"
            ));
            return;
        };
        if cur < base * floor {
            violations.push(format!(
                "{metric}: {cur:.1}/s is {:.0}% of baseline {base:.1}/s (floor {:.0}%)",
                100.0 * cur / base,
                100.0 * floor
            ));
        }
    };
    if let Some(base_kernels) = baseline.get("kernels").as_obj() {
        for (name, base_row) in base_kernels {
            check(
                &format!("kernels.{name}.per_sec"),
                current.get("kernels").get(name).get("per_sec").as_f64(),
                base_row.get("per_sec").as_f64(),
            );
        }
    }
    for metric in SERVE_THROUGHPUT_METRICS {
        check(
            &format!("serve.{metric}"),
            current.get("serve").get(metric).as_f64(),
            baseline.get("serve").get(metric).as_f64(),
        );
    }
    // tail latencies gate in the opposite direction: lower is better, so
    // the current value must stay under (1 + max_drop) x baseline
    let ceiling = 1.0 + max_drop;
    for metric in SERVE_LATENCY_METRICS {
        let Some(base) = baseline.get("serve").get(metric).as_f64().filter(|&b| b > 0.0)
        else {
            continue;
        };
        match current.get("serve").get(metric).as_f64() {
            None => violations.push(format!(
                "serve.{metric}: present in the baseline but missing from the current report — \
                 rename the baseline entry or restore the latency percentiles"
            )),
            Some(cur) if cur > base * ceiling => violations.push(format!(
                "serve.{metric}: {cur:.2}ms is {:.0}% of baseline {base:.2}ms \
                 (tail-latency ceiling {:.0}%)",
                100.0 * cur / base,
                100.0 * ceiling
            )),
            Some(_) => {}
        }
    }
    Ok(violations)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_json(mm_per_sec: f64, rps: Option<f64>, schema: f64) -> Json {
        let mut kernels = BTreeMap::new();
        let mut row = BTreeMap::new();
        row.insert("per_sec".to_string(), Json::Num(mm_per_sec));
        row.insert("mean_ns".to_string(), Json::Num(1000.0));
        kernels.insert("packed_matmul_f32_pc".to_string(), Json::Obj(row));
        let mut o = BTreeMap::new();
        o.insert("schema_version".to_string(), Json::Num(schema));
        o.insert("smoke".to_string(), Json::Bool(true));
        o.insert("kernels".to_string(), Json::Obj(kernels));
        if let Some(rps) = rps {
            let mut s = BTreeMap::new();
            s.insert("throughput_rps".to_string(), Json::Num(rps));
            o.insert("serve".to_string(), Json::Obj(s));
        }
        Json::Obj(o)
    }

    fn with_p95(mut j: Json, p95: f64) -> Json {
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Obj(s)) = o.get_mut("serve") {
                s.insert("p95_ms".to_string(), Json::Num(p95));
            }
        }
        j
    }

    #[test]
    fn regression_gate_trips_only_past_the_floor() {
        let base = report_json(1000.0, Some(200.0), 1.0);
        // 80% of baseline is within a 25% allowance
        let ok = report_json(800.0, Some(160.0), 1.0);
        assert!(check_regression(&ok, &base, 0.25).unwrap().is_empty());
        // 60% trips both metrics
        let bad = report_json(600.0, Some(120.0), 1.0);
        let v = check_regression(&bad, &base, 0.25).unwrap();
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v[0].contains("packed_matmul_f32_pc"));
        // metrics absent from the baseline are not compared
        let base_no_serve = report_json(1000.0, None, 1.0);
        let v = check_regression(&bad, &base_no_serve, 0.25).unwrap();
        assert_eq!(v.len(), 1);
        // ... but a baselined metric missing from the CURRENT report is a
        // gate hole and counts as a violation
        let cur_no_serve = report_json(900.0, None, 1.0);
        let v = check_regression(&cur_no_serve, &base, 0.25).unwrap();
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("missing from the current report"), "{v:?}");
        // schema mismatch refuses to compare at all
        assert!(check_regression(&ok, &report_json(1000.0, None, 2.0), 0.25).is_err());
    }

    #[test]
    fn tail_latency_gate_is_inverted() {
        let base = with_p95(report_json(1000.0, Some(200.0), 1.0), 10.0);
        // faster tail: fine
        let ok = with_p95(report_json(1000.0, Some(200.0), 1.0), 8.0);
        assert!(check_regression(&ok, &base, 0.25).unwrap().is_empty());
        // 20% slower tail is inside the 25% ceiling
        let ok = with_p95(report_json(1000.0, Some(200.0), 1.0), 12.0);
        assert!(check_regression(&ok, &base, 0.25).unwrap().is_empty());
        // 50% slower tail trips the gate
        let bad = with_p95(report_json(1000.0, Some(200.0), 1.0), 15.0);
        let v = check_regression(&bad, &base, 0.25).unwrap();
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("p95_ms"), "{v:?}");
        // dropping the percentile from the current report is a gate hole
        let cur_no_p95 = report_json(1000.0, Some(200.0), 1.0);
        let v = check_regression(&cur_no_p95, &base, 0.25).unwrap();
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("p95_ms") && v[0].contains("missing"), "{v:?}");
        // a current report with p95 vs a baseline without is not compared
        let base_no_p95 = report_json(1000.0, Some(200.0), 1.0);
        let cur = with_p95(report_json(1000.0, Some(200.0), 1.0), 99.0);
        assert!(check_regression(&cur, &base_no_p95, 0.25).unwrap().is_empty());
    }

    #[test]
    fn report_merges_serve_and_roundtrips_json() {
        let mut r = DeployBenchReport {
            schema_version: SCHEMA_VERSION,
            smoke: true,
            kernels: vec![KernelBenchRow {
                name: "packed_matmul_f32_pc".into(),
                per_sec: 123.0,
                mean_ns: 456.0,
            }],
            serve: None,
        };
        let mut s = BTreeMap::new();
        s.insert("throughput_rps".to_string(), Json::Num(99.0));
        r.merge_serve(Json::Obj(s));
        let j = r.to_json();
        let parsed = json::parse(&json::to_string(&j)).unwrap();
        assert_eq!(parsed, j);
        assert_eq!(parsed.get("schema_version").as_usize(), Some(SCHEMA_VERSION as usize));
        assert_eq!(parsed.get("serve").get("throughput_rps").as_f64(), Some(99.0));
        assert_eq!(
            parsed.get("kernels").get("packed_matmul_f32_pc").get("per_sec").as_f64(),
            Some(123.0)
        );
    }

    #[test]
    fn required_rows_and_speedup_summary() {
        let mk = |name: &str, per_sec: f64| KernelBenchRow {
            name: name.into(),
            per_sec,
            mean_ns: 1.0,
        };
        let mut r = DeployBenchReport {
            schema_version: SCHEMA_VERSION,
            smoke: true,
            kernels: vec![mk("packed_matmul_f32_pc", 100.0)],
            serve: None,
        };
        // all prepared rows missing
        assert_eq!(r.missing_required_rows().len(), REQUIRED_PREPARED_ROWS.len());
        for name in REQUIRED_PREPARED_ROWS {
            r.kernels.push(mk(name, 400.0));
        }
        assert!(r.missing_required_rows().is_empty());
        // the summary reports the 4x streaming -> prepared delta
        let s = r.speedup_summary();
        assert!(s.contains("matmul f32-pc decode-once"), "{s}");
        assert!(s.contains("4.00x"), "{s}");
    }

    #[test]
    fn microbench_smoke_produces_all_rows() {
        let r = run_deploy_microbench(true, 2).unwrap();
        assert_eq!(r.schema_version, SCHEMA_VERSION);
        assert!(r.smoke);
        let names: Vec<&str> = r.kernels.iter().map(|k| k.name.as_str()).collect();
        for want in [
            "packed_matmul_f32_pc",
            "packed_matmul_i32",
            "packed_dw_f32_pc",
            "packed_dw_i32",
            "packed_dw_spatial_f32_pc",
            "packed_dw_spatial_i32",
            "prepared_matmul_f32_pc",
            "prepared_matmul_i32",
            "prepared_dw_f32_pc",
            "prepared_dw_i32",
            "prepared_dw_spatial_f32_pc",
            "prepared_dw_spatial_i32",
            "engine_forward_pc_w4a4_streaming",
            "engine_forward_pc_w4a4",
            "engine_forward_pc_w4a4_mt",
            "engine_forward_pcact_w4a4_streaming",
            "engine_forward_pcact_w4a4",
            "engine_forward_pcact_w4a4_mt",
            "engine_forward_dw2d_w4a4_streaming",
            "engine_forward_dw2d_w4a4",
            "engine_forward_dw2d_w4a4_i32",
            "http_json_lazy",
            "http_json_tree",
        ] {
            assert!(names.contains(&want), "missing {want} in {names:?}");
        }
        for k in &r.kernels {
            assert!(k.per_sec > 0.0 && k.mean_ns > 0.0, "{k:?}");
        }
        assert!(r.missing_required_rows().is_empty());
        assert!(!r.speedup_summary().is_empty());
    }

    #[test]
    fn merged_serve_report_must_carry_http_rows() {
        let mk = |name: &str| KernelBenchRow { name: name.into(), per_sec: 1.0, mean_ns: 1.0 };
        let mut r = DeployBenchReport {
            schema_version: SCHEMA_VERSION,
            smoke: true,
            kernels: REQUIRED_PREPARED_ROWS.iter().map(|n| mk(n)).collect(),
            serve: None,
        };
        // without a merged serve report, only the kernel rows are checked
        assert!(r.missing_required_rows().is_empty());
        // a serve report missing the HTTP rows is a gate hole
        let mut s = BTreeMap::new();
        s.insert("throughput_rps".to_string(), Json::Num(100.0));
        s.insert("p95_ms".to_string(), Json::Num(4.0));
        r.merge_serve(Json::Obj(s.clone()));
        let missing = r.missing_required_rows();
        assert_eq!(
            missing,
            vec![
                "serve.http_keepalive_rps".to_string(),
                "serve.http_churn_rps".to_string(),
                "serve.http_overload_p99_ms".to_string(),
                "serve.hist_p95_ms".to_string(),
                "serve.fleet_rps_2".to_string(),
                "serve.fleet_rps_4".to_string(),
                "serve.fleet_rps_8".to_string(),
                "serve.swap_p99_spike_ms".to_string(),
                "serve.shard_rps_2".to_string(),
                "serve.shard_restart_ms".to_string(),
            ],
            "{missing:?}"
        );
        // with all required fields the report passes
        s.insert("http_keepalive_rps".to_string(), Json::Num(50.0));
        s.insert("http_churn_rps".to_string(), Json::Num(20.0));
        s.insert("http_overload_p99_ms".to_string(), Json::Num(100.0));
        s.insert("hist_p95_ms".to_string(), Json::Num(4.2));
        s.insert("fleet_rps_2".to_string(), Json::Num(80.0));
        s.insert("fleet_rps_4".to_string(), Json::Num(70.0));
        s.insert("fleet_rps_8".to_string(), Json::Num(60.0));
        s.insert("swap_p99_spike_ms".to_string(), Json::Num(25.0));
        s.insert("shard_rps_2".to_string(), Json::Num(40.0));
        s.insert("shard_restart_ms".to_string(), Json::Num(800.0));
        r.merge_serve(Json::Obj(s));
        assert!(r.missing_required_rows().is_empty());
    }

    #[test]
    fn http_serve_metrics_gate_in_both_directions() {
        let serve = |ka: f64, p99: f64| {
            let mut s = BTreeMap::new();
            s.insert("http_keepalive_rps".to_string(), Json::Num(ka));
            s.insert("http_overload_p99_ms".to_string(), Json::Num(p99));
            let mut o = BTreeMap::new();
            o.insert("schema_version".to_string(), Json::Num(1.0));
            o.insert("serve".to_string(), Json::Obj(s));
            Json::Obj(o)
        };
        let base = serve(100.0, 100.0);
        assert!(check_regression(&serve(90.0, 110.0), &base, 0.25).unwrap().is_empty());
        // keep-alive throughput below the floor trips
        let v = check_regression(&serve(50.0, 100.0), &base, 0.25).unwrap();
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("http_keepalive_rps"), "{v:?}");
        // overload p99 above the ceiling trips (inverted gate)
        let v = check_regression(&serve(100.0, 200.0), &base, 0.25).unwrap();
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("http_overload_p99_ms"), "{v:?}");
    }

    #[test]
    fn baseline_from_report_applies_margins() {
        let mut kernels = BTreeMap::new();
        let mut row = BTreeMap::new();
        row.insert("per_sec".to_string(), Json::Num(1000.0));
        row.insert("mean_ns".to_string(), Json::Num(5.0));
        kernels.insert("http_json_lazy".to_string(), Json::Obj(row));
        let mut s = BTreeMap::new();
        s.insert("throughput_rps".to_string(), Json::Num(200.0));
        s.insert("p95_ms".to_string(), Json::Num(10.0));
        s.insert("http_overload_p99_ms".to_string(), Json::Num(50.0));
        s.insert("preds_are_not_metrics".to_string(), Json::Str("x".into()));
        let mut o = BTreeMap::new();
        o.insert("schema_version".to_string(), Json::Num(1.0));
        o.insert("smoke".to_string(), Json::Bool(true));
        o.insert("kernels".to_string(), Json::Obj(kernels));
        o.insert("serve".to_string(), Json::Obj(s));
        let report = Json::Obj(o);

        let b = baseline_from_report(&report, 0.5, 2.0);
        assert_eq!(b.get("schema_version").as_f64(), Some(1.0));
        assert_eq!(
            b.get("kernels").get("http_json_lazy").get("per_sec").as_f64(),
            Some(500.0),
            "throughput floor = 0.5x measured"
        );
        // mean_ns is not a gated metric and is not carried over
        assert_eq!(b.get("kernels").get("http_json_lazy").get("mean_ns").as_f64(), None);
        assert_eq!(b.get("serve").get("throughput_rps").as_f64(), Some(100.0));
        assert_eq!(
            b.get("serve").get("p95_ms").as_f64(),
            Some(20.0),
            "latency ceiling = 2x measured"
        );
        assert_eq!(b.get("serve").get("http_overload_p99_ms").as_f64(), Some(100.0));
        assert_eq!(b.get("serve").get("preds_are_not_metrics"), &Json::Null);
        // the emitted baseline passes the gate against its own report
        assert!(check_regression(&report, &b, 0.25).unwrap().is_empty());
    }
}
