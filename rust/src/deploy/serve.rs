//! Batched serving front-end over the packed inference engine.
//!
//! Architecture (std channels + threads, no external deps):
//!
//! ```text
//! submit() --> ingress (bounded sync_channel, backpressure)
//!                 |
//!              batcher thread: drains up to max_batch queued requests
//!                 |            into one dynamic batch
//!              dispatch channel
//!                 |
//!              worker pool (N threads, shared Mutex<Receiver>):
//!                 concatenate inputs -> Engine::forward_batch -> one
//!                 Response per request through its own channel
//! ```
//!
//! The engine decodes each packed payload exactly once at load time
//! (`DeployModel::prepare`); every worker clones one `Arc<Engine>` whose
//! shared `PreparedModel` planes serve all requests, so no request — and
//! no batch — ever re-decodes weights. Dynamic batching then amortizes
//! the remaining per-call overhead (activation quantization, dispatch)
//! and keeps the blocked kernels fed with multi-row batches, so
//! throughput grows with queue pressure while lightly loaded requests
//! still see single-digit-batch latency.
//!
//! [`bench_serve`] drives a full open-loop benchmark and renders the
//! `BENCH_serve.json` report the CI perf trajectory tracks — including
//! per-request latency percentiles (p50/p95/p99/max and the mean), so
//! perf PRs can gate on tail latency rather than throughput alone.

use super::engine::{argmax, Engine};
use crate::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Serving knobs.
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// inference worker threads
    pub workers: usize,
    /// largest dynamic batch one worker runs
    pub max_batch: usize,
    /// ingress queue capacity (submit blocks when full — backpressure)
    pub queue_cap: usize,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg { workers: 4, max_batch: 16, queue_cap: 1024 }
    }
}

/// One served prediction.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub pred: usize,
    pub logits: Vec<f32>,
    /// submit-to-response wall time
    pub latency: Duration,
    /// size of the dynamic batch this request rode in
    pub batch_size: usize,
}

struct Job {
    id: u64,
    x: Vec<f32>,
    t0: Instant,
    tx: mpsc::Sender<Response>,
}

/// Shared serving counters.
#[derive(Debug, Default)]
pub struct ServeStats {
    pub batches: AtomicU64,
    pub requests: AtomicU64,
    /// requests whose batch failed in the engine (their responses never
    /// arrive — clients observe the closed channel)
    pub failed: AtomicU64,
    /// most recent engine failure (jobs of a failed batch are dropped,
    /// which closes their response channels; the cause is kept here)
    pub last_error: Mutex<Option<String>>,
}

/// A running server: batcher + worker pool around one shared engine.
pub struct Server {
    ingress: mpsc::SyncSender<Job>,
    batcher: JoinHandle<()>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<ServeStats>,
    next_id: AtomicU64,
    d_in: usize,
}

impl Server {
    /// Spawn the batcher and worker threads.
    pub fn start(engine: Arc<Engine>, cfg: &ServeCfg) -> Server {
        let d_in = engine.model().d_in();
        let num_classes = engine.model().num_classes;
        let max_batch = cfg.max_batch.max(1);
        let n_workers = cfg.workers.max(1);
        let stats = Arc::new(ServeStats::default());

        let (in_tx, in_rx) = mpsc::sync_channel::<Job>(cfg.queue_cap.max(1));
        let (disp_tx, disp_rx) = mpsc::sync_channel::<Vec<Job>>(n_workers * 2);

        let batcher_stats = stats.clone();
        let batcher = std::thread::spawn(move || {
            while let Ok(first) = in_rx.recv() {
                let mut batch = vec![first];
                while batch.len() < max_batch {
                    match in_rx.try_recv() {
                        Ok(job) => batch.push(job),
                        Err(_) => break,
                    }
                }
                batcher_stats.batches.fetch_add(1, Ordering::Relaxed);
                if disp_tx.send(batch).is_err() {
                    return; // workers gone
                }
            }
            // ingress closed: disp_tx drops here and the workers drain out
        });

        let disp_rx = Arc::new(Mutex::new(disp_rx));
        let workers = (0..n_workers)
            .map(|_| {
                let rx = disp_rx.clone();
                let eng = engine.clone();
                let st = stats.clone();
                std::thread::spawn(move || loop {
                    let got = rx.lock().expect("dispatch lock").recv();
                    let Ok(jobs) = got else { return };
                    let b = jobs.len();
                    let mut x = Vec::with_capacity(b * d_in);
                    for j in &jobs {
                        x.extend_from_slice(&j.x);
                    }
                    match eng.forward_batch(&x, b) {
                        Ok(logits) => {
                            for (i, job) in jobs.into_iter().enumerate() {
                                let row = &logits[i * num_classes..(i + 1) * num_classes];
                                let resp = Response {
                                    id: job.id,
                                    pred: argmax(row),
                                    logits: row.to_vec(),
                                    latency: job.t0.elapsed(),
                                    batch_size: b,
                                };
                                st.requests.fetch_add(1, Ordering::Relaxed);
                                let _ = job.tx.send(resp);
                            }
                        }
                        Err(e) => {
                            // dropping the jobs closes their response
                            // channels; clients observe the failure and
                            // the cause + count are preserved so the
                            // front-end can fail loudly (non-zero exit)
                            eprintln!("[serve] batch of {b} failed: {e}");
                            st.failed.fetch_add(b as u64, Ordering::Relaxed);
                            *st.last_error.lock().expect("stats lock") = Some(e.to_string());
                        }
                    }
                })
            })
            .collect();

        Server {
            ingress: in_tx,
            batcher,
            workers,
            stats,
            next_id: AtomicU64::new(0),
            d_in,
        }
    }

    /// Enqueue one request; the returned channel yields its [`Response`].
    /// Blocks when the ingress queue is full (backpressure).
    pub fn submit(&self, x: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        anyhow::ensure!(
            x.len() == self.d_in,
            "serve: request has {} features, model wants {}",
            x.len(),
            self.d_in
        );
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.ingress
            .send(Job { id, x, t0: Instant::now(), tx })
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        Ok(rx)
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Drain and stop: closes the ingress, joins the batcher and every
    /// worker, and returns (batches, requests) served.
    pub fn shutdown(self) -> (u64, u64) {
        let Server { ingress, batcher, workers, stats, .. } = self;
        drop(ingress);
        let _ = batcher.join();
        for w in workers {
            let _ = w.join();
        }
        (stats.batches.load(Ordering::Relaxed), stats.requests.load(Ordering::Relaxed))
    }
}

/// One serving benchmark result (rendered into BENCH_serve.json).
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub model: String,
    pub backend_mode: String,
    pub requests: usize,
    pub workers: usize,
    pub max_batch: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
    pub mean_batch: f64,
    pub batches: u64,
    /// per-request top-1 predictions, submit order
    pub preds: Vec<usize>,
}

impl ServeReport {
    /// JSON object (predictions excluded — they are test surface, not
    /// a perf metric).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("model".to_string(), Json::Str(self.model.clone()));
        o.insert("backend_mode".to_string(), Json::Str(self.backend_mode.clone()));
        o.insert("requests".to_string(), Json::Num(self.requests as f64));
        o.insert("workers".to_string(), Json::Num(self.workers as f64));
        o.insert("max_batch".to_string(), Json::Num(self.max_batch as f64));
        o.insert("wall_s".to_string(), Json::Num(self.wall_s));
        o.insert("throughput_rps".to_string(), Json::Num(self.throughput_rps));
        o.insert("p50_ms".to_string(), Json::Num(self.p50_ms));
        o.insert("p95_ms".to_string(), Json::Num(self.p95_ms));
        o.insert("p99_ms".to_string(), Json::Num(self.p99_ms));
        o.insert("mean_ms".to_string(), Json::Num(self.mean_ms));
        o.insert("max_ms".to_string(), Json::Num(self.max_ms));
        o.insert("mean_batch".to_string(), Json::Num(self.mean_batch));
        o.insert("batches".to_string(), Json::Num(self.batches as f64));
        Json::Obj(o)
    }

    pub fn write_json(&self, path: &Path) -> Result<()> {
        std::fs::write(path, crate::json::to_string(&self.to_json()))
            .with_context(|| format!("write {}", path.display()))?;
        Ok(())
    }

    pub fn summary(&self) -> String {
        format!(
            "{} [{}]: {} requests, {:.0} req/s, p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms, \
             mean batch {:.1} over {} batches ({} workers, max_batch {})",
            self.model,
            self.backend_mode,
            self.requests,
            self.throughput_rps,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.mean_batch,
            self.batches,
            self.workers,
            self.max_batch
        )
    }
}

/// Open-loop throughput/latency benchmark: submit every input as its own
/// request, collect every response, report percentiles.
pub fn bench_serve(engine: Arc<Engine>, cfg: &ServeCfg, inputs: &[Vec<f32>]) -> Result<ServeReport> {
    anyhow::ensure!(!inputs.is_empty(), "bench_serve: no inputs");
    let model = engine.model().name.clone();
    let mode = {
        let base = if engine.int_accum { "int-accum" } else { "f32-exact" };
        let mut m = String::from(base);
        if !engine.opts.prepared {
            m.push_str("-streaming");
        }
        if engine.opts.threads > 1 {
            m.push_str(&format!("-t{}", engine.opts.threads));
        }
        m
    };
    let server = Server::start(engine, cfg);
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(inputs.len());
    for x in inputs {
        rxs.push(server.submit(x.clone())?);
    }
    let mut preds = Vec::with_capacity(inputs.len());
    let mut latencies = Vec::with_capacity(inputs.len());
    let mut batch_sum = 0usize;
    for rx in &rxs {
        let r = match rx.recv() {
            Ok(r) => r,
            Err(_) => {
                let cause = server
                    .stats()
                    .last_error
                    .lock()
                    .expect("stats lock")
                    .clone()
                    .unwrap_or_else(|| "response channel closed".into());
                return Err(anyhow::anyhow!("serve response lost: {cause}"));
            }
        };
        preds.push(r.pred);
        latencies.push(r.latency);
        batch_sum += r.batch_size;
    }
    let wall = t0.elapsed().as_secs_f64();
    let failed = server.stats().failed.load(Ordering::Relaxed);
    let (batches, served) = server.shutdown();
    // a benchmark with any failed request must error out (the CI smoke
    // job exits non-zero on it), never report a rosy partial number
    anyhow::ensure!(failed == 0, "{failed} requests failed in the engine");
    anyhow::ensure!(
        served as usize == inputs.len(),
        "served {served} of {} requests",
        inputs.len()
    );
    latencies.sort();
    let ms = |d: Duration| d.as_secs_f64() * 1e3;
    let pick = |q: f64| latencies[((latencies.len() - 1) as f64 * q) as usize];
    let mean_ms =
        latencies.iter().map(|d| ms(*d)).sum::<f64>() / latencies.len().max(1) as f64;
    Ok(ServeReport {
        model,
        backend_mode: mode,
        requests: inputs.len(),
        workers: cfg.workers.max(1),
        max_batch: cfg.max_batch.max(1),
        wall_s: wall,
        throughput_rps: inputs.len() as f64 / wall.max(1e-9),
        p50_ms: ms(pick(0.5)),
        p95_ms: ms(pick(0.95)),
        p99_ms: ms(pick(0.99)),
        mean_ms,
        max_ms: ms(*latencies.last().expect("non-empty latencies")),
        mean_batch: batch_sum as f64 / inputs.len().max(1) as f64,
        batches,
        preds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deploy::format::{DeployLayer, DeployModel, DeployOp, Requant};
    use crate::deploy::packed::Packed;

    /// 12-feature identity-flavoured single-layer model: hw=2 so d_in =
    /// 2*2*3 = 12, 3 output classes.
    fn tiny_model() -> DeployModel {
        // weights [12, 3] on a 3-bit grid, s = 0.5: class c sums feature
        // block c (features 4c..4c+4 get weight +1 = code 5)
        let mut codes = vec![4u32; 12 * 3]; // grid int 0
        for c in 0..3usize {
            for f in 0..4usize {
                codes[(c * 4 + f) * 3 + c] = 6; // grid int +2 -> weight 1.0
            }
        }
        DeployModel {
            name: "tiny".into(),
            input_hw: 2,
            num_classes: 3,
            quant_a: false,
            bits_w: 3,
            bits_a: 8,
            layers: vec![DeployLayer {
                name: "head".into(),
                op: DeployOp::Full,
                d_in: 12,
                d_out: 3,
                relu: false,
                aq: false,
                act_bits: 8,
                a_scales: vec![1.0],
                w_bits: 3,
                w_scales: vec![0.5],
                weights: Packed::pack(&codes, 3).unwrap(),
                bias: None,
                requant: Some(Requant { mult: vec![1.0; 3], add: vec![0.0; 3] }),
            }],
        }
    }

    fn one_hot_block(c: usize) -> Vec<f32> {
        let mut x = vec![0.0f32; 12];
        for f in 0..4 {
            x[c * 4 + f] = 1.0;
        }
        x
    }

    #[test]
    fn server_routes_batched_requests() {
        let engine = Arc::new(Engine::new(tiny_model()));
        let server = Server::start(engine, &ServeCfg { workers: 3, max_batch: 4, queue_cap: 64 });
        let rxs: Vec<_> = (0..30)
            .map(|i| server.submit(one_hot_block(i % 3)).unwrap())
            .collect();
        for (i, rx) in rxs.iter().enumerate() {
            let r = rx.recv().unwrap();
            assert_eq!(r.pred, i % 3, "request {i}");
            assert!(r.batch_size >= 1 && r.batch_size <= 4);
            assert_eq!(r.logits.len(), 3);
        }
        let (batches, requests) = server.shutdown();
        assert_eq!(requests, 30);
        assert!(batches >= 8, "max_batch 4 needs >= 8 batches for 30 requests");
    }

    /// A structurally broken model (layer widths don't chain — only
    /// constructible directly, the QPKG loader rejects it) whose engine
    /// forward fails cleanly on every batch: the second layer expects 7
    /// inputs but the first emits 3.
    fn broken_model() -> DeployModel {
        let mut m = tiny_model();
        m.layers.push(DeployLayer {
            name: "bad".into(),
            op: DeployOp::Full,
            d_in: 7,
            d_out: 3,
            relu: false,
            aq: false,
            act_bits: 8,
            a_scales: vec![1.0],
            w_bits: 3,
            w_scales: vec![0.5],
            weights: Packed::pack(&[0u32; 21], 3).unwrap(),
            bias: None,
            requant: None,
        });
        m
    }

    #[test]
    fn failed_batches_surface_as_bench_errors() {
        let engine = Arc::new(Engine::new(broken_model()));
        let inputs: Vec<Vec<f32>> = (0..8).map(|i| one_hot_block(i % 3)).collect();
        let err = bench_serve(engine, &ServeCfg::default(), &inputs)
            .expect_err("engine failures must fail the benchmark");
        // the failure cause is surfaced, not swallowed
        assert!(format!("{err:#}").contains("serve response lost"), "{err:#}");
        // and the failed-request counter records the drops
        let engine = Arc::new(Engine::new(broken_model()));
        let server = Server::start(engine, &ServeCfg { workers: 1, max_batch: 4, queue_cap: 8 });
        let rx = server.submit(one_hot_block(0)).unwrap();
        assert!(rx.recv().is_err(), "response channel must close on failure");
        assert!(server.stats().failed.load(Ordering::Relaxed) >= 1);
        server.shutdown();
    }

    #[test]
    fn threaded_engine_serves_identical_predictions() {
        use crate::deploy::engine::EngineOpts;
        let inputs: Vec<Vec<f32>> = (0..24).map(|i| one_hot_block(i % 3)).collect();
        let cfg = ServeCfg { workers: 2, max_batch: 8, queue_cap: 32 };
        let base = bench_serve(Arc::new(Engine::new(tiny_model())), &cfg, &inputs).unwrap();
        let eng = Engine::with_opts(tiny_model(), true, EngineOpts { threads: 2, prepared: true });
        let mt = bench_serve(Arc::new(eng), &cfg, &inputs).unwrap();
        assert_eq!(base.preds, mt.preds);
        assert!(mt.backend_mode.ends_with("-t2"), "{}", mt.backend_mode);
    }

    #[test]
    fn submit_rejects_wrong_width() {
        let engine = Arc::new(Engine::new(tiny_model()));
        let server = Server::start(engine, &ServeCfg::default());
        assert!(server.submit(vec![0.0; 5]).is_err());
        server.shutdown();
    }

    #[test]
    fn bench_serve_reports_and_roundtrips_json() {
        let engine = Arc::new(Engine::new(tiny_model()));
        let inputs: Vec<Vec<f32>> = (0..40).map(|i| one_hot_block(i % 3)).collect();
        let cfg = ServeCfg { workers: 2, max_batch: 8, queue_cap: 16 };
        let report = bench_serve(engine, &cfg, &inputs).unwrap();
        assert_eq!(report.requests, 40);
        assert_eq!(report.preds.len(), 40);
        for (i, &p) in report.preds.iter().enumerate() {
            assert_eq!(p, i % 3);
        }
        assert!(report.throughput_rps > 0.0);
        assert!(report.p50_ms <= report.p95_ms + 1e-9);
        assert!(report.p95_ms <= report.p99_ms + 1e-9);
        assert!(report.p99_ms <= report.max_ms + 1e-9);
        assert!(report.mean_ms > 0.0 && report.mean_ms <= report.max_ms + 1e-9);
        assert!(report.mean_batch >= 1.0);
        let j = report.to_json();
        assert_eq!(j.get("requests").as_usize(), Some(40));
        // tail-latency fields ride in BENCH_serve.json for future gates
        assert_eq!(j.get("p99_ms").as_f64(), Some(report.p99_ms));
        assert_eq!(j.get("mean_ms").as_f64(), Some(report.mean_ms));
        let dir = std::env::temp_dir().join("qat_serve_bench");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("BENCH_serve.json");
        report.write_json(&p).unwrap();
        let parsed = crate::json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
        assert_eq!(parsed.get("model").as_str(), Some("tiny"));
    }
}
