//! Export: trained QAT state -> deployable integer model.
//!
//! The pipeline (run after BN re-estimation, §2.3.1):
//!
//! 1. **Grid snapping.** Every quantized weight tensor is snapped to its
//!    LSQ grid with the same `clip(round_ties_even(w/s), n, p)` the
//!    training-time fake-quantizer applies, so the deployed integers are
//!    exactly the integers simulated QAT evaluated. Frozen weights
//!    (Algorithm 1) are *verified* to already sit on the grid at their
//!    pinned integer — a frozen weight that drifted off `s * fint` means
//!    corrupted training state and aborts the export.
//! 2. **BN folding.** Batch-norm running statistics are folded into a
//!    per-channel requantization affine `y = mult[c] * z + add[c]` with
//!    `mult = g / sqrt(v + eps)` and `add = beta - mult * m`. Folding
//!    into the *requant constants* rather than into the weights keeps
//!    the weight tensor on its LSQ grid (folding into the weights would
//!    re-round the integers QAT converged to). Per-channel weight scales
//!    compose naturally: the engine's integer path requantizes channel
//!    `c` by `s_a * s_w[c]` before this affine, so both per-channel
//!    factors stack without ever touching the stored integers.
//! 3. **Bit-packing.** Weight grid indices are serialized at the target
//!    bit-width (2x int4 per byte, 8-bit stem/head one per byte, ...).
//!
//! The result round-trips through the QPKG format and is served by
//! [`super::engine::Engine`].

use super::format::{DeployLayer, DeployModel, DeployOp, Requant};
use super::packed::Packed;
use crate::quant::weight_grid;
use crate::runtime::native::interp::BN_EPS;
use crate::runtime::native::kernels;
use crate::runtime::native::model::{LayerOp, NativeModel};
use crate::state::NamedTensors;
use anyhow::{Context, Result};

/// Quantization configuration of the run being exported (must match the
/// `EvalQuant` the simulated eval used).
#[derive(Debug, Clone, Copy)]
pub struct ExportCfg {
    pub bits_w: u32,
    pub bits_a: u32,
    pub quant_a: bool,
}

/// What the export did — surfaced on the CLI and asserted in tests.
#[derive(Debug, Clone, Default)]
pub struct ExportReport {
    pub layers: usize,
    pub total_weights: usize,
    /// frozen weights verified to sit exactly on their pinned integer
    pub frozen_verified: usize,
    /// max |w/s - round(w/s)| over non-frozen in-range weights (grid units)
    pub max_offgrid: f32,
    pub packed_bytes: usize,
    pub f32_bytes: usize,
}

impl ExportReport {
    /// Packed-to-f32 weight size ratio (the `bits/32` headline number).
    pub fn ratio(&self) -> f64 {
        self.packed_bytes as f64 / (self.f32_bytes as f64).max(1.0)
    }
}

/// Snap weights to the `bits`-wide LSQ grid of their channel's scale
/// (the eval-time fake-quantizer's `clip(round_ties_even(w/s_c), n, p)`,
/// with `scales`/`group` as in `kernels::scale_index`) and bit-pack the
/// resulting grid indices. Returns the payload plus the grid minimum the
/// engine needs to decode it. The single source of truth for the
/// weight-to-code mapping — the bit-exactness tests encode through this
/// same function.
pub fn snap_and_pack_pc(
    w: &[f32],
    scales: &[f32],
    group: usize,
    bits: u32,
) -> Result<(Packed, i32)> {
    let (gn, gp) = weight_grid(bits);
    let q = kernels::int_weights_pc(w, scales, group, gn, gp);
    let codes: Vec<u32> = q.iter().map(|&v| (v - gn) as u32).collect();
    Ok((Packed::pack(&codes, bits)?, gn as i32))
}

/// Per-tensor wrapper over [`snap_and_pack_pc`].
pub fn snap_and_pack(w: &[f32], s: f32, bits: u32) -> Result<(Packed, i32)> {
    snap_and_pack_pc(w, std::slice::from_ref(&s), 1, bits)
}

/// Export a trained state for `model` into a [`DeployModel`].
///
/// `state` must hold `params/*` and (for BN layers) re-estimated `bn/*`
/// running statistics; `osc/*` tensors, when present, drive the frozen
/// weight verification.
pub fn export_model(
    model: &NativeModel,
    state: &NamedTensors,
    cfg: &ExportCfg,
) -> Result<(DeployModel, ExportReport)> {
    let mut report = ExportReport::default();
    let mut layers = Vec::with_capacity(model.layers.len());
    for l in &model.layers {
        let w = state
            .expect(&format!("params/{}.w", l.name))
            .with_context(|| format!("export {}: weights", l.name))?;
        let s_t = state
            .expect(&format!("params/{}.s", l.name))
            .with_context(|| format!("export {}: weight scale", l.name))?;
        // per-tensor (scalar) or per-channel LSQ scales — one per output
        // column for dense layers, one per channel for depthwise
        anyhow::ensure!(
            s_t.len() == 1 || s_t.len() == l.w_channels(),
            "export {}: {} weight scales for {} channels",
            l.name,
            s_t.len(),
            l.w_channels()
        );
        let w_scales: Vec<f32> = s_t.data.iter().map(|&v| v.max(1e-8)).collect();
        let group = l.scale_group();
        let n_scales = w_scales.len();
        let w_bits = if l.wq == "8bit" { 8 } else { cfg.bits_w };
        let (gn, gp) = weight_grid(w_bits);

        // snap to the LSQ grid (identical to the eval-time fake-quantizer)
        let q = kernels::int_weights_pc(&w.data, &w_scales, group, gn, gp);

        // Algorithm-1 consistency: frozen weights must already be on-grid
        // at their pinned integer (on their channel's grid). All other
        // in-range weights contribute their snap distance to the report.
        let b = state.get(&format!("osc/{}.w#b", l.name));
        let fint = state.get(&format!("osc/{}.w#fint", l.name));
        for i in 0..q.len() {
            let s_w = w_scales[kernels::scale_index(i, group, n_scales)];
            let frozen = b.map(|b| b.data[i] > 0.5).unwrap_or(false);
            if frozen {
                let fint = fint.with_context(|| {
                    format!("export {}: frozen mask without pinned integers", l.name)
                })?;
                anyhow::ensure!(
                    q[i] == fint.data[i],
                    "export {}: frozen weight {i} snaps to {} but is pinned to {}",
                    l.name,
                    q[i],
                    fint.data[i]
                );
                anyhow::ensure!(
                    (w.data[i] - s_w * fint.data[i]).abs() < 1e-5,
                    "export {}: frozen weight {i} drifted off the grid ({} vs {})",
                    l.name,
                    w.data[i],
                    s_w * fint.data[i]
                );
                report.frozen_verified += 1;
            } else {
                let r = w.data[i] / s_w;
                if r >= gn && r <= gp {
                    report.max_offgrid = report.max_offgrid.max((r - q[i]).abs());
                }
            }
        }

        let (packed, _) = snap_and_pack_pc(&w.data, &w_scales, group, w_bits)?;

        // BN fold: per-channel requant affine replacing the BN op
        let requant = if l.bn {
            let g = state.expect(&format!("params/{}.g", l.name))?;
            let beta = state.expect(&format!("params/{}.beta", l.name))?;
            let m = state.expect(&format!("bn/{}.bn_m", l.name))?;
            let v = state.expect(&format!("bn/{}.bn_v", l.name))?;
            let mut mult = Vec::with_capacity(l.d_out);
            let mut add = Vec::with_capacity(l.d_out);
            for c in 0..l.d_out {
                let ivar = 1.0 / (v.data[c] + BN_EPS).sqrt();
                let a = g.data[c] * ivar;
                mult.push(a);
                add.push((beta.data[c] as f64 - a as f64 * m.data[c] as f64) as f32);
            }
            Some(Requant { mult, add })
        } else {
            None
        };

        let bias = if l.bias {
            Some(state.expect(&format!("params/{}.bias", l.name))?.data.clone())
        } else {
            None
        };

        let aq = l.aq && cfg.quant_a;
        let act_bits = if l.wq == "8bit" { 8 } else { cfg.bits_a };
        // per-tensor (scalar) or per-input-channel LSQ scales — [d_in]
        // for 1-D layers, [C] for spatial depthwise
        let a_scales: Vec<f32> = if aq {
            let as_t = state
                .expect(&format!("params/{}.as", l.name))
                .with_context(|| format!("export {}: activation scale", l.name))?;
            anyhow::ensure!(
                as_t.len() == 1 || as_t.len() == l.act_channels(),
                "export {}: {} activation scales for {} input channels",
                l.name,
                as_t.len(),
                l.act_channels()
            );
            as_t.data.iter().map(|&v| v.max(1e-8)).collect()
        } else {
            vec![1.0]
        };

        report.total_weights += q.len();
        report.packed_bytes += packed.num_bytes();
        report.f32_bytes += q.len() * 4;
        layers.push(DeployLayer {
            name: l.name.clone(),
            op: match l.op {
                LayerOp::Full => DeployOp::Full,
                LayerOp::Dw => DeployOp::Dw,
                LayerOp::DwSpatial => DeployOp::DwSpatial,
            },
            d_in: l.d_in,
            d_out: l.d_out,
            relu: l.relu,
            aq,
            act_bits,
            a_scales,
            w_bits,
            w_scales,
            weights: packed,
            bias,
            requant,
            spatial: l.spatial.map(|sp| super::format::DwSpatialMeta {
                kernel: crate::runtime::native::model::SpatialSpec::KERNEL,
                stride: sp.stride,
                pad: sp.pad,
                hw_in: sp.hw_in,
                channels: sp.channels,
            }),
        });
    }
    report.layers = layers.len();
    let dm = DeployModel {
        name: model.name.clone(),
        input_hw: model.input_hw,
        num_classes: model.num_classes,
        quant_a: cfg.quant_a,
        bits_w: cfg.bits_w,
        bits_a: cfg.bits_a,
        layers,
    };
    Ok((dm, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::model::zoo_model;
    use crate::tensor::Tensor;

    fn cfg() -> ExportCfg {
        ExportCfg { bits_w: 3, bits_a: 3, quant_a: false }
    }

    #[test]
    fn exports_initial_state() {
        let m = zoo_model("efflite").unwrap();
        let state = m.initial_state();
        let (dm, report) = export_model(&m, &state, &cfg()).unwrap();
        assert_eq!(dm.layers.len(), m.layers.len());
        assert_eq!(report.total_weights, dm.total_weights());
        assert!(report.frozen_verified == 0, "fresh state has no frozen weights");
        // stem/head are 8-bit, interior is 3-bit
        assert_eq!(dm.layers.first().unwrap().w_bits, 8);
        assert_eq!(dm.layers.last().unwrap().w_bits, 8);
        assert!(dm.layers.iter().any(|l| l.w_bits == 3));
        // every BN layer folded, head kept its bias
        for (dl, nl) in dm.layers.iter().zip(&m.layers) {
            assert_eq!(dl.requant.is_some(), nl.bn, "{}", nl.name);
            assert_eq!(dl.bias.is_some(), nl.bias, "{}", nl.name);
        }
        assert!(report.ratio() < 0.26, "packed ratio {}", report.ratio());
    }

    #[test]
    fn snapped_codes_match_fake_quant() {
        let m = zoo_model("efflite").unwrap();
        let state = m.initial_state();
        let (dm, _) = export_model(&m, &state, &cfg()).unwrap();
        for (dl, nl) in dm.layers.iter().zip(&m.layers) {
            let w = state.get(&format!("params/{}.w", nl.name)).unwrap();
            let s = state.get(&format!("params/{}.s", nl.name)).unwrap().item().max(1e-8);
            let (gn, gp) = dl.w_grid();
            let fq = kernels::fake_quant(&w.data, s, gn, gp);
            let mut deq = Vec::new();
            dl.weights
                .dequant_pc_into(dl.grid_n_int(), &dl.w_scales, dl.scale_group(), &mut deq);
            assert_eq!(deq, fq, "layer {} dequant != fake_quant", nl.name);
        }
    }

    #[test]
    fn per_channel_export_roundtrips_scale_vectors() {
        let m = zoo_model("efflite").unwrap();
        let mut state = m.initial_state();
        // install distinct per-channel scales on every layer
        for l in &m.layers {
            let scales: Vec<f32> = (0..l.d_out).map(|c| 0.05 + 0.01 * c as f32).collect();
            state.insert(
                format!("params/{}.s", l.name),
                crate::tensor::Tensor::new(vec![l.d_out], scales),
            );
        }
        let (dm, report) = export_model(&m, &state, &cfg()).unwrap();
        assert_eq!(report.layers, m.layers.len());
        for (dl, nl) in dm.layers.iter().zip(&m.layers) {
            assert!(dl.per_channel(), "{}", nl.name);
            assert_eq!(dl.w_scales.len(), nl.d_out, "{}", nl.name);
            // the packed codes decode bit-exactly to the per-channel
            // fake-quant of the latent weights
            let w = state.get(&format!("params/{}.w", nl.name)).unwrap();
            let (gn, gp) = dl.w_grid();
            let fq =
                kernels::fake_quant_pc(&w.data, &dl.w_scales, nl.scale_group(), gn, gp);
            let mut deq = Vec::new();
            dl.weights
                .dequant_pc_into(dl.grid_n_int(), &dl.w_scales, dl.scale_group(), &mut deq);
            assert_eq!(deq, fq, "layer {}", nl.name);
        }
        // QPKG v2 round-trip preserves the scale arrays
        let dm2 = crate::deploy::format::DeployModel::from_bytes(&dm.to_bytes()).unwrap();
        assert_eq!(dm, dm2);
    }

    #[test]
    fn per_channel_activation_export_roundtrips() {
        let m = zoo_model("efflite").unwrap();
        let mut state = m.initial_state();
        for l in &m.layers {
            if l.aq {
                let scales: Vec<f32> = (0..l.d_in).map(|j| 0.02 + 1e-3 * j as f32).collect();
                state.insert(
                    format!("params/{}.as", l.name),
                    crate::tensor::Tensor::new(vec![l.d_in], scales),
                );
            }
        }
        let cfg = ExportCfg { bits_w: 4, bits_a: 4, quant_a: true };
        let (dm, _) = export_model(&m, &state, &cfg).unwrap();
        for (dl, nl) in dm.layers.iter().zip(&m.layers) {
            if nl.aq {
                assert!(dl.per_channel_act(), "{}", nl.name);
                assert_eq!(dl.a_scales.len(), nl.d_in, "{}", nl.name);
                assert_eq!(dl.a_scale_of(1), 0.02 + 1e-3);
            } else {
                assert_eq!(dl.a_scales, vec![1.0], "{}", nl.name);
            }
        }
        // QPKG v3 round-trip preserves the activation scale arrays
        let dm2 = crate::deploy::format::DeployModel::from_bytes(&dm.to_bytes()).unwrap();
        assert_eq!(dm, dm2);
    }

    #[test]
    fn spatial_export_roundtrips_qpkg_v4() {
        let m = zoo_model("efflite_2d").unwrap();
        let mut state = m.initial_state();
        // per-channel weight scales (length C on spatial dw layers) and
        // per-channel activation scales (length C on their inputs)
        for l in &m.layers {
            let wc = l.w_channels();
            let scales: Vec<f32> = (0..wc).map(|c| 0.05 + 0.01 * c as f32).collect();
            state.insert(format!("params/{}.s", l.name), Tensor::new(vec![wc], scales));
            if l.aq {
                let ac = l.act_channels();
                let ascales: Vec<f32> = (0..ac).map(|j| 0.02 + 1e-3 * j as f32).collect();
                state.insert(format!("params/{}.as", l.name), Tensor::new(vec![ac], ascales));
            }
        }
        let cfg = ExportCfg { bits_w: 4, bits_a: 4, quant_a: true };
        let (dm, report) = export_model(&m, &state, &cfg).unwrap();
        assert_eq!(report.layers, m.layers.len());
        let (dl, nl) = dm
            .layers
            .iter()
            .zip(&m.layers)
            .find(|(_, nl)| nl.op == LayerOp::DwSpatial)
            .unwrap();
        assert_eq!(dl.op, DeployOp::DwSpatial);
        let sp = dl.spatial.unwrap();
        let nsp = nl.spatial.unwrap();
        assert_eq!(
            (sp.kernel, sp.stride, sp.pad, sp.hw_in, sp.channels),
            (3, nsp.stride, nsp.pad, nsp.hw_in, nsp.channels)
        );
        assert_eq!(dl.w_scales.len(), nsp.channels);
        assert_eq!(dl.a_scales.len(), nsp.channels);
        assert_eq!(dl.weights.len, nsp.channels * 9);
        // packed codes decode bit-exactly to the group-9 fake-quant
        let w = state.get(&format!("params/{}.w", nl.name)).unwrap();
        let (gn, gp) = dl.w_grid();
        let fq = kernels::fake_quant_pc(&w.data, &dl.w_scales, 9, gn, gp);
        let mut deq = Vec::new();
        dl.weights
            .dequant_pc_into(dl.grid_n_int(), &dl.w_scales, dl.scale_group(), &mut deq);
        assert_eq!(deq, fq);
        // QPKG v4 round-trip preserves the spatial metadata
        let dm2 = crate::deploy::format::DeployModel::from_bytes(&dm.to_bytes()).unwrap();
        assert_eq!(dm, dm2);
    }

    #[test]
    fn export_rejects_bad_act_scale_count() {
        let m = zoo_model("efflite").unwrap();
        let mut state = m.initial_state();
        let l = m.layers.iter().find(|l| l.aq).unwrap();
        state.insert(
            format!("params/{}.as", l.name),
            crate::tensor::Tensor::new(vec![2], vec![0.1, 0.2]), // d_in != 2
        );
        let cfg = ExportCfg { bits_w: 4, bits_a: 4, quant_a: true };
        assert!(export_model(&m, &state, &cfg).is_err());
    }

    #[test]
    fn export_rejects_bad_scale_count() {
        let m = zoo_model("efflite").unwrap();
        let mut state = m.initial_state();
        let l = &m.layers[1]; // an interior layer
        state.insert(
            format!("params/{}.s", l.name),
            crate::tensor::Tensor::new(vec![2], vec![0.1, 0.2]), // d_out != 2
        );
        assert!(export_model(&m, &state, &cfg()).is_err());
    }

    #[test]
    fn frozen_offgrid_weight_aborts_export() {
        let m = zoo_model("efflite").unwrap();
        let mut state = m.initial_state();
        let name = m.lowbit()[0].clone(); // e.g. "b1.dw.w"
        let bkey = format!("osc/{name}#b");
        let fkey = format!("osc/{name}#fint");
        let shape = state.get(&bkey).unwrap().shape.clone();
        let mut b = Tensor::zeros(&shape);
        let mut fint = Tensor::zeros(&shape);
        b.data[0] = 1.0;
        fint.data[0] = 3.0; // pinned to +3, but the latent weight is not s*3
        state.insert(bkey, b);
        state.insert(fkey, fint);
        assert!(export_model(&m, &state, &cfg()).is_err());
    }
}
