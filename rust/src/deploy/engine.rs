//! The packed low-bit inference engine.
//!
//! The execution core is a **decode-once [`PreparedModel`]**: at QPKG
//! load time every layer's packed payload is decoded exactly once — into
//! a per-channel-dequantized f32 plane (`s_c * grid_int`, the operand of
//! the float path) and, for quantized-activation layers, a signed i32
//! grid-integer plane (the operand of the integer path). Forward calls
//! then run **cache-blocked, register-tiled kernels** straight over the
//! cached planes; nothing touches the bitstream on the hot path. The
//! pre-cache behaviour (re-decode per call) survives behind
//! [`EngineOpts::prepared`] `= false` for benchmarking the difference.
//!
//! Two execution paths per layer:
//!
//! * **f32 path** ([`matmul_f32`] / [`dw_f32`]) — the accumulation
//!   replays the native interpreter's term order per output element
//!   (`kk` ascending, same `a == 0.0` skip), so the output is
//!   **bit-exact** against the native fake-quant kernels over per-tensor
//!   *and* per-channel scale vectors. Blocking and register tiling only
//!   reorder *which* output element is updated next, never the terms
//!   within one element. This is the path for layers whose input
//!   activations are not quantized (the stem, and every layer of a
//!   weight-only run).
//! * **i32 path** ([`matmul_i32`] / [`dw_i32`]) — input activations
//!   arrive as unsigned grid codes, weights as signed grid integers, and
//!   the dot product accumulates in i32 (exact integer arithmetic, no
//!   rounding at all); one per-channel requantization multiply
//!   (`s_a * s_w[c] * acc`, in f64 — **composed with the folded-BN
//!   affine's `mult[c]` into a single per-output-channel factor** when
//!   the layer carries a requant and no bias) brings the result back to
//!   the real scale — per-channel weight scales factor out of each
//!   output channel's dot product, so the stored integers never change.
//!   Worst case here (255 x 127 x 768-deep) stays far inside i32 range.
//!
//! **Per-channel activation scales** (since QPKG v3, `n_a_scales = d_in`)
//! quantize each input channel on its own grid. A per-input-channel
//! scale does *not* factor out of a dense dot product, so no exact
//! per-output-channel integer requant exists for such layers; the engine
//! runs them through the f32 route with the interpreter's exact
//! arithmetic (`a_q[i] = s_a[i % d_in] * code_i` over the dequantized
//! plane), which keeps every mode — prepared, streaming, threaded, and
//! both accumulation settings — bit-exact vs the fake-quant reference.
//! Layers whose activation scale stays per-tensor keep the full i32
//! fast path.
//!
//! **Spatial depthwise layers are the exception** (QPKG v4,
//! [`dw_spatial_f32`] / [`dw_spatial_i32`]): a 3x3 depthwise receptive
//! field over the channel-last `[H, W, C]` layout stays entirely inside
//! one input channel, so the per-channel activation scale `s_a[c]`
//! factors out of every output element of channel `c` after all — the
//! exact integer path survives per-channel activation grids there, with
//! the composed per-output factor `s_a[o % C] * s_w[o % C] * mult[o]`.
//!
//! Batches parallelize over rows: [`EngineOpts::threads`] splits the
//! batch into contiguous row chunks and runs the full layer stack on
//! each under `std::thread::scope` (no extra deps, nothing outlives the
//! call). Samples are independent, so the split is bit-exact by
//! construction; serving workers share one `Arc<PreparedModel>` and
//! never re-decode.
//!
//! After the linear op the folded-BN requant affine (`mult[c]*z+add[c]`),
//! bias and ReLU are applied per channel — there is no batch-norm op and
//! no running statistic left at inference time.

use super::format::{DeployLayer, DeployModel, DeployOp};
use super::packed::Packed;
use crate::runtime::native::kernels;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

pub use crate::tensor::argmax;

/// k-panel height of the blocked matmul kernels: a `KB x n` slab of the
/// weight plane is reused across every batch row before moving on.
const KB: usize = 64;

/// One blocked matmul kernel per element type: the KB-panel blocking,
/// 2-way register tiling, zero-skip arms and tail are shared so the f32
/// and i32 kernels cannot drift apart. The fused arm's two *sequential*
/// adds per element keep the f32 term order identical to two separate
/// axpy passes (half the output-row traffic, same rounding); for i32
/// every order is exact anyway.
macro_rules! blocked_matmul_impl {
    ($(#[$meta:meta])* $name:ident, $ty:ty, $zero:expr) => {
        $(#[$meta])*
        pub fn $name(x: &[$ty], w: &[$ty], m: usize, k: usize, n: usize, out: &mut [$ty]) {
            debug_assert_eq!(w.len(), k * n);
            debug_assert_eq!(x.len(), m * k);
            debug_assert_eq!(out.len(), m * n);
            out.fill($zero);
            for k0 in (0..k).step_by(KB) {
                let k1 = (k0 + KB).min(k);
                for i in 0..m {
                    let arow = &x[i * k..(i + 1) * k];
                    let orow = &mut out[i * n..(i + 1) * n];
                    let mut kk = k0;
                    while kk + 1 < k1 {
                        let (a0, a1) = (arow[kk], arow[kk + 1]);
                        let r0 = &w[kk * n..(kk + 1) * n];
                        let r1 = &w[(kk + 1) * n..(kk + 2) * n];
                        match (a0 != $zero, a1 != $zero) {
                            (true, true) => {
                                for j in 0..n {
                                    let t = orow[j] + a0 * r0[j];
                                    orow[j] = t + a1 * r1[j];
                                }
                            }
                            (true, false) => {
                                for j in 0..n {
                                    orow[j] += a0 * r0[j];
                                }
                            }
                            (false, true) => {
                                for j in 0..n {
                                    orow[j] += a1 * r1[j];
                                }
                            }
                            (false, false) => {}
                        }
                        kk += 2;
                    }
                    if kk < k1 {
                        let a = arow[kk];
                        if a != $zero {
                            let row = &w[kk * n..(kk + 1) * n];
                            for j in 0..n {
                                orow[j] += a * row[j];
                            }
                        }
                    }
                }
            }
        }
    };
}

blocked_matmul_impl!(
    /// `x [m,k] @ wq [k,n]` over a decoded (dequantized) weight plane,
    /// accumulating into `out [m,n]`. Bit-exact vs `kernels::quant_matmul`
    /// / `kernels::fake_quant_pc` + the interpreter loop: per output
    /// element the terms are added in ascending `kk` with the same
    /// `a == 0.0` skip. Cache-blocked over `kk` (KB-panels) and
    /// register-tiled two `kk` rows at a time (one load/store of the
    /// output row per pair).
    matmul_f32,
    f32,
    0.0f32
);
blocked_matmul_impl!(
    /// Integer twin of [`matmul_f32`]: unsigned activation codes x signed
    /// weight integers from a decoded plane, i32 accumulation (exact, so
    /// blocking needs no order care). Zero codes are skipped.
    matmul_i32,
    i32,
    0i32
);

/// One circular depthwise 3-tap kernel per element type (shared peeling
/// logic, see the f32 instantiation for the order contract).
macro_rules! blocked_dw_impl {
    ($(#[$meta:meta])* $name:ident, $ty:ty, $zero:expr) => {
        $(#[$meta])*
        pub fn $name(x: &[$ty], w: &[$ty], b: usize, c_dim: usize, out: &mut [$ty]) {
            debug_assert_eq!(w.len(), c_dim * 3);
            debug_assert_eq!(x.len(), b * c_dim);
            debug_assert_eq!(out.len(), b * c_dim);
            if c_dim == 0 {
                return;
            }
            for bi in 0..b {
                let arow = &x[bi * c_dim..(bi + 1) * c_dim];
                let orow = &mut out[bi * c_dim..(bi + 1) * c_dim];
                let tap = |c: usize, jm1: usize, j0: usize, jp1: usize| -> $ty {
                    let w3 = &w[c * 3..c * 3 + 3];
                    let mut acc = $zero;
                    acc += w3[0] * arow[jm1];
                    acc += w3[1] * arow[j0];
                    acc += w3[2] * arow[jp1];
                    acc
                };
                orow[0] = tap(0, c_dim - 1, 0, 1 % c_dim);
                for c in 1..c_dim.saturating_sub(1) {
                    orow[c] = tap(c, c - 1, c, c + 1);
                }
                if c_dim > 1 {
                    orow[c_dim - 1] = tap(c_dim - 1, c_dim - 2, c_dim - 1, 0);
                }
            }
        }
    };
}

blocked_dw_impl!(
    /// Circular depthwise 3-tap conv over a decoded weight plane,
    /// mirroring the interpreter's tap order (`t = 0, 1, 2` onto
    /// `c-1, c, c+1` mod C) exactly — the accumulator starts at zero and
    /// adds the taps in `t` order, so the f32 rounding sequence is the
    /// scalar reference's. The two wrap-around channels are peeled so
    /// the interior loop is branch- and modulo-free contiguous access.
    dw_f32,
    f32,
    0.0f32
);
blocked_dw_impl!(
    /// Integer circular depthwise 3-tap conv over a decoded plane with
    /// i32 accumulation, wrap channels peeled like [`dw_f32`].
    dw_i32,
    i32,
    0i32
);

/// One spatial depthwise 3x3 kernel per element type. The activation
/// layout is channel-last `[H, W, C]` flattened (`j = (y*W + x)*C + c`),
/// the weight plane is `[C, 3, 3]` (`w[c*9 + ky*3 + kx]`). Zero padding
/// is realised by *skipping* out-of-bounds taps, and per output element
/// the in-bounds taps accumulate in ascending `(ky, kx)` order — exactly
/// the native interpreter's term sequence, so the f32 instantiation is
/// bit-exact against it. The channel loop is innermost: one valid tap
/// updates a contiguous `C`-run of outputs from a contiguous `C`-run of
/// inputs, which only reorders *which* output element is touched next,
/// never the terms within one element.
macro_rules! spatial_dw_impl {
    ($(#[$meta:meta])* $name:ident, $ty:ty, $zero:expr) => {
        $(#[$meta])*
        #[allow(clippy::too_many_arguments)]
        pub fn $name(
            x: &[$ty],
            w: &[$ty],
            b: usize,
            hw_in: usize,
            c_dim: usize,
            stride: usize,
            pad: usize,
            out: &mut [$ty],
        ) {
            let hw_out = (hw_in + 2 * pad - 3) / stride.max(1) + 1;
            let (d_in, d_out) = (hw_in * hw_in * c_dim, hw_out * hw_out * c_dim);
            debug_assert_eq!(w.len(), c_dim * 9);
            debug_assert_eq!(x.len(), b * d_in);
            debug_assert_eq!(out.len(), b * d_out);
            out.fill($zero);
            for bi in 0..b {
                let arow = &x[bi * d_in..(bi + 1) * d_in];
                let orow = &mut out[bi * d_out..(bi + 1) * d_out];
                for yo in 0..hw_out {
                    for xo in 0..hw_out {
                        let obase = (yo * hw_out + xo) * c_dim;
                        for ky in 0..3usize {
                            let y = yo * stride + ky;
                            if y < pad || y - pad >= hw_in {
                                continue; // zero-padded row: tap skipped
                            }
                            for kx in 0..3usize {
                                let xx = xo * stride + kx;
                                if xx < pad || xx - pad >= hw_in {
                                    continue; // zero-padded column
                                }
                                let jbase = ((y - pad) * hw_in + (xx - pad)) * c_dim;
                                let t = ky * 3 + kx;
                                for c in 0..c_dim {
                                    orow[obase + c] += w[c * 9 + t] * arow[jbase + c];
                                }
                            }
                        }
                    }
                }
            }
        }
    };
}

spatial_dw_impl!(
    /// Spatial depthwise 3x3 conv over a decoded (dequantized) weight
    /// plane, bit-exact vs the native interpreter's `DwSpatial` forward
    /// (ascending `(ky, kx)` tap order, out-of-bounds taps skipped).
    dw_spatial_f32,
    f32,
    0.0f32
);
spatial_dw_impl!(
    /// Integer twin of [`dw_spatial_f32`]: unsigned activation codes x
    /// signed weight integers, i32 accumulation (exact — worst case
    /// 9 taps x 255 x 127 stays far inside i32 range).
    dw_spatial_i32,
    i32,
    0i32
);

/// `x [m,k] @ dequant(w) [k,n]` with a **streaming** decode: the packed
/// payload is bulk-decoded on every call, then the blocked kernel runs.
/// Kept as the pre-cache reference path (and for one-shot callers);
/// bit-exact vs `kernels::quant_matmul` / `kernels::fake_quant_pc`.
/// `scales` holds one scale or one per output column.
pub fn packed_matmul(
    x: &[f32],
    w: &Packed,
    m: usize,
    k: usize,
    n: usize,
    scales: &[f32],
    grid_n: i32,
) -> Vec<f32> {
    debug_assert_eq!(w.len, k * n);
    debug_assert!(scales.len() == 1 || scales.len() == n);
    let mut wq = Vec::new();
    w.dequant_pc_into(grid_n, scales, 1, &mut wq);
    let mut out = vec![0.0f32; m * n];
    matmul_f32(x, &wq, m, k, n, &mut out);
    out
}

/// Streaming-decode circular depthwise 3-tap conv (`scales`: one scale
/// or one per channel row), mirroring the native interpreter exactly.
pub fn packed_dw(
    x: &[f32],
    w: &Packed,
    b: usize,
    c_dim: usize,
    scales: &[f32],
    grid_n: i32,
) -> Vec<f32> {
    debug_assert_eq!(w.len, c_dim * 3);
    debug_assert!(scales.len() == 1 || scales.len() == c_dim);
    let mut wq = Vec::new();
    w.dequant_pc_into(grid_n, scales, 3, &mut wq);
    let mut out = vec![0.0f32; b * c_dim];
    dw_f32(x, &wq, b, c_dim, &mut out);
    out
}

/// Streaming-decode integer matmul: unsigned activation codes x signed
/// weight integers, i32 accumulation. Zero codes are skipped (the
/// integer twin of the float path's `a == 0.0` fast path — `a_q == 0`
/// iff its code is 0).
pub fn packed_matmul_i32(
    qa: &[i32],
    w: &Packed,
    m: usize,
    k: usize,
    n: usize,
    grid_n: i32,
) -> Vec<i32> {
    debug_assert_eq!(w.len, k * n);
    let mut wi = Vec::new();
    w.ints_into(grid_n, &mut wi);
    let mut out = vec![0i32; m * n];
    matmul_i32(qa, &wi, m, k, n, &mut out);
    out
}

/// Streaming-decode integer circular depthwise 3-tap conv.
pub fn packed_dw_i32(qa: &[i32], w: &Packed, b: usize, c_dim: usize, grid_n: i32) -> Vec<i32> {
    debug_assert_eq!(w.len, c_dim * 3);
    let mut wi = Vec::new();
    w.ints_into(grid_n, &mut wi);
    let mut out = vec![0i32; b * c_dim];
    dw_i32(qa, &wi, b, c_dim, &mut out);
    out
}

/// Streaming-decode spatial depthwise 3x3 conv over channel-last
/// `[H, W, C]` activations (`scales`: one scale or one per channel
/// plane, `group = 9`), mirroring the native interpreter exactly.
#[allow(clippy::too_many_arguments)]
pub fn packed_dw_spatial(
    x: &[f32],
    w: &Packed,
    b: usize,
    hw_in: usize,
    c_dim: usize,
    stride: usize,
    pad: usize,
    scales: &[f32],
    grid_n: i32,
) -> Vec<f32> {
    debug_assert_eq!(w.len, c_dim * 9);
    debug_assert!(scales.len() == 1 || scales.len() == c_dim);
    let mut wq = Vec::new();
    w.dequant_pc_into(grid_n, scales, 9, &mut wq);
    let hw_out = (hw_in + 2 * pad - 3) / stride.max(1) + 1;
    let mut out = vec![0.0f32; b * hw_out * hw_out * c_dim];
    dw_spatial_f32(x, &wq, b, hw_in, c_dim, stride, pad, &mut out);
    out
}

/// Streaming-decode integer spatial depthwise 3x3 conv.
#[allow(clippy::too_many_arguments)]
pub fn packed_dw_spatial_i32(
    qa: &[i32],
    w: &Packed,
    b: usize,
    hw_in: usize,
    c_dim: usize,
    stride: usize,
    pad: usize,
    grid_n: i32,
) -> Vec<i32> {
    debug_assert_eq!(w.len, c_dim * 9);
    let mut wi = Vec::new();
    w.ints_into(grid_n, &mut wi);
    let hw_out = (hw_in + 2 * pad - 3) / stride.max(1) + 1;
    let mut out = vec![0i32; b * hw_out * hw_out * c_dim];
    dw_spatial_i32(qa, &wi, b, hw_in, c_dim, stride, pad, &mut out);
    out
}

/// One layer's decode-once weight planes.
#[derive(Debug, Clone)]
pub struct PreparedLayer {
    /// per-channel-dequantized f32 weights (`s_c * grid_int`), the float
    /// path's operand — decoded once at prepare time
    pub wq: Vec<f32>,
    /// signed grid integers, the i32 path's operand; only materialized
    /// for quantized-activation layers (the only ones that run it)
    pub wi: Option<Vec<i32>>,
}

/// A [`DeployModel`] plus its decode-once weight planes. Build one at
/// load time ([`DeployModel::prepare`]) and share it across serving
/// workers behind an `Arc` — every forward then runs on cached planes
/// and the packed bitstream is never touched again.
///
/// Memory-vs-latency tradeoff: the planes cost up to 8 bytes per weight
/// (f32 + i32) on top of the `bits/8`-byte payload, traded for never
/// paying the decode on the hot path ([`PreparedModel::plane_bytes`]
/// reports the exact overhead).
#[derive(Debug, Clone)]
pub struct PreparedModel {
    model: DeployModel,
    layers: Vec<PreparedLayer>,
}

impl PreparedModel {
    /// Decode every layer's packed payload exactly once.
    pub fn new(model: DeployModel) -> PreparedModel {
        let layers = model
            .layers
            .iter()
            .map(|l| {
                let grid_n = l.grid_n_int();
                let mut wq = Vec::new();
                l.weights.dequant_pc_into(grid_n, &l.w_scales, l.scale_group(), &mut wq);
                let wi = l.aq.then(|| {
                    let mut v = Vec::new();
                    l.weights.ints_into(grid_n, &mut v);
                    v
                });
                PreparedLayer { wq, wi }
            })
            .collect();
        PreparedModel { model, layers }
    }

    /// A prepared-model shell with **no cached planes** (zero decode,
    /// zero plane memory) for engines that serve in streaming mode
    /// (`EngineOpts::prepared = false`). The engine falls back to the
    /// per-call streaming decode for any layer whose plane is absent, so
    /// this is safe — just slow — even if `prepared` is flipped on.
    pub fn unprepared(model: DeployModel) -> PreparedModel {
        let layers = model
            .layers
            .iter()
            .map(|_| PreparedLayer { wq: Vec::new(), wi: None })
            .collect();
        PreparedModel { model, layers }
    }

    pub fn model(&self) -> &DeployModel {
        &self.model
    }

    pub fn layers(&self) -> &[PreparedLayer] {
        &self.layers
    }

    /// Bytes the cached planes occupy on top of the packed payload.
    pub fn plane_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|p| p.wq.len() * 4 + p.wi.as_ref().map_or(0, |v| v.len() * 4))
            .sum()
    }
}

/// Execution knobs of one [`Engine`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineOpts {
    /// batch-row worker threads per forward call (1 = inline, no spawn)
    pub threads: usize,
    /// run from the decode-once cached planes; `false` replays the
    /// pre-cache streaming decode on every call (benchmark reference)
    pub prepared: bool,
    /// accumulate per-layer wall time (`--layer-timing`); off-path cost
    /// is one bool test per layer — no clock read, no atomic
    pub layer_timing: bool,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts { threads: 1, prepared: true, layer_timing: false }
    }
}

/// Resolve a `--threads` CLI value — the single resolution rule shared
/// by `serve` and `bench-deploy`:
///
/// * no value -> `default`;
/// * `"auto"` -> [`std::thread::available_parallelism`] (falling back to
///   `default` if the platform cannot report it);
/// * a number -> that number, clamped to >= 1;
/// * anything else -> `default`.
pub fn resolve_threads(spec: Option<&str>, default: usize) -> usize {
    let default = default.max(1);
    match spec {
        None => default,
        Some("auto") => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(default),
        Some(v) => v.parse::<usize>().map(|n| n.max(1)).unwrap_or(default),
    }
}

/// Inference over a [`PreparedModel`].
pub struct Engine {
    prepared: Arc<PreparedModel>,
    /// use the i32 accumulation path on quantized-activation layers
    /// (false = f32 path everywhere, the closest mirror of simulated eval)
    pub int_accum: bool,
    pub opts: EngineOpts,
    /// per-layer accumulated wall time / call count, allocated only when
    /// `opts.layer_timing` is on (empty otherwise); atomics because the
    /// threaded forward's row chunks time the same layers concurrently
    layer_ns: Vec<AtomicU64>,
    layer_calls: Vec<AtomicU64>,
}

impl Engine {
    /// Engine with the integer fast path on (the deployment default).
    pub fn new(model: DeployModel) -> Self {
        Self::with_opts(model, true, EngineOpts::default())
    }

    pub fn with_mode(model: DeployModel, int_accum: bool) -> Self {
        Self::with_opts(model, int_accum, EngineOpts::default())
    }

    /// With `opts.prepared` the payloads are decoded once here; in
    /// streaming mode no planes are materialized at all (zero plane
    /// memory — the forward re-decodes per call).
    pub fn with_opts(model: DeployModel, int_accum: bool, opts: EngineOpts) -> Self {
        let prepared = if opts.prepared {
            PreparedModel::new(model)
        } else {
            PreparedModel::unprepared(model)
        };
        Self::from_prepared(Arc::new(prepared), int_accum, opts)
    }

    /// Share an already-prepared model (serving worker pools pass the
    /// same `Arc<PreparedModel>` to every engine instead of re-decoding).
    pub fn from_prepared(prepared: Arc<PreparedModel>, int_accum: bool, opts: EngineOpts) -> Self {
        let slots = if opts.layer_timing { prepared.model().layers.len() } else { 0 };
        Engine {
            prepared,
            int_accum,
            opts,
            layer_ns: (0..slots).map(|_| AtomicU64::new(0)).collect(),
            layer_calls: (0..slots).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Per-layer accumulated compute time since construction; empty when
    /// `opts.layer_timing` is off.
    pub fn layer_timing_summary(&self) -> Vec<crate::obs::LayerTime> {
        self.prepared
            .model()
            .layers
            .iter()
            .zip(self.layer_ns.iter().zip(self.layer_calls.iter()))
            .map(|(l, (ns, calls))| crate::obs::LayerTime {
                name: l.name.clone(),
                calls: calls.load(Ordering::Relaxed),
                total_ns: ns.load(Ordering::Relaxed),
            })
            .collect()
    }

    pub fn model(&self) -> &DeployModel {
        self.prepared.model()
    }

    pub fn prepared(&self) -> &Arc<PreparedModel> {
        &self.prepared
    }

    /// Forward `b` samples (`x` is `[b, input_hw*input_hw*3]` row-major
    /// flattened NHWC, same as the training `batch/x`); returns logits
    /// `[b, num_classes]`. With `opts.threads > 1` the batch rows are
    /// split into contiguous chunks, one scoped thread each; samples are
    /// independent, so the result is bit-identical to the 1-thread run.
    pub fn forward_batch(&self, x: &[f32], b: usize) -> Result<Vec<f32>> {
        let d_in = self.model().d_in();
        anyhow::ensure!(
            x.len() == b * d_in,
            "engine: input has {} elements, want {}x{}",
            x.len(),
            b,
            d_in
        );
        let threads = self.opts.threads.max(1).min(b.max(1));
        if threads <= 1 {
            return self.forward_chunk(x, b);
        }
        let nc = self.model().num_classes;
        let rows = (b + threads - 1) / threads;
        let mut out = vec![0.0f32; b * nc];
        let results: Vec<Result<()>> = std::thread::scope(|s| {
            let handles: Vec<_> = x
                .chunks(rows * d_in)
                .zip(out.chunks_mut(rows * nc))
                .map(|(xc, oc)| {
                    s.spawn(move || -> Result<()> {
                        let logits = self.forward_chunk(xc, xc.len() / d_in)?;
                        oc.copy_from_slice(&logits);
                        Ok(())
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(anyhow::anyhow!("engine worker thread panicked")))
                })
                .collect()
        });
        for r in results {
            r?;
        }
        Ok(out)
    }

    /// The full layer stack over one contiguous row chunk.
    fn forward_chunk(&self, x: &[f32], b: usize) -> Result<Vec<f32>> {
        let mut act = x.to_vec();
        for (li, (l, pl)) in
            self.prepared.model.layers.iter().zip(self.prepared.layers.iter()).enumerate()
        {
            let t0 = if self.opts.layer_timing { Some(Instant::now()) } else { None };
            let (d_in, d_out) = (l.d_in, l.d_out);
            anyhow::ensure!(
                act.len() == b * d_in,
                "engine layer {}: input has {} elements, want {}x{}",
                l.name,
                act.len(),
                b,
                d_in
            );
            let mut requant_applied = false;
            let mut z = if l.aq {
                // input activation codes on the unsigned LSQ grid; the
                // scales are per-tensor or per-input-channel (element `i`
                // of the `[b, d_in]` chunk belongs to channel `i % d_in`,
                // the same layout rule the interpreter applies)
                let codes = kernels::int_weights_pc(&act, &l.a_scales, 1, 0.0, l.act_p());
                if self.int_accum && (!l.per_channel_act() || l.op == DeployOp::DwSpatial) {
                    let qa: Vec<i32> = codes.iter().map(|&c| c as i32).collect();
                    let acc = self.linear_i32(l, pl, &qa, b);
                    // Per-output composed scale. For dense/circular-dw
                    // layers the fast path only admits per-tensor act
                    // scales (`a_scale_of` collapses to `a_scales[0]`);
                    // a spatial depthwise output element `o` reads only
                    // its own input channel `o % C`, so the per-channel
                    // act scale factors out of the dot product there too
                    // and `a_scale_of(o)` picks exactly that channel's
                    // scale (`o % n_scales`, the shared layout rule).
                    if let (Some(rq), None) = (&l.requant, &l.bias) {
                        // the per-channel requant composes with the
                        // folded-BN affine: one f64 multiply
                        // `s_a[o] * s_w[o] * mult[o]` per output element
                        // takes the i32 accumulator straight to the
                        // BN-scaled range (no intermediate f32 rounding)
                        let mult: Vec<f64> = (0..d_out)
                            .map(|o| {
                                l.a_scale_of(o) as f64
                                    * l.w_scale_of(o) as f64
                                    * rq.mult[o] as f64
                            })
                            .collect();
                        requant_applied = true;
                        acc.iter()
                            .enumerate()
                            .map(|(idx, &v)| {
                                let o = idx % d_out;
                                (mult[o] * v as f64) as f32 + rq.add[o]
                            })
                            .collect()
                    } else {
                        // one per-output requantization multiply back to
                        // the real scale: output idx -> slot idx % d_out
                        let zscales: Vec<f64> = (0..d_out)
                            .map(|o| l.a_scale_of(o) as f64 * l.w_scale_of(o) as f64)
                            .collect();
                        acc.iter()
                            .enumerate()
                            .map(|(idx, &v)| (zscales[idx % d_out] * v as f64) as f32)
                            .collect()
                    }
                } else {
                    // Per-channel activation scales do not factor out of
                    // the dot product (every input channel carries its
                    // own s_a[j]), so no per-output-channel integer
                    // requant exists; instead this path replays the
                    // interpreter's exact f32 arithmetic —
                    // `a_q[i] = s_a[i % d_in] * code_i`, then the blocked
                    // kernels over the dequantized plane — and is
                    // bit-exact vs the fake-quant reference by
                    // construction. (Per-tensor scales land here too in
                    // f32-exact mode.)
                    let ns = l.a_scales.len();
                    let a_q: Vec<f32> = codes
                        .iter()
                        .enumerate()
                        .map(|(i, &c)| l.a_scales[i % ns] * c)
                        .collect();
                    self.linear_f32(l, pl, &a_q, b)
                }
            } else {
                self.linear_f32(l, pl, &act, b)
            };
            if let Some(bias) = &l.bias {
                for bi in 0..b {
                    for c in 0..d_out {
                        z[bi * d_out + c] += bias[c];
                    }
                }
            }
            if let Some(rq) = &l.requant {
                if !requant_applied {
                    for bi in 0..b {
                        for c in 0..d_out {
                            let idx = bi * d_out + c;
                            z[idx] = rq.mult[c] * z[idx] + rq.add[c];
                        }
                    }
                }
            }
            if l.relu {
                for v in z.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            act = z;
            if let Some(t0) = t0 {
                self.layer_ns[li].fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                self.layer_calls[li].fetch_add(1, Ordering::Relaxed);
            }
        }
        Ok(act)
    }

    /// One f32-path linear op: cached plane when prepared (and the plane
    /// exists — an [`PreparedModel::unprepared`] shell has none),
    /// streaming decode otherwise.
    fn linear_f32(&self, l: &DeployLayer, pl: &PreparedLayer, x: &[f32], b: usize) -> Vec<f32> {
        if self.opts.prepared && pl.wq.len() == l.weights.len {
            let mut out = vec![0.0f32; b * l.d_out];
            match l.op {
                DeployOp::Full => matmul_f32(x, &pl.wq, b, l.d_in, l.d_out, &mut out),
                DeployOp::Dw => dw_f32(x, &pl.wq, b, l.d_out, &mut out),
                DeployOp::DwSpatial => {
                    let sp = l.spatial.expect("DwSpatial layer without metadata");
                    dw_spatial_f32(
                        x, &pl.wq, b, sp.hw_in, sp.channels, sp.stride, sp.pad, &mut out,
                    )
                }
            }
            out
        } else {
            match l.op {
                DeployOp::Full => {
                    packed_matmul(x, &l.weights, b, l.d_in, l.d_out, &l.w_scales, l.grid_n_int())
                }
                DeployOp::Dw => {
                    packed_dw(x, &l.weights, b, l.d_out, &l.w_scales, l.grid_n_int())
                }
                DeployOp::DwSpatial => {
                    let sp = l.spatial.expect("DwSpatial layer without metadata");
                    packed_dw_spatial(
                        x,
                        &l.weights,
                        b,
                        sp.hw_in,
                        sp.channels,
                        sp.stride,
                        sp.pad,
                        &l.w_scales,
                        l.grid_n_int(),
                    )
                }
            }
        }
    }

    /// One i32-path linear op: cached integer plane when prepared and
    /// materialized, streaming decode otherwise.
    fn linear_i32(&self, l: &DeployLayer, pl: &PreparedLayer, qa: &[i32], b: usize) -> Vec<i32> {
        match (self.opts.prepared, pl.wi.as_ref()) {
            (true, Some(wi)) => {
                let mut out = vec![0i32; b * l.d_out];
                match l.op {
                    DeployOp::Full => matmul_i32(qa, wi, b, l.d_in, l.d_out, &mut out),
                    DeployOp::Dw => dw_i32(qa, wi, b, l.d_out, &mut out),
                    DeployOp::DwSpatial => {
                        let sp = l.spatial.expect("DwSpatial layer without metadata");
                        dw_spatial_i32(
                            qa, wi, b, sp.hw_in, sp.channels, sp.stride, sp.pad, &mut out,
                        )
                    }
                }
                out
            }
            _ => match l.op {
                DeployOp::Full => {
                    packed_matmul_i32(qa, &l.weights, b, l.d_in, l.d_out, l.grid_n_int())
                }
                DeployOp::Dw => packed_dw_i32(qa, &l.weights, b, l.d_out, l.grid_n_int()),
                DeployOp::DwSpatial => {
                    let sp = l.spatial.expect("DwSpatial layer without metadata");
                    packed_dw_spatial_i32(
                        qa,
                        &l.weights,
                        b,
                        sp.hw_in,
                        sp.channels,
                        sp.stride,
                        sp.pad,
                        l.grid_n_int(),
                    )
                }
            },
        }
    }

    /// Top-1 class per sample (first index on ties, like `Tensor::argmax`).
    pub fn predict_batch(&self, x: &[f32], b: usize) -> Result<Vec<usize>> {
        let logits = self.forward_batch(x, b)?;
        let nc = self.model().num_classes;
        Ok((0..b).map(|i| argmax(&logits[i * nc..(i + 1) * nc])).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::weight_grid;
    use crate::rng::Pcg32;
    use crate::runtime::native::kernels::quant_matmul;

    fn pack_weights(w: &[f32], s: f32, bits: u32) -> (Packed, i32) {
        // the exporter's own mapping, so these tests cannot drift from it
        crate::deploy::export::snap_and_pack(w, s, bits).unwrap()
    }

    /// The pre-blocking scalar reference: plain triple loop, `kk`
    /// ascending, `a == 0.0` skipped — the order contract the blocked
    /// kernel must preserve per output element.
    fn matmul_f32_scalar(x: &[f32], wq: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let a = x[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out[i * n + j] += a * wq[kk * n + j];
                }
            }
        }
        out
    }

    fn dw_scalar(x: &[f32], wq: &[f32], b: usize, c_dim: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; b * c_dim];
        for bi in 0..b {
            for c in 0..c_dim {
                let mut acc = 0.0f32;
                for t in 0..3usize {
                    let j = (c + t + c_dim - 1) % c_dim;
                    acc += wq[c * 3 + t] * x[bi * c_dim + j];
                }
                out[bi * c_dim + c] = acc;
            }
        }
        out
    }

    #[test]
    fn blocked_matmul_bitexact_vs_scalar_reference() {
        let mut rng = Pcg32::new(7, 0xb10c);
        // odd k exercises the 2-way tail; k > KB exercises panel edges
        for (m, k, n) in [(1usize, 5usize, 3usize), (3, 17, 5), (4, 65, 7), (2, 130, 9)] {
            let mut x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            for i in (0..x.len()).step_by(3) {
                x[i] = 0.0; // exercise every zero-skip arm
            }
            let wq: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
            let mut got = vec![0.0f32; m * n];
            matmul_f32(&x, &wq, m, k, n, &mut got);
            assert_eq!(got, matmul_f32_scalar(&x, &wq, m, k, n), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn unrolled_dw_bitexact_vs_modulo_reference() {
        let mut rng = Pcg32::new(8, 0xd0);
        for c_dim in [1usize, 2, 3, 4, 9, 17] {
            let b = 3usize;
            let x: Vec<f32> = (0..b * c_dim).map(|_| rng.normal()).collect();
            let wq: Vec<f32> = (0..c_dim * 3).map(|_| rng.normal() * 0.3).collect();
            let mut got = vec![0.0f32; b * c_dim];
            dw_f32(&x, &wq, b, c_dim, &mut got);
            assert_eq!(got, dw_scalar(&x, &wq, b, c_dim), "c_dim {c_dim}");
        }
    }

    #[test]
    fn integer_kernels_match_scalar_loops() {
        let mut rng = Pcg32::new(9, 0x132);
        let (m, k, n) = (3usize, 33, 6);
        let qa: Vec<i32> = (0..m * k).map(|_| rng.below(16) as i32 - 1).collect();
        let wi: Vec<i32> = (0..k * n).map(|_| rng.below(15) as i32 - 7).collect();
        let mut got = vec![0i32; m * n];
        matmul_i32(&qa, &wi, m, k, n, &mut got);
        let mut want = vec![0i32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    want[i * n + j] += qa[i * k + kk] * wi[kk * n + j];
                }
            }
        }
        assert_eq!(got, want);

        let c_dim = 9usize;
        let qa: Vec<i32> = (0..m * c_dim).map(|_| rng.below(16) as i32).collect();
        let wi: Vec<i32> = (0..c_dim * 3).map(|_| rng.below(15) as i32 - 7).collect();
        let mut got = vec![0i32; m * c_dim];
        dw_i32(&qa, &wi, m, c_dim, &mut got);
        for bi in 0..m {
            for c in 0..c_dim {
                let mut acc = 0i32;
                for t in 0..3usize {
                    let j = (c + t + c_dim - 1) % c_dim;
                    acc += wi[c * 3 + t] * qa[bi * c_dim + j];
                }
                assert_eq!(got[bi * c_dim + c], acc, "[{bi},{c}]");
            }
        }
    }

    #[test]
    fn packed_matmul_bitexact_vs_quant_matmul() {
        let mut rng = Pcg32::new(11, 0xde);
        for bits in [2u32, 3, 4, 8] {
            let (gn, gp) = weight_grid(bits);
            let (m, k, n) = (3usize, 17, 5);
            let s = rng.uniform(0.01, 0.4);
            let mut x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            // exact zeros exercise the skip fast path
            for i in (0..x.len()).step_by(4) {
                x[i] = 0.0;
            }
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
            let (packed, grid_n) = pack_weights(&w, s, bits);
            let got = packed_matmul(&x, &packed, m, k, n, &[s], grid_n);
            let want = quant_matmul(&x, &w, m, k, n, s, gn, gp);
            assert_eq!(got, want, "bits {bits}");
        }
    }

    #[test]
    fn packed_matmul_per_channel_bitexact_vs_fake_quant_pc() {
        use crate::deploy::export::snap_and_pack_pc;
        use crate::runtime::native::kernels::fake_quant_pc;
        let mut rng = Pcg32::new(21, 0xfe);
        for bits in [2u32, 3, 4, 8] {
            let (m, k, n) = (3usize, 11, 6);
            let scales: Vec<f32> = (0..n).map(|_| rng.uniform(0.01, 0.4)).collect();
            let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
            let (packed, grid_n) = snap_and_pack_pc(&w, &scales, 1, bits).unwrap();
            let got = packed_matmul(&x, &packed, m, k, n, &scales, grid_n);
            // reference: per-channel fake-quant then the same loop order
            let (gn, gp) = weight_grid(bits);
            let wq = fake_quant_pc(&w, &scales, 1, gn, gp);
            let want = matmul_f32_scalar(&x, &wq, m, k, n);
            assert_eq!(got, want, "bits {bits}");
        }
    }

    fn tiny_pc_model() -> DeployModel {
        use crate::deploy::export::snap_and_pack_pc;
        use crate::deploy::format::Requant;
        let (d_in, d_out) = (12usize, 3usize);
        let scales = vec![0.5f32, 0.25, 0.125];
        let mut rng = Pcg32::new(9, 0x77);
        let w: Vec<f32> = (0..d_in * d_out)
            .map(|i| (rng.below(15) as f32 - 7.0) * scales[i % d_out])
            .collect();
        let (packed, _grid_n) = snap_and_pack_pc(&w, &scales, 1, 4).unwrap();
        let layer = DeployLayer {
            name: "l".into(),
            op: DeployOp::Full,
            d_in,
            d_out,
            relu: false,
            aq: true,
            act_bits: 3,
            a_scales: vec![0.5],
            w_bits: 4,
            w_scales: scales.clone(),
            weights: packed,
            bias: Some(vec![0.25, -0.5, 0.125]),
            requant: Some(Requant {
                mult: vec![2.0, 0.5, 1.0],
                add: vec![0.5, -0.25, 0.0],
            }),
            spatial: None,
        };
        DeployModel {
            name: "pc".into(),
            input_hw: 2,
            num_classes: 3,
            quant_a: true,
            bits_w: 4,
            bits_a: 3,
            layers: vec![layer],
        }
    }

    /// `tiny_pc_model` without bias (so the i32 requant composes with
    /// the BN affine into one per-channel factor).
    fn tiny_pc_model_no_bias() -> DeployModel {
        let mut m = tiny_pc_model();
        m.layers[0].bias = None;
        m
    }

    /// `tiny_pc_model` with per-input-channel activation scales (QPKG
    /// v3): power-of-two values so every f32 op stays exact.
    fn tiny_pcact_model() -> DeployModel {
        let mut m = tiny_pc_model();
        m.layers[0].a_scales = (0..12).map(|j| if j % 2 == 0 { 0.5 } else { 0.25 }).collect();
        m
    }

    #[test]
    fn thread_spec_resolution_rule() {
        assert_eq!(resolve_threads(None, 2), 2);
        assert_eq!(resolve_threads(Some("4"), 1), 4);
        assert_eq!(resolve_threads(Some("0"), 1), 1, "numbers clamp to >= 1");
        assert_eq!(resolve_threads(Some("nope"), 3), 3, "garbage falls back");
        assert_eq!(resolve_threads(None, 0), 1, "default clamps to >= 1");
        let auto = resolve_threads(Some("auto"), 1);
        assert!(auto >= 1, "auto resolves to the machine's parallelism");
    }

    #[test]
    fn composed_requant_matches_sequential_on_pow2() {
        // without a bias the i32 path folds s_a*s_w[c] into the BN
        // affine's mult[c]; on power-of-two scales every op is exact, so
        // the composed path must equal the f32-exact engine to the bit
        let dm = tiny_pc_model_no_bias();
        let mut rng = Pcg32::new(17, 0x99);
        let x: Vec<f32> = (0..3 * 12).map(|_| rng.below(8) as f32 * 0.5).collect();
        let exact = Engine::with_mode(dm.clone(), false).forward_batch(&x, 3).unwrap();
        let int = Engine::with_mode(dm, true).forward_batch(&x, 3).unwrap();
        assert_eq!(exact, int);
    }

    #[test]
    fn per_channel_act_engine_is_exact_and_mode_stable() {
        // per-channel activation scales: the engine replays the
        // interpreter's f32 arithmetic in every mode — int-accum,
        // f32-exact, prepared, streaming, threaded — bit-identically
        let dm = tiny_pcact_model();
        let mut rng = Pcg32::new(18, 0x9a);
        let b = 5usize;
        let x: Vec<f32> = (0..b * 12).map(|_| rng.below(8) as f32 * 0.5).collect();
        let reference = {
            // interpreter math: per-channel act fake-quant then the
            // scalar-order matmul over per-channel fake-quant weights
            let l = &dm.layers[0];
            let codes = kernels::int_weights_pc(&x, &l.a_scales, 1, 0.0, l.act_p());
            let a_q: Vec<f32> =
                codes.iter().enumerate().map(|(i, &c)| l.a_scales[i % 12] * c).collect();
            let mut w = Vec::new();
            l.weights.dequant_pc_into(l.grid_n_int(), &l.w_scales, 1, &mut w);
            let mut out = matmul_f32_scalar(&a_q, &w, b, 12, 3);
            for bi in 0..b {
                for c in 0..3 {
                    let idx = bi * 3 + c;
                    out[idx] += l.bias.as_ref().unwrap()[c];
                    let rq = l.requant.as_ref().unwrap();
                    out[idx] = rq.mult[c] * out[idx] + rq.add[c];
                }
            }
            out
        };
        for int_accum in [false, true] {
            for opts in [
                EngineOpts::default(),
                EngineOpts { prepared: false, ..Default::default() },
                EngineOpts { threads: 3, ..Default::default() },
            ] {
                let got = Engine::with_opts(dm.clone(), int_accum, opts)
                    .forward_batch(&x, b)
                    .unwrap();
                assert_eq!(got, reference, "int_accum {int_accum} opts {opts:?}");
            }
        }
    }

    #[test]
    fn i32_per_channel_requant_composes_with_bn_affine() {
        // power-of-two scales: every f32 op is exact, so the int-accum
        // engine must agree with the f32-exact engine to the bit even
        // with per-channel weight scales + a folded BN affine on top
        let dm = tiny_pc_model();
        let mut rng = Pcg32::new(10, 0x78);
        let x: Vec<f32> = (0..2 * 12).map(|_| rng.below(8) as f32 * 0.5).collect();
        let exact = Engine::with_mode(dm.clone(), false).forward_batch(&x, 2).unwrap();
        let int = Engine::with_mode(dm, true).forward_batch(&x, 2).unwrap();
        assert_eq!(exact, int);
    }

    #[test]
    fn prepared_streaming_and_threaded_forwards_agree() {
        // the decode-once planes, the per-call streaming decode, and the
        // scoped-thread batch split must all produce identical logits
        let dm = tiny_pc_model();
        let mut rng = Pcg32::new(12, 0x99);
        let b = 7usize; // odd batch: uneven final thread chunk
        let x: Vec<f32> = (0..b * 12).map(|_| rng.below(8) as f32 * 0.5).collect();
        for int_accum in [false, true] {
            let prepared = Engine::with_opts(dm.clone(), int_accum, EngineOpts::default())
                .forward_batch(&x, b)
                .unwrap();
            let streaming = Engine::with_opts(
                dm.clone(),
                int_accum,
                EngineOpts { prepared: false, ..Default::default() },
            )
            .forward_batch(&x, b)
            .unwrap();
            assert_eq!(prepared, streaming, "int_accum {int_accum}");
            // a plane-less shell (streaming serve mode) must agree too,
            // even if `prepared` is (mis)set: the engine falls back to
            // the streaming decode when a plane is absent
            for prep_flag in [false, true] {
                let shell = Engine::from_prepared(
                    Arc::new(PreparedModel::unprepared(dm.clone())),
                    int_accum,
                    EngineOpts { prepared: prep_flag, ..Default::default() },
                )
                .forward_batch(&x, b)
                .unwrap();
                assert_eq!(prepared, shell, "int_accum {int_accum} shell prep {prep_flag}");
            }
            for threads in [2usize, 3, 16] {
                let mt = Engine::with_opts(
                    dm.clone(),
                    int_accum,
                    EngineOpts { threads, ..Default::default() },
                )
                .forward_batch(&x, b)
                .unwrap();
                assert_eq!(prepared, mt, "int_accum {int_accum} threads {threads}");
            }
        }
    }

    #[test]
    fn prepared_model_caches_expected_planes() {
        let dm = tiny_pc_model();
        let pm = PreparedModel::new(dm);
        assert_eq!(pm.layers().len(), 1);
        let pl = &pm.layers()[0];
        assert_eq!(pl.wq.len(), 36);
        // aq layer: integer plane materialized, and consistent with wq
        let wi = pl.wi.as_ref().unwrap();
        assert_eq!(wi.len(), 36);
        for (i, (&q, &f)) in wi.iter().zip(&pl.wq).enumerate() {
            let s = pm.model().layers[0].w_scale_of(i % 3);
            assert_eq!(f, s * q as f32, "plane mismatch at {i}");
        }
        assert_eq!(pm.plane_bytes(), 36 * 8);
    }

    /// Scalar reference for the spatial depthwise kernels: per output
    /// element, taps in ascending `(ky, kx)` with out-of-bounds skipped
    /// — the interpreter's exact term order.
    #[allow(clippy::too_many_arguments)]
    fn dw_spatial_scalar(
        x: &[f32],
        wq: &[f32],
        b: usize,
        hw_in: usize,
        c_dim: usize,
        stride: usize,
        pad: usize,
    ) -> Vec<f32> {
        let hw_out = (hw_in + 2 * pad - 3) / stride + 1;
        let mut out = vec![0.0f32; b * hw_out * hw_out * c_dim];
        for bi in 0..b {
            for yo in 0..hw_out {
                for xo in 0..hw_out {
                    for c in 0..c_dim {
                        let mut acc = 0.0f32;
                        for ky in 0..3usize {
                            let y = yo * stride + ky;
                            if y < pad || y - pad >= hw_in {
                                continue;
                            }
                            for kx in 0..3usize {
                                let xx = xo * stride + kx;
                                if xx < pad || xx - pad >= hw_in {
                                    continue;
                                }
                                let j = ((y - pad) * hw_in + (xx - pad)) * c_dim + c;
                                acc +=
                                    wq[c * 9 + ky * 3 + kx] * x[bi * hw_in * hw_in * c_dim + j];
                            }
                        }
                        out[(bi * hw_out * hw_out + yo * hw_out + xo) * c_dim + c] = acc;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn spatial_dw_kernels_match_scalar_reference() {
        let mut rng = Pcg32::new(31, 0x2d);
        // geometry sweep: padded same-size, strided downsample, valid
        // (pad 0) 3x3 -> 1x1, strided valid, and a 1x1 input where every
        // output tap but the centre falls in the padding
        for (hw_in, c_dim, stride, pad) in
            [(4usize, 3usize, 1usize, 1usize), (4, 5, 2, 1), (3, 2, 1, 0), (5, 4, 2, 0), (1, 3, 1, 1)]
        {
            let b = 2usize;
            let hw_out = (hw_in + 2 * pad - 3) / stride + 1;
            let x: Vec<f32> = (0..b * hw_in * hw_in * c_dim).map(|_| rng.normal()).collect();
            let wq: Vec<f32> = (0..c_dim * 9).map(|_| rng.normal() * 0.3).collect();
            let mut got = vec![0.0f32; b * hw_out * hw_out * c_dim];
            dw_spatial_f32(&x, &wq, b, hw_in, c_dim, stride, pad, &mut got);
            assert_eq!(
                got,
                dw_spatial_scalar(&x, &wq, b, hw_in, c_dim, stride, pad),
                "f32 {hw_in}x{hw_in}x{c_dim} s{stride} p{pad}"
            );
            // integer twin: small codes keep every product exact in f32,
            // so the f32 scalar reference doubles as the i32 oracle
            let qa: Vec<i32> =
                (0..b * hw_in * hw_in * c_dim).map(|_| rng.below(16) as i32).collect();
            let wi: Vec<i32> = (0..c_dim * 9).map(|_| rng.below(15) as i32 - 7).collect();
            let mut goti = vec![0i32; b * hw_out * hw_out * c_dim];
            dw_spatial_i32(&qa, &wi, b, hw_in, c_dim, stride, pad, &mut goti);
            let xf: Vec<f32> = qa.iter().map(|&v| v as f32).collect();
            let wf: Vec<f32> = wi.iter().map(|&v| v as f32).collect();
            let want = dw_spatial_scalar(&xf, &wf, b, hw_in, c_dim, stride, pad);
            let gotf: Vec<f32> = goti.iter().map(|&v| v as f32).collect();
            assert_eq!(gotf, want, "i32 {hw_in}x{hw_in}x{c_dim} s{stride} p{pad}");
        }
    }

    /// A single spatial depthwise layer (2x2 input, 3 channels, pad 1)
    /// with per-channel weight AND activation scales on power-of-two
    /// grids plus a folded-BN requant and no bias: the configuration
    /// where the QPKG v4 exact-integer fast path must engage despite
    /// `per_channel_act()`.
    fn tiny_spatial_model() -> DeployModel {
        use crate::deploy::export::snap_and_pack_pc;
        use crate::deploy::format::{DwSpatialMeta, Requant};
        let (hw, nc) = (2usize, 3usize);
        let d = hw * hw * nc;
        let w_scales = vec![0.5f32, 0.25, 0.125];
        let mut rng = Pcg32::new(23, 0x5b);
        let w: Vec<f32> = (0..nc * 9)
            .map(|i| (rng.below(15) as f32 - 7.0) * w_scales[i / 9])
            .collect();
        let (packed, _grid_n) = snap_and_pack_pc(&w, &w_scales, 9, 4).unwrap();
        let layer = DeployLayer {
            name: "dw2d".into(),
            op: DeployOp::DwSpatial,
            d_in: d,
            d_out: d,
            relu: true,
            aq: true,
            act_bits: 4,
            a_scales: vec![0.5, 0.25, 0.125],
            w_bits: 4,
            w_scales,
            weights: packed,
            bias: None,
            requant: Some(Requant {
                mult: (0..d).map(|o| if o % 2 == 0 { 2.0 } else { 0.5 }).collect(),
                add: (0..d).map(|o| -0.25 + 0.25 * (o % 3) as f32).collect(),
            }),
            spatial: Some(DwSpatialMeta {
                kernel: 3,
                stride: 1,
                pad: 1,
                hw_in: hw,
                channels: nc,
            }),
        };
        DeployModel {
            name: "sp".into(),
            input_hw: 2,
            num_classes: d,
            quant_a: true,
            bits_w: 4,
            bits_a: 4,
            layers: vec![layer],
        }
    }

    #[test]
    fn spatial_per_channel_act_runs_exact_i32_fast_path() {
        // power-of-two scales: every f32 op is exact, so the int-accum
        // engine must agree with the f32-exact engine to the bit — and
        // it must do so *despite* per-channel activation scales, because
        // a spatial depthwise output only ever reads its own channel
        let dm = tiny_spatial_model();
        let mut rng = Pcg32::new(29, 0xaa);
        let b = 3usize;
        // activations already on each channel's pow2 grid (channel of
        // flat element i is i % 3: d_in = 12 is a multiple of 3)
        let x: Vec<f32> = (0..b * 12)
            .map(|i| rng.below(16) as f32 * dm.layers[0].a_scales[i % 3])
            .collect();
        let exact = Engine::with_mode(dm.clone(), false).forward_batch(&x, b).unwrap();
        let int = Engine::with_mode(dm.clone(), true).forward_batch(&x, b).unwrap();
        assert_eq!(exact, int);
        // every execution mode agrees bit-for-bit
        for int_accum in [false, true] {
            for opts in [
                EngineOpts { prepared: false, ..Default::default() },
                EngineOpts { threads: 2, ..Default::default() },
            ] {
                let got = Engine::with_opts(dm.clone(), int_accum, opts)
                    .forward_batch(&x, b)
                    .unwrap();
                assert_eq!(got, exact, "int_accum {int_accum} opts {opts:?}");
            }
        }
    }

    #[test]
    fn packed_dw_matches_dense_reference() {
        let mut rng = Pcg32::new(5, 0xd3);
        let (b, c) = (4usize, 9usize);
        let s = 0.07f32;
        let bits = 3;
        let (gn, gp) = weight_grid(bits);
        let x: Vec<f32> = (0..b * c).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..c * 3).map(|_| rng.normal() * 0.3).collect();
        let (packed, grid_n) = pack_weights(&w, s, bits);
        let got = packed_dw(&x, &packed, b, c, &[s], grid_n);
        let wq = kernels::fake_quant(&w, s, gn, gp);
        assert_eq!(got, dw_scalar(&x, &wq, b, c));
    }

    #[test]
    fn i32_path_exact_on_pow2_grids() {
        // power-of-two scales + small integers: every f32 op is exact, so
        // the i32 accumulation must agree with the float path to the bit
        let mut rng = Pcg32::new(3, 0x1a);
        let (s_a, s_w) = (0.5f32, 0.25f32);
        let bits = 4;
        let (m, k, n) = (2usize, 8, 6);
        let qa_codes: Vec<i32> = (0..m * k).map(|_| rng.below(8) as i32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| (rng.below(15) as f32 - 7.0) * s_w).collect();
        let (packed, grid_n) = pack_weights(&w, s_w, bits);

        let acc = packed_matmul_i32(&qa_codes, &packed, m, k, n, grid_n);
        let zscale = s_a as f64 * s_w as f64;
        let got: Vec<f32> = acc.iter().map(|&v| (zscale * v as f64) as f32).collect();

        let a_q: Vec<f32> = qa_codes.iter().map(|&c| s_a * c as f32).collect();
        let want = packed_matmul(&a_q, &packed, m, k, n, &[s_w], grid_n);
        assert_eq!(got, want);
    }

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[0.5]), 0);
    }
}
