//! The packed low-bit inference engine.
//!
//! Two execution paths per layer, both reading weights straight out of
//! the bit-packed QPKG payload:
//!
//! * **f32 path** ([`packed_matmul`] / [`packed_dw`]) — weights are
//!   dequantized on the fly (`s_c * grid_int`, one exact multiply with
//!   the channel's scale) and the accumulation replays the native
//!   interpreter's loop order including its `a == 0.0` skip, so the
//!   output is **bit-exact** against the native fake-quant kernels over
//!   per-tensor *and* per-channel scale vectors. This is the path for
//!   layers whose input activations are not quantized (the stem, and
//!   every layer of a weight-only run).
//! * **i32 path** ([`packed_matmul_i32`] / [`packed_dw_i32`]) — input
//!   activations arrive as unsigned grid codes, weights as signed grid
//!   integers, and the dot product accumulates in i32 (exact integer
//!   arithmetic, no rounding at all); one per-channel requantization
//!   multiply (`s_a * s_w[c] * acc`, in f64) brings the result back to
//!   the real scale — per-channel weight scales factor out of each
//!   output channel's dot product, so the stored integers never change.
//!   Worst case here (255 x 127 x 768-deep) stays far inside i32 range.
//!
//! After the linear op the folded-BN requant affine (`mult[c]*z+add[c]`),
//! bias and ReLU are applied per channel — there is no batch-norm op and
//! no running statistic left at inference time.

use super::format::{DeployModel, DeployOp};
use super::packed::Packed;
use crate::runtime::native::kernels;
use anyhow::Result;

pub use crate::tensor::argmax;

/// `x [m,k] @ dequant(w) [k,n]`, bit-exact vs `kernels::quant_matmul`
/// (per-tensor `scales = [s]`) / `kernels::fake_quant_pc` + the same
/// loop order (same `a == 0.0` skip). `scales` holds one scale or one
/// per output column.
pub fn packed_matmul(
    x: &[f32],
    w: &Packed,
    m: usize,
    k: usize,
    n: usize,
    scales: &[f32],
    grid_n: i32,
) -> Vec<f32> {
    debug_assert_eq!(w.len, k * n);
    debug_assert!(scales.len() == 1 || scales.len() == n);
    let mut wq = Vec::new();
    w.dequant_pc_into(grid_n, scales, 1, &mut wq);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let a = x[i * k + kk];
            if a == 0.0 {
                continue;
            }
            let row = &wq[kk * n..(kk + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += a * row[j];
            }
        }
    }
    out
}

/// Circular depthwise 3-tap conv with on-the-fly dequantized weights
/// (`scales`: one scale or one per channel row), mirroring the native
/// interpreter's loop exactly.
pub fn packed_dw(
    x: &[f32],
    w: &Packed,
    b: usize,
    c_dim: usize,
    scales: &[f32],
    grid_n: i32,
) -> Vec<f32> {
    debug_assert_eq!(w.len, c_dim * 3);
    debug_assert!(scales.len() == 1 || scales.len() == c_dim);
    let mut wq = Vec::new();
    w.dequant_pc_into(grid_n, scales, 3, &mut wq);
    let mut out = vec![0.0f32; b * c_dim];
    for bi in 0..b {
        let arow = &x[bi * c_dim..(bi + 1) * c_dim];
        let orow = &mut out[bi * c_dim..(bi + 1) * c_dim];
        for c in 0..c_dim {
            let mut acc = 0.0f32;
            for t in 0..3usize {
                let j = (c + t + c_dim - 1) % c_dim;
                acc += wq[c * 3 + t] * arow[j];
            }
            orow[c] = acc;
        }
    }
    out
}

/// Integer matmul: unsigned activation codes x signed weight integers,
/// i32 accumulation. Zero codes are skipped (the integer twin of the
/// float path's `a == 0.0` fast path — `a_q == 0` iff its code is 0).
pub fn packed_matmul_i32(
    qa: &[i32],
    w: &Packed,
    m: usize,
    k: usize,
    n: usize,
    grid_n: i32,
) -> Vec<i32> {
    debug_assert_eq!(w.len, k * n);
    let mut wi = Vec::new();
    w.ints_into(grid_n, &mut wi);
    let mut out = vec![0i32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let a = qa[i * k + kk];
            if a == 0 {
                continue;
            }
            let row = &wi[kk * n..(kk + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += a * row[j];
            }
        }
    }
    out
}

/// Integer circular depthwise 3-tap conv with i32 accumulation.
pub fn packed_dw_i32(qa: &[i32], w: &Packed, b: usize, c_dim: usize, grid_n: i32) -> Vec<i32> {
    debug_assert_eq!(w.len, c_dim * 3);
    let mut wi = Vec::new();
    w.ints_into(grid_n, &mut wi);
    let mut out = vec![0i32; b * c_dim];
    for bi in 0..b {
        let arow = &qa[bi * c_dim..(bi + 1) * c_dim];
        let orow = &mut out[bi * c_dim..(bi + 1) * c_dim];
        for c in 0..c_dim {
            let mut acc = 0i32;
            for t in 0..3usize {
                let j = (c + t + c_dim - 1) % c_dim;
                acc += wi[c * 3 + t] * arow[j];
            }
            orow[c] = acc;
        }
    }
    out
}

/// Inference over a [`DeployModel`].
pub struct Engine {
    model: DeployModel,
    /// use the i32 accumulation path on quantized-activation layers
    /// (false = f32 path everywhere, the closest mirror of simulated eval)
    pub int_accum: bool,
}

impl Engine {
    /// Engine with the integer fast path on (the deployment default).
    pub fn new(model: DeployModel) -> Self {
        Engine { model, int_accum: true }
    }

    pub fn with_mode(model: DeployModel, int_accum: bool) -> Self {
        Engine { model, int_accum }
    }

    pub fn model(&self) -> &DeployModel {
        &self.model
    }

    /// Forward `b` samples (`x` is `[b, input_hw*input_hw*3]` row-major
    /// flattened NHWC, same as the training `batch/x`); returns logits
    /// `[b, num_classes]`.
    pub fn forward_batch(&self, x: &[f32], b: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(
            x.len() == b * self.model.d_in(),
            "engine: input has {} elements, want {}x{}",
            x.len(),
            b,
            self.model.d_in()
        );
        let mut act = x.to_vec();
        for l in &self.model.layers {
            let (d_in, d_out) = (l.d_in, l.d_out);
            anyhow::ensure!(
                act.len() == b * d_in,
                "engine layer {}: input has {} elements, want {}x{}",
                l.name,
                act.len(),
                b,
                d_in
            );
            let grid_n = l.grid_n_int();
            let mut z = if l.aq {
                // input activation codes on the unsigned LSQ grid
                let codes = kernels::int_weights(&act, l.a_scale, 0.0, l.act_p());
                if self.int_accum {
                    let qa: Vec<i32> = codes.iter().map(|&c| c as i32).collect();
                    let acc = match l.op {
                        DeployOp::Full => {
                            packed_matmul_i32(&qa, &l.weights, b, d_in, d_out, grid_n)
                        }
                        DeployOp::Dw => packed_dw_i32(&qa, &l.weights, b, d_out, grid_n),
                    };
                    // one per-channel requantization multiply back to the
                    // real scale: output idx -> channel idx % d_out
                    let sa = l.a_scale as f64;
                    let zscales: Vec<f64> =
                        (0..d_out).map(|c| sa * l.w_scale_of(c) as f64).collect();
                    acc.iter()
                        .enumerate()
                        .map(|(idx, &v)| (zscales[idx % d_out] * v as f64) as f32)
                        .collect()
                } else {
                    let a_q: Vec<f32> = codes.iter().map(|&c| l.a_scale * c).collect();
                    match l.op {
                        DeployOp::Full => {
                            packed_matmul(&a_q, &l.weights, b, d_in, d_out, &l.w_scales, grid_n)
                        }
                        DeployOp::Dw => {
                            packed_dw(&a_q, &l.weights, b, d_out, &l.w_scales, grid_n)
                        }
                    }
                }
            } else {
                match l.op {
                    DeployOp::Full => {
                        packed_matmul(&act, &l.weights, b, d_in, d_out, &l.w_scales, grid_n)
                    }
                    DeployOp::Dw => packed_dw(&act, &l.weights, b, d_out, &l.w_scales, grid_n),
                }
            };
            if let Some(bias) = &l.bias {
                for bi in 0..b {
                    for c in 0..d_out {
                        z[bi * d_out + c] += bias[c];
                    }
                }
            }
            if let Some(rq) = &l.requant {
                for bi in 0..b {
                    for c in 0..d_out {
                        let idx = bi * d_out + c;
                        z[idx] = rq.mult[c] * z[idx] + rq.add[c];
                    }
                }
            }
            if l.relu {
                for v in z.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            act = z;
        }
        Ok(act)
    }

    /// Top-1 class per sample (first index on ties, like `Tensor::argmax`).
    pub fn predict_batch(&self, x: &[f32], b: usize) -> Result<Vec<usize>> {
        let logits = self.forward_batch(x, b)?;
        let nc = self.model.num_classes;
        Ok((0..b).map(|i| argmax(&logits[i * nc..(i + 1) * nc])).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::weight_grid;
    use crate::rng::Pcg32;
    use crate::runtime::native::kernels::quant_matmul;

    fn pack_weights(w: &[f32], s: f32, bits: u32) -> (Packed, i32) {
        // the exporter's own mapping, so these tests cannot drift from it
        crate::deploy::export::snap_and_pack(w, s, bits).unwrap()
    }

    #[test]
    fn packed_matmul_bitexact_vs_quant_matmul() {
        let mut rng = Pcg32::new(11, 0xde);
        for bits in [2u32, 3, 4, 8] {
            let (gn, gp) = weight_grid(bits);
            let (m, k, n) = (3usize, 17, 5);
            let s = rng.uniform(0.01, 0.4);
            let mut x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            // exact zeros exercise the skip fast path
            for i in (0..x.len()).step_by(4) {
                x[i] = 0.0;
            }
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
            let (packed, grid_n) = pack_weights(&w, s, bits);
            let got = packed_matmul(&x, &packed, m, k, n, &[s], grid_n);
            let want = quant_matmul(&x, &w, m, k, n, s, gn, gp);
            assert_eq!(got, want, "bits {bits}");
        }
    }

    #[test]
    fn packed_matmul_per_channel_bitexact_vs_fake_quant_pc() {
        use crate::deploy::export::snap_and_pack_pc;
        use crate::runtime::native::kernels::fake_quant_pc;
        let mut rng = Pcg32::new(21, 0xfe);
        for bits in [2u32, 3, 4, 8] {
            let (m, k, n) = (3usize, 11, 6);
            let scales: Vec<f32> = (0..n).map(|_| rng.uniform(0.01, 0.4)).collect();
            let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
            let (packed, grid_n) = snap_and_pack_pc(&w, &scales, 1, bits).unwrap();
            let got = packed_matmul(&x, &packed, m, k, n, &scales, grid_n);
            // reference: per-channel fake-quant then the same loop order
            let (gn, gp) = weight_grid(bits);
            let wq = fake_quant_pc(&w, &scales, 1, gn, gp);
            let mut want = vec![0.0f32; m * n];
            for i in 0..m {
                for kk in 0..k {
                    let a = x[i * k + kk];
                    if a == 0.0 {
                        continue;
                    }
                    for j in 0..n {
                        want[i * n + j] += a * wq[kk * n + j];
                    }
                }
            }
            assert_eq!(got, want, "bits {bits}");
        }
    }

    #[test]
    fn i32_per_channel_requant_composes_with_bn_affine() {
        use crate::deploy::format::{DeployLayer, DeployModel, DeployOp, Requant};
        use crate::deploy::export::snap_and_pack_pc;
        // power-of-two scales: every f32 op is exact, so the int-accum
        // engine must agree with the f32-exact engine to the bit even
        // with per-channel weight scales + a folded BN affine on top
        let (d_in, d_out) = (12usize, 3usize);
        let scales = vec![0.5f32, 0.25, 0.125];
        let mut rng = Pcg32::new(9, 0x77);
        let w: Vec<f32> = (0..d_in * d_out)
            .map(|i| (rng.below(15) as f32 - 7.0) * scales[i % d_out])
            .collect();
        let (packed, _grid_n) = snap_and_pack_pc(&w, &scales, 1, 4).unwrap();
        let layer = DeployLayer {
            name: "l".into(),
            op: DeployOp::Full,
            d_in,
            d_out,
            relu: false,
            aq: true,
            act_bits: 3,
            a_scale: 0.5,
            w_bits: 4,
            w_scales: scales.clone(),
            weights: packed,
            bias: Some(vec![0.25, -0.5, 0.125]),
            requant: Some(Requant {
                mult: vec![2.0, 0.5, 1.0],
                add: vec![0.5, -0.25, 0.0],
            }),
        };
        let dm = DeployModel {
            name: "pc".into(),
            input_hw: 2,
            num_classes: 3,
            quant_a: true,
            bits_w: 4,
            bits_a: 3,
            layers: vec![layer],
        };
        let x: Vec<f32> = (0..2 * d_in).map(|_| rng.below(8) as f32 * 0.5).collect();
        let exact = Engine::with_mode(dm.clone(), false).forward_batch(&x, 2).unwrap();
        let int = Engine::with_mode(dm, true).forward_batch(&x, 2).unwrap();
        assert_eq!(exact, int);
    }

    #[test]
    fn packed_dw_matches_dense_reference() {
        let mut rng = Pcg32::new(5, 0xd3);
        let (b, c) = (4usize, 9usize);
        let s = 0.07f32;
        let bits = 3;
        let (gn, gp) = weight_grid(bits);
        let x: Vec<f32> = (0..b * c).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..c * 3).map(|_| rng.normal() * 0.3).collect();
        let (packed, grid_n) = pack_weights(&w, s, bits);
        let got = packed_dw(&x, &packed, b, c, &[s], grid_n);
        let wq = kernels::fake_quant(&w, s, gn, gp);
        for bi in 0..b {
            for ci in 0..c {
                let mut acc = 0.0f32;
                for t in 0..3usize {
                    let j = (ci + t + c - 1) % c;
                    acc += wq[ci * 3 + t] * x[bi * c + j];
                }
                assert_eq!(got[bi * c + ci], acc, "[{bi},{ci}]");
            }
        }
    }

    #[test]
    fn i32_path_exact_on_pow2_grids() {
        // power-of-two scales + small integers: every f32 op is exact, so
        // the i32 accumulation must agree with the float path to the bit
        let mut rng = Pcg32::new(3, 0x1a);
        let (s_a, s_w) = (0.5f32, 0.25f32);
        let bits = 4;
        let (m, k, n) = (2usize, 8, 6);
        let qa_codes: Vec<i32> = (0..m * k).map(|_| rng.below(8) as i32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| (rng.below(15) as f32 - 7.0) * s_w).collect();
        let (packed, grid_n) = pack_weights(&w, s_w, bits);

        let acc = packed_matmul_i32(&qa_codes, &packed, m, k, n, grid_n);
        let zscale = s_a as f64 * s_w as f64;
        let got: Vec<f32> = acc.iter().map(|&v| (zscale * v as f64) as f32).collect();

        let a_q: Vec<f32> = qa_codes.iter().map(|&c| s_a * c as f32).collect();
        let want = packed_matmul(&a_q, &packed, m, k, n, &[s_w], grid_n);
        assert_eq!(got, want);
    }

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[0.5]), 0);
    }
}
