//! Quantized deployment: export, packed integer inference, and serving.
//!
//! Everything upstream of this module *simulates* quantization
//! (fake-quant in f32, BN kept as an op with running statistics). This
//! subsystem produces and runs the real thing — the deployable integer
//! artifact the paper's method exists to make accurate:
//!
//! * [`export`] — BN-folded export of a trained QAT state: snap weights
//!   to their LSQ grid (verifying Algorithm-1 frozen weights are already
//!   on-grid), fold BN running statistics into per-channel
//!   requantization constants, bit-pack the weight integers at the
//!   target width.
//! * [`format`] — the versioned QPKG on-disk model format and the
//!   [`format::DeployModel`] it round-trips.
//! * [`packed`] — the bit-packed code vectors (2x int4 per byte, ...)
//!   with a bulk byte-level LUT decoder (whole bytes per table lookup,
//!   u64-window chunks for the odd widths).
//! * [`engine`] — the decode-once inference engine: QPKG load prepares a
//!   [`engine::PreparedModel`] (each payload decoded exactly once into
//!   cached f32/i32 weight planes), forwards run cache-blocked
//!   register-tiled kernels over the planes — an f32 path bit-exact
//!   against the native backend's fake-quant kernels, and an
//!   i32-accumulation path for quantized-activation layers — and
//!   [`engine::EngineOpts::threads`] splits batch rows across scoped
//!   threads.
//! * [`serve`] — a multi-threaded dynamically-batching request server
//!   (workers share one `Arc` of the engine and its prepared planes)
//!   behind an HTTP/1.1 network front-end
//!   ([`serve::ingress::HttpServer`]: nonblocking accept/readiness
//!   polling over `std::net`, keep-alive, a zero-copy lazy JSON request
//!   codec, per-request deadlines answering 503, bounded-queue load
//!   shedding, and a response cache) — plus the `BENCH_serve.json`
//!   throughput/latency benchmark with p50/p95/p99 per-request latency
//!   percentiles, the network rows (keep-alive vs connection-churn
//!   throughput, overload p99), and the fleet rows (throughput at 2/4/8
//!   resident models, hot-swap p99 spike).
//! * [`serve::registry`] — the multi-model fleet:
//!   [`serve::ModelRegistry`] holds N QPKG models behind one ingress
//!   (resource routes `/v1/models/{id}/...`), each with its own worker
//!   pool (one model's overload sheds only its own requests), a
//!   prepared-plane memory budget with LRU demotion to streaming mode
//!   and promotion back on traffic, and zero-downtime hot-swap
//!   (`POST /v1/models/{id}/load`: in-flight requests drain on the old
//!   engine, the cutover is atomic, old planes drop at the last
//!   reference).
//! * [`trajectory`] — the CI perf-trajectory harness: deploy kernel
//!   micro-benchmarks merged with the serve report into a
//!   schema-versioned `BENCH_deploy.json`, gated against a committed
//!   baseline (throughput floors, tail-latency ceilings).
//!
//! Weight scales are per-tensor or **per-channel** (one scale per output
//! channel) end-to-end: the exporter snaps each channel to its own grid,
//! and the engine dequantizes / requantizes with the channel's scale in
//! both execution paths. Activation scales are likewise per-tensor or
//! **per-input-channel** (since QPKG version 3, `n_a_scales = d_in`);
//! layers with a per-tensor activation scale keep the exact i32 fast
//! path (requant composed with the folded-BN affine), while per-channel
//! activation dense/1-D layers replay the interpreter's exact f32
//! arithmetic (see [`engine`] — a per-input-channel scale cannot factor
//! out of those dot products; spatial depthwise layers, whose receptive
//! field stays inside one channel, keep the i32 path).
//!
//! Typical flow (also `examples/deploy_pipeline.rs` and the `export` /
//! `serve` CLI subcommands):
//!
//! ```text
//! QAT train -> BN re-estimate -> export_model() -> write_qpkg()
//!                                   read_qpkg() -> Engine -> Server
//! ```

pub mod engine;
pub mod export;
pub mod format;
pub mod packed;
pub mod serve;
pub mod trajectory;

pub use engine::{resolve_threads, Engine, EngineOpts, PreparedModel};
pub use export::{export_model, ExportCfg, ExportReport};
pub use format::{DeployLayer, DeployModel, DeployOp, Requant};
pub use packed::Packed;
pub use serve::{
    bench_fleet, bench_http, bench_serve, BatchForward, EngineCfg, FleetBenchReport, HttpCfg,
    HttpServer, LoadOutcome, ModelRegistry, RegistryCfg, ServeCfg, ServeReport, Server,
};
pub use trajectory::{check_regression, run_deploy_microbench, DeployBenchReport};
