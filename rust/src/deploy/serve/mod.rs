//! Batched serving core + the HTTP/1.1 network front-end.
//!
//! Architecture (std channels + threads + nonblocking sockets, no
//! external deps):
//!
//! ```text
//! TCP clients --> [ingress] nonblocking accept/readiness poller
//!                    |   HTTP/1.1 keep-alive parsing ([http]),
//!                    |   lazy JSON request codec, per-request
//!                    |   deadlines, queue admission control,
//!                    |   response cache ([cache])
//!                    v
//! submit()/try_submit() --> ingress queue (bounded sync_channel)
//!                 |
//!              batcher thread: drains up to max_batch queued requests
//!                 |            into one dynamic batch
//!              dispatch channel
//!                 |
//!              worker pool (N threads, shared Mutex<Receiver>):
//!                 drop expired jobs -> concatenate inputs ->
//!                 forward_batch -> one Response per request
//! ```
//!
//! The engine decodes each packed payload exactly once at load time
//! (`DeployModel::prepare`); every worker clones one `Arc` whose shared
//! `PreparedModel` planes serve all requests, so no request — and no
//! batch — ever re-decodes weights. Dynamic batching then amortizes the
//! remaining per-call overhead and keeps the blocked kernels fed with
//! multi-row batches.
//!
//! The worker pool runs behind the small [`BatchForward`] trait (the
//! packed [`Engine`] in production; tests substitute slow or panicking
//! forwards), and the pool **detects its own death**: if the batcher or
//! every worker exits — a panicking forward, for instance — a shared
//! flag flips and [`Server::submit`] returns an error instead of
//! blocking forever on a queue nobody drains.
//!
//! [`bench_serve`] drives a full open-loop benchmark over the channel
//! core and renders the `BENCH_serve.json` report the CI perf
//! trajectory tracks; [`ingress::bench_http`] adds the network-level
//! rows (keep-alive vs connection churn, overload p99) on top, and
//! [`registry::bench_fleet`] the multi-model rows (aggregate rps at
//! 2/4/8 resident models, hot-swap p99 spike).
//!
//! Multi-model serving lives in [`registry`]: a [`ModelRegistry`] holds
//! N models behind one ingress — each with its **own** bounded queue +
//! worker pool (so one model's overload sheds its own 503s) — under a
//! prepared-plane memory budget with LRU demotion to streaming mode,
//! and supports zero-downtime hot-swap of a model's QPKG.

pub mod cache;
pub mod http;
pub mod ingress;
pub mod registry;
pub mod shard;

pub use cache::{CachedResponse, ResponseCache};
pub use ingress::{bench_http, HttpBenchReport, HttpCfg, HttpServer, HttpStats};
pub use registry::{
    bench_fleet, EngineCfg, FleetBenchReport, LoadOutcome, ModelEntry, ModelRegistry, PoolBackend,
    RegistryCfg,
};
pub use shard::{bench_shards, Launcher, ShardBenchReport, ShardCfg, ShardPool};

use super::engine::{argmax, Engine};
use crate::json::Json;
use crate::obs::Histogram;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// The inference surface the serving pool drives. Production uses the
/// packed [`Engine`]; tests plug in slow/panicking stand-ins to pin the
/// pool's overload and failure behaviour.
pub trait BatchForward: Send + Sync {
    /// width of one input row
    fn d_in(&self) -> usize;
    fn num_classes(&self) -> usize;
    /// served model identifier (HTTP requests may name it explicitly)
    fn model_name(&self) -> &str;
    /// forward `b` rows of `d_in()` features; returns `[b*num_classes]`
    /// logits row-major
    fn forward_batch(&self, x: &[f32], b: usize) -> Result<Vec<f32>>;
}

impl BatchForward for Engine {
    fn d_in(&self) -> usize {
        self.model().d_in()
    }

    fn num_classes(&self) -> usize {
        self.model().num_classes
    }

    fn model_name(&self) -> &str {
        &self.model().name
    }

    fn forward_batch(&self, x: &[f32], b: usize) -> Result<Vec<f32>> {
        Engine::forward_batch(self, x, b)
    }
}

/// Serving knobs.
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// inference worker threads
    pub workers: usize,
    /// largest dynamic batch one worker runs
    pub max_batch: usize,
    /// ingress queue capacity: `submit` blocks when full (backpressure),
    /// `try_submit` sheds (admission control)
    pub queue_cap: usize,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg { workers: 4, max_batch: 16, queue_cap: 1024 }
    }
}

/// One served prediction.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub pred: usize,
    pub logits: Vec<f32>,
    /// submit-to-response wall time
    pub latency: Duration,
    /// size of the dynamic batch this request rode in
    pub batch_size: usize,
}

struct Job {
    id: u64,
    x: Vec<f32>,
    t0: Instant,
    /// drop unserved (closing the response channel) once this passes
    deadline: Option<Instant>,
    tx: mpsc::Sender<Response>,
}

/// Shared serving counters.
#[derive(Debug, Default)]
pub struct ServeStats {
    pub batches: AtomicU64,
    pub requests: AtomicU64,
    /// requests whose batch failed in the engine (their responses never
    /// arrive — clients observe the closed channel)
    pub failed: AtomicU64,
    /// requests dropped unserved because their deadline passed while
    /// queued (the HTTP front-end answers 503 from its own clock; raw
    /// channel clients observe the closed response channel)
    pub expired: AtomicU64,
    /// most recent engine failure (jobs of a failed batch are dropped,
    /// which closes their response channels; the cause is kept here)
    pub last_error: Mutex<Option<String>>,
    /// seconds a job waited from submit to compute start (the queue +
    /// batching stage); `Arc` so the ingress can adopt the same
    /// histogram into its `/metrics` registry
    pub queue_wait: Arc<Histogram>,
    /// seconds one `forward_batch` call took (per batch, not per job)
    pub compute: Arc<Histogram>,
}

impl ServeStats {
    /// Stats whose stage histograms are shared externally: the fleet
    /// registry hands every per-model pool the same two histograms so
    /// the ingress `/metrics` page keeps one aggregate
    /// `qat_stage_queue_seconds` / `qat_stage_compute_seconds` pair
    /// while counters stay per-pool.
    pub fn with_stage_histograms(queue_wait: Arc<Histogram>, compute: Arc<Histogram>) -> Self {
        ServeStats { queue_wait, compute, ..ServeStats::default() }
    }
}

/// Flips the shared dead flag when the watched thread exits — by
/// `return` or by panic unwind alike. Workers share one alive counter
/// (the pool dies when the *last* worker exits); the batcher kills the
/// pool on its own.
struct PoolGuard {
    dead: Arc<AtomicBool>,
    alive: Option<Arc<AtomicUsize>>,
}

impl Drop for PoolGuard {
    fn drop(&mut self) {
        match &self.alive {
            None => self.dead.store(true, Ordering::Release),
            Some(alive) => {
                if alive.fetch_sub(1, Ordering::AcqRel) == 1 {
                    self.dead.store(true, Ordering::Release);
                }
            }
        }
    }
}

/// A running server: batcher + worker pool around one shared forward.
pub struct Server {
    ingress: mpsc::SyncSender<Job>,
    batcher: JoinHandle<()>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<ServeStats>,
    /// true once the batcher or the whole worker pool has exited;
    /// submits fail fast instead of queueing for a dead pool
    dead: Arc<AtomicBool>,
    next_id: AtomicU64,
    /// kept for admission-time shape checks — read live (not captured at
    /// start) so a hot-swapped forward enforces its own input width
    fwd: Arc<dyn BatchForward>,
}

impl Server {
    /// Spawn the batcher and worker threads over the packed engine.
    pub fn start(engine: Arc<Engine>, cfg: &ServeCfg) -> Server {
        Self::start_with(engine as Arc<dyn BatchForward>, cfg)
    }

    /// Spawn over any [`BatchForward`] implementation.
    pub fn start_with(fwd: Arc<dyn BatchForward>, cfg: &ServeCfg) -> Server {
        Self::start_with_stats(fwd, cfg, ServeStats::default())
    }

    /// [`Server::start_with`] with caller-provided stats — the fleet
    /// registry injects [`ServeStats::with_stage_histograms`] so every
    /// per-model pool feeds the same aggregate stage histograms.
    pub fn start_with_stats(fwd: Arc<dyn BatchForward>, cfg: &ServeCfg, stats: ServeStats) -> Server {
        let max_batch = cfg.max_batch.max(1);
        let n_workers = cfg.workers.max(1);
        let stats = Arc::new(stats);
        let dead = Arc::new(AtomicBool::new(false));
        let workers_alive = Arc::new(AtomicUsize::new(n_workers));

        let (in_tx, in_rx) = mpsc::sync_channel::<Job>(cfg.queue_cap.max(1));
        let (disp_tx, disp_rx) = mpsc::sync_channel::<Vec<Job>>(n_workers * 2);

        let batcher_stats = stats.clone();
        let batcher_guard = PoolGuard { dead: dead.clone(), alive: None };
        let batcher = std::thread::spawn(move || {
            let _guard = batcher_guard;
            while let Ok(first) = in_rx.recv() {
                let mut batch = vec![first];
                while batch.len() < max_batch {
                    match in_rx.try_recv() {
                        Ok(job) => batch.push(job),
                        Err(_) => break,
                    }
                }
                batcher_stats.batches.fetch_add(1, Ordering::Relaxed);
                if disp_tx.send(batch).is_err() {
                    return; // workers gone (the guard flags the pool dead)
                }
            }
            // ingress closed: disp_tx drops here and the workers drain out
        });

        let disp_rx = Arc::new(Mutex::new(disp_rx));
        let workers = (0..n_workers)
            .map(|_| {
                let rx = disp_rx.clone();
                let f = fwd.clone();
                let st = stats.clone();
                let guard = PoolGuard { dead: dead.clone(), alive: Some(workers_alive.clone()) };
                std::thread::spawn(move || {
                    let _guard = guard;
                    loop {
                        let got = rx.lock().expect("dispatch lock").recv();
                        let Ok(jobs) = got else { return };
                        // deadline shedding: a job whose deadline passed
                        // while queued is dropped before it costs compute
                        let now = Instant::now();
                        let mut live = Vec::with_capacity(jobs.len());
                        for j in jobs {
                            if j.deadline.is_some_and(|d| now > d) {
                                st.expired.fetch_add(1, Ordering::Relaxed);
                            } else {
                                st.queue_wait.record(now.duration_since(j.t0).as_secs_f64());
                                live.push(j);
                            }
                        }
                        if live.is_empty() {
                            continue;
                        }
                        let b = live.len();
                        let mut x = Vec::with_capacity(b * live[0].x.len());
                        for j in &live {
                            x.extend_from_slice(&j.x);
                        }
                        let tc = Instant::now();
                        let result = f.forward_batch(&x, b);
                        st.compute.record(tc.elapsed().as_secs_f64());
                        match result {
                            Ok(logits) => {
                                // derive the row width from the returned
                                // logits, not a startup capture: a swapped
                                // forward may legally change num_classes
                                // between batches
                                let num_classes = logits.len() / b;
                                for (i, job) in live.into_iter().enumerate() {
                                    let row = &logits[i * num_classes..(i + 1) * num_classes];
                                    let resp = Response {
                                        id: job.id,
                                        pred: argmax(row),
                                        logits: row.to_vec(),
                                        latency: job.t0.elapsed(),
                                        batch_size: b,
                                    };
                                    st.requests.fetch_add(1, Ordering::Relaxed);
                                    let _ = job.tx.send(resp);
                                }
                            }
                            Err(e) => {
                                // dropping the jobs closes their response
                                // channels; clients observe the failure and
                                // the cause + count are preserved so the
                                // front-end can fail loudly (non-zero exit)
                                eprintln!("[serve] batch of {b} failed: {e}");
                                st.failed.fetch_add(b as u64, Ordering::Relaxed);
                                *st.last_error.lock().expect("stats lock") = Some(e.to_string());
                            }
                        }
                    }
                })
            })
            .collect();

        Server {
            ingress: in_tx,
            batcher,
            workers,
            stats,
            dead,
            next_id: AtomicU64::new(0),
            fwd,
        }
    }

    /// True once the batcher or every worker has exited (a panicking
    /// forward, for instance): the pool will never serve again.
    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::Acquire)
    }

    fn make_job(
        &self,
        x: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<(Job, mpsc::Receiver<Response>)> {
        let d_in = self.fwd.d_in();
        anyhow::ensure!(
            x.len() == d_in,
            "serve: request has {} features, model wants {d_in}",
            x.len(),
        );
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        Ok((Job { id, x, t0: Instant::now(), deadline, tx }, rx))
    }

    /// Enqueue one request; the returned channel yields its [`Response`].
    /// Blocks when the ingress queue is full (backpressure) — but errors
    /// out instead of blocking forever if the pool has died, so a
    /// panicked worker pool can never strand its clients.
    pub fn submit(&self, x: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        self.submit_deadline(x, None)
    }

    /// [`Server::submit`] with a deadline: the job is dropped unserved
    /// (its response channel closes) if the deadline passes in the queue.
    pub fn submit_deadline(
        &self,
        x: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<mpsc::Receiver<Response>> {
        let (mut job, rx) = self.make_job(x, deadline)?;
        loop {
            anyhow::ensure!(
                !self.is_dead(),
                "serving pool is dead (batcher or every worker exited)"
            );
            match self.ingress.try_send(job) {
                Ok(()) => return Ok(rx),
                Err(mpsc::TrySendError::Full(j)) => {
                    job = j;
                    // bounded backpressure wait, re-checking pool health
                    std::thread::sleep(Duration::from_micros(200));
                }
                Err(mpsc::TrySendError::Disconnected(_)) => {
                    anyhow::bail!("server is shut down")
                }
            }
        }
    }

    /// Non-blocking admission: `Ok(None)` when the queue is full (the
    /// caller sheds load with a fast error instead of queueing), `Err`
    /// when the pool is dead or the input is malformed.
    pub fn try_submit(
        &self,
        x: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<Option<mpsc::Receiver<Response>>> {
        anyhow::ensure!(
            !self.is_dead(),
            "serving pool is dead (batcher or every worker exited)"
        );
        let (job, rx) = self.make_job(x, deadline)?;
        match self.ingress.try_send(job) {
            Ok(()) => Ok(Some(rx)),
            Err(mpsc::TrySendError::Full(_)) => Ok(None),
            Err(mpsc::TrySendError::Disconnected(_)) => {
                anyhow::bail!("server is shut down")
            }
        }
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Drain and stop: closes the ingress, joins the batcher and every
    /// worker, and returns (batches, requests) served.
    pub fn shutdown(self) -> (u64, u64) {
        let Server { ingress, batcher, workers, stats, .. } = self;
        drop(ingress);
        let _ = batcher.join();
        for w in workers {
            let _ = w.join();
        }
        (stats.batches.load(Ordering::Relaxed), stats.requests.load(Ordering::Relaxed))
    }
}

/// Nearest-rank percentile over an ascending-sorted sample, with the
/// rank rounded **up**: the smallest element such that at least `q` of
/// the sample is at or below it. The truncating `((n-1)*q) as usize`
/// pick this replaces collapsed p95/p99 toward p50 at small n (n=8 put
/// both p95 and p99 on index 6).
///
/// An empty sample returns `NaN` — the explicit no-sample marker — so a
/// bench/overload leg where every request was shed reports instead of
/// panicking; serializers must map it to a 0-count row, never emit it
/// as a JSON number ([`finite_or_zero`]).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = (sorted.len() as f64 * q).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// NaN/±inf → 0.0: the serialization guard for latency metrics, since
/// `json::to_string` would print a bare `NaN` (invalid JSON). A 0 row
/// with `requests == 0` reads unambiguously as "no samples".
pub fn finite_or_zero(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

/// One serving benchmark result (rendered into BENCH_serve.json).
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub model: String,
    pub backend_mode: String,
    pub requests: usize,
    pub workers: usize,
    pub max_batch: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
    pub mean_batch: f64,
    pub batches: u64,
    /// live log-bucket-histogram percentiles over the same latencies —
    /// the `obs::Histogram` cross-check of the exact sort-based rows
    /// above, gated alongside them so in-process measurement can't
    /// silently diverge from offline measurement
    pub hist_p50_ms: f64,
    pub hist_p95_ms: f64,
    pub hist_p99_ms: f64,
    /// per-request top-1 predictions, submit order
    pub preds: Vec<usize>,
    /// network-level rows ([`ingress::bench_http`]), merged into the
    /// same BENCH_serve.json when the front-end benchmark also ran
    pub http: Option<HttpBenchReport>,
    /// multi-model fleet rows ([`registry::bench_fleet`]): aggregate
    /// throughput at 2/4/8 resident models + the hot-swap p99 spike
    pub fleet: Option<FleetBenchReport>,
    /// cross-process shard rows ([`shard::bench_shards`]): 2-shard
    /// throughput + kill-9 crash-recovery wall time
    pub shard: Option<ShardBenchReport>,
}

impl ServeReport {
    /// JSON object (predictions excluded — they are test surface, not
    /// a perf metric).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("model".to_string(), Json::Str(self.model.clone()));
        o.insert("backend_mode".to_string(), Json::Str(self.backend_mode.clone()));
        o.insert("requests".to_string(), Json::Num(self.requests as f64));
        o.insert("workers".to_string(), Json::Num(self.workers as f64));
        o.insert("max_batch".to_string(), Json::Num(self.max_batch as f64));
        o.insert("wall_s".to_string(), Json::Num(self.wall_s));
        o.insert("throughput_rps".to_string(), Json::Num(self.throughput_rps));
        // latency rows go through the NaN -> 0 guard: an all-shed run
        // yields no samples and a bare NaN is not valid JSON
        o.insert("p50_ms".to_string(), Json::Num(finite_or_zero(self.p50_ms)));
        o.insert("p95_ms".to_string(), Json::Num(finite_or_zero(self.p95_ms)));
        o.insert("p99_ms".to_string(), Json::Num(finite_or_zero(self.p99_ms)));
        o.insert("mean_ms".to_string(), Json::Num(finite_or_zero(self.mean_ms)));
        o.insert("max_ms".to_string(), Json::Num(finite_or_zero(self.max_ms)));
        o.insert("hist_p50_ms".to_string(), Json::Num(finite_or_zero(self.hist_p50_ms)));
        o.insert("hist_p95_ms".to_string(), Json::Num(finite_or_zero(self.hist_p95_ms)));
        o.insert("hist_p99_ms".to_string(), Json::Num(finite_or_zero(self.hist_p99_ms)));
        o.insert("mean_batch".to_string(), Json::Num(self.mean_batch));
        o.insert("batches".to_string(), Json::Num(self.batches as f64));
        if let Some(h) = &self.http {
            h.merge_into(&mut o);
        }
        if let Some(f) = &self.fleet {
            f.merge_into(&mut o);
        }
        if let Some(s) = &self.shard {
            let mut rows = BTreeMap::new();
            s.merge_into(&mut rows);
            for (k, v) in rows {
                o.insert(k, Json::Num(finite_or_zero(v)));
            }
        }
        Json::Obj(o)
    }

    pub fn write_json(&self, path: &Path) -> Result<()> {
        std::fs::write(path, crate::json::to_string(&self.to_json()))
            .with_context(|| format!("write {}", path.display()))?;
        Ok(())
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} [{}]: {} requests, {:.0} req/s, p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms, \
             mean batch {:.1} over {} batches ({} workers, max_batch {})",
            self.model,
            self.backend_mode,
            self.requests,
            self.throughput_rps,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.mean_batch,
            self.batches,
            self.workers,
            self.max_batch
        );
        if let Some(h) = &self.http {
            s.push('\n');
            s.push_str(&h.summary());
        }
        if let Some(f) = &self.fleet {
            s.push('\n');
            s.push_str(&f.summary());
        }
        if let Some(sh) = &self.shard {
            s.push('\n');
            s.push_str(&sh.summary());
        }
        s
    }
}

/// Open-loop throughput/latency benchmark: submit every input as its own
/// request, collect every response, report percentiles.
pub fn bench_serve(engine: Arc<Engine>, cfg: &ServeCfg, inputs: &[Vec<f32>]) -> Result<ServeReport> {
    anyhow::ensure!(!inputs.is_empty(), "bench_serve: no inputs");
    let model = engine.model().name.clone();
    let mode = {
        let base = if engine.int_accum { "int-accum" } else { "f32-exact" };
        let mut m = String::from(base);
        if !engine.opts.prepared {
            m.push_str("-streaming");
        }
        if engine.opts.threads > 1 {
            m.push_str(&format!("-t{}", engine.opts.threads));
        }
        m
    };
    let server = Server::start(engine, cfg);
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(inputs.len());
    for x in inputs {
        rxs.push(server.submit(x.clone())?);
    }
    let mut preds = Vec::with_capacity(inputs.len());
    let mut lat_ms = Vec::with_capacity(inputs.len());
    let mut batch_sum = 0usize;
    // the live histogram twin: fed the same per-request latencies, its
    // bucket-derived percentiles ride next to the exact ones in the gate
    let hist = Histogram::new();
    for rx in &rxs {
        let r = match rx.recv() {
            Ok(r) => r,
            Err(_) => {
                let cause = server
                    .stats()
                    .last_error
                    .lock()
                    .expect("stats lock")
                    .clone()
                    .unwrap_or_else(|| "response channel closed".into());
                return Err(anyhow::anyhow!("serve response lost: {cause}"));
            }
        };
        preds.push(r.pred);
        lat_ms.push(r.latency.as_secs_f64() * 1e3);
        hist.record(r.latency.as_secs_f64());
        batch_sum += r.batch_size;
    }
    let wall = t0.elapsed().as_secs_f64();
    let failed = server.stats().failed.load(Ordering::Relaxed);
    let (batches, served) = server.shutdown();
    // a benchmark with any failed request must error out (the CI smoke
    // job exits non-zero on it), never report a rosy partial number
    anyhow::ensure!(failed == 0, "{failed} requests failed in the engine");
    anyhow::ensure!(
        served as usize == inputs.len(),
        "served {served} of {} requests",
        inputs.len()
    );
    lat_ms.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
    let mean_ms = lat_ms.iter().sum::<f64>() / lat_ms.len().max(1) as f64;
    Ok(ServeReport {
        model,
        backend_mode: mode,
        requests: inputs.len(),
        workers: cfg.workers.max(1),
        max_batch: cfg.max_batch.max(1),
        wall_s: wall,
        throughput_rps: inputs.len() as f64 / wall.max(1e-9),
        p50_ms: percentile(&lat_ms, 0.5),
        p95_ms: percentile(&lat_ms, 0.95),
        p99_ms: percentile(&lat_ms, 0.99),
        mean_ms,
        max_ms: *lat_ms.last().expect("non-empty latencies"),
        hist_p50_ms: hist.percentile(0.5) * 1e3,
        hist_p95_ms: hist.percentile(0.95) * 1e3,
        hist_p99_ms: hist.percentile(0.99) * 1e3,
        mean_batch: batch_sum as f64 / inputs.len().max(1) as f64,
        batches,
        preds,
        http: None,
        fleet: None,
        shard: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deploy::format::{DeployLayer, DeployModel, DeployOp, Requant};
    use crate::deploy::packed::Packed;

    /// 12-feature identity-flavoured single-layer model: hw=2 so d_in =
    /// 2*2*3 = 12, 3 output classes.
    pub(crate) fn tiny_model() -> DeployModel {
        // weights [12, 3] on a 3-bit grid, s = 0.5: class c sums feature
        // block c (features 4c..4c+4 get weight +1 = code 5)
        let mut codes = vec![4u32; 12 * 3]; // grid int 0
        for c in 0..3usize {
            for f in 0..4usize {
                codes[(c * 4 + f) * 3 + c] = 6; // grid int +2 -> weight 1.0
            }
        }
        DeployModel {
            name: "tiny".into(),
            input_hw: 2,
            num_classes: 3,
            quant_a: false,
            bits_w: 3,
            bits_a: 8,
            layers: vec![DeployLayer {
                name: "head".into(),
                op: DeployOp::Full,
                d_in: 12,
                d_out: 3,
                relu: false,
                aq: false,
                act_bits: 8,
                a_scales: vec![1.0],
                w_bits: 3,
                w_scales: vec![0.5],
                weights: Packed::pack(&codes, 3).unwrap(),
                bias: None,
                requant: Some(Requant { mult: vec![1.0; 3], add: vec![0.0; 3] }),
                spatial: None,
            }],
        }
    }

    pub(crate) fn one_hot_block(c: usize) -> Vec<f32> {
        let mut x = vec![0.0f32; 12];
        for f in 0..4 {
            x[c * 4 + f] = 1.0;
        }
        x
    }

    #[test]
    fn server_routes_batched_requests() {
        let engine = Arc::new(Engine::new(tiny_model()));
        let server = Server::start(engine, &ServeCfg { workers: 3, max_batch: 4, queue_cap: 64 });
        let rxs: Vec<_> = (0..30)
            .map(|i| server.submit(one_hot_block(i % 3)).unwrap())
            .collect();
        for (i, rx) in rxs.iter().enumerate() {
            let r = rx.recv().unwrap();
            assert_eq!(r.pred, i % 3, "request {i}");
            assert!(r.batch_size >= 1 && r.batch_size <= 4);
            assert_eq!(r.logits.len(), 3);
        }
        // the worker loop feeds the stage histograms: one queue-wait
        // sample per served job, one compute sample per batch
        assert_eq!(server.stats().queue_wait.count(), 30);
        let compute_batches = server.stats().compute.count();
        let (batches, requests) = server.shutdown();
        assert_eq!(requests, 30);
        assert!(batches >= 8, "max_batch 4 needs >= 8 batches for 30 requests");
        assert_eq!(compute_batches, batches);
    }

    /// A structurally broken model (layer widths don't chain — only
    /// constructible directly, the QPKG loader rejects it) whose engine
    /// forward fails cleanly on every batch: the second layer expects 7
    /// inputs but the first emits 3.
    fn broken_model() -> DeployModel {
        let mut m = tiny_model();
        m.layers.push(DeployLayer {
            name: "bad".into(),
            op: DeployOp::Full,
            d_in: 7,
            d_out: 3,
            relu: false,
            aq: false,
            act_bits: 8,
            a_scales: vec![1.0],
            w_bits: 3,
            w_scales: vec![0.5],
            weights: Packed::pack(&[0u32; 21], 3).unwrap(),
            bias: None,
            requant: None,
            spatial: None,
        });
        m
    }

    #[test]
    fn failed_batches_surface_as_bench_errors() {
        let engine = Arc::new(Engine::new(broken_model()));
        let inputs: Vec<Vec<f32>> = (0..8).map(|i| one_hot_block(i % 3)).collect();
        let err = bench_serve(engine, &ServeCfg::default(), &inputs)
            .expect_err("engine failures must fail the benchmark");
        // the failure cause is surfaced, not swallowed
        assert!(format!("{err:#}").contains("serve response lost"), "{err:#}");
        // and the failed-request counter records the drops
        let engine = Arc::new(Engine::new(broken_model()));
        let server = Server::start(engine, &ServeCfg { workers: 1, max_batch: 4, queue_cap: 8 });
        let rx = server.submit(one_hot_block(0)).unwrap();
        assert!(rx.recv().is_err(), "response channel must close on failure");
        assert!(server.stats().failed.load(Ordering::Relaxed) >= 1);
        server.shutdown();
    }

    /// A forward that panics on every batch: the whole worker pool dies.
    struct PanickingForward;

    impl BatchForward for PanickingForward {
        fn d_in(&self) -> usize {
            12
        }
        fn num_classes(&self) -> usize {
            3
        }
        fn model_name(&self) -> &str {
            "panicker"
        }
        fn forward_batch(&self, _x: &[f32], _b: usize) -> Result<Vec<f32>> {
            panic!("engine hard-crashed");
        }
    }

    /// Regression: `submit` used to block forever once the worker pool
    /// had died with the ingress queue full — nobody drained the queue
    /// and nothing reported the death. The pool-health flag must turn
    /// that hang into a fast error.
    #[test]
    fn submit_errors_instead_of_hanging_when_pool_dies() {
        let server = Arc::new(Server::start_with(
            Arc::new(PanickingForward),
            &ServeCfg { workers: 2, max_batch: 2, queue_cap: 2 },
        ));
        // every accepted job's batch panics its worker; responses never
        // arrive and the channel closes
        let rx = server.submit(vec![0.0; 12]).unwrap();
        assert!(rx.recv().is_err(), "response channel must close when the worker dies");
        // keep submitting: once both workers have panicked the pool is
        // dead and submit must return an error in bounded time rather
        // than blocking on the full, undrained queue. Run it in a thread
        // so a regression fails the test instead of hanging it.
        let srv = server.clone();
        let h = std::thread::spawn(move || {
            let deadline = Instant::now() + Duration::from_secs(20);
            loop {
                match srv.submit(vec![0.0; 12]) {
                    Ok(rx) => {
                        let _ = rx.recv(); // lost response; keep pushing
                    }
                    Err(e) => return format!("{e:#}"),
                }
                assert!(
                    Instant::now() < deadline,
                    "submit kept succeeding against a dead pool"
                );
            }
        });
        let msg = h.join().expect("prober thread must not hang or panic");
        assert!(
            msg.contains("dead") || msg.contains("shut down"),
            "unexpected submit error: {msg}"
        );
        assert!(server.is_dead());
        // try_submit fails fast on the same dead pool
        assert!(server.try_submit(vec![0.0; 12], None).is_err());
    }

    #[test]
    fn try_submit_sheds_when_queue_is_full() {
        // a forward that blocks until released, so the queue backs up
        struct StallForward(Mutex<mpsc::Receiver<()>>);
        impl BatchForward for StallForward {
            fn d_in(&self) -> usize {
                4
            }
            fn num_classes(&self) -> usize {
                2
            }
            fn model_name(&self) -> &str {
                "stall"
            }
            fn forward_batch(&self, _x: &[f32], b: usize) -> Result<Vec<f32>> {
                let _ = self.0.lock().expect("gate lock").recv();
                Ok(vec![0.0; b * 2])
            }
        }
        let (gate_tx, gate_rx) = mpsc::channel();
        let server = Server::start_with(
            Arc::new(StallForward(Mutex::new(gate_rx))),
            &ServeCfg { workers: 1, max_batch: 1, queue_cap: 2 },
        );
        // fill: one in-flight batch, the batcher holding one, the queue
        // behind them — keep admitting until the queue reports full
        let mut admitted = Vec::new();
        let t0 = Instant::now();
        let mut shed = false;
        while Instant::now() - t0 < Duration::from_secs(10) {
            match server.try_submit(vec![0.0; 4], None).unwrap() {
                Some(rx) => admitted.push(rx),
                None => {
                    shed = true;
                    break;
                }
            }
        }
        assert!(shed, "bounded queue must eventually shed instead of admitting forever");
        // release the workers; every admitted request completes
        for _ in 0..admitted.len() + 4 {
            let _ = gate_tx.send(());
        }
        drop(gate_tx);
        for rx in &admitted {
            assert!(rx.recv().is_ok());
        }
        server.shutdown();
    }

    #[test]
    fn expired_jobs_are_dropped_unserved() {
        let engine = Arc::new(Engine::new(tiny_model()));
        let server = Server::start(engine, &ServeCfg { workers: 1, max_batch: 4, queue_cap: 8 });
        // a deadline already in the past: the worker drops the job and
        // the response channel closes
        let rx = server
            .submit_deadline(one_hot_block(0), Some(Instant::now() - Duration::from_millis(5)))
            .unwrap();
        assert!(rx.recv().is_err(), "expired job must be dropped unserved");
        // a generous deadline serves normally
        let rx = server
            .submit_deadline(one_hot_block(1), Some(Instant::now() + Duration::from_secs(30)))
            .unwrap();
        assert_eq!(rx.recv().unwrap().pred, 1);
        assert_eq!(server.stats().expired.load(Ordering::Relaxed), 1);
        server.shutdown();
    }

    #[test]
    fn threaded_engine_serves_identical_predictions() {
        use crate::deploy::engine::EngineOpts;
        let inputs: Vec<Vec<f32>> = (0..24).map(|i| one_hot_block(i % 3)).collect();
        let cfg = ServeCfg { workers: 2, max_batch: 8, queue_cap: 32 };
        let base = bench_serve(Arc::new(Engine::new(tiny_model())), &cfg, &inputs).unwrap();
        let opts = EngineOpts { threads: 2, ..Default::default() };
        let eng = Engine::with_opts(tiny_model(), true, opts);
        let mt = bench_serve(Arc::new(eng), &cfg, &inputs).unwrap();
        assert_eq!(base.preds, mt.preds);
        assert!(mt.backend_mode.ends_with("-t2"), "{}", mt.backend_mode);
    }

    #[test]
    fn submit_rejects_wrong_width() {
        let engine = Arc::new(Engine::new(tiny_model()));
        let server = Server::start(engine, &ServeCfg::default());
        assert!(server.submit(vec![0.0; 5]).is_err());
        server.shutdown();
    }

    /// Regression: the old `((n-1) as f64 * q) as usize` truncating pick
    /// collapsed p95/p99 toward p50 at small n (n=8 put both on index 6,
    /// below the max). Nearest-rank with rounding-up keeps the tail.
    #[test]
    fn percentile_nearest_rank_does_not_collapse_at_small_n() {
        let small: Vec<f64> = (1..=8).map(|v| v as f64).collect();
        assert_eq!(percentile(&small, 0.5), 4.0);
        assert_eq!(percentile(&small, 0.95), 8.0, "p95 of n=8 is the max");
        assert_eq!(percentile(&small, 0.99), 8.0, "p99 of n=8 is the max");
        assert!(percentile(&small, 0.99) > percentile(&small, 0.5));
        // larger n separates the ranks: nearest-rank lands exactly
        let big: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        assert_eq!(percentile(&big, 0.5), 50.0);
        assert_eq!(percentile(&big, 0.95), 95.0);
        assert_eq!(percentile(&big, 0.99), 99.0);
        assert_eq!(percentile(&big, 1.0), 100.0);
        // degenerate cases stay in range
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
        assert_eq!(percentile(&big, 0.0), 1.0);
    }

    /// Regression: `percentile(&[], q)` used to assert — an overload
    /// bench leg where every request is shed panicked instead of
    /// reporting. NaN is the no-sample marker and the serialization
    /// guard turns it into a 0 row.
    #[test]
    fn empty_sample_percentile_is_nan_not_panic() {
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert!(percentile(&[], q).is_nan(), "q={q}");
        }
        assert_eq!(finite_or_zero(f64::NAN), 0.0);
        assert_eq!(finite_or_zero(f64::INFINITY), 0.0);
        assert_eq!(finite_or_zero(3.25), 3.25);
    }

    #[test]
    fn bench_serve_reports_and_roundtrips_json() {
        let engine = Arc::new(Engine::new(tiny_model()));
        let inputs: Vec<Vec<f32>> = (0..40).map(|i| one_hot_block(i % 3)).collect();
        let cfg = ServeCfg { workers: 2, max_batch: 8, queue_cap: 16 };
        let report = bench_serve(engine, &cfg, &inputs).unwrap();
        assert_eq!(report.requests, 40);
        assert_eq!(report.preds.len(), 40);
        for (i, &p) in report.preds.iter().enumerate() {
            assert_eq!(p, i % 3);
        }
        assert!(report.throughput_rps > 0.0);
        assert!(report.p50_ms <= report.p95_ms + 1e-9);
        assert!(report.p95_ms <= report.p99_ms + 1e-9);
        assert!(report.p99_ms <= report.max_ms + 1e-9);
        assert!(report.mean_ms > 0.0 && report.mean_ms <= report.max_ms + 1e-9);
        assert!(report.mean_batch >= 1.0);
        // the live-histogram cross-check rows track the exact rows to
        // within the log-bucket resolution (upper edge: >= exact, and
        // no more than one √2 bucket above)
        assert!(report.hist_p95_ms >= report.p95_ms * (1.0 - 1e-12), "{report:?}");
        assert!(report.hist_p95_ms <= report.max_ms * std::f64::consts::SQRT_2 + 1e-9);
        assert!(report.hist_p50_ms <= report.hist_p95_ms + 1e-9);
        let j = report.to_json();
        assert_eq!(j.get("requests").as_usize(), Some(40));
        // tail-latency fields ride in BENCH_serve.json for future gates
        assert_eq!(j.get("p99_ms").as_f64(), Some(report.p99_ms));
        assert_eq!(j.get("mean_ms").as_f64(), Some(report.mean_ms));
        assert_eq!(j.get("hist_p95_ms").as_f64(), Some(report.hist_p95_ms));
        let dir = std::env::temp_dir().join("qat_serve_bench");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("BENCH_serve.json");
        report.write_json(&p).unwrap();
        let parsed = crate::json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
        assert_eq!(parsed.get("model").as_str(), Some("tiny"));
    }
}
