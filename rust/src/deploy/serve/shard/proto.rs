//! Length-prefixed binary wire protocol between the shard supervisor
//! (ingress process) and `shard-worker` child processes.
//!
//! Frame layout (little-endian):
//!
//! ```text
//! +------+------+---------+------+----------------+---------...
//! | 0x51 | 0x53 | version | type | payload len u32 | payload
//! +------+------+---------+------+----------------+---------...
//! ```
//!
//! The 8-byte header carries a two-byte magic (`"QS"`), a protocol
//! version, a frame type, and the payload length. Payloads are typed
//! structs with their own strict codecs: every decoder consumes the
//! payload with a cursor and rejects trailing bytes, truncation,
//! oversized lengths, and unknown discriminants with a typed
//! [`ProtoError`] — never a panic. The framing layer is incremental
//! ([`decode_frame`] returns `Ok(None)` on a partial buffer) so the
//! supervisor can feed it straight from nonblocking reads, and
//! [`read_frame`] wraps it for blocking sockets, turning EOF in the
//! middle of a frame (a killed shard's half-written frame) into a
//! clean `UnexpectedEof` transport error rather than a hang.

use std::io::Read;

/// Two-byte frame magic: `b"QS"` (QAT shard).
pub const MAGIC: [u8; 2] = [0x51, 0x53];
/// Protocol version; bumped on any incompatible wire change.
pub const VERSION: u8 = 1;
/// Fixed frame header length: magic(2) + version(1) + type(1) + len(4).
pub const HEADER_LEN: usize = 8;
/// Upper bound on a single frame payload (16 MiB) — far above any real
/// request, low enough that a corrupt length field cannot OOM the
/// supervisor.
pub const MAX_FRAME: usize = 16 * 1024 * 1024;

/// Frame discriminants on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameType {
    /// Child -> supervisor, once after connect: model identity + dims.
    Hello = 1,
    /// Supervisor -> child: one prediction request.
    Request = 2,
    /// Child -> supervisor: successful answer for a request id.
    Response = 3,
    /// Child -> supervisor: terminal per-request error (real answer —
    /// the supervisor must not fail over on it).
    Error = 4,
    /// Child -> supervisor: periodic liveness beacon.
    Heartbeat = 5,
    /// Supervisor -> child: drain and exit 0.
    Shutdown = 6,
}

impl FrameType {
    fn from_u8(v: u8) -> Result<Self, ProtoError> {
        Ok(match v {
            1 => FrameType::Hello,
            2 => FrameType::Request,
            3 => FrameType::Response,
            4 => FrameType::Error,
            5 => FrameType::Heartbeat,
            6 => FrameType::Shutdown,
            other => return Err(ProtoError::BadType(other)),
        })
    }
}

/// Typed decode failure. Any of these on a live connection means the
/// peer is broken (or malicious) and the session must be torn down.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtoError {
    /// First two bytes were not `b"QS"`.
    BadMagic,
    /// Unknown protocol version byte.
    BadVersion(u8),
    /// Unknown frame-type byte.
    BadType(u8),
    /// Declared payload length exceeds [`MAX_FRAME`].
    Oversized(usize),
    /// Payload failed its typed codec (truncated, trailing bytes, bad
    /// string, ...).
    Malformed(&'static str),
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::BadMagic => write!(f, "bad frame magic"),
            ProtoError::BadVersion(v) => write!(f, "unsupported protocol version {v}"),
            ProtoError::BadType(t) => write!(f, "unknown frame type {t}"),
            ProtoError::Oversized(n) => {
                write!(f, "frame payload {n} bytes exceeds max {MAX_FRAME}")
            }
            ProtoError::Malformed(what) => write!(f, "malformed payload: {what}"),
        }
    }
}

impl std::error::Error for ProtoError {}

/// Encode one frame (header + payload) into a fresh buffer.
pub fn encode_frame(ty: FrameType, payload: &[u8]) -> Vec<u8> {
    debug_assert!(payload.len() <= MAX_FRAME, "oversized frame encoded");
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(ty as u8);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Incremental frame decoder. `Ok(None)` means the buffer holds only a
/// partial frame (read more bytes); `Ok(Some((ty, payload, used)))`
/// borrows the payload out of `buf` — the caller copies what it needs
/// and then drains `used` bytes from the front.
pub fn decode_frame(buf: &[u8]) -> Result<Option<(FrameType, &[u8], usize)>, ProtoError> {
    // validate the header byte-by-byte as it arrives, so garbage is
    // rejected as early as possible instead of after buffering 8 bytes
    if !buf.is_empty() && buf[0] != MAGIC[0] {
        return Err(ProtoError::BadMagic);
    }
    if buf.len() >= 2 && buf[1] != MAGIC[1] {
        return Err(ProtoError::BadMagic);
    }
    if buf.len() >= 3 && buf[2] != VERSION {
        return Err(ProtoError::BadVersion(buf[2]));
    }
    if buf.len() >= 4 {
        FrameType::from_u8(buf[3])?;
    }
    if buf.len() < HEADER_LEN {
        return Ok(None);
    }
    let len = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]) as usize;
    if len > MAX_FRAME {
        return Err(ProtoError::Oversized(len));
    }
    if buf.len() < HEADER_LEN + len {
        return Ok(None);
    }
    let ty = FrameType::from_u8(buf[3])?;
    Ok(Some((ty, &buf[HEADER_LEN..HEADER_LEN + len], HEADER_LEN + len)))
}

/// Blocking frame read for sockets with no read timeout (the reader
/// thread). `buf` carries leftover bytes between calls. EOF with a
/// partial frame buffered — the signature of a `kill -9`'d shard — is
/// an `UnexpectedEof` transport error, not a hang or a panic.
pub fn read_frame(
    r: &mut impl Read,
    buf: &mut Vec<u8>,
) -> std::io::Result<(FrameType, Vec<u8>)> {
    let mut chunk = [0u8; 4096];
    loop {
        match decode_frame(buf) {
            Ok(Some((ty, payload, used))) => {
                let out = payload.to_vec();
                buf.drain(..used);
                return Ok((ty, out));
            }
            Ok(None) => {}
            Err(e) => {
                return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, e));
            }
        }
        let n = r.read(&mut chunk)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed mid-frame",
            ));
        }
        buf.extend_from_slice(&chunk[..n]);
    }
}

// ---------------------------------------------------------------------------
// payload codecs — strict cursor readers over little-endian fields
// ---------------------------------------------------------------------------

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.buf.len() - self.pos < n {
            return Err(ProtoError::Malformed("truncated payload"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, ProtoError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }
    fn u32(&mut self) -> Result<u32, ProtoError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn u64(&mut self) -> Result<u64, ProtoError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, ProtoError> {
        let b = self.take(n.checked_mul(4).ok_or(ProtoError::Malformed("vector length"))?)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
    fn finish(self) -> Result<(), ProtoError> {
        if self.pos != self.buf.len() {
            return Err(ProtoError::Malformed("trailing bytes"));
        }
        Ok(())
    }
}

fn put_str_u16(out: &mut Vec<u8>, s: &str) {
    let b = s.as_bytes();
    let n = b.len().min(u16::MAX as usize);
    out.extend_from_slice(&(n as u16).to_le_bytes());
    out.extend_from_slice(&b[..n]);
}

fn get_str_u16(c: &mut Cursor<'_>) -> Result<String, ProtoError> {
    let n = c.u16()? as usize;
    let b = c.take(n)?;
    String::from_utf8(b.to_vec()).map_err(|_| ProtoError::Malformed("non-utf8 string"))
}

/// Child's introduction, sent once after connect.
#[derive(Debug, Clone, PartialEq)]
pub struct Hello {
    pub model: String,
    pub d_in: u32,
    pub num_classes: u32,
    pub plane_bytes: u64,
    pub pid: u32,
}

impl Hello {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_str_u16(&mut out, &self.model);
        out.extend_from_slice(&self.d_in.to_le_bytes());
        out.extend_from_slice(&self.num_classes.to_le_bytes());
        out.extend_from_slice(&self.plane_bytes.to_le_bytes());
        out.extend_from_slice(&self.pid.to_le_bytes());
        out
    }
    pub fn decode(buf: &[u8]) -> Result<Self, ProtoError> {
        let mut c = Cursor::new(buf);
        let model = get_str_u16(&mut c)?;
        let d_in = c.u32()?;
        let num_classes = c.u32()?;
        let plane_bytes = c.u64()?;
        let pid = c.u32()?;
        c.finish()?;
        Ok(Hello { model, d_in, num_classes, plane_bytes, pid })
    }
}

/// One prediction request. `deadline_ms` is the remaining budget when
/// the frame was written (0 = no deadline); `idempotent` gates whether
/// the supervisor may retry it on a sibling after bytes were written.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    pub id: u64,
    pub deadline_ms: u32,
    pub idempotent: bool,
    pub input: Vec<f32>,
}

impl WireRequest {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(17 + 4 * self.input.len());
        out.extend_from_slice(&self.id.to_le_bytes());
        out.extend_from_slice(&self.deadline_ms.to_le_bytes());
        out.push(u8::from(self.idempotent));
        out.extend_from_slice(&(self.input.len() as u32).to_le_bytes());
        for v in &self.input {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }
    pub fn decode(buf: &[u8]) -> Result<Self, ProtoError> {
        let mut c = Cursor::new(buf);
        let id = c.u64()?;
        let deadline_ms = c.u32()?;
        let flags = c.u8()?;
        let n = c.u32()? as usize;
        let input = c.f32s(n)?;
        c.finish()?;
        Ok(WireRequest { id, deadline_ms, idempotent: flags & 1 != 0, input })
    }
}

/// Successful answer for a request id.
#[derive(Debug, Clone, PartialEq)]
pub struct WireResponse {
    pub id: u64,
    pub pred: u32,
    pub batch: u32,
    pub latency_us: u64,
    pub logits: Vec<f32>,
}

impl WireResponse {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(28 + 4 * self.logits.len());
        out.extend_from_slice(&self.id.to_le_bytes());
        out.extend_from_slice(&self.pred.to_le_bytes());
        out.extend_from_slice(&self.batch.to_le_bytes());
        out.extend_from_slice(&self.latency_us.to_le_bytes());
        out.extend_from_slice(&(self.logits.len() as u32).to_le_bytes());
        for v in &self.logits {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }
    pub fn decode(buf: &[u8]) -> Result<Self, ProtoError> {
        let mut c = Cursor::new(buf);
        let id = c.u64()?;
        let pred = c.u32()?;
        let batch = c.u32()?;
        let latency_us = c.u64()?;
        let n = c.u32()? as usize;
        let logits = c.f32s(n)?;
        c.finish()?;
        Ok(WireResponse { id, pred, batch, latency_us, logits })
    }
}

/// Terminal per-request error from inside the shard (queue full, pool
/// dead, dropped). A stable machine code, not prose.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    pub id: u64,
    pub code: String,
}

impl WireError {
    pub fn encode(&self) -> Vec<u8> {
        let b = self.code.as_bytes();
        let n = b.len().min(u8::MAX as usize);
        let mut out = Vec::with_capacity(9 + n);
        out.extend_from_slice(&self.id.to_le_bytes());
        out.push(n as u8);
        out.extend_from_slice(&b[..n]);
        out
    }
    pub fn decode(buf: &[u8]) -> Result<Self, ProtoError> {
        let mut c = Cursor::new(buf);
        let id = c.u64()?;
        let n = c.u8()? as usize;
        let b = c.take(n)?;
        let code = String::from_utf8(b.to_vec())
            .map_err(|_| ProtoError::Malformed("non-utf8 error code"))?;
        c.finish()?;
        Ok(WireError { id, code })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_request() -> WireRequest {
        WireRequest {
            id: 42,
            deadline_ms: 1500,
            idempotent: true,
            input: vec![0.5, -1.25, 3.0],
        }
    }

    #[test]
    fn frame_round_trips_every_type() {
        let hello = Hello {
            model: "efflite".into(),
            d_in: 12,
            num_classes: 3,
            plane_bytes: 4096,
            pid: 777,
        };
        let req = sample_request();
        let resp = WireResponse {
            id: 42,
            pred: 2,
            batch: 4,
            latency_us: 1234,
            logits: vec![0.1, 0.2, 0.9],
        };
        let err = WireError { id: 42, code: "queue_full".into() };
        let cases: Vec<(FrameType, Vec<u8>)> = vec![
            (FrameType::Hello, hello.encode()),
            (FrameType::Request, req.encode()),
            (FrameType::Response, resp.encode()),
            (FrameType::Error, err.encode()),
            (FrameType::Heartbeat, Vec::new()),
            (FrameType::Shutdown, Vec::new()),
        ];
        for (ty, payload) in cases {
            let wire = encode_frame(ty, &payload);
            let (got_ty, got_payload, used) =
                decode_frame(&wire).expect("decode ok").expect("complete frame");
            assert_eq!(got_ty, ty);
            assert_eq!(got_payload, &payload[..]);
            assert_eq!(used, wire.len());
        }
        assert_eq!(Hello::decode(&hello.encode()).unwrap(), hello);
        assert_eq!(WireRequest::decode(&req.encode()).unwrap(), req);
        assert_eq!(WireResponse::decode(&resp.encode()).unwrap(), resp);
        assert_eq!(WireError::decode(&err.encode()).unwrap(), err);
    }

    #[test]
    fn every_truncated_prefix_is_need_more_not_panic() {
        let wire = encode_frame(FrameType::Request, &sample_request().encode());
        for cut in 0..wire.len() {
            let r = decode_frame(&wire[..cut]).expect("prefix of valid frame");
            assert!(r.is_none(), "prefix of {cut} bytes decoded a frame");
        }
    }

    #[test]
    fn garbage_and_bad_headers_are_typed_errors() {
        assert_eq!(decode_frame(b"XX"), Err(ProtoError::BadMagic));
        assert_eq!(decode_frame(&[0x51, 0x00]), Err(ProtoError::BadMagic));
        assert_eq!(decode_frame(&[0x51, 0x53, 99]), Err(ProtoError::BadVersion(99)));
        assert_eq!(decode_frame(&[0x51, 0x53, VERSION, 200]), Err(ProtoError::BadType(200)));
        // oversized declared length is rejected before any allocation
        let mut wire = encode_frame(FrameType::Heartbeat, &[]);
        wire[4..8].copy_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        assert_eq!(decode_frame(&wire), Err(ProtoError::Oversized(MAX_FRAME + 1)));
    }

    #[test]
    fn payload_codecs_reject_truncation_and_trailing_bytes() {
        let enc = sample_request().encode();
        for cut in 0..enc.len() {
            assert!(WireRequest::decode(&enc[..cut]).is_err(), "cut {cut}");
        }
        let mut padded = enc.clone();
        padded.push(0);
        assert_eq!(
            WireRequest::decode(&padded),
            Err(ProtoError::Malformed("trailing bytes"))
        );
        // a declared vector length far past the buffer must not allocate
        let huge = WireRequest { id: 1, deadline_ms: 0, idempotent: false, input: vec![] };
        let mut enc = huge.encode();
        let n = enc.len();
        enc[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(WireRequest::decode(&enc).is_err());
    }

    #[test]
    fn half_written_frame_from_killed_peer_is_unexpected_eof() {
        // a shard killed mid-write leaves a prefix of a frame on the
        // socket; the blocking reader must surface UnexpectedEof, not
        // hang or misparse
        let wire = encode_frame(FrameType::Response, &[0u8; 64]);
        let mut half = std::io::Cursor::new(wire[..wire.len() / 2].to_vec());
        let mut buf = Vec::new();
        let err = read_frame(&mut half, &mut buf).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn read_frame_reassembles_split_frames() {
        let a = encode_frame(FrameType::Heartbeat, &[]);
        let b = encode_frame(FrameType::Error, &WireError { id: 9, code: "x".into() }.encode());
        let mut stream = Vec::new();
        stream.extend_from_slice(&a);
        stream.extend_from_slice(&b);
        let mut r = std::io::Cursor::new(stream);
        let mut buf = Vec::new();
        let (t1, p1) = read_frame(&mut r, &mut buf).unwrap();
        assert_eq!((t1, p1.len()), (FrameType::Heartbeat, 0));
        let (t2, p2) = read_frame(&mut r, &mut buf).unwrap();
        assert_eq!(t2, FrameType::Error);
        assert_eq!(WireError::decode(&p2).unwrap().code, "x");
    }
}
