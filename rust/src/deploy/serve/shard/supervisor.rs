//! Supervisor side of the shard boundary: owns child lifecycle (spawn,
//! liveness, crash detection), automatic restart with capped
//! exponential backoff and a restart-storm circuit breaker, and bounded
//! retry/failover of orphaned requests to sibling shards.
//!
//! Topology: per shard slot, one lifecycle thread binds an ephemeral
//! local listener, spawns the child (which connects back), performs the
//! [`Hello`] handshake, and then multiplexes requests over the single
//! connection keyed by request id. Liveness is belt-and-braces:
//! protocol heartbeats (a stalled worker stops beating), child
//! `try_wait` (a `kill -9`'d worker is reaped), and reader EOF (a
//! half-written frame surfaces as a transport error, never a hang).
//!
//! Failover policy: a request orphaned by a dying shard is retried at
//! most **once**, and never after its bytes were written to a shard
//! unless the request is idempotent. Per-request [`WireError`] frames
//! are terminal answers from a *healthy* shard and are never retried.

use std::collections::{HashMap, VecDeque};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::proto::{encode_frame, read_frame, FrameType, Hello, WireError, WireRequest, WireResponse};
use crate::deploy::serve::{Response, ServeCfg, ServeStats};
use crate::obs::Histogram;

/// How shard children are launched. [`Launcher::Thread`] is a test and
/// bench seam: the "child" is an in-process thread handed the
/// supervisor end of a real socket, so crash/stall/protocol behavior is
/// unit-testable without spawning binaries.
#[derive(Clone)]
pub enum Launcher {
    /// Re-invoke the binary with the hidden `shard-worker` subcommand
    /// (`None` = [`std::env::current_exe`] at spawn time).
    Process { exe: Option<PathBuf> },
    /// Run the closure on an in-process thread with the connected
    /// socket. Cannot be force-killed; the supervisor's connection
    /// shutdown is what makes a fake exit.
    Thread(Arc<dyn Fn(usize, TcpStream) + Send + Sync>),
}

impl std::fmt::Debug for Launcher {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Launcher::Process { exe } => write!(f, "Process({exe:?})"),
            Launcher::Thread(_) => write!(f, "Thread"),
        }
    }
}

/// Shard supervision knobs. `shards == 0` (the default) means no
/// sharding at all — the registry keeps the in-process pool.
#[derive(Debug, Clone)]
pub struct ShardCfg {
    /// shard processes per model (0 = in-process pool, unchanged)
    pub shards: usize,
    /// pool shape handed to each child (workers/max-batch/queue-cap)
    pub serve: ServeCfg,
    /// engine threads per child
    pub threads: usize,
    pub launcher: Launcher,
    /// raw `QAT_FAULT_INJECT` value (`model[#ix]=spec;...`), if set
    pub fault_env: Option<String>,
    /// heartbeat cadence requested of children
    pub heartbeat_every: Duration,
    /// silence longer than this kills and restarts the shard
    pub heartbeat_timeout: Duration,
    /// spawned child must connect back within this
    pub connect_timeout: Duration,
    /// connected child must finish loading + send Hello within this
    pub hello_timeout: Duration,
    /// restart backoff: `base * 2^consecutive_failures`, capped
    pub backoff_base: Duration,
    pub backoff_max: Duration,
    /// a session this long resets the consecutive-failure counter
    pub stable_after: Duration,
    /// circuit breaker: >= `storm_limit` restarts within `storm_window`
    /// parks the slot for `storm_cooldown` (requests degrade to a fast
    /// `shard_restarting` error instead of wedging)
    pub storm_window: Duration,
    pub storm_limit: usize,
    pub storm_cooldown: Duration,
    /// grace a child gets to exit after a Shutdown frame
    pub shutdown_grace: Duration,
}

impl Default for ShardCfg {
    fn default() -> Self {
        ShardCfg {
            shards: 0,
            serve: ServeCfg::default(),
            threads: 1,
            launcher: Launcher::Process { exe: None },
            fault_env: None,
            heartbeat_every: Duration::from_millis(250),
            heartbeat_timeout: Duration::from_secs(3),
            connect_timeout: Duration::from_secs(10),
            hello_timeout: Duration::from_secs(30),
            backoff_base: Duration::from_millis(100),
            backoff_max: Duration::from_secs(2),
            stable_after: Duration::from_secs(5),
            storm_window: Duration::from_secs(10),
            storm_limit: 5,
            storm_cooldown: Duration::from_secs(5),
            shutdown_grace: Duration::from_millis(500),
        }
    }
}

/// Match a `QAT_FAULT_INJECT` rule list (`model[#ix]=spec;...`, `*`
/// matches any model) against one shard; returns the spec to pass as
/// `--fault-inject`. Malformed rules are skipped, never fatal.
pub fn fault_for(env: Option<&str>, model: &str, ix: usize) -> Option<String> {
    for rule in env?.split(';') {
        let rule = rule.trim();
        if rule.is_empty() {
            continue;
        }
        let Some((target, spec)) = rule.split_once('=') else { continue };
        let (m, sel) = match target.split_once('#') {
            Some((m, i)) => {
                let Ok(i) = i.trim().parse::<usize>() else { continue };
                (m.trim(), Some(i))
            }
            None => (target.trim(), None),
        };
        let ix_match = match sel {
            Some(s) => s == ix,
            None => true,
        };
        if (m == "*" || m == model) && ix_match {
            return Some(spec.trim().to_string());
        }
    }
    None
}

/// One request in flight toward a shard.
struct ShardJob {
    x: Vec<f32>,
    deadline: Option<Instant>,
    idempotent: bool,
    tx: mpsc::Sender<Response>,
    /// failover budget already spent (max 1 retry)
    attempts: u32,
    t0: Instant,
}

/// One shard slot: the submit-facing surface of a lifecycle thread.
struct Slot {
    ix: usize,
    up: AtomicBool,
    /// live session's job queue; `None` while (re)starting
    jobs: Mutex<Option<mpsc::SyncSender<ShardJob>>>,
    /// hot-swap: finish in-flight work, then respawn on the new QPKG
    restart_now: AtomicBool,
    /// chaos/bench: SIGKILL the child (crash path, with backoff)
    kill_now: AtomicBool,
}

struct Shared {
    cfg: ShardCfg,
    slots: Vec<Arc<Slot>>,
    /// QPKG the *next* spawned child loads (swapped for hot-reload)
    qpkg: Mutex<PathBuf>,
    stop: AtomicBool,
    restarts: AtomicU64,
    failovers: AtomicU64,
    dropped: AtomicU64,
    hb_hist: Arc<Histogram>,
    stats: Arc<ServeStats>,
    model_id: String,
    d_in: usize,
}

/// A supervised pool of shard processes serving one model.
pub struct ShardPool {
    shared: Arc<Shared>,
    threads: Vec<JoinHandle<()>>,
    next: AtomicUsize,
}

impl ShardPool {
    /// Spawn one lifecycle thread per shard. Does **not** block waiting
    /// for children to come up — requests before the first Hello get a
    /// fast "no shard available" error (the ingress's
    /// `shard_restarting`).
    pub fn start(
        model_id: &str,
        qpkg: PathBuf,
        d_in: usize,
        cfg: ShardCfg,
        stats: ServeStats,
        hb_hist: Arc<Histogram>,
    ) -> Result<ShardPool> {
        let n = cfg.shards.max(1);
        let slots: Vec<Arc<Slot>> = (0..n)
            .map(|ix| {
                Arc::new(Slot {
                    ix,
                    up: AtomicBool::new(false),
                    jobs: Mutex::new(None),
                    restart_now: AtomicBool::new(false),
                    kill_now: AtomicBool::new(false),
                })
            })
            .collect();
        let shared = Arc::new(Shared {
            cfg,
            slots,
            qpkg: Mutex::new(qpkg),
            stop: AtomicBool::new(false),
            restarts: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            hb_hist,
            stats: Arc::new(stats),
            model_id: model_id.to_string(),
            d_in,
        });
        let threads = shared
            .slots
            .iter()
            .map(|slot| {
                let sh = shared.clone();
                let slot = slot.clone();
                std::thread::Builder::new()
                    .name(format!("shard-{model_id}-{}", slot.ix))
                    .spawn(move || lifecycle(&sh, &slot))
                    .context("spawn shard lifecycle thread")
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ShardPool { shared, threads, next: AtomicUsize::new(0) })
    }

    pub fn shards(&self) -> usize {
        self.shared.slots.len()
    }

    pub fn up_count(&self) -> usize {
        self.shared.slots.iter().filter(|s| s.up.load(Ordering::Acquire)).count()
    }

    pub fn restarts(&self) -> u64 {
        self.shared.restarts.load(Ordering::Relaxed)
    }

    pub fn failovers(&self) -> u64 {
        self.shared.failovers.load(Ordering::Relaxed)
    }

    pub fn dropped(&self) -> u64 {
        self.shared.dropped.load(Ordering::Relaxed)
    }

    pub fn stats(&self) -> &ServeStats {
        &self.shared.stats
    }

    /// Poll until at least `n` shards are serving (tests, benches).
    pub fn wait_up(&self, n: usize, timeout: Duration) -> bool {
        let t0 = Instant::now();
        while self.up_count() < n {
            if t0.elapsed() > timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        true
    }

    /// Hot-swap: children spawned from now on load `path`; every live
    /// shard is asked to finish in-flight work and respawn.
    pub fn swap_qpkg(&self, path: PathBuf) {
        *self.shared.qpkg.lock().expect("qpkg lock") = path;
        for s in &self.shared.slots {
            s.restart_now.store(true, Ordering::Release);
        }
    }

    /// Chaos/bench: SIGKILL shard `ix`'s child (no-op for thread fakes;
    /// their connection is shut down instead). The crash-recovery path
    /// — detection, failover, backoff, respawn — runs exactly as for a
    /// real crash.
    pub fn kill_shard(&self, ix: usize) {
        if let Some(s) = self.shared.slots.get(ix) {
            s.kill_now.store(true, Ordering::Release);
        }
    }

    /// Non-blocking admission mirroring `Server::try_submit`:
    /// `Ok(None)` = every live shard's queue is full (shed), `Err` =
    /// no shard is up at all (restarting/storm-parked) or bad input.
    pub fn try_submit(
        &self,
        x: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<Option<mpsc::Receiver<Response>>> {
        self.try_submit_with(x, deadline, true)
    }

    /// [`ShardPool::try_submit`] with an explicit idempotency marker:
    /// non-idempotent requests are never replayed onto a sibling once
    /// their bytes reached a shard.
    pub fn try_submit_with(
        &self,
        x: Vec<f32>,
        deadline: Option<Instant>,
        idempotent: bool,
    ) -> Result<Option<mpsc::Receiver<Response>>> {
        anyhow::ensure!(
            x.len() == self.shared.d_in,
            "serve: request has {} features, model wants {}",
            x.len(),
            self.shared.d_in,
        );
        let (tx, rx) = mpsc::channel();
        let mut job =
            Some(ShardJob { x, deadline, idempotent, tx, attempts: 0, t0: Instant::now() });
        let n = self.shared.slots.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed);
        let mut any_up = false;
        for k in 0..n {
            let slot = &self.shared.slots[(start + k) % n];
            if !slot.up.load(Ordering::Acquire) {
                continue;
            }
            let guard = slot.jobs.lock().expect("slot jobs lock");
            let Some(jtx) = guard.as_ref() else { continue };
            any_up = true;
            match jtx.try_send(job.take().expect("job present")) {
                Ok(()) => return Ok(Some(rx)),
                Err(mpsc::TrySendError::Full(j)) | Err(mpsc::TrySendError::Disconnected(j)) => {
                    job = Some(j);
                }
            }
        }
        if any_up {
            Ok(None) // live shards exist but every queue is full: shed
        } else {
            anyhow::bail!("no shard available (restarting)")
        }
    }

    /// Blocking submit for tests and benches: waits for a shard to come
    /// up and for queue space, bounded at 30 s.
    pub fn submit(&self, x: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        anyhow::ensure!(
            x.len() == self.shared.d_in,
            "serve: request has {} features, model wants {}",
            x.len(),
            self.shared.d_in,
        );
        let t0 = Instant::now();
        loop {
            if let Ok(Some(rx)) = self.try_submit(x.clone(), None) {
                return Ok(rx);
            }
            anyhow::ensure!(
                t0.elapsed() < Duration::from_secs(30),
                "shard submit timed out: no shard accepted the request in 30s"
            );
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Stop every lifecycle thread, shut children down gracefully, and
    /// return `(batches, requests)` — batches are always 0 here (the
    /// children batch internally; the supervisor counts requests).
    pub fn shutdown(self) -> (u64, u64) {
        self.shared.stop.store(true, Ordering::Release);
        for t in self.threads {
            let _ = t.join();
        }
        (0, self.shared.stats.requests.load(Ordering::Relaxed))
    }
}

/// Child handle abstraction over real processes and thread fakes.
enum ChildHandle {
    Proc(std::process::Child),
    Thread(Option<JoinHandle<()>>),
}

impl ChildHandle {
    fn is_exited(&mut self) -> bool {
        match self {
            ChildHandle::Proc(c) => matches!(c.try_wait(), Ok(Some(_))),
            ChildHandle::Thread(h) => match h {
                Some(h) => h.is_finished(),
                None => true,
            },
        }
    }

    /// SIGKILL for processes; a no-op for thread fakes (the connection
    /// shutdown at teardown is what unblocks them).
    fn kill(&mut self) {
        if let ChildHandle::Proc(c) = self {
            let _ = c.kill();
        }
    }

    /// Reap the child so no zombies accumulate across restarts. A
    /// stalled thread fake is deliberately leaked (joining it would
    /// wedge the supervisor — exactly what this subsystem exists to
    /// avoid).
    fn reap(&mut self) {
        match self {
            ChildHandle::Proc(c) => {
                let _ = c.wait();
            }
            ChildHandle::Thread(h) => {
                if h.as_ref().is_some_and(|h| h.is_finished()) {
                    if let Some(h) = h.take() {
                        let _ = h.join();
                    }
                }
            }
        }
    }
}

fn sleep_unless_stop(sh: &Shared, d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d && !sh.stop.load(Ordering::Acquire) {
        std::thread::sleep(Duration::from_millis(10).min(d));
    }
}

/// One slot's forever-loop: spawn, serve, tear down, back off, repeat.
fn lifecycle(sh: &Arc<Shared>, slot: &Arc<Slot>) {
    let mut consecutive: u32 = 0;
    let mut recent: VecDeque<Instant> = VecDeque::new();
    let mut first = true;
    while !sh.stop.load(Ordering::Acquire) {
        if !first {
            sh.restarts.fetch_add(1, Ordering::Relaxed);
            let now = Instant::now();
            recent.push_back(now);
            while recent.front().is_some_and(|t| now.duration_since(*t) > sh.cfg.storm_window) {
                recent.pop_front();
            }
            if recent.len() >= sh.cfg.storm_limit {
                eprintln!(
                    "[shard {}/{}] restart storm ({} in {:?}): parking for {:?}",
                    sh.model_id,
                    slot.ix,
                    recent.len(),
                    sh.cfg.storm_window,
                    sh.cfg.storm_cooldown,
                );
                sleep_unless_stop(sh, sh.cfg.storm_cooldown);
                recent.clear();
            }
            let backoff = sh
                .cfg
                .backoff_base
                .saturating_mul(2u32.saturating_pow(consecutive.min(6)))
                .min(sh.cfg.backoff_max);
            sleep_unless_stop(sh, backoff);
            if sh.stop.load(Ordering::Acquire) {
                break;
            }
        }
        first = false;
        let started = Instant::now();
        match run_one_session(sh, slot) {
            Ok(()) => consecutive = 0,
            Err(e) => {
                eprintln!("[shard {}/{}] session ended: {e:#}", sh.model_id, slot.ix);
                if started.elapsed() >= sh.cfg.stable_after {
                    consecutive = 0;
                } else {
                    consecutive = consecutive.saturating_add(1);
                }
            }
        }
    }
}

fn spawn_child(sh: &Shared, ix: usize, qpkg: &Path, addr: std::net::SocketAddr) -> Result<ChildHandle> {
    match &sh.cfg.launcher {
        Launcher::Process { exe } => {
            let exe = match exe {
                Some(p) => p.clone(),
                None => std::env::current_exe().context("resolve current_exe for shard-worker")?,
            };
            let mut cmd = std::process::Command::new(exe);
            cmd.arg("shard-worker")
                .arg("--connect")
                .arg(addr.to_string())
                .arg("--qpkg")
                .arg(qpkg)
                .arg("--model-id")
                .arg(&sh.model_id)
                .arg("--shard-ix")
                .arg(ix.to_string())
                .arg("--workers")
                .arg(sh.cfg.serve.workers.to_string())
                .arg("--max-batch")
                .arg(sh.cfg.serve.max_batch.to_string())
                .arg("--queue-cap")
                .arg(sh.cfg.serve.queue_cap.to_string())
                .arg("--threads")
                .arg(sh.cfg.threads.to_string())
                .arg("--heartbeat-ms")
                .arg(sh.cfg.heartbeat_every.as_millis().to_string())
                .stdin(std::process::Stdio::null())
                .stdout(std::process::Stdio::null());
            if let Some(spec) = fault_for(sh.cfg.fault_env.as_deref(), &sh.model_id, ix) {
                cmd.arg("--fault-inject").arg(spec);
            }
            Ok(ChildHandle::Proc(cmd.spawn().context("spawn shard-worker child")?))
        }
        Launcher::Thread(f) => {
            let f = f.clone();
            let h = std::thread::Builder::new()
                .name(format!("shard-fake-{ix}"))
                .spawn(move || {
                    if let Ok(c) = TcpStream::connect(addr) {
                        f(ix, c);
                    }
                })
                .context("spawn shard thread fake")?;
            Ok(ChildHandle::Thread(Some(h)))
        }
    }
}

/// Run one child session start to finish. `Ok(())` = graceful end
/// (shutdown or hot-swap restart); `Err` = crash/stall/protocol fault
/// (the lifecycle loop backs off before respawning).
fn run_one_session(sh: &Arc<Shared>, slot: &Arc<Slot>) -> Result<()> {
    let qpkg = sh.qpkg.lock().expect("qpkg lock").clone();
    let listener = TcpListener::bind("127.0.0.1:0").context("bind shard listener")?;
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    let mut child = spawn_child(sh, slot.ix, &qpkg, addr)?;

    // --- wait for the child to connect back
    let t0 = Instant::now();
    let conn = loop {
        if sh.stop.load(Ordering::Acquire) {
            child.kill();
            child.reap();
            return Ok(());
        }
        if child.is_exited() {
            child.reap();
            anyhow::bail!("shard exited before connecting");
        }
        match listener.accept() {
            Ok((c, _)) => break c,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if t0.elapsed() > sh.cfg.connect_timeout {
                    child.kill();
                    child.reap();
                    anyhow::bail!("shard did not connect within {:?}", sh.cfg.connect_timeout);
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => {
                child.kill();
                child.reap();
                return Err(e).context("accept shard connection");
            }
        }
    };
    drop(listener);
    let _ = conn.set_nodelay(true);
    conn.set_read_timeout(Some(Duration::from_millis(50)))?;

    // --- Hello handshake (the child loads its QPKG before this)
    let mut rbuf: Vec<u8> = Vec::new();
    let hello = loop {
        if sh.stop.load(Ordering::Acquire) {
            child.kill();
            child.reap();
            return Ok(());
        }
        if t0.elapsed() > sh.cfg.hello_timeout {
            child.kill();
            child.reap();
            anyhow::bail!("shard sent no Hello within {:?}", sh.cfg.hello_timeout);
        }
        match read_hello_step(&conn, &mut rbuf)? {
            HelloStep::NeedMore => {
                if child.is_exited() && rbuf.is_empty() {
                    child.reap();
                    anyhow::bail!("shard exited before Hello (bad artifact?)");
                }
            }
            HelloStep::Got(h) => break h,
            HelloStep::Eof => {
                child.kill();
                child.reap();
                anyhow::bail!("shard closed the connection before Hello");
            }
        }
    };
    if hello.d_in as usize != sh.d_in {
        child.kill();
        child.reap();
        anyhow::bail!(
            "shard Hello d_in {} does not match registry d_in {}",
            hello.d_in,
            sh.d_in,
        );
    }

    // --- live session
    let queue_cap = sh.cfg.serve.queue_cap.max(1);
    let (jtx, jrx) = mpsc::sync_channel::<ShardJob>(queue_cap);
    let pending: Arc<Mutex<HashMap<u64, ShardJob>>> = Arc::new(Mutex::new(HashMap::new()));
    let conn_dead = Arc::new(AtomicBool::new(false));
    let last_hb = Arc::new(Mutex::new(Instant::now()));

    let reader_conn = conn.try_clone().context("clone shard connection")?;
    reader_conn.set_read_timeout(None)?;
    let reader = {
        let pending = pending.clone();
        let conn_dead = conn_dead.clone();
        let last_hb = last_hb.clone();
        let hb_hist = sh.hb_hist.clone();
        let stats = sh.stats.clone();
        let leftover = std::mem::take(&mut rbuf);
        std::thread::Builder::new()
            .name(format!("shard-rd-{}-{}", sh.model_id, slot.ix))
            .spawn(move || reader_loop(reader_conn, leftover, pending, conn_dead, last_hb, hb_hist, stats))
            .context("spawn shard reader thread")?
    };

    *slot.jobs.lock().expect("slot jobs lock") = Some(jtx);
    slot.up.store(true, Ordering::Release);
    *last_hb.lock().expect("hb lock") = Instant::now();

    let mut next_id: u64 = 0;
    let mut last_sweep = Instant::now();
    let mut graceful = false;
    let mut result: Result<()> = Ok(());
    use std::io::Write;
    let mut wconn = &conn;
    loop {
        if sh.stop.load(Ordering::Acquire) {
            let _ = wconn.write_all(&encode_frame(FrameType::Shutdown, &[]));
            graceful = true;
            break;
        }
        if slot.kill_now.swap(false, Ordering::AcqRel) {
            child.kill();
            result = Err(anyhow::anyhow!("killed by supervisor (kill_shard)"));
            break;
        }
        if slot.restart_now.swap(false, Ordering::AcqRel) {
            let _ = wconn.write_all(&encode_frame(FrameType::Shutdown, &[]));
            graceful = true;
            break;
        }
        if conn_dead.load(Ordering::Acquire) {
            result = Err(anyhow::anyhow!("shard connection lost"));
            break;
        }
        if child.is_exited() {
            result = Err(anyhow::anyhow!("shard process exited"));
            break;
        }
        let hb_age = last_hb.lock().expect("hb lock").elapsed();
        if hb_age > sh.cfg.heartbeat_timeout {
            child.kill();
            result = Err(anyhow::anyhow!("heartbeat silence {hb_age:?} (stalled shard)"));
            break;
        }
        if last_sweep.elapsed() > Duration::from_millis(200) {
            let now = Instant::now();
            let mut p = pending.lock().expect("pending lock");
            let before = p.len();
            p.retain(|_, j| !j.deadline.is_some_and(|d| now > d));
            let swept = before - p.len();
            drop(p);
            if swept > 0 {
                sh.stats.expired.fetch_add(swept as u64, Ordering::Relaxed);
            }
            last_sweep = now;
        }
        match jrx.recv_timeout(Duration::from_millis(20)) {
            Ok(job) => {
                let now = Instant::now();
                if job.deadline.is_some_and(|d| now > d) {
                    sh.stats.expired.fetch_add(1, Ordering::Relaxed);
                    continue; // dropping the job closes the client channel
                }
                let id = next_id;
                next_id += 1;
                let deadline_ms = job
                    .deadline
                    .map(|d| {
                        (d.saturating_duration_since(now).as_millis() as u64)
                            .clamp(1, u64::from(u32::MAX)) as u32
                    })
                    .unwrap_or(0);
                let wire = WireRequest {
                    id,
                    deadline_ms,
                    idempotent: job.idempotent,
                    input: job.x.clone(),
                };
                pending.lock().expect("pending lock").insert(id, job);
                if let Err(e) = wconn.write_all(&encode_frame(FrameType::Request, &wire.encode())) {
                    result = Err(anyhow::anyhow!("write to shard failed: {e}"));
                    break;
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                result = Err(anyhow::anyhow!("job queue disconnected"));
                break;
            }
        }
    }

    // --- teardown: stop admissions, close the socket, reap the child,
    // then fail orphans over to siblings
    slot.up.store(false, Ordering::Release);
    *slot.jobs.lock().expect("slot jobs lock") = None;
    let _ = conn.shutdown(std::net::Shutdown::Both);
    if graceful {
        let t = Instant::now();
        while !child.is_exited() && t.elapsed() < sh.cfg.shutdown_grace {
            std::thread::sleep(Duration::from_millis(10));
        }
    }
    child.kill();
    child.reap();
    drop(conn);
    let _ = reader.join();

    let mut orphans: Vec<(ShardJob, bool)> = pending
        .lock()
        .expect("pending lock")
        .drain()
        .map(|(_, j)| (j, true)) // bytes reached the shard
        .collect();
    while let Ok(j) = jrx.try_recv() {
        orphans.push((j, false)); // queued, never written
    }
    if sh.stop.load(Ordering::Acquire) {
        // shutting down: dropping the jobs closes their channels
        drop(orphans);
    } else {
        failover(sh, slot.ix, orphans);
    }
    result
}

enum HelloStep {
    NeedMore,
    Got(Hello),
    Eof,
}

/// One bounded read toward the Hello frame (50 ms read timeout set by
/// the caller). Protocol garbage instead of a Hello is an error.
fn read_hello_step(mut conn: &TcpStream, rbuf: &mut Vec<u8>) -> Result<HelloStep> {
    use std::io::Read;
    if let Some((ty, payload, used)) = super::proto::decode_frame(rbuf)
        .map_err(|e| anyhow::anyhow!("shard handshake: {e}"))?
    {
        anyhow::ensure!(ty == FrameType::Hello, "expected Hello, got {ty:?}");
        let hello = Hello::decode(payload).map_err(|e| anyhow::anyhow!("bad Hello: {e}"))?;
        rbuf.drain(..used);
        return Ok(HelloStep::Got(hello));
    }
    let mut chunk = [0u8; 1024];
    match conn.read(&mut chunk) {
        Ok(0) => Ok(HelloStep::Eof),
        Ok(n) => {
            rbuf.extend_from_slice(&chunk[..n]);
            Ok(HelloStep::NeedMore)
        }
        Err(e)
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut =>
        {
            Ok(HelloStep::NeedMore)
        }
        Err(e) => Err(e).context("read shard Hello"),
    }
}

#[allow(clippy::too_many_arguments)]
fn reader_loop(
    mut conn: TcpStream,
    mut rbuf: Vec<u8>,
    pending: Arc<Mutex<HashMap<u64, ShardJob>>>,
    conn_dead: Arc<AtomicBool>,
    last_hb: Arc<Mutex<Instant>>,
    hb_hist: Arc<Histogram>,
    stats: Arc<ServeStats>,
) {
    loop {
        match read_frame(&mut conn, &mut rbuf) {
            Ok((FrameType::Heartbeat, _)) => {
                let mut hb = last_hb.lock().expect("hb lock");
                hb_hist.record(hb.elapsed().as_secs_f64());
                *hb = Instant::now();
            }
            Ok((FrameType::Response, payload)) => {
                let Ok(r) = WireResponse::decode(&payload) else {
                    conn_dead.store(true, Ordering::Release);
                    return;
                };
                if let Some(job) = pending.lock().expect("pending lock").remove(&r.id) {
                    stats.requests.fetch_add(1, Ordering::Relaxed);
                    let _ = job.tx.send(Response {
                        id: r.id,
                        pred: r.pred as usize,
                        logits: r.logits,
                        latency: job.t0.elapsed(),
                        batch_size: r.batch.max(1) as usize,
                    });
                }
            }
            Ok((FrameType::Error, payload)) => {
                // a per-request error from a *live* shard is a terminal
                // answer: close the client channel, never fail over
                let Ok(e) = WireError::decode(&payload) else {
                    conn_dead.store(true, Ordering::Release);
                    return;
                };
                if pending.lock().expect("pending lock").remove(&e.id).is_some() {
                    stats.failed.fetch_add(1, Ordering::Relaxed);
                    *stats.last_error.lock().expect("stats lock") =
                        Some(format!("shard error: {}", e.code));
                }
            }
            Ok((FrameType::Hello, _)) => {} // duplicate Hello: ignore
            Ok((ty, _)) => {
                eprintln!("[shard] unexpected frame {ty:?} from child");
                conn_dead.store(true, Ordering::Release);
                return;
            }
            Err(_) => {
                // EOF / half-written frame / protocol garbage: the
                // session is over (writer observes conn_dead)
                conn_dead.store(true, Ordering::Release);
                return;
            }
        }
    }
}

/// Re-home requests orphaned by a dying shard. Policy: one retry max;
/// never replay a non-idempotent request whose bytes were written.
fn failover(sh: &Arc<Shared>, from_ix: usize, orphans: Vec<(ShardJob, bool)>) {
    for (mut job, written) in orphans {
        if job.attempts >= 1 || (written && !job.idempotent) {
            sh.dropped.fetch_add(1, Ordering::Relaxed);
            continue; // dropping the job closes the client channel
        }
        job.attempts += 1;
        let n = sh.slots.len();
        let mut job = Some(job);
        for k in 0..n {
            let slot = &sh.slots[(from_ix + 1 + k) % n];
            if slot.ix == from_ix || !slot.up.load(Ordering::Acquire) {
                continue;
            }
            let guard = slot.jobs.lock().expect("slot jobs lock");
            let Some(jtx) = guard.as_ref() else { continue };
            match jtx.try_send(job.take().expect("job present")) {
                Ok(()) => {
                    sh.failovers.fetch_add(1, Ordering::Relaxed);
                    break;
                }
                Err(mpsc::TrySendError::Full(j)) | Err(mpsc::TrySendError::Disconnected(j)) => {
                    job = Some(j);
                }
            }
        }
        if job.is_some() {
            sh.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Test-only shard fake shared with registry/ingress tests: a healthy
/// in-process "child" on the supervisor's socket.
#[cfg(test)]
pub(crate) mod testutil {
    use super::super::proto::{
        decode_frame, encode_frame, FrameType, Hello, WireRequest, WireResponse,
    };
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::time::{Duration, Instant};

    /// Serve argmax predictions (logits echo the input) with 25 ms
    /// heartbeats until Shutdown or disconnect, introducing itself with
    /// the given input width.
    pub(crate) fn healthy_fake(d_in: usize, mut conn: TcpStream) {
        let _ = conn.set_nodelay(true);
        let _ = conn.set_read_timeout(Some(Duration::from_millis(10)));
        let hello = Hello {
            model: "fake".into(),
            d_in: d_in as u32,
            num_classes: 3,
            plane_bytes: 0,
            pid: 0,
        };
        if conn.write_all(&encode_frame(FrameType::Hello, &hello.encode())).is_err() {
            return;
        }
        let mut rbuf: Vec<u8> = Vec::new();
        let mut chunk = [0u8; 1024];
        let mut last_hb = Instant::now();
        loop {
            match conn.read(&mut chunk) {
                Ok(0) => return,
                Ok(n) => rbuf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut => {}
                Err(_) => return,
            }
            loop {
                let Ok(frame) = decode_frame(&rbuf) else { return };
                let Some((ty, payload, used)) = frame else { break };
                let payload = payload.to_vec();
                rbuf.drain(..used);
                match ty {
                    FrameType::Shutdown => return,
                    FrameType::Request => {
                        let Ok(req) = WireRequest::decode(&payload) else { return };
                        let pred = req
                            .input
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                            .map(|(i, _)| i)
                            .unwrap_or(0);
                        let resp = WireResponse {
                            id: req.id,
                            pred: pred as u32,
                            batch: 1,
                            latency_us: 1,
                            logits: req.input,
                        };
                        if conn
                            .write_all(&encode_frame(FrameType::Response, &resp.encode()))
                            .is_err()
                        {
                            return;
                        }
                    }
                    _ => return,
                }
            }
            if last_hb.elapsed() >= Duration::from_millis(25) {
                if conn.write_all(&encode_frame(FrameType::Heartbeat, &[])).is_err() {
                    return;
                }
                last_hb = Instant::now();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Behavior of a thread-fake shard for one session.
    #[derive(Clone, Copy, PartialEq)]
    enum Fake {
        /// serve requests (pred = argmax) with heartbeats, forever
        Healthy,
        /// send Hello, then close as soon as the first request arrives
        CrashOnRequest,
        /// send Hello + one heartbeat, then hold the socket silently
        Stall,
    }

    const FAKE_D_IN: usize = 4;

    fn fake_session(behavior: Fake, conn: TcpStream) {
        use std::io::{Read, Write};
        let _ = conn.set_nodelay(true);
        let _ = conn.set_read_timeout(Some(Duration::from_millis(10)));
        let mut conn = conn;
        let hello = Hello {
            model: "fake".into(),
            d_in: FAKE_D_IN as u32,
            num_classes: FAKE_D_IN as u32,
            plane_bytes: 0,
            pid: 0,
        };
        if conn.write_all(&encode_frame(FrameType::Hello, &hello.encode())).is_err() {
            return;
        }
        if behavior == Fake::Stall {
            let _ = conn.write_all(&encode_frame(FrameType::Heartbeat, &[]));
            std::thread::sleep(Duration::from_secs(30));
            return;
        }
        let mut rbuf: Vec<u8> = Vec::new();
        let mut chunk = [0u8; 1024];
        let mut last_hb = Instant::now();
        loop {
            match conn.read(&mut chunk) {
                Ok(0) => return,
                Ok(n) => rbuf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut => {}
                Err(_) => return,
            }
            loop {
                let Ok(frame) = super::super::proto::decode_frame(&rbuf) else { return };
                let Some((ty, payload, used)) = frame else { break };
                let payload = payload.to_vec();
                rbuf.drain(..used);
                match ty {
                    FrameType::Shutdown => return,
                    FrameType::Request => {
                        if behavior == Fake::CrashOnRequest {
                            return; // simulated crash: socket closes
                        }
                        let Ok(req) = WireRequest::decode(&payload) else { return };
                        let pred = req
                            .input
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                            .map(|(i, _)| i)
                            .unwrap_or(0);
                        let resp = WireResponse {
                            id: req.id,
                            pred: pred as u32,
                            batch: 1,
                            latency_us: 1,
                            logits: req.input,
                        };
                        if conn
                            .write_all(&encode_frame(FrameType::Response, &resp.encode()))
                            .is_err()
                        {
                            return;
                        }
                    }
                    _ => return,
                }
            }
            if last_hb.elapsed() >= Duration::from_millis(25) {
                if conn.write_all(&encode_frame(FrameType::Heartbeat, &[])).is_err() {
                    return;
                }
                last_hb = Instant::now();
            }
        }
    }

    fn fast_cfg(shards: usize, launcher: Launcher) -> ShardCfg {
        ShardCfg {
            shards,
            launcher,
            heartbeat_every: Duration::from_millis(25),
            heartbeat_timeout: Duration::from_millis(300),
            connect_timeout: Duration::from_secs(5),
            hello_timeout: Duration::from_secs(5),
            backoff_base: Duration::from_millis(5),
            backoff_max: Duration::from_millis(40),
            stable_after: Duration::from_millis(400),
            storm_window: Duration::from_millis(500),
            storm_limit: 4,
            storm_cooldown: Duration::from_millis(300),
            shutdown_grace: Duration::from_millis(100),
            ..ShardCfg::default()
        }
    }

    fn start_pool(cfg: ShardCfg) -> ShardPool {
        ShardPool::start(
            "fake",
            PathBuf::from("unused.qpkg"),
            FAKE_D_IN,
            cfg,
            ServeStats::default(),
            Arc::new(Histogram::default()),
        )
        .expect("pool start")
    }

    fn one_hot(i: usize) -> Vec<f32> {
        let mut x = vec![0.0; FAKE_D_IN];
        x[i % FAKE_D_IN] = 1.0;
        x
    }

    #[test]
    fn thread_shards_round_trip_requests() {
        let launcher = Launcher::Thread(Arc::new(|_, c| fake_session(Fake::Healthy, c)));
        let pool = start_pool(fast_cfg(2, launcher));
        assert!(pool.wait_up(2, Duration::from_secs(5)), "shards never came up");
        for i in 0..8 {
            let rx = pool.submit(one_hot(i)).expect("submit");
            let resp = rx.recv_timeout(Duration::from_secs(5)).expect("response");
            assert_eq!(resp.pred, i % FAKE_D_IN, "request {i}");
            assert_eq!(resp.logits.len(), FAKE_D_IN);
        }
        assert_eq!(pool.stats().requests.load(Ordering::Relaxed), 8);
        assert_eq!(pool.failovers(), 0);
        let (_, requests) = pool.shutdown();
        assert_eq!(requests, 8);
    }

    #[test]
    fn crash_fails_over_to_sibling_and_restarts() {
        // shard crashes only on its first session; respawns are healthy
        let crashed = Arc::new(AtomicBool::new(false));
        let flag = crashed.clone();
        let launcher = Launcher::Thread(Arc::new(move |ix, c| {
            let b = if ix == 0 && !flag.swap(true, Ordering::AcqRel) {
                Fake::CrashOnRequest
            } else {
                Fake::Healthy
            };
            fake_session(b, c);
        }));
        let pool = start_pool(fast_cfg(2, launcher));
        assert!(pool.wait_up(2, Duration::from_secs(5)));
        // two submits: round-robin puts one on each shard; the one the
        // crasher ate is replayed onto the sibling (idempotent, 1 retry)
        let rx_a = pool.submit(one_hot(1)).expect("submit a");
        let rx_b = pool.submit(one_hot(2)).expect("submit b");
        let a = rx_a.recv_timeout(Duration::from_secs(10)).expect("a answered");
        let b = rx_b.recv_timeout(Duration::from_secs(10)).expect("b answered");
        assert_eq!((a.pred, b.pred), (1, 2));
        assert_eq!(pool.failovers(), 1, "exactly one orphan replayed");
        // the crashed slot must come back on its own
        assert!(pool.wait_up(2, Duration::from_secs(10)), "crashed shard not restarted");
        assert!(pool.restarts() >= 1);
        assert_eq!(pool.dropped(), 0);
        pool.shutdown();
    }

    #[test]
    fn written_non_idempotent_orphans_are_dropped_not_replayed() {
        let crashed = Arc::new(AtomicBool::new(false));
        let flag = crashed.clone();
        let launcher = Launcher::Thread(Arc::new(move |ix, c| {
            let b = if ix == 0 && !flag.swap(true, Ordering::AcqRel) {
                Fake::CrashOnRequest
            } else {
                Fake::Healthy
            };
            fake_session(b, c);
        }));
        let pool = start_pool(fast_cfg(2, launcher));
        assert!(pool.wait_up(2, Duration::from_secs(5)));
        let rx_a = pool
            .try_submit_with(one_hot(1), None, false)
            .expect("admit a")
            .expect("queue space a");
        let rx_b = pool
            .try_submit_with(one_hot(2), None, false)
            .expect("admit b")
            .expect("queue space b");
        // one request hit the crasher after its bytes were written: it
        // must surface as a closed channel, not a silent replay
        let got_a = rx_a.recv_timeout(Duration::from_secs(10));
        let got_b = rx_b.recv_timeout(Duration::from_secs(10));
        assert_eq!(
            got_a.is_ok() as usize + got_b.is_ok() as usize,
            1,
            "exactly one of the two non-idempotent requests must be dropped"
        );
        assert_eq!(pool.failovers(), 0, "non-idempotent must never fail over");
        assert_eq!(pool.dropped(), 1);
        pool.shutdown();
    }

    #[test]
    fn all_shards_down_is_a_fast_error_and_storm_parks() {
        // every session dies immediately after Hello-less connect
        let launcher = Launcher::Thread(Arc::new(|_, _c| {}));
        let pool = start_pool(fast_cfg(1, launcher));
        // let it churn through enough failures to trip the breaker
        let t0 = Instant::now();
        while pool.restarts() < 4 && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(pool.restarts() >= 4, "restart churn never happened");
        let t = Instant::now();
        let err = pool.try_submit(one_hot(0), None).expect_err("no shard is up");
        assert!(t.elapsed() < Duration::from_millis(100), "error must be fast, not a hang");
        assert!(err.to_string().contains("no shard available"), "{err}");
        pool.shutdown();
    }

    #[test]
    fn stalled_shard_is_killed_by_heartbeat_timeout_and_replaced() {
        let stalled = Arc::new(AtomicBool::new(false));
        let flag = stalled.clone();
        let launcher = Launcher::Thread(Arc::new(move |_, c| {
            let b = if !flag.swap(true, Ordering::AcqRel) { Fake::Stall } else { Fake::Healthy };
            fake_session(b, c);
        }));
        let pool = start_pool(fast_cfg(1, launcher));
        // first session comes up, then stalls; the heartbeat watchdog
        // must kill it and the replacement must serve
        assert!(pool.wait_up(1, Duration::from_secs(5)));
        let t0 = Instant::now();
        while pool.restarts() == 0 && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(pool.restarts() >= 1, "stall never detected");
        assert!(pool.wait_up(1, Duration::from_secs(10)), "replacement never came up");
        let rx = pool.submit(one_hot(3)).expect("submit after stall");
        let resp = rx.recv_timeout(Duration::from_secs(5)).expect("served after stall");
        assert_eq!(resp.pred, 3);
        pool.shutdown();
    }

    #[test]
    fn swap_qpkg_restarts_shards_gracefully() {
        let launcher = Launcher::Thread(Arc::new(|_, c| fake_session(Fake::Healthy, c)));
        let pool = start_pool(fast_cfg(2, launcher));
        assert!(pool.wait_up(2, Duration::from_secs(5)));
        pool.swap_qpkg(PathBuf::from("v2.qpkg"));
        let t0 = Instant::now();
        while pool.restarts() < 2 && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(pool.restarts(), 2, "both shards respawn once on swap");
        assert!(pool.wait_up(2, Duration::from_secs(10)));
        assert_eq!(pool.shared.qpkg.lock().unwrap().clone(), PathBuf::from("v2.qpkg"));
        pool.shutdown();
    }

    #[test]
    fn fault_rules_match_models_and_indices() {
        assert_eq!(fault_for(None, "m", 0), None);
        assert_eq!(fault_for(Some("m=panic:0.5"), "m", 0), Some("panic:0.5".into()));
        assert_eq!(fault_for(Some("m=panic:0.5"), "other", 0), None);
        assert_eq!(fault_for(Some("*=stall:100"), "anything", 3), Some("stall:100".into()));
        assert_eq!(fault_for(Some("m#1=stall:100"), "m", 0), None);
        assert_eq!(fault_for(Some("m#1=stall:100"), "m", 1), Some("stall:100".into()));
        assert_eq!(
            fault_for(Some("a=panic:1; b#0=stall:5"), "b", 0),
            Some("stall:5".into())
        );
        // malformed rules are skipped, not fatal
        assert_eq!(fault_for(Some("garbage;;m#x=stall:5"), "m", 0), None);
    }

    #[test]
    fn bad_input_width_is_rejected_at_admission() {
        let launcher = Launcher::Thread(Arc::new(|_, c| fake_session(Fake::Healthy, c)));
        let pool = start_pool(fast_cfg(1, launcher));
        assert!(pool.wait_up(1, Duration::from_secs(5)));
        let err = pool.try_submit(vec![1.0; FAKE_D_IN + 1], None).expect_err("width");
        assert!(err.to_string().contains("features"), "{err}");
        pool.shutdown();
    }
}
