//! Child-process side of the shard boundary: the hidden `shard-worker`
//! subcommand.
//!
//! A shard worker is spawned by the supervisor with `--connect
//! 127.0.0.1:PORT`, connects back, loads its QPKG **inside the child**
//! (so a corrupt artifact or a panicking engine can only kill this
//! process), introduces itself with a [`Hello`] frame, and then serves
//! [`WireRequest`] frames from its own in-process batching pool,
//! interleaving [`Heartbeat`](FrameType::Heartbeat) beacons. Faults can
//! be injected (`--fault-inject panic:p,stall:ms`) for chaos tests:
//! the stall runs on the serve loop itself, so a stalled worker also
//! stops heartbeating — exactly how a real allocator stall or OOM
//! thrash presents to the supervisor.

use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::proto::{
    decode_frame, encode_frame, FrameType, Hello, WireError, WireRequest, WireResponse,
};
use crate::cli::Args;
use crate::deploy::engine::{Engine, EngineOpts, PreparedModel};
use crate::deploy::format::DeployModel;
use crate::deploy::serve::{BatchForward, Response, ServeCfg, Server};

/// Fault-injection plan parsed from `--fault-inject panic:p,stall:ms`.
/// Both knobs are optional and compose: `panic:0.02,stall:500`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultPlan {
    /// probability a given request panics the whole worker process
    pub panic_p: f64,
    /// per-request stall on the serve loop (blocks heartbeats too)
    pub stall_ms: u64,
}

impl FaultPlan {
    /// Parse a spec like `panic:0.5`, `stall:2000`, or
    /// `panic:0.5,stall:2000`. Unknown or malformed parts are ignored
    /// (chaos knobs must never make a healthy boot fail).
    pub fn parse(spec: &str) -> FaultPlan {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let Some((key, val)) = part.split_once(':') else { continue };
            match key.trim() {
                "panic" => plan.panic_p = val.trim().parse().unwrap_or(0.0),
                "stall" => plan.stall_ms = val.trim().parse().unwrap_or(0),
                _ => {}
            }
        }
        plan
    }

    fn is_noop(&self) -> bool {
        self.panic_p <= 0.0 && self.stall_ms == 0
    }
}

/// Deterministic-per-process coin flips for `panic:p` (LCG seeded by
/// pid, so restarted shards don't all panic on the same request index).
struct Lcg(u64);

impl Lcg {
    fn new(seed: u64) -> Lcg {
        Lcg(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1)
    }
    fn unit(&mut self) -> f64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        (self.0 >> 33) as f64 / (1u64 << 31) as f64
    }
}

struct WorkerCfg {
    qpkg: PathBuf,
    connect: String,
    model_id: String,
    serve: ServeCfg,
    threads: usize,
    heartbeat: Duration,
    fault: FaultPlan,
}

fn cfg_from_args(args: &Args) -> Result<WorkerCfg> {
    let qpkg = args.get("qpkg").context("shard-worker: --qpkg is required")?;
    let connect = args.get("connect").context("shard-worker: --connect is required")?;
    Ok(WorkerCfg {
        qpkg: PathBuf::from(qpkg),
        connect: connect.to_string(),
        model_id: args.str_or("model-id", "model"),
        serve: ServeCfg {
            workers: args.usize_or("workers", 2),
            max_batch: args.usize_or("max-batch", 16),
            queue_cap: args.usize_or("queue-cap", 256),
        },
        threads: args.usize_or("threads", 1),
        heartbeat: Duration::from_millis(args.u64_or("heartbeat-ms", 250)),
        fault: args.get("fault-inject").map(FaultPlan::parse).unwrap_or_default(),
    })
}

/// Entry point for the hidden `shard-worker` subcommand. Returns only
/// on a graceful [`Shutdown`](FrameType::Shutdown) or supervisor
/// disconnect; errors exit the process non-zero and the supervisor
/// restarts the shard.
pub fn run_from_args(args: &Args) -> Result<()> {
    let cfg = cfg_from_args(args)?;
    run_worker(&cfg)
}

fn run_worker(cfg: &WorkerCfg) -> Result<()> {
    // connect FIRST: if the supervisor is already gone there is nothing
    // to load a model for, and the supervisor learns of a bad artifact
    // through the missing Hello rather than a connect timeout
    let mut conn = TcpStream::connect(&cfg.connect)
        .with_context(|| format!("shard-worker: connect {}", cfg.connect))?;
    let _ = conn.set_nodelay(true);
    conn.set_read_timeout(Some(Duration::from_millis(20)))?;
    conn.set_write_timeout(Some(Duration::from_secs(5)))?;

    let bytes = std::fs::read(&cfg.qpkg)
        .with_context(|| format!("shard-worker: read {}", cfg.qpkg.display()))?;
    let dm = DeployModel::from_bytes(&bytes).context("shard-worker: parse qpkg")?;
    let (d_in, num_classes) = (dm.d_in(), dm.num_classes);
    let prepared = Arc::new(PreparedModel::new(dm));
    let plane_bytes = prepared.plane_bytes() as u64;
    let engine = Engine::from_prepared(
        prepared,
        true,
        EngineOpts { threads: cfg.threads, prepared: true, layer_timing: false },
    );
    let pool = Server::start_with(Arc::new(engine) as Arc<dyn BatchForward>, &cfg.serve);

    let hello = Hello {
        model: cfg.model_id.clone(),
        d_in: d_in as u32,
        num_classes: num_classes as u32,
        plane_bytes,
        pid: std::process::id(),
    };
    write_frame(&mut conn, FrameType::Hello, &hello.encode())?;

    let mut rng = Lcg::new(std::process::id() as u64);
    let mut rbuf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut pending: Vec<(u64, mpsc::Receiver<Response>)> = Vec::new();
    let mut last_hb = Instant::now();

    loop {
        // --- read whatever the supervisor sent (bounded by the timeout)
        use std::io::Read;
        match conn.read(&mut chunk) {
            Ok(0) => return Ok(()), // supervisor gone: exit cleanly
            Ok(n) => rbuf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => return Err(e).context("shard-worker: read"),
        }

        // --- drain complete frames
        loop {
            let frame = decode_frame(&rbuf)
                .map_err(|e| anyhow::anyhow!("shard-worker: bad frame from supervisor: {e}"))?;
            let Some((ty, payload, used)) = frame else { break };
            let payload = payload.to_vec();
            rbuf.drain(..used);
            match ty {
                FrameType::Shutdown => return Ok(()),
                FrameType::Request => {
                    let req = WireRequest::decode(&payload)
                        .map_err(|e| anyhow::anyhow!("shard-worker: bad request: {e}"))?;
                    // fault hooks run on the serve loop itself, so a
                    // stall also blocks heartbeats — the supervisor sees
                    // a stalled shard exactly like a wedged real one
                    if !cfg.fault.is_noop() {
                        if cfg.fault.stall_ms > 0 {
                            std::thread::sleep(Duration::from_millis(cfg.fault.stall_ms));
                        }
                        if cfg.fault.panic_p > 0.0 && rng.unit() < cfg.fault.panic_p {
                            panic!("shard-worker: injected panic (--fault-inject)");
                        }
                    }
                    let deadline = (req.deadline_ms > 0)
                        .then(|| Instant::now() + Duration::from_millis(u64::from(req.deadline_ms)));
                    match pool.try_submit(req.input, deadline) {
                        Ok(Some(rx)) => pending.push((req.id, rx)),
                        Ok(None) => {
                            let e = WireError { id: req.id, code: "queue_full".into() };
                            write_frame(&mut conn, FrameType::Error, &e.encode())?;
                        }
                        Err(_) => {
                            // the in-child pool died (worker panic):
                            // answer this request, then exit non-zero so
                            // the supervisor respawns a healthy process
                            let e = WireError { id: req.id, code: "pool_dead".into() };
                            let _ = write_frame(&mut conn, FrameType::Error, &e.encode());
                            anyhow::bail!("shard-worker: in-process pool died");
                        }
                    }
                }
                // supervisor only ever sends Request/Shutdown
                other => {
                    anyhow::bail!("shard-worker: unexpected frame {other:?} from supervisor")
                }
            }
        }

        // --- flush finished predictions (out-of-order completion is fine:
        // frames carry the request id)
        let mut i = 0;
        while i < pending.len() {
            match pending[i].1.try_recv() {
                Ok(resp) => {
                    let (id, _) = pending.swap_remove(i);
                    let wire = WireResponse {
                        id,
                        pred: resp.pred as u32,
                        batch: resp.batch_size as u32,
                        latency_us: resp.latency.as_micros() as u64,
                        logits: resp.logits,
                    };
                    write_frame(&mut conn, FrameType::Response, &wire.encode())?;
                }
                Err(mpsc::TryRecvError::Empty) => i += 1,
                Err(mpsc::TryRecvError::Disconnected) => {
                    // deadline-expired or failed batch: channel closed
                    // without a response — a terminal answer, not a crash
                    let (id, _) = pending.swap_remove(i);
                    let e = WireError { id, code: "dropped".into() };
                    write_frame(&mut conn, FrameType::Error, &e.encode())?;
                }
            }
        }

        // --- liveness beacon
        if last_hb.elapsed() >= cfg.heartbeat {
            write_frame(&mut conn, FrameType::Heartbeat, &[])?;
            last_hb = Instant::now();
        }
    }
}

fn write_frame(conn: &mut TcpStream, ty: FrameType, payload: &[u8]) -> Result<()> {
    use std::io::Write;
    conn.write_all(&encode_frame(ty, payload)).context("shard-worker: write")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_plan_parses_combined_specs() {
        assert_eq!(FaultPlan::parse("panic:0.5"), FaultPlan { panic_p: 0.5, stall_ms: 0 });
        assert_eq!(FaultPlan::parse("stall:2000"), FaultPlan { panic_p: 0.0, stall_ms: 2000 });
        assert_eq!(
            FaultPlan::parse("panic:0.02,stall:500"),
            FaultPlan { panic_p: 0.02, stall_ms: 500 }
        );
        // malformed parts never fail the boot
        assert_eq!(FaultPlan::parse("garbage"), FaultPlan::default());
        assert_eq!(FaultPlan::parse("panic:not-a-number"), FaultPlan::default());
        assert!(FaultPlan::default().is_noop());
        assert!(!FaultPlan::parse("stall:1").is_noop());
    }

    #[test]
    fn lcg_unit_stays_in_range_and_varies() {
        let mut rng = Lcg::new(1234);
        let draws: Vec<f64> = (0..64).map(|_| rng.unit()).collect();
        assert!(draws.iter().all(|v| (0.0..1.0).contains(v)), "{draws:?}");
        let first = draws[0];
        assert!(draws.iter().any(|v| (v - first).abs() > 1e-6));
    }
}
