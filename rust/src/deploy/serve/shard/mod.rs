//! Cross-process shard serving: fault-isolated worker pools with crash
//! recovery.
//!
//! A model's worker pool can run as child **shard processes** instead
//! of in-process threads (`--shards N`): the supervisor
//! ([`supervisor::ShardPool`]) spawns the binary's hidden
//! `shard-worker` subcommand ([`worker`]), each child loads the QPKG
//! and serves a length-prefixed binary protocol ([`proto`]) over a
//! local socket. A panicking engine, allocator stall, or `kill -9`
//! then takes down one child — the supervisor detects it (heartbeats +
//! `try_wait` + transport errors), fails orphaned requests over to a
//! sibling shard (bounded: one retry, idempotent-safe), and respawns
//! the child with capped exponential backoff behind a restart-storm
//! circuit breaker. `--shards 0` (default) keeps the in-process pool —
//! behavior unchanged.

pub mod proto;
pub mod supervisor;
pub mod worker;

pub use supervisor::{fault_for, Launcher, ShardCfg, ShardPool};
pub use worker::run_from_args as run_shard_worker;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::deploy::format::DeployModel;
use crate::deploy::serve::{ServeCfg, ServeStats};
use crate::obs::Histogram;

/// Sharded-serving benchmark rows (the `shard_*` serve metrics in
/// `BENCH_deploy.json`).
#[derive(Debug, Clone)]
pub struct ShardBenchReport {
    /// throughput over 2 shard processes (requests/s)
    pub shard_rps_2: f64,
    pub shard_requests: usize,
    /// wall time from `kill -9` of one shard to both shards serving
    /// again (crash detection + backoff + respawn + QPKG reload)
    pub shard_restart_ms: f64,
    pub shard_failovers: u64,
    pub shard_restarts: u64,
}

impl ShardBenchReport {
    pub fn merge_into(&self, out: &mut BTreeMap<String, f64>) {
        out.insert("shard_rps_2".into(), self.shard_rps_2);
        out.insert("shard_requests".into(), self.shard_requests as f64);
        out.insert("shard_restart_ms".into(), self.shard_restart_ms);
        out.insert("shard_failovers".into(), self.shard_failovers as f64);
        out.insert("shard_restarts".into(), self.shard_restarts as f64);
    }

    pub fn summary(&self) -> String {
        format!(
            "shards=2 rps={:.1} ({} reqs)  crash->serving again in {:.0} ms  \
             failovers={} restarts={}",
            self.shard_rps_2,
            self.shard_requests,
            self.shard_restart_ms,
            self.shard_failovers,
            self.shard_restarts,
        )
    }
}

/// Benchmark the sharded path end to end with **real child processes**:
/// throughput over 2 shards, then a `kill -9` of shard 0 under light
/// traffic, measuring time back to full strength. Only callable from
/// the binary (`current_exe` must accept the `shard-worker`
/// subcommand).
pub fn bench_shards(
    qpkg: &Path,
    serve_cfg: &ServeCfg,
    threads: usize,
    smoke: bool,
) -> Result<ShardBenchReport> {
    let bytes = std::fs::read(qpkg).with_context(|| format!("read {}", qpkg.display()))?;
    let dm = DeployModel::from_bytes(&bytes).context("parse qpkg for shard bench")?;
    let d_in = dm.d_in();
    drop(dm);
    let cfg = ShardCfg {
        shards: 2,
        serve: serve_cfg.clone(),
        threads,
        ..ShardCfg::default()
    };
    let pool = ShardPool::start(
        "bench",
        qpkg.to_path_buf(),
        d_in,
        cfg,
        ServeStats::default(),
        Arc::new(Histogram::default()),
    )?;
    anyhow::ensure!(
        pool.wait_up(2, Duration::from_secs(60)),
        "shard bench: children did not come up in 60s"
    );

    // --- throughput over both shards
    let n = if smoke { 64 } else { 512 };
    let input = |i: usize| -> Vec<f32> {
        (0..d_in).map(|j| ((i * 31 + j * 7) % 17) as f32 / 16.0).collect()
    };
    let t0 = Instant::now();
    let rxs: Vec<_> =
        (0..n).map(|i| pool.submit(input(i))).collect::<Result<Vec<_>>>()?;
    for (i, rx) in rxs.into_iter().enumerate() {
        rx.recv_timeout(Duration::from_secs(60))
            .with_context(|| format!("shard bench request {i} unanswered"))?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let shard_rps_2 = n as f64 / wall.max(1e-9);

    // --- crash recovery: SIGKILL shard 0, keep light traffic flowing,
    // measure wall time until both shards serve again
    pool.kill_shard(0);
    let t_kill = Instant::now();
    while pool.up_count() == 2 && t_kill.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(2));
    }
    anyhow::ensure!(pool.up_count() < 2, "kill_shard was never acted on");
    while pool.up_count() < 2 && t_kill.elapsed() < Duration::from_secs(60) {
        // light traffic keeps the failover path exercised during
        // recovery; responses are not awaited (dropped receivers are
        // fine — the supervisor tolerates closed client channels)
        let _ = pool.try_submit(input(0), None);
        std::thread::sleep(Duration::from_millis(5));
    }
    anyhow::ensure!(
        pool.up_count() == 2,
        "killed shard did not come back within 60s"
    );
    let shard_restart_ms = t_kill.elapsed().as_secs_f64() * 1e3;

    // prove the recovered pool serves
    let rx = pool.submit(input(1))?;
    rx.recv_timeout(Duration::from_secs(30)).context("post-recovery request unanswered")?;

    let report = ShardBenchReport {
        shard_rps_2,
        shard_requests: n,
        shard_restart_ms,
        shard_failovers: pool.failovers(),
        shard_restarts: pool.restarts(),
    };
    pool.shutdown();
    Ok(report)
}
