//! HTTP/1.1 wire handling + the zero-copy lazy JSON request codec.
//!
//! Parsing is incremental: the ingress poller feeds whatever bytes a
//! nonblocking read produced into [`parse_request`], which answers
//! `NeedMore` until a full head+body is buffered. Responses are
//! written with explicit `Content-Length` (no chunked encoding), so
//! keep-alive framing is trivial on both sides.
//!
//! The request codec never builds a [`crate::json::Json`] tree: a
//! predict body is one object whose only interesting fields are
//! `model` (small string), `input` (a large float array — the bulk of
//! the bytes), and optionally `deadline_ms`. [`lazy_field`] scans the
//! top-level object for one key, skipping other values structurally,
//! and [`lazy_f32s`] parses the float array straight out of the byte
//! span — no intermediate `Json::Num` boxing per element. The
//! `http_json_lazy` vs `http_json_tree` microbench rows quantify the
//! win.

use crate::json;
use std::collections::BTreeMap;
use std::io::Read;
use std::ops::Range;

/// Headers larger than this are refused with 431.
pub const MAX_HEAD: usize = 16 * 1024;

/// One parsed request head (+ located body) inside the connection's
/// read buffer. Ranges index into the buffer passed to
/// [`parse_request`]; `consumed` is how many bytes the request spans
/// so the poller can drain them and keep any pipelined remainder.
#[derive(Debug, Clone, PartialEq)]
pub struct ParsedReq {
    pub method: String,
    /// path with any `?query` stripped
    pub path: String,
    pub keep_alive: bool,
    /// `X-Deadline-Ms` header when present
    pub deadline_ms: Option<u64>,
    pub content_len: usize,
    pub body: Range<usize>,
    pub consumed: usize,
}

/// Incremental parse outcome.
#[derive(Debug)]
pub enum Parse {
    /// not enough bytes buffered yet
    NeedMore,
    /// malformed or over-limit; answer `status` and close
    Bad { status: u16, msg: String },
    Ready(ParsedReq),
}

fn bad(status: u16, msg: &str) -> Parse {
    Parse::Bad { status, msg: msg.to_string() }
}

/// Find the end of the header block: `\r\n\r\n` (or bare `\n\n` from
/// sloppy clients). Returns (head_end, body_start).
fn head_end(buf: &[u8]) -> Option<(usize, usize)> {
    for i in 0..buf.len().saturating_sub(1) {
        if buf[i] == b'\n' {
            if buf[i + 1] == b'\n' {
                return Some((i + 1, i + 2));
            }
            if i + 3 < buf.len() + 1 && buf[i + 1] == b'\r' && buf.get(i + 2) == Some(&b'\n') {
                return Some((i + 1, i + 3));
            }
        }
    }
    None
}

/// Parse one request from the front of `buf`. `max_body` caps the
/// declared `Content-Length` (413 beyond it).
pub fn parse_request(buf: &[u8], max_body: usize) -> Parse {
    let Some((head_stop, body_start)) = head_end(buf) else {
        if buf.len() > MAX_HEAD {
            return bad(431, "header block too large");
        }
        return Parse::NeedMore;
    };
    if head_stop > MAX_HEAD {
        return bad(431, "header block too large");
    }
    let Ok(head) = std::str::from_utf8(&buf[..head_stop]) else {
        return bad(400, "non-utf8 header block");
    };
    let mut lines = head.split('\n').map(|l| l.trim_end_matches('\r'));
    let req_line = lines.next().unwrap_or("");
    let mut parts = req_line.split(' ').filter(|p| !p.is_empty());
    let (Some(method), Some(target), Some(version)) = (parts.next(), parts.next(), parts.next())
    else {
        return bad(400, "malformed request line");
    };
    if !version.starts_with("HTTP/1.") {
        return bad(505, "only HTTP/1.x is supported");
    }
    // keep-alive is the HTTP/1.1 default; 1.0 defaults to close
    let mut keep_alive = version != "HTTP/1.0";
    let mut content_len = 0usize;
    let mut deadline_ms = None;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else { continue };
        let value = value.trim();
        if name.eq_ignore_ascii_case("connection") {
            if value.eq_ignore_ascii_case("close") {
                keep_alive = false;
            } else if value.eq_ignore_ascii_case("keep-alive") {
                keep_alive = true;
            }
        } else if name.eq_ignore_ascii_case("content-length") {
            match value.parse::<usize>() {
                Ok(n) => content_len = n,
                Err(_) => return bad(400, "bad content-length"),
            }
        } else if name.eq_ignore_ascii_case("x-deadline-ms") {
            match value.parse::<u64>() {
                Ok(n) => deadline_ms = Some(n),
                Err(_) => return bad(400, "bad x-deadline-ms"),
            }
        }
    }
    if content_len > max_body {
        return bad(413, "request body too large");
    }
    if buf.len() < body_start + content_len {
        return Parse::NeedMore;
    }
    let path = target.split('?').next().unwrap_or(target).to_string();
    Parse::Ready(ParsedReq {
        method: method.to_string(),
        path,
        keep_alive,
        deadline_ms,
        content_len,
        body: body_start..body_start + content_len,
        consumed: body_start + content_len,
    })
}

fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Append one full JSON response (head + body) to `out`.
pub fn write_response(
    out: &mut Vec<u8>,
    status: u16,
    keep_alive: bool,
    extra: &[(&str, &str)],
    body: &[u8],
) {
    write_response_with_type(out, status, keep_alive, extra, "application/json", body);
}

/// Append one full response with an explicit `Content-Type` (the
/// `/metrics` route serves Prometheus text, everything else JSON).
pub fn write_response_with_type(
    out: &mut Vec<u8>,
    status: u16,
    keep_alive: bool,
    extra: &[(&str, &str)],
    content_type: &str,
    body: &[u8],
) {
    out.extend_from_slice(format!("HTTP/1.1 {} {}\r\n", status, status_text(status)).as_bytes());
    out.extend_from_slice(format!("Content-Type: {content_type}\r\n").as_bytes());
    out.extend_from_slice(format!("Content-Length: {}\r\n", body.len()).as_bytes());
    out.extend_from_slice(if keep_alive {
        b"Connection: keep-alive\r\n"
    } else {
        b"Connection: close\r\n"
    });
    for (k, v) in extra {
        out.extend_from_slice(format!("{k}: {v}\r\n").as_bytes());
    }
    out.extend_from_slice(b"\r\n");
    out.extend_from_slice(body);
}

/// Structured JSON error body, one schema for every error the ingress
/// can answer:
///
/// ```json
/// {"error":{"code":"model_not_found","message":"...","model":"mbv2"|null}}
/// ```
///
/// `code` is a stable machine-readable slug (clients switch on it;
/// `message` is human-oriented and may change), `model` is the model id
/// the request resolved to when one was resolved. The stable codes:
/// `model_not_found`, `bad_input_width`, `deadline_exceeded`,
/// `queue_full`, `pool_dead`, `shard_restarting` (a sharded pool's
/// children are all mid-restart — retryable, connection kept),
/// `bad_request`, `route_not_found`, `method_not_allowed`,
/// `inference_failed`, `load_failed`, `not_swappable`,
/// `too_many_connections`, plus the parse-layer slugs from
/// [`status_code_slug`].
pub fn error_body(code: &str, msg: &str, model: Option<&str>) -> Vec<u8> {
    let mut s = String::from("{\"error\":{\"code\":");
    json_escape_into(&mut s, code);
    s.push_str(",\"message\":");
    json_escape_into(&mut s, msg);
    s.push_str(",\"model\":");
    match model {
        Some(m) => json_escape_into(&mut s, m),
        None => s.push_str("null"),
    }
    s.push_str("}}");
    s.into_bytes()
}

/// Stable error-code slug for a parse-layer rejection status (the
/// [`Parse::Bad`] path, where no route ever ran).
pub fn status_code_slug(status: u16) -> &'static str {
    match status {
        400 => "bad_request",
        404 => "route_not_found",
        405 => "method_not_allowed",
        413 => "payload_too_large",
        431 => "header_too_large",
        503 => "unavailable",
        505 => "http_version_unsupported",
        _ => "internal_error",
    }
}

fn json_escape_into(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// lazy JSON request codec
// ---------------------------------------------------------------------------

fn lazy_err<T>(at: usize, msg: &str) -> Result<T, String> {
    Err(format!("body byte {at}: {msg}"))
}

fn skip_ws(b: &[u8], mut i: usize) -> usize {
    while i < b.len() && b[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

/// Span of the raw string token starting at `i` (which must be `"`),
/// honoring backslash escapes. Returns (content_range, one_past_close).
fn raw_string_span(b: &[u8], i: usize) -> Result<(Range<usize>, usize), String> {
    if b.get(i) != Some(&b'"') {
        return lazy_err(i, "expected string");
    }
    let start = i + 1;
    let mut j = start;
    while j < b.len() {
        match b[j] {
            b'"' => return Ok((start..j, j + 1)),
            b'\\' => j += 2,
            _ => j += 1,
        }
    }
    lazy_err(i, "unterminated string")
}

/// One-past-the-end of the JSON value starting at `i`, without decoding
/// it: strings skip by escape-aware scan, containers by depth counting,
/// scalars by token-character run.
fn skip_value(b: &[u8], i: usize) -> Result<usize, String> {
    let i = skip_ws(b, i);
    match b.get(i) {
        None => lazy_err(i, "expected value"),
        Some(b'"') => raw_string_span(b, i).map(|(_, end)| end),
        Some(&open @ (b'{' | b'[')) => {
            let close = if open == b'{' { b'}' } else { b']' };
            let mut depth = 0usize;
            let mut j = i;
            while j < b.len() {
                match b[j] {
                    b'"' => {
                        let (_, end) = raw_string_span(b, j)?;
                        j = end;
                        continue;
                    }
                    b'{' | b'[' => depth += 1,
                    b'}' | b']' => {
                        depth -= 1;
                        if depth == 0 {
                            if b[j] != close {
                                return lazy_err(j, "mismatched bracket");
                            }
                            return Ok(j + 1);
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            lazy_err(i, "unterminated container")
        }
        Some(_) => {
            // number / true / false / null: consume the token run
            let mut j = i;
            while j < b.len()
                && matches!(b[j],
                    b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E'
                    | b'a'..=b'z' | b'A'..=b'Z')
            {
                j += 1;
            }
            if j == i {
                lazy_err(i, "expected value")
            } else {
                Ok(j)
            }
        }
    }
}

/// Scan the top-level object in `b` for `key` and return the byte range
/// of its raw value, or `None` when absent. Keys containing escape
/// sequences are compared raw (so an escaped spelling of `key` won't
/// match — predict-request keys are plain ASCII).
pub fn lazy_field(b: &[u8], key: &str) -> Result<Option<Range<usize>>, String> {
    let mut i = skip_ws(b, 0);
    if b.get(i) != Some(&b'{') {
        return lazy_err(i, "expected top-level object");
    }
    i = skip_ws(b, i + 1);
    if b.get(i) == Some(&b'}') {
        return Ok(None);
    }
    loop {
        let (kspan, after_key) = raw_string_span(b, i)?;
        i = skip_ws(b, after_key);
        if b.get(i) != Some(&b':') {
            return lazy_err(i, "expected ':'");
        }
        i = skip_ws(b, i + 1);
        let vstart = i;
        let vend = skip_value(b, i)?;
        if &b[kspan.clone()] == key.as_bytes() {
            return Ok(Some(vstart..vend));
        }
        i = skip_ws(b, vend);
        match b.get(i) {
            Some(b',') => i = skip_ws(b, i + 1),
            Some(b'}') => return Ok(None),
            _ => return lazy_err(i, "expected ',' or '}'"),
        }
    }
}

/// Parse `key`'s value as a flat float array, straight from the bytes.
pub fn lazy_f32s(b: &[u8], key: &str) -> Result<Option<Vec<f32>>, String> {
    let Some(span) = lazy_field(b, key)? else { return Ok(None) };
    let v = &b[span.clone()];
    let mut i = skip_ws(v, 0);
    if v.get(i) != Some(&b'[') {
        return lazy_err(span.start + i, "expected array");
    }
    i = skip_ws(v, i + 1);
    let mut out = Vec::new();
    if v.get(i) == Some(&b']') {
        return Ok(Some(out));
    }
    loop {
        let start = i;
        while i < v.len() && matches!(v[i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
            i += 1;
        }
        let tok = std::str::from_utf8(&v[start..i]).map_err(|_| "non-utf8 number".to_string())?;
        let f: f32 = tok
            .parse()
            .map_err(|_| format!("body byte {}: bad number {tok:?}", span.start + start))?;
        out.push(f);
        i = skip_ws(v, i);
        match v.get(i) {
            Some(b',') => i = skip_ws(v, i + 1),
            Some(b']') => return Ok(Some(out)),
            _ => return lazy_err(span.start + i, "expected ',' or ']'"),
        }
    }
}

/// Parse `key`'s value as a string (full escape decoding via the tree
/// parser's string routine — surrogate pairs included).
pub fn lazy_str(b: &[u8], key: &str) -> Result<Option<String>, String> {
    let Some(span) = lazy_field(b, key)? else { return Ok(None) };
    let at = skip_ws(b, span.start);
    let (s, _) = json::decode_str_at(b, at).map_err(|e| e.to_string())?;
    Ok(Some(s))
}

/// Parse `key`'s value as a non-negative integer.
pub fn lazy_u64(b: &[u8], key: &str) -> Result<Option<u64>, String> {
    let Some(span) = lazy_field(b, key)? else { return Ok(None) };
    let tok = std::str::from_utf8(&b[span.clone()])
        .map_err(|_| "non-utf8 number".to_string())?
        .trim();
    tok.parse::<u64>()
        .map(Some)
        .map_err(|_| format!("body byte {}: expected integer, got {tok:?}", span.start))
}

// ---------------------------------------------------------------------------
// tiny client helpers (tests + benchmarks)
// ---------------------------------------------------------------------------

/// Format a POST request with a JSON body (client side).
pub fn format_request(path: &str, body: &[u8], headers: &[(&str, &str)]) -> Vec<u8> {
    format_request_method("POST", path, body, headers)
}

/// [`format_request`] with an explicit method (the resource-oriented
/// fleet routes add GETs beyond the hand-written healthz probes).
pub fn format_request_method(
    method: &str,
    path: &str,
    body: &[u8],
    headers: &[(&str, &str)],
) -> Vec<u8> {
    let mut out = Vec::with_capacity(body.len() + 128);
    out.extend_from_slice(format!("{method} {path} HTTP/1.1\r\n").as_bytes());
    out.extend_from_slice(b"Host: localhost\r\n");
    out.extend_from_slice(format!("Content-Length: {}\r\n", body.len()).as_bytes());
    for (k, v) in headers {
        out.extend_from_slice(format!("{k}: {v}\r\n").as_bytes());
    }
    out.extend_from_slice(b"\r\n");
    out.extend_from_slice(body);
    out
}

/// One response read by the test/bench client.
#[derive(Debug)]
pub struct ClientResponse {
    pub status: u16,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl ClientResponse {
    /// case-insensitive header lookup
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }
}

/// Blocking-read one full response off `stream` (requires the server's
/// explicit `Content-Length` framing).
pub fn read_response(stream: &mut impl Read) -> std::io::Result<ClientResponse> {
    let bad = |msg: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string());
    let mut buf = Vec::new();
    let mut chunk = [0u8; 4096];
    let (stop, body_start) = loop {
        if let Some(found) = head_end(&buf) {
            break found;
        }
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(bad("connection closed mid-head"));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..stop]).map_err(|_| bad("non-utf8 head"))?;
    let mut lines = head.split('\n').map(|l| l.trim_end_matches('\r'));
    let status_line = lines.next().unwrap_or("");
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| bad("bad status line"))?;
    let mut headers = BTreeMap::new();
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    let content_len: usize = headers
        .get("content-length")
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| bad("missing content-length"))?;
    let mut body = buf[body_start..].to_vec();
    while body.len() < content_len {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(bad("connection closed mid-body"));
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_len);
    Ok(ClientResponse { status, headers, body })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req_bytes(body: &str, extra: &str) -> Vec<u8> {
        format!(
            "POST /v1/predict HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n{extra}\r\n{body}",
            body.len()
        )
        .into_bytes()
    }

    #[test]
    fn parses_full_request_with_keepalive_default() {
        let b = req_bytes(r#"{"input":[1,2]}"#, "");
        let Parse::Ready(r) = parse_request(&b, 1 << 20) else { panic!("not ready") };
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/v1/predict");
        assert!(r.keep_alive, "HTTP/1.1 defaults to keep-alive");
        assert_eq!(&b[r.body.clone()], br#"{"input":[1,2]}"#);
        assert_eq!(r.consumed, b.len());
        assert_eq!(r.deadline_ms, None);
    }

    #[test]
    fn connection_close_and_deadline_header() {
        let b = req_bytes("{}", "Connection: close\r\nX-Deadline-Ms: 250\r\n");
        let Parse::Ready(r) = parse_request(&b, 1 << 20) else { panic!("not ready") };
        assert!(!r.keep_alive);
        assert_eq!(r.deadline_ms, Some(250));
    }

    #[test]
    fn http10_defaults_to_close() {
        let b = b"GET /healthz HTTP/1.0\r\n\r\n".to_vec();
        let Parse::Ready(r) = parse_request(&b, 1 << 20) else { panic!("not ready") };
        assert!(!r.keep_alive);
        assert_eq!(r.path, "/healthz");
        assert_eq!(r.content_len, 0);
    }

    #[test]
    fn needs_more_until_complete() {
        let full = req_bytes(r#"{"input":[1]}"#, "");
        for cut in [3, 10, full.len() - 5, full.len() - 1] {
            assert!(
                matches!(parse_request(&full[..cut], 1 << 20), Parse::NeedMore),
                "cut {cut}"
            );
        }
        assert!(matches!(parse_request(&full, 1 << 20), Parse::Ready(_)));
    }

    #[test]
    fn strips_query_and_caps_body() {
        let b = b"GET /stats?verbose=1 HTTP/1.1\r\n\r\n".to_vec();
        let Parse::Ready(r) = parse_request(&b, 1 << 20) else { panic!("not ready") };
        assert_eq!(r.path, "/stats");
        let big = req_bytes("{}", "");
        match parse_request(&big, 1) {
            Parse::Bad { status, .. } => assert_eq!(status, 413),
            other => panic!("expected 413, got {other:?}"),
        }
    }

    #[test]
    fn oversized_head_is_431() {
        let mut b = b"POST / HTTP/1.1\r\n".to_vec();
        b.extend_from_slice(format!("X-Junk: {}\r\n", "j".repeat(MAX_HEAD)).as_bytes());
        match parse_request(&b, 1 << 20) {
            Parse::Bad { status, .. } => assert_eq!(status, 431),
            other => panic!("expected 431, got {other:?}"),
        }
    }

    #[test]
    fn response_roundtrips_through_client_reader() {
        let mut out = Vec::new();
        write_response(&mut out, 200, true, &[("X-Cache", "hit")], br#"{"pred":2}"#);
        let mut cur = std::io::Cursor::new(out);
        let r = read_response(&mut cur).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.header("x-cache"), Some("hit"));
        assert_eq!(r.header("connection"), Some("keep-alive"));
        assert_eq!(r.body, br#"{"pred":2}"#);
    }

    #[test]
    fn typed_response_carries_content_type() {
        let mut out = Vec::new();
        let ct = "text/plain; version=0.0.4";
        write_response_with_type(&mut out, 200, false, &[], ct, b"x 1\n");
        let mut cur = std::io::Cursor::new(out);
        let r = read_response(&mut cur).unwrap();
        assert_eq!(r.header("content-type"), Some(ct));
        assert_eq!(r.header("connection"), Some("close"));
        assert_eq!(r.body, b"x 1\n");
    }

    #[test]
    fn lazy_matches_tree_extraction() {
        let body = br#"{ "model" : "tiny", "deadline_ms": 40,
                        "meta": {"a":[1,{"b":"}]\""}]},
                        "input": [1.0, -2.5, 3e-1, 4, 0.125] }"#;
        let tree = crate::json::parse(std::str::from_utf8(body).unwrap()).unwrap();
        assert_eq!(lazy_str(body, "model").unwrap().as_deref(), tree.get("model").as_str());
        assert_eq!(lazy_u64(body, "deadline_ms").unwrap(), Some(40));
        let lazy: Vec<f32> = lazy_f32s(body, "input").unwrap().unwrap();
        let treed: Vec<f32> = tree
            .get("input")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        assert_eq!(lazy, treed);
        // absent keys are None, not errors — including keys that only
        // appear nested (the scan is strictly top-level)
        assert_eq!(lazy_field(body, "absent").unwrap(), None);
        assert_eq!(lazy_field(body, "a").unwrap(), None);
        assert_eq!(lazy_field(body, "b").unwrap(), None);
    }

    #[test]
    fn lazy_str_decodes_astral_model_names() {
        let body = "{\"model\":\"\\ud83d\\ude00net\",\"input\":[1]}".as_bytes();
        assert_eq!(lazy_str(body, "model").unwrap().as_deref(), Some("😀net"));
    }

    #[test]
    fn lazy_rejects_malformed_bodies() {
        assert!(lazy_field(b"[1,2]", "x").is_err(), "top level must be an object");
        assert!(lazy_field(br#"{"a" 1}"#, "a").is_err());
        assert!(lazy_f32s(br#"{"input": [1, "x"]}"#, "input").is_err());
        assert!(lazy_f32s(br#"{"input": 3}"#, "input").is_err());
        assert!(lazy_u64(br#"{"deadline_ms": -4}"#, "deadline_ms").is_err());
        assert!(lazy_field(br#"{"a": "unterminated"#, "a").is_err());
    }

    #[test]
    fn error_body_is_valid_json() {
        let b = error_body("bad_input_width", "bad \"input\"\nwidth", Some("mbv2"));
        let j = crate::json::parse(std::str::from_utf8(&b).unwrap()).unwrap();
        let e = j.get("error");
        assert_eq!(e.get("code").as_str(), Some("bad_input_width"));
        assert_eq!(e.get("message").as_str(), Some("bad \"input\"\nwidth"));
        assert_eq!(e.get("model").as_str(), Some("mbv2"));
        // no model resolved -> null, not a missing key
        let b = error_body("route_not_found", "no such route", None);
        let j = crate::json::parse(std::str::from_utf8(&b).unwrap()).unwrap();
        assert_eq!(j.get("error").get("model"), &crate::json::Json::Null);
    }

    #[test]
    fn method_aware_request_formatting() {
        let req = format_request_method("GET", "/v1/models", b"", &[]);
        let Parse::Ready(r) = parse_request(&req, 1 << 20) else { panic!("not ready") };
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/v1/models");
        assert_eq!(r.content_len, 0);
        // the POST shorthand is unchanged
        let req = format_request("/v1/predict", b"{}", &[]);
        let Parse::Ready(r) = parse_request(&req, 1 << 20) else { panic!("not ready") };
        assert_eq!(r.method, "POST");
    }
}
