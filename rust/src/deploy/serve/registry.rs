//! Multi-model fleet registry: N QPKG models resident behind one
//! ingress, routed by model id.
//!
//! Three properties the single-model server could not offer:
//!
//! - **Per-model pool isolation.** Every entry owns its own bounded
//!   queue + batcher + worker pool ([`Server`]), so one model's traffic
//!   spike fills *its* queue and sheds *its* 503s — the rest of the
//!   fleet keeps serving. All pools feed the same two stage histograms
//!   (`qat_stage_queue_seconds` / `qat_stage_compute_seconds`) so the
//!   `/metrics` page stays one aggregate exposition.
//! - **A memory-budgeted prepared-plane cache.** Decoded weight planes
//!   are the dominant resident cost (`PreparedModel::plane_bytes`). The
//!   registry keeps the total under `RegistryCfg::mem_budget` by
//!   demoting the least-recently-used model to streaming mode (packed
//!   codes decoded per forward — slower, but tiny) and promoting it
//!   back when its traffic returns. Promotion only steals planes from
//!   entries *colder than the claimant*, so round-robin traffic over an
//!   over-budget fleet settles instead of thrashing rebuilds.
//! - **Zero-downtime hot-swap.** [`ModelRegistry::load_qpkg`] on an
//!   existing id builds the new engine off-path, then atomically
//!   replaces the `Arc<Engine>` inside the entry's [`SwapForward`].
//!   In-flight batches hold the old `Arc` and drain on the old planes;
//!   queued and future requests get the new version; the old planes
//!   free at the last reference. Nothing is dropped, nothing blocks.
//!   The QPKG content fingerprint rides into the response-cache key, so
//!   a swap implicitly invalidates every cached answer of the old
//!   version ([`ResponseCache::key`]).
//!
//! [`bench_fleet`] produces the gated rows: aggregate throughput at
//! 2/4/8 resident models and the p99 latency spike while hot-swaps cut
//! over under load.

use super::cache::ResponseCache;
use super::http;
use super::ingress::{HttpCfg, HttpServer};
use super::shard::{ShardCfg, ShardPool};
use super::{finite_or_zero, percentile, BatchForward, Response, ServeCfg, ServeStats, Server};
use crate::deploy::engine::{Engine, EngineOpts, PreparedModel};
use crate::deploy::format::DeployModel;
use crate::json::Json;
use crate::obs::Histogram;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc, RwLock};
use std::time::{Duration, Instant};

/// How each entry's engine is built (the registry rebuilds engines on
/// demote/promote/swap, so it owns the construction knobs).
#[derive(Debug, Clone, Copy)]
pub struct EngineCfg {
    /// integer-accumulation fast path (false = f32-exact reference)
    pub int_accum: bool,
    /// intra-batch threads per engine
    pub threads: usize,
    /// per-layer timing counters
    pub layer_timing: bool,
}

impl Default for EngineCfg {
    fn default() -> Self {
        EngineCfg { int_accum: true, threads: 1, layer_timing: false }
    }
}

/// Fleet configuration.
#[derive(Debug, Clone, Default)]
pub struct RegistryCfg {
    /// per-model pool shape (every entry gets its own pool of this shape)
    pub serve: ServeCfg,
    pub engine: EngineCfg,
    /// total prepared-plane byte budget across the fleet; `None` is
    /// unlimited, `Some(0)` forces every model to streaming mode
    pub mem_budget: Option<usize>,
    /// shard supervision knobs; `shard.shards > 0` moves every
    /// QPKG-backed entry's pool into child processes (`shard.serve` /
    /// `shard.threads` are overridden by `serve` / `engine.threads` so
    /// there is a single source of truth for pool shape)
    pub shard: ShardCfg,
}

/// The swappable forward an entry's pool drives: readers clone the
/// inner `Arc<Engine>` under a read lock, a swap write-locks and
/// replaces it. An in-flight `forward_batch` keeps its clone alive, so
/// cutover never interrupts a running batch and the old planes drop at
/// the last reference.
pub struct SwapForward {
    id: String,
    inner: RwLock<Arc<Engine>>,
}

impl SwapForward {
    fn new(id: String, engine: Engine) -> Self {
        SwapForward { id, inner: RwLock::new(Arc::new(engine)) }
    }

    /// The current engine (cloned `Arc`; survives a concurrent swap).
    pub fn engine(&self) -> Arc<Engine> {
        self.inner.read().expect("swap lock").clone()
    }

    fn set(&self, engine: Arc<Engine>) {
        *self.inner.write().expect("swap lock") = engine;
    }
}

impl BatchForward for SwapForward {
    fn d_in(&self) -> usize {
        self.engine().model().d_in()
    }

    fn num_classes(&self) -> usize {
        self.engine().model().num_classes
    }

    /// The registry id, not the QPKG-internal name: routing identity is
    /// stable across hot-swaps even if the payload renames itself.
    fn model_name(&self) -> &str {
        &self.id
    }

    fn forward_batch(&self, x: &[f32], b: usize) -> Result<Vec<f32>> {
        self.engine().forward_batch(x, b)
    }
}

/// QPKG-backed state of one entry (everything a demote/promote/swap
/// rebuild needs).
struct QpkgBacking {
    swap: Arc<SwapForward>,
    /// retained source model so promote can re-decode planes
    model: DeployModel,
    /// FNV-1a fingerprint of the serialized QPKG bytes — the cache-key
    /// component that makes hot-swap stale-proof
    content_id: u64,
    /// bumped on every successful load over this id
    version: u64,
    prepared: bool,
    /// plane cost when prepared (stable across demotion)
    plane_bytes: usize,
    source: String,
}

enum Backing {
    /// caller-provided forward (tests, wrappers): not swappable, not
    /// budget-managed
    External(Arc<dyn BatchForward>),
    Qpkg(QpkgBacking),
}

/// The serving backend behind one entry: the classic in-process
/// batching pool, or a supervised pool of shard child processes
/// (`--shards N`). Both expose the same admission surface
/// (`try_submit` / `submit` / `stats`), so the ingress routes without
/// caring which is behind an id.
pub enum PoolBackend {
    InProcess(Server),
    Sharded(ShardPool),
}

impl PoolBackend {
    /// Non-blocking admission: `Ok(None)` = shed (queue full), `Err` =
    /// pool unusable (dead in-process pool / no shard up / bad input).
    pub fn try_submit(
        &self,
        x: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<Option<mpsc::Receiver<Response>>> {
        match self {
            PoolBackend::InProcess(s) => s.try_submit(x, deadline),
            PoolBackend::Sharded(p) => p.try_submit(x, deadline),
        }
    }

    /// Blocking submit (tests and benches).
    pub fn submit(&self, x: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        match self {
            PoolBackend::InProcess(s) => s.submit(x),
            PoolBackend::Sharded(p) => p.submit(x),
        }
    }

    pub fn stats(&self) -> &ServeStats {
        match self {
            PoolBackend::InProcess(s) => s.stats(),
            PoolBackend::Sharded(p) => p.stats(),
        }
    }

    /// A sharded pool never reports dead: a crashed child is a restart
    /// in progress, not a permanently wedged pool.
    pub fn is_dead(&self) -> bool {
        match self {
            PoolBackend::InProcess(s) => s.is_dead(),
            PoolBackend::Sharded(_) => false,
        }
    }

    pub fn is_sharded(&self) -> bool {
        matches!(self, PoolBackend::Sharded(_))
    }

    pub fn shard(&self) -> Option<&ShardPool> {
        match self {
            PoolBackend::Sharded(p) => Some(p),
            PoolBackend::InProcess(_) => None,
        }
    }

    pub fn shutdown(self) -> (u64, u64) {
        match self {
            PoolBackend::InProcess(s) => s.shutdown(),
            PoolBackend::Sharded(p) => p.shutdown(),
        }
    }
}

/// One resident model: its backing, its own serving pool, and the
/// LRU/traffic bookkeeping the ingress event loop maintains.
pub struct ModelEntry {
    id: String,
    backing: Backing,
    pool: PoolBackend,
    last_used: u64,
    requests: u64,
    ok: u64,
}

impl ModelEntry {
    pub fn id(&self) -> &str {
        &self.id
    }

    pub fn pool(&self) -> &PoolBackend {
        &self.pool
    }

    pub fn d_in(&self) -> usize {
        match &self.backing {
            Backing::External(f) => f.d_in(),
            Backing::Qpkg(b) => b.swap.d_in(),
        }
    }

    /// Cache-key content identity (0 for external forwards, which have
    /// no content to fingerprint and never swap).
    pub fn content_id(&self) -> u64 {
        match &self.backing {
            Backing::External(_) => 0,
            Backing::Qpkg(b) => b.content_id,
        }
    }

    pub fn version(&self) -> u64 {
        match &self.backing {
            Backing::External(_) => 0,
            Backing::Qpkg(b) => b.version,
        }
    }

    pub fn mode_str(&self) -> &'static str {
        if self.pool.is_sharded() {
            return "sharded";
        }
        match &self.backing {
            Backing::External(_) => "external",
            Backing::Qpkg(b) if b.prepared => "prepared",
            Backing::Qpkg(_) => "streaming",
        }
    }

    /// Prepared-plane cost in bytes (what residency costs, whether or
    /// not the planes are currently resident).
    pub fn plane_cost(&self) -> usize {
        match &self.backing {
            Backing::External(_) => 0,
            Backing::Qpkg(b) => b.plane_bytes,
        }
    }

    pub fn requests(&self) -> u64 {
        self.requests
    }

    pub fn ok(&self) -> u64 {
        self.ok
    }

    fn summary_json(&self, is_default: bool) -> Json {
        let mut o = BTreeMap::new();
        o.insert("id".to_string(), Json::Str(self.id.clone()));
        o.insert("mode".to_string(), Json::Str(self.mode_str().to_string()));
        o.insert("default".to_string(), Json::Bool(is_default));
        o.insert("version".to_string(), Json::Num(self.version() as f64));
        o.insert("plane_bytes".to_string(), Json::Num(self.plane_cost() as f64));
        o.insert("requests".to_string(), Json::Num(self.requests as f64));
        o.insert("pool_dead".to_string(), Json::Bool(self.pool.is_dead()));
        if let PoolBackend::Sharded(sp) = &self.pool {
            o.insert("shards".to_string(), Json::Num(sp.shards() as f64));
            o.insert("shards_up".to_string(), Json::Num(sp.up_count() as f64));
        }
        if let Backing::Qpkg(b) = &self.backing {
            o.insert("content".to_string(), Json::Str(format!("{:016x}", b.content_id)));
            o.insert("bits_w".to_string(), Json::Num(b.model.bits_w as f64));
            o.insert("bits_a".to_string(), Json::Num(b.model.bits_a as f64));
        }
        Json::Obj(o)
    }

    fn detail_json(&self, is_default: bool) -> Json {
        let mut j = self.summary_json(is_default);
        if let Json::Obj(o) = &mut j {
            o.insert("d_in".to_string(), Json::Num(self.d_in() as f64));
            o.insert("ok".to_string(), Json::Num(self.ok as f64));
            if let Backing::Qpkg(b) = &self.backing {
                o.insert("num_classes".to_string(), Json::Num(b.model.num_classes as f64));
                o.insert("layers".to_string(), Json::Num(b.model.layers.len() as f64));
                o.insert(
                    "packed_bytes".to_string(),
                    Json::Num(b.model.packed_weight_bytes() as f64),
                );
                o.insert("source".to_string(), Json::Str(b.source.clone()));
            }
        }
        j
    }
}

/// What a load/swap produced (CLI banner + `/load` response body).
#[derive(Debug, Clone)]
pub struct LoadOutcome {
    pub id: String,
    pub version: u64,
    pub prepared: bool,
    pub plane_bytes: usize,
    pub content_id: u64,
    /// served by child shard processes rather than the in-process pool
    pub sharded: bool,
}

/// Fleet residency counts for the registry gauges.
#[derive(Debug, Clone, Copy, Default)]
pub struct RegistryCounts {
    pub prepared: usize,
    pub streaming: usize,
    pub external: usize,
    pub swaps: u64,
    pub demotions: u64,
    pub promotions: u64,
}

/// Prepared-plane cost of a model **without** decoding the planes:
/// mirrors [`PreparedModel::plane_bytes`] (one f32 plane always, plus
/// an i32 plane for activation-quantized layers).
pub fn plane_cost(dm: &DeployModel) -> usize {
    dm.layers
        .iter()
        .map(|l| l.weights.len * 4 * if l.aq { 2 } else { 1 })
        .sum()
}

fn build_engine(dm: DeployModel, prepared: bool, ec: &EngineCfg) -> Engine {
    let pm = if prepared { PreparedModel::new(dm) } else { PreparedModel::unprepared(dm) };
    let opts = EngineOpts { threads: ec.threads, prepared, layer_timing: ec.layer_timing };
    Engine::from_prepared(Arc::new(pm), ec.int_accum, opts)
}

/// The fleet: ordered model entries (insertion order is the public
/// listing order; indices are stable because entries are never
/// removed, only demoted), an LRU clock, and the shared stage
/// histograms every per-model pool feeds.
pub struct ModelRegistry {
    cfg: RegistryCfg,
    entries: Vec<ModelEntry>,
    default_id: Option<String>,
    /// monotone LRU clock, bumped per routed request
    tick: u64,
    swaps: u64,
    demotions: u64,
    promotions: u64,
    stage_queue: Arc<Histogram>,
    stage_compute: Arc<Histogram>,
    /// observed heartbeat intervals across every shard of every model
    shard_hb: Arc<Histogram>,
}

impl ModelRegistry {
    pub fn new(cfg: RegistryCfg) -> Self {
        ModelRegistry {
            cfg,
            entries: Vec::new(),
            default_id: None,
            tick: 0,
            swaps: 0,
            demotions: 0,
            promotions: 0,
            stage_queue: Arc::new(Histogram::new()),
            stage_compute: Arc::new(Histogram::new()),
            shard_hb: Arc::new(Histogram::new()),
        }
    }

    /// The fleet-wide stage histograms (the ingress adopts these into
    /// its `/metrics` registry once, covering every pool).
    pub fn stage_histograms(&self) -> (Arc<Histogram>, Arc<Histogram>) {
        (self.stage_queue.clone(), self.stage_compute.clone())
    }

    /// Fleet-wide shard heartbeat-interval histogram (adopted by the
    /// ingress as `qat_shard_heartbeat_age_seconds`).
    pub fn shard_heartbeat_histogram(&self) -> Arc<Histogram> {
        self.shard_hb.clone()
    }

    /// Whether QPKG-backed entries serve from child shard processes.
    pub fn sharded(&self) -> bool {
        self.cfg.shard.shards > 0
    }

    fn start_pool(&self, fwd: Arc<dyn BatchForward>) -> Server {
        let stats =
            ServeStats::with_stage_histograms(self.stage_queue.clone(), self.stage_compute.clone());
        Server::start_with_stats(fwd, &self.cfg.serve, stats)
    }

    /// Start the supervised child-process pool for one entry. Pool
    /// shape and engine threads come from the registry-level `serve` /
    /// `engine` config so `--workers`-style knobs mean the same thing
    /// sharded or not.
    fn start_shard_pool(&self, id: &str, qpkg: PathBuf, d_in: usize) -> Result<ShardPool> {
        let stats =
            ServeStats::with_stage_histograms(self.stage_queue.clone(), self.stage_compute.clone());
        let cfg = ShardCfg {
            serve: self.cfg.serve.clone(),
            threads: self.cfg.engine.threads,
            ..self.cfg.shard.clone()
        };
        ShardPool::start(id, qpkg, d_in, cfg, stats, self.shard_hb.clone())
    }

    /// Shard children load their QPKG from disk; an in-memory model
    /// (`insert_model`) is first written to a stable temp path. The
    /// version rides in the filename so a hot-swap never overwrites the
    /// artifact a still-running child may be re-reading.
    fn materialize_qpkg(id: &str, version: u64, dm: &DeployModel) -> Result<PathBuf> {
        let dir = std::env::temp_dir().join("qat_shard_qpkg");
        std::fs::create_dir_all(&dir).context("create shard qpkg dir")?;
        let safe: String = id
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .collect();
        let path = dir.join(format!("{safe}_{}_v{version}.qpkg", std::process::id()));
        dm.write_qpkg(&path)
            .with_context(|| format!("materialize qpkg for shard children: {}", path.display()))?;
        Ok(path)
    }

    /// Register a caller-managed forward under its own `model_name`.
    /// External entries route and serve like any other but cannot be
    /// hot-swapped, never count against the plane budget, and always
    /// run in-process (there is no QPKG artifact to hand a shard child).
    pub fn add_external(&mut self, fwd: Arc<dyn BatchForward>) -> Result<()> {
        let id = fwd.model_name().to_string();
        anyhow::ensure!(self.index_of(&id).is_none(), "duplicate model id {id:?}");
        let pool = PoolBackend::InProcess(self.start_pool(fwd.clone()));
        self.tick += 1;
        self.entries.push(ModelEntry {
            id: id.clone(),
            backing: Backing::External(fwd),
            pool,
            last_used: self.tick,
            requests: 0,
            ok: 0,
        });
        if self.default_id.is_none() {
            self.default_id = Some(id);
        }
        Ok(())
    }

    /// Load (new id) or hot-swap (existing id) a QPKG file.
    pub fn load_qpkg(&mut self, id: &str, path: &Path) -> Result<LoadOutcome> {
        let bytes =
            std::fs::read(path).with_context(|| format!("read qpkg {}", path.display()))?;
        let dm = DeployModel::from_bytes(&bytes)
            .with_context(|| format!("parse qpkg {}", path.display()))?;
        let content_id = ResponseCache::fingerprint(&bytes);
        self.install(id, dm, content_id, path.display().to_string(), Some(path))
    }

    /// Register an in-memory model (tests + benchmarks); content
    /// identity is fingerprinted off its serialized form, exactly as a
    /// file load would.
    pub fn insert_model(&mut self, id: &str, dm: DeployModel) -> Result<LoadOutcome> {
        let content_id = ResponseCache::fingerprint(&dm.to_bytes());
        self.install(id, dm, content_id, "(inline)".to_string(), None)
    }

    fn install(
        &mut self,
        id: &str,
        dm: DeployModel,
        content_id: u64,
        source: String,
        src_path: Option<&Path>,
    ) -> Result<LoadOutcome> {
        let cost = plane_cost(&dm);
        let d_in = dm.d_in();
        let sharded = self.sharded();
        let existing = self.index_of(id);
        if let Some(ix) = existing {
            anyhow::ensure!(
                matches!(self.entries[ix].backing, Backing::Qpkg(_)),
                "model {id:?} is not hot-swappable (externally managed forward)"
            );
            // a shard pool's admission width is fixed for its lifetime
            // (children validate d_in in the Hello handshake)
            anyhow::ensure!(
                !self.entries[ix].pool.is_sharded() || self.entries[ix].d_in() == d_in,
                "sharded hot-swap cannot change input width ({} -> {})",
                self.entries[ix].d_in(),
                d_in,
            );
        }
        // an explicit load outranks residency history: anything colder
        // than "now" may be demoted to make room. Sharded entries keep a
        // streaming (plane-free) engine in the parent — the prepared
        // planes live inside the children, outside this budget.
        let prepared = !sharded && self.ensure_budget(existing, cost, u64::MAX);
        let engine = build_engine(dm.clone(), prepared, &self.cfg.engine);
        let version = match existing {
            Some(ix) => {
                let Backing::Qpkg(b) = &mut self.entries[ix].backing else { unreachable!() };
                // atomic cutover: queued + future requests see the new
                // engine, in-flight batches drain on their old Arc, old
                // planes free at the last reference
                b.swap.set(Arc::new(engine));
                b.model = dm;
                b.content_id = content_id;
                b.version += 1;
                b.prepared = prepared;
                b.plane_bytes = cost;
                b.source = source;
                let v = b.version;
                self.swaps += 1;
                if self.entries[ix].pool.is_sharded() {
                    let path = match src_path {
                        Some(p) => p.to_path_buf(),
                        None => {
                            let Backing::Qpkg(b) = &self.entries[ix].backing else {
                                unreachable!()
                            };
                            Self::materialize_qpkg(id, v, &b.model)?
                        }
                    };
                    if let PoolBackend::Sharded(sp) = &self.entries[ix].pool {
                        // children drain in-flight work, then respawn on
                        // the new artifact (rolling, one slot at a time)
                        sp.swap_qpkg(path);
                    }
                }
                v
            }
            None => {
                // the swap holds the parent-side engine either way: for
                // in-process entries it is the serving path, for sharded
                // entries it is the metadata + hot-swap identity
                // (streaming, no planes — the children serve)
                let swap = Arc::new(SwapForward::new(id.to_string(), engine));
                let pool = if sharded {
                    let path = match src_path {
                        Some(p) => p.to_path_buf(),
                        None => Self::materialize_qpkg(id, 1, &dm)?,
                    };
                    PoolBackend::Sharded(self.start_shard_pool(id, path, d_in)?)
                } else {
                    PoolBackend::InProcess(self.start_pool(swap.clone() as Arc<dyn BatchForward>))
                };
                self.tick += 1;
                self.entries.push(ModelEntry {
                    id: id.to_string(),
                    backing: Backing::Qpkg(QpkgBacking {
                        swap,
                        model: dm,
                        content_id,
                        version: 1,
                        prepared,
                        plane_bytes: cost,
                        source,
                    }),
                    pool,
                    last_used: self.tick,
                    requests: 0,
                    ok: 0,
                });
                if self.default_id.is_none() {
                    self.default_id = Some(id.to_string());
                }
                1
            }
        };
        Ok(LoadOutcome {
            id: id.to_string(),
            version,
            prepared,
            plane_bytes: cost,
            content_id,
            sharded,
        })
    }

    /// Make room for `want` prepared bytes on behalf of `skip` (which
    /// never demotes itself). Only entries whose `last_used` is below
    /// `colder_than` are demotable — the anti-thrash rule: promotion on
    /// traffic may only steal planes from strictly colder models, so an
    /// over-budget round-robin doesn't rebuild engines every request.
    /// Returns whether `want` bytes fit (demoting as needed); demotes
    /// nothing when it can't succeed.
    fn ensure_budget(&mut self, skip: Option<usize>, want: usize, colder_than: u64) -> bool {
        let Some(budget) = self.cfg.mem_budget else { return true };
        if want > budget {
            return false;
        }
        let mut used = 0usize;
        let mut reclaimable = 0usize;
        for (i, e) in self.entries.iter().enumerate() {
            if Some(i) == skip {
                continue;
            }
            if let Backing::Qpkg(b) = &e.backing {
                if b.prepared {
                    used += b.plane_bytes;
                    if e.last_used < colder_than {
                        reclaimable += b.plane_bytes;
                    }
                }
            }
        }
        if used + want <= budget {
            return true;
        }
        if used.saturating_sub(reclaimable) + want > budget {
            return false;
        }
        while used + want > budget {
            let victim = self
                .entries
                .iter()
                .enumerate()
                .filter(|(i, e)| {
                    Some(*i) != skip
                        && e.last_used < colder_than
                        && matches!(&e.backing, Backing::Qpkg(b) if b.prepared)
                })
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i);
            let Some(ix) = victim else { return false };
            used -= self.entries[ix].plane_cost();
            self.demote(ix);
        }
        true
    }

    fn demote(&mut self, ix: usize) {
        let ec = self.cfg.engine;
        let id = self.entries[ix].id.clone();
        let Backing::Qpkg(b) = &mut self.entries[ix].backing else { return };
        if !b.prepared {
            return;
        }
        b.swap.set(Arc::new(build_engine(b.model.clone(), false, &ec)));
        b.prepared = false;
        let freed = b.plane_bytes;
        self.demotions += 1;
        eprintln!("[fleet] demoted model {id:?} to streaming ({freed} plane bytes freed)");
    }

    fn promote(&mut self, ix: usize) {
        let ec = self.cfg.engine;
        let id = self.entries[ix].id.clone();
        let Backing::Qpkg(b) = &mut self.entries[ix].backing else { return };
        if b.prepared {
            return;
        }
        b.swap.set(Arc::new(build_engine(b.model.clone(), true, &ec)));
        b.prepared = true;
        let bytes = b.plane_bytes;
        self.promotions += 1;
        eprintln!("[fleet] promoted model {id:?} to prepared planes ({bytes} bytes resident)");
    }

    /// Record one routed request: bumps the LRU clock + per-model
    /// counter, and promotes a streaming entry back to prepared planes
    /// when the budget allows (stealing only from colder entries).
    pub fn touch_ix(&mut self, ix: usize) {
        let prev = self.entries[ix].last_used;
        self.tick += 1;
        self.entries[ix].last_used = self.tick;
        self.entries[ix].requests += 1;
        // sharded entries never promote: the parent-side engine stays
        // streaming by design (planes are resident in the children)
        let wants = match &self.entries[ix].backing {
            Backing::Qpkg(b) if !b.prepared && !self.entries[ix].pool.is_sharded() => {
                Some(b.plane_bytes)
            }
            _ => None,
        };
        if let Some(cost) = wants {
            if self.ensure_budget(Some(ix), cost, prev) {
                self.promote(ix);
            }
        }
    }

    /// Record one 200 answer attributed to entry `ix` (pool- or
    /// cache-served alike).
    pub fn mark_ok_ix(&mut self, ix: usize) {
        self.entries[ix].ok += 1;
    }

    pub fn index_of(&self, id: &str) -> Option<usize> {
        self.entries.iter().position(|e| e.id == id)
    }

    pub fn entry(&self, ix: usize) -> &ModelEntry {
        &self.entries[ix]
    }

    pub fn default_id(&self) -> Option<&str> {
        self.default_id.as_deref()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &ModelEntry> {
        self.entries.iter()
    }

    /// True when any entry's pool has died (a panicked worker fleet).
    pub fn any_dead(&self) -> bool {
        self.entries.iter().any(|e| e.pool.is_dead())
    }

    pub fn mem_budget(&self) -> Option<usize> {
        self.cfg.mem_budget
    }

    /// Total plane bytes currently resident (prepared entries only).
    pub fn prepared_bytes(&self) -> usize {
        self.entries
            .iter()
            .filter_map(|e| match &e.backing {
                Backing::Qpkg(b) if b.prepared => Some(b.plane_bytes),
                _ => None,
            })
            .sum()
    }

    pub fn counts(&self) -> RegistryCounts {
        let mut c = RegistryCounts {
            swaps: self.swaps,
            demotions: self.demotions,
            promotions: self.promotions,
            ..RegistryCounts::default()
        };
        for e in &self.entries {
            match &e.backing {
                Backing::External(_) => c.external += 1,
                Backing::Qpkg(b) if b.prepared => c.prepared += 1,
                Backing::Qpkg(_) => c.streaming += 1,
            }
        }
        c
    }

    /// `GET /v1/models` body.
    pub fn list_json(&self) -> Json {
        let mut o = BTreeMap::new();
        let models: Vec<Json> = self
            .entries
            .iter()
            .map(|e| e.summary_json(self.default_id.as_deref() == Some(e.id.as_str())))
            .collect();
        o.insert("models".to_string(), Json::Arr(models));
        match self.cfg.mem_budget {
            Some(b) => o.insert("mem_budget_bytes".to_string(), Json::Num(b as f64)),
            None => o.insert("mem_budget_bytes".to_string(), Json::Null),
        };
        o.insert("prepared_bytes".to_string(), Json::Num(self.prepared_bytes() as f64));
        Json::Obj(o)
    }

    /// `GET /v1/models/{id}` body.
    pub fn detail_json(&self, ix: usize) -> Json {
        let e = &self.entries[ix];
        e.detail_json(self.default_id.as_deref() == Some(e.id.as_str()))
    }

    /// Drain and stop every pool; returns fleet-total (batches,
    /// requests).
    pub fn shutdown(self) -> (u64, u64) {
        let (mut batches, mut requests) = (0u64, 0u64);
        for e in self.entries {
            let (b, r) = e.pool.shutdown();
            batches += b;
            requests += r;
        }
        (batches, requests)
    }
}

// ---------------------------------------------------------------------------
// fleet benchmark
// ---------------------------------------------------------------------------

/// Fleet rows merged into BENCH_serve.json beside the `http_*` rows.
#[derive(Debug, Clone)]
pub struct FleetBenchReport {
    /// (resident models, aggregate requests/sec) for N in {2, 4, 8}
    pub fleet_rps: Vec<(usize, f64)>,
    pub swap_requests: usize,
    pub swap_count: usize,
    /// p99 predict latency across every request issued while hot-swaps
    /// were cutting over under load — the swap-induced spike the
    /// baseline bounds from above
    pub swap_p99_spike_ms: f64,
}

impl FleetBenchReport {
    pub fn merge_into(&self, o: &mut BTreeMap<String, Json>) {
        for (n, rps) in &self.fleet_rps {
            o.insert(format!("fleet_rps_{n}"), Json::Num(finite_or_zero(*rps)));
        }
        o.insert("swap_requests".to_string(), Json::Num(self.swap_requests as f64));
        o.insert("swap_count".to_string(), Json::Num(self.swap_count as f64));
        o.insert(
            "swap_p99_spike_ms".to_string(),
            Json::Num(finite_or_zero(self.swap_p99_spike_ms)),
        );
    }

    pub fn summary(&self) -> String {
        let rows: Vec<String> = self
            .fleet_rps
            .iter()
            .map(|(n, r)| format!("{n} models {r:.0} req/s"))
            .collect();
        format!(
            "fleet: {}; hot-swap p99 {:.2}ms ({} requests across {} swaps, zero drops)",
            rows.join(", "),
            self.swap_p99_spike_ms,
            self.swap_requests,
            self.swap_count
        )
    }
}

fn fleet_input(d_in: usize, seed: usize) -> Vec<f32> {
    (0..d_in).map(|i| ((seed * 31 + i * 7) % 13) as f32 * 0.25).collect()
}

fn fleet_body(input: &[f32]) -> Vec<u8> {
    let mut s = String::from("{\"input\":[");
    for (i, v) in input.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("{v}"));
    }
    s.push_str("]}");
    s.into_bytes()
}

fn json_quote(s: &str) -> String {
    crate::json::to_string(&Json::Str(s.to_string()))
}

fn send_fleet_request(
    stream: &mut TcpStream,
    path: &str,
    body: &[u8],
) -> Result<(u16, Duration)> {
    let req = http::format_request(path, body, &[]);
    let t0 = Instant::now();
    stream.write_all(&req).context("write request")?;
    let resp = http::read_response(stream).context("read response")?;
    Ok((resp.status, t0.elapsed()))
}

/// The two fleet scenarios behind the gated rows:
///
/// 1. **Aggregate throughput at N ∈ {2, 4, 8} resident models** — N
///    renamed copies of `dm` (distinct content ids), clients
///    round-robining `/v1/models/{id}/predict` across the fleet.
/// 2. **Hot-swap spike** — clients hammer one model while the bench
///    alternates two QPKG versions through `/v1/models/{id}/load`;
///    every request must answer 200 (zero drops) and the p99 over all
///    of them is the gated spike row.
pub fn bench_fleet(dm: &DeployModel, serve_cfg: &ServeCfg, smoke: bool) -> Result<FleetBenchReport> {
    // cache off: the rows measure the serving path, not the cache
    let http_cfg = HttpCfg { cache_cap: 0, ..HttpCfg::default() };
    let d_in = dm.d_in();

    let mut fleet_rps = Vec::new();
    for n in [2usize, 4, 8] {
        let mut models =
            ModelRegistry::new(RegistryCfg { serve: serve_cfg.clone(), ..RegistryCfg::default() });
        for i in 0..n {
            let mut m = dm.clone();
            m.name = format!("{}_r{i}", m.name);
            models.insert_model(&format!("m{i}"), m)?;
        }
        let srv = HttpServer::start_registry(models, &http_cfg)?;
        let addr = srv.addr();
        let clients = n.min(4);
        let per_client = if smoke { 24 } else { 96 };
        let t0 = Instant::now();
        std::thread::scope(|s| -> Result<()> {
            let handles: Vec<_> = (0..clients)
                .map(|c| {
                    s.spawn(move || -> Result<()> {
                        let mut stream = TcpStream::connect(addr).context("connect")?;
                        let _ = stream.set_nodelay(true);
                        for r in 0..per_client {
                            let k = (c + r * clients) % n;
                            let body = fleet_body(&fleet_input(d_in, c * per_client + r));
                            let (status, _) = send_fleet_request(
                                &mut stream,
                                &format!("/v1/models/m{k}/predict"),
                                &body,
                            )?;
                            anyhow::ensure!(status == 200, "fleet request got {status}");
                        }
                        Ok(())
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("client thread panicked")?;
            }
            Ok(())
        })?;
        let wall = t0.elapsed().as_secs_f64();
        srv.stop();
        fleet_rps.push((n, (clients * per_client) as f64 / wall.max(1e-9)));
    }

    // --- hot-swap under load
    let dir = std::env::temp_dir().join("qat_fleet_bench");
    std::fs::create_dir_all(&dir).context("create bench dir")?;
    let mut v1 = dm.clone();
    v1.name = format!("{}_v1", dm.name);
    let mut v2 = dm.clone();
    v2.name = format!("{}_v2", dm.name);
    let p1 = dir.join("swap_v1.qpkg");
    let p2 = dir.join("swap_v2.qpkg");
    v1.write_qpkg(&p1)?;
    v2.write_qpkg(&p2)?;
    let mut models =
        ModelRegistry::new(RegistryCfg { serve: serve_cfg.clone(), ..RegistryCfg::default() });
    models.load_qpkg("swap", &p1)?;
    let srv = HttpServer::start_registry(models, &http_cfg)?;
    let addr = srv.addr();
    let clients = 2usize;
    let per_client = if smoke { 40 } else { 160 };
    let swap_count = if smoke { 4 } else { 12 };
    let mut lat: Vec<f64> = std::thread::scope(|s| -> Result<Vec<f64>> {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                s.spawn(move || -> Result<Vec<f64>> {
                    let mut stream = TcpStream::connect(addr).context("connect")?;
                    let _ = stream.set_nodelay(true);
                    let mut lat = Vec::with_capacity(per_client);
                    for r in 0..per_client {
                        let body = fleet_body(&fleet_input(d_in, c * per_client + r));
                        let (status, dt) =
                            send_fleet_request(&mut stream, "/v1/models/swap/predict", &body)?;
                        // the hot-swap guarantee: zero drops mid-swap
                        anyhow::ensure!(status == 200, "mid-swap predict got {status}");
                        lat.push(dt.as_secs_f64() * 1e3);
                    }
                    Ok(lat)
                })
            })
            .collect();
        // alternate versions while the clients run
        let mut admin = TcpStream::connect(addr).context("connect admin")?;
        let _ = admin.set_nodelay(true);
        let paths = [&p2, &p1];
        for sw in 0..swap_count {
            std::thread::sleep(Duration::from_millis(5));
            let body = format!("{{\"qpkg\":{}}}", json_quote(&paths[sw % 2].display().to_string()));
            let (status, _) =
                send_fleet_request(&mut admin, "/v1/models/swap/load", body.as_bytes())?;
            anyhow::ensure!(status == 200, "hot-swap load got {status}");
        }
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().expect("client thread panicked")?);
        }
        Ok(all)
    })?;
    srv.stop();
    lat.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
    Ok(FleetBenchReport {
        fleet_rps,
        swap_requests: clients * per_client,
        swap_count,
        swap_p99_spike_ms: percentile(&lat, 0.99),
    })
}

#[cfg(test)]
mod tests {
    use super::super::tests::{one_hot_block, tiny_model};
    use super::*;
    use crate::deploy::format::DeployModel;

    /// `tiny_model` with the class mapping rotated: `one_hot_block(c)`
    /// predicts `(c + rot) % 3`.
    fn rot_model(name: &str, rot: usize) -> DeployModel {
        use crate::deploy::packed::Packed;
        let mut m = tiny_model();
        m.name = name.to_string();
        let mut codes = vec![4u32; 12 * 3];
        for c in 0..3usize {
            for f in 0..4usize {
                codes[(c * 4 + f) * 3 + (c + rot) % 3] = 6;
            }
        }
        m.layers[0].weights = Packed::pack(&codes, 3).unwrap();
        m
    }

    fn pred_of(reg: &ModelRegistry, id: &str, c: usize) -> usize {
        let ix = reg.index_of(id).expect("known id");
        let rx = reg.entry(ix).pool().submit(one_hot_block(c)).unwrap();
        rx.recv().unwrap().pred
    }

    #[test]
    fn plane_cost_matches_prepared_model() {
        let m = tiny_model();
        assert_eq!(plane_cost(&m), PreparedModel::new(m.clone()).plane_bytes());
        assert!(plane_cost(&m) > 0);
    }

    #[test]
    fn budget_demotes_lru_and_promotes_on_traffic() {
        let cost = plane_cost(&tiny_model());
        let mut reg = ModelRegistry::new(RegistryCfg {
            mem_budget: Some(2 * cost),
            ..RegistryCfg::default()
        });
        for id in ["a", "b", "c"] {
            let out = reg.insert_model(id, rot_model(id, 0)).unwrap();
            assert_eq!(out.version, 1);
        }
        // three models, room for two: the LRU ("a", loaded first) was
        // demoted to make room for "c"
        let mode = |reg: &ModelRegistry, id: &str| {
            reg.entry(reg.index_of(id).unwrap()).mode_str().to_string()
        };
        assert_eq!(mode(&reg, "a"), "streaming");
        assert_eq!(mode(&reg, "b"), "prepared");
        assert_eq!(mode(&reg, "c"), "prepared");
        assert_eq!(reg.counts().demotions, 1);
        assert_eq!(reg.prepared_bytes(), 2 * cost);
        // the streaming model still serves, bit-exact
        assert_eq!(pred_of(&reg, "a", 1), 1);
        // one touch: "a" is now the warmest, but its *previous*
        // recency was coldest, so nothing colder exists to steal from
        let a = reg.index_of("a").unwrap();
        reg.touch_ix(a);
        assert_eq!(mode(&reg, "a"), "streaming");
        // sustained traffic: the second touch finds "b"/"c" colder
        // than "a"'s previous touch, demotes the LRU of them, and
        // promotes "a" back to prepared planes
        reg.touch_ix(a);
        assert_eq!(mode(&reg, "a"), "prepared");
        assert_eq!(mode(&reg, "b"), "streaming");
        assert_eq!(mode(&reg, "c"), "prepared");
        let counts = reg.counts();
        assert_eq!(counts.promotions, 1);
        assert_eq!(counts.demotions, 2);
        assert_eq!((counts.prepared, counts.streaming), (2, 1));
        // predictions survive the residency churn
        assert_eq!(pred_of(&reg, "a", 2), 2);
        assert_eq!(pred_of(&reg, "b", 0), 0);
        reg.shutdown();
    }

    #[test]
    fn a_model_too_big_for_the_budget_stays_streaming() {
        let cost = plane_cost(&tiny_model());
        let mut reg = ModelRegistry::new(RegistryCfg {
            mem_budget: Some(cost - 1),
            ..RegistryCfg::default()
        });
        let out = reg.insert_model("m", tiny_model()).unwrap();
        assert!(!out.prepared);
        assert_eq!(reg.entry(0).mode_str(), "streaming");
        assert_eq!(pred_of(&reg, "m", 0), 0);
        reg.shutdown();
    }

    #[test]
    fn hot_swap_bumps_version_and_serves_the_new_weights() {
        let dir = std::env::temp_dir().join("qat_registry_swap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut reg = ModelRegistry::new(RegistryCfg::default());
        let out = reg.insert_model("m", rot_model("m_v1", 0)).unwrap();
        assert_eq!((out.version, out.prepared), (1, true));
        assert_eq!(pred_of(&reg, "m", 0), 0);
        // swap in the rotated version through the file path
        let p = dir.join("m_v2.qpkg");
        rot_model("m_v2", 1).write_qpkg(&p).unwrap();
        let swapped = reg.load_qpkg("m", &p).unwrap();
        assert_eq!(swapped.version, 2);
        assert_ne!(swapped.content_id, out.content_id, "content identity must change");
        assert_eq!(reg.counts().swaps, 1);
        // same pool, same id, new weights: class 0 now maps to 1
        assert_eq!(pred_of(&reg, "m", 0), 1);
        assert_eq!(pred_of(&reg, "m", 2), 0);
        // the entry reports the new version in the listing
        let ix = reg.index_of("m").unwrap();
        let j = reg.detail_json(ix);
        assert_eq!(j.get("version").as_usize(), Some(2));
        assert_eq!(j.get("mode").as_str(), Some("prepared"));
        reg.shutdown();
    }

    #[test]
    fn external_entries_reject_swap() {
        use crate::deploy::engine::Engine;
        let mut reg = ModelRegistry::new(RegistryCfg::default());
        reg.add_external(Arc::new(Engine::new(tiny_model()))).unwrap();
        assert_eq!(reg.default_id(), Some("tiny"));
        assert_eq!(reg.entry(0).mode_str(), "external");
        let err = reg
            .insert_model("tiny", rot_model("x", 1))
            .expect_err("external entries must not be swappable");
        assert!(format!("{err:#}").contains("not hot-swappable"), "{err:#}");
        // duplicate external ids are rejected too
        assert!(reg.add_external(Arc::new(Engine::new(tiny_model()))).is_err());
        reg.shutdown();
    }

    #[test]
    fn list_json_reports_the_fleet() {
        let cost = plane_cost(&tiny_model());
        let mut reg = ModelRegistry::new(RegistryCfg {
            mem_budget: Some(2 * cost),
            ..RegistryCfg::default()
        });
        for id in ["a", "b", "c"] {
            reg.insert_model(id, rot_model(id, 0)).unwrap();
        }
        let j = reg.list_json();
        let models = j.get("models").as_arr().expect("models array");
        assert_eq!(models.len(), 3);
        assert_eq!(models[0].get("id").as_str(), Some("a"));
        assert_eq!(models[0].get("mode").as_str(), Some("streaming"));
        assert_eq!(models[0].get("default"), &Json::Bool(true));
        assert_eq!(models[1].get("mode").as_str(), Some("prepared"));
        assert_eq!(models[1].get("plane_bytes").as_usize(), Some(cost));
        assert_eq!(models[1].get("bits_w").as_usize(), Some(3));
        assert_eq!(j.get("mem_budget_bytes").as_usize(), Some(2 * cost));
        assert_eq!(j.get("prepared_bytes").as_usize(), Some(2 * cost));
        reg.shutdown();
    }

    #[test]
    fn sharded_entries_serve_through_child_shards_and_roll_on_swap() {
        use super::super::shard::supervisor::testutil::healthy_fake;
        use super::super::shard::Launcher;
        let m = tiny_model();
        let d_in = m.d_in();
        let shard = ShardCfg {
            shards: 2,
            launcher: Launcher::Thread(Arc::new(move |_, c| healthy_fake(d_in, c))),
            backoff_base: Duration::from_millis(5),
            backoff_max: Duration::from_millis(40),
            ..ShardCfg::default()
        };
        let mut reg = ModelRegistry::new(RegistryCfg { shard, ..RegistryCfg::default() });
        let out = reg.insert_model("s", m).unwrap();
        assert!(out.sharded, "outcome must flag the sharded backend");
        assert!(!out.prepared, "parent-side engine stays streaming");
        let ix = reg.index_of("s").unwrap();
        assert_eq!(reg.entry(ix).mode_str(), "sharded");
        {
            let sp = reg.entry(ix).pool().shard().expect("sharded backend");
            assert!(sp.wait_up(2, Duration::from_secs(10)), "shards never came up");
            let rx = reg.entry(ix).pool().submit(one_hot_block(0)).unwrap();
            let resp = rx.recv_timeout(Duration::from_secs(5)).expect("shard answered");
            assert_eq!(resp.logits.len(), d_in, "fake echoes the input as logits");
        }
        let j = reg.detail_json(ix);
        assert_eq!(j.get("mode").as_str(), Some("sharded"));
        assert_eq!(j.get("shards").as_usize(), Some(2));
        assert_eq!(j.get("shards_up").as_usize(), Some(2));
        // hot-swap: materialized artifact + rolling child restarts
        let out2 = reg.insert_model("s", rot_model("s_v2", 1)).unwrap();
        assert_eq!(out2.version, 2);
        let sp = reg.entry(ix).pool().shard().unwrap();
        let t0 = Instant::now();
        while sp.restarts() < 2 && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(sp.restarts(), 2, "both children respawn once per swap");
        assert!(sp.wait_up(2, Duration::from_secs(10)), "swap must end fully up");
        // width changes are rejected for sharded entries
        let mut wide = tiny_model();
        wide.input_hw += 1;
        let err = reg.insert_model("s", wide).expect_err("width change");
        assert!(format!("{err:#}").contains("input width"), "{err:#}");
        reg.shutdown();
    }

    #[test]
    fn bench_fleet_smoke_reports_all_rows() {
        let report = bench_fleet(&tiny_model(), &ServeCfg::default(), true).unwrap();
        assert_eq!(report.fleet_rps.len(), 3);
        for (n, rps) in &report.fleet_rps {
            assert!(*rps > 0.0, "fleet_rps_{n} must be positive");
        }
        assert!(report.swap_p99_spike_ms > 0.0);
        assert!(report.swap_count > 0);
        let mut o = BTreeMap::new();
        report.merge_into(&mut o);
        for key in ["fleet_rps_2", "fleet_rps_4", "fleet_rps_8", "swap_p99_spike_ms"] {
            assert!(o.contains_key(key), "missing merged fleet row {key}");
        }
    }
}
