//! FIFO-bounded response cache for repeated queries.
//!
//! Keyed on an FNV-1a hash of the model id, the model's QPKG **content
//! fingerprint**, and the exact input bit patterns (`f32::to_bits`, so
//! `-0.0` and `0.0` are distinct keys and NaN payloads can't poison
//! equality). The content fingerprint is what makes hot-swap safe: a
//! `POST /v1/models/{id}/load` replaces the model under the same id,
//! and because the swapped-in QPKG hashes differently, every key the
//! old version populated simply stops matching — stale predictions can
//! never be served for the new weights. Predictions are deterministic
//! for a fixed packed model, so a hash hit can serve the cached
//! response without re-running the engine; a (astronomically unlikely)
//! 64-bit collision would serve the colliding entry's prediction —
//! acceptable for a serving cache, not for correctness-critical paths.

use std::collections::{HashMap, VecDeque};

/// The cached subset of a response (latency/batch metadata is
/// per-request, not cacheable).
#[derive(Debug, Clone)]
pub struct CachedResponse {
    pub pred: usize,
    pub logits: Vec<f32>,
}

/// Bounded map with FIFO eviction: inserting past `cap` evicts the
/// oldest key. No recency tracking — repeated-query traffic is bursty
/// enough that FIFO captures it without per-hit bookkeeping.
#[derive(Debug)]
pub struct ResponseCache {
    cap: usize,
    map: HashMap<u64, CachedResponse>,
    order: VecDeque<u64>,
    hits: u64,
    misses: u64,
}

impl ResponseCache {
    pub fn new(cap: usize) -> ResponseCache {
        ResponseCache {
            cap: cap.max(1),
            map: HashMap::new(),
            order: VecDeque::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// FNV-1a over raw bytes — the content-identity fingerprint for a
    /// serialized QPKG payload (and the primitive [`ResponseCache::key`]
    /// builds on).
    pub fn fingerprint(bytes: &[u8]) -> u64 {
        const OFFSET: u64 = 0xcbf29ce484222325;
        const PRIME: u64 = 0x100000001b3;
        let mut h = OFFSET;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
        h
    }

    /// FNV-1a over the model id, its QPKG content fingerprint, and the
    /// input bit patterns. Including `content_id` means a hot-swapped
    /// model version implicitly invalidates every key the old version
    /// wrote — same id, different content, different keys.
    pub fn key(model: &str, content_id: u64, input: &[f32]) -> u64 {
        const PRIME: u64 = 0x100000001b3;
        let mut h = Self::fingerprint(model.as_bytes());
        h ^= 0xff; // separator so ("ab", [..]) != ("a", [b-led input])
        h = h.wrapping_mul(PRIME);
        for b in content_id.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
        for &v in input {
            for b in v.to_bits().to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(PRIME);
            }
        }
        h
    }

    pub fn get(&mut self, key: u64) -> Option<CachedResponse> {
        match self.map.get(&key) {
            Some(v) => {
                self.hits += 1;
                Some(v.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    pub fn put(&mut self, key: u64, v: CachedResponse) {
        if self.map.insert(key, v).is_some() {
            return; // overwrite: already in the order queue
        }
        self.order.push_back(key);
        while self.map.len() > self.cap {
            if let Some(old) = self.order.pop_front() {
                self.map.remove(&old);
            } else {
                break;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_after_put_and_counters() {
        let mut c = ResponseCache::new(8);
        let k = ResponseCache::key("tiny", 7, &[1.0, 2.0]);
        assert!(c.get(k).is_none());
        c.put(k, CachedResponse { pred: 2, logits: vec![0.0, 0.0, 1.0] });
        let hit = c.get(k).expect("hit");
        assert_eq!(hit.pred, 2);
        assert_eq!((c.hits(), c.misses()), (1, 1));
    }

    #[test]
    fn keys_separate_model_and_bits() {
        let a = ResponseCache::key("m", 1, &[1.0]);
        assert_ne!(a, ResponseCache::key("n", 1, &[1.0]));
        assert_ne!(a, ResponseCache::key("m", 1, &[1.0 + f32::EPSILON]));
        assert_ne!(ResponseCache::key("m", 1, &[0.0]), ResponseCache::key("m", 1, &[-0.0]));
        assert_eq!(a, ResponseCache::key("m", 1, &[1.0]));
    }

    /// The hot-swap guarantee: same id + same input but a different
    /// content fingerprint must key to a different slot, so a swapped
    /// model version can never read the old version's cached answer.
    #[test]
    fn keys_separate_content_versions() {
        let v1 = ResponseCache::fingerprint(b"qpkg bytes v1");
        let v2 = ResponseCache::fingerprint(b"qpkg bytes v2");
        assert_ne!(v1, v2);
        let input = [1.0f32, 0.0, 0.5];
        assert_ne!(
            ResponseCache::key("m", v1, &input),
            ResponseCache::key("m", v2, &input)
        );
        // and the fingerprint itself is deterministic
        assert_eq!(v1, ResponseCache::fingerprint(b"qpkg bytes v1"));
    }

    #[test]
    fn fifo_evicts_oldest_at_cap() {
        let mut c = ResponseCache::new(2);
        let keys: Vec<u64> =
            (0..3).map(|i| ResponseCache::key("m", 0, &[i as f32])).collect();
        for &k in &keys {
            c.put(k, CachedResponse { pred: 0, logits: vec![] });
        }
        assert_eq!(c.len(), 2);
        assert!(c.get(keys[0]).is_none(), "oldest entry must be evicted");
        assert!(c.get(keys[1]).is_some());
        assert!(c.get(keys[2]).is_some());
    }

    #[test]
    fn overwrite_does_not_grow_order_queue() {
        let mut c = ResponseCache::new(2);
        let k = ResponseCache::key("m", 0, &[5.0]);
        for pred in 0..10 {
            c.put(k, CachedResponse { pred, logits: vec![] });
        }
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(k).unwrap().pred, 9);
        // the repeatedly-overwritten key must not evict itself
        let k2 = ResponseCache::key("m", 0, &[6.0]);
        c.put(k2, CachedResponse { pred: 1, logits: vec![] });
        assert!(c.get(k).is_some());
        assert!(c.get(k2).is_some());
    }
}
