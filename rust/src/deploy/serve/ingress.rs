//! Nonblocking TCP ingress: accept loop, readiness polling, and the
//! HTTP front-end event loop over the multi-model [`ModelRegistry`].
//!
//! Dependency-light by design: a single event-loop thread drives
//! nonblocking `std::net` sockets — accept until `WouldBlock`, then for
//! every connection flush pending writes, poll the in-flight response
//! channel, read whatever bytes arrived, and parse/route complete
//! requests. When one full sweep makes no progress the loop sleeps a
//! few hundred microseconds instead of spinning. That is a hand-rolled
//! readiness poller, not epoll — plenty for the benchmark fleet sizes
//! this repo serves (hundreds of connections), and zero new deps.
//!
//! The API surface is resource-oriented:
//!
//! | route | meaning |
//! |---|---|
//! | `POST /v1/models/{id}/predict` | predict against model `id` |
//! | `GET /v1/models` | list resident models (mode, version, bytes) |
//! | `GET /v1/models/{id}` | one model's detail document |
//! | `POST /v1/models/{id}/load` | zero-downtime hot-swap (body `{"qpkg": path}`) |
//! | `POST /v1/predict` | **deprecated** alias: `model` body field routes; answers `Deprecation: true` |
//!
//! Every error answers one structured JSON shape —
//! `{"error":{"code":..,"message":..,"model":..}}` — with stable
//! machine-readable codes (`model_not_found`, `bad_input_width`,
//! `deadline_exceeded`, `queue_full`, `pool_dead`, ...); the `X-Shed`
//! headers ride alongside unchanged.
//!
//! Robustness properties the raw channel server lacked:
//! - **deadlines**: a request carrying `X-Deadline-Ms` (or a
//!   `deadline_ms` body field, or the server default) answers `503`
//!   once the budget passes instead of queueing forever; an explicit
//!   budget of `0` sheds immediately and deterministically
//! - **admission control**: each model's bounded ingress queue sheds
//!   with a fast `503` + `X-Shed: queue` under overload rather than
//!   collapsing — and because pools are per-model, one model's spike
//!   sheds its own traffic without starving the rest of the fleet
//! - **response cache**: repeated queries (same model + QPKG content +
//!   input bits) are answered from the FIFO [`ResponseCache`] without
//!   touching any pool; hot-swaps change the content fingerprint, so
//!   stale answers can never survive a swap
//! - **fail-fast on a dead pool**: a panicked worker pool turns into
//!   `503` + connection close, never a hang

use super::cache::{CachedResponse, ResponseCache};
use super::http::{self, Parse, ParsedReq};
use super::registry::{ModelRegistry, RegistryCfg};
use super::{finite_or_zero, percentile, BatchForward, ServeCfg};
use crate::obs::{Histogram, Registry};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// HTTP front-end knobs (the pools behind it are shaped by [`ServeCfg`]
/// via [`RegistryCfg`]).
#[derive(Debug, Clone)]
pub struct HttpCfg {
    /// bind address; port 0 picks an ephemeral port
    pub addr: String,
    /// deadline applied when a request carries none (0 = no deadline)
    pub default_deadline_ms: u64,
    /// response-cache capacity (0 disables the cache)
    pub cache_cap: usize,
    /// connections beyond this are answered 503 and closed
    pub max_conns: usize,
    /// request bodies beyond this are answered 413
    pub max_body: usize,
    /// idle keep-alive connections are dropped after this long
    pub idle_timeout: Duration,
}

impl Default for HttpCfg {
    fn default() -> Self {
        HttpCfg {
            addr: "127.0.0.1:0".to_string(),
            default_deadline_ms: 0,
            cache_cap: 1024,
            max_conns: 256,
            max_body: 4 << 20,
            idle_timeout: Duration::from_secs(30),
        }
    }
}

/// Front-end counters (each pool's own counters live in its
/// `ServeStats`; `/stats` and `/metrics` expose the fleet sums).
#[derive(Debug, Default)]
pub struct HttpStats {
    pub conns: AtomicU64,
    pub reqs: AtomicU64,
    pub ok: AtomicU64,
    /// 4xx answers (malformed bodies, unknown models, bad widths)
    pub bad: AtomicU64,
    /// 503s from full-queue admission control
    pub shed_queue: AtomicU64,
    /// 503s from expired deadlines
    pub shed_deadline: AtomicU64,
    pub cache_hits: AtomicU64,
    /// predict answers computed by a pool (the `X-Cache: miss` path)
    pub cache_misses: AtomicU64,
    /// 500s (engine failure mid-batch)
    pub failed: AtomicU64,
    /// currently open connections (a gauge; `conns` is cumulative)
    pub open_conns: AtomicU64,
    /// end-to-end predict latency, request routed → response queued
    pub latency: Arc<Histogram>,
    /// head+body parse time per complete request
    pub parse_s: Arc<Histogram>,
    /// duration of each nonblocking response-write burst
    pub write_s: Arc<Histogram>,
}

/// A running HTTP front-end (event-loop thread + per-model pools).
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    drain_ms: Arc<AtomicU64>,
    thread: JoinHandle<()>,
    stats: Arc<HttpStats>,
}

impl HttpServer {
    /// Single-model convenience: wrap `fwd` as the only (external,
    /// non-swappable) registry entry and serve it. The legacy
    /// constructor every pre-fleet caller and test uses.
    pub fn start(
        fwd: Arc<dyn BatchForward>,
        serve_cfg: &ServeCfg,
        http_cfg: &HttpCfg,
    ) -> Result<HttpServer> {
        let mut models =
            ModelRegistry::new(RegistryCfg { serve: serve_cfg.clone(), ..RegistryCfg::default() });
        models.add_external(fwd)?;
        Self::start_registry(models, http_cfg)
    }

    /// Bind `http_cfg.addr`, spawn the event loop (which owns the
    /// registry and every per-model pool), and return once accepting.
    pub fn start_registry(models: ModelRegistry, http_cfg: &HttpCfg) -> Result<HttpServer> {
        let listener = TcpListener::bind(&http_cfg.addr)
            .with_context(|| format!("bind {}", http_cfg.addr))?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let addr = listener.local_addr().context("local_addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        let draining = Arc::new(AtomicBool::new(false));
        let drain_ms = Arc::new(AtomicU64::new(0));
        let stats = Arc::new(HttpStats::default());
        let cfg = http_cfg.clone();
        let loop_stop = stop.clone();
        let loop_drain = draining.clone();
        let loop_drain_ms = drain_ms.clone();
        let loop_stats = stats.clone();
        let thread = std::thread::spawn(move || {
            event_loop(listener, models, cfg, loop_stop, loop_drain, loop_drain_ms, loop_stats);
        });
        Ok(HttpServer { addr, stop, draining, drain_ms, thread, stats })
    }

    /// The bound address (with the resolved ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> &HttpStats {
        &self.stats
    }

    /// Signal the event loop and join it (drains every pool too).
    pub fn stop(self) {
        self.stop.store(true, Ordering::Release);
        let _ = self.thread.join();
    }

    /// Graceful shutdown: close the listener (no new connections),
    /// answer every in-flight request — or let its deadline shed it —
    /// within `timeout`, then tear the fleet down (shard children get
    /// protocol `Shutdown` frames before any SIGKILL). This is what
    /// `serve --listen` runs on SIGTERM/SIGINT, bounded by
    /// `--drain-ms`.
    pub fn drain(self, timeout: Duration) {
        self.drain_ms.store(timeout.as_millis() as u64, Ordering::Release);
        self.draining.store(true, Ordering::Release);
        let _ = self.thread.join();
    }
}

/// The in-flight request of one connection: the pool's response channel
/// plus everything needed to render the answer.
struct Pending {
    rx: mpsc::Receiver<super::Response>,
    deadline: Option<Instant>,
    keep_alive: bool,
    cache_key: Option<u64>,
    /// when the request was routed — closes the latency histogram
    t0: Instant,
    /// answered with `Deprecation: true` (legacy `/v1/predict` alias)
    deprecated: bool,
    /// registry index of the model this request rode on
    model_ix: usize,
    /// rode a sharded pool — a dropped channel means a crashed child
    /// mid-restart (retryable 503), not a dead in-process pool
    sharded: bool,
}

struct Conn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    wpos: usize,
    pending: Option<Pending>,
    last_active: Instant,
    close_after_write: bool,
    dead: bool,
}

impl Conn {
    fn queue(&mut self, status: u16, keep_alive: bool, extra: &[(&str, &str)], body: &[u8]) {
        http::write_response(&mut self.wbuf, status, keep_alive, extra, body);
        if !keep_alive {
            self.close_after_write = true;
        }
    }

    /// Like [`Conn::queue`] but with an explicit content type (the
    /// `/metrics` route serves Prometheus text, not JSON).
    fn queue_typed(&mut self, status: u16, keep_alive: bool, ctype: &str, body: &[u8]) {
        http::write_response_with_type(&mut self.wbuf, status, keep_alive, &[], ctype, body);
        if !keep_alive {
            self.close_after_write = true;
        }
    }
}

fn predict_body(pred: usize, logits: &[f32], batch_size: usize, cached: bool) -> Vec<u8> {
    let mut s = format!("{{\"pred\":{pred},\"logits\":[");
    for (i, v) in logits.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("{v}"));
    }
    s.push_str(&format!("],\"batch_size\":{batch_size},\"cached\":{cached}}}"));
    s.into_bytes()
}

/// Response headers: the deprecation marker (legacy alias only) plus
/// whatever route-specific extras (`X-Shed`, `X-Cache`) apply.
fn resp_headers(
    deprecated: bool,
    extra: &[(&'static str, &'static str)],
) -> Vec<(&'static str, &'static str)> {
    let mut v = Vec::with_capacity(extra.len() + 1);
    if deprecated {
        v.push(("Deprecation", "true"));
    }
    v.extend_from_slice(extra);
    v
}

struct EventLoop {
    /// the fleet: per-model pools, LRU plane budget, hot-swap
    models: ModelRegistry,
    cache: Option<ResponseCache>,
    cfg: HttpCfg,
    stats: Arc<HttpStats>,
    /// `/metrics` registry; stage histograms are adopted at startup,
    /// counters/gauges are synced from their sources at scrape time
    registry: Registry,
}

impl EventLoop {
    /// Fleet-summed pool counters + the most recent engine error across
    /// every pool (first entry reporting one wins — entries are checked
    /// in listing order).
    fn pool_totals(&self) -> (u64, u64, u64, u64, Option<String>) {
        let (mut batches, mut requests, mut failed, mut expired) = (0u64, 0u64, 0u64, 0u64);
        let mut last_error = None;
        for e in self.models.iter() {
            let ps = e.pool().stats();
            batches += ps.batches.load(Ordering::Relaxed);
            requests += ps.requests.load(Ordering::Relaxed);
            failed += ps.failed.load(Ordering::Relaxed);
            expired += ps.expired.load(Ordering::Relaxed);
            if last_error.is_none() {
                last_error = ps.last_error.lock().expect("stats lock").clone();
            }
        }
        (batches, requests, failed, expired, last_error)
    }

    /// One merged `/stats` document: front-end counters, fleet-summed
    /// pool counters under `pool_*` keys, the most recent engine error,
    /// and live request-latency percentiles. Keys stay flat so existing
    /// scrapers of the old single-model document keep working.
    fn stats_body(&self) -> Vec<u8> {
        let st = &self.stats;
        let (pool_batches, pool_requests, pool_failed, pool_expired, last_error) =
            self.pool_totals();
        let pairs: [(&str, u64); 15] = [
            ("conns", st.conns.load(Ordering::Relaxed)),
            ("reqs", st.reqs.load(Ordering::Relaxed)),
            ("ok", st.ok.load(Ordering::Relaxed)),
            ("bad", st.bad.load(Ordering::Relaxed)),
            ("shed_queue", st.shed_queue.load(Ordering::Relaxed)),
            ("shed_deadline", st.shed_deadline.load(Ordering::Relaxed)),
            ("cache_hits", st.cache_hits.load(Ordering::Relaxed)),
            ("cache_misses", st.cache_misses.load(Ordering::Relaxed)),
            ("failed", st.failed.load(Ordering::Relaxed)),
            ("open_conns", st.open_conns.load(Ordering::Relaxed)),
            ("models", self.models.len() as u64),
            ("pool_batches", pool_batches),
            ("pool_requests", pool_requests),
            ("pool_failed", pool_failed),
            ("pool_expired", pool_expired),
        ];
        let mut s = String::from("{");
        for (k, v) in pairs.iter() {
            s.push_str(&format!("\"{k}\":{v},"));
        }
        let snap = st.latency.snapshot();
        for (k, q) in [("p50_ms", 0.5), ("p95_ms", 0.95), ("p99_ms", 0.99)] {
            s.push_str(&format!("\"{k}\":{},", finite_or_zero(snap.percentile(q) * 1e3)));
        }
        match last_error.as_deref() {
            Some(e) => s.push_str(&format!("\"last_error\":{}", json_quote(e))),
            None => s.push_str("\"last_error\":null"),
        }
        s.push('}');
        s.into_bytes()
    }

    /// Render the Prometheus text exposition: sync counters and gauges
    /// from their sources of truth (front-end atomics, fleet-summed
    /// pool counters, registry residency gauges, per-model and
    /// per-shard labeled series), then render the registry — the
    /// adopted stage histograms are always live.
    fn metrics_body(&self) -> Vec<u8> {
        let st = &self.stats;
        let (pool_batches, pool_requests, pool_failed, pool_expired, _) = self.pool_totals();
        let counts = self.models.counts();
        let counters: [(&str, &str, u64); 16] = [
            ("qat_http_requests_total", "requests received", st.reqs.load(Ordering::Relaxed)),
            ("qat_http_ok_total", "2xx responses", st.ok.load(Ordering::Relaxed)),
            ("qat_http_bad_total", "4xx responses", st.bad.load(Ordering::Relaxed)),
            (
                "qat_http_shed_queue_total",
                "503s from queue admission control",
                st.shed_queue.load(Ordering::Relaxed),
            ),
            (
                "qat_http_shed_deadline_total",
                "503s from expired deadlines",
                st.shed_deadline.load(Ordering::Relaxed),
            ),
            (
                "qat_http_cache_hits_total",
                "cache-served predict answers",
                st.cache_hits.load(Ordering::Relaxed),
            ),
            (
                "qat_http_cache_misses_total",
                "pool-served predict answers",
                st.cache_misses.load(Ordering::Relaxed),
            ),
            ("qat_http_failed_total", "5xx responses", st.failed.load(Ordering::Relaxed)),
            ("qat_http_connections_total", "connections accepted", st.conns.load(Ordering::Relaxed)),
            ("qat_pool_batches_total", "pool batches executed (fleet sum)", pool_batches),
            ("qat_pool_requests_total", "pool jobs admitted (fleet sum)", pool_requests),
            ("qat_pool_failed_total", "pool jobs failed in the engine (fleet sum)", pool_failed),
            ("qat_pool_expired_total", "pool jobs expired unserved (fleet sum)", pool_expired),
            ("qat_registry_swaps_total", "hot-swap cutovers", counts.swaps),
            ("qat_registry_demotions_total", "prepared->streaming demotions", counts.demotions),
            ("qat_registry_promotions_total", "streaming->prepared promotions", counts.promotions),
        ];
        for (name, help, v) in counters {
            self.registry.counter(name, help).store(v);
        }
        self.registry
            .gauge("qat_http_open_connections", "currently open connections")
            .set(st.open_conns.load(Ordering::Relaxed) as f64);
        self.registry
            .gauge("qat_registry_models", "resident models")
            .set(self.models.len() as f64);
        self.registry
            .gauge("qat_registry_prepared", "models with prepared planes resident")
            .set(counts.prepared as f64);
        self.registry
            .gauge("qat_registry_streaming", "models demoted to streaming mode")
            .set(counts.streaming as f64);
        self.registry
            .gauge("qat_registry_plane_bytes", "prepared plane bytes resident")
            .set(self.models.prepared_bytes() as f64);
        // per-model (and, for sharded entries, per-shard-pool) labeled
        // series, synced through the registry's labeled families
        for e in self.models.iter() {
            let lbl = [("model", e.id())];
            self.registry
                .counter_with("qat_model_requests_total", "requests routed per model", &lbl)
                .store(e.requests());
            self.registry
                .counter_with("qat_model_ok_total", "200 answers per model", &lbl)
                .store(e.ok());
            self.registry
                .gauge_with("qat_model_prepared", "1 when the model's planes are resident", &lbl)
                .set(if e.mode_str() == "streaming" { 0.0 } else { 1.0 });
            self.registry
                .gauge_with("qat_model_plane_bytes", "prepared-plane cost per model", &lbl)
                .set(e.plane_cost() as f64);
            if let Some(sp) = e.pool().shard() {
                self.registry
                    .gauge_with("qat_shard_up", "live shard children per model", &lbl)
                    .set(sp.up_count() as f64);
                self.registry
                    .counter_with(
                        "qat_shard_restarts_total",
                        "shard children respawned after a crash or stall",
                        &lbl,
                    )
                    .store(sp.restarts());
                self.registry
                    .counter_with(
                        "qat_shard_failovers_total",
                        "orphaned requests replayed onto a sibling shard",
                        &lbl,
                    )
                    .store(sp.failovers());
                self.registry
                    .counter_with(
                        "qat_shard_dropped_total",
                        "orphaned requests dropped (retry budget or idempotency)",
                        &lbl,
                    )
                    .store(sp.dropped());
            }
        }
        self.registry.render().into_bytes()
    }

    /// Route one complete request: either queues a response into the
    /// write buffer or parks a [`Pending`] on the connection.
    fn route(&mut self, conn: &mut Conn, req: &ParsedReq, body: &[u8]) {
        self.stats.reqs.fetch_add(1, Ordering::Relaxed);
        match (req.method.as_str(), req.path.as_str()) {
            // legacy alias: body `model` field routes; deprecated
            ("POST", "/v1/predict" | "/predict") => self.predict(conn, req, body, None, true),
            ("GET", "/healthz") => {
                let b = format!(
                    "{{\"ok\":true,\"model\":{},\"models\":{},\"pool_dead\":{}}}",
                    json_quote(self.models.default_id().unwrap_or("")),
                    self.models.len(),
                    self.models.any_dead()
                );
                self.stats.ok.fetch_add(1, Ordering::Relaxed);
                conn.queue(200, req.keep_alive, &[], b.as_bytes());
            }
            ("GET", "/stats") => {
                self.stats.ok.fetch_add(1, Ordering::Relaxed);
                let b = self.stats_body();
                conn.queue(200, req.keep_alive, &[], &b);
            }
            ("GET", "/metrics") => {
                self.stats.ok.fetch_add(1, Ordering::Relaxed);
                let b = self.metrics_body();
                conn.queue_typed(200, req.keep_alive, "text/plain; version=0.0.4", &b);
            }
            ("GET", "/v1/models") => {
                self.stats.ok.fetch_add(1, Ordering::Relaxed);
                let b = crate::json::to_string(&self.models.list_json());
                conn.queue(200, req.keep_alive, &[], b.as_bytes());
            }
            (method, path) if path.starts_with("/v1/models/") => {
                let rest = &path["/v1/models/".len()..];
                match (method, rest.split_once('/')) {
                    ("POST", Some((id, "predict"))) => self.predict(conn, req, body, Some(id), false),
                    ("POST", Some((id, "load"))) => self.load_model(conn, req, id, body),
                    ("GET", None) if !rest.is_empty() => match self.models.index_of(rest) {
                        Some(ix) => {
                            self.stats.ok.fetch_add(1, Ordering::Relaxed);
                            let b = crate::json::to_string(&self.models.detail_json(ix));
                            conn.queue(200, req.keep_alive, &[], b.as_bytes());
                        }
                        None => {
                            self.stats.bad.fetch_add(1, Ordering::Relaxed);
                            conn.queue(
                                404,
                                req.keep_alive,
                                &[],
                                &http::error_body(
                                    "model_not_found",
                                    &format!("unknown model {rest:?}"),
                                    Some(rest),
                                ),
                            );
                        }
                    },
                    ("GET" | "POST", _) => {
                        self.stats.bad.fetch_add(1, Ordering::Relaxed);
                        conn.queue(
                            404,
                            req.keep_alive,
                            &[],
                            &http::error_body("route_not_found", "no such route", None),
                        );
                    }
                    _ => {
                        self.stats.bad.fetch_add(1, Ordering::Relaxed);
                        conn.queue(
                            405,
                            req.keep_alive,
                            &[],
                            &http::error_body("method_not_allowed", "method not allowed", None),
                        );
                    }
                }
            }
            ("POST" | "GET", _) => {
                self.stats.bad.fetch_add(1, Ordering::Relaxed);
                conn.queue(
                    404,
                    req.keep_alive,
                    &[],
                    &http::error_body("route_not_found", "no such route", None),
                );
            }
            _ => {
                self.stats.bad.fetch_add(1, Ordering::Relaxed);
                conn.queue(
                    405,
                    req.keep_alive,
                    &[],
                    &http::error_body("method_not_allowed", "method not allowed", None),
                );
            }
        }
    }

    /// `POST /v1/models/{id}/predict` (resource route) and the legacy
    /// `/v1/predict` alias (`path_id: None`, `deprecated: true`). Model
    /// resolution order: path id, then body `model` field, then the
    /// registry default.
    fn predict(
        &mut self,
        conn: &mut Conn,
        req: &ParsedReq,
        body: &[u8],
        path_id: Option<&str>,
        deprecated: bool,
    ) {
        let t0 = Instant::now();
        let ka = req.keep_alive;
        let stats = &self.stats;
        let bad = |conn: &mut Conn, status: u16, code: &str, msg: &str, model: Option<&str>| {
            stats.bad.fetch_add(1, Ordering::Relaxed);
            let hdrs = resp_headers(deprecated, &[]);
            conn.queue(status, ka, &hdrs, &http::error_body(code, msg, model));
        };
        let body_model = match http::lazy_str(body, "model") {
            Err(e) => return bad(conn, 400, "bad_request", &format!("bad model field: {e}"), None),
            Ok(m) => m,
        };
        let id: String = match path_id {
            Some(p) => {
                // a body model field on the resource route must agree
                // with the path — a contradiction is a client bug
                if let Some(m) = &body_model {
                    if m != p {
                        return bad(
                            conn,
                            400,
                            "bad_request",
                            &format!("body model {m:?} contradicts path id {p:?}"),
                            Some(p),
                        );
                    }
                }
                p.to_string()
            }
            None => match body_model {
                Some(m) => m,
                None => match self.models.default_id() {
                    Some(d) => d.to_string(),
                    None => return bad(conn, 404, "model_not_found", "no models loaded", None),
                },
            },
        };
        let Some(ix) = self.models.index_of(&id) else {
            return bad(conn, 404, "model_not_found", &format!("unknown model {id:?}"), Some(&id));
        };
        let input = match http::lazy_f32s(body, "input") {
            Err(e) => {
                return bad(conn, 400, "bad_request", &format!("bad input field: {e}"), Some(&id))
            }
            Ok(None) => return bad(conn, 400, "bad_request", "missing input field", Some(&id)),
            Ok(Some(x)) => x,
        };
        let d_in = self.models.entry(ix).d_in();
        if input.len() != d_in {
            return bad(
                conn,
                400,
                "bad_input_width",
                &format!("input has {} features, model wants {d_in}", input.len()),
                Some(&id),
            );
        }
        // deadline priority: header, then body field, then server default
        let requested_ms = match req.deadline_ms {
            Some(ms) => Some(ms),
            None => match http::lazy_u64(body, "deadline_ms") {
                Err(e) => {
                    return bad(
                        conn,
                        400,
                        "bad_request",
                        &format!("bad deadline_ms field: {e}"),
                        Some(&id),
                    )
                }
                Ok(v) => v,
            },
        };
        let effective_ms = requested_ms.or_else(|| {
            (self.cfg.default_deadline_ms > 0).then_some(self.cfg.default_deadline_ms)
        });
        // an explicit zero budget is already expired: shed deterministically
        if effective_ms == Some(0) {
            self.stats.shed_deadline.fetch_add(1, Ordering::Relaxed);
            let hdrs = resp_headers(deprecated, &[("X-Shed", "deadline")]);
            conn.queue(
                503,
                ka,
                &hdrs,
                &http::error_body("deadline_exceeded", "deadline expired", Some(&id)),
            );
            return;
        }
        let deadline = effective_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
        // the routed request counts for LRU residency (and may promote
        // a streaming model whose traffic is back)
        self.models.touch_ix(ix);
        let cache_key = self
            .cache
            .as_ref()
            .map(|_| ResponseCache::key(&id, self.models.entry(ix).content_id(), &input));
        if let (Some(cache), Some(key)) = (self.cache.as_mut(), cache_key) {
            if let Some(hit) = cache.get(key) {
                self.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
                self.stats.ok.fetch_add(1, Ordering::Relaxed);
                self.models.mark_ok_ix(ix);
                let b = predict_body(hit.pred, &hit.logits, 0, true);
                let hdrs = resp_headers(deprecated, &[("X-Cache", "hit")]);
                conn.queue(200, ka, &hdrs, &b);
                self.stats.latency.record(t0.elapsed().as_secs_f64());
                return;
            }
        }
        let sharded = self.models.entry(ix).pool().is_sharded();
        match self.models.entry(ix).pool().try_submit(input, deadline) {
            Ok(Some(rx)) => {
                conn.pending = Some(Pending {
                    rx,
                    deadline,
                    keep_alive: ka,
                    cache_key,
                    t0,
                    deprecated,
                    model_ix: ix,
                    sharded,
                });
            }
            Ok(None) => {
                // this model's queue is full: shed its own traffic with
                // a fast error — the rest of the fleet is unaffected
                self.stats.shed_queue.fetch_add(1, Ordering::Relaxed);
                let hdrs = resp_headers(deprecated, &[("X-Shed", "queue")]);
                conn.queue(
                    503,
                    ka,
                    &hdrs,
                    &http::error_body("queue_full", "server overloaded", Some(&id)),
                );
            }
            Err(e) if sharded => {
                // every shard child is mid-restart: the supervisor is
                // respawning them, so this is a retryable 503 on a
                // connection worth keeping — not a dead pool
                self.stats.failed.fetch_add(1, Ordering::Relaxed);
                let hdrs = resp_headers(deprecated, &[("X-Shed", "restart")]);
                conn.queue(
                    503,
                    ka,
                    &hdrs,
                    &http::error_body("shard_restarting", &format!("{e:#}"), Some(&id)),
                );
            }
            Err(e) => {
                // dead pool: fail fast and close
                self.stats.failed.fetch_add(1, Ordering::Relaxed);
                let hdrs = resp_headers(deprecated, &[]);
                conn.queue(503, false, &hdrs, &http::error_body("pool_dead", &format!("{e:#}"), Some(&id)));
            }
        }
    }

    /// `POST /v1/models/{id}/load`: zero-downtime hot-swap (existing
    /// id) or cold load (new id) of the QPKG named by the body's
    /// `qpkg` field.
    fn load_model(&mut self, conn: &mut Conn, req: &ParsedReq, id: &str, body: &[u8]) {
        let ka = req.keep_alive;
        let path = match http::lazy_str(body, "qpkg") {
            Err(e) => {
                self.stats.bad.fetch_add(1, Ordering::Relaxed);
                conn.queue(
                    400,
                    ka,
                    &[],
                    &http::error_body("bad_request", &format!("bad qpkg field: {e}"), Some(id)),
                );
                return;
            }
            Ok(None) => {
                self.stats.bad.fetch_add(1, Ordering::Relaxed);
                conn.queue(
                    400,
                    ka,
                    &[],
                    &http::error_body("bad_request", "missing qpkg field", Some(id)),
                );
                return;
            }
            Ok(Some(p)) => p,
        };
        match self.models.load_qpkg(id, Path::new(&path)) {
            Ok(out) => {
                self.stats.ok.fetch_add(1, Ordering::Relaxed);
                let b = format!(
                    "{{\"ok\":true,\"id\":{},\"version\":{},\"mode\":{},\"plane_bytes\":{},\"content\":{}}}",
                    json_quote(&out.id),
                    out.version,
                    json_quote(if out.prepared { "prepared" } else { "streaming" }),
                    out.plane_bytes,
                    json_quote(&format!("{:016x}", out.content_id)),
                );
                conn.queue(200, ka, &[], b.as_bytes());
            }
            Err(e) => {
                self.stats.bad.fetch_add(1, Ordering::Relaxed);
                let msg = format!("{e:#}");
                let code =
                    if msg.contains("not hot-swappable") { "not_swappable" } else { "load_failed" };
                conn.queue(400, ka, &[], &http::error_body(code, &msg, Some(id)));
            }
        }
    }

    /// Poll a connection's in-flight response. Returns true on progress.
    fn poll_pending(&mut self, conn: &mut Conn) -> bool {
        let Some(p) = &conn.pending else { return false };
        match p.rx.try_recv() {
            Ok(resp) => {
                let p = conn.pending.take().expect("pending just matched");
                if let (Some(cache), Some(key)) = (self.cache.as_mut(), p.cache_key) {
                    cache.put(key, CachedResponse { pred: resp.pred, logits: resp.logits.clone() });
                }
                self.models.mark_ok_ix(p.model_ix);
                self.stats.ok.fetch_add(1, Ordering::Relaxed);
                self.stats.cache_misses.fetch_add(1, Ordering::Relaxed);
                let b = predict_body(resp.pred, &resp.logits, resp.batch_size, false);
                let hdrs = resp_headers(p.deprecated, &[("X-Cache", "miss")]);
                conn.queue(200, p.keep_alive, &hdrs, &b);
                self.stats.latency.record(p.t0.elapsed().as_secs_f64());
                true
            }
            Err(mpsc::TryRecvError::Empty) => {
                // enforce the deadline from the ingress clock too, so a
                // stalled pool can't hold a deadlined request hostage
                if p.deadline.is_some_and(|d| Instant::now() > d) {
                    let p = conn.pending.take().expect("pending just matched");
                    let id = self.models.entry(p.model_ix).id();
                    self.stats.shed_deadline.fetch_add(1, Ordering::Relaxed);
                    let hdrs = resp_headers(p.deprecated, &[("X-Shed", "deadline")]);
                    conn.queue(
                        503,
                        p.keep_alive,
                        &hdrs,
                        &http::error_body("deadline_exceeded", "deadline expired", Some(id)),
                    );
                    self.stats.latency.record(p.t0.elapsed().as_secs_f64());
                    true
                } else {
                    false
                }
            }
            Err(mpsc::TryRecvError::Disconnected) => {
                // the job was dropped: expired in the worker (answer 503),
                // orphaned by a crashed shard child past its retry budget
                // (answer a retryable 503 — the supervisor is respawning),
                // or its batch failed in the engine (answer 500 + close)
                let p = conn.pending.take().expect("pending just matched");
                let id = self.models.entry(p.model_ix).id();
                if p.deadline.is_some() {
                    self.stats.shed_deadline.fetch_add(1, Ordering::Relaxed);
                    let hdrs = resp_headers(p.deprecated, &[("X-Shed", "deadline")]);
                    conn.queue(
                        503,
                        p.keep_alive,
                        &hdrs,
                        &http::error_body("deadline_exceeded", "deadline expired", Some(id)),
                    );
                } else if p.sharded {
                    self.stats.failed.fetch_add(1, Ordering::Relaxed);
                    let hdrs = resp_headers(p.deprecated, &[("X-Shed", "restart")]);
                    conn.queue(
                        503,
                        p.keep_alive,
                        &hdrs,
                        &http::error_body(
                            "shard_restarting",
                            "shard crashed; restarting",
                            Some(id),
                        ),
                    );
                } else {
                    self.stats.failed.fetch_add(1, Ordering::Relaxed);
                    let hdrs = resp_headers(p.deprecated, &[]);
                    conn.queue(
                        500,
                        false,
                        &hdrs,
                        &http::error_body("inference_failed", "inference failed", Some(id)),
                    );
                }
                self.stats.latency.record(p.t0.elapsed().as_secs_f64());
                true
            }
        }
    }
}

fn json_quote(s: &str) -> String {
    crate::json::to_string(&crate::json::Json::Str(s.to_string()))
}

fn event_loop(
    listener: TcpListener,
    models: ModelRegistry,
    cfg: HttpCfg,
    stop: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    drain_ms: Arc<AtomicU64>,
    stats: Arc<HttpStats>,
) {
    let cache = (cfg.cache_cap > 0).then(|| ResponseCache::new(cfg.cache_cap));
    let registry = Registry::default();
    // the two stage histograms are fleet-shared: every per-model pool
    // feeds the same pair, so adopting them once covers the whole fleet
    let (stage_queue, stage_compute) = models.stage_histograms();
    let adopt = [
        ("qat_request_latency_seconds", "predict latency, routed to answered", stats.latency.clone()),
        ("qat_stage_parse_seconds", "head+body parse time per request", stats.parse_s.clone()),
        ("qat_stage_write_seconds", "response write-burst duration", stats.write_s.clone()),
        ("qat_stage_queue_seconds", "pool queue+batch wait per job", stage_queue),
        ("qat_stage_compute_seconds", "engine forward time per batch", stage_compute),
        (
            "qat_shard_heartbeat_age_seconds",
            "interval between shard heartbeats (fleet-wide)",
            models.shard_heartbeat_histogram(),
        ),
    ];
    for (name, help, h) in adopt {
        registry.adopt_histogram(name, help, h);
    }
    let mut el = EventLoop { models, cache, cfg, stats, registry };
    // dropped (closing the socket) when a drain begins
    let mut listener = Some(listener);
    let mut drain_deadline: Option<Instant> = None;
    let mut conns: Vec<Conn> = Vec::new();
    let mut chunk = [0u8; 16 * 1024];
    while !stop.load(Ordering::Acquire) {
        if draining.load(Ordering::Acquire) && drain_deadline.is_none() {
            listener = None; // no new connections from here on
            drain_deadline =
                Some(Instant::now() + Duration::from_millis(drain_ms.load(Ordering::Acquire)));
        }
        let mut progress = false;
        // 1. accept everything that's ready
        while let Some(l) = &listener {
            match l.accept() {
                Ok((stream, _)) => {
                    progress = true;
                    el.stats.conns.fetch_add(1, Ordering::Relaxed);
                    el.stats.open_conns.fetch_add(1, Ordering::Relaxed);
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let mut conn = Conn {
                        stream,
                        rbuf: Vec::new(),
                        wbuf: Vec::new(),
                        wpos: 0,
                        pending: None,
                        last_active: Instant::now(),
                        close_after_write: false,
                        dead: false,
                    };
                    if conns.len() >= el.cfg.max_conns {
                        conn.queue(
                            503,
                            false,
                            &[],
                            &http::error_body("too_many_connections", "too many connections", None),
                        );
                    }
                    conns.push(conn);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
        // 2. sweep every connection
        for conn in conns.iter_mut() {
            // flush queued response bytes (partial-write safe)
            let wstart = conn.wpos;
            let wt0 = (conn.wpos < conn.wbuf.len()).then(Instant::now);
            while conn.wpos < conn.wbuf.len() {
                match conn.stream.write(&conn.wbuf[conn.wpos..]) {
                    Ok(0) => {
                        conn.dead = true;
                        break;
                    }
                    Ok(n) => {
                        conn.wpos += n;
                        conn.last_active = Instant::now();
                        progress = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        conn.dead = true;
                        break;
                    }
                }
            }
            if let Some(t0) = wt0 {
                if conn.wpos > wstart {
                    el.stats.write_s.record(t0.elapsed().as_secs_f64());
                }
            }
            if conn.wpos == conn.wbuf.len() && !conn.wbuf.is_empty() {
                conn.wbuf.clear();
                conn.wpos = 0;
                if conn.close_after_write {
                    conn.dead = true;
                }
            }
            if conn.dead {
                continue;
            }
            // poll the in-flight response
            if el.poll_pending(conn) {
                progress = true;
                conn.last_active = Instant::now();
            }
            // read whatever arrived
            loop {
                match conn.stream.read(&mut chunk) {
                    Ok(0) => {
                        // peer closed its write side; finish what's queued
                        if conn.pending.is_none() && conn.wbuf.is_empty() {
                            conn.dead = true;
                        }
                        break;
                    }
                    Ok(n) => {
                        conn.rbuf.extend_from_slice(&chunk[..n]);
                        conn.last_active = Instant::now();
                        progress = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        conn.dead = true;
                        break;
                    }
                }
            }
            if conn.dead {
                continue;
            }
            // parse + route complete requests, one in-flight at a time so
            // pipelined responses keep request order
            while conn.pending.is_none() && !conn.close_after_write {
                let pt0 = Instant::now();
                match http::parse_request(&conn.rbuf, el.cfg.max_body) {
                    Parse::NeedMore => break,
                    Parse::Bad { status, msg } => {
                        el.stats.bad.fetch_add(1, Ordering::Relaxed);
                        conn.rbuf.clear();
                        conn.queue(
                            status,
                            false,
                            &[],
                            &http::error_body(http::status_code_slug(status), &msg, None),
                        );
                        progress = true;
                        break;
                    }
                    Parse::Ready(req) => {
                        el.stats.parse_s.record(pt0.elapsed().as_secs_f64());
                        let body: Vec<u8> = conn.rbuf[req.body.clone()].to_vec();
                        conn.rbuf.drain(..req.consumed);
                        el.route(conn, &req, &body);
                        progress = true;
                    }
                }
            }
        }
        // 3. drop dead and idle connections
        let idle = el.cfg.idle_timeout;
        let before = conns.len();
        conns.retain(|c| {
            !c.dead
                && !(c.pending.is_none()
                    && c.wbuf.is_empty()
                    && c.last_active.elapsed() > idle)
        });
        let dropped = (before - conns.len()) as u64;
        if dropped > 0 {
            el.stats.open_conns.fetch_sub(dropped, Ordering::Relaxed);
        }
        // 4. a drain ends once every connection is quiescent (no
        // in-flight response, nothing left to flush) or the budget is
        // spent — whichever comes first
        if let Some(dd) = drain_deadline {
            let quiescent = conns.iter().all(|c| c.pending.is_none() && c.wbuf.is_empty());
            if quiescent || Instant::now() > dd {
                break;
            }
        }
        if !progress {
            std::thread::sleep(Duration::from_micros(300));
        }
    }
    let n_open = conns.len() as u64;
    if n_open > 0 {
        el.stats.open_conns.fetch_sub(n_open, Ordering::Relaxed);
    }
    drop(conns);
    el.models.shutdown();
}

// ---------------------------------------------------------------------------
// network-level benchmark
// ---------------------------------------------------------------------------

/// Network benchmark rows merged into BENCH_serve.json.
#[derive(Debug, Clone)]
pub struct HttpBenchReport {
    pub keepalive_requests: usize,
    pub keepalive_rps: f64,
    pub keepalive_p99_ms: f64,
    pub churn_requests: usize,
    pub churn_rps: f64,
    pub overload_requests: usize,
    pub overload_ok: usize,
    pub overload_shed: usize,
    pub overload_p99_ms: f64,
}

impl HttpBenchReport {
    /// Flat `http_*` keys, merged beside the channel-level serve rows.
    pub fn merge_into(&self, o: &mut BTreeMap<String, crate::json::Json>) {
        use crate::json::Json;
        let ka_p99 = finite_or_zero(self.keepalive_p99_ms);
        let ov_p99 = finite_or_zero(self.overload_p99_ms);
        o.insert("http_keepalive_requests".into(), Json::Num(self.keepalive_requests as f64));
        o.insert("http_keepalive_rps".into(), Json::Num(self.keepalive_rps));
        o.insert("http_keepalive_p99_ms".into(), Json::Num(ka_p99));
        o.insert("http_churn_requests".into(), Json::Num(self.churn_requests as f64));
        o.insert("http_churn_rps".into(), Json::Num(self.churn_rps));
        o.insert("http_overload_requests".into(), Json::Num(self.overload_requests as f64));
        o.insert("http_overload_ok".into(), Json::Num(self.overload_ok as f64));
        o.insert("http_overload_shed".into(), Json::Num(self.overload_shed as f64));
        o.insert("http_overload_p99_ms".into(), Json::Num(ov_p99));
    }

    pub fn summary(&self) -> String {
        format!(
            "http: keep-alive {:.0} req/s (p99 {:.2}ms, {} reqs), churn {:.0} req/s ({} reqs), \
             overload p99 {:.2}ms ({} ok / {} shed of {})",
            self.keepalive_rps,
            self.keepalive_p99_ms,
            self.keepalive_requests,
            self.churn_rps,
            self.churn_requests,
            self.overload_p99_ms,
            self.overload_ok,
            self.overload_shed,
            self.overload_requests
        )
    }
}

pub(crate) fn bench_input(d_in: usize, seed: usize) -> Vec<f32> {
    (0..d_in).map(|i| ((seed * 31 + i * 7) % 13) as f32 * 0.25).collect()
}

fn bench_body(model: &str, input: &[f32]) -> Vec<u8> {
    let mut s = format!("{{\"model\":{},\"input\":[", json_quote(model));
    for (i, v) in input.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("{v}"));
    }
    s.push_str("]}");
    s.into_bytes()
}

fn send_one(
    stream: &mut TcpStream,
    path: &str,
    body: &[u8],
) -> std::io::Result<(u16, Duration)> {
    let req = http::format_request(path, body, &[]);
    let t0 = Instant::now();
    stream.write_all(&req)?;
    let resp = http::read_response(stream)?;
    Ok((resp.status, t0.elapsed()))
}

/// A [`BatchForward`] wrapper that slows every batch down, to model a
/// heavier engine than the microscopic bench model and make the
/// overload scenario actually saturate the queue.
struct Throttled {
    inner: Arc<dyn BatchForward>,
    delay: Duration,
}

impl BatchForward for Throttled {
    fn d_in(&self) -> usize {
        self.inner.d_in()
    }
    fn num_classes(&self) -> usize {
        self.inner.num_classes()
    }
    fn model_name(&self) -> &str {
        self.inner.model_name()
    }
    fn forward_batch(&self, x: &[f32], b: usize) -> Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        self.inner.forward_batch(x, b)
    }
}

/// The three network scenarios: keep-alive throughput, connection-churn
/// throughput, and tail latency under ~2x queue-capacity overload.
pub fn bench_http(
    fwd: Arc<dyn BatchForward>,
    serve_cfg: &ServeCfg,
    smoke: bool,
) -> Result<HttpBenchReport> {
    let model = fwd.model_name().to_string();
    let d_in = fwd.d_in();
    // cache off: the benchmark measures the serving path, not the cache
    let http_cfg = HttpCfg { cache_cap: 0, ..HttpCfg::default() };

    // --- scenario 1: keep-alive connections, sequential requests each
    let (n_conns, per_conn) = if smoke { (3, 32) } else { (4, 192) };
    let srv = HttpServer::start(fwd.clone(), serve_cfg, &http_cfg)?;
    let addr = srv.addr();
    let t0 = Instant::now();
    let mut ka_lat: Vec<f64> = std::thread::scope(|s| -> Result<Vec<f64>> {
        let handles: Vec<_> = (0..n_conns)
            .map(|c| {
                let model = model.clone();
                s.spawn(move || -> Result<Vec<f64>> {
                    let mut stream = TcpStream::connect(addr).context("connect")?;
                    let _ = stream.set_nodelay(true);
                    let mut lat = Vec::with_capacity(per_conn);
                    for r in 0..per_conn {
                        let body = bench_body(&model, &bench_input(d_in, c * per_conn + r));
                        let (status, dt) = send_one(&mut stream, "/v1/predict", &body)?;
                        anyhow::ensure!(status == 200, "keep-alive request got {status}");
                        lat.push(dt.as_secs_f64() * 1e3);
                    }
                    Ok(lat)
                })
            })
            .collect();
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().expect("client thread panicked")?);
        }
        Ok(all)
    })?;
    let ka_wall = t0.elapsed().as_secs_f64();
    srv.stop();
    ka_lat.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
    let keepalive_requests = n_conns * per_conn;
    let keepalive_rps = keepalive_requests as f64 / ka_wall.max(1e-9);
    let keepalive_p99_ms = percentile(&ka_lat, 0.99);

    // --- scenario 2: one fresh connection per request (churn)
    let (churn_conns, churn_per) = if smoke { (3, 16) } else { (4, 64) };
    let srv = HttpServer::start(fwd.clone(), serve_cfg, &http_cfg)?;
    let addr = srv.addr();
    let t0 = Instant::now();
    std::thread::scope(|s| -> Result<()> {
        let handles: Vec<_> = (0..churn_conns)
            .map(|c| {
                let model = model.clone();
                s.spawn(move || -> Result<()> {
                    for r in 0..churn_per {
                        let mut stream = TcpStream::connect(addr).context("connect")?;
                        let _ = stream.set_nodelay(true);
                        let body = bench_body(&model, &bench_input(d_in, c * churn_per + r));
                        let (status, _) = send_one(&mut stream, "/v1/predict", &body)?;
                        anyhow::ensure!(status == 200, "churn request got {status}");
                    }
                    Ok(())
                })
            })
            .collect();
        for h in handles {
            h.join().expect("client thread panicked")?;
        }
        Ok(())
    })?;
    let churn_wall = t0.elapsed().as_secs_f64();
    srv.stop();
    let churn_requests = churn_conns * churn_per;
    let churn_rps = churn_requests as f64 / churn_wall.max(1e-9);

    // --- scenario 3: overload at ~2x queue capacity. A throttled
    // forward (so the tiny bench model behaves like a real engine) with
    // a deliberately small queue; twice that many concurrent clients.
    // Every answer must be a 200 or a fast 503 — the p99 over *all*
    // requests is the row the baseline gates (bounded, no collapse).
    let q = if smoke { 4 } else { 8 };
    let throttled: Arc<dyn BatchForward> = Arc::new(Throttled {
        inner: fwd,
        delay: Duration::from_millis(2),
    });
    let overload_serve = ServeCfg { workers: 1, max_batch: 1, queue_cap: q };
    let srv = HttpServer::start(throttled, &overload_serve, &http_cfg)?;
    let addr = srv.addr();
    let clients = 2 * (q + 4); // ~2x the pool's total in-flight capacity
    let per_client = if smoke { 4 } else { 8 };
    let results: Vec<(u16, f64)> = std::thread::scope(|s| -> Result<Vec<(u16, f64)>> {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let model = model.clone();
                s.spawn(move || -> Result<Vec<(u16, f64)>> {
                    let mut stream = TcpStream::connect(addr).context("connect")?;
                    let _ = stream.set_nodelay(true);
                    let mut out = Vec::with_capacity(per_client);
                    for r in 0..per_client {
                        let body = bench_body(&model, &bench_input(d_in, c * per_client + r));
                        let (status, dt) = send_one(&mut stream, "/v1/predict", &body)?;
                        anyhow::ensure!(
                            status == 200 || status == 503,
                            "overload request got {status}"
                        );
                        out.push((status, dt.as_secs_f64() * 1e3));
                    }
                    Ok(out)
                })
            })
            .collect();
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().expect("client thread panicked")?);
        }
        Ok(all)
    })?;
    srv.stop();
    let overload_ok = results.iter().filter(|(s, _)| *s == 200).count();
    let overload_shed = results.len() - overload_ok;
    let mut ov_lat: Vec<f64> = results.iter().map(|(_, l)| *l).collect();
    ov_lat.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
    let overload_p99_ms = percentile(&ov_lat, 0.99);

    Ok(HttpBenchReport {
        keepalive_requests,
        keepalive_rps,
        keepalive_p99_ms,
        churn_requests,
        churn_rps,
        overload_requests: results.len(),
        overload_ok,
        overload_shed,
        overload_p99_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::super::tests::{one_hot_block, tiny_model};
    use super::*;
    use crate::deploy::engine::Engine;

    fn start_tiny(serve: &ServeCfg, http_cfg: &HttpCfg) -> HttpServer {
        let engine: Arc<dyn BatchForward> = Arc::new(Engine::new(tiny_model()));
        HttpServer::start(engine, serve, http_cfg).expect("server start")
    }

    fn predict_req(input: &[f32], extra: &[(&str, &str)]) -> Vec<u8> {
        http::format_request("/v1/predict", &bench_body("tiny", input), extra)
    }

    /// Body without a `model` field, for the resource route (the path
    /// carries the id there).
    fn input_only_body(input: &[f32]) -> Vec<u8> {
        let mut s = String::from("{\"input\":[");
        for (i, v) in input.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("{v}"));
        }
        s.push_str("]}");
        s.into_bytes()
    }

    fn error_code(resp: &http::ClientResponse) -> String {
        let j = crate::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        j.get("error").get("code").as_str().expect("error.code").to_string()
    }

    #[test]
    fn keepalive_connection_serves_multiple_predictions() {
        let srv = start_tiny(&ServeCfg::default(), &HttpCfg::default());
        let mut stream = TcpStream::connect(srv.addr()).unwrap();
        for c in 0..3 {
            stream.write_all(&predict_req(&one_hot_block(c), &[])).unwrap();
            let resp = http::read_response(&mut stream).unwrap();
            assert_eq!(resp.status, 200);
            let j = crate::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
            assert_eq!(j.get("pred").as_usize(), Some(c), "class {c}");
            assert_eq!(j.get("logits").as_arr().unwrap().len(), 3);
            assert_eq!(resp.header("connection"), Some("keep-alive"));
        }
        assert_eq!(srv.stats().ok.load(Ordering::Relaxed), 3);
        srv.stop();
    }

    #[test]
    fn zero_deadline_sheds_with_503() {
        let srv = start_tiny(&ServeCfg::default(), &HttpCfg::default());
        let mut stream = TcpStream::connect(srv.addr()).unwrap();
        stream
            .write_all(&predict_req(&one_hot_block(0), &[("X-Deadline-Ms", "0")]))
            .unwrap();
        let resp = http::read_response(&mut stream).unwrap();
        assert_eq!(resp.status, 503);
        assert_eq!(resp.header("x-shed"), Some("deadline"));
        assert_eq!(error_code(&resp), "deadline_exceeded");
        // the connection survives the shed: a normal request still works
        stream.write_all(&predict_req(&one_hot_block(2), &[])).unwrap();
        let resp = http::read_response(&mut stream).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(srv.stats().shed_deadline.load(Ordering::Relaxed), 1);
        srv.stop();
    }

    #[test]
    fn repeated_query_hits_the_cache() {
        let srv = start_tiny(&ServeCfg::default(), &HttpCfg::default());
        let mut stream = TcpStream::connect(srv.addr()).unwrap();
        stream.write_all(&predict_req(&one_hot_block(1), &[])).unwrap();
        let first = http::read_response(&mut stream).unwrap();
        assert_eq!(first.status, 200);
        assert_eq!(first.header("x-cache"), Some("miss"));
        stream.write_all(&predict_req(&one_hot_block(1), &[])).unwrap();
        let second = http::read_response(&mut stream).unwrap();
        assert_eq!(second.status, 200);
        assert_eq!(second.header("x-cache"), Some("hit"));
        let j = crate::json::parse(std::str::from_utf8(&second.body).unwrap()).unwrap();
        assert_eq!(j.get("pred").as_usize(), Some(1));
        assert_eq!(j.get("cached"), &crate::json::Json::Bool(true));
        assert_eq!(srv.stats().cache_hits.load(Ordering::Relaxed), 1);
        srv.stop();
    }

    #[test]
    fn bad_requests_get_4xx_not_hangs() {
        let srv = start_tiny(&ServeCfg::default(), &HttpCfg::default());
        let mut stream = TcpStream::connect(srv.addr()).unwrap();
        // wrong width
        stream.write_all(&predict_req(&[1.0, 2.0], &[])).unwrap();
        assert_eq!(http::read_response(&mut stream).unwrap().status, 400);
        // wrong model name
        let body = bench_body("other-model", &one_hot_block(0));
        stream
            .write_all(&http::format_request("/v1/predict", &body, &[]))
            .unwrap();
        assert_eq!(http::read_response(&mut stream).unwrap().status, 404);
        // unknown route
        stream
            .write_all(&http::format_request("/nope", b"{}", &[]))
            .unwrap();
        assert_eq!(http::read_response(&mut stream).unwrap().status, 404);
        // healthz still fine on the same connection
        stream.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        let h = http::read_response(&mut stream).unwrap();
        assert_eq!(h.status, 200);
        srv.stop();
    }

    /// The structured error schema: stable machine-readable codes under
    /// `error.code`, the offending model under `error.model`.
    #[test]
    fn errors_carry_stable_codes() {
        let srv = start_tiny(&ServeCfg::default(), &HttpCfg::default());
        let mut stream = TcpStream::connect(srv.addr()).unwrap();
        // wrong width -> bad_input_width
        stream.write_all(&predict_req(&[1.0, 2.0], &[])).unwrap();
        let resp = http::read_response(&mut stream).unwrap();
        assert_eq!(resp.status, 400);
        assert_eq!(error_code(&resp), "bad_input_width");
        let j = crate::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("error").get("model").as_str(), Some("tiny"));
        // unknown model -> model_not_found (legacy + resource routes)
        let body = bench_body("nope", &one_hot_block(0));
        stream.write_all(&http::format_request("/v1/predict", &body, &[])).unwrap();
        let resp = http::read_response(&mut stream).unwrap();
        assert_eq!((resp.status, error_code(&resp)), (404, "model_not_found".into()));
        stream
            .write_all(&http::format_request(
                "/v1/models/nope/predict",
                &input_only_body(&one_hot_block(0)),
                &[],
            ))
            .unwrap();
        let resp = http::read_response(&mut stream).unwrap();
        assert_eq!((resp.status, error_code(&resp)), (404, "model_not_found".into()));
        // unknown route -> route_not_found
        stream.write_all(&http::format_request("/nope", b"{}", &[])).unwrap();
        let resp = http::read_response(&mut stream).unwrap();
        assert_eq!((resp.status, error_code(&resp)), (404, "route_not_found".into()));
        // missing input -> bad_request
        stream.write_all(&http::format_request("/v1/predict", b"{}", &[])).unwrap();
        let resp = http::read_response(&mut stream).unwrap();
        assert_eq!((resp.status, error_code(&resp)), (400, "bad_request".into()));
        srv.stop();
    }

    /// The resource routes: `/v1/models/{id}/predict` serves without a
    /// body model field, `/v1/models` lists the fleet, and only the
    /// legacy alias carries `Deprecation: true`.
    #[test]
    fn resource_routes_serve_and_legacy_is_deprecated() {
        let srv = start_tiny(&ServeCfg::default(), &HttpCfg::default());
        let mut stream = TcpStream::connect(srv.addr()).unwrap();
        // resource route: no Deprecation header
        stream
            .write_all(&http::format_request(
                "/v1/models/tiny/predict",
                &input_only_body(&one_hot_block(2)),
                &[],
            ))
            .unwrap();
        let resp = http::read_response(&mut stream).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("deprecation"), None);
        let j = crate::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("pred").as_usize(), Some(2));
        // legacy alias answers the same prediction, flagged deprecated
        stream.write_all(&predict_req(&one_hot_block(2), &[])).unwrap();
        let legacy = http::read_response(&mut stream).unwrap();
        assert_eq!(legacy.status, 200);
        assert_eq!(legacy.header("deprecation"), Some("true"));
        // a contradictory body model on the resource route is rejected
        stream
            .write_all(&http::format_request(
                "/v1/models/tiny/predict",
                &bench_body("other", &one_hot_block(0)),
                &[],
            ))
            .unwrap();
        let resp = http::read_response(&mut stream).unwrap();
        assert_eq!((resp.status, error_code(&resp)), (400, "bad_request".into()));
        // fleet listing + model detail
        stream.write_all(b"GET /v1/models HTTP/1.1\r\n\r\n").unwrap();
        let list = http::read_response(&mut stream).unwrap();
        assert_eq!(list.status, 200);
        let j = crate::json::parse(std::str::from_utf8(&list.body).unwrap()).unwrap();
        let models = j.get("models").as_arr().expect("models array");
        assert_eq!(models.len(), 1);
        assert_eq!(models[0].get("id").as_str(), Some("tiny"));
        assert_eq!(models[0].get("mode").as_str(), Some("external"));
        stream.write_all(b"GET /v1/models/tiny HTTP/1.1\r\n\r\n").unwrap();
        let detail = http::read_response(&mut stream).unwrap();
        assert_eq!(detail.status, 200);
        let j = crate::json::parse(std::str::from_utf8(&detail.body).unwrap()).unwrap();
        assert_eq!(j.get("id").as_str(), Some("tiny"));
        assert_eq!(j.get("d_in").as_usize(), Some(12));
        srv.stop();
    }

    #[test]
    fn merged_stats_and_metrics_expose_front_end_and_pool() {
        let srv = start_tiny(&ServeCfg::default(), &HttpCfg::default());
        let mut stream = TcpStream::connect(srv.addr()).unwrap();
        // one pool-served answer, then the same query from the cache
        for _ in 0..2 {
            stream.write_all(&predict_req(&one_hot_block(0), &[])).unwrap();
            assert_eq!(http::read_response(&mut stream).unwrap().status, 200);
        }
        stream.write_all(b"GET /stats HTTP/1.1\r\n\r\n").unwrap();
        let resp = http::read_response(&mut stream).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("content-type"), Some("application/json"));
        let j = crate::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("cache_hits").as_usize(), Some(1));
        assert_eq!(j.get("cache_misses").as_usize(), Some(1));
        assert_eq!(j.get("pool_requests").as_usize(), Some(1));
        assert_eq!(j.get("pool_batches").as_usize(), Some(1));
        assert_eq!(j.get("open_conns").as_usize(), Some(1));
        assert_eq!(j.get("models").as_usize(), Some(1));
        assert_eq!(j.get("last_error"), &crate::json::Json::Null);
        assert!(j.get("p99_ms").as_f64().unwrap() >= j.get("p50_ms").as_f64().unwrap());
        stream.write_all(b"GET /metrics HTTP/1.1\r\n\r\n").unwrap();
        let m = http::read_response(&mut stream).unwrap();
        assert_eq!(m.status, 200);
        assert_eq!(m.header("content-type"), Some("text/plain; version=0.0.4"));
        let text = std::str::from_utf8(&m.body).unwrap();
        for needle in [
            "# TYPE qat_http_requests_total counter",
            "qat_http_requests_total 4",
            "qat_http_cache_hits_total 1",
            "qat_http_cache_misses_total 1",
            "qat_pool_requests_total 1",
            "# TYPE qat_request_latency_seconds histogram",
            "qat_request_latency_seconds_count 2",
            "qat_stage_queue_seconds_count 1",
            "qat_stage_compute_seconds_count 1",
            "qat_http_open_connections 1",
            "qat_registry_models 1",
            "# TYPE qat_model_requests_total counter",
            "qat_model_requests_total{model=\"tiny\"} 2",
            "qat_model_ok_total{model=\"tiny\"} 2",
            "qat_model_prepared{model=\"tiny\"} 1",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        assert!(text.contains("_bucket{le=\"+Inf\"}"), "{text}");
        srv.stop();
    }

    /// The hot-swap cache guarantee end to end: a swapped model must
    /// never answer from the old version's cache entries — same id,
    /// same input, new content, fresh miss, new prediction.
    #[test]
    fn hot_swap_invalidates_cache_and_keeps_serving() {
        use crate::deploy::packed::Packed;
        let mut models = ModelRegistry::new(RegistryCfg::default());
        models.insert_model("m", tiny_model()).unwrap();
        let srv = HttpServer::start_registry(models, &HttpCfg::default()).unwrap();
        let mut stream = TcpStream::connect(srv.addr()).unwrap();
        let req = http::format_request(
            "/v1/models/m/predict",
            &input_only_body(&one_hot_block(0)),
            &[],
        );
        // miss, then hit, on v1
        stream.write_all(&req).unwrap();
        let r1 = http::read_response(&mut stream).unwrap();
        assert_eq!((r1.status, r1.header("x-cache")), (200, Some("miss")));
        let j = crate::json::parse(std::str::from_utf8(&r1.body).unwrap()).unwrap();
        assert_eq!(j.get("pred").as_usize(), Some(0));
        stream.write_all(&req).unwrap();
        let r2 = http::read_response(&mut stream).unwrap();
        assert_eq!((r2.status, r2.header("x-cache")), (200, Some("hit")));
        // hot-swap to a rotated model: one_hot(0) now predicts class 1
        let mut v2 = tiny_model();
        v2.name = "m_v2".into();
        let mut codes = vec![4u32; 12 * 3];
        for c in 0..3usize {
            for f in 0..4usize {
                codes[(c * 4 + f) * 3 + (c + 1) % 3] = 6;
            }
        }
        v2.layers[0].weights = Packed::pack(&codes, 3).unwrap();
        let dir = std::env::temp_dir().join("qat_ingress_swap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m_v2.qpkg");
        v2.write_qpkg(&p).unwrap();
        let load_body = format!("{{\"qpkg\":{}}}", json_quote(&p.display().to_string()));
        stream
            .write_all(&http::format_request("/v1/models/m/load", load_body.as_bytes(), &[]))
            .unwrap();
        let loaded = http::read_response(&mut stream).unwrap();
        assert_eq!(loaded.status, 200, "{:?}", std::str::from_utf8(&loaded.body));
        let j = crate::json::parse(std::str::from_utf8(&loaded.body).unwrap()).unwrap();
        assert_eq!(j.get("version").as_usize(), Some(2));
        // same id + input: fresh miss (content changed), new prediction
        stream.write_all(&req).unwrap();
        let r3 = http::read_response(&mut stream).unwrap();
        assert_eq!((r3.status, r3.header("x-cache")), (200, Some("miss")));
        let j = crate::json::parse(std::str::from_utf8(&r3.body).unwrap()).unwrap();
        assert_eq!(j.get("pred").as_usize(), Some(1), "swapped weights must serve");
        // a load body without the qpkg field is rejected cleanly
        stream
            .write_all(&http::format_request("/v1/models/m/load", b"{}", &[]))
            .unwrap();
        let resp = http::read_response(&mut stream).unwrap();
        assert_eq!((resp.status, error_code(&resp)), (400, "bad_request".into()));
        srv.stop();
    }

    /// A sharded entry whose children can never come up answers a
    /// fast, retryable `shard_restarting` 503 — the connection (and the
    /// ingress) survives, and `/metrics` carries the shard families.
    #[test]
    fn sharded_pool_with_no_children_answers_shard_restarting() {
        use super::super::shard::{Launcher, ShardCfg};
        let cfg = RegistryCfg {
            shard: ShardCfg {
                shards: 1,
                // a launcher that drops the child's socket on the floor:
                // the handshake fails forever, no shard is ever up
                launcher: Launcher::Thread(Arc::new(|_, _conn| {})),
                backoff_base: Duration::from_millis(50),
                backoff_max: Duration::from_millis(200),
                ..ShardCfg::default()
            },
            ..RegistryCfg::default()
        };
        let mut models = ModelRegistry::new(cfg);
        models.insert_model("m", tiny_model()).unwrap();
        let srv = HttpServer::start_registry(models, &HttpCfg::default()).unwrap();
        let mut stream = TcpStream::connect(srv.addr()).unwrap();
        stream
            .write_all(&http::format_request(
                "/v1/models/m/predict",
                &input_only_body(&one_hot_block(0)),
                &[],
            ))
            .unwrap();
        let resp = http::read_response(&mut stream).unwrap();
        assert_eq!((resp.status, error_code(&resp)), (503, "shard_restarting".into()));
        assert_eq!(resp.header("x-shed"), Some("restart"));
        // the connection survives the shed: health + metrics still work
        stream.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(http::read_response(&mut stream).unwrap().status, 200);
        stream.write_all(b"GET /metrics HTTP/1.1\r\n\r\n").unwrap();
        let m = http::read_response(&mut stream).unwrap();
        let text = std::str::from_utf8(&m.body).unwrap();
        assert!(text.contains("qat_shard_up{model=\"m\"} 0"), "{text}");
        assert!(text.contains("# TYPE qat_shard_restarts_total counter"), "{text}");
        assert!(text.contains("# TYPE qat_shard_heartbeat_age_seconds histogram"), "{text}");
        srv.stop();
    }

    /// The response cache keys on (id, content fingerprint, input
    /// bits) — no shard identity — so an answer cached before a shard
    /// crash keeps hitting after the supervisor restarts the child,
    /// and the restarted child repopulates under the same fingerprint.
    #[test]
    fn cache_keys_survive_shard_restart() {
        use super::super::shard::supervisor::testutil::healthy_fake;
        use super::super::shard::{Launcher, ShardCfg};
        // keep a clone of every live shard connection so the test can
        // sever it — the supervisor sees the disconnect as a crash
        let live: Arc<std::sync::Mutex<Vec<TcpStream>>> =
            Arc::new(std::sync::Mutex::new(Vec::new()));
        let live_in = Arc::clone(&live);
        let cfg = RegistryCfg {
            shard: ShardCfg {
                shards: 1,
                launcher: Launcher::Thread(Arc::new(move |_ix, conn: TcpStream| {
                    live_in.lock().unwrap().push(conn.try_clone().unwrap());
                    healthy_fake(12, conn);
                })),
                backoff_base: Duration::from_millis(20),
                backoff_max: Duration::from_millis(100),
                ..ShardCfg::default()
            },
            ..RegistryCfg::default()
        };
        let mut models = ModelRegistry::new(cfg);
        models.insert_model("m", tiny_model()).unwrap();
        let srv = HttpServer::start_registry(models, &HttpCfg::default()).unwrap();
        let mut stream = TcpStream::connect(srv.addr()).unwrap();
        let req = http::format_request(
            "/v1/models/m/predict",
            &input_only_body(&one_hot_block(1)),
            &[],
        );
        // wait for the shard to come up, then prime the cache
        let t0 = Instant::now();
        let first = loop {
            stream.write_all(&req).unwrap();
            let resp = http::read_response(&mut stream).unwrap();
            if resp.status == 200 {
                break resp;
            }
            assert_eq!(resp.status, 503, "unexpected status while the shard spawns");
            assert!(t0.elapsed() < Duration::from_secs(30), "shard never came up");
            std::thread::sleep(Duration::from_millis(20));
        };
        assert_eq!(first.header("x-cache"), Some("miss"));
        stream.write_all(&req).unwrap();
        assert_eq!(http::read_response(&mut stream).unwrap().header("x-cache"), Some("hit"));
        // crash the shard: sever its socket from the child side
        for c in live.lock().unwrap().drain(..) {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
        // an uncached input forces pool traffic; it answers 200 again
        // once the supervisor has respawned the shard
        let fresh = http::format_request(
            "/v1/models/m/predict",
            &input_only_body(&one_hot_block(2)),
            &[],
        );
        let t0 = Instant::now();
        loop {
            stream.write_all(&fresh).unwrap();
            let resp = http::read_response(&mut stream).unwrap();
            if resp.status == 200 {
                assert_eq!(resp.header("x-cache"), Some("miss"));
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(30), "shard never restarted");
            std::thread::sleep(Duration::from_millis(20));
        }
        // the pre-crash key still hits: the fingerprint carries no
        // shard identity, so a restart invalidates nothing
        stream.write_all(&req).unwrap();
        let resp = http::read_response(&mut stream).unwrap();
        assert_eq!(resp.header("x-cache"), Some("hit"), "cache key changed across restart");
        assert_eq!(resp.status, 200);
        srv.stop();
    }

    /// Graceful drain: the in-flight request is answered before the
    /// event loop exits, and the listener is closed to new connections.
    #[test]
    fn drain_answers_in_flight_and_refuses_new_connections() {
        // a slow engine so the request is genuinely in flight when the
        // drain begins
        let engine: Arc<dyn BatchForward> = Arc::new(Throttled {
            inner: Arc::new(Engine::new(tiny_model())),
            delay: Duration::from_millis(150),
        });
        let srv = HttpServer::start(engine, &ServeCfg::default(), &HttpCfg::default()).unwrap();
        let addr = srv.addr();
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(&predict_req(&one_hot_block(1), &[])).unwrap();
        std::thread::sleep(Duration::from_millis(50)); // let it reach the pool
        let t0 = Instant::now();
        srv.drain(Duration::from_secs(30));
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "drain must return via quiescence, not the deadline"
        );
        // the in-flight answer was flushed before the loop exited
        let resp = http::read_response(&mut stream).unwrap();
        assert_eq!(resp.status, 200);
        let j = crate::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("pred").as_usize(), Some(1));
        // the listener is gone: new connections are refused
        assert!(TcpStream::connect(addr).is_err(), "listener must be closed after drain");
    }

    #[test]
    fn bench_http_smoke_reports_all_rows() {
        let engine: Arc<dyn BatchForward> = Arc::new(Engine::new(tiny_model()));
        let report = bench_http(engine, &ServeCfg::default(), true).unwrap();
        assert!(report.keepalive_rps > 0.0);
        assert!(report.churn_rps > 0.0);
        assert!(report.keepalive_p99_ms > 0.0);
        assert!(report.overload_p99_ms > 0.0);
        assert_eq!(report.overload_ok + report.overload_shed, report.overload_requests);
        assert!(report.overload_ok > 0, "overload run must still serve some requests");
        let mut o = BTreeMap::new();
        report.merge_into(&mut o);
        for key in [
            "http_keepalive_rps",
            "http_churn_rps",
            "http_overload_p99_ms",
            "http_overload_shed",
        ] {
            assert!(o.contains_key(key), "missing merged row {key}");
        }
    }
}
