//! oscillations-qat CLI: the leader binary driving the whole system.
//!
//! Subcommands:
//!   train    one training run (FP or QAT) with full knob control
//!   eval     evaluate a checkpoint on the validation split
//!   export   QAT state -> BN-folded bit-packed integer model (.qpkg)
//!   serve    batched-serving throughput/latency benchmark over a .qpkg
//!   toy      the 1-D toy regression (prints a trace)
//!   table1..table8, fig1..fig6   regenerate a paper table/figure
//!   suite    run every table + figure in one process (artifact compiles
//!            are cached, so this is much cheaper than separate processes)
//!   bench-step / bench-kernels   perf micro-benchmarks
//!
//! Backends: --backend {auto,pjrt,native}. `pjrt` replays the AOT HLO
//! artifacts under --artifacts; `native` is the artifact-free pure-Rust
//! interpreter; `auto` (default) picks PJRT when usable, else native.
//!
//! Common flags: --backend auto --artifacts DIR --steps N --fp-steps N
//! --seeds 0,1. Run with no arguments for usage.

use anyhow::Result;
use oscillations_qat::cli::Args;
use std::sync::atomic::{AtomicBool, Ordering};
use oscillations_qat::coordinator::evaluator::{EvalQuant, Evaluator};
use oscillations_qat::coordinator::experiment::{Lab, QatSpec};
use oscillations_qat::coordinator::{Schedule, Trainer};
use oscillations_qat::runtime::{self, Backend};
use oscillations_qat::toy::{run as toy_run, stats as toy_stats, ToyCfg, ToyEstimator};
use std::path::PathBuf;

const USAGE: &str = "oscillations-qat — QAT oscillation study (Nagel et al., ICML 2022)

USAGE: oscillations-qat <subcommand> [flags]

  train     --model mbv2 --estimator lsq --steps 400 --bits-w 3 [--bits-a 3 --quant-a]
            [--per-tensor] [--lam cos(0,0.01)] [--f-th cos(0.04,0.01)] [--seed 0]
            [--fp-steps 600] [--telemetry run.jsonl]
            (per-channel LSQ scales are the default; --per-tensor restores
            the legacy single-scale quantizers; --telemetry streams
            qat_step/qat_layer/bn_drift JSONL records for obs-report)
  eval      --model mbv2 --ckpt ckpts/<tag>.qtns --bits-w 3 [--fp | --quant-a]
  export    --model mbv2 --bits-w 3 [--bits-a 3 --quant-a --per-tensor] [--out m.qpkg]
            [--ckpt state.qtns]   (no --ckpt: run the QAT pipeline first)
  serve     --model id=path.qpkg (repeatable) | --qpkg m.qpkg | m.qpkg
            (a bare --qpkg / positional QPKG is sugar for
            --model default=path.qpkg)
            [--requests 2048 --workers 4 --max-batch 16]
            [--threads N|auto] [--exact] [--streaming] [--smoke]
            [--no-http] [--no-fleet] [--bench-out BENCH_serve.json]
            [--layer-timing] [--telemetry serve.jsonl]
            benchmark mode (default): channel-level serve bench plus the
            HTTP front-end rows (keep-alive vs churn, overload p99), the
            fleet rows (throughput at 2/4/8 resident models, hot-swap
            p99 spike), and the shard rows (2-process throughput,
            kill -9 recovery time); --no-http skips the network
            scenarios, --no-fleet skips the fleet + shard rows;
            --layer-timing turns on per-layer engine timing (reported
            via --telemetry)
            --listen 127.0.0.1:8090 [--mem-budget-mb N] [--deadline-ms 0]
            [--cache-cap 1024] [--queue-cap 1024] [--shards N]
            [--drain-ms 5000]   run the HTTP/1.1 front-end instead:
            POST /v1/models/{id}/predict, GET /v1/models[/{id}],
            POST /v1/models/{id}/load (hot-swap), legacy POST
            /v1/predict (Deprecation: true), GET /healthz, /stats,
            /metrics; --mem-budget-mb caps total prepared-plane bytes
            (LRU demotion to streaming); --shards N runs each model's
            pool as N fault-isolated child processes with crash
            recovery and failover (QAT_FAULT_INJECT='model[#ix]=spec;...'
            injects panic:p / stall:ms faults into matching children);
            SIGTERM/SIGINT drains in-flight requests within --drain-ms
            and exits 0
  obs-report  <run.jsonl>   summarize a --telemetry JSONL stream (freeze
            timeline, top oscillating layers, BN drift, serve rows,
            per-layer compute time)
  toy       [--estimator ste|ewgs|dsq|psg|dampen] [--w-star 0.252] [--lr 0.01]
  table1 .. table8, fig1, fig2, fig34, fig5, fig6
  table-spatial   reference rows for the 2-D spatial-depthwise zoo
            (mbv2_2d / efflite_2d) under the per-channel default;
            see RESULTS.md for the re-baseline protocol
  suite     [--quick]       run everything in one process; writes the
            run settings to results/PROVENANCE.txt
  bench-step / bench-kernels
  bench-deploy  [--smoke] [--threads N|auto] [--serve-json BENCH_serve.json]
                [--out BENCH_deploy.json]
                [--baseline BENCH_baseline.json --max-regress 0.25]
                [--emit-baseline BENCH_baseline_suggested.json]
                deploy micro-bench (streaming + prepared decode, 1 and N
                threads, lazy vs tree request JSON) -> merged
                perf-trajectory report; exits non-zero when a required
                row is missing, any throughput drops past the baseline
                floor, or a latency ceiling is exceeded; --emit-baseline
                writes conservative floors from this run's numbers

Common flags: --backend auto|pjrt|native   (native needs no artifacts)
              --artifacts artifacts --results results --ckpts ckpts
              --steps N --fp-steps N --seeds 0,1";

/// Set by the SIGTERM/SIGINT handler; polled by `serve --listen` to
/// start a graceful drain instead of dying mid-request.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" fn on_signal(_sig: i32) {
        // an atomic store is async-signal-safe
        SHUTDOWN.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    // SIGINT = 2, SIGTERM = 15 (POSIX-mandated numbers on every unix)
    unsafe {
        signal(2, on_signal as usize);
        signal(15, on_signal as usize);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

fn lab_from_args<'rt>(rt: &'rt dyn Backend, args: &Args) -> Lab<'rt> {
    let mut lab = Lab::new(rt);
    lab.qat_steps = args.u64_or("steps", lab.qat_steps);
    lab.fp_steps = args.u64_or("fp-steps", lab.fp_steps);
    let default_seeds = lab.seeds.clone();
    lab.seeds = args.u64_list_or("seeds", &default_seeds);
    lab.ckpt_dir = PathBuf::from(args.str_or("ckpts", "ckpts"));
    lab.results_dir = PathBuf::from(args.str_or("results", "results"));
    lab.data.noise = args.f32_or("noise", lab.data.noise);
    lab.data.max_shift = args.u32_or("max-shift", lab.data.max_shift as u32) as i32;
    if args.flag("quick") {
        lab.qat_steps = lab.qat_steps.min(120);
        lab.fp_steps = lab.fp_steps.min(150);
        lab.seeds.truncate(1);
        lab.bn_batches = 8;
    }
    lab
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let Some(cmd) = args.subcommand.clone() else {
        println!("{USAGE}");
        return Ok(());
    };

    // toy and obs-report need no backend
    if cmd == "toy" {
        return cmd_toy(&args);
    }
    if cmd == "obs-report" {
        return cmd_obs_report(&args);
    }
    // hidden entry point: `serve --shards N` re-invokes this binary as
    // `shard-worker --connect ... --qpkg ...` for each child process
    if cmd == "shard-worker" {
        return oscillations_qat::deploy::serve::shard::run_shard_worker(&args);
    }

    let artifact_dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let be = runtime::backend_by_name(&args.str_or("backend", "auto"), &artifact_dir)?;
    let be: &dyn Backend = be.as_ref();
    eprintln!("[runtime] backend: {}", be.kind());
    let lab = lab_from_args(be, &args);

    match cmd.as_str() {
        "train" => cmd_train(&lab, &args)?,
        "eval" => cmd_eval(be, &args)?,
        "export" => cmd_export(&lab, &args)?,
        "serve" => cmd_serve(&args)?,
        "table1" => drop(lab.table1()?),
        "table2" => drop(lab.table2()?),
        "table3" => drop(lab.table3()?),
        "table4" => drop(lab.table4()?),
        "table5" => drop(lab.table5()?),
        "table6" => drop(lab.table6()?),
        "table7" => drop(lab.table7()?),
        "table8" => drop(lab.table8()?),
        "table-spatial" | "spatial" => drop(lab.table_spatial()?),
        "fig1" => drop(lab.fig1()?),
        "fig2" => drop(lab.fig2()?),
        "fig34" | "fig3" | "fig4" => drop(lab.fig34()?),
        "fig5" => drop(lab.fig5()?),
        "fig6" => drop(lab.fig6()?),
        "suite" => cmd_suite(&lab)?,
        "bench-step" => cmd_bench_step(be, &args)?,
        "bench-kernels" => cmd_bench_kernels(be)?,
        "bench-deploy" => cmd_bench_deploy(&args)?,
        other => {
            eprintln!("unknown subcommand {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    if be.compile_seconds() > 0.0 {
        eprintln!(
            "[runtime] total XLA compile time this process: {:.1}s",
            be.compile_seconds()
        );
    }
    Ok(())
}

fn cmd_train(lab: &Lab, args: &Args) -> Result<()> {
    let model = args.str_or("model", "mbv2");
    let spec = QatSpec {
        model: model.clone(),
        estimator: args.str_or("estimator", "lsq"),
        bits_w: args.u32_or("bits-w", 3),
        bits_a: args.u32_or("bits-a", args.u32_or("bits-w", 3)),
        quant_a: args.flag("quant-a"),
        // per-channel is the default; --per-tensor is the escape hatch
        // (--per-channel is still accepted as an explicit confirmation)
        per_channel: args.flag("per-channel") || !args.flag("per-tensor"),
        lam: Schedule::parse(&args.str_or("lam", "0")).expect("bad --lam"),
        f_th: Schedule::parse(&args.str_or("f-th", "1.1")).expect("bad --f-th"),
        seed: args.u64_or("seed", 0),
        trace: args.get("trace-weight").map(|w| (w.to_string(), 9)),
        telemetry: args.get("telemetry").map(String::from),
    };
    let out = lab.run_qat(&spec)?;
    println!(
        "final: pre-BN {:.2}%  post-BN {:.2}%  osc {:.2}%  frozen {:.2}%  ({:.1} steps/s)",
        out.pre_bn_acc, out.post_bn_acc, out.osc_pct, out.frozen_pct,
        out.run.steps_per_sec
    );
    let curve = lab.results_dir.join(format!("train_{model}_{}.csv", spec.seed));
    out.run.history.save_csv(&curve)?;
    println!("loss curve -> {}", curve.display());
    Ok(())
}

fn cmd_eval(rt: &dyn Backend, args: &Args) -> Result<()> {
    let model = args.str_or("model", "mbv2");
    // `eval --fp ckpts/run.qtns` keeps the path positional (--fp is a
    // declared boolean flag), so accept it there too
    let ckpt_arg = args.get("ckpt").map(String::from).or_else(|| {
        args.positional.first().cloned()
    });
    let Some(ckpt_arg) = ckpt_arg else {
        anyhow::bail!("eval needs a checkpoint: --ckpt <state.qtns> (or positional)");
    };
    let ckpt = PathBuf::from(ckpt_arg);
    let state = oscillations_qat::state::NamedTensors::read_qtns(&ckpt)?;
    let ev = Evaluator::new(rt, &model)?;
    let bits = args.u32_or("bits-w", 3);
    let q = if args.flag("fp") {
        EvalQuant::fp()
    } else if args.flag("quant-a") {
        EvalQuant::full(bits)
    } else {
        EvalQuant::weights(bits)
    };
    let r = ev.eval_val(&state, &Default::default(), q)?;
    println!("val acc {:.2}%  loss {:.4}  ({} samples)", r.acc, r.loss, r.samples);
    Ok(())
}

fn cmd_export(lab: &Lab, args: &Args) -> Result<()> {
    use oscillations_qat::deploy::export::{export_model, ExportCfg};
    use oscillations_qat::runtime::native::model::zoo_model;

    let model = args.str_or("model", "mbv2");
    let bits_w = args.u32_or("bits-w", 3);
    let bits_a = args.u32_or("bits-a", bits_w);
    let quant_a = args.flag("quant-a");
    let out = PathBuf::from(args.str_or("out", &format!("{model}_w{bits_w}.qpkg")));
    let cfg = ExportCfg { bits_w, bits_a, quant_a };

    let (dm, report) = if let Some(ckpt) = args.get("ckpt") {
        // export a saved state directly (assumed already BN-re-estimated)
        let state = oscillations_qat::state::NamedTensors::read_qtns(&PathBuf::from(ckpt))?;
        let nm = zoo_model(&model)
            .ok_or_else(|| anyhow::anyhow!("no zoo model {model:?} to export"))?;
        export_model(&nm, &state, &cfg)?
    } else {
        // full pipeline: FP pretrain -> QAT -> BN re-estimation -> export
        let spec = QatSpec {
            model: model.clone(),
            estimator: args.str_or("estimator", "lsq"),
            bits_w,
            bits_a,
            quant_a,
            per_channel: !args.flag("per-tensor"),
            lam: Schedule::parse(&args.str_or("lam", "0")).expect("bad --lam"),
            f_th: Schedule::parse(&args.str_or("f-th", "cos(0.04,0.01)")).expect("bad --f-th"),
            seed: args.u64_or("seed", 0),
            trace: None,
            telemetry: args.get("telemetry").map(String::from),
        };
        let (outcome, dm, report) = lab.run_qat_and_export(&spec)?;
        println!(
            "trained: pre-BN {:.2}%  post-BN {:.2}%  frozen {:.2}%",
            outcome.pre_bn_acc, outcome.post_bn_acc, outcome.frozen_pct
        );
        (dm, report)
    };
    dm.write_qpkg(&out)?;
    let file_bytes = std::fs::metadata(&out).map(|m| m.len()).unwrap_or(0);
    println!(
        "exported {} -> {}: {} layers, {} weights, {} frozen verified, \
         max off-grid {:.4} grid units, packed {} B vs f32 {} B (ratio {:.3}), file {} B",
        model,
        out.display(),
        report.layers,
        report.total_weights,
        report.frozen_verified,
        report.max_offgrid,
        report.packed_bytes,
        report.f32_bytes,
        report.ratio(),
        file_bytes
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use oscillations_qat::data::{DataCfg, Dataset};
    use oscillations_qat::deploy::format::DeployModel;
    use oscillations_qat::deploy::serve::{
        bench_fleet, bench_http, bench_serve, bench_shards, BatchForward, EngineCfg, HttpCfg,
        HttpServer, ModelRegistry, RegistryCfg, ServeCfg, ShardCfg,
    };
    use oscillations_qat::deploy::{resolve_threads, Engine, EngineOpts};
    use std::path::Path;
    use std::sync::Arc;

    // fleet spec: repeatable `--model id=path.qpkg`; `--qpkg m.qpkg` or a
    // bare positional QPKG is sugar for `--model default=path`
    let mut specs: Vec<(String, String)> = Vec::new();
    let qpkg = args.str_or("qpkg", "");
    let qpkg =
        if qpkg.is_empty() { args.positional.first().cloned().unwrap_or_default() } else { qpkg };
    if !qpkg.is_empty() {
        specs.push(("default".to_string(), qpkg));
    }
    for spec in args.get_all("model") {
        let Some((id, path)) = spec.split_once('=') else {
            anyhow::bail!("--model wants id=path.qpkg, got {spec:?}");
        };
        anyhow::ensure!(
            !id.is_empty() && !path.is_empty(),
            "--model wants id=path.qpkg, got {spec:?}"
        );
        specs.push((id.to_string(), path.to_string()));
    }
    anyhow::ensure!(
        !specs.is_empty(),
        "serve needs --qpkg <model.qpkg> or --model id=path.qpkg (see `export`)"
    );

    let threads = resolve_threads(args.get("threads"), 1);
    let smoke = args.flag("smoke");
    let requests = args.u64_or("requests", if smoke { 256 } else { 2048 }) as usize;
    let cfg = ServeCfg {
        workers: args.u64_or("workers", 4) as usize,
        max_batch: args.u64_or("max-batch", 16) as usize,
        queue_cap: args.u64_or("queue-cap", 1024) as usize,
    };

    // --listen: run the HTTP/1.1 front-end until killed instead of
    // benchmarking. The fleet registry owns every model: each entry gets
    // its own worker pool, --mem-budget-mb caps the total prepared-plane
    // bytes (LRU demotion to streaming), and POST /v1/models/{id}/load
    // hot-swaps an entry in place.
    if let Some(listen) = args.get("listen") {
        let mem_budget = if args.flag("streaming") {
            // honor the single-model flag fleet-wide: budget 0 keeps
            // every entry in streaming mode
            Some(0)
        } else {
            args.get("mem-budget-mb")
                .and_then(|v| v.parse::<usize>().ok())
                .map(|mb| mb * 1024 * 1024)
        };
        let engine_cfg = EngineCfg {
            int_accum: !args.flag("exact"),
            threads,
            layer_timing: args.flag("layer-timing"),
        };
        // --shards N: each model's pool runs as N child processes with
        // crash recovery; QAT_FAULT_INJECT seeds chaos-test faults into
        // matching children (model:ix:panic:p,stall:ms rules)
        let shards = args.usize_or("shards", 0);
        let shard = ShardCfg {
            shards,
            fault_env: std::env::var("QAT_FAULT_INJECT").ok(),
            ..ShardCfg::default()
        };
        let mut models = ModelRegistry::new(RegistryCfg {
            serve: cfg.clone(),
            engine: engine_cfg,
            mem_budget,
            shard,
        });
        for (id, path) in &specs {
            let out = models.load_qpkg(id, Path::new(path))?;
            eprintln!(
                "[serve] model {id}: {} v{} ({} plane bytes) <- {path}",
                if out.prepared { "prepared" } else { "streaming" },
                out.version,
                out.plane_bytes
            );
        }
        let http_cfg = HttpCfg {
            addr: listen.to_string(),
            default_deadline_ms: args.u64_or("deadline-ms", 0),
            cache_cap: args.usize_or("cache-cap", 1024),
            ..HttpCfg::default()
        };
        let n_models = models.len();
        let srv = HttpServer::start_registry(models, &http_cfg)?;
        println!(
            "[serve] fleet of {} listening on http://{} — POST /v1/models/{{id}}/predict, \
             GET /v1/models[/{{id}}], POST /v1/models/{{id}}/load; legacy POST /v1/predict \
             (Deprecation: true); GET /healthz, /stats, /metrics \
             (deadline default {}ms, cache {} entries{}{})",
            n_models,
            srv.addr(),
            http_cfg.default_deadline_ms,
            http_cfg.cache_cap,
            match mem_budget {
                Some(b) => format!(", plane budget {b} B"),
                None => String::new(),
            },
            if shards > 0 { format!(", {shards} shard procs/model") } else { String::new() }
        );
        // tests and supervisors parse the banner for the bound address;
        // make sure it is out even when stdout is a pipe
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
        // park until SIGTERM/SIGINT, then drain: close the listener,
        // answer in-flight requests within --drain-ms, shut the fleet
        // (and any shard children) down, exit 0
        let drain_ms = args.u64_or("drain-ms", 5000);
        install_signal_handlers();
        while !SHUTDOWN.load(Ordering::SeqCst) {
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        eprintln!("[serve] shutdown signal: draining ({drain_ms} ms budget)");
        srv.drain(std::time::Duration::from_millis(drain_ms));
        eprintln!("[serve] drained");
        return Ok(());
    }

    // benchmark mode: the channel/HTTP rows measure one engine (the
    // first spec); the fleet rows clone it into 2/4/8 registry entries
    let opts = EngineOpts {
        threads,
        prepared: !args.flag("streaming"),
        layer_timing: args.flag("layer-timing"),
    };
    // load-time prepare: with_opts decodes the packed payloads exactly
    // once (every worker shares the planes through the Arc); --streaming
    // skips the decode entirely and re-decodes per call
    let dm = DeployModel::read_qpkg(&PathBuf::from(&specs[0].1))?;
    let fleet_dm = dm.clone();
    let engine = Arc::new(Engine::with_opts(dm, !args.flag("exact"), opts));
    if opts.prepared {
        eprintln!(
            "[serve] prepared planes: {} B cached on top of {} B packed ({} threads/forward)",
            engine.prepared().plane_bytes(),
            engine.model().packed_weight_bytes(),
            opts.threads
        );
    } else {
        eprintln!(
            "[serve] streaming decode: no cached planes, {} B packed re-decoded per call \
             ({} threads/forward)",
            engine.model().packed_weight_bytes(),
            opts.threads
        );
    }

    // request stream: individual samples from the deterministic val
    // split, generated once and cycled to the requested count
    let d_in = engine.model().d_in();
    let hw = engine.model().input_hw;
    let ds = Dataset::new(DataCfg { val_size: 256, hw, ..Default::default() });
    let mut samples: Vec<Vec<f32>> = Vec::new();
    for b in ds.val_batches() {
        let bs = b.x.shape[0];
        for i in 0..bs {
            samples.push(b.x.data[i * d_in..(i + 1) * d_in].to_vec());
        }
    }
    anyhow::ensure!(!samples.is_empty(), "empty validation stream");
    let inputs: Vec<Vec<f32>> =
        (0..requests).map(|i| samples[i % samples.len()].clone()).collect();

    let mut report = bench_serve(engine.clone(), &cfg, &inputs)?;
    // network-level scenarios over the same engine (--no-http skips)
    if !args.flag("no-http") {
        let fwd: Arc<dyn BatchForward> = engine.clone();
        report.http = Some(bench_http(fwd, &cfg, smoke)?);
        // fleet scenarios: throughput with 2/4/8 resident model clones
        // and the hot-swap p99 spike (--no-fleet skips just these)
        if !args.flag("no-fleet") {
            report.fleet = Some(bench_fleet(&fleet_dm, &cfg, smoke)?);
            // sharded serving: throughput over 2 real child processes,
            // then kill -9 one and measure time back to full strength
            report.shard = Some(bench_shards(Path::new(&specs[0].1), &cfg, threads, smoke)?);
        }
    }
    println!("{}", report.summary());
    let out = PathBuf::from(args.str_or("bench-out", "BENCH_serve.json"));
    report.write_json(&out)?;
    println!("report -> {}", out.display());

    // --telemetry: stream the bench rows (and, with --layer-timing, the
    // per-layer engine times) as JSONL for `obs-report`
    if let Some(path) = args.get("telemetry") {
        use oscillations_qat::json::Json;
        use oscillations_qat::obs::events::num;
        use oscillations_qat::obs::EventSink;
        let sink = EventSink::to_path(path)?;
        sink.emit(
            "serve_bench",
            &[
                ("name", Json::Str("channel_serve".into())),
                ("throughput_rps", num(report.throughput_rps)),
                ("p50_ms", num(report.p50_ms)),
                ("p95_ms", num(report.p95_ms)),
                ("p99_ms", num(report.p99_ms)),
                ("hist_p95_ms", num(report.hist_p95_ms)),
                ("mean_batch", num(report.mean_batch)),
            ],
        );
        if let Some(h) = &report.http {
            sink.emit(
                "serve_bench",
                &[
                    ("name", Json::Str("http".into())),
                    ("keepalive_rps", num(h.keepalive_rps)),
                    ("churn_rps", num(h.churn_rps)),
                    ("overload_p99_ms", num(h.overload_p99_ms)),
                    ("overload_shed", num(h.overload_shed as f64)),
                ],
            );
        }
        if let Some(f) = &report.fleet {
            let rps_for = |n: usize| {
                f.fleet_rps.iter().find(|(m, _)| *m == n).map(|(_, r)| *r).unwrap_or(0.0)
            };
            sink.emit(
                "serve_bench",
                &[
                    ("name", Json::Str("fleet".into())),
                    ("fleet_rps_2", num(rps_for(2))),
                    ("fleet_rps_4", num(rps_for(4))),
                    ("fleet_rps_8", num(rps_for(8))),
                    ("swap_requests", num(f.swap_requests as f64)),
                    ("swap_p99_spike_ms", num(f.swap_p99_spike_ms)),
                ],
            );
        }
        if let Some(sh) = &report.shard {
            sink.emit(
                "serve_bench",
                &[
                    ("name", Json::Str("shard".into())),
                    ("shard_rps_2", num(sh.shard_rps_2)),
                    ("shard_restart_ms", num(sh.shard_restart_ms)),
                    ("shard_failovers", num(sh.shard_failovers as f64)),
                    ("shard_restarts", num(sh.shard_restarts as f64)),
                ],
            );
        }
        for lt in engine.layer_timing_summary() {
            sink.emit(
                "layer_timing",
                &[
                    ("layer", Json::Str(lt.name.clone())),
                    ("calls", num(lt.calls as f64)),
                    ("total_ns", num(lt.total_ns as f64)),
                ],
            );
        }
        println!("telemetry -> {path}");
    }
    Ok(())
}

fn cmd_obs_report(args: &Args) -> Result<()> {
    let path = args.get("file").map(String::from).or_else(|| {
        args.positional.first().cloned()
    });
    let Some(path) = path else {
        anyhow::bail!("obs-report needs a telemetry file: obs-report <run.jsonl>");
    };
    let text = oscillations_qat::obs::report::report_file(&path)
        .map_err(|e| anyhow::anyhow!("read {path}: {e}"))?;
    print!("{text}");
    Ok(())
}

fn cmd_toy(args: &Args) -> Result<()> {
    let est = match args.str_or("estimator", "ste").as_str() {
        "ste" => ToyEstimator::Ste,
        "ewgs" => ToyEstimator::Ewgs { delta: args.f32_or("delta", 0.2) },
        "dsq" => ToyEstimator::Dsq { k: args.f32_or("k", 5.0) },
        "psg" => ToyEstimator::Psg { eps: args.f32_or("eps", 0.01) },
        "dampen" => ToyEstimator::Dampen { lambda: args.f32_or("lambda", 0.6) },
        other => anyhow::bail!("unknown estimator {other}"),
    };
    let cfg = ToyCfg {
        est,
        w_star: args.f32_or("w-star", 0.252),
        lr: args.f32_or("lr", 0.01),
        steps: args.u64_or("steps", 600) as usize,
        ..Default::default()
    };
    let traj = toy_run(&cfg);
    let st = toy_stats(&traj, traj.len() / 4, cfg.s);
    for (i, (w, q)) in traj.iter().enumerate() {
        if i % args.u64_or("every", 10) as usize == 0 {
            println!("{i:>5}  w={w:+.4}  q(w)={q:+.2}");
        }
    }
    println!(
        "freq={:.4} flips/iter  amplitude={:.5}  frac_upper={:.3}",
        st.freq, st.amplitude, st.frac_up
    );
    Ok(())
}

fn cmd_suite(lab: &Lab) -> Result<()> {
    let t0 = std::time::Instant::now();
    lab.fig1()?;
    lab.fig5()?;
    lab.fig6()?;
    lab.table2()?;
    lab.table1()?;
    lab.table4()?;
    lab.table5()?;
    lab.fig2()?;
    lab.fig34()?;
    lab.table3()?;
    lab.table6()?;
    lab.table7()?;
    lab.table8()?;
    lab.table_spatial()?;
    // Committed reference numbers (RESULTS.md) must carry the settings
    // they were produced with; a suite run records its own.
    let prov = format!(
        "qat_steps={}\nfp_steps={}\nseeds={:?}\nbn_batches={}\nbackend={}\nelapsed_s={:.1}\n",
        lab.qat_steps,
        lab.fp_steps,
        lab.seeds,
        lab.bn_batches,
        lab.rt.kind(),
        t0.elapsed().as_secs_f64()
    );
    std::fs::create_dir_all(&lab.results_dir).ok();
    std::fs::write(lab.results_dir.join("PROVENANCE.txt"), prov)?;
    eprintln!("[suite] everything regenerated in {:.1?}", t0.elapsed());
    Ok(())
}

fn cmd_bench_step(rt: &dyn Backend, args: &Args) -> Result<()> {
    use oscillations_qat::bench::bench_for;
    use oscillations_qat::coordinator::RunCfg;
    let model = args.str_or("model", "mbv2");
    let state = rt.initial_state(&model)?;
    let trainer = Trainer::new(rt);
    let mut cfg = RunCfg::qat(&model, 1, 3, 0);
    cfg.quant_a = true;
    let mut cur = Some(state);
    let stats = bench_for(
        &format!("train_step[{model},lsq,w3a3]"),
        1,
        std::time::Duration::from_secs(10),
        || {
            let s = cur.take().unwrap();
            let out = trainer.train(s, &cfg).expect("step");
            cur = Some(out.state);
        },
    );
    println!("{}", stats.report());
    println!(
        "  = {:.1} samples/s (batch {})",
        stats.per_sec(rt.index().model(&model)?.batch_size as f64),
        rt.index().model(&model)?.batch_size
    );
    Ok(())
}

fn cmd_bench_deploy(args: &Args) -> Result<()> {
    use oscillations_qat::deploy::resolve_threads;
    use oscillations_qat::deploy::trajectory::{
        baseline_from_report, check_regression, run_deploy_microbench,
    };
    use oscillations_qat::json;

    let smoke = args.flag("smoke");
    let threads = resolve_threads(args.get("threads"), 2);
    let mut report = run_deploy_microbench(smoke, threads)?;
    for k in &report.kernels {
        println!("{:<34} {:>14.0} items/s  mean {:>10.0} ns", k.name, k.per_sec, k.mean_ns);
    }

    // streaming -> prepared / 1 -> N-thread deltas, also appended to the
    // GitHub Actions job summary when running in CI
    let speedups = report.speedup_summary();
    if !speedups.is_empty() {
        println!("-- decode-once / threading speedups --\n{speedups}");
        if let Ok(summary_path) = std::env::var("GITHUB_STEP_SUMMARY") {
            let md = format!(
                "### bench-deploy kernel throughput deltas\n\n```\n{speedups}\n```\n"
            );
            if let Err(e) = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&summary_path)
                .and_then(|mut f| std::io::Write::write_all(&mut f, md.as_bytes()))
            {
                eprintln!("[bench-deploy] could not append job summary: {e}");
            }
        }
    }

    // merge the serve smoke bench, when present, into one trajectory file
    if let Some(serve_path) = args.get("serve-json") {
        let text = std::fs::read_to_string(serve_path)
            .map_err(|e| anyhow::anyhow!("read serve report {serve_path}: {e}"))?;
        let parsed = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse serve report {serve_path}: {e}"))?;
        println!(
            "merged serve report: {:.0} req/s",
            parsed.get("throughput_rps").as_f64().unwrap_or(f64::NAN)
        );
        report.merge_serve(parsed);
    }

    // a report that lost its prepared-path kernel rows — or, once the
    // serve report is merged, its serve/HTTP rows — would blind the perf
    // gate; fail before writing anything. (This runs after the merge so
    // the required serve fields are actually validated.)
    let missing = report.missing_required_rows();
    anyhow::ensure!(
        missing.is_empty(),
        "bench-deploy report is missing required rows: {missing:?}"
    );

    let out = PathBuf::from(args.str_or("out", "BENCH_deploy.json"));
    report.write_json(&out)?;
    println!("trajectory report -> {}", out.display());

    // suggested-baseline artifact: this run's numbers with conservative
    // margins, ready to commit as BENCH_baseline.json after eyeballing
    if let Some(path) = args.get("emit-baseline") {
        let suggested = baseline_from_report(&report.to_json(), 0.5, 2.0);
        std::fs::write(path, json::to_string(&suggested))
            .map_err(|e| anyhow::anyhow!("write {path}: {e}"))?;
        println!("suggested baseline (0.5x floors / 2x latency ceilings) -> {path}");
    }

    // regression gate against the committed baseline
    if let Some(baseline_path) = args.get("baseline") {
        let text = std::fs::read_to_string(baseline_path)
            .map_err(|e| anyhow::anyhow!("read baseline {baseline_path}: {e}"))?;
        let baseline = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse baseline {baseline_path}: {e}"))?;
        let max_drop = args.f32_or("max-regress", 0.25) as f64;
        let violations = check_regression(&report.to_json(), &baseline, max_drop)?;
        if violations.is_empty() {
            println!(
                "regression gate: all metrics within {:.0}% of {baseline_path}",
                100.0 * max_drop
            );
        } else {
            for v in &violations {
                eprintln!("REGRESSION {v}");
            }
            anyhow::bail!(
                "{} throughput metric(s) regressed past the {:.0}% floor",
                violations.len(),
                100.0 * max_drop
            );
        }
    }
    Ok(())
}

fn cmd_bench_kernels(rt: &dyn Backend) -> Result<()> {
    use oscillations_qat::bench::bench_for;
    let kernels = rt.index().kernels.clone();
    for (label, artifact_name) in kernels {
        let sig = rt.signature(&artifact_name)?;
        let io = oscillations_qat::bench::kernel_bench_inputs(&sig);
        let stats = bench_for(&label, 2, std::time::Duration::from_secs(3), || {
            let _ = rt.execute(&artifact_name, &[&io]).expect("kernel exec");
        });
        println!("{}", stats.report());
    }
    Ok(())
}
