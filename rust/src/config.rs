//! Experiment configuration: a hand-rolled TOML-subset parser (offline
//! cache has no serde/toml) + typed run configs.
//!
//! Supported grammar — ample for experiment files:
//!   [section]
//!   key = "string" | 123 | 1.5 | true | false | [1, 2, 3]
//!   # comments
//!
//! See `configs/` for the shipped experiment files.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    List(Vec<f64>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// section -> key -> value. The pre-section area is section "".
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: bad section", lineno + 1))?;
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let value = Self::parse_value(v.trim())
                .with_context(|| format!("line {}: bad value {:?}", lineno + 1, v.trim()))?;
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), value);
        }
        Ok(cfg)
    }

    fn parse_value(v: &str) -> Result<Value> {
        if let Some(rest) = v.strip_prefix('"') {
            let s = rest.strip_suffix('"').context("unterminated string")?;
            return Ok(Value::Str(s.to_string()));
        }
        if v == "true" {
            return Ok(Value::Bool(true));
        }
        if v == "false" {
            return Ok(Value::Bool(false));
        }
        if let Some(rest) = v.strip_prefix('[') {
            let inner = rest.strip_suffix(']').context("unterminated list")?;
            let mut out = Vec::new();
            for part in inner.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                out.push(part.parse::<f64>().context("non-numeric list item")?);
            }
            return Ok(Value::List(out));
        }
        if let Ok(n) = v.parse::<f64>() {
            return Ok(Value::Num(n));
        }
        bail!("unparseable value")
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.f64_or(section, key, default as f64) as usize
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment file
name = "table4"

[train]
steps = 400
lr = 0.01          # base learning rate
cosine = true
lambdas = [0.0001, 0.001, 0.01]

[data]
classes = 10
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("", "name", "?"), "table4");
        assert_eq!(c.usize_or("train", "steps", 0), 400);
        assert_eq!(c.f64_or("train", "lr", 0.0), 0.01);
        assert!(c.bool_or("train", "cosine", false));
        assert_eq!(
            c.get("train", "lambdas"),
            Some(&Value::List(vec![0.0001, 0.001, 0.01]))
        );
        assert_eq!(c.usize_or("data", "classes", 0), 10);
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("train", "steps", 7), 7);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("[unclosed").is_err());
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("x = \"unterminated").is_err());
    }
}
