//! Criterion-style micro-bench harness (criterion itself is not in the
//! offline crate cache). Warmup + timed iterations, robust summary stats,
//! and a one-line report format shared by all `benches/*.rs` targets.

use crate::runtime::Signature;
use crate::state::NamedTensors;
use crate::tensor::Tensor;
use std::time::{Duration, Instant};

/// Synthesize a meaningful input set for a kernel artifact from its
/// signature: arrays get a deterministic value sweep, while the grid and
/// state-machine scalars get realistic values (a positive scale, a proper
/// n < p 3-bit grid, a sane EMA momentum and freezing threshold) — a
/// uniform fill would hand the kernels a degenerate one-point grid and a
/// negative threshold, benchmarking paths no training run takes.
pub fn kernel_bench_inputs(sig: &Signature) -> NamedTensors {
    let mut io = NamedTensors::new();
    for spec in &sig.inputs {
        let n: usize = spec.shape.iter().product::<usize>().max(1);
        let data: Vec<f32> = (0..n).map(|i| ((i % 17) as f32 - 8.0) * 0.01).collect();
        io.insert(spec.name.clone(), Tensor::new(spec.shape.clone(), data));
    }
    for (name, v) in [
        ("s", 0.05),
        ("n", -4.0),
        ("p", 3.0),
        ("m", 0.01),
        ("f_th", 1.1),
    ] {
        if io.get(name).is_some() {
            io.insert(name, Tensor::scalar(v));
        }
    }
    io
}

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<38} {:>6} iters  mean {:>10.3?}  p50 {:>10.3?}  p95 {:>10.3?}  min {:>10.3?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.min
        )
    }

    /// throughput given per-iteration item count
    pub fn per_sec(&self, items: f64) -> f64 {
        items / self.mean.as_secs_f64()
    }
}

/// Run `f` for `warmup` unrecorded + `iters` recorded iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    summarize(name, samples)
}

/// Time-budgeted variant: run until `budget` elapsed (at least 3 iters).
pub fn bench_for<F: FnMut()>(name: &str, warmup: usize, budget: Duration, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < 3 || start.elapsed() < budget {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() > 100_000 {
            break;
        }
    }
    summarize(name, samples)
}

fn summarize(name: &str, mut samples: Vec<Duration>) -> BenchStats {
    samples.sort();
    let n = samples.len().max(1);
    let mean = samples.iter().sum::<Duration>() / n as u32;
    let pick = |q: f64| samples[((n - 1) as f64 * q) as usize];
    BenchStats {
        name: name.to_string(),
        iters: samples.len(),
        mean,
        p50: pick(0.5),
        p95: pick(0.95),
        min: *samples.first().unwrap_or(&Duration::ZERO),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_stats() {
        let mut x = 0u64;
        let s = bench("noop", 2, 50, || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert_eq!(s.iters, 50);
        assert!(s.min <= s.p50 && s.p50 <= s.p95);
    }

    #[test]
    fn budgeted_runs_at_least_three() {
        let s = bench_for("fast", 0, Duration::from_millis(1), || {});
        assert!(s.iters >= 3);
    }
}
