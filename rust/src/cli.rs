//! Hand-rolled CLI argument parser (no clap in the offline crate cache).
//!
//! Grammar: `prog <subcommand> [--key value] [--key=value] [--flag]`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.options.insert(key.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u32_or(&self, key: &str, default: u32) -> u32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// comma-separated u64 list
    pub fn u64_list_or(&self, key: &str, default: &[u64]) -> Vec<u64> {
        self.get(key)
            .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
            .unwrap_or_else(|| default.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --model mbv2 --steps=400 --trace");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("mbv2"));
        assert_eq!(a.u64_or("steps", 0), 400);
        assert!(a.flag("trace"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn negative_number_values() {
        let a = parse("toy --w0 -0.3");
        // "-0.3" does not start with -- so it is consumed as the value
        assert_eq!(a.f32_or("w0", 0.0), -0.3);
    }

    #[test]
    fn lists() {
        let a = parse("x --seeds 0,1,2");
        assert_eq!(a.u64_list_or("seeds", &[9]), vec![0, 1, 2]);
        assert_eq!(parse("x").u64_list_or("seeds", &[9]), vec![9]);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.str_or("x", "d"), "d");
    }
}
