//! Hand-rolled CLI argument parser (no clap in the offline crate cache).
//!
//! Grammar: `prog <subcommand> [--key value] [--key=value] [--flag] [--] [positional...]`
//!
//! Disambiguation rules (the part a naive splitter gets wrong):
//!
//! * **Boolean flags are declared.** A `--key` in [`BOOL_FLAGS`] never
//!   consumes the next token, so `eval --fp ckpt.qtns` keeps `ckpt.qtns`
//!   positional instead of parsing `fp = "ckpt.qtns"`. Unknown `--key`s
//!   take a value when one follows (`--lr 0.01`) and default to `"true"`
//!   otherwise.
//! * **Negative numbers are values, not options.** Only `--`-prefixed
//!   tokens start an option, so `--lr -0.1` and `--w0 -0.3` parse as
//!   values; a bare `-0.3` with no pending key is positional.
//! * **`--` ends option parsing**: every later token is treated as plain
//!   text, even if it looks like an option (the first plain token seen
//!   overall still fills the subcommand slot).
//! * `--key=value` always binds, including `--quick=false` overrides of
//!   declared flags and values containing `=`.

use std::collections::BTreeMap;

/// Options that never take a value. Kept in sync with the `args.flag()`
/// call sites in `main.rs` — the `bool_flags_match_main_rs_call_sites`
/// test below enforces both directions, so a new flag can't silently
/// eat a positional.
pub const BOOL_FLAGS: &[&str] = &[
    "quick",
    "fp",
    "quant-a",
    "smoke",
    "exact",
    "per-channel",
    "per-tensor",
    "streaming",
    "no-http",
    "no-fleet",
    "layer-timing",
];

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    /// every `--key value` binding in argv order, duplicates included —
    /// the map above keeps last-wins semantics, this keeps repeatable
    /// options (`--model a=x.qpkg --model b=y.qpkg`)
    pub occurrences: Vec<(String, String)>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        Self::parse_with_flags(argv, BOOL_FLAGS)
    }

    /// Parse with an explicit boolean-flag registry (tests and embedders
    /// with a different flag set).
    pub fn parse_with_flags(
        argv: impl IntoIterator<Item = String>,
        bool_flags: &[&str],
    ) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        let mut opts_done = false;
        while let Some(arg) = iter.next() {
            if !opts_done && arg == "--" {
                opts_done = true;
                continue;
            }
            if !opts_done {
                if let Some(key) = arg.strip_prefix("--") {
                    let (k, v) = if let Some((k, v)) = key.split_once('=') {
                        (k.to_string(), v.to_string())
                    } else if bool_flags.contains(&key) {
                        (key.to_string(), "true".to_string())
                    } else if iter
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false)
                    {
                        (key.to_string(), iter.next().unwrap())
                    } else {
                        (key.to_string(), "true".to_string())
                    };
                    out.options.insert(k.clone(), v.clone());
                    out.occurrences.push((k, v));
                    continue;
                }
            }
            if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Every value bound to `key`, in argv order (repeatable options:
    /// `--model a=x.qpkg --model b=y.qpkg` yields both bindings, where
    /// [`Args::get`] would only see the last).
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.occurrences
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u32_or(&self, key: &str, default: u32) -> u32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// comma-separated u64 list
    pub fn u64_list_or(&self, key: &str, default: &[u64]) -> Vec<u64> {
        self.get(key)
            .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
            .unwrap_or_else(|| default.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --model mbv2 --steps=400 --trace");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("mbv2"));
        assert_eq!(a.u64_or("steps", 0), 400);
        assert!(a.flag("trace"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn negative_number_values() {
        // "-0.3" does not start with -- so it is consumed as the value
        let a = parse("toy --w0 -0.3");
        assert_eq!(a.f32_or("w0", 0.0), -0.3);
        // same through the = form, and for integers
        let a = parse("toy --lr=-0.1 --shift -2");
        assert_eq!(a.f32_or("lr", 0.0), -0.1);
        assert_eq!(a.get("shift"), Some("-2"));
    }

    #[test]
    fn declared_flag_does_not_eat_a_positional() {
        // --fp is a declared boolean flag: the token after it stays
        // positional instead of becoming fp's value
        let a = parse("eval --fp ckpts/run.qtns");
        assert!(a.flag("fp"));
        assert_eq!(a.positional, vec!["ckpts/run.qtns".to_string()]);
        // ... and a declared flag right before another option still works
        let a = parse("train --quant-a --steps 5");
        assert!(a.flag("quant-a"));
        assert_eq!(a.u64_or("steps", 0), 5);
    }

    #[test]
    fn declared_flag_accepts_explicit_value() {
        let a = parse("suite --quick=false");
        assert!(!a.flag("quick"));
        let a = parse("suite --quick");
        assert!(a.flag("quick"));
    }

    #[test]
    fn undeclared_trailing_key_defaults_to_true() {
        let a = parse("train --verbose");
        assert!(a.flag("verbose"));
        let a = parse("train --verbose --steps 3");
        assert!(a.flag("verbose"));
        assert_eq!(a.u64_or("steps", 0), 3);
    }

    #[test]
    fn double_dash_ends_options() {
        let a = parse("run --steps 2 -- --not-an-option -0.5");
        assert_eq!(a.u64_or("steps", 0), 2);
        assert_eq!(
            a.positional,
            vec!["--not-an-option".to_string(), "-0.5".to_string()]
        );
        // the subcommand slot is just the first plain token; `--` only
        // stops option recognition
        let a = parse("-- run --x");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["--x".to_string()]);
        assert!(a.options.is_empty());
    }

    #[test]
    fn equals_value_may_contain_equals() {
        let a = parse("train --lam=cos(0,1e-2)=x");
        assert_eq!(a.get("lam"), Some("cos(0,1e-2)=x"));
    }

    #[test]
    fn custom_flag_registry() {
        let argv = ["go", "--dry-run", "target"].iter().map(|s| s.to_string());
        let a = Args::parse_with_flags(argv, &["dry-run"]);
        assert!(a.flag("dry-run"));
        assert_eq!(a.positional, vec!["target".to_string()]);
    }

    #[test]
    fn usize_values() {
        let a = parse("serve --threads 4");
        assert_eq!(a.usize_or("threads", 1), 4);
        assert_eq!(a.usize_or("missing", 2), 2);
        // declared flags still keep the next token positional
        let a = parse("serve --streaming m.qpkg --threads 3");
        assert!(a.flag("streaming"));
        assert_eq!(a.usize_or("threads", 1), 3);
        assert_eq!(a.positional, vec!["m.qpkg".to_string()]);
    }

    #[test]
    fn layer_timing_is_a_flag_and_telemetry_takes_a_value() {
        // --layer-timing must not eat the qpkg positional; --telemetry
        // is a valued option, not a declared flag
        let a = parse("serve --layer-timing m.qpkg --telemetry run.jsonl");
        assert!(a.flag("layer-timing"));
        assert_eq!(a.get("telemetry"), Some("run.jsonl"));
        assert_eq!(a.positional, vec!["m.qpkg".to_string()]);
    }

    #[test]
    fn repeatable_options_keep_every_occurrence() {
        let a = parse("serve --model a=x.qpkg --model b=y.qpkg --mem-budget-mb 64");
        assert_eq!(a.get_all("model"), vec!["a=x.qpkg", "b=y.qpkg"]);
        // the map keeps last-wins for single-value readers
        assert_eq!(a.get("model"), Some("b=y.qpkg"));
        assert_eq!(a.u64_or("mem-budget-mb", 0), 64);
        // = form and valued form mix; flags don't pollute occurrences of
        // other keys
        let a = parse("serve --model=a=x.qpkg --no-fleet --model b=y.qpkg");
        assert_eq!(a.get_all("model"), vec!["a=x.qpkg", "b=y.qpkg"]);
        assert!(a.flag("no-fleet"));
        assert_eq!(a.get_all("missing"), Vec::<&str>::new());
    }

    #[test]
    fn lists() {
        let a = parse("x --seeds 0,1,2");
        assert_eq!(a.u64_list_or("seeds", &[9]), vec![0, 1, 2]);
        assert_eq!(parse("x").u64_list_or("seeds", &[9]), vec![9]);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.str_or("x", "d"), "d");
    }

    /// Enforce the "keep in sync with main.rs" comment on [`BOOL_FLAGS`]:
    /// every flag consumed via `args.flag("...")` in main.rs must be
    /// declared, and every declared flag must have a call site. An
    /// undeclared flag would silently eat the next positional argument
    /// (`serve --no-http m.qpkg` parsing `no-http = "m.qpkg"`).
    #[test]
    fn bool_flags_match_main_rs_call_sites() {
        let main_src = include_str!("main.rs");
        let mut consumed: Vec<&str> = Vec::new();
        let needle = ".flag(\"";
        for (at, _) in main_src.match_indices(needle) {
            let rest = &main_src[at + needle.len()..];
            let end = rest.find('"').expect("unterminated .flag(\" literal in main.rs");
            let name = &rest[..end];
            if !consumed.contains(&name) {
                consumed.push(name);
            }
        }
        assert!(
            !consumed.is_empty(),
            "found no .flag(\"...\") call sites in main.rs — did the scan break?"
        );
        for name in &consumed {
            assert!(
                BOOL_FLAGS.contains(name),
                "main.rs consumes --{name} via args.flag() but BOOL_FLAGS does not \
                 declare it; the parser would let --{name} eat the next positional"
            );
        }
        for name in BOOL_FLAGS {
            assert!(
                consumed.contains(name),
                "BOOL_FLAGS declares --{name} but main.rs never consumes it via \
                 args.flag(); remove it or wire it up"
            );
        }
    }
}
