//! Synthetic image corpus + batch pipeline (the ImageNet substitute).
//!
//! The paper's phenomena (STE-induced weight oscillations, corrupted BN
//! statistics) are properties of low-bit grids + depthwise layers near
//! convergence, not of the dataset, so a deterministic synthetic corpus
//! exercises the same dynamics (DESIGN.md §3). Each class gets a
//! structured prototype — a mixture of oriented sinusoids and a Gaussian
//! blob with a per-class channel mix — and each sample is the prototype
//! under a random translation, amplitude jitter and pixel noise. The task
//! is learnable but non-trivial: FP accuracy saturates well below 100%.
//!
//! The pipeline generates train batches on the fly on a background
//! producer thread (bounded channel, so the step loop never blocks on
//! data), while the validation set is materialized once, deterministically.

use crate::rng::Pcg32;
use crate::tensor::Tensor;
use std::sync::mpsc;

/// Corpus configuration.
#[derive(Debug, Clone)]
pub struct DataCfg {
    pub num_classes: usize,
    pub hw: usize,
    pub batch: usize,
    pub seed: u64,
    /// pixel noise stddev; higher = harder task
    pub noise: f32,
    /// max |translation| in pixels
    pub max_shift: i32,
    pub val_size: usize,
}

impl Default for DataCfg {
    fn default() -> Self {
        DataCfg {
            num_classes: 10,
            hw: 16,
            batch: 16,
            seed: 0,
            noise: 2.0,
            max_shift: 2,
            val_size: 1024,
        }
    }
}

/// One batch: x (B, H, W, 3) and one-hot y (B, C).
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Tensor,
    pub y: Tensor,
    pub labels: Vec<usize>,
}

/// Per-class prototype parameters.
struct Proto {
    /// (freq_y, freq_x, phase, amp) per sinusoid
    waves: Vec<(f32, f32, f32, f32)>,
    /// (cy, cx, sigma, amp) blob
    blob: (f32, f32, f32, f32),
    /// channel mixing weights, 3 per component source
    mix: [[f32; 2]; 3],
}

/// Deterministic synthetic dataset.
pub struct Dataset {
    pub cfg: DataCfg,
    protos: Vec<Proto>,
}

impl Dataset {
    pub fn new(cfg: DataCfg) -> Self {
        let mut protos = Vec::with_capacity(cfg.num_classes);
        for c in 0..cfg.num_classes {
            // Class stream is independent of the sampling stream so the
            // same classes exist across seeds (only sampling varies).
            let mut r = Pcg32::new(1000 + c as u64, 77);
            let waves = (0..3)
                .map(|_| {
                    (
                        r.uniform(0.5, 3.0),
                        r.uniform(0.5, 3.0),
                        r.uniform(0.0, std::f32::consts::TAU),
                        r.uniform(0.4, 1.0),
                    )
                })
                .collect();
            let blob = (
                r.uniform(0.25, 0.75),
                r.uniform(0.25, 0.75),
                r.uniform(0.1, 0.25),
                r.uniform(0.6, 1.2),
            );
            let mut mix = [[0.0f32; 2]; 3];
            for ch in &mut mix {
                ch[0] = r.uniform(-1.0, 1.0);
                ch[1] = r.uniform(-1.0, 1.0);
            }
            protos.push(Proto { waves, blob, mix });
        }
        Dataset { cfg, protos }
    }

    /// Render one sample of class `c` into `out` (H*W*3, NHWC layout).
    fn render(&self, c: usize, r: &mut Pcg32, out: &mut [f32]) {
        let hw = self.cfg.hw;
        let p = &self.protos[c];
        let dy = r.below((2 * self.cfg.max_shift + 1) as usize) as i32
            - self.cfg.max_shift;
        let dx = r.below((2 * self.cfg.max_shift + 1) as usize) as i32
            - self.cfg.max_shift;
        let amp = r.uniform(0.8, 1.2);
        let tau = std::f32::consts::TAU;
        for y in 0..hw {
            for x in 0..hw {
                let fy = ((y as i32 + dy).rem_euclid(hw as i32)) as f32 / hw as f32;
                let fx = ((x as i32 + dx).rem_euclid(hw as i32)) as f32 / hw as f32;
                let mut wave = 0.0;
                for &(ky, kx, ph, a) in &p.waves {
                    wave += a * (tau * (ky * fy + kx * fx) + ph).sin();
                }
                let (cy, cx, sg, ba) = p.blob;
                let d2 = (fy - cy) * (fy - cy) + (fx - cx) * (fx - cx);
                let blob = ba * (-d2 / (2.0 * sg * sg)).exp();
                let base = (y * hw + x) * 3;
                for ch in 0..3 {
                    let v = p.mix[ch][0] * wave + p.mix[ch][1] * blob;
                    out[base + ch] = amp * v + self.cfg.noise * r.normal();
                }
            }
        }
    }

    fn make_batch(&self, r: &mut Pcg32) -> Batch {
        let (b, hw, nc) = (self.cfg.batch, self.cfg.hw, self.cfg.num_classes);
        let mut x = vec![0.0f32; b * hw * hw * 3];
        let mut y = vec![0.0f32; b * nc];
        let mut labels = Vec::with_capacity(b);
        for i in 0..b {
            let c = r.below(nc);
            labels.push(c);
            y[i * nc + c] = 1.0;
            self.render(c, r, &mut x[i * hw * hw * 3..(i + 1) * hw * hw * 3]);
        }
        Batch {
            x: Tensor::new(vec![b, hw, hw, 3], x),
            y: Tensor::new(vec![b, nc], y),
            labels,
        }
    }

    /// The `i`-th training batch for `seed` — pure function of (seed, i).
    pub fn train_batch(&self, seed: u64, i: u64) -> Batch {
        let mut r = Pcg32::new(self.cfg.seed ^ seed, 0x5eed_0000 + i);
        self.make_batch(&mut r)
    }

    /// Deterministic validation set, independent of the train stream.
    pub fn val_batches(&self) -> Vec<Batch> {
        let n = self.cfg.val_size / self.cfg.batch;
        (0..n)
            .map(|i| {
                let mut r = Pcg32::new(self.cfg.seed, 0x7a1_0000 + i as u64);
                self.make_batch(&mut r)
            })
            .collect()
    }
}

/// Background-producer batch stream with bounded prefetch.
pub struct Loader {
    rx: mpsc::Receiver<Batch>,
    _handle: std::thread::JoinHandle<()>,
}

impl Loader {
    /// Spawn a producer generating `train_batch(seed, 0..)` with `depth`
    /// batches of lookahead. Generation overlaps the PJRT step.
    pub fn new(ds: Dataset, seed: u64, depth: usize) -> Self {
        let (tx, rx) = mpsc::sync_channel(depth);
        let handle = std::thread::spawn(move || {
            let mut i = 0u64;
            loop {
                let b = ds.train_batch(seed, i);
                if tx.send(b).is_err() {
                    return; // consumer dropped
                }
                i += 1;
            }
        });
        Loader { rx, _handle: handle }
    }

    pub fn next(&self) -> Batch {
        self.rx.recv().expect("data producer died")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_deterministic() {
        let ds = Dataset::new(DataCfg::default());
        let a = ds.train_batch(1, 5);
        let b = ds.train_batch(1, 5);
        assert_eq!(a.x.data, b.x.data);
        assert_eq!(a.labels, b.labels);
        let c = ds.train_batch(2, 5);
        assert_ne!(a.x.data, c.x.data);
    }

    #[test]
    fn one_hot_consistent() {
        let ds = Dataset::new(DataCfg::default());
        let b = ds.train_batch(0, 0);
        for (i, &c) in b.labels.iter().enumerate() {
            let row = &b.y.data[i * 10..(i + 1) * 10];
            assert_eq!(row[c], 1.0);
            assert_eq!(row.iter().sum::<f32>(), 1.0);
        }
    }

    #[test]
    fn val_set_shape_and_determinism() {
        let ds = Dataset::new(DataCfg { val_size: 64, ..Default::default() });
        let v1 = ds.val_batches();
        let v2 = ds.val_batches();
        assert_eq!(v1.len(), 4);
        assert_eq!(v1[0].x.shape, vec![16, 16, 16, 3]);
        assert_eq!(v1[3].x.data, v2[3].x.data);
    }

    #[test]
    fn classes_are_distinguishable() {
        // mean inter-class L2 distance must exceed intra-class distance
        // (shift augmentation off so only noise separates same-class pairs)
        let ds = Dataset::new(DataCfg { noise: 0.1, max_shift: 0, ..Default::default() });
        let mut r = Pcg32::new(9, 9);
        let mut render = |c: usize, r: &mut Pcg32| {
            let mut buf = vec![0.0; 16 * 16 * 3];
            ds.render(c, r, &mut buf);
            buf
        };
        let a1 = render(0, &mut r);
        let a2 = render(0, &mut r);
        let b1 = render(1, &mut r);
        let d = |x: &[f32], y: &[f32]| -> f32 {
            x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum()
        };
        assert!(d(&a1, &b1) > d(&a1, &a2));
    }

    #[test]
    fn loader_streams() {
        let ds = Dataset::new(DataCfg { val_size: 32, ..Default::default() });
        let expect = ds.train_batch(3, 0);
        let loader = Loader::new(ds, 3, 2);
        let got = loader.next();
        assert_eq!(got.x.data, expect.x.data);
        let _ = loader.next(); // stream continues
    }
}
