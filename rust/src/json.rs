//! Minimal JSON parser/writer substrate.
//!
//! The offline crate cache has no serde, so the coordinator carries its own
//! small JSON implementation for the artifact manifests (`*.manifest.json`,
//! `index.json`), the JSONL metrics sink, and the HTTP serving codec. It
//! supports the full JSON grammar except exotic number forms; strings
//! handle the standard escape set plus `\uXXXX` including surrogate
//! pairs (astral-plane characters arrive from real HTTP clients), and
//! lone surrogates are rejected as parse errors.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic — handy for golden tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["k1"]["k2"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError { pos: self.pos, msg: msg.to_string() })
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected {:?}", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected {s}"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(JsonError { pos: start, msg: "bad number".into() })
    }

    /// Read 4 hex digits at byte offset `at`.
    fn hex4(&self, at: usize) -> Result<u32, JsonError> {
        if at + 4 > self.b.len() {
            return Err(JsonError { pos: self.pos, msg: "bad \\u escape".into() });
        }
        let hex = &self.b[at..at + 4];
        if !hex.iter().all(|c| c.is_ascii_hexdigit()) {
            return Err(JsonError { pos: at, msg: "bad \\u escape".into() });
        }
        let s = std::str::from_utf8(hex).expect("ascii hex digits");
        Ok(u32::from_str_radix(s, 16).expect("validated hex"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4(self.pos + 1)?;
                            if (0xD800..0xDC00).contains(&cp) {
                                // high surrogate: a low-surrogate escape
                                // must follow immediately
                                let next = self.pos + 5;
                                if self.b.get(next) != Some(&b'\\')
                                    || self.b.get(next + 1) != Some(&b'u')
                                {
                                    return self.err("unpaired surrogate");
                                }
                                let lo = self.hex4(next + 2)?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return self.err("unpaired surrogate");
                                }
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                out.push(char::from_u32(c).expect("valid astral scalar"));
                                // land on the pair's last hex digit; the
                                // shared += 1 below steps past it
                                self.pos = next + 5;
                            } else if (0xDC00..0xE000).contains(&cp) {
                                return self.err("unpaired surrogate");
                            } else {
                                out.push(char::from_u32(cp).expect("non-surrogate BMP scalar"));
                                self.pos += 4;
                            }
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes at once
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos]).map_err(|_| {
                            JsonError { pos: start, msg: "invalid utf8".into() }
                        })?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return self.err("expected , or ]"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return self.err("expected , or }"),
            }
        }
    }
}

/// Decode the JSON string token starting at byte `at` of `b` (which
/// must be an opening quote). Returns the decoded string and the offset
/// one past the closing quote — the hook the lazy HTTP request codec
/// uses to decode a single field without parsing the whole document.
pub(crate) fn decode_str_at(b: &[u8], at: usize) -> Result<(String, usize), JsonError> {
    let mut p = Parser { b, pos: at };
    let s = p.string()?;
    Ok((s, p.pos))
}

/// Parse a complete JSON document.
pub fn parse(s: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: s.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

/// Serialize a value (compact form).
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_value(&mut s, v);
    s
}

fn write_value(out: &mut String, v: &Json) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => write_str(out, s),
        Json::Arr(a) => {
            out.push('[');
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(out, e);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, e)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(out, k);
                out.push(':');
                write_value(out, e);
            }
            out.push('}');
        }
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let j = parse(r#"{"name":"x","inputs":[{"shape":[2,3],"dtype":"f32"}]}"#)
            .unwrap();
        assert_eq!(j.get("name").as_str(), Some("x"));
        let inputs = j.get("inputs").as_arr().unwrap();
        let dims: Vec<usize> = inputs[0]
            .get("shape")
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        assert_eq!(dims, vec![2, 3]);
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"a":[1,2.5,-3],"b":"hi\nthere","c":true,"d":null,"e":{}}"#;
        let j = parse(src).unwrap();
        let j2 = parse(&to_string(&j)).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("[1] x").is_err());
    }

    #[test]
    fn escapes() {
        let j = parse("\"a\\u0041b\"").unwrap();
        assert_eq!(j.as_str(), Some("aAb"));
    }

    /// Regression: surrogate escape pairs used to decode as two U+FFFD
    /// replacement characters instead of the astral-plane scalar.
    #[test]
    fn surrogate_pairs_decode_to_astral_chars() {
        let j = parse("\"\\ud83d\\ude00\"").unwrap();
        assert_eq!(j.as_str(), Some("😀"));
        let j = parse("\"x\\ud834\\udd1ey\"").unwrap();
        assert_eq!(j.as_str(), Some("x𝄞y")); // U+1D11E musical G clef
    }

    #[test]
    fn lone_surrogates_are_parse_errors() {
        for src in [
            "\"\\ud83d\"",          // lone high
            "\"\\ude00\"",          // lone low
            "\"\\ud83d \\ude00\"",  // pair split by a space
            "\"\\ud83dx\"",         // high followed by plain text
            "\"\\ud83d\\u0041\"",   // high followed by a BMP escape
            "\"\\ud83d\\ud83d\"",   // high followed by another high
        ] {
            let err = parse(src).expect_err(src);
            assert!(err.msg.contains("unpaired surrogate"), "{src}: {err}");
        }
    }

    #[test]
    fn astral_strings_roundtrip() {
        let j = Json::Str("naïve 😀 𝄞 text".to_string());
        let j2 = parse(&to_string(&j)).unwrap();
        assert_eq!(j, j2);
        // and via an object value, as the serving codec sees them
        let src = "{\"model\":\"\\ud83d\\ude00net\"}";
        let j = parse(src).unwrap();
        assert_eq!(j.get("model").as_str(), Some("😀net"));
        let j2 = parse(&to_string(&j)).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn decode_str_at_reports_end_offset() {
        let b = br#"{"k": "a\u0041\ud83d\ude00" , "z": 1}"#;
        let at = 6; // opening quote of the value
        let (s, end) = decode_str_at(b, at).unwrap();
        assert_eq!(s, "aA😀");
        assert_eq!(b[end - 1], b'"');
        assert_eq!(&b[end..end + 2], b" ,");
    }
}
