//! AdaRound-flavoured binary optimization of oscillating weights (Table 3).
//!
//! After a converged QAT run, every oscillating weight sits between two
//! adjacent integer states (w_down, w_up). The paper optimizes this binary
//! assignment on the final task loss "akin to ... simulated annealing to
//! solve binary optimization problems" (§2.3.2). This module implements
//! exactly that: Metropolis simulated annealing over per-weight up/down
//! bits, with the loss evaluated through the compiled eval artifact on a
//! fixed set of training batches.
//!
//! Scales may be per-tensor (scalar `params/{layer}.s`) or **per-channel**
//! (`[d_out]` vectors): each candidate resolves and carries *its own
//! channel's* step size at collection time (`osc::scale_for` applies the
//! `kernels::scale_index` layout rule — dense `[d_in, d_out]` columns vs
//! depthwise `[C, 3]` rows), so Table-3 assignments land every latent on
//! its channel's grid.

use crate::osc::scale_for;
use crate::rng::Pcg32;
use crate::state::NamedTensors;
use crate::tensor::round_ties_even;
use anyhow::Result;

/// One binary decision variable: an oscillating weight and its two states.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// state key of the weight tensor (e.g. "params/b3.dw.w")
    pub tensor: String,
    pub index: usize,
    /// lower integer state
    pub down: f32,
    /// current assignment (false = down, true = up)
    pub up: bool,
    /// probability weight spent in the up state (from the integer EMA)
    pub p_up: f32,
    /// this element's LSQ step size (its channel's, when per-channel)
    pub scale: f32,
}

/// Collect oscillating-weight candidates from a trained state.
///
/// A weight qualifies if its tracked oscillation frequency exceeds
/// `f_threshold`. Its two states bracket the integer EMA; the current
/// assignment is read from the latent weight on its channel's grid.
pub fn collect_candidates(
    state: &NamedTensors,
    lowbit: &[String],
    scale_of: impl Fn(&str) -> String,
    f_threshold: f32,
    n: f32,
    p: f32,
) -> Vec<Candidate> {
    let mut out = Vec::new();
    for name in lowbit {
        let (Some(w), Some(f), Some(iema)) = (
            state.get(&format!("params/{name}")),
            state.get(&format!("osc/{name}#f")),
            state.get(&format!("osc/{name}#iema")),
        ) else {
            continue;
        };
        // scalar (per-tensor) or [d_out] (per-channel) step sizes
        let scales: Vec<f32> = state
            .get(&format!("params/{}", scale_of(name)))
            .map(|t| t.data.clone())
            .unwrap_or_else(|| vec![1.0]);
        for i in 0..w.len() {
            if f.data[i] <= f_threshold {
                continue;
            }
            let s = scale_for(&w.shape, &scales, i);
            let ema = iema.data[i];
            let down = ema.floor().clamp(n, p - 1.0);
            let cur = round_ties_even(w.data[i] / s).clamp(n, p);
            let p_up = (ema - down).clamp(0.0, 1.0);
            out.push(Candidate {
                tensor: format!("params/{name}"),
                index: i,
                down,
                up: cur > down + 0.5,
                p_up,
                scale: s,
            });
        }
    }
    out
}

/// Write an assignment into a copy of the state: each latent weight moves
/// to the chosen grid point **on its own channel's grid** (`c.scale`), so
/// the graph's (per-tensor or per-channel) fake-quant reproduces it
/// exactly.
pub fn apply_assignment(state: &mut NamedTensors, cands: &[Candidate]) {
    for c in cands {
        let int = if c.up { c.down + 1.0 } else { c.down };
        if let Some(t) = state.map.get_mut(&c.tensor) {
            t.data[c.index] = c.scale * int;
        }
    }
}

/// Simulated-annealing config.
#[derive(Debug, Clone)]
pub struct AnnealCfg {
    pub iters: usize,
    pub t0: f64,
    pub t_end: f64,
    pub seed: u64,
    /// bits flipped per proposal
    pub flips: usize,
}

impl Default for AnnealCfg {
    fn default() -> Self {
        AnnealCfg { iters: 400, t0: 5e-3, t_end: 1e-5, seed: 0, flips: 4 }
    }
}

/// Metropolis annealing over the candidate bits. `loss` evaluates the task
/// loss for an assignment (the caller owns the eval artifact + batches).
/// Returns (best assignment, best loss, loss trace).
pub fn anneal(
    cands: &mut Vec<Candidate>,
    cfg: &AnnealCfg,
    mut loss: impl FnMut(&[Candidate]) -> Result<f64>,
) -> Result<(Vec<Candidate>, f64, Vec<f64>)> {
    let mut rng = Pcg32::new(cfg.seed, 0xada);
    let mut cur_loss = loss(cands)?;
    let mut best = cands.clone();
    let mut best_loss = cur_loss;
    let mut trace = vec![cur_loss];
    if cands.is_empty() {
        return Ok((best, best_loss, trace));
    }
    for it in 0..cfg.iters {
        let frac = it as f64 / cfg.iters.max(1) as f64;
        let t = cfg.t0 * (cfg.t_end / cfg.t0).powf(frac);
        // propose: flip a few random bits
        let mut flipped = Vec::with_capacity(cfg.flips);
        for _ in 0..cfg.flips {
            let i = rng.below(cands.len());
            cands[i].up = !cands[i].up;
            flipped.push(i);
        }
        let new_loss = loss(cands)?;
        let accept = new_loss <= cur_loss
            || (rng.next_f32() as f64) < ((cur_loss - new_loss) / t).exp();
        if accept {
            cur_loss = new_loss;
            if new_loss < best_loss {
                best_loss = new_loss;
                best = cands.clone();
            }
        } else {
            for &i in flipped.iter().rev() {
                cands[i].up = !cands[i].up;
            }
        }
        trace.push(cur_loss);
    }
    Ok((best, best_loss, trace))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn toy_state() -> (NamedTensors, Vec<String>) {
        let mut s = NamedTensors::new();
        s.insert("params/l.w", Tensor::new(vec![4], vec![0.1, 0.0, 0.25, -0.3]));
        s.insert("params/l.s", Tensor::scalar(0.1));
        s.insert(
            "osc/l.w#f",
            Tensor::new(vec![4], vec![0.05, 0.0, 0.06, 0.0]),
        );
        s.insert(
            "osc/l.w#iema",
            Tensor::new(vec![4], vec![0.7, 0.0, 2.4, -3.0]),
        );
        (s, vec!["l.w".to_string()])
    }

    fn scale_name(n: &str) -> String {
        format!("{}.s", &n[..n.len() - 2])
    }

    #[test]
    fn collects_only_oscillating() {
        let (s, lb) = toy_state();
        let c = collect_candidates(&s, &lb, scale_name, 0.02, -4.0, 3.0);
        assert_eq!(c.len(), 2);
        assert_eq!(c[0].index, 0);
        assert_eq!(c[0].down, 0.0);
        assert!((c[0].p_up - 0.7).abs() < 1e-6);
        assert!(c[0].up); // latent 0.1/0.1 = 1 > 0.5
        assert_eq!(c[0].scale, 0.1);
        assert_eq!(c[1].index, 2);
        assert_eq!(c[1].down, 2.0);
    }

    #[test]
    fn anneal_finds_planted_optimum() {
        // loss = number of bits that differ from a planted pattern
        let mut cands: Vec<Candidate> = (0..12)
            .map(|i| Candidate {
                tensor: "params/x".into(),
                index: i,
                down: 0.0,
                up: false,
                p_up: 0.5,
                scale: 0.1,
            })
            .collect();
        let target: Vec<bool> = (0..12).map(|i| i % 3 == 0).collect();
        let cfg = AnnealCfg { iters: 600, seed: 3, flips: 2, ..Default::default() };
        let (best, best_loss, _) = anneal(&mut cands, &cfg, |cs| {
            Ok(cs.iter().zip(&target).filter(|(c, t)| c.up != **t).count() as f64)
        })
        .unwrap();
        assert_eq!(best_loss, 0.0, "{best:?}");
    }

    #[test]
    fn apply_assignment_moves_latents() {
        let (mut s, lb) = toy_state();
        let mut c = collect_candidates(&s, &lb, scale_name, 0.02, -4.0, 3.0);
        c[0].up = false;
        apply_assignment(&mut s, &c);
        assert!((s.get("params/l.w").unwrap().data[0] - 0.0).abs() < 1e-6);
    }

    #[test]
    fn per_channel_candidates_carry_their_channels_scale() {
        // depthwise-shaped [2, 3] weights with per-channel scales: row 0
        // on s = 0.1, row 1 on s = 1.0; every element oscillates
        let mut s = NamedTensors::new();
        s.insert(
            "params/d.w",
            Tensor::new(vec![2, 3], vec![0.1, 0.2, -0.1, 1.0, 2.0, -1.0]),
        );
        s.insert("params/d.s", Tensor::new(vec![2], vec![0.1, 1.0]));
        s.insert("osc/d.w#f", Tensor::new(vec![2, 3], vec![0.9; 6]));
        s.insert(
            "osc/d.w#iema",
            Tensor::new(vec![2, 3], vec![1.3, 2.3, -1.3, 1.3, 2.3, -1.3]),
        );
        let lb = vec!["d.w".to_string()];
        let cands = collect_candidates(&s, &lb, scale_name, 0.02, -4.0, 3.0);
        assert_eq!(cands.len(), 6);
        for c in &cands[..3] {
            assert_eq!(c.scale, 0.1, "row 0 uses channel 0's scale");
        }
        for c in &cands[3..] {
            assert_eq!(c.scale, 1.0, "row 1 uses channel 1's scale");
        }
        // rows see the same latent pattern on their own grids, so the
        // up/down reads agree across channels
        for (a, b) in cands[..3].iter().zip(&cands[3..]) {
            assert_eq!(a.up, b.up);
            assert_eq!(a.down, b.down);
        }
        // applying an assignment lands each latent on its channel's grid
        let mut assigned = cands.clone();
        for (i, c) in assigned.iter_mut().enumerate() {
            c.up = i % 2 == 0;
        }
        apply_assignment(&mut s, &assigned);
        let w = s.get("params/d.w").unwrap().clone();
        for (c, got) in assigned.iter().zip(&w.data) {
            let int = if c.up { c.down + 1.0 } else { c.down };
            assert_eq!(*got, c.scale * int, "index {}", c.index);
        }
    }
}
