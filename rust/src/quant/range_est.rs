//! MSE range estimation for quantization-scale initialization (§5.1).
//!
//! The paper instantiates weight/activation quantization parameters with
//! MSE range estimation before QAT. For weights we grid-search the scale
//! minimizing the squared quantization error; for activations we use the
//! LSQ heuristic s = 2 * E|x| / sqrt(p) seeded from the calibration
//! forward pass (bnstats artifact).

use super::quant_mse;

/// Number of scale candidates in the grid search.
const CANDIDATES: usize = 60;

/// Best per-tensor scale for grid [n, p] by MSE grid search over
/// fractions of the absmax-implied scale.
pub fn mse_weight_scale(w: &[f32], n: f32, p: f32) -> f32 {
    let absmax = w.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    if absmax == 0.0 {
        return 1e-4;
    }
    let s_max = absmax / p.max(-n); // scale covering the full range
    // the full-range scale is always a candidate so the search can never
    // return something worse than the naive absmax init
    let mut best = (quant_mse(w, s_max, n, p), s_max);
    for i in 0..CANDIDATES {
        let frac = 0.2 + 1.0 * (i as f32 / (CANDIDATES - 1) as f32);
        let s = (s_max * frac).max(1e-6);
        let mse = quant_mse(w, s, n, p);
        if mse < best.0 {
            best = (mse, s);
        }
    }
    best.1
}

/// Per-channel MSE scales: element `i` belongs to channel
/// `(i / group) % n_ch` (dense `[d_in, d_out]` columns: `group = 1`,
/// `n_ch = d_out`; depthwise `[C, 3]` rows: `group = 3`, `n_ch = C`) and
/// each channel's scale is grid-searched independently on its own
/// elements — the per-channel twin of [`mse_weight_scale`].
pub fn mse_weight_scale_pc(w: &[f32], n_ch: usize, group: usize, n: f32, p: f32) -> Vec<f32> {
    let n_ch = n_ch.max(1);
    let g = group.max(1);
    let mut buckets: Vec<Vec<f32>> = vec![Vec::with_capacity(w.len() / n_ch + 1); n_ch];
    for (i, &x) in w.iter().enumerate() {
        buckets[(i / g) % n_ch].push(x);
    }
    buckets.iter().map(|b| mse_weight_scale(b, n, p)).collect()
}

/// LSQ-style activation scale from a calibration mean-|x|.
pub fn lsq_act_scale(abs_mean: f32, p: f32) -> f32 {
    (2.0 * abs_mean / p.max(1.0).sqrt()).max(1e-4)
}

/// Per-channel LSQ activation scales from per-channel calibration
/// mean-|x| values (one entry per input channel of the site, as emitted
/// by the bnstats artifact's `.absmean_pc` output) — the per-channel
/// twin of [`lsq_act_scale`]. A channel that saw no signal during
/// calibration gets the same 1e-4 floor the scalar rule applies.
pub fn lsq_act_scale_pc(abs_means: &[f32], p: f32) -> Vec<f32> {
    abs_means.iter().map(|&m| lsq_act_scale(m, p)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quant_mse;
    use crate::rng::Pcg32;

    #[test]
    fn recovers_good_scale_for_gaussian() {
        let mut r = Pcg32::new(0, 0);
        let w: Vec<f32> = (0..4096).map(|_| 0.3 * r.normal()).collect();
        let (n, p) = (-4.0, 3.0);
        let s = mse_weight_scale(&w, n, p);
        // must beat the naive absmax scale by a margin
        let absmax = w.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let naive = absmax / 4.0;
        assert!(quant_mse(&w, s, n, p) <= quant_mse(&w, naive, n, p));
        assert!(s > 0.0 && s < naive * 1.3);
    }

    #[test]
    fn zero_tensor_safe() {
        let s = mse_weight_scale(&[0.0; 16], -4.0, 3.0);
        assert!(s > 0.0);
    }

    #[test]
    fn per_channel_beats_shared_scale_on_mixed_ranges() {
        // two dense output columns with very different magnitudes: each
        // channel's MSE over its own scale must be <= its MSE over the
        // shared per-tensor scale
        let mut r = Pcg32::new(3, 9);
        let (d_in, d_out) = (256usize, 2usize);
        let mut w = vec![0.0f32; d_in * d_out];
        for i in 0..d_in {
            w[i * d_out] = 0.02 * r.normal(); // tiny channel
            w[i * d_out + 1] = 1.5 * r.normal(); // wide channel
        }
        let (n, p) = (-4.0, 3.0);
        let shared = mse_weight_scale(&w, n, p);
        let per_ch = mse_weight_scale_pc(&w, d_out, 1, n, p);
        assert_eq!(per_ch.len(), 2);
        assert!(per_ch[0] < per_ch[1], "channel scales should differ: {per_ch:?}");
        for c in 0..d_out {
            let col: Vec<f32> = (0..d_in).map(|i| w[i * d_out + c]).collect();
            assert!(
                quant_mse(&col, per_ch[c], n, p) <= quant_mse(&col, shared, n, p) + 1e-12,
                "channel {c} worse than shared"
            );
        }
        // degenerate single channel matches the per-tensor search
        let one = mse_weight_scale_pc(&w, 1, 1, n, p);
        assert_eq!(one, vec![mse_weight_scale(&w, n, p)]);
    }

    #[test]
    fn act_scale_positive() {
        assert!(lsq_act_scale(0.0, 7.0) > 0.0);
        let s = lsq_act_scale(0.5, 7.0);
        assert!((s - 2.0 * 0.5 / 7.0f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn act_scale_pc_maps_channels_independently() {
        let s = lsq_act_scale_pc(&[0.0, 0.5, 2.0], 7.0);
        assert_eq!(s.len(), 3);
        assert_eq!(s[0], 1e-4, "dead channel gets the floor");
        assert!((s[1] - lsq_act_scale(0.5, 7.0)).abs() < 1e-9);
        assert!((s[2] - lsq_act_scale(2.0, 7.0)).abs() < 1e-9);
        assert!(s[1] < s[2], "scale grows with the channel's magnitude");
    }
}
