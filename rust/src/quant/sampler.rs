//! Stochastic-rounding sampler over oscillating weights (Table 3, "SR").
//!
//! §2.3.2: sample every oscillating weight between its two states with
//! probability proportional to the time spent in each (the integer EMA),
//! i.e. p(w_up) = E_t[w^t = w_up]. Table 3 reports mean/std/best training
//! loss over such samples.

use super::adaround::{apply_assignment, Candidate};
use crate::rng::Pcg32;
use crate::state::NamedTensors;

/// Draw one stochastic sample of the oscillating weights into `state`.
/// Each candidate carries its own (per-tensor or per-channel) step size,
/// so the sampled latents land on their channel's grid.
pub fn sample_assignment(state: &mut NamedTensors, cands: &mut [Candidate], rng: &mut Pcg32) {
    for c in cands.iter_mut() {
        c.up = rng.next_f32() < c.p_up;
    }
    apply_assignment(state, cands);
}

/// Summary statistics over sampled losses.
#[derive(Debug, Clone)]
pub struct SampleStats {
    pub mean: f64,
    pub std: f64,
    pub best: f64,
    pub losses: Vec<f64>,
}

pub fn summarize(losses: Vec<f64>) -> SampleStats {
    let n = losses.len().max(1) as f64;
    let mean = losses.iter().sum::<f64>() / n;
    let var = losses.iter().map(|l| (l - mean) * (l - mean)).sum::<f64>() / n;
    let best = losses.iter().cloned().fold(f64::INFINITY, f64::min);
    SampleStats { mean, std: var.sqrt(), best, losses }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_respects_probabilities() {
        let mut rng = Pcg32::new(0, 0);
        let mut cands: Vec<Candidate> = vec![
            Candidate {
                tensor: "params/x".into(),
                index: 0,
                down: 0.0,
                up: false,
                p_up: 1.0,
                scale: 0.1,
            },
            Candidate {
                tensor: "params/x".into(),
                index: 1,
                down: 0.0,
                up: true,
                p_up: 0.0,
                scale: 0.1,
            },
        ];
        let mut ups = [0u32; 2];
        for _ in 0..200 {
            for c in cands.iter_mut() {
                c.up = rng.next_f32() < c.p_up;
            }
            ups[0] += cands[0].up as u32;
            ups[1] += cands[1].up as u32;
        }
        assert_eq!(ups[0], 200);
        assert_eq!(ups[1], 0);
    }

    #[test]
    fn stats() {
        let s = summarize(vec![1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-9);
        assert_eq!(s.best, 1.0);
        assert!((s.std - (2.0f64 / 3.0).sqrt()).abs() < 1e-9);
    }
}
