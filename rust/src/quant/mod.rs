//! Host-side quantization substrate.
//!
//! A bit-exact mirror of the L1 fake-quant kernel (same round-half-to-even
//! as XLA's `round-nearest-even`) plus the pieces of the paper's workflow
//! that are naturally host-side:
//!
//! * MSE range estimation for scale initialization (§5.1, Nagel et al.
//!   2021 white-paper style grid search),
//! * the AdaRound-flavoured binary optimization of oscillating weights
//!   (Table 3) via simulated annealing,
//! * the stochastic-rounding sampler over oscillating weights (Table 3).

pub mod adaround;
pub mod range_est;
pub mod sampler;

use crate::tensor::round_ties_even;

/// Signed integer grid for a weight bit-width: n = -2^(b-1), p = 2^(b-1)-1.
pub fn weight_grid(bits: u32) -> (f32, f32) {
    let half = 1i64 << (bits - 1);
    (-(half as f32), (half - 1) as f32)
}

/// Unsigned activation grid: p = 2^b - 1.
pub fn act_grid(bits: u32) -> f32 {
    ((1i64 << bits) - 1) as f32
}

/// Fake quantization, identical to the L1 kernel / ref.fake_quant_ref.
pub fn fake_quant(w: &[f32], s: f32, n: f32, p: f32) -> Vec<f32> {
    w.iter().map(|&x| s * round_ties_even(x / s).clamp(n, p)).collect()
}

/// Integer (grid index) representation.
pub fn int_weights(w: &[f32], s: f32, n: f32, p: f32) -> Vec<f32> {
    w.iter().map(|&x| round_ties_even(x / s).clamp(n, p)).collect()
}

/// Per-channel fake quantization lives in
/// `runtime::native::kernels::fake_quant_pc` — the single source of
/// truth for the per-channel weight-to-grid mapping (the exporter,
/// packed engine and bit-exactness tests all encode through it).
pub use crate::runtime::native::kernels::fake_quant_pc;

/// Mean squared quantization error for a candidate scale.
pub fn quant_mse(w: &[f32], s: f32, n: f32, p: f32) -> f64 {
    let mut acc = 0.0f64;
    for &x in w {
        let q = s * round_ties_even(x / s).clamp(n, p);
        acc += ((x - q) as f64).powi(2);
    }
    acc / w.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids() {
        assert_eq!(weight_grid(3), (-4.0, 3.0));
        assert_eq!(weight_grid(4), (-8.0, 7.0));
        assert_eq!(weight_grid(8), (-128.0, 127.0));
        assert_eq!(act_grid(3), 7.0);
        assert_eq!(act_grid(8), 255.0);
    }

    #[test]
    fn fake_quant_on_grid() {
        let w = vec![0.12, -0.37, 0.05, 2.0, -2.0];
        let q = fake_quant(&w, 0.1, -4.0, 3.0);
        for v in &q {
            let i = v / 0.1;
            assert!((i - i.round()).abs() < 1e-5);
            assert!((-4.0..=3.0).contains(&i.round()));
        }
        // clipping
        assert_eq!(q[3], 0.3);
        assert_eq!(q[4], -0.4);
    }

    #[test]
    fn ties_even_matches_xla_semantics() {
        // 0.05/0.1 = 0.5 -> rounds to 0 (ties to even), not 1
        let q = fake_quant(&[0.05], 0.1, -4.0, 3.0);
        assert_eq!(q[0], 0.0);
        let q = fake_quant(&[0.15], 0.1, -4.0, 3.0);
        assert_eq!(q[0], 0.2); // 1.5 -> 2
    }

    #[test]
    fn mse_zero_for_exact_grid() {
        let w = vec![0.1, -0.2, 0.3];
        assert!(quant_mse(&w, 0.1, -4.0, 3.0) < 1e-12);
    }
}
