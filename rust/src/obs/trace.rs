//! Stage timing helpers: the request-path stopwatch and the per-layer
//! engine timing summary.
//!
//! The ingress and pool time each stage of a request's life
//! (accept→parse→queue→batch→compute→write) into the stage histograms
//! of `obs::metrics` — this module only carries the tiny clock
//! plumbing, so the hot paths stay free of metric bookkeeping beyond a
//! single `Instant::now()` per stage boundary.

use std::time::Instant;

/// Restartable stopwatch over `Instant`. `lap()` returns the seconds
/// since the last lap (or construction) and restarts, so consecutive
/// laps partition a request's life into disjoint stages.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    /// Seconds since the last lap; resets the lap origin.
    pub fn lap(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.0).as_secs_f64();
        self.0 = now;
        dt
    }

    /// Seconds since construction/last lap, without resetting.
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Accumulated compute time of one model layer, as reported by
/// `Engine::layer_timing_summary()` when `EngineOpts::layer_timing`
/// is on.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerTime {
    pub name: String,
    pub calls: u64,
    pub total_ns: u64,
}

impl LayerTime {
    pub fn total_ms(&self) -> f64 {
        self.total_ns as f64 * 1e-6
    }

    pub fn mean_us(&self) -> f64 {
        if self.calls == 0 {
            return 0.0;
        }
        self.total_ns as f64 * 1e-3 / self.calls as f64
    }
}

/// Fixed-width text table over a layer timing summary, sorted by total
/// time descending — the shape `obs-report` and `serve --bench` print.
pub fn layer_table(rows: &[LayerTime]) -> String {
    let mut rows: Vec<&LayerTime> = rows.iter().collect();
    rows.sort_by(|a, b| b.total_ns.cmp(&a.total_ns));
    let total: u64 = rows.iter().map(|r| r.total_ns).sum();
    let mut out = String::new();
    out.push_str(&format!(
        "{:<28} {:>8} {:>12} {:>10} {:>6}\n",
        "layer", "calls", "total_ms", "mean_us", "share"
    ));
    for r in rows {
        let share = if total > 0 { 100.0 * r.total_ns as f64 / total as f64 } else { 0.0 };
        out.push_str(&format!(
            "{:<28} {:>8} {:>12.3} {:>10.2} {:>5.1}%\n",
            r.name,
            r.calls,
            r.total_ms(),
            r.mean_us(),
            share
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_laps_partition_elapsed_time() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let a = sw.lap();
        let b = sw.lap();
        assert!(a >= 0.002, "first lap covers the sleep: {a}");
        assert!(b < a, "second lap restarts from the first: {b} vs {a}");
    }

    #[test]
    fn layer_table_sorts_by_total_and_reports_share() {
        let rows = vec![
            LayerTime { name: "l0.small".into(), calls: 10, total_ns: 1_000_000 },
            LayerTime { name: "l1.big".into(), calls: 10, total_ns: 3_000_000 },
        ];
        let t = layer_table(&rows);
        let big = t.find("l1.big").unwrap();
        let small = t.find("l0.small").unwrap();
        assert!(big < small, "rows sorted by total desc:\n{t}");
        assert!(t.contains("75.0%"), "share column:\n{t}");
        assert!(rows[1].mean_us() > 299.0 && rows[1].mean_us() < 301.0);
    }

    #[test]
    fn empty_layer_table_is_just_the_header() {
        let t = layer_table(&[]);
        assert_eq!(t.lines().count(), 1);
    }
}
