//! Structured JSONL telemetry sink.
//!
//! One self-describing JSON object per line; every record carries
//! `kind` (the record shape) and `t_ms` (milliseconds since the sink
//! was opened). The trainer emits `qat_step`/`qat_layer`/`bn_drift`
//! records, the serve bench emits `serve_bench`/`layer_timing` —
//! `obs::report` consumes all of them.
//!
//! A disabled sink (`--telemetry` not given) is a no-op whose `emit`
//! never formats anything, so telemetry costs nothing when off.

use crate::json::{self, Json};
use std::fs::File;
use std::io::Write as _;
use std::sync::Mutex;
use std::time::Instant;

/// JSONL event sink. Cheap to share behind `&` — writes are serialized
/// by an internal mutex.
pub struct EventSink {
    out: Mutex<Option<File>>,
    t0: Instant,
}

impl EventSink {
    /// A sink that drops everything (`enabled()` is false).
    pub fn disabled() -> Self {
        EventSink { out: Mutex::new(None), t0: Instant::now() }
    }

    /// Open (truncate) `path` for writing.
    pub fn to_path(path: &str) -> std::io::Result<Self> {
        let f = File::create(path)?;
        Ok(EventSink { out: Mutex::new(Some(f)), t0: Instant::now() })
    }

    /// `--telemetry` plumbing: `None` → disabled sink.
    pub fn from_opt(path: Option<&str>) -> std::io::Result<Self> {
        match path {
            Some(p) => Self::to_path(p),
            None => Ok(Self::disabled()),
        }
    }

    pub fn enabled(&self) -> bool {
        self.out.lock().expect("event sink lock").is_some()
    }

    /// Append one record. `fields` are merged after `kind`/`t_ms`
    /// (keys sort in the output, per `json.rs`). Write errors are
    /// swallowed — telemetry must never take down the workload.
    pub fn emit(&self, kind: &str, fields: &[(&str, Json)]) {
        let mut guard = self.out.lock().expect("event sink lock");
        let Some(f) = guard.as_mut() else { return };
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("kind".to_string(), Json::Str(kind.to_string()));
        obj.insert("t_ms".to_string(), num(self.t0.elapsed().as_secs_f64() * 1e3));
        for (k, v) in fields {
            obj.insert((*k).to_string(), v.clone());
        }
        let mut line = json::to_string(&Json::Obj(obj));
        line.push('\n');
        let _ = f.write_all(line.as_bytes());
    }
}

/// Finite-safe number: `json.rs` would happily print `NaN`/`inf`
/// (invalid JSON), so every numeric event field goes through here.
pub fn num(v: f64) -> Json {
    Json::Num(if v.is_finite() { v } else { 0.0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_sink_is_a_noop() {
        let s = EventSink::disabled();
        assert!(!s.enabled());
        s.emit("x", &[("a", num(1.0))]); // must not panic
    }

    #[test]
    fn emits_one_parseable_object_per_line() {
        let dir = std::env::temp_dir().join(format!("obs_ev_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        let s = EventSink::to_path(path.to_str().unwrap()).unwrap();
        assert!(s.enabled());
        s.emit("qat_step", &[("step", num(3.0)), ("loss", num(0.25))]);
        s.emit("qat_layer", &[("layer", Json::Str("l0.w".into())), ("osc", num(f64::NAN))]);
        drop(s);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let a = json::parse(lines[0]).unwrap();
        assert_eq!(a.get("kind").as_str(), Some("qat_step"));
        assert_eq!(a.get("step").as_f64(), Some(3.0));
        assert!(a.get("t_ms").as_f64().unwrap() >= 0.0);
        // NaN was sanitized to 0 and the line still parses
        let b = json::parse(lines[1]).unwrap();
        assert_eq!(b.get("osc").as_f64(), Some(0.0));
        std::fs::remove_dir_all(&dir).ok();
    }
}
