//! Unified observability: metrics registry, stage tracing, and the
//! structured telemetry event stream — shared by training and serving.
//!
//! Zero new dependencies, by the same rule as the rest of the repo:
//!
//! * [`metrics`] — atomic [`metrics::Counter`]s / [`metrics::Gauge`]s
//!   and lock-free log-bucketed [`metrics::Histogram`]s behind a
//!   [`metrics::Registry`] that renders Prometheus text exposition
//!   (the `GET /metrics` route of the HTTP ingress).
//! * [`trace`] — per-request stage timing support
//!   (accept→parse→queue→batch→compute→write stopwatches threaded
//!   through the ingress and the batching pool) plus the per-layer
//!   engine timing summary behind `EngineOpts::layer_timing`.
//! * [`events`] — the JSONL telemetry sink (`--telemetry PATH` on
//!   `train` and `serve`): one self-describing JSON object per line,
//!   fed per-epoch per-layer oscillation frequency, frozen fraction,
//!   boundary distance and BN-drift records by the QAT trainer.
//! * [`report`] — the `obs-report` CLI summarizer over a telemetry
//!   file: top oscillating layers, freeze timeline, latency breakdown.
//!
//! The histograms are the live twin of the offline sort-based
//! percentiles in `deploy::serve`: `bench-deploy` carries both as
//! cross-check rows so in-process and offline measurement can be
//! compared by the regression gate.

pub mod events;
pub mod metrics;
pub mod report;
pub mod trace;

pub use events::EventSink;
pub use metrics::{label_escape, Counter, Gauge, Histogram, HistogramSnapshot, Registry};
pub use trace::{LayerTime, Stopwatch};
