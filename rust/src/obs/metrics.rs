//! Atomic metric primitives + the Prometheus-text registry.
//!
//! Everything here is lock-free on the record path: counters and gauges
//! are single atomics, histograms are fixed arrays of atomic buckets.
//! The only mutex sits in [`Registry`]'s name table, taken on
//! registration and scrape — never per sample.
//!
//! The histogram is **log-bucketed**: 64 buckets whose upper edges grow
//! by √2 from 1µs, covering ~1µs .. ~36min of latency with ≤ one
//! bucket (≤ ~41%) of relative error. Percentiles are derived
//! nearest-rank over the bucket counts and return the containing
//! bucket's upper edge — validated against the exact sort-based
//! `deploy::serve::percentile` (see the tests here and the proptest in
//! `tests/proptests.rs`). An empty histogram reports `NaN`, the same
//! no-sample marker the hardened `serve::percentile` uses.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotonic counter. `store` exists so scrape time can sync a registry
/// counter from an external source-of-truth atomic (e.g. `HttpStats`).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn store(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
}

/// Last-write-wins f64 gauge (bit-stored in one atomic).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Number of finite buckets; one more overflow bucket rides behind them.
pub const HIST_BUCKETS: usize = 64;

/// Upper edge of the first bucket, in seconds (1µs).
const HIST_LO: f64 = 1e-6;

/// Upper bucket edges in seconds, ascending: `HIST_LO * (√2)^i`.
/// Computed once — every histogram shares the same geometry, which is
/// what makes snapshots mergeable across histograms of the same name.
pub fn bucket_edges() -> &'static [f64; HIST_BUCKETS] {
    static EDGES: OnceLock<[f64; HIST_BUCKETS]> = OnceLock::new();
    EDGES.get_or_init(|| {
        let mut e = [0.0; HIST_BUCKETS];
        for (i, v) in e.iter_mut().enumerate() {
            *v = HIST_LO * 2f64.powf(i as f64 / 2.0);
        }
        e
    })
}

/// Bucket index for a value: the first bucket whose upper edge is >= v
/// (`HIST_BUCKETS` = the overflow bucket). `partition_point` on the
/// shared edge table keeps `record` and `percentile` consistent with
/// each other by construction — no float-log fuzz at bucket borders.
fn bucket_of(secs: f64) -> usize {
    bucket_edges().partition_point(|&e| secs > e)
}

/// Lock-free log-bucketed latency histogram (values in seconds).
#[derive(Debug)]
pub struct Histogram {
    /// `HIST_BUCKETS` finite buckets + 1 overflow bucket
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..=HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample (seconds). Negative/NaN samples clamp into the
    /// first bucket rather than being dropped — a sample happened.
    pub fn record(&self, secs: f64) {
        let secs = if secs.is_finite() && secs > 0.0 { secs } else { 0.0 };
        self.buckets[bucket_of(secs)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Consistent-enough copy for scraping/merging. Relaxed loads: a
    /// scrape racing a record may see the bucket before the count (or
    /// vice versa) — off-by-one-sample, which exposition tolerates.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum_seconds: self.sum_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        }
    }

    /// Nearest-rank percentile from the live buckets; `NaN` when empty.
    pub fn percentile(&self, q: f64) -> f64 {
        self.snapshot().percentile(q)
    }
}

/// A point-in-time copy of one histogram's buckets, mergeable with
/// other snapshots of the same geometry (all histograms here share it).
#[derive(Debug, Clone, Default)]
pub struct HistogramSnapshot {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum_seconds: f64,
}

impl HistogramSnapshot {
    /// Add another snapshot's samples into this one (e.g. folding
    /// per-worker histograms into a pool-wide view).
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        if self.buckets.len() < other.buckets.len() {
            self.buckets.resize(other.buckets.len(), 0);
        }
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_seconds += other.sum_seconds;
    }

    /// Nearest-rank percentile (rank rounded up, like
    /// `deploy::serve::percentile`): the upper edge of the bucket
    /// holding the rank-th sample. `NaN` marks an empty sample — the
    /// caller serializes it as a 0-count row, never as a number.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = ((self.count as f64 * q).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let edges = bucket_edges();
                // the overflow bucket has no finite edge; report the
                // largest finite one (the floor of the true value)
                return edges[i.min(HIST_BUCKETS - 1)];
            }
        }
        bucket_edges()[HIST_BUCKETS - 1]
    }

    pub fn mean_seconds(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum_seconds / self.count as f64
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
    /// one family, many labeled series (key = rendered label block)
    LabeledCounters(BTreeMap<String, Arc<Counter>>),
    LabeledGauges(BTreeMap<String, Arc<Gauge>>),
}

/// Prometheus label-value escaping (backslash, quote, newline).
pub fn label_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// The rendered `{k="v",...}` block that keys one series inside a
/// labeled family. Label *names* are trusted (call-site literals);
/// values are escaped.
fn series_key(labels: &[(&str, &str)]) -> String {
    let mut s = String::from("{");
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{k}=\"{}\"", label_escape(v));
    }
    s.push('}');
    s
}

/// Named metric table rendering Prometheus text exposition. Metrics are
/// `Arc`-shared: `counter`/`gauge`/`histogram` get-or-create (so call
/// sites need no registration phase), and `adopt_histogram` registers a
/// histogram that lives somewhere else (e.g. inside `ServeStats`) so
/// the hot path records without ever touching the registry.
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, (String, Metric)>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().expect("registry lock");
        let entry = m
            .entry(name.to_string())
            .or_insert_with(|| (help.to_string(), Metric::Counter(Arc::new(Counter::default()))));
        match &entry.1 {
            Metric::Counter(c) => c.clone(),
            _ => panic!("metric {name} already registered with another type"),
        }
    }

    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().expect("registry lock");
        let entry = m
            .entry(name.to_string())
            .or_insert_with(|| (help.to_string(), Metric::Gauge(Arc::new(Gauge::default()))));
        match &entry.1 {
            Metric::Gauge(g) => g.clone(),
            _ => panic!("metric {name} already registered with another type"),
        }
    }

    pub fn histogram(&self, name: &str, help: &str) -> Arc<Histogram> {
        let mut m = self.metrics.lock().expect("registry lock");
        let entry = m.entry(name.to_string()).or_insert_with(|| {
            (help.to_string(), Metric::Histogram(Arc::new(Histogram::new())))
        });
        match &entry.1 {
            Metric::Histogram(h) => h.clone(),
            _ => panic!("metric {name} already registered with another type"),
        }
    }

    /// Get-or-create one labeled series inside a counter family. The
    /// family renders a single `# HELP`/`# TYPE` header followed by one
    /// sample row per distinct label set (sorted by label block).
    pub fn counter_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let key = series_key(labels);
        let mut m = self.metrics.lock().expect("registry lock");
        let entry = m
            .entry(name.to_string())
            .or_insert_with(|| (help.to_string(), Metric::LabeledCounters(BTreeMap::new())));
        match &mut entry.1 {
            Metric::LabeledCounters(series) => {
                series.entry(key).or_insert_with(|| Arc::new(Counter::default())).clone()
            }
            _ => panic!("metric {name} already registered with another type"),
        }
    }

    /// Labeled-gauge twin of [`Registry::counter_with`].
    pub fn gauge_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let key = series_key(labels);
        let mut m = self.metrics.lock().expect("registry lock");
        let entry = m
            .entry(name.to_string())
            .or_insert_with(|| (help.to_string(), Metric::LabeledGauges(BTreeMap::new())));
        match &mut entry.1 {
            Metric::LabeledGauges(series) => {
                series.entry(key).or_insert_with(|| Arc::new(Gauge::default())).clone()
            }
            _ => panic!("metric {name} already registered with another type"),
        }
    }

    /// Register an externally-owned histogram under `name` (the owner
    /// keeps recording into its own `Arc`; scrapes see it live).
    pub fn adopt_histogram(&self, name: &str, help: &str, h: Arc<Histogram>) {
        let mut m = self.metrics.lock().expect("registry lock");
        m.insert(name.to_string(), (help.to_string(), Metric::Histogram(h)));
    }

    /// Prometheus text exposition (version 0.0.4): `# HELP`/`# TYPE`
    /// per family; histograms render cumulative `_bucket{le=...}` rows
    /// plus `_sum`/`_count`.
    pub fn render(&self) -> String {
        let m = self.metrics.lock().expect("registry lock");
        let mut out = String::new();
        for (name, (help, metric)) in m.iter() {
            if !help.is_empty() {
                let _ = writeln!(out, "# HELP {name} {help}");
            }
            match metric {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "# TYPE {name} counter");
                    let _ = writeln!(out, "{name} {}", c.get());
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(out, "# TYPE {name} gauge");
                    let _ = writeln!(out, "{name} {}", g.get());
                }
                Metric::LabeledCounters(series) => {
                    let _ = writeln!(out, "# TYPE {name} counter");
                    for (labels, c) in series {
                        let _ = writeln!(out, "{name}{labels} {}", c.get());
                    }
                }
                Metric::LabeledGauges(series) => {
                    let _ = writeln!(out, "# TYPE {name} gauge");
                    for (labels, g) in series {
                        let _ = writeln!(out, "{name}{labels} {}", g.get());
                    }
                }
                Metric::Histogram(h) => {
                    let _ = writeln!(out, "# TYPE {name} histogram");
                    let snap = h.snapshot();
                    let edges = bucket_edges();
                    let mut cum = 0u64;
                    for (i, &edge) in edges.iter().enumerate() {
                        cum += snap.buckets.get(i).copied().unwrap_or(0);
                        let _ = writeln!(out, "{name}_bucket{{le=\"{edge}\"}} {cum}");
                    }
                    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", snap.count);
                    let _ = writeln!(out, "{name}_sum {}", snap.sum_seconds);
                    let _ = writeln!(out, "{name}_count {}", snap.count);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deploy::serve::percentile as exact_percentile;
    use crate::rng::Pcg32;

    #[test]
    fn counters_and_gauges_roundtrip() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        c.store(17);
        assert_eq!(c.get(), 17);
        let g = Gauge::default();
        g.set(2.5);
        assert_eq!(g.get(), 2.5);
    }

    #[test]
    fn bucket_edges_are_sorted_and_bucketing_is_consistent() {
        let edges = bucket_edges();
        for w in edges.windows(2) {
            assert!(w[0] < w[1]);
        }
        // a value strictly inside bucket i maps to i; the edge itself
        // belongs to its own bucket (le = "less or equal")
        for (i, &e) in edges.iter().enumerate() {
            assert_eq!(bucket_of(e), i, "edge {e} must close bucket {i}");
        }
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(f64::MAX), HIST_BUCKETS, "overflow bucket");
    }

    #[test]
    fn empty_histogram_reports_nan_not_panic() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert!(h.percentile(0.5).is_nan());
        assert!(h.snapshot().mean_seconds().is_nan());
    }

    #[test]
    fn percentiles_stay_within_one_bucket_of_exact() {
        let mut rng = Pcg32::new(3, 0x0b5);
        for n in [1usize, 2, 7, 100, 999] {
            let h = Histogram::new();
            let mut xs: Vec<f64> = (0..n)
                .map(|_| (rng.uniform(1e-5, 0.5) as f64).powi(2) + 1e-6)
                .collect();
            for &x in &xs {
                h.record(x);
            }
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for q in [0.5, 0.95, 0.99] {
                let exact = exact_percentile(&xs, q);
                let approx = h.percentile(q);
                let (be, ba) = (bucket_of(exact), bucket_of(approx));
                assert!(
                    be.abs_diff(ba) <= 1,
                    "n={n} q={q}: exact {exact} (bucket {be}) vs hist {approx} (bucket {ba})"
                );
                // the reported edge is an upper bound of the true value
                assert!(approx >= exact * (1.0 - 1e-12), "n={n} q={q}");
            }
        }
    }

    #[test]
    fn snapshots_merge_additively() {
        let a = Histogram::new();
        let b = Histogram::new();
        for i in 0..50 {
            a.record(1e-4 * (i + 1) as f64);
            b.record(1e-2 * (i + 1) as f64);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        assert_eq!(merged.count, 100);
        let sum = a.snapshot().sum_seconds + b.snapshot().sum_seconds;
        assert!((merged.sum_seconds - sum).abs() < 1e-9);
        // the merged p99 lands in b's (slower) range
        assert!(merged.percentile(0.99) > a.percentile(0.99));
    }

    #[test]
    fn registry_renders_valid_exposition() {
        let r = Registry::new();
        r.counter("qat_test_total", "test counter").add(3);
        r.gauge("qat_test_gauge", "test gauge").set(1.5);
        let h = r.histogram("qat_test_seconds", "test histogram");
        h.record(0.002);
        h.record(0.004);
        let text = r.render();
        assert!(text.contains("# TYPE qat_test_total counter"), "{text}");
        assert!(text.contains("qat_test_total 3"), "{text}");
        assert!(text.contains("# TYPE qat_test_gauge gauge"), "{text}");
        assert!(text.contains("qat_test_gauge 1.5"), "{text}");
        assert!(text.contains("# TYPE qat_test_seconds histogram"), "{text}");
        assert!(text.contains("qat_test_seconds_bucket{le=\"+Inf\"} 2"), "{text}");
        assert!(text.contains("qat_test_seconds_count 2"), "{text}");
        // bucket rows are cumulative and end at the total count
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.starts_with("qat_test_seconds_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "non-cumulative bucket row: {line}");
            last = v;
        }
        assert_eq!(last, 2);
        // get-or-create returns the same underlying metric
        assert_eq!(r.counter("qat_test_total", "").get(), 3);
    }

    #[test]
    fn labeled_families_render_one_header_many_series() {
        let r = Registry::new();
        r.counter_with("qat_lbl_total", "per-model requests", &[("model", "aux")]).add(2);
        r.counter_with("qat_lbl_total", "", &[("model", "tiny")]).add(5);
        r.gauge_with("qat_lbl_up", "per-model liveness", &[("model", "tiny")]).set(1.0);
        let text = r.render();
        assert_eq!(text.matches("# TYPE qat_lbl_total counter").count(), 1, "{text}");
        assert!(text.contains("qat_lbl_total{model=\"aux\"} 2"), "{text}");
        assert!(text.contains("qat_lbl_total{model=\"tiny\"} 5"), "{text}");
        assert!(text.contains("qat_lbl_up{model=\"tiny\"} 1"), "{text}");
        // get-or-create: the same label set returns the same series
        assert_eq!(r.counter_with("qat_lbl_total", "", &[("model", "aux")]).get(), 2);
        // label values are escaped, never break the exposition line
        r.gauge_with("qat_lbl_up", "", &[("model", "we\"ird\n")]).set(0.0);
        assert!(r.render().contains("qat_lbl_up{model=\"we\\\"ird\\n\"} 0"), "escape");
    }

    #[test]
    fn adopted_histogram_is_scraped_live() {
        let r = Registry::new();
        let h = Arc::new(Histogram::new());
        r.adopt_histogram("qat_adopted_seconds", "externally owned", h.clone());
        h.record(0.01);
        let text = r.render();
        assert!(text.contains("qat_adopted_seconds_count 1"), "{text}");
    }
}
