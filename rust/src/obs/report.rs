//! `obs-report`: summarize a telemetry JSONL file into a terminal
//! report — freeze timeline, top oscillating layers, BN drift, serve
//! bench rows, and the per-layer compute-time table.
//!
//! The reader is deliberately forgiving: unknown `kind`s and
//! unparseable lines are counted and skipped, so a report can always be
//! produced from a partially-written file (e.g. a live training run).

use crate::json::{self, Json};
use crate::obs::trace::{layer_table, LayerTime};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Summarize the telemetry file at `path`.
pub fn report_file(path: &str) -> std::io::Result<String> {
    let text = std::fs::read_to_string(path)?;
    Ok(report_from_str(&text))
}

/// Summarize telemetry JSONL text. Never fails: bad lines are skipped
/// (and counted), an empty stream yields an explicit empty report.
pub fn report_from_str(text: &str) -> String {
    let mut steps: Vec<Json> = Vec::new();
    // latest qat_layer / bn_drift record per layer (later lines win)
    let mut layers: BTreeMap<String, Json> = BTreeMap::new();
    let mut drifts: BTreeMap<String, Json> = BTreeMap::new();
    let mut serve_rows: Vec<Json> = Vec::new();
    let mut timing: Vec<LayerTime> = Vec::new();
    let mut skipped = 0usize;
    let mut total = 0usize;

    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        total += 1;
        let Ok(j) = json::parse(line) else {
            skipped += 1;
            continue;
        };
        match j.get("kind").as_str() {
            Some("qat_step") => steps.push(j),
            Some("qat_layer") => {
                if let Some(name) = j.get("layer").as_str() {
                    layers.insert(name.to_string(), j.clone());
                }
            }
            Some("bn_drift") => {
                if let Some(name) = j.get("layer").as_str() {
                    drifts.insert(name.to_string(), j.clone());
                }
            }
            Some("serve_bench") => serve_rows.push(j),
            Some("layer_timing") => {
                timing.push(LayerTime {
                    name: j.get("layer").as_str().unwrap_or("?").to_string(),
                    calls: j.get("calls").as_f64().unwrap_or(0.0) as u64,
                    total_ns: j.get("total_ns").as_f64().unwrap_or(0.0) as u64,
                });
            }
            _ => skipped += 1,
        }
    }

    let mut out = String::new();
    let _ = writeln!(out, "telemetry report: {total} records ({skipped} skipped)");
    if total == 0 {
        out.push_str("(empty telemetry stream)\n");
        return out;
    }

    if !steps.is_empty() {
        let _ = writeln!(out, "\n== freeze timeline ({} steps logged) ==", steps.len());
        let _ = writeln!(
            out,
            "{:>8} {:>10} {:>8} {:>8} {:>9}",
            "step", "loss", "acc", "osc%", "frozen%"
        );
        // downsample long runs to ~20 evenly spaced rows, keeping the last
        let stride = (steps.len() / 20).max(1);
        for (i, s) in steps.iter().enumerate() {
            if i % stride != 0 && i + 1 != steps.len() {
                continue;
            }
            let g = |k: &str| s.get(k).as_f64().unwrap_or(0.0);
            let _ = writeln!(
                out,
                "{:>8} {:>10.4} {:>8.4} {:>8.2} {:>9.2}",
                g("step") as u64,
                g("loss"),
                g("acc"),
                100.0 * g("osc_frac"),
                100.0 * g("frozen_frac"),
            );
        }
    }

    if !layers.is_empty() {
        let mut rows: Vec<(&String, &Json)> = layers.iter().collect();
        rows.sort_by(|a, b| {
            let (oa, ob) = (
                a.1.get("osc").as_f64().unwrap_or(0.0),
                b.1.get("osc").as_f64().unwrap_or(0.0),
            );
            ob.partial_cmp(&oa).unwrap_or(std::cmp::Ordering::Equal)
        });
        let _ = writeln!(out, "\n== top oscillating layers (latest record per layer) ==");
        let _ = writeln!(
            out,
            "{:<28} {:>8} {:>9} {:>10}",
            "layer", "osc%", "frozen%", "boundary"
        );
        for (name, j) in rows.iter().take(10) {
            let g = |k: &str| j.get(k).as_f64().unwrap_or(0.0);
            let _ = writeln!(
                out,
                "{:<28} {:>8.2} {:>9.2} {:>10.4}",
                name,
                100.0 * g("osc"),
                100.0 * g("frozen"),
                g("boundary"),
            );
        }
    }

    if !drifts.is_empty() {
        let _ = writeln!(out, "\n== BN drift (latest record per layer) ==");
        let _ = writeln!(out, "{:<28} {:>12} {:>12}", "layer", "d_mean", "d_var");
        for (name, j) in drifts.iter() {
            let g = |k: &str| j.get(k).as_f64().unwrap_or(0.0);
            let _ = writeln!(out, "{:<28} {:>12.6} {:>12.6}", name, g("dm"), g("dv"));
        }
    }

    if !serve_rows.is_empty() {
        let _ = writeln!(out, "\n== serve bench ==");
        for j in &serve_rows {
            let name = j.get("name").as_str().unwrap_or("?");
            let mut parts: Vec<String> = Vec::new();
            if let Some(o) = j.as_obj() {
                for (k, v) in o {
                    if matches!(k.as_str(), "kind" | "t_ms" | "name") {
                        continue;
                    }
                    if let Some(n) = v.as_f64() {
                        parts.push(format!("{k}={n:.3}"));
                    }
                }
            }
            let _ = writeln!(out, "{name:<24} {}", parts.join("  "));
        }
    }

    if !timing.is_empty() {
        let _ = writeln!(out, "\n== per-layer compute time ==");
        out.push_str(&layer_table(&timing));
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_stream_reports_itself() {
        let r = report_from_str("");
        assert!(r.contains("0 records"), "{r}");
        assert!(r.contains("empty telemetry stream"), "{r}");
    }

    #[test]
    fn summarizes_all_record_kinds_and_skips_garbage() {
        let src = concat!(
            r#"{"kind":"qat_step","step":0,"loss":1.5,"acc":0.1,"osc_frac":0.2,"frozen_frac":0}"#, "\n",
            r#"{"kind":"qat_step","step":50,"loss":0.6,"acc":0.8,"osc_frac":0.05,"frozen_frac":0.4}"#, "\n",
            r#"{"kind":"qat_layer","layer":"l0.w","osc":0.01,"frozen":0.5,"boundary":0.12}"#, "\n",
            r#"{"kind":"qat_layer","layer":"l1.w","osc":0.30,"frozen":0.1,"boundary":0.02}"#, "\n",
            r#"{"kind":"qat_layer","layer":"l1.w","osc":0.40,"frozen":0.2,"boundary":0.01}"#, "\n",
            r#"{"kind":"bn_drift","layer":"l0","dm":0.001,"dv":0.0002}"#, "\n",
            r#"{"kind":"serve_bench","name":"keepalive","rps":1200.5,"p95_ms":3.2}"#, "\n",
            r#"{"kind":"layer_timing","layer":"l1.w","calls":8,"total_ns":4000000}"#, "\n",
            "not json at all\n",
        );
        let r = report_from_str(src);
        assert!(r.contains("9 records (1 skipped)"), "{r}");
        assert!(r.contains("freeze timeline (2 steps logged)"), "{r}");
        // latest qat_layer record per layer wins, sorted osc-desc
        let l1 = r.find("l1.w").unwrap();
        let l0 = r.find("l0.w").unwrap();
        assert!(l1 < l0, "l1.w (osc 40%) ranks above l0.w:\n{r}");
        assert!(r.contains("40.00"), "latest l1.w record used:\n{r}");
        assert!(r.contains("BN drift"), "{r}");
        assert!(r.contains("keepalive"), "{r}");
        assert!(r.contains("rps=1200.500"), "{r}");
        assert!(r.contains("per-layer compute time"), "{r}");
    }
}
