//! PJRT backend: one compiled executable + manifest per artifact, with
//! named I/O. This is the [`super::Backend`] implementation that replays
//! AOT HLO-text artifacts; see `runtime/native/` for the artifact-free
//! pure-Rust implementation of the same contract.

use super::manifest::{ArtifactIndex, Manifest};
use super::{Backend, Signature};
use crate::state::NamedTensors;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

/// Shared PJRT CPU client + executable cache.
///
/// XLA compilation of a train artifact takes tens of seconds on this host,
/// so every experiment suite runs inside one `Runtime` and compiles each
/// artifact at most once.
pub struct Runtime {
    client: xla::PjRtClient,
    pub index: ArtifactIndex,
    cache: RefCell<BTreeMap<String, Rc<Artifact>>>,
    /// cumulative compile seconds (reported by the bench harness)
    pub compile_secs: RefCell<f64>,
}

impl Runtime {
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let index = ArtifactIndex::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("PjRtClient::cpu")?;
        Ok(Runtime {
            client,
            index,
            cache: RefCell::new(BTreeMap::new()),
            compile_secs: RefCell::new(0.0),
        })
    }

    /// Load + compile (or fetch from cache) an artifact by name.
    pub fn artifact(&self, name: &str) -> Result<Rc<Artifact>> {
        if let Some(a) = self.cache.borrow().get(name) {
            return Ok(a.clone());
        }
        let manifest = Manifest::load(&self.index.dir.join(format!("{name}.manifest.json")))?;
        let hlo_path = self.index.dir.join(&manifest.hlo_file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile {name}"))?;
        let dt = t0.elapsed().as_secs_f64();
        *self.compile_secs.borrow_mut() += dt;
        log::info!("compiled {name} in {dt:.1}s");
        eprintln!("[runtime] compiled {name} in {dt:.1}s");
        let a = Rc::new(Artifact { manifest, exe });
        self.cache.borrow_mut().insert(name.to_string(), a.clone());
        Ok(a)
    }

    /// Initial state QTNS for a model.
    pub fn initial_state(&self, model: &str) -> Result<NamedTensors> {
        let info = self.index.model(model)?;
        NamedTensors::read_qtns(&self.index.dir.join(&info.params_bin))
    }
}

/// One compiled executable with manifest-driven named I/O.
pub struct Artifact {
    pub manifest: Manifest,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with a by-name resolver. The resolver must provide every
    /// manifest input; outputs come back keyed by manifest output names.
    pub fn execute_with<F>(&self, resolve: F) -> Result<NamedTensors>
    where
        F: Fn(&str) -> Option<Tensor>,
    {
        let mut args: Vec<xla::Literal> = Vec::with_capacity(self.manifest.inputs.len());
        for spec in &self.manifest.inputs {
            let t = resolve(&spec.name)
                .with_context(|| format!("unresolved input {:?} for {}", spec.name, self.manifest.name))?;
            if t.len() != spec.num_elements() {
                bail!(
                    "input {:?}: resolver gave {} elements, manifest wants {:?}",
                    spec.name,
                    t.len(),
                    spec.shape
                );
            }
            args.push(tensor_to_literal(&t, &spec.shape)?);
        }
        let result = self.exe.execute::<xla::Literal>(&args).context("pjrt execute")?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = tuple.decompose_tuple().context("decompose result tuple")?;
        if parts.len() != self.manifest.outputs.len() {
            bail!(
                "artifact {} returned {} outputs, manifest expects {}",
                self.manifest.name,
                parts.len(),
                self.manifest.outputs.len()
            );
        }
        let mut out = NamedTensors::new();
        for (spec, lit) in self.manifest.outputs.iter().zip(parts) {
            let data = lit.to_vec::<f32>().with_context(|| format!("output {}", spec.name))?;
            out.insert(spec.name.clone(), Tensor::new(spec.shape.clone(), data));
        }
        Ok(out)
    }

    /// Execute against a set of name->tensor maps searched in order.
    /// Names may appear in the manifest under a `group/` prefix that the
    /// map keys already include.
    pub fn execute(&self, sources: &[&NamedTensors]) -> Result<NamedTensors> {
        self.execute_with(|name| super::resolve(sources, name))
    }
}

impl Backend for Runtime {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn index(&self) -> &ArtifactIndex {
        &self.index
    }

    fn initial_state(&self, model: &str) -> Result<NamedTensors> {
        Runtime::initial_state(self, model)
    }

    fn signature(&self, artifact: &str) -> Result<Signature> {
        if let Some(a) = self.cache.borrow().get(artifact) {
            return Ok(Signature {
                inputs: a.manifest.inputs.clone(),
                outputs: a.manifest.outputs.clone(),
            });
        }
        // manifests are plain JSON sidecars; no compilation needed
        let m = Manifest::load(&self.index.dir.join(format!("{artifact}.manifest.json")))?;
        Ok(Signature { inputs: m.inputs, outputs: m.outputs })
    }

    fn execute(&self, artifact: &str, sources: &[&NamedTensors]) -> Result<NamedTensors> {
        self.artifact(artifact)?.execute(sources)
    }

    fn compile_seconds(&self) -> f64 {
        *self.compile_secs.borrow()
    }
}

fn tensor_to_literal(t: &Tensor, shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}
