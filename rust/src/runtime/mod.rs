//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the step loop.
//!
//! The interchange contract with `python/compile/aot.py`:
//! * HLO **text** (`*.hlo.txt`) — the text parser reassigns instruction
//!   ids, dodging the 64-bit-id protos jax >= 0.5 emits that
//!   xla_extension 0.5.1 rejects.
//! * A JSON manifest per artifact listing the flat input/output tensor
//!   signature (names, shapes); the runtime binds tensors **by name**
//!   through a resolver, so callers never depend on positional order.
//! * Executables return one tuple; the runtime decomposes it and re-keys
//!   the parts by the manifest output names.

mod artifact;
mod manifest;

pub use artifact::{Artifact, Runtime};
pub use manifest::{ArtifactIndex, LayerInfo, Manifest, ModelInfo, TensorSpec};
