//! Execution backends behind one [`Backend`] trait.
//!
//! The coordinator drives training through an abstract artifact executor:
//! `execute(name, sources) -> NamedTensors` plus model-index and signature
//! lookup. Two implementations exist:
//!
//! * **PJRT** ([`Runtime`], `artifact.rs`) — loads AOT HLO-text artifacts
//!   produced by `python/compile/aot.py`, compiles them once through the
//!   PJRT C API and replays them from the step loop. Requires a `make
//!   artifacts` output directory and a real `xla` binding.
//! * **Native** ([`NativeBackend`], `native/`) — a pure-Rust interpreter of
//!   the same QAT step semantics (fused fake-quant with the paper's
//!   gradient estimators, the Algorithm-1 oscillation state machine,
//!   quantized matmul, BN statistics, SGD + momentum), numerically
//!   mirroring `python/compile/kernels/ref.py`. Needs no artifacts, no
//!   Python and no XLA — this is what CI and a fresh checkout run.
//!
//! The interchange contract shared by both backends:
//! * Tensors bind **by name** through a resolver ([`resolve`]); callers
//!   never depend on positional order. A manifest name `state/params/x`
//!   also matches a source key `params/x` (first path component stripped).
//! * Train artifacts return the whole mutable state re-keyed under
//!   `state/...` plus scalar `metrics/...` entries.

mod artifact;
mod manifest;
pub mod native;

pub use artifact::{Artifact, Runtime};
pub use manifest::{ArtifactIndex, LayerInfo, Manifest, ModelInfo, TensorSpec};
pub use native::NativeBackend;

use crate::state::NamedTensors;
use crate::tensor::Tensor;
use anyhow::{bail, Result};
use std::path::Path;

/// The hyper scalars every train/eval/bnstats artifact binds (all under a
/// `hyper/` prefix). The single source of truth for the contract the four
/// coordinator hyper builders and the native interpreter share.
pub const HYPER_KEYS: [&str; 11] = [
    "lr", "lam", "f_th", "m_osc", "bn_mom", "mu", "n_w", "p_w", "p_a", "wq_on", "aq_on",
];

/// Flat input/output signature of one artifact.
#[derive(Debug, Clone)]
pub struct Signature {
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// An artifact executor: the coordinator's only window onto compute.
pub trait Backend {
    /// Short backend tag: `"pjrt"` or `"native"`.
    fn kind(&self) -> &'static str;

    /// The model/kernel index (layer tables, low-bit weight lists, the
    /// role -> artifact-name maps).
    fn index(&self) -> &ArtifactIndex;

    /// Fresh initial training state for a model.
    fn initial_state(&self, model: &str) -> Result<NamedTensors>;

    /// Input/output signature of an artifact (no compilation implied).
    fn signature(&self, artifact: &str) -> Result<Signature>;

    /// Execute an artifact, binding every input by name from `sources`
    /// (searched in order, see [`resolve`]).
    fn execute(&self, artifact: &str, sources: &[&NamedTensors]) -> Result<NamedTensors>;

    /// Cumulative seconds spent compiling artifacts (0 for native).
    fn compile_seconds(&self) -> f64 {
        0.0
    }
}

/// By-name input resolution shared by both backends: try the raw name in
/// each source, then the name with its first path component stripped
/// (train-step inputs are `state/params/x`; state maps key `params/x`).
pub fn resolve(sources: &[&NamedTensors], name: &str) -> Option<Tensor> {
    for src in sources {
        if let Some(t) = src.get(name) {
            return Some(t.clone());
        }
    }
    let stripped = name.splitn(2, '/').nth(1)?;
    for src in sources {
        if let Some(t) = src.get(stripped) {
            return Some(t.clone());
        }
    }
    None
}

/// Instantiate a backend by CLI name: `pjrt`, `native`, or `auto`
/// (PJRT when an artifact index exists and the binding works, else native).
pub fn backend_by_name(kind: &str, artifact_dir: &Path) -> Result<Box<dyn Backend>> {
    match kind {
        "pjrt" => Ok(Box::new(Runtime::new(artifact_dir)?)),
        "native" => Ok(Box::new(NativeBackend::new())),
        "auto" | "" => auto_backend(artifact_dir),
        other => bail!("unknown backend {other:?} (expected pjrt | native | auto)"),
    }
}

/// PJRT when usable, otherwise the artifact-free native fallback.
pub fn auto_backend(artifact_dir: &Path) -> Result<Box<dyn Backend>> {
    if artifact_dir.join("index.json").exists() {
        match Runtime::new(artifact_dir) {
            Ok(rt) => return Ok(Box::new(rt)),
            Err(e) => {
                eprintln!("[runtime] PJRT backend unavailable ({e}); falling back to native");
            }
        }
    }
    Ok(Box::new(NativeBackend::new()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_strips_group_prefix() {
        let mut a = NamedTensors::new();
        a.insert("params/w", Tensor::scalar(1.0));
        let mut b = NamedTensors::new();
        b.insert("hyper/lr", Tensor::scalar(0.1));
        let srcs: Vec<&NamedTensors> = vec![&a, &b];
        assert_eq!(resolve(&srcs, "params/w").unwrap().item(), 1.0);
        assert_eq!(resolve(&srcs, "state/params/w").unwrap().item(), 1.0);
        assert_eq!(resolve(&srcs, "hyper/lr").unwrap().item(), 0.1);
        assert!(resolve(&srcs, "nope/x").is_none());
    }

    #[test]
    fn auto_backend_falls_back_to_native() {
        let be = auto_backend(Path::new("/definitely/not/a/dir")).unwrap();
        assert_eq!(be.kind(), "native");
        assert!(be.index().models.contains_key("mbv2"));
    }

    #[test]
    fn backend_by_name_rejects_unknown() {
        assert!(backend_by_name("tpu", Path::new(".")).is_err());
        assert_eq!(backend_by_name("native", Path::new(".")).unwrap().kind(), "native");
    }
}
