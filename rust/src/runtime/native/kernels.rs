//! Pure-Rust QAT hot-path kernels, numerically mirroring
//! `python/compile/kernels/ref.py` (the jnp oracles the Pallas kernels are
//! tested against). Same rounding mode everywhere: round-half-to-even,
//! like XLA's `round-nearest-even`.
//!
//! Clipping uses `max(n).min(p)` rather than `f32::clamp` so a degenerate
//! grid (n > p, possible with synthetic bench inputs) degrades instead of
//! panicking.

use crate::tensor::round_ties_even;

/// `sign` with jnp semantics: sign(0) = 0 (Rust's `signum(0.0)` is 1!).
#[inline]
pub fn sign0(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

#[inline]
fn clip(x: f32, n: f32, p: f32) -> f32 {
    x.max(n).min(p)
}

/// LSQ-style fake quantization: `s * clip(round(w/s), n, p)`
/// (ref.fake_quant_ref).
pub fn fake_quant(w: &[f32], s: f32, n: f32, p: f32) -> Vec<f32> {
    w.iter().map(|&x| s * clip(round_ties_even(x / s), n, p)).collect()
}

/// Integer (grid-index) representation: `clip(round(w/s), n, p)`
/// (ref.int_weights_ref).
pub fn int_weights(w: &[f32], s: f32, n: f32, p: f32) -> Vec<f32> {
    w.iter().map(|&x| clip(round_ties_even(x / s), n, p)).collect()
}

/// Matmul with the RHS fake-quantized: `x @ fq(w)` (ref.quant_matmul_ref).
/// `x` is `[m, k]` row-major, `w` is `[k, n]` row-major.
pub fn quant_matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, s: f32, gn: f32, gp: f32) -> Vec<f32> {
    let wq = fake_quant(w, s, gn, gp);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let a = x[i * k + kk];
            if a == 0.0 {
                continue;
            }
            let row = &wq[kk * n..(kk + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += a * row[j];
            }
        }
    }
    out
}

/// Oscillation-dampening regularizer (eq. 5), per-tensor sum:
/// `|| fq(w) - clip(w, s*n, s*p) ||_F^2` (ref.dampening_loss_ref).
pub fn dampening_loss(w: &[f32], s: f32, n: f32, p: f32) -> f32 {
    let mut acc = 0.0f64;
    for &x in w {
        let wq = s * clip(round_ties_even(x / s), n, p);
        let wc = clip(x, s * n, s * p);
        acc += ((wq - wc) as f64) * ((wq - wc) as f64);
    }
    acc as f32
}

/// Algorithm-1 oscillation state for one weight tensor (all arrays share
/// the tensor's length; masks/ints are stored as floats, matching the
/// single-dtype HLO graphs).
#[derive(Debug, Clone)]
pub struct OscState {
    /// oscillation-frequency EMA (eq. 4)
    pub f: Vec<f32>,
    /// frozen mask in {0, 1}
    pub b: Vec<f32>,
    /// integer value a frozen weight is pinned to
    pub fint: Vec<f32>,
    /// sign of the previous integer transition, in {-1, 0, +1}
    pub psign: Vec<f32>,
    /// previous step's integer weights
    pub wintp: Vec<f32>,
    /// EMA of the integer weights (alg. 1 line 15)
    pub iema: Vec<f32>,
}

/// One step of the Algorithm-1 state machine (ref.osc_update_ref), applied
/// to `w` (the latent weights *after* this step's SGD update) in place.
/// Returns the per-weight oscillation indicator o^t for this step.
pub fn osc_update(
    w: &mut [f32],
    s: f32,
    n: f32,
    p: f32,
    st: &mut OscState,
    m: f32,
    f_th: f32,
) -> Vec<f32> {
    let len = w.len();
    debug_assert!(
        st.f.len() == len
            && st.b.len() == len
            && st.fint.len() == len
            && st.psign.len() == len
            && st.wintp.len() == len
            && st.iema.len() == len
    );
    let mut osc_out = vec![0.0f32; len];
    for i in 0..len {
        // Frozen weights ignore the SGD proposal and stay pinned (in the
        // *integer* domain, so a moving scale s cannot re-round them).
        let w_eff = if st.b[i] > 0.5 { s * st.fint[i] } else { w[i] };
        let wint = clip(round_ties_even(w_eff / s), n, p);

        let delta = wint - st.wintp[i];
        let changed = delta != 0.0;
        let sign = sign0(delta);
        // An oscillation: integer value changed AND direction flipped vs
        // the previous change (psign == 0 means "no previous change yet").
        let osc = if changed && sign != st.psign[i] && st.psign[i] != 0.0 {
            1.0
        } else {
            0.0
        };

        let f_new = m * osc + (1.0 - m) * st.f[i];
        let iema_new = m * wint + (1.0 - m) * st.iema[i];

        let newly = f_new > f_th && st.b[i] < 0.5;
        let b_new = if newly { 1.0 } else { st.b[i] };
        let fint_new = if newly {
            clip(round_ties_even(iema_new), n, p)
        } else {
            st.fint[i]
        };

        let w_out = if b_new > 0.5 { s * fint_new } else { w_eff };
        let wint_out = clip(round_ties_even(w_out / s), n, p);
        let psign_out = if changed { sign } else { st.psign[i] };

        w[i] = w_out;
        st.f[i] = f_new;
        st.b[i] = b_new;
        st.fint[i] = fint_new;
        st.psign[i] = psign_out;
        st.wintp[i] = wint_out;
        st.iema[i] = iema_new;
        osc_out[i] = osc;
    }
    osc_out
}

/// Gradient estimator through the weight fake-quantizer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Estimator {
    /// STE with clip gating + learned step size (LSQ)
    Lsq,
    /// element-wise gradient scaling (multiplicative)
    Ewgs,
    /// differentiable soft quantization (multiplicative)
    Dsq,
    /// position-based scaled gradient (multiplicative)
    Psg,
    /// PACT (clipping-centric; STE on the weight path)
    Pact,
}

impl Estimator {
    pub fn parse(name: &str) -> Option<Estimator> {
        Some(match name {
            "lsq" => Estimator::Lsq,
            "ewgs" => Estimator::Ewgs,
            "dsq" => Estimator::Dsq,
            "psg" => Estimator::Psg,
            "pact" => Estimator::Pact,
            _ => return None,
        })
    }
}

/// Backward through the weight fake-quantizer: maps the gradient w.r.t.
/// the quantized weight (`g`) to the latent-weight gradient, per the
/// chosen estimator, and accumulates the LSQ step-size gradient into
/// `ds`. `w` is the latent weight, `s` the step size.
///
/// Every estimator gates the gradient to zero outside the clip range (the
/// LSQ rule); the multiplicative variants additionally modulate it by the
/// distance `t = w/s - round(w/s)` from the grid point.
#[allow(clippy::too_many_arguments)]
pub fn fake_quant_bwd(
    est: Estimator,
    w: &[f32],
    g: &[f32],
    s: f32,
    n: f32,
    p: f32,
    dw: &mut [f32],
    ds: &mut f32,
) {
    let gscale = 1.0 / ((w.len() as f32).max(1.0) * p.abs().max(1.0)).sqrt();
    for i in 0..w.len() {
        let r = w[i] / s;
        let inside = r >= n && r <= p;
        // LSQ step-size gradient (identical grid term for all estimators)
        let s_term = if r < n {
            n
        } else if r > p {
            p
        } else {
            round_ties_even(r) - r
        };
        *ds += g[i] * s_term * gscale;
        if !inside {
            continue;
        }
        let t = r - round_ties_even(r);
        let factor = match est {
            Estimator::Lsq | Estimator::Pact => 1.0,
            Estimator::Ewgs => 1.0 + 0.2 * sign0(g[i]) * t,
            Estimator::Psg => t.abs() + 0.01,
            Estimator::Dsq => {
                let k = 5.0f32;
                let u = t.abs() - 0.5;
                k * (1.0 - (k * u).tanh().powi(2)) / (2.0 * (k / 2.0).tanh())
            }
        };
        dw[i] += g[i] * factor;
    }
}

/// Gradient of the dampening regularizer (eq. 5) w.r.t. the latent weight:
/// `d/dw || fq(w) - clip(w, s*n, s*p) ||^2 = 2 (clip(w) - fq(w))` inside
/// the clip range (stop-gradient through fq), 0 outside. Accumulates
/// `lam * grad` into `dw`.
pub fn dampening_bwd(w: &[f32], s: f32, n: f32, p: f32, lam: f32, dw: &mut [f32]) {
    for i in 0..w.len() {
        let x = w[i];
        if x >= s * n && x <= s * p {
            let wq = s * clip(round_ties_even(x / s), n, p);
            dw[i] += lam * 2.0 * (x - wq);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fake_quant_matches_host_mirror() {
        let w = vec![0.12, -0.37, 0.05, 2.0, -2.0];
        assert_eq!(fake_quant(&w, 0.1, -4.0, 3.0), crate::quant::fake_quant(&w, 0.1, -4.0, 3.0));
        assert_eq!(int_weights(&w, 0.1, -4.0, 3.0), crate::quant::int_weights(&w, 0.1, -4.0, 3.0));
    }

    #[test]
    fn sign0_matches_jnp() {
        assert_eq!(sign0(2.5), 1.0);
        assert_eq!(sign0(-0.1), -1.0);
        assert_eq!(sign0(0.0), 0.0);
    }

    #[test]
    fn quant_matmul_small() {
        // x = [[1, 2]], w = [[0.1], [0.22]] with s=0.1 -> fq(w) = [0.1, 0.2]
        let out = quant_matmul(&[1.0, 2.0], &[0.1, 0.22], 1, 2, 1, 0.1, -4.0, 3.0);
        assert!((out[0] - 0.5).abs() < 1e-6, "{out:?}");
    }

    #[test]
    fn osc_update_flags_direction_flip() {
        // latent weight crosses down after a previous up-transition
        let mut w = vec![0.04]; // rounds to integer 0 with s = 0.1
        let mut st = OscState {
            f: vec![0.0],
            b: vec![0.0],
            fint: vec![0.0],
            psign: vec![1.0], // previous transition was upward
            wintp: vec![1.0], // was at integer 1
            iema: vec![1.0],
        };
        let osc = osc_update(&mut w, 0.1, -4.0, 3.0, &mut st, 0.5, 1.1);
        assert_eq!(osc[0], 1.0, "down-after-up must count as oscillation");
        assert_eq!(st.psign[0], -1.0);
        assert_eq!(st.wintp[0], 0.0);
        assert!((st.f[0] - 0.5).abs() < 1e-6, "EMA: 0.5*1 + 0.5*0");
        // f_th = 1.1 disables freezing
        assert_eq!(st.b[0], 0.0);
        // a repeat of the same state with no change is not an oscillation
        let osc2 = osc_update(&mut w, 0.1, -4.0, 3.0, &mut st, 0.5, 1.1);
        assert_eq!(osc2[0], 0.0);
    }

    #[test]
    fn freezing_pins_to_integer_grid() {
        let s = 0.1;
        let mut w = vec![0.26];
        let mut st = OscState {
            f: vec![0.5], // already above any threshold after EMA
            b: vec![0.0],
            fint: vec![0.0],
            psign: vec![1.0],
            wintp: vec![2.0],
            iema: vec![2.6],
        };
        let osc = osc_update(&mut w, s, -4.0, 3.0, &mut st, 0.1, 0.05);
        assert_eq!(st.b[0], 1.0, "should freeze");
        // pinned to round(iema) on the grid
        assert!((w[0] - s * st.fint[0]).abs() < 1e-7);
        assert!(osc[0] == 0.0 || osc[0] == 1.0);
    }

    #[test]
    fn frozen_weight_ignores_sgd_proposal() {
        let s = 0.1;
        let mut st = OscState {
            f: vec![0.9],
            b: vec![1.0],
            fint: vec![3.0],
            psign: vec![0.0],
            wintp: vec![3.0],
            iema: vec![3.0],
        };
        for proposal in [-5.0f32, 0.0, 0.123, 7.0] {
            let mut w = vec![proposal];
            osc_update(&mut w, s, -4.0, 3.0, &mut st, 0.02, 0.01);
            assert!((w[0] - 0.3).abs() < 1e-7, "frozen weight moved to {}", w[0]);
        }
    }

    #[test]
    fn dampening_zero_on_grid() {
        let w = vec![0.1, -0.2, 0.3];
        assert!(dampening_loss(&w, 0.1, -4.0, 3.0) < 1e-12);
        let mut dw = vec![0.0; 3];
        dampening_bwd(&w, 0.1, -4.0, 3.0, 1.0, &mut dw);
        for d in dw {
            assert!(d.abs() < 1e-6);
        }
    }

    #[test]
    fn lsq_bwd_gates_outside_grid() {
        let w = vec![0.05, 10.0, -10.0];
        let g = vec![1.0, 1.0, 1.0];
        let mut dw = vec![0.0; 3];
        let mut ds = 0.0;
        fake_quant_bwd(Estimator::Lsq, &w, &g, 0.1, -4.0, 3.0, &mut dw, &mut ds);
        assert_eq!(dw[0], 1.0);
        assert_eq!(dw[1], 0.0);
        assert_eq!(dw[2], 0.0);
        assert!(ds != 0.0);
    }
}
