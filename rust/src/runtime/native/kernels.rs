//! Pure-Rust QAT hot-path kernels, numerically mirroring
//! `python/compile/kernels/ref.py` (the jnp oracles the Pallas kernels are
//! tested against). Same rounding mode everywhere: round-half-to-even,
//! like XLA's `round-nearest-even`.
//!
//! Clipping uses `max(n).min(p)` rather than `f32::clamp` so a degenerate
//! grid (n > p, possible with synthetic bench inputs) degrades instead of
//! panicking.

use crate::tensor::round_ties_even;

/// `sign` with jnp semantics: sign(0) = 0 (Rust's `signum(0.0)` is 1!).
#[inline]
pub fn sign0(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

#[inline]
fn clip(x: f32, n: f32, p: f32) -> f32 {
    x.max(n).min(p)
}

/// Index of the per-channel scale for element `i` of a weight tensor:
/// `channel = (i / group) % n_scales`.
///
/// * dense `[d_in, d_out]` row-major, one scale per output column:
///   `group = 1`, `n_scales = d_out`;
/// * depthwise `[C, 3]` rows, one scale per channel row: `group = 3`,
///   `n_scales = C`;
/// * per-tensor: `n_scales = 1` (any group) — always index 0, which is
///   how the scalar wrappers below reproduce the per-tensor behaviour
///   bit for bit.
#[inline]
pub fn scale_index(i: usize, group: usize, n_scales: usize) -> usize {
    (i / group.max(1)) % n_scales.max(1)
}

/// Per-channel LSQ fake quantization: element `i` is quantized on the
/// grid of its channel's scale, `s_c * clip(round(w/s_c), n, p)`.
pub fn fake_quant_pc(w: &[f32], scales: &[f32], group: usize, n: f32, p: f32) -> Vec<f32> {
    let ns = scales.len();
    w.iter()
        .enumerate()
        .map(|(i, &x)| {
            let s = scales[scale_index(i, group, ns)];
            s * clip(round_ties_even(x / s), n, p)
        })
        .collect()
}

/// Per-channel integer (grid-index) representation.
pub fn int_weights_pc(w: &[f32], scales: &[f32], group: usize, n: f32, p: f32) -> Vec<f32> {
    let ns = scales.len();
    w.iter()
        .enumerate()
        .map(|(i, &x)| clip(round_ties_even(x / scales[scale_index(i, group, ns)]), n, p))
        .collect()
}

/// LSQ-style fake quantization: `s * clip(round(w/s), n, p)`
/// (ref.fake_quant_ref). Per-tensor wrapper over [`fake_quant_pc`].
pub fn fake_quant(w: &[f32], s: f32, n: f32, p: f32) -> Vec<f32> {
    fake_quant_pc(w, std::slice::from_ref(&s), 1, n, p)
}

/// Integer (grid-index) representation: `clip(round(w/s), n, p)`
/// (ref.int_weights_ref). Per-tensor wrapper over [`int_weights_pc`].
pub fn int_weights(w: &[f32], s: f32, n: f32, p: f32) -> Vec<f32> {
    int_weights_pc(w, std::slice::from_ref(&s), 1, n, p)
}

/// Matmul with the RHS fake-quantized: `x @ fq(w)` (ref.quant_matmul_ref).
/// `x` is `[m, k]` row-major, `w` is `[k, n]` row-major.
pub fn quant_matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, s: f32, gn: f32, gp: f32) -> Vec<f32> {
    let wq = fake_quant(w, s, gn, gp);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let a = x[i * k + kk];
            if a == 0.0 {
                continue;
            }
            let row = &wq[kk * n..(kk + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += a * row[j];
            }
        }
    }
    out
}

/// Oscillation-dampening regularizer (eq. 5) with per-channel scales:
/// `sum_i (fq(w_i; s_c) - clip(w_i, s_c*n, s_c*p))^2`.
pub fn dampening_loss_pc(w: &[f32], scales: &[f32], group: usize, n: f32, p: f32) -> f32 {
    let ns = scales.len();
    let mut acc = 0.0f64;
    for (i, &x) in w.iter().enumerate() {
        let s = scales[scale_index(i, group, ns)];
        let wq = s * clip(round_ties_even(x / s), n, p);
        let wc = clip(x, s * n, s * p);
        acc += ((wq - wc) as f64) * ((wq - wc) as f64);
    }
    acc as f32
}

/// Oscillation-dampening regularizer (eq. 5), per-tensor sum:
/// `|| fq(w) - clip(w, s*n, s*p) ||_F^2` (ref.dampening_loss_ref).
pub fn dampening_loss(w: &[f32], s: f32, n: f32, p: f32) -> f32 {
    dampening_loss_pc(w, std::slice::from_ref(&s), 1, n, p)
}

/// Output side length of a 3x3 spatial conv: `(hw + 2*pad - 3)/stride + 1`.
pub fn dw_spatial_out(hw_in: usize, stride: usize, pad: usize) -> usize {
    (hw_in + 2 * pad - 3) / stride.max(1) + 1
}

/// True 2-D spatial depthwise 3x3 forward (ref.dw_spatial_ref): `a` is a
/// `[bsz, hw_in*hw_in*channels]` channel-last activation block, `w` the
/// effective `[channels, 3, 3]` taps, `z` the `[bsz, hw_out^2*channels]`
/// output. Zero padding is realized by skipping out-of-bounds taps; taps
/// accumulate in `(ky, kx)` ascending order per output element — the
/// bit-exactness contract shared with the deploy engine's
/// scalar/blocked/streaming kernels.
pub fn dw_spatial_fwd(
    a: &[f32],
    w: &[f32],
    bsz: usize,
    hw_in: usize,
    channels: usize,
    stride: usize,
    pad: usize,
    z: &mut [f32],
) {
    let hw_out = dw_spatial_out(hw_in, stride, pad);
    let d_in = hw_in * hw_in * channels;
    let d_out = hw_out * hw_out * channels;
    debug_assert!(a.len() == bsz * d_in && z.len() == bsz * d_out && w.len() == channels * 9);
    for bi in 0..bsz {
        let arow = &a[bi * d_in..(bi + 1) * d_in];
        let zrow = &mut z[bi * d_out..(bi + 1) * d_out];
        for yo in 0..hw_out {
            for xo in 0..hw_out {
                for c in 0..channels {
                    let mut acc = 0.0f32;
                    for ky in 0..3usize {
                        let y = yo * stride + ky;
                        if y < pad || y - pad >= hw_in {
                            continue;
                        }
                        for kx in 0..3usize {
                            let x = xo * stride + kx;
                            if x < pad || x - pad >= hw_in {
                                continue;
                            }
                            let j = ((y - pad) * hw_in + (x - pad)) * channels + c;
                            acc += w[c * 9 + ky * 3 + kx] * arow[j];
                        }
                    }
                    zrow[(yo * hw_out + xo) * channels + c] = acc;
                }
            }
        }
    }
}

/// Backward of [`dw_spatial_fwd`]: mirror of the forward tap walk; every
/// `(output, tap)` pair contributes `dz*a` to the weight grad and `dz*w`
/// to the input grad at the same flat index. Accumulates (`+=`) into
/// `dw` (`[channels, 3, 3]`) and `da` (`[bsz, hw_in^2*channels]`), so a
/// caller can fold multiple calls into one gradient buffer.
pub fn dw_spatial_bwd(
    a: &[f32],
    w: &[f32],
    dz: &[f32],
    bsz: usize,
    hw_in: usize,
    channels: usize,
    stride: usize,
    pad: usize,
    dw: &mut [f32],
    da: &mut [f32],
) {
    let hw_out = dw_spatial_out(hw_in, stride, pad);
    let d_in = hw_in * hw_in * channels;
    let d_out = hw_out * hw_out * channels;
    debug_assert!(
        a.len() == bsz * d_in
            && da.len() == bsz * d_in
            && dz.len() == bsz * d_out
            && w.len() == channels * 9
            && dw.len() == channels * 9
    );
    for bi in 0..bsz {
        let arow = &a[bi * d_in..(bi + 1) * d_in];
        let dzrow = &dz[bi * d_out..(bi + 1) * d_out];
        let darow = &mut da[bi * d_in..(bi + 1) * d_in];
        for yo in 0..hw_out {
            for xo in 0..hw_out {
                for c in 0..channels {
                    let g = dzrow[(yo * hw_out + xo) * channels + c];
                    if g == 0.0 {
                        continue;
                    }
                    for ky in 0..3usize {
                        let y = yo * stride + ky;
                        if y < pad || y - pad >= hw_in {
                            continue;
                        }
                        for kx in 0..3usize {
                            let x = xo * stride + kx;
                            if x < pad || x - pad >= hw_in {
                                continue;
                            }
                            let j = ((y - pad) * hw_in + (x - pad)) * channels + c;
                            let wi = c * 9 + ky * 3 + kx;
                            dw[wi] += g * arow[j];
                            darow[j] += g * w[wi];
                        }
                    }
                }
            }
        }
    }
}

/// Algorithm-1 oscillation state for one weight tensor (all arrays share
/// the tensor's length; masks/ints are stored as floats, matching the
/// single-dtype HLO graphs).
#[derive(Debug, Clone)]
pub struct OscState {
    /// oscillation-frequency EMA (eq. 4)
    pub f: Vec<f32>,
    /// frozen mask in {0, 1}
    pub b: Vec<f32>,
    /// integer value a frozen weight is pinned to
    pub fint: Vec<f32>,
    /// sign of the previous integer transition, in {-1, 0, +1}
    pub psign: Vec<f32>,
    /// previous step's integer weights
    pub wintp: Vec<f32>,
    /// EMA of the integer weights (alg. 1 line 15)
    pub iema: Vec<f32>,
}

/// One step of the Algorithm-1 state machine with per-channel scales:
/// element `i` runs the freeze/oscillation bookkeeping on its channel's
/// grid (`s_c = scales[scale_index(i, group, n_scales)]`). Applied to `w`
/// (the latent weights *after* this step's SGD update) in place. Returns
/// the per-weight oscillation indicator o^t for this step.
#[allow(clippy::too_many_arguments)]
pub fn osc_update_pc(
    w: &mut [f32],
    scales: &[f32],
    group: usize,
    n: f32,
    p: f32,
    st: &mut OscState,
    m: f32,
    f_th: f32,
) -> Vec<f32> {
    let len = w.len();
    let ns = scales.len();
    debug_assert!(
        st.f.len() == len
            && st.b.len() == len
            && st.fint.len() == len
            && st.psign.len() == len
            && st.wintp.len() == len
            && st.iema.len() == len
    );
    let mut osc_out = vec![0.0f32; len];
    for i in 0..len {
        let s = scales[scale_index(i, group, ns)];
        // Frozen weights ignore the SGD proposal and stay pinned (in the
        // *integer* domain, so a moving scale s cannot re-round them).
        let w_eff = if st.b[i] > 0.5 { s * st.fint[i] } else { w[i] };
        let wint = clip(round_ties_even(w_eff / s), n, p);

        let delta = wint - st.wintp[i];
        let changed = delta != 0.0;
        let sign = sign0(delta);
        // An oscillation: integer value changed AND direction flipped vs
        // the previous change (psign == 0 means "no previous change yet").
        let osc = if changed && sign != st.psign[i] && st.psign[i] != 0.0 {
            1.0
        } else {
            0.0
        };

        let f_new = m * osc + (1.0 - m) * st.f[i];
        let iema_new = m * wint + (1.0 - m) * st.iema[i];

        let newly = f_new > f_th && st.b[i] < 0.5;
        let b_new = if newly { 1.0 } else { st.b[i] };
        let fint_new = if newly {
            clip(round_ties_even(iema_new), n, p)
        } else {
            st.fint[i]
        };

        let w_out = if b_new > 0.5 { s * fint_new } else { w_eff };
        let wint_out = clip(round_ties_even(w_out / s), n, p);
        let psign_out = if changed { sign } else { st.psign[i] };

        w[i] = w_out;
        st.f[i] = f_new;
        st.b[i] = b_new;
        st.fint[i] = fint_new;
        st.psign[i] = psign_out;
        st.wintp[i] = wint_out;
        st.iema[i] = iema_new;
        osc_out[i] = osc;
    }
    osc_out
}

/// One step of the Algorithm-1 state machine (ref.osc_update_ref) with a
/// single per-tensor scale. Wrapper over [`osc_update_pc`].
pub fn osc_update(
    w: &mut [f32],
    s: f32,
    n: f32,
    p: f32,
    st: &mut OscState,
    m: f32,
    f_th: f32,
) -> Vec<f32> {
    osc_update_pc(w, std::slice::from_ref(&s), 1, n, p, st, m, f_th)
}

/// Gradient estimator through the weight fake-quantizer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Estimator {
    /// STE with clip gating + learned step size (LSQ)
    Lsq,
    /// element-wise gradient scaling (multiplicative)
    Ewgs,
    /// differentiable soft quantization (multiplicative)
    Dsq,
    /// position-based scaled gradient (multiplicative)
    Psg,
    /// PACT (clipping-centric; STE on the weight path)
    Pact,
}

impl Estimator {
    pub fn parse(name: &str) -> Option<Estimator> {
        Some(match name {
            "lsq" => Estimator::Lsq,
            "ewgs" => Estimator::Ewgs,
            "dsq" => Estimator::Dsq,
            "psg" => Estimator::Psg,
            "pact" => Estimator::Pact,
            _ => return None,
        })
    }
}

/// Backward through the weight fake-quantizer with per-channel scales:
/// maps the gradient w.r.t. the quantized weight (`g`) to the
/// latent-weight gradient, per the chosen estimator, and accumulates the
/// LSQ step-size gradient of channel `c` into `ds[c]` (`ds.len()` must
/// equal `scales.len()`). The LSQ gradient scaling uses the *per-channel*
/// weight count `N_c = w.len() / n_scales` — `1/sqrt(N_c * p)` — so each
/// channel's step size sees the same normalized gradient magnitude the
/// per-tensor rule gives the whole tensor.
///
/// Every estimator gates the gradient to zero outside the clip range (the
/// LSQ rule); the multiplicative variants additionally modulate it by the
/// distance `t = w/s - round(w/s)` from the grid point.
#[allow(clippy::too_many_arguments)]
pub fn fake_quant_bwd_pc(
    est: Estimator,
    w: &[f32],
    g: &[f32],
    scales: &[f32],
    group: usize,
    n: f32,
    p: f32,
    dw: &mut [f32],
    ds: &mut [f32],
) {
    let ns = scales.len();
    debug_assert_eq!(ds.len(), ns, "ds must have one slot per scale");
    let per_ch = (w.len() / ns.max(1)) as f32;
    let gscale = 1.0 / (per_ch.max(1.0) * p.abs().max(1.0)).sqrt();
    for i in 0..w.len() {
        let c = scale_index(i, group, ns);
        let r = w[i] / scales[c];
        let inside = r >= n && r <= p;
        // LSQ step-size gradient (identical grid term for all estimators)
        let s_term = if r < n {
            n
        } else if r > p {
            p
        } else {
            round_ties_even(r) - r
        };
        ds[c] += g[i] * s_term * gscale;
        if !inside {
            continue;
        }
        let t = r - round_ties_even(r);
        let factor = match est {
            Estimator::Lsq | Estimator::Pact => 1.0,
            Estimator::Ewgs => 1.0 + 0.2 * sign0(g[i]) * t,
            Estimator::Psg => t.abs() + 0.01,
            Estimator::Dsq => {
                let k = 5.0f32;
                let u = t.abs() - 0.5;
                k * (1.0 - (k * u).tanh().powi(2)) / (2.0 * (k / 2.0).tanh())
            }
        };
        dw[i] += g[i] * factor;
    }
}

/// Per-tensor wrapper over [`fake_quant_bwd_pc`].
#[allow(clippy::too_many_arguments)]
pub fn fake_quant_bwd(
    est: Estimator,
    w: &[f32],
    g: &[f32],
    s: f32,
    n: f32,
    p: f32,
    dw: &mut [f32],
    ds: &mut f32,
) {
    fake_quant_bwd_pc(
        est,
        w,
        g,
        std::slice::from_ref(&s),
        1,
        n,
        p,
        dw,
        std::slice::from_mut(ds),
    );
}

/// Backward through the **activation** fake-quantizer (unsigned LSQ grid
/// `[0, p]`) with per-channel scales: activations are `[B, d_in]`
/// row-major, element `i` belongs to channel `i % n_scales` (`n_scales`
/// is 1 for per-tensor or `d_in` for per-channel). Maps the gradient
/// w.r.t. the quantized activation (`g`) to the input gradient `da`
/// (STE gated to the `[0, p]` clip range) and accumulates the LSQ
/// step-size gradient of channel `c` into `ds[c]` with the per-channel
/// gradient scaling `1/sqrt(N_c * p)`, `N_c = a.len() / n_scales` — the
/// activation twin of [`fake_quant_bwd_pc`]'s LSQ rule. With a single
/// scale this reproduces the per-tensor activation backward bit for bit.
pub fn act_quant_bwd_pc(
    a: &[f32],
    g: &[f32],
    scales: &[f32],
    p: f32,
    da: &mut [f32],
    ds: &mut [f32],
) {
    let ns = scales.len().max(1);
    debug_assert_eq!(ds.len(), scales.len(), "ds must have one slot per scale");
    debug_assert_eq!(da.len(), a.len());
    debug_assert_eq!(g.len(), a.len());
    let per_ch = (a.len() / ns) as f32;
    let gscale = 1.0 / (per_ch.max(1.0) * p.max(1.0)).sqrt();
    for i in 0..a.len() {
        let c = i % ns;
        let r = a[i] / scales[c];
        if r < 0.0 {
            // clipped at zero: no gradient to a, none to the scale
        } else if r > p {
            ds[c] += g[i] * p * gscale;
        } else {
            ds[c] += g[i] * (round_ties_even(r) - r) * gscale;
            da[i] = g[i];
        }
    }
}

/// Gradient of the dampening regularizer (eq. 5) w.r.t. the latent weight
/// with per-channel scales: `2 (w - fq(w; s_c))` inside the channel's
/// clip range (stop-gradient through fq), 0 outside. Accumulates
/// `lam * grad` into `dw`.
pub fn dampening_bwd_pc(
    w: &[f32],
    scales: &[f32],
    group: usize,
    n: f32,
    p: f32,
    lam: f32,
    dw: &mut [f32],
) {
    let ns = scales.len();
    for (i, &x) in w.iter().enumerate() {
        let s = scales[scale_index(i, group, ns)];
        if x >= s * n && x <= s * p {
            let wq = s * clip(round_ties_even(x / s), n, p);
            dw[i] += lam * 2.0 * (x - wq);
        }
    }
}

/// Per-tensor wrapper over [`dampening_bwd_pc`].
pub fn dampening_bwd(w: &[f32], s: f32, n: f32, p: f32, lam: f32, dw: &mut [f32]) {
    dampening_bwd_pc(w, std::slice::from_ref(&s), 1, n, p, lam, dw);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fake_quant_matches_host_mirror() {
        let w = vec![0.12, -0.37, 0.05, 2.0, -2.0];
        assert_eq!(fake_quant(&w, 0.1, -4.0, 3.0), crate::quant::fake_quant(&w, 0.1, -4.0, 3.0));
        assert_eq!(int_weights(&w, 0.1, -4.0, 3.0), crate::quant::int_weights(&w, 0.1, -4.0, 3.0));
    }

    #[test]
    fn sign0_matches_jnp() {
        assert_eq!(sign0(2.5), 1.0);
        assert_eq!(sign0(-0.1), -1.0);
        assert_eq!(sign0(0.0), 0.0);
    }

    #[test]
    fn quant_matmul_small() {
        // x = [[1, 2]], w = [[0.1], [0.22]] with s=0.1 -> fq(w) = [0.1, 0.2]
        let out = quant_matmul(&[1.0, 2.0], &[0.1, 0.22], 1, 2, 1, 0.1, -4.0, 3.0);
        assert!((out[0] - 0.5).abs() < 1e-6, "{out:?}");
    }

    #[test]
    fn osc_update_flags_direction_flip() {
        // latent weight crosses down after a previous up-transition
        let mut w = vec![0.04]; // rounds to integer 0 with s = 0.1
        let mut st = OscState {
            f: vec![0.0],
            b: vec![0.0],
            fint: vec![0.0],
            psign: vec![1.0], // previous transition was upward
            wintp: vec![1.0], // was at integer 1
            iema: vec![1.0],
        };
        let osc = osc_update(&mut w, 0.1, -4.0, 3.0, &mut st, 0.5, 1.1);
        assert_eq!(osc[0], 1.0, "down-after-up must count as oscillation");
        assert_eq!(st.psign[0], -1.0);
        assert_eq!(st.wintp[0], 0.0);
        assert!((st.f[0] - 0.5).abs() < 1e-6, "EMA: 0.5*1 + 0.5*0");
        // f_th = 1.1 disables freezing
        assert_eq!(st.b[0], 0.0);
        // a repeat of the same state with no change is not an oscillation
        let osc2 = osc_update(&mut w, 0.1, -4.0, 3.0, &mut st, 0.5, 1.1);
        assert_eq!(osc2[0], 0.0);
    }

    #[test]
    fn freezing_pins_to_integer_grid() {
        let s = 0.1;
        let mut w = vec![0.26];
        let mut st = OscState {
            f: vec![0.5], // already above any threshold after EMA
            b: vec![0.0],
            fint: vec![0.0],
            psign: vec![1.0],
            wintp: vec![2.0],
            iema: vec![2.6],
        };
        let osc = osc_update(&mut w, s, -4.0, 3.0, &mut st, 0.1, 0.05);
        assert_eq!(st.b[0], 1.0, "should freeze");
        // pinned to round(iema) on the grid
        assert!((w[0] - s * st.fint[0]).abs() < 1e-7);
        assert!(osc[0] == 0.0 || osc[0] == 1.0);
    }

    #[test]
    fn frozen_weight_ignores_sgd_proposal() {
        let s = 0.1;
        let mut st = OscState {
            f: vec![0.9],
            b: vec![1.0],
            fint: vec![3.0],
            psign: vec![0.0],
            wintp: vec![3.0],
            iema: vec![3.0],
        };
        for proposal in [-5.0f32, 0.0, 0.123, 7.0] {
            let mut w = vec![proposal];
            osc_update(&mut w, s, -4.0, 3.0, &mut st, 0.02, 0.01);
            assert!((w[0] - 0.3).abs() < 1e-7, "frozen weight moved to {}", w[0]);
        }
    }

    #[test]
    fn dampening_zero_on_grid() {
        let w = vec![0.1, -0.2, 0.3];
        assert!(dampening_loss(&w, 0.1, -4.0, 3.0) < 1e-12);
        let mut dw = vec![0.0; 3];
        dampening_bwd(&w, 0.1, -4.0, 3.0, 1.0, &mut dw);
        for d in dw {
            assert!(d.abs() < 1e-6);
        }
    }

    #[test]
    fn scale_index_layouts() {
        // dense [d_in, d_out] columns: group 1, n_scales = d_out
        assert_eq!(scale_index(0, 1, 3), 0);
        assert_eq!(scale_index(4, 1, 3), 1);
        // depthwise [C, 3] rows: group 3, n_scales = C
        assert_eq!(scale_index(2, 3, 5), 0);
        assert_eq!(scale_index(3, 3, 5), 1);
        assert_eq!(scale_index(14, 3, 5), 4);
        // per-tensor: always 0
        assert_eq!(scale_index(99, 1, 1), 0);
        assert_eq!(scale_index(99, 3, 1), 0);
    }

    #[test]
    fn per_channel_fq_uses_each_channels_grid() {
        // 2 channels (dense columns): channel 0 at s=0.1, channel 1 at s=1.0
        let w = vec![0.12, 0.12, -0.37, -0.37]; // [2, 2] row-major
        let scales = vec![0.1, 1.0];
        let q = fake_quant_pc(&w, &scales, 1, -4.0, 3.0);
        assert_eq!(q[0], 0.1); // 0.12/0.1 -> 1 -> 0.1
        assert_eq!(q[1], 0.0); // 0.12/1.0 -> 0
        assert!((q[2] - -0.4).abs() < 1e-6); // -3.7 -> clip -4 -> -0.4
        assert_eq!(q[3], 0.0); // -0.37 -> 0
        // n_scales = 1 reproduces the scalar function exactly
        assert_eq!(fake_quant_pc(&w, &[0.1], 1, -4.0, 3.0), fake_quant(&w, 0.1, -4.0, 3.0));
        assert_eq!(
            int_weights_pc(&w, &[0.1], 3, -4.0, 3.0),
            int_weights(&w, 0.1, -4.0, 3.0)
        );
    }

    #[test]
    fn per_channel_bwd_accumulates_per_channel_ds() {
        // dw layout [2, 3]: rows are channels (group 3)
        let w = vec![0.05, 0.0, 10.0, 0.26, -0.1, 0.0];
        let g = vec![1.0; 6];
        let scales = vec![0.1, 0.2];
        let mut dw = vec![0.0; 6];
        let mut ds = vec![0.0f32; 2];
        fake_quant_bwd_pc(Estimator::Lsq, &w, &g, &scales, 3, -4.0, 3.0, &mut dw, &mut ds);
        // element 2 (channel 0) is clipped: no dw, but p contributes to ds
        assert_eq!(dw[2], 0.0);
        assert!(dw[0] == 1.0 && dw[3] == 1.0);
        assert!(ds[0] != 0.0 && ds[1] != 0.0);
        // per-tensor wrapper agrees with the pc core on a single scale
        let mut dw_a = vec![0.0; 6];
        let mut ds_a = 0.0f32;
        fake_quant_bwd(Estimator::Lsq, &w, &g, 0.1, -4.0, 3.0, &mut dw_a, &mut ds_a);
        let mut dw_b = vec![0.0; 6];
        let mut ds_b = vec![0.0f32; 1];
        fake_quant_bwd_pc(Estimator::Lsq, &w, &g, &[0.1], 1, -4.0, 3.0, &mut dw_b, &mut ds_b);
        assert_eq!(dw_a, dw_b);
        assert_eq!(ds_a, ds_b[0]);
    }

    #[test]
    fn per_channel_osc_freezes_on_channel_grid() {
        // two dw channels with very different scales; both freeze and pin
        // to their own channel's grid
        let scales = vec![0.1f32, 1.0];
        let mut w = vec![0.26, 0.0, 0.0, 2.6, 0.0, 0.0];
        let mut st = OscState {
            f: vec![0.5; 6],
            b: vec![0.0; 6],
            fint: vec![0.0; 6],
            psign: vec![1.0; 6],
            wintp: vec![2.0, 0.0, 0.0, 2.0, 0.0, 0.0],
            iema: vec![2.6, 0.0, 0.0, 2.6, 0.0, 0.0],
        };
        osc_update_pc(&mut w, &scales, 3, -4.0, 3.0, &mut st, 0.1, 0.05);
        assert_eq!(st.b[0], 1.0);
        assert_eq!(st.b[3], 1.0);
        assert!((w[0] - 0.1 * st.fint[0]).abs() < 1e-7);
        assert!((w[3] - 1.0 * st.fint[3]).abs() < 1e-7);
    }

    #[test]
    fn per_channel_dampening_matches_scalar_on_uniform_scales() {
        let w = vec![0.13, -0.22, 0.31, 0.04];
        let a = dampening_loss(&w, 0.1, -4.0, 3.0);
        let b = dampening_loss_pc(&w, &[0.1, 0.1], 1, -4.0, 3.0);
        assert!((a - b).abs() < 1e-7);
        let mut dwa = vec![0.0; 4];
        let mut dwb = vec![0.0; 4];
        dampening_bwd(&w, 0.1, -4.0, 3.0, 0.5, &mut dwa);
        dampening_bwd_pc(&w, &[0.1, 0.1], 1, -4.0, 3.0, 0.5, &mut dwb);
        assert_eq!(dwa, dwb);
    }

    #[test]
    fn act_bwd_per_channel_matches_scalar_on_one_scale() {
        // [2, 3] activations on a binary-exact grid (s = 0.25, p = 7):
        // r = [-2, 3.2, 40, 0, 1.2, 7.2] covers the clip-at-zero,
        // in-range and clip-at-p arms
        let a = vec![-0.5, 0.8, 10.0, 0.0, 0.3, 1.8];
        let g = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let p = 7.0f32;
        // per-tensor: one scale slot, N_c = a.len()
        let mut da_s = vec![0.0; 6];
        let mut ds_s = vec![0.0f32; 1];
        act_quant_bwd_pc(&a, &g, &[0.25], p, &mut da_s, &mut ds_s);
        assert_eq!(da_s, vec![0.0, 2.0, 0.0, 4.0, 5.0, 0.0]);
        let gscale = 1.0 / (6.0f32 * p).sqrt();
        // 2*(3-3.2) + 3*7 + 4*0 + 5*(1-1.2) + 6*7 = 61.6
        assert!((ds_s[0] - 61.6 * gscale).abs() < 1e-4, "{ds_s:?}");
        // per-channel: 3 channels, each accumulates only its own columns
        let scales = vec![0.25f32; 3];
        let mut da_c = vec![0.0; 6];
        let mut ds_c = vec![0.0f32; 3];
        act_quant_bwd_pc(&a, &g, &scales, p, &mut da_c, &mut ds_c);
        assert_eq!(da_c, da_s, "uniform per-channel scales keep the STE gate");
        // N_c = 2 per channel instead of 6: gscale grows by sqrt(3)
        let gscale_c = 1.0 / (2.0f32 * p).sqrt();
        assert!((ds_c[0] - 0.0).abs() < 1e-6);
        assert!((ds_c[1] - (2.0 * -0.2 + 5.0 * -0.2) * gscale_c).abs() < 1e-4, "{ds_c:?}");
        assert!((ds_c[2] - (3.0 * 7.0 + 6.0 * 7.0) * gscale_c).abs() < 1e-4, "{ds_c:?}");
    }

    #[test]
    fn lsq_bwd_gates_outside_grid() {
        let w = vec![0.05, 10.0, -10.0];
        let g = vec![1.0, 1.0, 1.0];
        let mut dw = vec![0.0; 3];
        let mut ds = 0.0;
        fake_quant_bwd(Estimator::Lsq, &w, &g, 0.1, -4.0, 3.0, &mut dw, &mut ds);
        assert_eq!(dw[0], 1.0);
        assert_eq!(dw[1], 0.0);
        assert_eq!(dw[2], 0.0);
        assert!(ds != 0.0);
    }
}
