//! The native execution backend: a pure-Rust interpreter for the QAT
//! pipeline, behind the same [`Backend`](crate::runtime::Backend) trait as
//! the PJRT artifact replayer.
//!
//! No artifacts, no Python, no XLA: model states are generated
//! procedurally ([`model`]), and the train/eval/bnstats "artifacts" are
//! interpreted step functions ([`interp`]) built on the hot-path kernels
//! ([`kernels`]) that numerically mirror `python/compile/kernels/ref.py`.
//! This is what `cargo test` and CI run on a fresh checkout.
//!
//! Artifact naming: `native.<model>.<role>` (e.g. `native.mbv2.train_lsq`)
//! and `native.kernel.<name>` for the standalone kernel benches. The
//! `*_ref` kernel twins resolve to the same implementation — the native
//! interpreter *is* the reference.

pub mod interp;
pub mod kernels;
pub mod model;

pub use kernels::Estimator;

use crate::runtime::{ArtifactIndex, Backend, Signature, TensorSpec};
use crate::state::NamedTensors;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;

/// The artifact-free backend over the native model zoo.
pub struct NativeBackend {
    index: ArtifactIndex,
    models: BTreeMap<String, model::NativeModel>,
}

impl NativeBackend {
    pub fn new() -> Self {
        let mut models = BTreeMap::new();
        let mut infos = BTreeMap::new();
        for m in model::zoo() {
            infos.insert(m.name.clone(), m.info());
            models.insert(m.name.clone(), m);
        }
        let kernels = [
            ("kernel_fakequant", "native.kernel.fakequant"),
            ("kernel_fakequant_ref", "native.kernel.fakequant_ref"),
            ("kernel_osc", "native.kernel.osc"),
            ("kernel_osc_ref", "native.kernel.osc_ref"),
            ("kernel_qmm", "native.kernel.qmm"),
            ("kernel_qmm_ref", "native.kernel.qmm_ref"),
        ]
        .into_iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect();
        NativeBackend {
            index: ArtifactIndex { dir: PathBuf::new(), models: infos, kernels },
            models,
        }
    }

    fn model(&self, name: &str) -> Result<&model::NativeModel> {
        self.models
            .get(name)
            .with_context(|| format!("native backend has no model {name:?}"))
    }

    /// Run a standalone kernel "artifact" (the bench/golden entry points).
    fn run_kernel(&self, kernel: &str, sources: &[&NamedTensors]) -> Result<NamedTensors> {
        let get = |name: &str| -> Result<Tensor> {
            crate::runtime::resolve(sources, name)
                .with_context(|| format!("kernel {kernel}: missing input {name:?}"))
        };
        let scalar = |name: &str| -> Result<f32> { Ok(get(name)?.item()) };
        let mut out = NamedTensors::new();
        match kernel {
            "fakequant" | "fakequant_ref" => {
                let w = get("w")?;
                let q = kernels::fake_quant(&w.data, scalar("s")?, scalar("n")?, scalar("p")?);
                out.insert("out", Tensor::new(w.shape.clone(), q));
            }
            "qmm" | "qmm_ref" => {
                let x = get("x")?;
                let w = get("w")?;
                let (m, k) = (x.shape[0], x.shape[1]);
                let n = w.shape[1];
                anyhow::ensure!(w.shape[0] == k, "qmm: inner dims {} vs {}", w.shape[0], k);
                let z = kernels::quant_matmul(
                    &x.data,
                    &w.data,
                    m,
                    k,
                    n,
                    scalar("s")?,
                    scalar("n")?,
                    scalar("p")?,
                );
                out.insert("out", Tensor::new(vec![m, n], z));
            }
            "osc" | "osc_ref" => {
                let mut w = get("w")?;
                let mut st = kernels::OscState {
                    f: get("f")?.data,
                    b: get("b")?.data,
                    fint: get("fint")?.data,
                    psign: get("psign")?.data,
                    wintp: get("wintp")?.data,
                    iema: get("iema")?.data,
                };
                let osc = kernels::osc_update(
                    &mut w.data,
                    scalar("s")?,
                    scalar("n")?,
                    scalar("p")?,
                    &mut st,
                    scalar("m")?,
                    scalar("f_th")?,
                );
                let shape = w.shape.clone();
                out.insert("w_out", w);
                for (name, data) in [
                    ("f_out", st.f),
                    ("b_out", st.b),
                    ("fint_out", st.fint),
                    ("psign_out", st.psign),
                    ("wint_out", st.wintp),
                    ("iema_out", st.iema),
                    ("osc", osc),
                ] {
                    out.insert(name, Tensor::new(shape.clone(), data));
                }
            }
            other => bail!("unknown native kernel {other:?}"),
        }
        Ok(out)
    }

    fn kernel_signature(kernel: &str) -> Result<Signature> {
        let spec = |name: &str, shape: Vec<usize>| TensorSpec { name: name.into(), shape };
        let arr = |name: &str| spec(name, vec![64, 64]);
        let sc = |name: &str| spec(name, vec![]);
        Ok(match kernel {
            "fakequant" | "fakequant_ref" => Signature {
                inputs: vec![arr("w"), sc("s"), sc("n"), sc("p")],
                outputs: vec![arr("out")],
            },
            "qmm" | "qmm_ref" => Signature {
                inputs: vec![
                    spec("x", vec![32, 64]),
                    spec("w", vec![64, 48]),
                    sc("s"),
                    sc("n"),
                    sc("p"),
                ],
                outputs: vec![spec("out", vec![32, 48])],
            },
            "osc" | "osc_ref" => Signature {
                inputs: vec![
                    arr("w"),
                    sc("s"),
                    sc("n"),
                    sc("p"),
                    arr("f"),
                    arr("b"),
                    arr("fint"),
                    arr("psign"),
                    arr("wintp"),
                    arr("iema"),
                    sc("m"),
                    sc("f_th"),
                ],
                outputs: vec![
                    arr("w_out"),
                    arr("f_out"),
                    arr("b_out"),
                    arr("fint_out"),
                    arr("psign_out"),
                    arr("wint_out"),
                    arr("iema_out"),
                    arr("osc"),
                ],
            },
            other => bail!("unknown native kernel {other:?}"),
        })
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn index(&self) -> &ArtifactIndex {
        &self.index
    }

    fn initial_state(&self, model: &str) -> Result<NamedTensors> {
        Ok(self.model(model)?.initial_state())
    }

    fn signature(&self, artifact: &str) -> Result<Signature> {
        let rest = artifact
            .strip_prefix("native.")
            .with_context(|| format!("not a native artifact: {artifact:?}"))?;
        if let Some(kernel) = rest.strip_prefix("kernel.") {
            return Self::kernel_signature(kernel);
        }
        let (model_name, role) = rest
            .split_once('.')
            .with_context(|| format!("bad native artifact name {artifact:?}"))?;
        let m = self.model(model_name)?;
        let state = m.initial_state();
        // Eval/bnstats only bind the forward-pass state; train binds all.
        let state_input = |k: &str| {
            role.starts_with("train_") || k.starts_with("params/") || k.starts_with("bn/")
        };
        let mut inputs: Vec<TensorSpec> = state
            .map
            .iter()
            .filter(|(k, _)| state_input(k))
            .map(|(k, t)| TensorSpec { name: format!("state/{k}"), shape: t.shape.clone() })
            .collect();
        inputs.push(TensorSpec {
            name: "batch/x".into(),
            shape: vec![m.batch_size, m.input_hw, m.input_hw, 3],
        });
        inputs.push(TensorSpec { name: "batch/y".into(), shape: vec![m.batch_size, m.num_classes] });
        for k in crate::runtime::HYPER_KEYS {
            inputs.push(TensorSpec { name: format!("hyper/{k}"), shape: vec![] });
        }
        let scalar = |name: &str| TensorSpec { name: name.into(), shape: vec![] };
        let outputs: Vec<TensorSpec> = match role {
            "eval" => vec![
                scalar("correct"),
                scalar("loss"),
                TensorSpec { name: "pred".into(), shape: vec![m.batch_size] },
            ],
            "bnstats" => {
                let mut outs = Vec::new();
                for l in &m.layers {
                    if l.bn {
                        outs.push(TensorSpec {
                            name: format!("{}.bn_bm", l.name),
                            shape: vec![l.d_out],
                        });
                        outs.push(TensorSpec {
                            name: format!("{}.bn_bv", l.name),
                            shape: vec![l.d_out],
                        });
                    }
                    if l.aq {
                        outs.push(scalar(&format!("{}.absmean", l.name)));
                        // per-input-channel E|x| for per-channel
                        // activation-scale calibration
                        outs.push(TensorSpec {
                            name: format!("{}.absmean_pc", l.name),
                            shape: vec![l.d_in],
                        });
                    }
                }
                outs
            }
            _ => {
                let mut outs: Vec<TensorSpec> = state
                    .map
                    .iter()
                    .map(|(k, t)| TensorSpec {
                        name: format!("state/{k}"),
                        shape: t.shape.clone(),
                    })
                    .collect();
                for k in ["loss", "ce", "damp", "acc", "osc_frac", "frozen_frac"] {
                    outs.push(scalar(&format!("metrics/{k}")));
                }
                outs
            }
        };
        Ok(Signature { inputs, outputs })
    }

    fn execute(&self, artifact: &str, sources: &[&NamedTensors]) -> Result<NamedTensors> {
        let rest = artifact
            .strip_prefix("native.")
            .with_context(|| format!("not a native artifact: {artifact:?}"))?;
        if let Some(kernel) = rest.strip_prefix("kernel.") {
            return self.run_kernel(kernel, sources);
        }
        let (model_name, role) = rest
            .split_once('.')
            .with_context(|| format!("bad native artifact name {artifact:?}"))?;
        let m = self.model(model_name)?;
        match role {
            "eval" => interp::eval_step(m, sources),
            "bnstats" => interp::bnstats_step(m, sources),
            _ => {
                let est_name = role
                    .strip_prefix("train_")
                    .with_context(|| format!("unknown native role {role:?}"))?;
                let est = Estimator::parse(est_name)
                    .with_context(|| format!("unknown estimator {est_name:?}"))?;
                interp::train_step(m, est, sources)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_artifacts_execute_and_ref_twins_agree() {
        let be = NativeBackend::new();
        for key in ["kernel_fakequant", "kernel_osc", "kernel_qmm"] {
            let name = be.index.kernels[key].clone();
            let ref_name = be.index.kernels[&format!("{key}_ref")].clone();
            let sig = be.signature(&name).unwrap();
            let mut io = NamedTensors::new();
            for spec in &sig.inputs {
                let n = spec.num_elements().max(1);
                // scalars (s/m/f_th...) land on 0.11; arrays get a sweep
                let data: Vec<f32> =
                    (0..n).map(|i| if n == 1 { 0.11 } else { ((i % 31) as f32 - 15.0) * 0.013 }).collect();
                io.insert(spec.name.clone(), Tensor::new(spec.shape.clone(), data));
            }
            // grids need n < p to be meaningful
            io.insert("n", Tensor::scalar(-4.0));
            io.insert("p", Tensor::scalar(3.0));
            let a = be.execute(&name, &[&io]).unwrap();
            let b = be.execute(&ref_name, &[&io]).unwrap();
            assert!(!a.is_empty());
            for (k, va) in &a.map {
                let vb = b.get(k).unwrap();
                assert_eq!(va.data, vb.data, "{key}/{k} mismatch");
            }
        }
    }

    #[test]
    fn train_artifact_names_resolve() {
        let be = NativeBackend::new();
        let info = be.index().model("mbv2").unwrap();
        let name = &info.artifacts["train_lsq"];
        assert_eq!(name, "native.mbv2.train_lsq");
        assert!(be.signature(name).unwrap().inputs.iter().any(|s| s.name == "batch/x"));
        assert!(be.execute("native.mbv2.nope", &[]).is_err());
        assert!(be.execute("mbv2_lsq_train", &[]).is_err());
    }
}
