//! The native model zoo: compact separable networks over the synthetic
//! 16x16x3 corpus, mirroring the structural traits the paper's phenomena
//! need — low-bit interior layers with **few weights per output channel**
//! (depthwise-style 3-tap channel convolutions), 8-bit first/last layers,
//! batch norm after every hidden linear op.
//!
//! Layer inventory per model (names follow `python/compile/arch.py` style):
//! * `stem`   — full matmul `768 -> C`, BN + ReLU, 8-bit weights
//! * `b{i}.dw` — depthwise circular 3-tap channel conv (`[C, 3]` weights,
//!   3 weights per channel — the oscillation hot spot), BN + ReLU, low-bit.
//!   In the `*_2d` zoo members this is a true spatial 3x3 depthwise conv
//!   (`[C, 3, 3]` weights, stride/pad over an `[H, W, C]` channel-last
//!   block — 9 weights per channel, the paper's actual op shape)
//! * `b{i}.pw` — pointwise matmul `C -> C`, BN + ReLU, low-bit
//! * `l{i}.a/.b` — plain full matmuls (the ResNet-style no-depthwise zoo
//!   member), BN + ReLU, low-bit
//! * `head`   — full matmul `C -> 10` with bias, 8-bit weights
//!
//! State layout (same `group/tensor` naming as the PJRT artifacts):
//! `params/{layer}.w|.s|.as|.g|.beta|.bias`, `bn/{layer}.bn_m|.bn_v`,
//! `opt/<params key>` momenta and `osc/{w}#f|#b|#fint|#psign|#wintp|#iema`
//! Algorithm-1 state for every low-bit weight tensor.

use crate::rng::Pcg32;
use crate::runtime::manifest::{LayerInfo, ModelInfo};
use crate::state::NamedTensors;
use crate::tensor::Tensor;
use std::collections::BTreeMap;

/// How a layer mixes its input activations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerOp {
    /// dense matmul `[d_in, d_out]`
    Full,
    /// circular depthwise 3-tap channel conv, weights `[C, 3]`
    Dw,
    /// true 2-D spatial depthwise 3x3 conv over an `[H, W, C]`
    /// channel-last block, weights `[C, 3, 3]`
    DwSpatial,
}

/// Spatial geometry for [`LayerOp::DwSpatial`] layers. Activations are
/// flattened channel-last (`idx = (y * W + x) * C + c`), so `idx % C == c`
/// — per-channel activation scales of length `C` compose with the same
/// `i % n_scales` indexing every per-channel kernel already uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpatialSpec {
    /// square input side: activations are `hw_in * hw_in * channels` flat
    pub hw_in: usize,
    pub channels: usize,
    pub stride: usize,
    /// zero padding on every spatial edge
    pub pad: usize,
}

impl SpatialSpec {
    /// Fixed 3x3 kernel (the paper's depthwise-separable building block).
    pub const KERNEL: usize = 3;

    /// Output side length under stride/pad.
    pub fn hw_out(&self) -> usize {
        (self.hw_in + 2 * self.pad - Self::KERNEL) / self.stride + 1
    }

    /// Flat input activation length.
    pub fn d_in(&self) -> usize {
        self.hw_in * self.hw_in * self.channels
    }

    /// Flat output activation length.
    pub fn d_out(&self) -> usize {
        let h = self.hw_out();
        h * h * self.channels
    }
}

/// One native layer specification.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub name: String,
    pub op: LayerOp,
    /// kind tag used by the analysis tables: "full" | "dw" | "pw"
    pub kind: &'static str,
    pub d_in: usize,
    pub d_out: usize,
    pub bn: bool,
    pub relu: bool,
    /// weight-quantizer grid class: "8bit" (first/last) or "low"
    pub wq: &'static str,
    /// whether this layer's input activations are quantized (LSQ, unsigned)
    pub aq: bool,
    pub bias: bool,
    /// geometry for [`LayerOp::DwSpatial`]; `None` for 1-D ops
    pub spatial: Option<SpatialSpec>,
}

impl LayerSpec {
    /// Weight-tensor shape.
    pub fn w_shape(&self) -> Vec<usize> {
        match self.op {
            LayerOp::Full => vec![self.d_in, self.d_out],
            LayerOp::Dw => vec![self.d_out, 3],
            LayerOp::DwSpatial => {
                let sp = self.spatial.expect("DwSpatial layer without SpatialSpec");
                vec![sp.channels, SpatialSpec::KERNEL, SpatialSpec::KERNEL]
            }
        }
    }

    /// Per-channel scale layout `group` (see `kernels::scale_index`):
    /// dense weights carry one scale per output column (`group = 1`),
    /// depthwise `[C, 3]` rows one scale per channel row (`group = 3`),
    /// spatial depthwise `[C, 3, 3]` planes one per channel (`group = 9`).
    pub fn scale_group(&self) -> usize {
        match self.op {
            LayerOp::Full => 1,
            LayerOp::Dw => 3,
            LayerOp::DwSpatial => SpatialSpec::KERNEL * SpatialSpec::KERNEL,
        }
    }

    /// Number of weight-scale channels in the per-channel layout: one per
    /// output column for dense layers, one per channel for depthwise.
    pub fn w_channels(&self) -> usize {
        match self.op {
            LayerOp::Full | LayerOp::Dw => self.d_out,
            LayerOp::DwSpatial => self.spatial.expect("DwSpatial layer without SpatialSpec").channels,
        }
    }

    /// Number of activation-scale channels admitted on this layer's input.
    /// A spatial depthwise reads `[H, W, C]` channel-last, so its input
    /// carries `C` scale channels, not `d_in`.
    pub fn act_channels(&self) -> usize {
        match self.op {
            LayerOp::DwSpatial => self.spatial.expect("DwSpatial layer without SpatialSpec").channels,
            _ => self.d_in,
        }
    }
}

/// A native model: ordered layers over the synthetic corpus.
#[derive(Debug, Clone)]
pub struct NativeModel {
    pub name: String,
    pub batch_size: usize,
    pub num_classes: usize,
    pub input_hw: usize,
    pub layers: Vec<LayerSpec>,
}

fn full(name: &str, kind: &'static str, d_in: usize, d_out: usize, wq: &'static str, aq: bool) -> LayerSpec {
    LayerSpec {
        name: name.into(),
        op: LayerOp::Full,
        kind,
        d_in,
        d_out,
        bn: true,
        relu: true,
        wq,
        aq,
        bias: false,
        spatial: None,
    }
}

/// Build one zoo member. `dw = true` gives MobileNet-style dw/pw blocks,
/// `false` gives plain full-layer blocks (the ResNet stand-in).
fn separable(name: &str, width: usize, blocks: usize, dw: bool) -> NativeModel {
    let d_in0 = 16 * 16 * 3;
    let mut layers = vec![full("stem", "full", d_in0, width, "8bit", false)];
    for b in 1..=blocks {
        if dw {
            layers.push(LayerSpec {
                name: format!("b{b}.dw"),
                op: LayerOp::Dw,
                kind: "dw",
                d_in: width,
                d_out: width,
                bn: true,
                relu: true,
                wq: "low",
                aq: true,
                bias: false,
                spatial: None,
            });
            layers.push(full(&format!("b{b}.pw"), "pw", width, width, "low", true));
        } else {
            layers.push(full(&format!("l{b}.a"), "full", width, width, "low", true));
            layers.push(full(&format!("l{b}.b"), "full", width, width, "low", true));
        }
    }
    let mut head = full("head", "full", width, 10, "8bit", true);
    head.bn = false;
    head.relu = false;
    head.bias = true;
    layers.push(head);
    NativeModel {
        name: name.into(),
        batch_size: 16,
        num_classes: 10,
        input_hw: 16,
        layers,
    }
}

/// Build one 2-D zoo member: MobileNet-style blocks with true spatial
/// 3x3 depthwise convs over `[hw, hw, channels]` channel-last blocks.
/// `stride2_at` marks the block whose depthwise stage halves the side
/// (stride 2, pad 1); all other blocks are stride 1 / pad 1 ("same").
fn separable2d(
    name: &str,
    channels: usize,
    hw: usize,
    blocks: usize,
    stride2_at: Option<usize>,
) -> NativeModel {
    let d_in0 = 16 * 16 * 3;
    let mut side = hw;
    let mut layers = vec![full("stem", "full", d_in0, side * side * channels, "8bit", false)];
    for b in 1..=blocks {
        let stride = if stride2_at == Some(b) { 2 } else { 1 };
        let sp = SpatialSpec {
            hw_in: side,
            channels,
            stride,
            pad: 1,
        };
        let (d_in, d_out) = (sp.d_in(), sp.d_out());
        layers.push(LayerSpec {
            name: format!("b{b}.dw"),
            op: LayerOp::DwSpatial,
            kind: "dw",
            d_in,
            d_out,
            bn: true,
            relu: true,
            wq: "low",
            aq: true,
            bias: false,
            spatial: Some(sp),
        });
        side = sp.hw_out();
        layers.push(full(&format!("b{b}.pw"), "pw", d_out, d_out, "low", true));
    }
    let mut head = full("head", "full", side * side * channels, 10, "8bit", true);
    head.bn = false;
    head.relu = false;
    head.bias = true;
    layers.push(head);
    NativeModel {
        name: name.into(),
        batch_size: 16,
        num_classes: 10,
        input_hw: 16,
        layers,
    }
}

/// The models the experiment drivers reference: the original 1-D zoo
/// (kept verbatim for fixture/ckpt continuity) plus the spatial members.
pub fn zoo() -> Vec<NativeModel> {
    vec![
        separable("mbv2", 48, 3, true),
        separable("resnet18", 64, 2, false),
        separable("mbv3", 40, 2, true),
        separable("efflite", 32, 2, true),
        separable2d("mbv2_2d", 12, 4, 3, None),
        separable2d("efflite_2d", 8, 4, 2, Some(2)),
    ]
}

/// Zoo lookup by name (the deploy export needs the layer structure, not
/// just the `ModelInfo` index row).
pub fn zoo_model(name: &str) -> Option<NativeModel> {
    zoo().into_iter().find(|m| m.name == name)
}

/// Per-model deterministic seed for weight init.
fn seed_of(name: &str) -> u64 {
    name.bytes().fold(0x9e3779b97f4a7c15u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

impl NativeModel {
    /// Names of weight tensors on the runtime low-bit grid.
    pub fn lowbit(&self) -> Vec<String> {
        self.layers
            .iter()
            .filter(|l| l.wq == "low")
            .map(|l| format!("{}.w", l.name))
            .collect()
    }

    /// Deterministic initial training state (pure function of the model).
    pub fn initial_state(&self) -> NamedTensors {
        let mut rng = Pcg32::new(seed_of(&self.name), 0xa11ce);
        let mut s = NamedTensors::new();
        for l in &self.layers {
            let shape = l.w_shape();
            let data: Vec<f32> = match l.op {
                LayerOp::Full => {
                    let lim = (6.0 / (l.d_in + l.d_out) as f32).sqrt();
                    (0..l.d_in * l.d_out).map(|_| rng.uniform(-lim, lim)).collect()
                }
                LayerOp::Dw => {
                    // near-identity: strong center tap, noisy side taps, so
                    // signal flows at init and weights spread across bins
                    let mut v = Vec::with_capacity(l.d_out * 3);
                    for _ in 0..l.d_out {
                        v.push(rng.uniform(-0.35, 0.35));
                        v.push(rng.uniform(0.6, 1.4));
                        v.push(rng.uniform(-0.35, 0.35));
                    }
                    v
                }
                LayerOp::DwSpatial => {
                    // same near-identity idea in 2-D: strong center tap of
                    // each 3x3 plane, noisy surround taps
                    let channels = l.spatial.expect("DwSpatial layer without SpatialSpec").channels;
                    let mut v = Vec::with_capacity(channels * 9);
                    for _ in 0..channels {
                        for t in 0..9 {
                            if t == 4 {
                                v.push(rng.uniform(0.6, 1.4));
                            } else {
                                v.push(rng.uniform(-0.35, 0.35));
                            }
                        }
                    }
                    v
                }
            };
            let w = Tensor::new(shape.clone(), data);
            // absmax-style init; prepare_qat replaces this with the MSE
            // grid-searched scale before any QAT run
            s.insert(format!("params/{}.s", l.name), Tensor::scalar(w.abs_max().max(1e-4) / 7.0));
            s.insert(format!("params/{}.w", l.name), w);
            if l.aq {
                s.insert(format!("params/{}.as", l.name), Tensor::scalar(1.0));
            }
            if l.bias {
                s.insert(format!("params/{}.bias", l.name), Tensor::zeros(&[l.d_out]));
            }
            if l.bn {
                s.insert(format!("params/{}.g", l.name), Tensor::filled(&[l.d_out], 1.0));
                s.insert(format!("params/{}.beta", l.name), Tensor::zeros(&[l.d_out]));
                s.insert(format!("bn/{}.bn_m", l.name), Tensor::zeros(&[l.d_out]));
                s.insert(format!("bn/{}.bn_v", l.name), Tensor::filled(&[l.d_out], 1.0));
            }
            if l.wq == "low" {
                for suffix in ["f", "b", "fint", "psign", "wintp", "iema"] {
                    s.insert(format!("osc/{}.w#{suffix}", l.name), Tensor::zeros(&shape));
                }
            }
        }
        // SGD momentum buffer per parameter tensor
        let params: Vec<(String, Vec<usize>)> = s
            .names_under("params/")
            .map(String::from)
            .collect::<Vec<_>>()
            .into_iter()
            .map(|k| {
                let shape = s.get(&k).unwrap().shape.clone();
                (k, shape)
            })
            .collect();
        for (k, shape) in params {
            let rest = k.strip_prefix("params/").unwrap();
            s.insert(format!("opt/{rest}"), Tensor::zeros(&shape));
        }
        s
    }

    /// The [`ModelInfo`] row this model exposes through the artifact index.
    pub fn info(&self) -> ModelInfo {
        let mut layers = BTreeMap::new();
        for l in &self.layers {
            layers.insert(
                l.name.clone(),
                LayerInfo {
                    kind: l.kind.to_string(),
                    weight: format!("{}.w", l.name),
                    bn: l.bn,
                    // per-channel scale-channel count: channels for spatial
                    // depthwise (weights are [C, 3, 3]), d_out otherwise
                    cout: l.w_channels(),
                    wq: l.wq.to_string(),
                },
            );
        }
        let mut artifacts = BTreeMap::new();
        for role in ["train_lsq", "train_ewgs", "train_dsq", "train_psg", "train_pact", "eval", "bnstats"] {
            artifacts.insert(role.to_string(), format!("native.{}.{role}", self.name));
        }
        let param_count = self
            .initial_state()
            .map
            .iter()
            .filter(|(k, _)| k.starts_with("params/"))
            .map(|(_, t)| t.len())
            .sum();
        ModelInfo {
            name: self.name.clone(),
            batch_size: self.batch_size,
            num_classes: self.num_classes,
            input_hw: self.input_hw,
            param_count,
            params_bin: String::new(),
            lowbit: self.lowbit(),
            layers,
            artifacts,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_models_are_well_formed() {
        for m in zoo() {
            assert_eq!(m.layers.first().unwrap().name, "stem");
            assert_eq!(m.layers.last().unwrap().name, "head");
            assert!(!m.lowbit().is_empty(), "{} has no low-bit weights", m.name);
            let info = m.info();
            assert!(info.param_count > 10_000, "{} too small", m.name);
            assert!(info.artifacts.contains_key("train_lsq"));
            assert!(info.artifacts.contains_key("eval"));
            assert!(info.artifacts.contains_key("bnstats"));
            if m.name == "resnet18" {
                assert!(info.depthwise().is_empty());
            } else {
                assert!(!info.depthwise().is_empty());
            }
        }
    }

    #[test]
    fn spatial_zoo_members_have_consistent_geometry() {
        for name in ["mbv2_2d", "efflite_2d"] {
            let m = zoo_model(name).unwrap();
            let mut d_prev = None;
            let mut saw_spatial = false;
            for l in &m.layers {
                if let Some(prev) = d_prev {
                    assert_eq!(l.d_in, prev, "{name}/{}: d_in breaks the chain", l.name);
                }
                d_prev = Some(l.d_out);
                if l.op == LayerOp::DwSpatial {
                    saw_spatial = true;
                    let sp = l.spatial.unwrap();
                    assert_eq!(l.d_in, sp.d_in());
                    assert_eq!(l.d_out, sp.d_out());
                    assert_eq!(l.w_shape(), vec![sp.channels, 3, 3]);
                    assert_eq!(l.scale_group(), 9);
                    assert_eq!(l.w_channels(), sp.channels);
                    assert_eq!(l.act_channels(), sp.channels);
                    // channel-last flat layout: positions divide cleanly
                    assert_eq!(l.d_in % sp.channels, 0);
                    assert_eq!(l.d_out % sp.channels, 0);
                } else {
                    assert!(l.spatial.is_none());
                }
            }
            assert!(saw_spatial, "{name} has no spatial depthwise layer");
            let w = m.initial_state();
            let sp_layer = m.layers.iter().find(|l| l.op == LayerOp::DwSpatial).unwrap();
            let t = w.get(&format!("params/{}.w", sp_layer.name)).unwrap();
            assert_eq!(t.shape, sp_layer.w_shape());
            // strong center taps
            let c = sp_layer.spatial.unwrap().channels;
            for ch in 0..c {
                assert!(t.data[ch * 9 + 4] >= 0.6, "{name} center tap too weak");
            }
        }
        // efflite_2d block 2 downsamples 4x4 -> 2x2
        let m = zoo_model("efflite_2d").unwrap();
        let l = m.layers.iter().find(|l| l.name == "b2.dw").unwrap();
        let sp = l.spatial.unwrap();
        assert_eq!(sp.stride, 2);
        assert_eq!(sp.hw_out(), 2);
        assert_eq!(l.d_out, 2 * 2 * 8);
    }

    #[test]
    fn initial_state_is_deterministic_and_complete() {
        let models = zoo();
        let m = &models[0];
        let a = m.initial_state();
        let b = m.initial_state();
        assert_eq!(a.map, b.map);
        for l in &m.layers {
            assert!(a.get(&format!("params/{}.w", l.name)).is_some());
            assert!(a.get(&format!("opt/{}.w", l.name)).is_some());
            if l.bn {
                assert!(a.get(&format!("bn/{}.bn_m", l.name)).is_some());
            }
            if l.wq == "low" {
                assert!(a.get(&format!("osc/{}.w#f", l.name)).is_some());
            }
        }
    }
}
