//! The native model zoo: compact separable networks over the synthetic
//! 16x16x3 corpus, mirroring the structural traits the paper's phenomena
//! need — low-bit interior layers with **few weights per output channel**
//! (depthwise-style 3-tap channel convolutions), 8-bit first/last layers,
//! batch norm after every hidden linear op.
//!
//! Layer inventory per model (names follow `python/compile/arch.py` style):
//! * `stem`   — full matmul `768 -> C`, BN + ReLU, 8-bit weights
//! * `b{i}.dw` — depthwise circular 3-tap channel conv (`[C, 3]` weights,
//!   3 weights per channel — the oscillation hot spot), BN + ReLU, low-bit
//! * `b{i}.pw` — pointwise matmul `C -> C`, BN + ReLU, low-bit
//! * `l{i}.a/.b` — plain full matmuls (the ResNet-style no-depthwise zoo
//!   member), BN + ReLU, low-bit
//! * `head`   — full matmul `C -> 10` with bias, 8-bit weights
//!
//! State layout (same `group/tensor` naming as the PJRT artifacts):
//! `params/{layer}.w|.s|.as|.g|.beta|.bias`, `bn/{layer}.bn_m|.bn_v`,
//! `opt/<params key>` momenta and `osc/{w}#f|#b|#fint|#psign|#wintp|#iema`
//! Algorithm-1 state for every low-bit weight tensor.

use crate::rng::Pcg32;
use crate::runtime::manifest::{LayerInfo, ModelInfo};
use crate::state::NamedTensors;
use crate::tensor::Tensor;
use std::collections::BTreeMap;

/// How a layer mixes its input activations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerOp {
    /// dense matmul `[d_in, d_out]`
    Full,
    /// circular depthwise 3-tap channel conv, weights `[C, 3]`
    Dw,
}

/// One native layer specification.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub name: String,
    pub op: LayerOp,
    /// kind tag used by the analysis tables: "full" | "dw" | "pw"
    pub kind: &'static str,
    pub d_in: usize,
    pub d_out: usize,
    pub bn: bool,
    pub relu: bool,
    /// weight-quantizer grid class: "8bit" (first/last) or "low"
    pub wq: &'static str,
    /// whether this layer's input activations are quantized (LSQ, unsigned)
    pub aq: bool,
    pub bias: bool,
}

impl LayerSpec {
    /// Weight-tensor shape.
    pub fn w_shape(&self) -> Vec<usize> {
        match self.op {
            LayerOp::Full => vec![self.d_in, self.d_out],
            LayerOp::Dw => vec![self.d_out, 3],
        }
    }

    /// Per-channel scale layout `group` (see `kernels::scale_index`):
    /// dense weights carry one scale per output column (`group = 1`),
    /// depthwise `[C, 3]` rows one scale per channel row (`group = 3`).
    pub fn scale_group(&self) -> usize {
        match self.op {
            LayerOp::Full => 1,
            LayerOp::Dw => 3,
        }
    }
}

/// A native model: ordered layers over the synthetic corpus.
#[derive(Debug, Clone)]
pub struct NativeModel {
    pub name: String,
    pub batch_size: usize,
    pub num_classes: usize,
    pub input_hw: usize,
    pub layers: Vec<LayerSpec>,
}

fn full(name: &str, kind: &'static str, d_in: usize, d_out: usize, wq: &'static str, aq: bool) -> LayerSpec {
    LayerSpec {
        name: name.into(),
        op: LayerOp::Full,
        kind,
        d_in,
        d_out,
        bn: true,
        relu: true,
        wq,
        aq,
        bias: false,
    }
}

/// Build one zoo member. `dw = true` gives MobileNet-style dw/pw blocks,
/// `false` gives plain full-layer blocks (the ResNet stand-in).
fn separable(name: &str, width: usize, blocks: usize, dw: bool) -> NativeModel {
    let d_in0 = 16 * 16 * 3;
    let mut layers = vec![full("stem", "full", d_in0, width, "8bit", false)];
    for b in 1..=blocks {
        if dw {
            layers.push(LayerSpec {
                name: format!("b{b}.dw"),
                op: LayerOp::Dw,
                kind: "dw",
                d_in: width,
                d_out: width,
                bn: true,
                relu: true,
                wq: "low",
                aq: true,
                bias: false,
            });
            layers.push(full(&format!("b{b}.pw"), "pw", width, width, "low", true));
        } else {
            layers.push(full(&format!("l{b}.a"), "full", width, width, "low", true));
            layers.push(full(&format!("l{b}.b"), "full", width, width, "low", true));
        }
    }
    let mut head = full("head", "full", width, 10, "8bit", true);
    head.bn = false;
    head.relu = false;
    head.bias = true;
    layers.push(head);
    NativeModel {
        name: name.into(),
        batch_size: 16,
        num_classes: 10,
        input_hw: 16,
        layers,
    }
}

/// The four models the experiment drivers reference.
pub fn zoo() -> Vec<NativeModel> {
    vec![
        separable("mbv2", 48, 3, true),
        separable("resnet18", 64, 2, false),
        separable("mbv3", 40, 2, true),
        separable("efflite", 32, 2, true),
    ]
}

/// Zoo lookup by name (the deploy export needs the layer structure, not
/// just the `ModelInfo` index row).
pub fn zoo_model(name: &str) -> Option<NativeModel> {
    zoo().into_iter().find(|m| m.name == name)
}

/// Per-model deterministic seed for weight init.
fn seed_of(name: &str) -> u64 {
    name.bytes().fold(0x9e3779b97f4a7c15u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

impl NativeModel {
    /// Names of weight tensors on the runtime low-bit grid.
    pub fn lowbit(&self) -> Vec<String> {
        self.layers
            .iter()
            .filter(|l| l.wq == "low")
            .map(|l| format!("{}.w", l.name))
            .collect()
    }

    /// Deterministic initial training state (pure function of the model).
    pub fn initial_state(&self) -> NamedTensors {
        let mut rng = Pcg32::new(seed_of(&self.name), 0xa11ce);
        let mut s = NamedTensors::new();
        for l in &self.layers {
            let shape = l.w_shape();
            let data: Vec<f32> = match l.op {
                LayerOp::Full => {
                    let lim = (6.0 / (l.d_in + l.d_out) as f32).sqrt();
                    (0..l.d_in * l.d_out).map(|_| rng.uniform(-lim, lim)).collect()
                }
                LayerOp::Dw => {
                    // near-identity: strong center tap, noisy side taps, so
                    // signal flows at init and weights spread across bins
                    let mut v = Vec::with_capacity(l.d_out * 3);
                    for _ in 0..l.d_out {
                        v.push(rng.uniform(-0.35, 0.35));
                        v.push(rng.uniform(0.6, 1.4));
                        v.push(rng.uniform(-0.35, 0.35));
                    }
                    v
                }
            };
            let w = Tensor::new(shape.clone(), data);
            // absmax-style init; prepare_qat replaces this with the MSE
            // grid-searched scale before any QAT run
            s.insert(format!("params/{}.s", l.name), Tensor::scalar(w.abs_max().max(1e-4) / 7.0));
            s.insert(format!("params/{}.w", l.name), w);
            if l.aq {
                s.insert(format!("params/{}.as", l.name), Tensor::scalar(1.0));
            }
            if l.bias {
                s.insert(format!("params/{}.bias", l.name), Tensor::zeros(&[l.d_out]));
            }
            if l.bn {
                s.insert(format!("params/{}.g", l.name), Tensor::filled(&[l.d_out], 1.0));
                s.insert(format!("params/{}.beta", l.name), Tensor::zeros(&[l.d_out]));
                s.insert(format!("bn/{}.bn_m", l.name), Tensor::zeros(&[l.d_out]));
                s.insert(format!("bn/{}.bn_v", l.name), Tensor::filled(&[l.d_out], 1.0));
            }
            if l.wq == "low" {
                for suffix in ["f", "b", "fint", "psign", "wintp", "iema"] {
                    s.insert(format!("osc/{}.w#{suffix}", l.name), Tensor::zeros(&shape));
                }
            }
        }
        // SGD momentum buffer per parameter tensor
        let params: Vec<(String, Vec<usize>)> = s
            .names_under("params/")
            .map(String::from)
            .collect::<Vec<_>>()
            .into_iter()
            .map(|k| {
                let shape = s.get(&k).unwrap().shape.clone();
                (k, shape)
            })
            .collect();
        for (k, shape) in params {
            let rest = k.strip_prefix("params/").unwrap();
            s.insert(format!("opt/{rest}"), Tensor::zeros(&shape));
        }
        s
    }

    /// The [`ModelInfo`] row this model exposes through the artifact index.
    pub fn info(&self) -> ModelInfo {
        let mut layers = BTreeMap::new();
        for l in &self.layers {
            layers.insert(
                l.name.clone(),
                LayerInfo {
                    kind: l.kind.to_string(),
                    weight: format!("{}.w", l.name),
                    bn: l.bn,
                    cout: l.d_out,
                    wq: l.wq.to_string(),
                },
            );
        }
        let mut artifacts = BTreeMap::new();
        for role in ["train_lsq", "train_ewgs", "train_dsq", "train_psg", "train_pact", "eval", "bnstats"] {
            artifacts.insert(role.to_string(), format!("native.{}.{role}", self.name));
        }
        let param_count = self
            .initial_state()
            .map
            .iter()
            .filter(|(k, _)| k.starts_with("params/"))
            .map(|(_, t)| t.len())
            .sum();
        ModelInfo {
            name: self.name.clone(),
            batch_size: self.batch_size,
            num_classes: self.num_classes,
            input_hw: self.input_hw,
            param_count,
            params_bin: String::new(),
            lowbit: self.lowbit(),
            layers,
            artifacts,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_models_are_well_formed() {
        for m in zoo() {
            assert_eq!(m.layers.first().unwrap().name, "stem");
            assert_eq!(m.layers.last().unwrap().name, "head");
            assert!(!m.lowbit().is_empty(), "{} has no low-bit weights", m.name);
            let info = m.info();
            assert!(info.param_count > 10_000, "{} too small", m.name);
            assert!(info.artifacts.contains_key("train_lsq"));
            assert!(info.artifacts.contains_key("eval"));
            assert!(info.artifacts.contains_key("bnstats"));
            if m.name == "resnet18" {
                assert!(info.depthwise().is_empty());
            } else {
                assert!(!info.depthwise().is_empty());
            }
        }
    }

    #[test]
    fn initial_state_is_deterministic_and_complete() {
        let models = zoo();
        let m = &models[0];
        let a = m.initial_state();
        let b = m.initial_state();
        assert_eq!(a.map, b.map);
        for l in &m.layers {
            assert!(a.get(&format!("params/{}.w", l.name)).is_some());
            assert!(a.get(&format!("opt/{}.w", l.name)).is_some());
            if l.bn {
                assert!(a.get(&format!("bn/{}.bn_m", l.name)).is_some());
            }
            if l.wq == "low" {
                assert!(a.get(&format!("osc/{}.w#f", l.name)).is_some());
            }
        }
    }
}
