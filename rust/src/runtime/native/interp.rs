//! The native step interpreter: forward/backward of a [`NativeModel`]
//! under the same I/O contract as the compiled PJRT train/eval/bnstats
//! artifacts.
//!
//! * `train_step` — quantized forward (LSQ weight + unsigned activation
//!   fake-quant), softmax cross-entropy + dampening loss, full backward
//!   with the selected gradient estimator, SGD + momentum, BN
//!   running-stat EMA update and the Algorithm-1 oscillation/freezing
//!   update; returns `state/...` + `metrics/...`.
//! * `eval_step` — inference forward (BN running stats); returns
//!   `correct` and `loss`.
//! * `bnstats_step` — train-mode forward; returns per-BN-layer batch
//!   statistics (`{layer}.bn_bm` / `{layer}.bn_bv`) and per-site
//!   calibration means (`{layer}.absmean`).
//!
//! Everything is f32 with round-half-to-even grid math, matching
//! `python/compile/kernels/ref.py` bit-for-bit on the kernel paths.

use super::kernels::{self, Estimator, OscState};
use super::model::{LayerOp, NativeModel};
use crate::runtime::resolve;
use crate::state::NamedTensors;
use crate::tensor::Tensor;
use anyhow::{Context, Result};

/// Batch-norm variance epsilon, shared with the deploy export's BN fold.
pub const BN_EPS: f32 = 1e-5;

/// Hyper scalars threaded into every artifact call.
#[derive(Debug, Clone, Copy)]
struct Hyper {
    lr: f32,
    lam: f32,
    f_th: f32,
    m_osc: f32,
    bn_mom: f32,
    mu: f32,
    n_w: f32,
    p_w: f32,
    p_a: f32,
    wq_on: bool,
    aq_on: bool,
}

fn req(sources: &[&NamedTensors], name: &str) -> Result<Tensor> {
    resolve(sources, name).with_context(|| format!("native: unresolved input {name:?}"))
}

fn scalar(sources: &[&NamedTensors], name: &str) -> Result<f32> {
    Ok(req(sources, name)?.item())
}

fn hyper(sources: &[&NamedTensors]) -> Result<Hyper> {
    Ok(Hyper {
        lr: scalar(sources, "hyper/lr")?,
        lam: scalar(sources, "hyper/lam")?,
        f_th: scalar(sources, "hyper/f_th")?,
        m_osc: scalar(sources, "hyper/m_osc")?,
        bn_mom: scalar(sources, "hyper/bn_mom")?,
        mu: scalar(sources, "hyper/mu")?,
        n_w: scalar(sources, "hyper/n_w")?,
        p_w: scalar(sources, "hyper/p_w")?,
        p_a: scalar(sources, "hyper/p_a")?,
        wq_on: scalar(sources, "hyper/wq_on")? > 0.5,
        aq_on: scalar(sources, "hyper/aq_on")? > 0.5,
    })
}

/// Per-layer forward cache (everything backward needs).
struct LayerFwd {
    /// layer input before activation quantization, [B * d_in]
    a_in: Vec<f32>,
    /// layer input actually fed to the linear op (quantized or same)
    a_q: Vec<f32>,
    /// effective (fake-quantized or raw) weights used
    w_eff: Vec<f32>,
    /// linear output, [B * d_out]
    z: Vec<f32>,
    /// BN caches (empty when the layer has no BN)
    bn_mean: Vec<f32>,
    bn_var: Vec<f32>,
    xhat: Vec<f32>,
    /// post-BN post-activation output, [B * d_out]
    out: Vec<f32>,
    /// act-quant bookkeeping: one scale (per-tensor) or one per input
    /// channel (`[d_in]`, element `i` of a `[B, d_in]` activation uses
    /// `act_scales[i % d_in]`), plus the scale tensor's shape (the
    /// gradient tensor must mirror it)
    act_scales: Vec<f32>,
    act_scale_shape: Vec<usize>,
    act_p: f32,
    act_quantized: bool,
    /// weight-quant bookkeeping: one scale (per-tensor) or one per
    /// output channel, plus the element-to-channel layout `group`
    /// (see `kernels::scale_index`) and the scale tensor's shape (the
    /// gradient tensor must mirror it)
    w_scales: Vec<f32>,
    w_group: usize,
    w_scale_shape: Vec<usize>,
    w_n: f32,
    w_p: f32,
    w_quantized: bool,
}

struct Forward {
    layers: Vec<LayerFwd>,
    logits: Vec<f32>,
}

/// BN statistics source for the forward pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BnMode {
    /// batch statistics (training / bnstats calibration)
    Batch,
    /// running EMA statistics (inference)
    Running,
}

fn forward(
    model: &NativeModel,
    sources: &[&NamedTensors],
    h: &Hyper,
    bn_mode: BnMode,
) -> Result<Forward> {
    let x = req(sources, "batch/x")?;
    let b = *x.shape.first().context("batch/x missing batch dim")?;
    let mut act = x.data.clone(); // [B, 768] row-major (flattened NHWC)
    let mut layers = Vec::with_capacity(model.layers.len());

    for l in &model.layers {
        let (d_in, d_out) = (l.d_in, l.d_out);
        anyhow::ensure!(
            act.len() == b * d_in,
            "layer {}: input has {} elements, want {}x{}",
            l.name,
            act.len(),
            b,
            d_in
        );
        let a_in = act;

        // --- input activation fake-quant (unsigned LSQ grid [0, p]) ---
        // The scale tensor is a scalar (per-tensor LSQ) or a vector of
        // one scale per input channel — [d_in] for 1-D layers, [C] for
        // spatial depthwise (channel-last layout makes `i % C` the
        // channel of flat element `i`, so the same `i % n_scales`
        // indexing covers both).
        let act_quantized = l.aq && h.aq_on;
        let act_p = if l.wq == "8bit" { 255.0 } else { h.p_a };
        let (act_scales, act_scale_shape) = if act_quantized {
            let as_t = req(sources, &format!("params/{}.as", l.name))?;
            anyhow::ensure!(
                as_t.len() == 1 || as_t.len() == l.act_channels(),
                "layer {}: {} activation scales for {} input channels",
                l.name,
                as_t.len(),
                l.act_channels()
            );
            let scales: Vec<f32> = as_t.data.iter().map(|&v| v.max(1e-8)).collect();
            (scales, as_t.shape.clone())
        } else {
            (vec![1.0], vec![])
        };
        let a_q = if act_quantized {
            kernels::fake_quant_pc(&a_in, &act_scales, 1, 0.0, act_p)
        } else {
            a_in.clone()
        };

        // --- weights (fake-quantized on the layer's grid when gated on) ---
        // The scale tensor is a scalar (per-tensor LSQ) or a [d_out]
        // vector (per-channel LSQ); all grid math below indexes it
        // through the layer's channel layout.
        let w = req(sources, &format!("params/{}.w", l.name))?;
        let w_quantized = h.wq_on;
        let (w_n, w_p) = if l.wq == "8bit" { (-128.0, 127.0) } else { (h.n_w, h.p_w) };
        let s_t = req(sources, &format!("params/{}.s", l.name))?;
        let w_scales: Vec<f32> = s_t.data.iter().map(|&v| v.max(1e-8)).collect();
        let w_group = l.scale_group();
        let w_eff = if w_quantized {
            kernels::fake_quant_pc(&w.data, &w_scales, w_group, w_n, w_p)
        } else {
            w.data.clone()
        };

        // --- linear op ---
        let mut z = vec![0.0f32; b * d_out];
        match l.op {
            LayerOp::Full => {
                for bi in 0..b {
                    let arow = &a_q[bi * d_in..(bi + 1) * d_in];
                    let zrow = &mut z[bi * d_out..(bi + 1) * d_out];
                    for (i, &a) in arow.iter().enumerate() {
                        if a == 0.0 {
                            continue;
                        }
                        let wrow = &w_eff[i * d_out..(i + 1) * d_out];
                        for (zv, &wv) in zrow.iter_mut().zip(wrow) {
                            *zv += a * wv;
                        }
                    }
                }
            }
            LayerOp::Dw => {
                // circular depthwise 3-tap conv over the channel axis:
                // z[b,c] = sum_t w[c,t] * a[b, (c + t - 1) mod C]
                for bi in 0..b {
                    let arow = &a_q[bi * d_in..(bi + 1) * d_in];
                    let zrow = &mut z[bi * d_out..(bi + 1) * d_out];
                    for c in 0..d_out {
                        let mut acc = 0.0f32;
                        for t in 0..3usize {
                            let j = (c + t + d_in - 1) % d_in;
                            acc += w_eff[c * 3 + t] * arow[j];
                        }
                        zrow[c] = acc;
                    }
                }
            }
            LayerOp::DwSpatial => {
                // true 2-D spatial depthwise 3x3 conv over the [H, W, C]
                // channel-last block (kernels::dw_spatial_fwd, golden-
                // tested against the jax oracle); the (ky, kx ascending)
                // tap order is the bit-exactness contract shared with
                // the deploy engine's scalar/blocked/streaming kernels.
                let sp = l.spatial.expect("DwSpatial layer without SpatialSpec");
                kernels::dw_spatial_fwd(
                    &a_q, &w_eff, b, sp.hw_in, sp.channels, sp.stride, sp.pad, &mut z,
                );
            }
        }
        if l.bias {
            let bias = req(sources, &format!("params/{}.bias", l.name))?;
            for bi in 0..b {
                for c in 0..d_out {
                    z[bi * d_out + c] += bias.data[c];
                }
            }
        }

        // --- batch norm ---
        let (mut bn_mean, mut bn_var, mut xhat) = (vec![], vec![], vec![]);
        let mut out = if l.bn {
            let g = req(sources, &format!("params/{}.g", l.name))?;
            let beta = req(sources, &format!("params/{}.beta", l.name))?;
            let (mean, var) = match bn_mode {
                BnMode::Batch => batch_stats(&z, b, d_out),
                BnMode::Running => (
                    req(sources, &format!("bn/{}.bn_m", l.name))?.data,
                    req(sources, &format!("bn/{}.bn_v", l.name))?.data,
                ),
            };
            let mut xh = vec![0.0f32; b * d_out];
            let mut o = vec![0.0f32; b * d_out];
            for c in 0..d_out {
                let ivar = 1.0 / (var[c] + BN_EPS).sqrt();
                for bi in 0..b {
                    let idx = bi * d_out + c;
                    let v = (z[idx] - mean[c]) * ivar;
                    xh[idx] = v;
                    o[idx] = g.data[c] * v + beta.data[c];
                }
            }
            bn_mean = mean;
            bn_var = var;
            xhat = xh;
            o
        } else {
            z.clone()
        };

        // --- activation ---
        if l.relu {
            for v in out.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }

        act = out.clone();
        layers.push(LayerFwd {
            a_in,
            a_q,
            w_eff,
            z,
            bn_mean,
            bn_var,
            xhat,
            out,
            act_scales,
            act_scale_shape,
            act_p,
            act_quantized,
            w_scales,
            w_group,
            w_scale_shape: s_t.shape.clone(),
            w_n,
            w_p,
            w_quantized,
        });
    }

    Ok(Forward { layers, logits: act })
}

/// Per-channel biased batch statistics of `z` ([B, C] row-major).
fn batch_stats(z: &[f32], b: usize, c: usize) -> (Vec<f32>, Vec<f32>) {
    let mut mean = vec![0.0f32; c];
    let mut var = vec![0.0f32; c];
    for bi in 0..b {
        for ci in 0..c {
            mean[ci] += z[bi * c + ci];
        }
    }
    for m in mean.iter_mut() {
        *m /= b as f32;
    }
    for bi in 0..b {
        for ci in 0..c {
            let d = z[bi * c + ci] - mean[ci];
            var[ci] += d * d;
        }
    }
    for v in var.iter_mut() {
        *v /= b as f32;
    }
    (mean, var)
}

/// Softmax cross-entropy + accuracy against one-hot labels.
/// Returns (mean CE, correct count, d loss / d logits).
fn softmax_ce(logits: &[f32], y: &[f32], b: usize, c: usize) -> (f32, f32, Vec<f32>) {
    let mut dlogits = vec![0.0f32; b * c];
    let mut ce = 0.0f64;
    let mut correct = 0.0f32;
    for bi in 0..b {
        let row = &logits[bi * c..(bi + 1) * c];
        let yrow = &y[bi * c..(bi + 1) * c];
        let maxv = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let mut denom = 0.0f32;
        for &v in row {
            denom += (v - maxv).exp();
        }
        let mut best = 0usize;
        let mut ybest = 0usize;
        for i in 0..c {
            let p = (row[i] - maxv).exp() / denom;
            if yrow[i] > 0.5 {
                ce -= (p.max(1e-12) as f64).ln();
            }
            dlogits[bi * c + i] = (p - yrow[i]) / b as f32;
            if row[i] > row[best] {
                best = i;
            }
            if yrow[i] > yrow[ybest] {
                ybest = i;
            }
        }
        if best == ybest {
            correct += 1.0;
        }
    }
    ((ce / b as f64) as f32, correct, dlogits)
}

/// Echo every state tensor found in `sources` (keys under the four state
/// groups) into `out` under a `state/` prefix.
fn echo_state(sources: &[&NamedTensors], out: &mut NamedTensors) {
    for src in sources {
        for (k, v) in &src.map {
            if k.starts_with("params/")
                || k.starts_with("opt/")
                || k.starts_with("bn/")
                || k.starts_with("osc/")
            {
                let key = format!("state/{k}");
                if out.get(&key).is_none() {
                    out.insert(key, v.clone());
                }
            }
        }
    }
}

/// One full training step. See the module docs for the exact pipeline.
pub fn train_step(
    model: &NativeModel,
    est: Estimator,
    sources: &[&NamedTensors],
) -> Result<NamedTensors> {
    let h = hyper(sources)?;
    let y = req(sources, "batch/y")?;
    let b = model.batch_size_of(sources)?;
    let c = model.num_classes;

    let fwd = forward(model, sources, &h, BnMode::Batch)?;
    let (ce, correct, dlogits) = softmax_ce(&fwd.logits, &y.data, b, c);

    // dampening regularizer over the low-bit weight tensors
    let mut damp = 0.0f32;
    if h.wq_on && h.lam > 0.0 {
        for l in &model.layers {
            if l.wq != "low" {
                continue;
            }
            let w = req(sources, &format!("params/{}.w", l.name))?;
            let s_t = req(sources, &format!("params/{}.s", l.name))?;
            let scales: Vec<f32> = s_t.data.iter().map(|&v| v.max(1e-8)).collect();
            damp += kernels::dampening_loss_pc(&w.data, &scales, l.scale_group(), h.n_w, h.p_w);
        }
        damp *= h.lam;
    }
    let loss = ce + damp;

    // ---------------- backward ----------------
    // gradients keyed by bare param name ("stem.w", "b1.dw.g", ...)
    let mut grads: NamedTensors = NamedTensors::new();
    let mut dact = dlogits; // gradient w.r.t. the current layer's output

    for (li, l) in model.layers.iter().enumerate().rev() {
        let cache = &fwd.layers[li];
        let d_out = l.d_out;
        let d_in = l.d_in;

        // activation backward
        if l.relu {
            for (dv, &o) in dact.iter_mut().zip(&cache.out) {
                if o <= 0.0 {
                    *dv = 0.0;
                }
            }
        }

        // BN backward (batch statistics)
        let dz = if l.bn {
            let g = req(sources, &format!("params/{}.g", l.name))?;
            let mut dg = vec![0.0f32; d_out];
            let mut dbeta = vec![0.0f32; d_out];
            let mut dzv = vec![0.0f32; b * d_out];
            for ci in 0..d_out {
                let ivar = 1.0 / (cache.bn_var[ci] + BN_EPS).sqrt();
                let mut sum_dxhat = 0.0f32;
                let mut sum_dxhat_xhat = 0.0f32;
                for bi in 0..b {
                    let idx = bi * d_out + ci;
                    let dxhat = dact[idx] * g.data[ci];
                    sum_dxhat += dxhat;
                    sum_dxhat_xhat += dxhat * cache.xhat[idx];
                    dg[ci] += dact[idx] * cache.xhat[idx];
                    dbeta[ci] += dact[idx];
                }
                // dz = ivar/B * (B*dxhat - sum(dxhat) - xhat * sum(dxhat*xhat))
                let binv = 1.0 / b as f32;
                for bi in 0..b {
                    let idx = bi * d_out + ci;
                    let dxhat = dact[idx] * g.data[ci];
                    dzv[idx] = ivar
                        * (dxhat - binv * sum_dxhat - cache.xhat[idx] * binv * sum_dxhat_xhat);
                }
            }
            grads.insert(format!("{}.g", l.name), Tensor::new(vec![d_out], dg));
            grads.insert(format!("{}.beta", l.name), Tensor::new(vec![d_out], dbeta));
            dzv
        } else {
            dact.clone()
        };

        if l.bias {
            let mut dbias = vec![0.0f32; d_out];
            for bi in 0..b {
                for ci in 0..d_out {
                    dbias[ci] += dz[bi * d_out + ci];
                }
            }
            grads.insert(format!("{}.bias", l.name), Tensor::new(vec![d_out], dbias));
        }

        // linear backward: d a_q and d w_eff
        let mut da_q = vec![0.0f32; b * d_in];
        let w = req(sources, &format!("params/{}.w", l.name))?;
        let mut dw_eff = vec![0.0f32; w.len()];
        match l.op {
            LayerOp::Full => {
                for bi in 0..b {
                    let arow = &cache.a_q[bi * d_in..(bi + 1) * d_in];
                    let dzrow = &dz[bi * d_out..(bi + 1) * d_out];
                    let darow = &mut da_q[bi * d_in..(bi + 1) * d_in];
                    for i in 0..d_in {
                        let wrow = &cache.w_eff[i * d_out..(i + 1) * d_out];
                        let dwrow = &mut dw_eff[i * d_out..(i + 1) * d_out];
                        let a = arow[i];
                        let mut acc = 0.0f32;
                        for j in 0..d_out {
                            acc += dzrow[j] * wrow[j];
                            dwrow[j] += a * dzrow[j];
                        }
                        darow[i] = acc;
                    }
                }
            }
            LayerOp::Dw => {
                for bi in 0..b {
                    let arow = &cache.a_q[bi * d_in..(bi + 1) * d_in];
                    let dzrow = &dz[bi * d_out..(bi + 1) * d_out];
                    let darow = &mut da_q[bi * d_in..(bi + 1) * d_in];
                    for ci in 0..d_out {
                        for t in 0..3usize {
                            let j = (ci + t + d_in - 1) % d_in;
                            dw_eff[ci * 3 + t] += dzrow[ci] * arow[j];
                            darow[j] += dzrow[ci] * cache.w_eff[ci * 3 + t];
                        }
                    }
                }
            }
            LayerOp::DwSpatial => {
                // mirror of the forward tap walk (kernels::dw_spatial_bwd,
                // golden-tested against the jax vjp): every (output, tap)
                // pair contributes dz*a to the weight grad and dz*w to
                // the input grad at the same flat index
                let sp = l.spatial.expect("DwSpatial layer without SpatialSpec");
                kernels::dw_spatial_bwd(
                    &cache.a_q,
                    &cache.w_eff,
                    &dz,
                    b,
                    sp.hw_in,
                    sp.channels,
                    sp.stride,
                    sp.pad,
                    &mut dw_eff,
                    &mut da_q,
                );
            }
        }

        // weight fake-quant backward (estimator) + dampening gradient;
        // the step-size gradient mirrors the scale tensor (scalar or
        // per-channel vector)
        let mut dw = vec![0.0f32; w.len()];
        if cache.w_quantized {
            let mut ds = vec![0.0f32; cache.w_scales.len()];
            kernels::fake_quant_bwd_pc(
                est,
                &w.data,
                &dw_eff,
                &cache.w_scales,
                cache.w_group,
                cache.w_n,
                cache.w_p,
                &mut dw,
                &mut ds,
            );
            if l.wq == "low" && h.lam > 0.0 {
                kernels::dampening_bwd_pc(
                    &w.data,
                    &cache.w_scales,
                    cache.w_group,
                    cache.w_n,
                    cache.w_p,
                    h.lam,
                    &mut dw,
                );
            }
            grads.insert(
                format!("{}.s", l.name),
                Tensor::new(cache.w_scale_shape.clone(), ds),
            );
        } else {
            dw.copy_from_slice(&dw_eff);
        }
        grads.insert(format!("{}.w", l.name), Tensor::new(w.shape.clone(), dw));

        // input activation fake-quant backward (unsigned LSQ); the
        // step-size gradient mirrors the scale tensor (scalar or
        // per-channel vector), with per-channel 1/sqrt(N_c*p) scaling
        if cache.act_quantized {
            let mut dsa = vec![0.0f32; cache.act_scales.len()];
            let mut da_in = vec![0.0f32; b * d_in];
            kernels::act_quant_bwd_pc(
                &cache.a_in,
                &da_q,
                &cache.act_scales,
                cache.act_p,
                &mut da_in,
                &mut dsa,
            );
            grads.insert(
                format!("{}.as", l.name),
                Tensor::new(cache.act_scale_shape.clone(), dsa),
            );
            dact = da_in;
        } else {
            dact = da_q;
        }
    }

    // ---------------- SGD + momentum ----------------
    let mut out = NamedTensors::new();
    echo_state(sources, &mut out);
    for (pname, g) in &grads.map {
        // scale parameters only learn while their quantizer is active
        if pname.ends_with(".s") && !h.wq_on {
            continue;
        }
        if pname.ends_with(".as") && !h.aq_on {
            continue;
        }
        let pkey = format!("state/params/{pname}");
        let okey = format!("state/opt/{pname}");
        let mut param = out.expect(&pkey)?.clone();
        let mut mom = out.expect(&okey)?.clone();
        for i in 0..param.len() {
            mom.data[i] = h.mu * mom.data[i] + g.data[i];
            param.data[i] -= h.lr * mom.data[i];
        }
        if pname.ends_with(".s") || pname.ends_with(".as") {
            // LSQ step sizes (per-tensor or per-channel) must stay positive
            for v in param.data.iter_mut() {
                *v = v.max(1e-6);
            }
        }
        out.insert(pkey, param);
        out.insert(okey, mom);
    }

    // ---------------- BN running-stat EMA update ----------------
    for (li, l) in model.layers.iter().enumerate() {
        if !l.bn {
            continue;
        }
        let cache = &fwd.layers[li];
        let mkey = format!("state/bn/{}.bn_m", l.name);
        let vkey = format!("state/bn/{}.bn_v", l.name);
        let mut m = out.expect(&mkey)?.clone();
        let mut v = out.expect(&vkey)?.clone();
        for ci in 0..l.d_out {
            m.data[ci] = (1.0 - h.bn_mom) * m.data[ci] + h.bn_mom * cache.bn_mean[ci];
            v.data[ci] = (1.0 - h.bn_mom) * v.data[ci] + h.bn_mom * cache.bn_var[ci];
        }
        out.insert(mkey, m);
        out.insert(vkey, v);
    }

    // ---------------- Algorithm-1 oscillation / freezing update ----------
    let mut osc_hits = 0usize;
    let mut frozen = 0usize;
    let mut total = 0usize;
    if h.wq_on {
        for l in &model.layers {
            if l.wq != "low" {
                continue;
            }
            let wkey = format!("state/params/{}.w", l.name);
            let mut w = out.expect(&wkey)?.clone();
            let scales: Vec<f32> = out
                .expect(&format!("state/params/{}.s", l.name))?
                .data
                .iter()
                .map(|&v| v.max(1e-8))
                .collect();
            let read = |suffix: &str| -> Result<Vec<f32>> {
                Ok(out
                    .expect(&format!("state/osc/{}.w#{suffix}", l.name))?
                    .data
                    .clone())
            };
            let mut st = OscState {
                f: read("f")?,
                b: read("b")?,
                fint: read("fint")?,
                psign: read("psign")?,
                wintp: read("wintp")?,
                iema: read("iema")?,
            };
            kernels::osc_update_pc(
                &mut w.data,
                &scales,
                l.scale_group(),
                h.n_w,
                h.p_w,
                &mut st,
                h.m_osc,
                h.f_th,
            );
            total += w.len();
            osc_hits += st.f.iter().filter(|&&x| x > crate::osc::OSC_METRIC_TH).count();
            frozen += st.b.iter().filter(|&&x| x > 0.5).count();
            let shape = w.shape.clone();
            out.insert(wkey, w);
            for (suffix, data) in [
                ("f", st.f),
                ("b", st.b),
                ("fint", st.fint),
                ("psign", st.psign),
                ("wintp", st.wintp),
                ("iema", st.iema),
            ] {
                out.insert(
                    format!("state/osc/{}.w#{suffix}", l.name),
                    Tensor::new(shape.clone(), data),
                );
            }
        }
    }

    // ---------------- metrics ----------------
    let acc = correct / b as f32;
    let denom = total.max(1) as f32;
    let mut put = |k: &str, v: f32| out.insert(format!("metrics/{k}"), Tensor::scalar(v));
    put("loss", loss);
    put("ce", ce);
    put("damp", damp);
    put("acc", acc);
    put("osc_frac", if total == 0 { 0.0 } else { osc_hits as f32 / denom });
    put("frozen_frac", if total == 0 { 0.0 } else { frozen as f32 / denom });
    Ok(out)
}

/// Inference pass over one batch: `correct` count, mean CE `loss`, and
/// the per-sample top-1 `pred` (the deploy round-trip's agreement
/// reference).
pub fn eval_step(model: &NativeModel, sources: &[&NamedTensors]) -> Result<NamedTensors> {
    let h = hyper(sources)?;
    let y = req(sources, "batch/y")?;
    let b = model.batch_size_of(sources)?;
    let fwd = forward(model, sources, &h, BnMode::Running)?;
    let c = model.num_classes;
    let (ce, correct, _) = softmax_ce(&fwd.logits, &y.data, b, c);
    let mut preds = Vec::with_capacity(b);
    for bi in 0..b {
        let row = &fwd.logits[bi * c..(bi + 1) * c];
        preds.push(crate::tensor::argmax(row) as f32);
    }
    let mut out = NamedTensors::new();
    out.insert("correct", Tensor::scalar(correct));
    out.insert("loss", Tensor::scalar(ce));
    out.insert("pred", Tensor::new(vec![b], preds));
    Ok(out)
}

/// Train-mode forward emitting per-layer batch BN statistics and per-site
/// calibration activation magnitudes.
pub fn bnstats_step(model: &NativeModel, sources: &[&NamedTensors]) -> Result<NamedTensors> {
    let h = hyper(sources)?;
    let b = model.batch_size_of(sources)?;
    let fwd = forward(model, sources, &h, BnMode::Batch)?;
    let mut out = NamedTensors::new();
    for (li, l) in model.layers.iter().enumerate() {
        let cache = &fwd.layers[li];
        if l.bn {
            out.insert(
                format!("{}.bn_bm", l.name),
                Tensor::new(vec![l.d_out], cache.bn_mean.clone()),
            );
            out.insert(
                format!("{}.bn_bv", l.name),
                Tensor::new(vec![l.d_out], cache.bn_var.clone()),
            );
        }
        if l.aq {
            let n = (b * l.d_in) as f32;
            let absmean = cache.a_in.iter().map(|x| x.abs()).sum::<f32>() / n.max(1.0);
            out.insert(format!("{}.absmean", l.name), Tensor::scalar(absmean));
            // per-input-channel E|x| for per-channel activation-scale
            // calibration (qat::to_per_channel_scales). 1-D layers have
            // one channel per flat input element ([d_in]); spatial
            // depthwise aggregates over positions into [C] (flat element
            // j belongs to channel j % C under the channel-last layout).
            let nc = l.act_channels();
            let mut pc = vec![0.0f32; nc];
            for bi in 0..b {
                for j in 0..l.d_in {
                    pc[j % nc] += cache.a_in[bi * l.d_in + j].abs();
                }
            }
            let inv = 1.0 / ((b * (l.d_in / nc)) as f32).max(1.0);
            for v in pc.iter_mut() {
                *v *= inv;
            }
            out.insert(format!("{}.absmean_pc", l.name), Tensor::new(vec![nc], pc));
        }
    }
    Ok(out)
}

impl NativeModel {
    /// Batch size from the incoming batch tensor (falls back to the model
    /// default when absent).
    fn batch_size_of(&self, sources: &[&NamedTensors]) -> Result<usize> {
        let x = req(sources, "batch/x")?;
        Ok(*x.shape.first().unwrap_or(&self.batch_size))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::model::zoo;

    fn hyper_map(wq_on: bool) -> NamedTensors {
        let mut hm = NamedTensors::new();
        let mut put = |k: &str, v: f32| hm.insert(format!("hyper/{k}"), Tensor::scalar(v));
        put("lr", 0.02);
        put("lam", 0.0);
        put("f_th", 1.1);
        put("m_osc", 0.02);
        put("bn_mom", 0.1);
        put("mu", 0.9);
        put("n_w", -4.0);
        put("p_w", 3.0);
        put("p_a", 7.0);
        put("wq_on", if wq_on { 1.0 } else { 0.0 });
        put("aq_on", 0.0);
        hm
    }

    fn batch(model: &NativeModel) -> NamedTensors {
        let ds = crate::data::Dataset::new(crate::data::DataCfg {
            val_size: 32,
            ..Default::default()
        });
        let bch = ds.train_batch(0, 0);
        let mut io = NamedTensors::new();
        io.insert("batch/x", bch.x);
        io.insert("batch/y", bch.y);
        let _ = model;
        io
    }

    #[test]
    fn train_step_round_trips_state_and_reduces_loss() {
        let models = zoo();
        let m = &models[3]; // efflite: smallest
        let mut state = m.initial_state();
        let hm = hyper_map(false);
        let n_keys = state.len();
        let mut losses = vec![];
        for i in 0..12 {
            let ds = crate::data::Dataset::new(Default::default());
            let bch = ds.train_batch(0, i);
            let mut io = NamedTensors::new();
            io.insert("batch/x", bch.x);
            io.insert("batch/y", bch.y);
            let out = train_step(m, Estimator::Lsq, &[&state, &io, &hm]).unwrap();
            let mut next = NamedTensors::new();
            for (k, v) in out.map {
                if let Some(rest) = k.strip_prefix("state/") {
                    next.insert(rest.to_string(), v);
                } else if k == "metrics/loss" {
                    losses.push(v.item());
                }
            }
            state = next;
            assert_eq!(state.len(), n_keys, "state keys must round-trip");
        }
        assert!(losses.iter().all(|l| l.is_finite()));
        let first: f32 = losses[..3].iter().sum::<f32>() / 3.0;
        let last: f32 = losses[losses.len() - 3..].iter().sum::<f32>() / 3.0;
        assert!(last < first, "loss should drop: {losses:?}");
    }

    #[test]
    fn eval_step_reports_sane_metrics() {
        let models = zoo();
        let m = &models[3];
        let state = m.initial_state();
        let io = batch(m);
        let out = eval_step(m, &[&state, &io, &hyper_map(false)]).unwrap();
        let correct = out.expect("correct").unwrap().item();
        let loss = out.expect("loss").unwrap().item();
        assert!((0.0..=16.0).contains(&correct));
        assert!(loss.is_finite() && loss > 0.0);
    }

    #[test]
    fn bnstats_step_emits_stats_and_absmeans() {
        let models = zoo();
        let m = &models[0]; // mbv2
        let state = m.initial_state();
        let io = batch(m);
        let out = bnstats_step(m, &[&state, &io, &hyper_map(false)]).unwrap();
        assert!(out.get("stem.bn_bm").is_some());
        assert!(out.get("stem.bn_bv").is_some());
        assert!(out.get("b1.dw.absmean").is_some());
        assert!(out.get("head.absmean").is_some());
        let am = out.get("b1.dw.absmean").unwrap().item();
        assert!(am > 0.0 && am.is_finite());
        // per-channel calibration output: one E|x| per input channel,
        // whose mean equals the scalar absmean
        let pc = out.get("b1.dw.absmean_pc").unwrap();
        let d_in = m.layers.iter().find(|l| l.name == "b1.dw").unwrap().d_in;
        assert_eq!(pc.len(), d_in);
        let mean = pc.data.iter().sum::<f32>() / d_in as f32;
        assert!((mean - am).abs() < 1e-4, "pc mean {mean} vs scalar {am}");
        assert!(pc.data.iter().all(|v| v.is_finite() && *v >= 0.0));
    }

    #[test]
    fn spatial_model_trains_and_emits_channel_calibration() {
        let m = crate::runtime::native::model::zoo_model("mbv2_2d").unwrap();
        let mut state = m.initial_state();
        // per-channel activation scales of length C on the spatial dw
        // layers ([d_in] on the 1-D ones), as to_per_channel_scales makes
        for l in &m.layers {
            if l.aq {
                let nc = l.act_channels();
                state.insert(format!("params/{}.as", l.name), Tensor::new(vec![nc], vec![0.5; nc]));
                state.insert(format!("opt/{}.as", l.name), Tensor::zeros(&[nc]));
            }
        }
        let mut hm = hyper_map(true);
        hm.insert("hyper/aq_on", Tensor::scalar(1.0));
        let n_keys = state.len();
        let mut losses = vec![];
        for i in 0..10 {
            let ds = crate::data::Dataset::new(Default::default());
            let bch = ds.train_batch(0, i);
            let mut io = NamedTensors::new();
            io.insert("batch/x", bch.x);
            io.insert("batch/y", bch.y);
            let out = train_step(&m, Estimator::Lsq, &[&state, &io, &hm]).unwrap();
            let mut next = NamedTensors::new();
            for (k, v) in out.map {
                if let Some(rest) = k.strip_prefix("state/") {
                    next.insert(rest.to_string(), v);
                } else if k == "metrics/loss" {
                    losses.push(v.item());
                }
            }
            state = next;
            assert_eq!(state.len(), n_keys, "state keys must round-trip");
        }
        assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
        let first: f32 = losses[..3].iter().sum::<f32>() / 3.0;
        let last: f32 = losses[losses.len() - 3..].iter().sum::<f32>() / 3.0;
        assert!(last < first, "spatial loss should drop: {losses:?}");

        // bnstats: spatial dw sites calibrate per channel ([C]), and the
        // position-aggregated mean matches the scalar absmean
        let io = batch(&m);
        let out = bnstats_step(&m, &[&state, &io, &hm]).unwrap();
        let l = m.layers.iter().find(|l| l.name == "b1.dw").unwrap();
        let c = l.act_channels();
        assert!(c < l.d_in);
        let pc = out.get("b1.dw.absmean_pc").unwrap();
        assert_eq!(pc.len(), c);
        let am = out.get("b1.dw.absmean").unwrap().item();
        let mean = pc.data.iter().sum::<f32>() / c as f32;
        assert!((mean - am).abs() < 1e-4, "pc mean {mean} vs scalar {am}");
    }

    #[test]
    fn spatial_forward_matches_hand_reference() {
        // 2x2 input, 1 channel, stride 1, pad 1 ("same"): each output is
        // a 3x3 window over the zero-padded 2x2 block. Checked against a
        // hand-computed convolution.
        use crate::runtime::native::model::{LayerSpec, SpatialSpec};
        let sp = SpatialSpec { hw_in: 2, channels: 1, stride: 1, pad: 1 };
        let l = LayerSpec {
            name: "t.dw".into(),
            op: LayerOp::DwSpatial,
            kind: "dw",
            d_in: sp.d_in(),
            d_out: sp.d_out(),
            bn: false,
            relu: false,
            wq: "low",
            aq: false,
            bias: false,
            spatial: Some(sp),
        };
        let m = NativeModel {
            name: "t".into(),
            batch_size: 1,
            num_classes: 4,
            input_hw: 2,
            layers: vec![l],
        };
        let mut state = NamedTensors::new();
        // w = [[1,2,3],[4,5,6],[7,8,9]] (single channel)
        state.insert(
            "params/t.dw.w",
            Tensor::new(vec![1, 3, 3], (1..=9).map(|v| v as f32).collect()),
        );
        state.insert("params/t.dw.s", Tensor::scalar(1.0));
        state.insert("opt/t.dw.w", Tensor::zeros(&[1, 3, 3]));
        state.insert("opt/t.dw.s", Tensor::scalar(0.0));
        let mut io = NamedTensors::new();
        // a = [[1,2],[3,4]]
        io.insert("batch/x", Tensor::new(vec![1, 4], vec![1.0, 2.0, 3.0, 4.0]));
        io.insert("batch/y", Tensor::new(vec![1, 4], vec![1.0, 0.0, 0.0, 0.0]));
        let hm = hyper_map(false);
        let out = eval_step(&m, &[&state, &io, &hm]).unwrap();
        assert!(out.expect("loss").unwrap().item().is_finite());
        // forward() is private to the module, so recover z through a
        // train-free eval: logits are the raw conv output here
        let fwd = forward(&m, &[&state, &io, &hm], &hyper(&[&state, &io, &hm]).unwrap(), BnMode::Batch).unwrap();
        // y=0,x=0 window covers padded rows/cols: taps (1,1)..(2,2) ->
        // w5*a11 + w6*a12 + w8*a21 + w9*a22 evaluated per position
        let expect = [
            5.0 * 1.0 + 6.0 * 2.0 + 8.0 * 3.0 + 9.0 * 4.0,
            4.0 * 1.0 + 5.0 * 2.0 + 7.0 * 3.0 + 8.0 * 4.0,
            2.0 * 1.0 + 3.0 * 2.0 + 5.0 * 3.0 + 6.0 * 4.0,
            1.0 * 1.0 + 2.0 * 2.0 + 4.0 * 3.0 + 5.0 * 4.0,
        ];
        for (got, want) in fwd.logits.iter().zip(expect) {
            assert!((got - want).abs() < 1e-5, "{:?} vs {expect:?}", fwd.logits);
        }
    }

    #[test]
    fn per_channel_activation_scales_round_trip_training() {
        // replace every act scale with a [d_in] vector: train_step must
        // run, keep state keys stable, and keep the vector shape on the
        // updated scale + its momentum
        let models = zoo();
        let m = &models[3]; // efflite
        let mut state = m.initial_state();
        for l in &m.layers {
            if l.aq {
                state.insert(
                    format!("params/{}.as", l.name),
                    Tensor::new(vec![l.d_in], vec![0.5; l.d_in]),
                );
                state.insert(format!("opt/{}.as", l.name), Tensor::zeros(&[l.d_in]));
            }
        }
        let mut hm = hyper_map(true);
        hm.insert("hyper/aq_on", Tensor::scalar(1.0));
        let ds = crate::data::Dataset::new(Default::default());
        let bch = ds.train_batch(0, 0);
        let mut io = NamedTensors::new();
        io.insert("batch/x", bch.x);
        io.insert("batch/y", bch.y);
        let n_keys = state.len();
        let out = train_step(m, Estimator::Lsq, &[&state, &io, &hm]).unwrap();
        let mut next = NamedTensors::new();
        for (k, v) in out.map {
            if let Some(rest) = k.strip_prefix("state/") {
                next.insert(rest.to_string(), v);
            }
        }
        assert_eq!(next.len(), n_keys, "state keys must round-trip");
        for l in &m.layers {
            if l.aq {
                let s = next.get(&format!("params/{}.as", l.name)).unwrap();
                assert_eq!(s.len(), l.d_in, "{} act scale stays per-channel", l.name);
                assert!(s.data.iter().all(|&v| v > 0.0), "{} scales positive", l.name);
                let mom = next.get(&format!("opt/{}.as", l.name)).unwrap();
                assert_eq!(mom.len(), l.d_in);
            }
        }
        // eval with the same per-channel scales also runs
        let ev = eval_step(m, &[&next, &batch(m), &hm]).unwrap();
        assert!(ev.expect("loss").unwrap().item().is_finite());
    }
}
