//! Artifact manifests + the global artifact index (artifacts/index.json).

use crate::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape/dtype signature of one tensor in an artifact's flat I/O list.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Per-artifact manifest: ordered input and output signatures.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub hlo_file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

fn specs(j: &Json, what: &str) -> Result<Vec<TensorSpec>> {
    let arr = j.as_arr().with_context(|| format!("manifest {what} not a list"))?;
    arr.iter()
        .map(|e| {
            let name = e
                .get("name")
                .as_str()
                .with_context(|| format!("{what} entry missing name"))?
                .to_string();
            let shape = e
                .get("shape")
                .as_arr()
                .with_context(|| format!("{what} {name} missing shape"))?
                .iter()
                .map(|d| d.as_usize().context("bad dim"))
                .collect::<Result<Vec<_>>>()?;
            Ok(TensorSpec { name, shape })
        })
        .collect()
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = json::parse(&text).with_context(|| format!("parse {}", path.display()))?;
        Ok(Manifest {
            name: j.get("name").as_str().context("manifest missing name")?.into(),
            hlo_file: j.get("hlo").as_str().context("manifest missing hlo")?.into(),
            inputs: specs(j.get("inputs"), "inputs")?,
            outputs: specs(j.get("outputs"), "outputs")?,
        })
    }
}

/// Static metadata about one model emitted by aot.py (layer kinds, the
/// low-bit weight list the oscillation machinery acts on, artifact names).
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub batch_size: usize,
    pub num_classes: usize,
    pub input_hw: usize,
    pub param_count: usize,
    pub params_bin: String,
    /// weight-tensor names on the runtime low-bit grid
    pub lowbit: Vec<String>,
    /// layer name -> (kind, weight tensor, has_bn, cout, wq)
    pub layers: BTreeMap<String, LayerInfo>,
    /// role -> artifact name, e.g. "train_lsq" -> "mbv2_lsq_train"
    pub artifacts: BTreeMap<String, String>,
}

#[derive(Debug, Clone)]
pub struct LayerInfo {
    pub kind: String,
    pub weight: String,
    pub bn: bool,
    pub cout: usize,
    pub wq: String,
}

impl ModelInfo {
    /// Depthwise conv layers — the paper's oscillation hot spots.
    pub fn depthwise(&self) -> Vec<&str> {
        self.layers
            .iter()
            .filter(|(_, l)| l.kind == "dw")
            .map(|(n, _)| n.as_str())
            .collect()
    }

    pub fn pointwise(&self) -> Vec<&str> {
        self.layers
            .iter()
            .filter(|(_, l)| l.kind == "pw")
            .map(|(n, _)| n.as_str())
            .collect()
    }
}

/// The parsed artifacts/index.json.
#[derive(Debug, Clone)]
pub struct ArtifactIndex {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelInfo>,
    /// kernel-bench artifact names (name -> artifact)
    pub kernels: BTreeMap<String, String>,
}

impl ArtifactIndex {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("index.json"))
            .with_context(|| format!("read {}/index.json — run `make artifacts`", dir.display()))?;
        let j = json::parse(&text)?;
        let mut models = BTreeMap::new();
        let jm = j.get("models").as_obj().context("index missing models")?;
        for (name, m) in jm {
            let layers = m
                .get("layers")
                .as_obj()
                .context("model missing layers")?
                .iter()
                .map(|(ln, l)| {
                    (
                        ln.clone(),
                        LayerInfo {
                            kind: l.get("kind").as_str().unwrap_or("?").into(),
                            weight: l.get("weight").as_str().unwrap_or("").into(),
                            bn: matches!(l.get("bn"), Json::Bool(true)),
                            cout: l.get("cout").as_usize().unwrap_or(0),
                            wq: l.get("wq").as_str().unwrap_or("").into(),
                        },
                    )
                })
                .collect();
            let artifacts = m
                .get("artifacts")
                .as_obj()
                .context("model missing artifacts")?
                .iter()
                .map(|(k, v)| (k.clone(), v.as_str().unwrap_or("").to_string()))
                .collect();
            let lowbit = m
                .get("lowbit")
                .as_arr()
                .context("model missing lowbit")?
                .iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect();
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    batch_size: m.get("batch_size").as_usize().unwrap_or(16),
                    num_classes: m.get("num_classes").as_usize().unwrap_or(10),
                    input_hw: m.get("input_hw").as_usize().unwrap_or(16),
                    param_count: m.get("param_count").as_usize().unwrap_or(0),
                    params_bin: m.get("params_bin").as_str().unwrap_or("").into(),
                    lowbit,
                    layers,
                    artifacts,
                },
            );
        }
        let kernels = j
            .get("kernels")
            .as_obj()
            .map(|o| {
                o.iter()
                    .map(|(k, v)| (k.clone(), v.as_str().unwrap_or("").to_string()))
                    .collect()
            })
            .unwrap_or_default();
        if models.is_empty() {
            bail!("artifact index has no models");
        }
        Ok(ArtifactIndex { dir: dir.to_path_buf(), models, kernels })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .with_context(|| format!("model {name:?} not in index (have {:?})",
                                     self.models.keys().collect::<Vec<_>>()))
    }
}
