//! Training-state ownership: named tensors, the QTNS initial-state format,
//! and checkpointing.
//!
//! All mutable state of a run — parameters, SGD momenta, BN running stats,
//! Algorithm-1 oscillation state — lives here between steps, keyed by the
//! same `group/tensor` names the artifact manifests use
//! (`params/stem.w`, `osc/b1.dw.w#f`, ...). The artifacts are pure
//! functions; the coordinator threads this struct through them.

use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

/// An ordered name -> tensor map (BTreeMap: deterministic iteration).
#[derive(Debug, Clone, Default)]
pub struct NamedTensors {
    pub map: BTreeMap<String, Tensor>,
}

impl NamedTensors {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.map.get(name)
    }

    pub fn expect(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).with_context(|| format!("missing tensor {name:?}"))
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        self.map.insert(name.into(), t);
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// All names with a given prefix (e.g. `params/`).
    pub fn names_under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> {
        self.map
            .keys()
            .filter(move |k| k.starts_with(prefix))
            .map(|k| k.as_str())
    }

    /// Total number of f32 elements.
    pub fn num_elements(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    // ---------------------------------------------------------------
    // QTNS binary format (shared with python/compile/aot.py::write_qtns):
    // magic 'QTNS', u32 version, u32 count, then per tensor:
    //   u16 name_len, name utf8, u8 dtype (0 = f32), u8 ndim,
    //   u32 dims..., f32 LE data.

    pub fn read_qtns(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::from_qtns_bytes(&buf)
    }

    pub fn from_qtns_bytes(buf: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > buf.len() {
                bail!("qtns truncated at byte {}", *pos);
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 4)? != b"QTNS" {
            bail!("bad qtns magic");
        }
        let version = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?);
        if version != 1 {
            bail!("unsupported qtns version {version}");
        }
        let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        let mut out = NamedTensors::new();
        for _ in 0..count {
            let name_len =
                u16::from_le_bytes(take(&mut pos, 2)?.try_into()?) as usize;
            let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())?;
            let dtype = take(&mut pos, 1)?[0];
            if dtype != 0 {
                bail!("tensor {name}: unsupported dtype {dtype}");
            }
            let ndim = take(&mut pos, 1)?[0] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize);
            }
            let n: usize = shape.iter().product();
            let raw = take(&mut pos, n * 4)?;
            let mut data = Vec::with_capacity(n);
            for c in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes(c.try_into()?));
            }
            out.insert(name, Tensor::new(shape, data));
        }
        if pos != buf.len() {
            bail!("qtns trailing bytes ({} of {})", buf.len() - pos, buf.len());
        }
        Ok(out)
    }

    pub fn write_qtns(&self, path: &Path) -> Result<()> {
        let mut buf = Vec::with_capacity(self.num_elements() * 4 + 64);
        buf.extend_from_slice(b"QTNS");
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&(self.map.len() as u32).to_le_bytes());
        for (name, t) in &self.map {
            let nb = name.as_bytes();
            buf.extend_from_slice(&(nb.len() as u16).to_le_bytes());
            buf.extend_from_slice(nb);
            buf.push(0); // dtype f32
            buf.push(t.shape.len() as u8);
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &v in &t.data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(&buf)?;
        Ok(())
    }
}

/// Checkpoint = QTNS state file + sidecar metadata. Used to reuse the FP
/// pretraining across every QAT table row (paper workflow: pretrained FP
/// net -> range estimation -> QAT fine-tune).
pub struct Checkpoint;

impl Checkpoint {
    pub fn save(dir: &Path, tag: &str, state: &NamedTensors, step: u64) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        state.write_qtns(&dir.join(format!("{tag}.qtns")))?;
        std::fs::write(dir.join(format!("{tag}.meta")), format!("step={step}\n"))?;
        Ok(())
    }

    pub fn load(dir: &Path, tag: &str) -> Result<NamedTensors> {
        NamedTensors::read_qtns(&dir.join(format!("{tag}.qtns")))
    }

    pub fn exists(dir: &Path, tag: &str) -> bool {
        dir.join(format!("{tag}.qtns")).exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> NamedTensors {
        let mut s = NamedTensors::new();
        s.insert("params/w", Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        s.insert("params/s", Tensor::scalar(0.05));
        s.insert("osc/w#f", Tensor::zeros(&[2, 3]));
        s
    }

    #[test]
    fn qtns_roundtrip() {
        let dir = std::env::temp_dir().join("qat_state_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.qtns");
        let s = sample();
        s.write_qtns(&p).unwrap();
        let s2 = NamedTensors::read_qtns(&p).unwrap();
        assert_eq!(s.map, s2.map);
    }

    #[test]
    fn qtns_rejects_corrupt() {
        assert!(NamedTensors::from_qtns_bytes(b"NOPE").is_err());
        let mut buf = Vec::new();
        buf.extend_from_slice(b"QTNS");
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&5u32.to_le_bytes()); // claims 5 tensors, has 0
        assert!(NamedTensors::from_qtns_bytes(&buf).is_err());
    }

    #[test]
    fn names_under_prefix() {
        let s = sample();
        let names: Vec<_> = s.names_under("params/").collect();
        assert_eq!(names, vec!["params/s", "params/w"]);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join("qat_ckpt_test");
        let s = sample();
        Checkpoint::save(&dir, "fp_seed0", &s, 42).unwrap();
        assert!(Checkpoint::exists(&dir, "fp_seed0"));
        let s2 = Checkpoint::load(&dir, "fp_seed0").unwrap();
        assert_eq!(s.map, s2.map);
    }
}
